"""Quickstart: build a FreshDiskANN system, stream updates, search.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.system import bootstrap_system
from repro.data.pipelines import vector_stream

DIM, N = 32, 2048


def main():
    # 1. A corpus of vectors (any embedding source works the same way).
    stream = vector_stream(N, DIM, seed=1)
    corpus = next(stream)

    # 2. Bootstrap: static DiskANN-style build of the Long-Term Index.
    cfg = SystemConfig(
        index=IndexConfig(capacity=4 * N, dim=DIM, R=24, L_build=32,
                          L_search=72, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=64, kmeans_iters=6),
        ro_snapshot_points=256, merge_threshold=512,
        temp_capacity=1024, insert_batch=64)
    system = bootstrap_system(corpus, np.arange(N), cfg)
    print(f"bootstrapped {N} points")

    # 3. Live updates: inserts go to the in-memory TempIndex (sub-ms),
    #    deletes to the DeleteList (instant).  A background StreamingMerge
    #    folds them into the LTI when enough accumulate.
    fresh = next(vector_stream(512, DIM, seed=2))
    for i, v in enumerate(fresh):
        system.insert(N + i, v)
    for ext_id in range(0, 200):
        system.delete(ext_id)
    print(f"after updates: size={system.size} merges={system.stats.merges}")

    # 4. Search spans LTI + TempIndex and filters deleted ids.
    queries = next(vector_stream(16, DIM, seed=3))
    ids, dists = system.search(queries, k=5)
    print("top-5 ids for query 0:", ids[0])

    # 5. Verify against exact ground truth over the live set.
    live_ids = np.array([e for e in range(N + 512)
                         if e >= 200 and (e < N or e - N < 512)])
    live_vecs = np.concatenate([corpus[200:], fresh])
    gt = brute_force(jnp.asarray(live_vecs),
                     jnp.ones(len(live_vecs), bool),
                     jnp.asarray(queries), 5)
    gt_ext = live_ids[np.asarray(gt)]
    print(f"5-recall@5 vs brute force: "
          f"{float(recall_at_k(jnp.asarray(ids), jnp.asarray(gt_ext))):.3f}")


if __name__ == "__main__":
    main()
