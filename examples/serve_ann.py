"""End-to-end serving driver: sustained concurrent insert/delete/search
stream against a FreshDiskANN system with background merges — the paper's
§6.2 steady-state experiment at CPU scale.

    PYTHONPATH=src python examples/serve_ann.py --minutes 0.5

By default every search batch rides the unified §5.2 fan-out: the RW tier,
all frozen RO snapshots, AND the PQ-navigated LTI lane as ONE jitted device
program per micro-batch (watch the ``disp/batch`` column sit at 1.0 however
many tiers are live).  ``--split-fanout`` switches to the sequential
per-tier oracle — bit-identical results, one device program per tier.
``--batch-queries N`` serves requests in fixed-shape micro-batches of N;
``--shard-lti N`` row-shards the LTI lane over N devices (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to try it on CPU —
the docs/SERVING.md recipe); ``--autotune-beam`` lets the system pick the
beam width W by probing the unified program (architecture:
docs/ARCHITECTURE.md; serving guide: docs/SERVING.md).
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.system import bootstrap_system
from repro.data.pipelines import vector_stream

DIM = 32


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=float, default=0.5)
    ap.add_argument("--points", type=int, default=2048)
    ap.add_argument("--split-fanout", action="store_true",
                    help="sequential per-tier search loop (the bit-parity "
                         "oracle) instead of the one-program unified fan-out")
    ap.add_argument("--autotune-beam", action="store_true",
                    help="calibrate the beam width W against the unified "
                         "fan-out program instead of using the static W")
    ap.add_argument("--batch-queries", type=int, default=0,
                    help="fixed serving micro-batch width (0 = natural "
                         "request shape); search_dispatches counts "
                         "ceil(B/N) programs per request")
    ap.add_argument("--shard-lti", type=int, default=0,
                    help="row-shard the LTI lane over this many devices "
                         "(capped at the device census; 0 = off)")
    args = ap.parse_args()
    n = args.points

    corpus = next(vector_stream(n, DIM, seed=1))
    cfg = SystemConfig(
        index=IndexConfig(capacity=8 * n, dim=DIM, R=24, L_build=32,
                          L_search=48, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=64, kmeans_iters=6),
        ro_snapshot_points=n // 8, merge_threshold=n // 4,
        temp_capacity=n, insert_batch=64,
        batch_fanout=not args.split_fanout,
        autotune_beam=args.autotune_beam,
        batch_queries=args.batch_queries, shard_lti=args.shard_lti)
    system = bootstrap_system(corpus, np.arange(n), cfg)
    live = dict(enumerate(corpus))
    upd = vector_stream(64, DIM, seed=7)
    qs = vector_stream(16, DIM, seed=9)
    rng = np.random.default_rng(0)

    next_id = n
    deadline = time.time() + args.minutes * 60
    ins_lat, recalls = [], []
    cycle = searches = 0
    while time.time() < deadline:
        batch = next(upd)
        for v in batch:                      # steady state: equal in/out
            t = time.perf_counter()
            system.insert(next_id, v)
            ins_lat.append(time.perf_counter() - t)
            live[next_id] = v
            next_id += 1
        victims = rng.choice(sorted(live), 64, replace=False)
        for e in victims:
            system.delete(int(e))
            live.pop(int(e))
        cycle += 1
        if cycle % 4 == 0:
            q = next(qs)
            t = time.perf_counter()
            ids, _ = system.search_batch(q, k=5)
            s_lat = time.perf_counter() - t
            searches += 1
            keys = np.asarray(sorted(live))
            mat = np.stack([live[k] for k in keys])
            gt = brute_force(jnp.asarray(mat), jnp.ones(len(keys), bool),
                             jnp.asarray(q), 5)
            rec = float(recall_at_k(jnp.asarray(ids),
                                    jnp.asarray(keys[np.asarray(gt)])))
            recalls.append(rec)
            print(f"[steady-state] t={time.time() - deadline + args.minutes * 60:5.0f}s "
                  f"size={system.size} recall@5={rec:.3f} "
                  f"search={s_lat * 1e3:.0f}ms "
                  f"disp/batch={system.stats.search_dispatches / searches:.1f} "
                  f"ins_p50={np.median(ins_lat) * 1e3:.1f}ms "
                  f"merges={system.stats.merges}")
    mode = "split" if args.split_fanout else "unified"
    if system.lti_shards:
        mode += f" x {system.lti_shards}-shard LTI lane"
    print(f"final: mean recall {np.mean(recalls):.3f}, "
          f"{system.stats.inserts} inserts, {system.stats.deletes} deletes, "
          f"{system.stats.merges} merges, {mode} fan-out: "
          f"{system.stats.search_dispatches / max(searches, 1):.1f} "
          f"device programs per search batch")


if __name__ == "__main__":
    main()
