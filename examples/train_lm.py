"""End-to-end LM training driver: a ~100M-param qwen-family model trained
for a few hundred steps on the synthetic token stream, with checkpointing
and crash-safe resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(CPU: use --small for a fast demonstration run.)
"""
import argparse

import jax

from repro.data.pipelines import lm_token_stream
from repro.distributed.ctx import activation_sharding
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import TransformerConfig, init_params, lm_loss
from repro.optim.adamw import adamw_init
from repro.training.loop import run_training
from repro.training.steps import make_train_step


def config(small: bool) -> TransformerConfig:
    if small:
        return TransformerConfig(
            name="lm-demo-small", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, d_head=32, d_ff=256, vocab=2048, qk_norm=True,
            pattern=("g",), q_chunk=64, kv_chunk=64, dtype="float32")
    # ~100M params: 12L x 512 with a 32k vocab
    return TransformerConfig(
        name="lm-demo-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=4, d_head=64, d_ff=2048, vocab=32768, qk_norm=True,
        pattern=("g",), q_chunk=128, kv_chunk=128, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = config(args.small)
    if args.small:
        args.seq = min(args.seq, 64)
    mesh = make_host_mesh()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}: {n_params / 1e6:.1f}M params")
    opt = adamw_init(params)
    step = make_train_step(
        lambda p, b: lm_loss(p, b["tokens"], b["targets"], cfg), lr=1e-3)

    def wrapped(p, o, b):
        with activation_sharding(mesh):
            return step(p, o, b)

    jit_step = jax.jit(wrapped, donate_argnums=(0, 1))
    params, opt, log = run_training(
        mesh, jit_step, params, opt,
        lambda s: lm_token_stream(args.batch, args.seq, cfg.vocab,
                                  start_step=s),
        n_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100)
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
