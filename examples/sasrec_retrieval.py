"""The showcase paper-technique integration: SASRec sequential recommender
whose candidate retrieval runs through a *streaming* FreshDiskANN index of
item embeddings.

New items are inserted into the index online; retired items are deleted;
the recommender's query vector (the encoder's final hidden state) searches
the fresh index — exactly the fresh-ANNS problem the paper solves.
Compares ANN retrieval against exact brute-force scoring.

    PYTHONPATH=src python examples/sasrec_retrieval.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.system import bootstrap_system
from repro.data.pipelines import sasrec_stream
from repro.models import recsys as rec
from repro.optim.adamw import adamw_init, adamw_update


def main():
    cfg = get_arch("sasrec").smoke_config
    n_items = cfg.n_items
    params = rec.init_recsys_params(jax.random.PRNGKey(0), cfg)

    # --- 1. train SASRec briefly on the synthetic interaction stream -----
    stream = sasrec_stream(64, cfg.seq_len, n_items, seed=2)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(
            lambda pp: rec.sasrec_loss(pp, b["seq"], b["pos"], b["neg"],
                                       cfg))(p)
        p, o = adamw_update(p, grads, o, lr=5e-3, weight_decay=0.0)
        return p, o, loss

    opt = adamw_init(params)
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, loss = step(params, opt, b)
    print(f"[sasrec] trained 40 steps, BPR loss {float(loss):.4f}")

    # --- 2. index the item embeddings in FreshDiskANN --------------------
    items = np.asarray(params["item_emb"])
    # cosine/IP retrieval -> L2 on normalized vectors (paper: "identical
    # when the data is normalized")
    norm = items / np.maximum(np.linalg.norm(items, axis=1, keepdims=True),
                              1e-6)
    scfg = SystemConfig(
        index=IndexConfig(capacity=4 * n_items, dim=cfg.embed_dim, R=24,
                          L_build=32, L_search=64, alpha=1.2),
        pq=PQConfig(dim=cfg.embed_dim, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=128, merge_threshold=256,
        temp_capacity=1024, insert_batch=64)
    index = bootstrap_system(norm[1:], np.arange(1, n_items), scfg)
    print(f"[sasrec] indexed {n_items - 1} items")

    # --- 3. streaming catalog updates: new items in, retired items out ---
    rng = np.random.default_rng(5)
    new_vecs = rng.standard_normal((64, cfg.embed_dim)).astype(np.float32)
    new_vecs /= np.linalg.norm(new_vecs, axis=1, keepdims=True)
    for i, v in enumerate(new_vecs):
        index.insert(n_items + i, v)
    retired = rng.choice(np.arange(1, n_items), 64, replace=False)
    for e in retired:
        index.delete(int(e))
    print(f"[sasrec] +64 new items, -64 retired (live size {index.size})")

    # --- 4. retrieval: encoder query -> fresh index -----------------------
    q_seq = b["seq"][:8]
    qv = np.asarray(rec.sasrec_user_embedding(params, q_seq, cfg))
    qv = qv / np.maximum(np.linalg.norm(qv, axis=1, keepdims=True), 1e-6)
    ann_ids, _ = index.search(qv, k=10)

    # exact baseline over the live catalog (incl. new, excl. retired)
    old_live = np.setdiff1d(np.arange(1, n_items), retired)
    live = np.concatenate([old_live, np.arange(n_items, n_items + 64)])
    table = np.concatenate([norm[old_live], new_vecs])
    scores = qv @ table.T
    exact = live[np.argsort(-scores, axis=1)[:, :10]]

    inter = np.mean([len(set(a.tolist()) & set(e.tolist())) / 10
                     for a, e in zip(np.asarray(ann_ids), exact)])
    print(f"[sasrec] ANN-vs-exact top-10 overlap: {inter:.2f}")
    print(f"[sasrec] retired items absent from results: "
          f"{not np.isin(np.asarray(ann_ids), retired).any()}")


if __name__ == "__main__":
    main()
