#!/usr/bin/env bash
# CI smoke: docs reference check + tier-1 tests + a short kernel-path
# throughput probe.
#
# REPRO_PALLAS_INTERPRET=1 forces the Pallas kernels through the interpreter,
# so kernel-path regressions (shape/padding/semantics) surface on any CPU box
# without a TPU.  The bench probe builds a small LTI and runs the beam-width
# sweep with the kernels enabled — ~30s end to end.
#
# `smoke.sh --shards` runs the sharded-serving probe instead: 4 fake host
# devices (XLA_FLAGS) + scripts/shard_probe.py asserting the shard-count
# invariance / dispatch / micro-batching contracts of docs/SERVING.md.
#
# `smoke.sh --serving` runs the serving-front-end probe instead: 4 fake host
# devices + scripts/serving_probe.py asserting the continuous-batching
# scheduler's virtual-clock invariants (deadline-aware close, backpressure
# shed, scheduled-vs-direct bit-parity) and multi-replica routing (1/2/4
# replica parity, 2x2 replica-x-shard composition, round-robin accounting,
# background-merge survival) — contracts of docs/SERVING.md.
#
# `smoke.sh --disk` runs the storage-tier probe instead: a tiny system with
# storage_dir set + scripts/disk_probe.py asserting bit-parity at prefetch
# depths 0/1/2, the read/cache-hit conservation law, delta patching, and
# staging-buffer reuse (contracts of docs/STORAGE.md).
#
# `smoke.sh --locality` runs the locality-aware update batching probe
# instead: two systems differing only in SystemConfig.locality_order driven
# through the same clustered stream + scripts/locality_probe.py asserting
# seeded-permutation determinism, bucketed prune-launch reduction, storage
# delta coherence, and recall equivalence (contracts of
# docs/ARCHITECTURE.md, "Update-path locality").
#
# `smoke.sh --filters` runs the filtered/multi-tenant probe instead: 4 fake
# host devices + scripts/filter_probe.py asserting selectivity-1.0 bit-parity
# (direct + replica-routed), tenant isolation across tiers, post-merge label
# survival, and the scheduler's single-spec batch closes + tenant-quota
# sheds (contracts of docs/ARCHITECTURE.md, "Filtered & multi-tenant
# search").
#
# `smoke.sh --local-repair` runs the localized delete-repair probe instead:
# two systems routed always-local vs always-global through interleaved
# inserts/deletes/merges + scripts/local_repair_probe.py asserting merge
# bit-parity across the routing, the repair counters, the reachability
# gauge, and standalone consolidate() (contracts of docs/ARCHITECTURE.md,
# "Localized delete repair").
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export REPRO_PALLAS_INTERPRET=1

if [[ "${1:-}" == "--shards" ]]; then
  XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python scripts/shard_probe.py
  exit 0
fi

if [[ "${1:-}" == "--serving" ]]; then
  XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python scripts/serving_probe.py
  exit 0
fi

if [[ "${1:-}" == "--filters" ]]; then
  XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python scripts/filter_probe.py
  exit 0
fi

if [[ "${1:-}" == "--disk" ]]; then
  python scripts/disk_probe.py
  exit 0
fi

if [[ "${1:-}" == "--local-repair" ]]; then
  python scripts/local_repair_probe.py
  exit 0
fi

if [[ "${1:-}" == "--locality" ]]; then
  python scripts/locality_probe.py
  exit 0
fi

# Docs first (cheapest): docs/*.md + README references (file paths, links,
# file.py::symbol refs, python snippets) must match the tree.
python scripts/check_docs.py

# Kernel probe next: surfaces kernel-path regressions even when an
# unrelated (e.g. env-dependent) test failure would abort the -x suite run.
python - <<'PY'
import time
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, default_pq, queryset
from benchmarks.bench_throughput import beam_sweep
from repro.core.config import IndexConfig
from repro.core.lti import build_lti

t0 = time.time()
n, dim = 600, 32
cfg = IndexConfig(capacity=2 * n, dim=dim, R=20, L_build=24, L_search=32,
                  alpha=1.2, use_kernel=True)   # force the Pallas ops path
lti = build_lti(dataset(n, dim), cfg, default_pq(dim), batch=64)
beam_sweep(lti, cfg, queryset(16, dim), widths=(1, 4), tag="smoke_beam")

# Fused frontier_select: Pallas (interpret) must match the jnp contract
# bit-for-bit on an engine-shaped input, including INVALID-padded lanes.
from repro.kernels import ops
rng = np.random.default_rng(0)
L, K, V, W = 16, 24, 30, 4
cand_i = jnp.asarray(np.concatenate([rng.permutation(100)[:8],
                                     np.full(L - 8, -1)]).astype(np.int32))
cand_d = jnp.asarray(np.concatenate([np.sort(rng.random(8)),
                                     np.full(L - 8, np.inf)]).astype(np.float32))
new_i = jnp.asarray(np.concatenate([200 + rng.permutation(100)[:12],
                                    np.full(K - 12, -1)]).astype(np.int32))
new_d = jnp.asarray(np.concatenate([rng.random(12),
                                    np.full(K - 12, np.inf)]).astype(np.float32))
vis_i = jnp.full((V,), -1, jnp.int32).at[0].set(cand_i[0])
vis_d = jnp.full((V,), jnp.inf, jnp.float32).at[0].set(cand_d[0])
a = ops.frontier_select(cand_i, cand_d, new_i, new_d, vis_i, vis_d,
                        jnp.int32(1), W=W, max_visits=V, use_kernel=True)
b = ops.frontier_select(cand_i, cand_d, new_i, new_d, vis_i, vis_d,
                        jnp.int32(1), W=W, max_visits=V, use_kernel=False)
for x, y in zip(a, b):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

# Mutation engine: fused robust_prune (Pallas interpret) must match the jnp
# oracle bit-for-bit on an engine-shaped candidate row.
C, d, Rp = 48, 16, 8
vecs = jnp.asarray(rng.standard_normal((C, d)).astype(np.float32))
ids = jnp.asarray(rng.permutation(1000)[:C].astype(np.int32))
ok = jnp.asarray(rng.random(C) > 0.3)
anchor = jnp.asarray(rng.standard_normal(d).astype(np.float32))
diff = anchor[None] - vecs
d_p = jnp.sum(diff * diff, -1)
pw = ops.robust_prune_fp(d_p[None], vecs[None], ids[None], ok[None],
                         alpha=1.2, R=Rp, use_kernel=False)
pg = ops.robust_prune_fp(d_p[None], vecs[None], ids[None], ok[None],
                         alpha=1.2, R=Rp, use_kernel=True)
np.testing.assert_array_equal(np.asarray(pw[0]), np.asarray(pg[0]))
np.testing.assert_array_equal(np.asarray(pw[1]), np.asarray(pg[1]))
print(f"# kernel-path smoke ok in {time.time() - t0:.1f}s")
PY

python -m pytest -x -q
