#!/usr/bin/env bash
# CI smoke: tier-1 tests + a short kernel-path throughput probe.
#
# REPRO_PALLAS_INTERPRET=1 forces the Pallas kernels through the interpreter,
# so kernel-path regressions (shape/padding/semantics) surface on any CPU box
# without a TPU.  The bench probe builds a small LTI and runs the beam-width
# sweep with the kernels enabled — ~30s end to end.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export REPRO_PALLAS_INTERPRET=1

# Kernel probe first: surfaces kernel-path regressions even when an
# unrelated (e.g. env-dependent) test failure would abort the -x suite run.
python - <<'PY'
import time
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, default_pq, queryset
from benchmarks.bench_throughput import beam_sweep
from repro.core.config import IndexConfig
from repro.core.lti import build_lti

t0 = time.time()
n, dim = 600, 32
cfg = IndexConfig(capacity=2 * n, dim=dim, R=20, L_build=24, L_search=32,
                  alpha=1.2, use_kernel=True)   # force the Pallas ops path
lti = build_lti(dataset(n, dim), cfg, default_pq(dim), batch=64)
beam_sweep(lti, cfg, queryset(16, dim), widths=(1, 4), tag="smoke_beam")
print(f"# kernel-path smoke ok in {time.time() - t0:.1f}s")
PY

python -m pytest -x -q
