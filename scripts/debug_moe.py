import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import re
import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import get_arch
from repro.launch.build import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_parse import analyze_text, parse_module, \
    _shape_elems_bytes, _TRIP_RE
from repro.configs.common import ArchSpec, Cell, lm_cells

arch0 = get_arch("mixtral-8x7b")
cfg = dataclasses.replace(arch0.full_config, n_layers=2)
arch = ArchSpec("mixtral-2l", "lm", cfg, arch0.smoke_config, lm_cells(cfg))
mesh = make_production_mesh()
built = build_cell(arch, arch.cell("train_4k"), mesh)
with mesh:
    compiled = jax.jit(built.fn, donate_argnums=built.donate).lower(
        *built.args).compile()
txt = compiled.as_text()
open("/tmp/moe_hlo.txt", "w").write(txt)

# collective ops with metadata provenance, weighted by trip counts
comps = parse_module(txt)
entry = comps.pop("__entry_name__")
sizes = {c: {o.name: _shape_elems_bytes(o.type_str)[1] for o in ops}
         for c, ops in comps.items()}
out = []

def walk(cname, count):
    for op in comps.get(cname, []):
        if op.opcode == "while":
            tm = _TRIP_RE.search(op.args_str)
            trip = int(tm.group(1)) if tm else 1
            bm = re.search(r"body=%?([\w.\-]+)", op.args_str)
            if bm:
                walk(bm.group(1), count * trip)
            continue
        base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
        if base in ("all-reduce", "all-gather", "all-to-all",
                    "reduce-scatter", "collective-permute"):
            b = sum(sizes[cname].get(o, 0) for o in op.operands)
            m = re.search(r'op_name="([^"]+)"', op.args_str)
            out.append((count * b, base, count,
                        m.group(1) if m else "?"))

walk(entry, 1.0)
out.sort(reverse=True)
total = sum(o[0] for o in out)
print(f"total coll bytes: {total:.3e}")
for b, kind, cnt, name in out[:25]:
    print(f"{b/2**30:9.2f} GiB x{cnt:4.0f} {kind:18s} {name[:130]}")
