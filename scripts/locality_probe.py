#!/usr/bin/env python
"""Locality-aware update batching probe (``scripts/smoke.sh --locality``).

Builds two small live FreshDiskANN systems that differ only in
``SystemConfig.locality_order`` and drives them through the same clustered
insert/delete/merge stream, asserting the contracts of
docs/ARCHITECTURE.md, "Update-path locality", end to end:

  1. determinism — ``locality_order`` is a permutation and bit-stable for a
     fixed (batch, seed), and a SECOND locality system driven through the
     identical op stream lands a bit-identical LTI adjacency (the
     proximity schedule is seeded, never clock- or thread-dependent);
  2. work reduction — the locality system's flush + merge Delta prunes
     launch strictly fewer rows than the arrival-order worst case, with
     the distinct-target counters accumulating on both systems;
  3. storage — with ``storage_dir`` set, merges patch the delta only:
     rows patched stay well below a full rewrite, the 4KB block counter
     tracks the row counter, and the locality system does not patch more
     rows than the arrival-order system (same logical update stream);
  4. recall equivalence — after the full stream, both systems serve the
     same clustered queries with recall within a small tolerance of each
     other (topology differs; quality must not).

Exits non-zero on the first violated contract.  The same invariants run
as tier-1 tests in ``tests/test_locality.py``; this probe is the
CI-visible end-to-end pass, mirroring disk_probe.py /
local_repair_probe.py.
"""
import os
import sys
import tempfile

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np                                    # noqa: E402
import jax.numpy as jnp                               # noqa: E402

from repro.core.config import (IndexConfig, PQConfig,  # noqa: E402
                               SystemConfig)
from repro.core.locality import locality_order        # noqa: E402
from repro.core.system import bootstrap_system        # noqa: E402

DIM = 24
N_CENTERS = 16


def make_points(rng, n, spread=0.25):
    centers = rng.standard_normal((N_CENTERS, DIM)) * 4.0
    which = rng.integers(0, N_CENTERS, n)
    return (centers[which] + spread * rng.standard_normal((n, DIM))
            ).astype(np.float32)


def build_system(locality, storage_dir):
    rng = np.random.default_rng(0)
    pts = make_points(rng, 900)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32,
        locality_order=locality, storage_dir=storage_dir)
    sys_ = bootstrap_system(pts[:500], np.arange(500), cfg)
    return sys_, pts, make_points(np.random.default_rng(5), 32)


def drive(sys_, pts, n_rounds=3):
    """Clustered inserts + deletes + explicit merges, identical stream."""
    for r in range(n_rounds):
        for i in range(48):
            sys_.insert(2000 + 100 * r + i, pts[500 + 48 * r + i])
        for e in range(12 * r, 12 * r + 10):          # bootstrap residents
            sys_.delete(e)
        sys_.merge()


def live_recall(sys_, pts, queries, k=10):
    ids, _ = sys_.search(queries, k=k)
    ids = np.asarray(ids)
    ext = {}
    for e in range(500):
        if e not in sys_.deleted_ext:
            ext[e] = pts[e]
    for r in range(3):
        for i in range(48):
            ext[2000 + 100 * r + i] = pts[500 + 48 * r + i]
    keys = np.asarray(sorted(ext))
    mat = np.stack([ext[kk] for kk in keys])
    hits = 0
    for qi, q in enumerate(queries):
        d = ((mat - q) ** 2).sum(1)
        gt = set(keys[np.argsort(d)[:k]].tolist())
        hits += len(gt & set(ids[qi].tolist()))
    return hits / (k * len(queries))


def main() -> int:
    # 1a. the ordering primitive: permutation + bit-determinism.
    rng = np.random.default_rng(9)
    batch = jnp.asarray(make_points(rng, 128))
    p1 = np.asarray(locality_order(batch, seed=4))
    p2 = np.asarray(locality_order(batch, seed=4))
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(np.sort(p1), np.arange(128))
    print("# ordering ok: seeded permutation, bit-stable")

    with tempfile.TemporaryDirectory() as td:
        sys_off, pts, queries = build_system(False, os.path.join(td, "off"))
        sys_on, _, _ = build_system(True, os.path.join(td, "on"))
        sys_on2, _, _ = build_system(True, os.path.join(td, "on2"))
        for s in (sys_off, sys_on, sys_on2):
            drive(s, pts)

        # 1b. run-twice bit-determinism of the whole locality update path.
        np.testing.assert_array_equal(
            np.asarray(sys_on.lti.graph.adjacency),
            np.asarray(sys_on2.lti.graph.adjacency))
        print("# determinism ok: locality LTI bit-identical across runs")

        # 2. bucketed launches strictly beat the arrival-order worst case.
        st_on, st_off = sys_on.stats, sys_off.stats
        assert st_on.flushes == st_off.flushes >= 3
        assert st_on.merges == st_off.merges == 3
        for s in (st_on, st_off):
            assert s.flush_backedge_targets > 0
            assert s.merge_backedge_targets > 0
        assert st_on.flush_prune_rows < st_off.flush_prune_rows, (
            st_on.flush_prune_rows, st_off.flush_prune_rows)
        assert st_on.merge_prune_rows < st_off.merge_prune_rows, (
            st_on.merge_prune_rows, st_off.merge_prune_rows)
        print(f"# prune-work ok: flush rows {st_off.flush_prune_rows}->"
              f"{st_on.flush_prune_rows}, merge rows "
              f"{st_off.merge_prune_rows}->{st_on.merge_prune_rows}")

        # 3. storage deltas: patches stay partial, block counter coheres,
        # and locality does not inflate the patched footprint.
        for s in (st_on, st_off):
            assert s.storage_rows_patched > 0
            assert s.storage_blocks_patched > 0
            assert s.storage_blocks_patched <= s.storage_rows_patched
            assert s.storage_rows_patched < 3 * 2048   # never full rewrites
        assert st_on.storage_rows_patched <= int(
            1.15 * st_off.storage_rows_patched), (
            st_on.storage_rows_patched, st_off.storage_rows_patched)
        print(f"# storage ok: rows patched off={st_off.storage_rows_patched} "
              f"on={st_on.storage_rows_patched}, blocks "
              f"off={st_off.storage_blocks_patched} "
              f"on={st_on.storage_blocks_patched}")

        # 4. recall equivalence on the served surface.
        r_off = live_recall(sys_off, pts, queries)
        r_on = live_recall(sys_on, pts, queries)
        assert r_on >= r_off - 0.05, (r_off, r_on)
        print(f"# recall ok: off={r_off:.3f} on={r_on:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
