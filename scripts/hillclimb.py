import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf-iteration driver: lowers optimization variants of the three chosen
cells and records roofline deltas into experiments/perf/*.json."""
import json
import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.configs import get_arch
from repro.launch import ann_steps
from repro.launch.build import build_cell, _input_sds
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh


def record(name, compiled):
    import re
    from collections import Counter
    txt = compiled.as_text()
    DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "u8": 1,
          "f16": 2, "s8": 1}
    sizes = Counter()
    for m in re.finditer(r"= ([a-z0-9]+)\[([0-9,]+)\]", txt):
        if m.group(1) not in DT:
            continue
        n = 1
        for d in m.group(2).split(","):
            n *= int(d)
        key = f"{m.group(1)}[{m.group(2)}]"
        sizes[key] = n * DT[m.group(1)]
    print(f"[perf] {name} top shapes:",
          [(k, f"{v/2**30:.2f}GiB") for k, v in sizes.most_common(5)],
          flush=True)
    roof = analyze_compiled(compiled)
    ma = compiled.memory_analysis()
    out = {
        "variant": name,
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes
                     - ma.alias_size_in_bytes) / 2**30,
        "roofline": roof.as_dict(),
    }
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/{name}.json", "w") as f:
        json.dump(out, f, indent=1)
    r = out["roofline"]
    print(f"[perf] {name}: peak={out['peak_gib']:.2f}GiB "
          f"t_comp={r['t_compute']:.4f} t_mem={r['t_memory']:.4f} "
          f"t_coll={r['t_collective']:.4f}", flush=True)
    return out


def merge_sdc():
    arch = get_arch("freshdiskann-1b")
    dep = arch.full_config
    mesh = make_production_mesh()
    cell = arch.cell("merge_1b")
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    lti = ann_steps.abstract_lti(dep.index, dep.pq, mesh)
    batch = _input_sds(mesh, cell.specs(), {
        "new_vecs": P(), "new_valid": P(), "delete_mask": P()})
    n = len(mesh.devices.flat)
    dmask = jax.ShapeDtypeStruct(
        (dep.index.capacity * n,), jnp.bool_,
        sharding=NamedSharding(mesh, P(tuple(mesh.axis_names))))
    fn = ann_steps.make_distributed_merge(mesh, dep.index, dep.pq,
                                          use_sdc=True)
    with mesh:
        compiled = jax.jit(fn, donate_argnums=(0,)).lower(
            lti, batch["new_vecs"], batch["new_valid"], dmask).compile()
    record("merge_1b_sdc", compiled)


def lower_cell(arch_name, shape, tag, cfg_overrides=None):
    import dataclasses
    from repro.configs.common import ArchSpec, lm_cells
    arch = get_arch(arch_name)
    if cfg_overrides:
        cfg = dataclasses.replace(arch.full_config, **cfg_overrides)
        arch = ArchSpec(arch.name, arch.family, cfg, arch.smoke_config,
                        lm_cells(cfg))
    mesh = make_production_mesh()
    built = build_cell(arch, arch.cell(shape), mesh)
    with mesh:
        compiled = jax.jit(built.fn, donate_argnums=built.donate).lower(
            *built.args).compile()
    record(tag, compiled)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "merge"):
        merge_sdc()
    if which in ("all", "mixtral"):
        lower_cell("mixtral-8x7b", "train_4k", "mixtral_train_gathercombine")
    if which in ("all", "qwen3"):
        lower_cell("qwen3-14b", "train_4k", "qwen3_train_bf16p",
                   {"attn_p_dtype": "bfloat16"})
    if which in ("all", "qwen3_kv512"):
        lower_cell("qwen3-14b", "train_4k", "qwen3_train_bf16p_kv512",
                   {"attn_p_dtype": "bfloat16", "kv_chunk": 512})
    if which == "qwen3_f32p":
        lower_cell("qwen3-14b", "train_4k", "qwen3_train_accum2_f32p",
                   {"attn_p_dtype": "float32"})
    if which == "mixtral_prefill":
        lower_cell("mixtral-8x7b", "prefill_32k",
                   "mixtral_prefill_gathercombine")
