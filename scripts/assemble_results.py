"""Assemble experiments/dryrun/*.json into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python scripts/assemble_results.py [--md]
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch  # noqa: E402

PEAK = 197e12
HBM_GB = 16 * 2**30

# expected trips for data-dependent loops (beam search): the paper's own
# operating point — ~"a little more than L" hops (L=100) -> 120 expansions.
ANN_SEARCH_TRIP = 120


def model_flops(arch_name, shape, kind, meta):
    """6*N*D (dense) / 6*N_active*D (MoE) per step — 'useful' flops."""
    try:
        arch = get_arch(arch_name)
    except KeyError:
        return None
    if arch.family != "lm":
        return None
    cfg = arch.full_config
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if kind == "train":
        tokens = meta.get("batch", 0) * meta.get("seq", 0)
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = meta.get("batch", 0) * meta.get("seq", 0)
        return 2.0 * n * tokens
    if kind == "decode":
        return 2.0 * n * meta.get("batch", 1)
    return None


def load_rows(out_dir="experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(f))
        rows.append(r)
    return rows


def fmt_row(r):
    arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
    if r["status"] == "SKIP":
        return (f"| {arch} | {shape} | {mesh} | SKIP | — | — | — | — | — | "
                f"{r['reason'][:60]} |")
    if r["status"] != "OK":
        return f"| {arch} | {shape} | {mesh} | FAIL | — | — | — | — | — | {r.get('error','')[:60]} |"
    roof = r["roofline"]
    peak = r["memory_analysis"]["peak_bytes"] / 2**30
    tc, tm, tl = roof["t_compute"], roof["t_memory"], roof["t_collective"]
    if roof.get("dynamic_loops") and r["kind"] == "ann_search":
        # the whole cell IS the data-dependent beam search: scale by the
        # paper's ~120 expansions/query operating point
        note = f"dyn-loops x{ANN_SEARCH_TRIP} applied"
        tc, tm, tl = (t * ANN_SEARCH_TRIP for t in (tc, tm, tl))
    elif roof.get("dynamic_loops"):
        # insert/merge: static block passes dominate; their embedded beam
        # searches are counted once (slight underestimate)
        note = "beam loops counted 1x"
    else:
        note = ""
    bott = max((tc, "compute"), (tm, "memory"), (tl, "collective"))[1]
    try:
        arch_o = get_arch(arch)
        cell = arch_o.cell(shape)
        mf = model_flops(arch, shape, r["kind"], cell.meta)
    except Exception:
        mf = None
    n_chips = 512 if "2x16" in mesh else 256
    useful = (f"{mf / (roof['flops'] * n_chips):.2f}"
              if mf and roof["flops"] else "—")
    step = max(tc, tm, tl)
    mfu = (mf / n_chips / PEAK) / step if mf and step else None
    mfu_s = f"{100 * mfu:.1f}%" if mfu else "—"
    return (f"| {arch} | {shape} | {mesh} | OK | {peak:.1f} | "
            f"{tc:.4f} | {tm:.4f} | {tl:.4f} | {bott} | "
            f"useful={useful} mfu={mfu_s} {note} |")


def main():
    rows = load_rows()
    print("| arch | shape | mesh | status | peak GiB/chip | t_comp s | "
          "t_mem s | t_coll s | bottleneck | notes |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    order = {"pod16x16": 0, "pod2x16x16": 1}
    rows.sort(key=lambda r: (r["arch"], r["shape"], order.get(r["mesh"], 2)))
    for r in rows:
        print(fmt_row(r))
    n_ok = sum(r["status"] == "OK" for r in rows)
    n_skip = sum(r["status"] == "SKIP" for r in rows)
    n_fail = sum(r["status"] == "FAIL" for r in rows)
    over = [f'{r["arch"]}x{r["shape"]}x{r["mesh"]}' for r in rows
            if r["status"] == "OK"
            and r["memory_analysis"]["peak_bytes"] > HBM_GB]
    print(f"\nOK={n_ok} SKIP={n_skip} FAIL={n_fail}; "
          f"over 16GiB/chip: {over or 'none'}")


if __name__ == "__main__":
    main()
