#!/usr/bin/env python
"""Localized delete-repair smoke probe (``scripts/smoke.sh --local-repair``).

Builds two small live FreshDiskANN systems that differ only in how merges
route the Delete phase — ``local_repair_threshold=1.0`` (every merge runs
the localized affected-set repair) vs ``0.0`` (every merge runs the global
Algorithm-4 sweep) — and asserts the contracts of docs/ARCHITECTURE.md,
"Localized delete repair", end to end:

  1. after interleaved inserts / deletes / merges, the two systems' LTI
     adjacencies and search results are **bit-identical** (routing is a
     cost choice, never a result choice);
  2. the routing counters split as configured: the local system logs only
     local_repairs, the global one only global_repairs;
  3. the reachability monitor ran after every merge (reach_probes), its
     gauge is a valid fraction, and the localized system's gauge did not
     degrade past the escalation bar relative to the global system's;
  4. a standalone ``consolidate(mode="local")`` repairs LTI-resident
     deletes in place and retires them from the DeleteList.

Exits non-zero on the first violated contract.  The same invariants run
as tier-1 tests in ``tests/test_streaming_property.py`` and
``tests/test_update_engine.py``; this probe is the CI-visible end-to-end
pass, mirroring shard_probe.py / disk_probe.py.
"""
import os
import sys

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np                                    # noqa: E402

from repro.core.config import (IndexConfig, PQConfig,  # noqa: E402
                               SystemConfig)
from repro.core.system import bootstrap_system        # noqa: E402

DIM = 24


def build_system(threshold):
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((700, DIM)).astype(np.float32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32,
        local_repair_threshold=threshold, reach_probe_samples=64,
        # The probe checks the *routing* split, so keep it deterministic:
        # a noisy 64-sample probe must not escalate a merge to global
        # mid-run.  The drift check below uses an explicit bar instead.
        reach_escalate_frac=1.0)
    sys_ = bootstrap_system(pts[:400], np.arange(400), cfg)
    return sys_, pts, rng.standard_normal((16, DIM)).astype(np.float32)


def drive(sys_, pts, n_rounds=3):
    """Interleave inserts, LTI-resident deletes and explicit merges."""
    for r in range(n_rounds):
        for i in range(40):
            sys_.insert(2000 + 100 * r + i, pts[400 + 40 * r + i])
        for e in range(10 * r, 10 * r + 8):           # bootstrap residents
            sys_.delete(e)
        sys_.merge()


def main() -> int:
    sys_l, pts, queries = build_system(threshold=1.0)   # always localized
    sys_g, _, _ = build_system(threshold=0.0)           # always global
    drive(sys_l, pts)
    drive(sys_g, pts)

    # 1. bit-parity of the merged LTI and of served results.
    np.testing.assert_array_equal(
        np.asarray(sys_l.lti.graph.adjacency),
        np.asarray(sys_g.lti.graph.adjacency))
    ids_l, d_l = sys_l.search(queries, k=10)
    ids_g, d_g = sys_g.search(queries, k=10)
    np.testing.assert_array_equal(np.asarray(ids_l), np.asarray(ids_g))
    np.testing.assert_array_equal(np.asarray(d_l), np.asarray(d_g))
    print(f"# parity ok: adjacency + search bit-identical across routing")

    # 2. the routing counters split as configured.
    assert sys_l.stats.local_repairs == 3, sys_l.stats.local_repairs
    assert sys_l.stats.global_repairs == 0
    assert sys_g.stats.global_repairs == 3, sys_g.stats.global_repairs
    assert sys_g.stats.local_repairs == 0
    print(f"# routing ok: local={sys_l.stats.local_repairs} "
          f"global={sys_g.stats.global_repairs}")

    # 3. the reachability monitor ran and its gauge held.
    for s in (sys_l, sys_g):
        assert s.stats.reach_probes == 3, s.stats.reach_probes
        assert 0.0 <= s.stats.unreachable_frac <= 1.0
    bar = 0.05 + 2.0 / 64                             # escalation bar + noise
    drift = sys_l.stats.unreachable_frac - sys_g.stats.unreachable_frac
    assert drift <= bar, (sys_l.stats.unreachable_frac,
                          sys_g.stats.unreachable_frac)
    print(f"# reachability ok: local={sys_l.stats.unreachable_frac:.3f} "
          f"global={sys_g.stats.unreachable_frac:.3f} "
          f"(probes={sys_l.stats.reach_probes})")

    # 4. standalone localized consolidate retires LTI-resident deletes.
    victims = [100, 101, 102]
    for e in victims:
        sys_l.delete(e)
    n = sys_l.consolidate(mode="local")
    assert n == len(victims), n
    assert not (set(victims) & sys_l.deleted_ext)
    ids, _ = sys_l.search(pts[100:101], k=10)
    assert 100 not in np.asarray(ids)
    print(f"# consolidate ok: {n} deletes repaired in place and retired")
    return 0


if __name__ == "__main__":
    sys.exit(main())
