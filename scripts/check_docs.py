#!/usr/bin/env python
"""Docs reference checker (run by CI smoke via scripts/smoke.sh).

Validates that the prose in docs/*.md and README.md stays true to the tree:

  1. every relative markdown link [text](target) resolves to a real file;
  2. every backticked repo path (``src/.../x.py``, ``tests/x.py``, ...)
     exists;
  3. every ``path.py::symbol`` code reference names a symbol that actually
     appears in that file (function/class/assignment or test name);
  4. every fenced ``python`` snippet parses (syntax check only — snippets
     are illustrative, not executed).

Exits non-zero listing every stale reference, so a refactor that renames a
module or test cannot silently orphan the documentation.
"""
from __future__ import annotations

import ast
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
TICK_RE = re.compile(r"`([^`\n]+)`")
PATH_RE = re.compile(r"^[\w./-]+\.(?:py|md|sh|yml|yaml|json|bin)$")
SYMREF_RE = re.compile(r"^([\w./-]+\.py)::(\w+)$")
FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)


def check_file(md_path: str) -> list[str]:
    errors = []
    base = os.path.dirname(md_path)
    rel = os.path.relpath(md_path, ROOT)
    with open(md_path) as f:
        text = f.read()

    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = target.split("#")[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            errors.append(f"{rel}: broken link -> {target}")

    # Strip fenced code before scanning inline ticks (fences have their own
    # check), then validate path-shaped and ``file.py::symbol`` spans.
    prose = FENCE_RE.sub("", text)
    for span in TICK_RE.findall(prose):
        span = span.strip()
        m = SYMREF_RE.match(span)
        if m:
            fpath, sym = m.groups()
            resolved = os.path.normpath(os.path.join(ROOT, fpath))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: code ref to missing file `{span}`")
            else:
                # A real definition, not a substring: def/class (any
                # indentation, so methods count) or a top-level assignment.
                def_re = re.compile(
                    rf"^\s*(?:def|class)\s+{re.escape(sym)}\b"
                    rf"|^{re.escape(sym)}\s*=", re.M)
                if not def_re.search(open(resolved).read()):
                    errors.append(
                        f"{rel}: `{fpath}` does not define `{sym}`")
            continue
        if "/" in span and PATH_RE.match(span) and "*" not in span:
            resolved = os.path.normpath(os.path.join(ROOT, span))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: backticked path missing -> `{span}`")

    for lang, body in FENCE_RE.findall(text):
        if lang == "python":
            try:
                ast.parse(body)
            except SyntaxError as e:
                errors.append(f"{rel}: python snippet fails to parse: {e}")
    return errors


def main() -> int:
    targets = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    targets.append(os.path.join(ROOT, "README.md"))
    missing = [t for t in targets if not os.path.exists(t)]
    errors = [f"expected doc missing: {os.path.relpath(t, ROOT)}"
              for t in missing]
    for t in targets:
        if os.path.exists(t):
            errors.extend(check_file(t))
    if errors:
        print("\n".join(errors))
        print(f"# check_docs: {len(errors)} stale reference(s)")
        return 1
    print(f"# check_docs: {len(targets)} files ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
