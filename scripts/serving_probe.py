#!/usr/bin/env python
"""Serving front-end smoke probe (run by ``scripts/smoke.sh --serving``
and CI).

Forces 4 fake host devices and asserts the continuous-batching + replica
contracts end to end (docs/SERVING.md):

  1. scheduler invariants under an injected VirtualClock — a full queue
     closes immediately at exactly ``batch_queries``, a partial batch
     closes at the oldest deadline minus the dispatch estimate, an empty
     queue never dispatches, overflow submissions shed;
  2. every scheduled request's (ids, dists) row is bit-identical to
     calling ``search_batch`` directly;
  3. replica-count invariance on REAL device groups — 1 vs 2 vs 4
     replicas return bit-identical rows, micro-batches land round-robin
     (``ReplicaSet.dispatches``);
  4. the 2-axis composition: 2 replicas x 2 ``shard_lti`` row shards on
     the same 4 devices, still bit-identical;
  5. routing survives a background merge: the LTI generation swap misses
     every replica's placement cache and re-places the new graph.

Exits non-zero on the first violated contract.  The single-device halves
of these contracts run in-process in ``tests/test_scheduler.py`` and
``tests/test_serving.py``; this probe is the multi-device half, invoked
as a subprocess there and as a dedicated CI step.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402

from repro.core.config import (IndexConfig, PQConfig,  # noqa: E402
                               SystemConfig)
from repro.core.system import bootstrap_system        # noqa: E402
from repro.serving import (BatchScheduler, ReplicaSet,  # noqa: E402
                           VirtualClock)


def build_system(**kw):
    dim = 24
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((700, dim)).astype(np.float32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=dim, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32, **kw)
    sys_ = bootstrap_system(pts[:400], np.arange(400), cfg)
    for i in range(150):                      # 2 RO rollovers + live RW tier
        sys_.insert(2000 + i, pts[500 + i])
    for e in (0, 5, 2000, 2149):              # deletes across every tier
        sys_.delete(e)
    return sys_, rng.standard_normal((16, dim)).astype(np.float32)


def probe_scheduler() -> None:
    clk = VirtualClock()
    sys_, q = build_system(batch_queries=4, slo_ms=25.0,
                           serve_queue_capacity=8, dispatch_estimate_ms=5.0,
                           clock=clk)
    ref_ids, ref_d = sys_.search_batch(q, k=5)
    sizes = []
    ref = sys_.search_batch

    def serve(qs, k, L=None, beam_width=None):
        sizes.append(len(qs))
        return ref(qs, k, L=L, beam_width=beam_width)

    sched = BatchScheduler(sys_, k=5, serve=serve)
    assert sched.clock is clk, "scheduler must use the injected clock"
    assert sched.run_once() == 0, "empty queue must never dispatch"
    tickets = [sched.submit(qi) for qi in q[:6]]
    assert sched.run_once() == 4, "full queue closes at batch_queries"
    close = sched.next_close_time()
    assert close == clk.now() + 0.025 - sched.dispatch_estimate, \
        "partial close time = oldest deadline - dispatch estimate"
    clk.advance(close - clk.now())
    assert sched.run_once() == 2, "deadline close takes the partial batch"
    assert sizes == [4, 2] and sys_.stats.deadline_misses == 0
    for i, t in enumerate(tickets):
        np.testing.assert_array_equal(t.ids, ref_ids[i])
        np.testing.assert_array_equal(t.dists, ref_d[i])
    print("# scheduler: close policy + bit-parity OK on the virtual clock")

    outs = [sched.submit(q[0]) for _ in range(10)]      # capacity 8
    assert sum(t is None for t in outs) == 2
    assert sys_.stats.shed_requests == 2, "overflow must shed, not queue"
    assert sched.flush() == 8
    print("# scheduler: backpressure sheds beyond capacity OK")


def probe_replicas() -> None:
    sys_, q = build_system(batch_queries=4)
    ref_ids, ref_d = sys_.search_batch(q, k=5)

    for nr in (1, 2, 4):
        rs = ReplicaSet(sys_, nr)
        assert rs.n_replicas == nr, f"wanted {nr} replicas on 4 devices"
        ids, d = rs.search_batch(q, k=5)                # 16 -> 4 micro-batches
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(d, ref_d)
        spread = 4 // nr
        assert rs.dispatches == [spread] * nr, \
            f"round-robin spread {rs.dispatches} != uniform over {nr}"
        print(f"# replicas={nr}: bit-identical, dispatches={rs.dispatches}")

    # 2-axis composition: 2 replicas x 2 LTI row shards on the same grid.
    rs = ReplicaSet(sys_, 2, n_shards=2)
    assert (rs.n_replicas, rs.n_shards) == (2, 2)
    ids, d = rs.search_batch(q, k=5)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(d, ref_d)
    print("# 2 replicas x 2 shards: composition bit-identical")

    # Generation swap under routing: background merge, then re-serve.
    sys_, q = build_system(batch_queries=4, background_merge=True)
    rs = ReplicaSet(sys_, 2)
    rs.search_batch(q[:4], k=5)                 # warm every placement path
    sys_.delete(2001)
    sys_.merge(background=True)
    sys_.wait_merge()
    assert sys_.stats.merges == 1
    ref_ids, ref_d = sys_.search_batch(q, k=5)  # post-merge reference
    ids, d = rs.search_batch(q, k=5)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(d, ref_d)
    print("# routing survives the background merge's generation swap")


def main() -> int:
    n_dev = len(jax.devices())
    print(f"# serving probe: {n_dev} devices ({jax.default_backend()})")
    assert n_dev >= 4, "expected 4 fake host devices (set XLA_FLAGS)"
    probe_scheduler()
    probe_replicas()
    print("# SERVING-PROBE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
