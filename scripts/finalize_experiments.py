"""Regenerate the EXPERIMENTS.md roofline table from experiments/dryrun."""
import io
import re
import subprocess
import sys

out = subprocess.run(
    [sys.executable, "scripts/assemble_results.py"],
    capture_output=True, text=True).stdout

md = open("EXPERIMENTS.md").read()
table = out.strip()
md = re.sub(
    r"<!-- ROOFLINE_TABLE -->.*?(?=\n\nReading the table:)",
    "<!-- ROOFLINE_TABLE -->\n\n" + table,
    md, flags=re.S)
open("EXPERIMENTS.md", "w").write(md)
print("EXPERIMENTS.md roofline table updated "
      f"({table.count(chr(10))} lines)")
