#!/usr/bin/env python
"""Sharded-serving smoke probe (run by ``scripts/smoke.sh --shards`` and CI).

Forces 4 fake host devices (the XLA_FLAGS trick from docs/SERVING.md), builds
one live FreshDiskANN system — LTI + two frozen RO snapshots + an RW tier,
with DeleteList members in every tier — and asserts the serving-engine
contracts end to end on REAL multi-device sharding:

  1. `search_batch` under `shard_lti` in {1, 2, 4} returns (ids, dists)
     bit-identical to the unsharded unified program — shard-count invariance
     by construction (owner-computes + psum, replicated beam state);
  2. every sharded batch is still ONE device program
     (`SystemStats.search_dispatches` += 1 per micro-batch);
  3. `batch_queries` micro-batching chunks/pads without changing any result
     and counts ceil(B/N) programs;
  4. per-query serving (B=1 calls) matches the batch, row for row.

Exits non-zero on the first violated contract.  The same invariants run
in-process (single device, shards=1) in ``tests/test_serving.py``; this
probe is the multi-device half, invoked as a subprocess there and as a
dedicated CI step.
"""
import dataclasses
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402

from repro.core.config import (IndexConfig, PQConfig,  # noqa: E402
                               SystemConfig)
from repro.core.system import bootstrap_system        # noqa: E402


def build_system(**kw):
    dim = 24
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((700, dim)).astype(np.float32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=dim, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32, **kw)
    sys_ = bootstrap_system(pts[:400], np.arange(400), cfg)
    for i in range(150):                      # 2 RO rollovers + live RW tier
        sys_.insert(2000 + i, pts[500 + i])
    for e in (0, 5, 2000, 2149):              # deletes across every tier
        sys_.delete(e)
    return sys_, rng.standard_normal((16, dim)).astype(np.float32)


def main() -> int:
    n_dev = len(jax.devices())
    print(f"# shard probe: {n_dev} devices ({jax.default_backend()})")
    assert n_dev >= 4, "expected 4 fake host devices (set XLA_FLAGS)"
    sys_, q = build_system()
    ref_ids, ref_d = sys_.search_batch(q, k=5)

    # 1+2: shard-count invariance + one-program dispatch on the SAME system
    # (reconfiguring shard_lti in place exercises the mesh/placement cache
    # turnover too).
    for ns in (1, 2, 4):
        sys_.cfg = dataclasses.replace(sys_.cfg, shard_lti=ns)
        ids, d = sys_.search_batch(q, k=5)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(d, ref_d)
        d0 = sys_.stats.search_dispatches
        sys_.search_batch(q, k=5)
        assert sys_.stats.search_dispatches - d0 == 1, \
            f"shards={ns}: batch must stay ONE program"
        print(f"# shards={ns}: bit-identical to unsharded, 1 dispatch/batch")

    # 3: micro-batching under sharding — chunk + pad, same bits, ceil(B/N).
    sys_.cfg = dataclasses.replace(sys_.cfg, shard_lti=4, batch_queries=6)
    d0 = sys_.stats.search_dispatches
    ids, d = sys_.search_batch(q, k=5)                 # 16 -> 3 micro-batches
    assert sys_.stats.search_dispatches - d0 == 3
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(d, ref_d)
    ids, d = sys_.search_batch(q[:3], k=5)             # 3 < 6 -> padded
    np.testing.assert_array_equal(ids, ref_ids[:3])
    np.testing.assert_array_equal(d, ref_d[:3])
    print("# batch_queries=6: ceil(B/N) programs, results unchanged")

    # 4: per-query oracle under the sharded engine.
    for i in range(4):
        ids, d = sys_.search_batch(q[i:i + 1], k=5)
        np.testing.assert_array_equal(ids[0], ref_ids[i])
        np.testing.assert_array_equal(d[0], ref_d[i])
    print("# per-query == batched, row for row")
    print("# SHARD-PROBE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
