#!/usr/bin/env python
"""Filtered & multi-tenant serving probe (run by ``scripts/smoke.sh
--filters`` and CI).

Forces 4 fake host devices and asserts the filtered-search contracts of
docs/ARCHITECTURE.md ("Filtered & multi-tenant search") end to end:

  1. selectivity-1.0 parity — a filter every live point matches returns
     bit-identical (ids, dists) to the unfiltered call, on the system
     path AND through a 2-replica ``ReplicaSet`` on real device groups
     (the filter folds into the same cached drop mask, applied
     post-search, so it can never perturb the unfiltered program);
  2. tenant isolation — per-tenant filtered searches across all three
     tiers (LTI + RO + RW) never return a cross-tenant id, replica-routed
     or direct, and the per-tenant search counters accrue;
  3. post-merge label survival — labels follow points through a
     StreamingMerge's slot scatter: filtered searches stay leak-free and
     the merged LTI's label side tables carry every live tenant;
  4. scheduler de-interleave — mixed-FilterSpec tickets through a
     ``BatchScheduler`` under a VirtualClock close into single-spec
     micro-batches, per-tenant quota sheds are counted in
     ``SystemStats.tenant_sheds``, and every served row is bit-identical
     to direct filtered ``search_batch``.

Exits non-zero on the first violated contract.  The single-device halves
run in-process in ``tests/test_filtered.py`` / ``tests/test_scheduler.py``;
this probe is the multi-device half.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402

from repro.core.config import (IndexConfig, PQConfig,  # noqa: E402
                               SystemConfig)
from repro.core.graph import FilterSpec               # noqa: E402
from repro.core.system import bootstrap_system        # noqa: E402
from repro.serving import (BatchScheduler, ReplicaSet,  # noqa: E402
                           VirtualClock)

N_TENANTS = 3


def build_system(**kw):
    dim = 24
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((700, dim)).astype(np.float32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=dim, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32, filter_words=1, **kw)
    sys_ = bootstrap_system(pts[:400], np.arange(400), cfg,
                            labels=[[0, i % 4] for i in range(400)],
                            tenants=[i % N_TENANTS for i in range(400)])
    for i in range(150):                      # 2 RO rollovers + live RW tier
        sys_.insert(2000 + i, pts[500 + i], labels=[0, i % 4],
                    tenant=(2000 + i) % N_TENANTS)
    for e in (0, 5, 2000, 2149):              # deletes across every tier
        sys_.delete(e)
    return sys_, rng.standard_normal((16, dim)).astype(np.float32)


def tenant_of(e):
    return e % N_TENANTS


def check(cond, msg):
    if not cond:
        print(f"FILTER-PROBE FAILED: {msg}", file=sys.stderr)
        sys.exit(1)


def main():
    check(len(jax.devices()) == 4, f"expected 4 devices, {jax.devices()}")

    # ---- 1. selectivity-1.0 parity, direct and replica-routed ----------
    sys_, q = build_system(batch_queries=8)
    ids_u, d_u = sys_.search_batch(q, k=5)
    ids_f, d_f = sys_.search_batch(q, k=5, filter=FilterSpec(all_of=(0,)))
    check(np.array_equal(ids_f, ids_u) and np.array_equal(d_f, d_u),
          "selectivity-1.0 filter not bit-identical to unfiltered")
    rs = ReplicaSet(sys_, 2, n_shards=1)
    check(rs.n_replicas == 2, f"wanted 2 replicas, got {rs.n_replicas}")
    ids_r, d_r = rs.search_batch(q, k=5, filter=FilterSpec(all_of=(0,)))
    check(np.array_equal(ids_r, ids_u) and np.array_equal(d_r, d_u),
          "replica-routed sel-1.0 filter not bit-identical")
    print("# sel-1.0 parity ok (direct + 2 replicas)")

    # ---- 2. tenant isolation across tiers, both paths ------------------
    for t in range(N_TENANTS):
        for tag, (ids, _) in (
                ("direct", sys_.search_batch(q, 5, filter=FilterSpec(tenant=t))),
                ("replica", rs.search_batch(q, 5, filter=FilterSpec(tenant=t)))):
            for row in np.asarray(ids):
                for e in (int(x) for x in row if x >= 0):
                    check(tenant_of(e) == t,
                          f"{tag}: id {e} leaked into tenant {t}")
    check(sum(sys_.stats.tenant_searches.values()) > 0,
          "tenant search counters did not accrue")
    print("# tenant isolation ok (3 tenants x direct/replica)")

    # ---- 3. post-merge label survival ----------------------------------
    sys_.merge()
    sys_.wait_merge()
    for t in range(N_TENANTS):
        ids, _ = sys_.search_batch(q, 5, filter=FilterSpec(tenant=t))
        for row in np.asarray(ids):
            for e in (int(x) for x in row if x >= 0):
                check(tenant_of(e) == t,
                      f"post-merge: id {e} leaked into tenant {t}")
    live = sys_.lti_ext_ids >= 0
    check((sys_.lti_labels.tenant[live] >= 0).all(),
          "merged LTI rows lost their tenant tags")
    check(set(np.unique(sys_.lti_labels.tenant[live]).tolist())
          == set(range(N_TENANTS)),
          "merged LTI label table does not cover every tenant")
    print("# post-merge label survival ok")

    # ---- 4. scheduler de-interleave + tenant quota ---------------------
    clk = VirtualClock()
    sys2, q2 = build_system(batch_queries=4, slo_ms=50.0,
                            serve_queue_capacity=64, clock=clk,
                            tenant_quota=2)
    served = []
    ref = sys2.search_batch

    def serve(qs, k, L=None, beam_width=None, **kw):
        served.append(kw.get("filter"))
        return ref(qs, k, L=L, beam_width=beam_width, **kw)

    sched = BatchScheduler(sys2, k=5, serve=serve)
    s0, s1 = FilterSpec(tenant=0), FilterSpec(tenant=1)
    tickets = [(sched.submit(q2[i], filter=s), s) for i, s in
               enumerate([s0, s1, s0, None, s1, None])]
    check(all(t is not None for t, _ in tickets), "in-quota ticket shed")
    check(sched.submit(q2[7], filter=s0) is None,
          "3rd queued tenant-0 ticket not quota-shed")
    check(sys2.stats.tenant_sheds == {0: 1},
          f"tenant_sheds {sys2.stats.tenant_sheds} != {{0: 1}}")
    while sched.flush():
        pass
    specs = {str(s) for s in served}
    check(specs == {str(s0), str(s1), str(None)},
          f"expected one single-spec batch per distinct spec, got {specs}")
    for t, s in tickets:
        kw = {"filter": s} if s is not None else {}
        ids, d = ref(t.query[None, :], 5, **kw)
        check(np.array_equal(t.ids, np.asarray(ids)[0])
              and np.array_equal(t.dists, np.asarray(d)[0]),
              "scheduled filtered row not bit-identical to direct")
    print("# scheduler de-interleave + quota ok "
          f"({len(served)} single-spec batches)")

    print("FILTER-PROBE OK")


if __name__ == "__main__":
    main()
