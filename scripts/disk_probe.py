#!/usr/bin/env python
"""Disk-tier smoke probe (run by ``scripts/smoke.sh --disk`` and CI).

Builds one small live FreshDiskANN system with ``storage_dir`` set — so the
LTI is mirrored to the decoupled on-disk layout (topology.bin + data.bin,
docs/STORAGE.md) — and asserts the storage-tier contracts end to end:

  1. `search_disk` at prefetch_depth in {0, 1, 2} returns (ids, dists)
     bit-identical to the in-memory engine's `search_batch` oracle —
     prefetch moves IO off the critical path, it never changes results;
  2. read accounting obeys the conservation law: with the block cache on,
     every requested adjacency row is either a file read
     (SystemStats.io_rows_read) or a cache hit (io_cache_hits), and with
     the cache off the reads match the in-memory engine's n_reads;
  3. a StreamingMerge delta-patches the layout in place
     (storage_rows_patched > 0) and post-merge disk results still match;
  4. the prefetcher's two staging buffers are identity-stable across
     searches (allocation-free steady state).

Exits non-zero on the first violated contract.  The same invariants run as
tier-1 tests in ``tests/test_storage.py``; this probe is the CI-visible
end-to-end pass over a real tempdir layout, mirroring shard_probe.py.
"""
import dataclasses
import os
import sys
import tempfile

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np                                    # noqa: E402

from repro.core.config import (IndexConfig, PQConfig,  # noqa: E402
                               SystemConfig)
from repro.core.system import bootstrap_system        # noqa: E402


def build_system(storage_dir, **kw):
    dim = 24
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((700, dim)).astype(np.float32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=dim, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32,
        storage_dir=storage_dir, **kw)
    sys_ = bootstrap_system(pts[:400], np.arange(400), cfg)
    for i in range(150):                      # 2 RO rollovers + live RW tier
        sys_.insert(2000 + i, pts[500 + i])
    for e in (0, 5, 2000, 2149):              # deletes across every tier
        sys_.delete(e)
    return sys_, rng.standard_normal((16, dim)).astype(np.float32)


def main() -> int:
    with tempfile.TemporaryDirectory() as td:
        sys_, q = build_system(td)
        assert os.path.exists(os.path.join(td, "lti", "topology.bin")), \
            "storage_dir must mirror the LTI as a decoupled layout"
        ref_ids, ref_d = sys_.search_batch(q, k=5)

        # 1+2: depth sweep — bit-parity + the read conservation law.
        # Cache off: disk reads must equal the in-memory engine's n_reads.
        for depth in (0, 1, 2):
            sys_.cfg = dataclasses.replace(
                sys_.cfg, prefetch_depth=depth, adjacency_cache_mb=0)
            sys_.close_storage()              # re-open with the new knobs
            r0, c0 = sys_.stats.io_rows_read, sys_.stats.io_cache_hits
            ids, d = sys_.search_disk(q, k=5)
            np.testing.assert_array_equal(ids, ref_ids)
            np.testing.assert_array_equal(d, ref_d)
            reads = sys_.stats.io_rows_read - r0
            assert sys_.stats.io_cache_hits == c0, "cache off -> no hits"
            if depth == 0:
                reads_ref = reads
            else:
                assert reads == reads_ref, \
                    f"depth={depth}: n_reads must not depend on prefetch"
            print(f"# depth={depth}: bit-identical to in-memory, "
                  f"reads={reads}")

        # 2b: cache on — every requested row is a read XOR a cache hit.
        sys_.cfg = dataclasses.replace(
            sys_.cfg, prefetch_depth=1, adjacency_cache_mb=4)
        sys_.close_storage()
        r0, c0 = sys_.stats.io_rows_read, sys_.stats.io_cache_hits
        ids, d = sys_.search_disk(q, k=5)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(d, ref_d)
        reads = sys_.stats.io_rows_read - r0
        hits = sys_.stats.io_cache_hits - c0
        assert hits > 0, "a 4MB cache over a tiny layout must hit"
        assert reads + hits == reads_ref, \
            f"conservation: {reads} reads + {hits} hits != {reads_ref}"
        print(f"# cache on: {reads} reads + {hits} hits == {reads_ref}")

        # 4: staging buffers are identity-stable across searches.
        pf = sys_._disk_searcher_get().reader.prefetcher
        a0 = pf.allocations
        b0 = [id(b) for b in pf.staging_buffers()]
        sys_.search_disk(q, k=5)
        assert pf.allocations == a0, "steady state must not reallocate"
        assert [id(b) for b in pf.staging_buffers()] == b0, \
            "staging buffers must keep their identity"
        print(f"# staging buffers stable (allocations={a0})")

        # 3: merge -> in-place delta patch -> post-merge parity.
        sys_.merge()
        assert sys_.stats.storage_rows_patched > 0, \
            "StreamingMerge must delta-patch the layout"
        ref_ids2, ref_d2 = sys_.search_batch(q, k=5)
        ids, d = sys_.search_disk(q, k=5)
        np.testing.assert_array_equal(ids, ref_ids2)
        np.testing.assert_array_equal(d, ref_d2)
        print(f"# post-merge: {sys_.stats.storage_rows_patched} rows "
              f"patched, disk == in-memory")
        sys_.close_storage()
    print("# DISK-PROBE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
