"""Batched multi-tier query fan-out: the unified heterogeneous-lane program
(RW + RO tiers + PQ-navigated LTI lane in ONE device dispatch) must be
bit-identical to the sequential per-tier loop across deletes, merges and
beam-width sweeps; per-lane results must match the dedicated engines
counter-for-counter; tier padding must be inert; k<=L must be validated;
and threshold merges must honor the background knob."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import index as mem
from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.graph import pad_graph, stack_graphs, stack_lanes
from repro.core.lti import build_lti, search_lti
from repro.core.search import rerank_candidates
from repro.core.system import bootstrap_system

from conftest import DIM


def _sys_cfg(**kw):
    base = dict(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,   # keep tiers staged
        temp_capacity=256, insert_batch=32)
    base.update(kw)
    return SystemConfig(**base)


def _three_tier_system(points, **kw):
    """LTI + 2 frozen RO snapshots + a live RW tier."""
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg(**kw))
    for i in range(150):                       # 2 rollovers at 64 and 128
        sys_.insert(2000 + i, points[500 + i])
    return sys_


def test_batched_fanout_bit_identical_to_sequential(points, queries):
    """The acceptance bar: identical (ids, dists) on a 3-tier system."""
    sys_b = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    assert len(sys_b.ro) == 2 and len(sys_s.ro) == 2
    ids_b, d_b = sys_b.search(queries, k=5)
    ids_s, d_s = sys_s.search(queries, k=5)
    np.testing.assert_array_equal(ids_b, ids_s)
    np.testing.assert_array_equal(d_b, d_s)


def test_batched_fanout_bit_identical_kernel_path(points, queries):
    """Same parity with the Pallas ops layer engaged (interpret on CPU)."""
    kcfg = IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                       L_search=64, alpha=1.2, use_kernel=True)
    sys_b = _three_tier_system(points, index=kcfg)
    sys_s = _three_tier_system(points, index=kcfg, batch_fanout=False)
    ids_b, d_b = sys_b.search(queries[:8], k=5)
    ids_s, d_s = sys_s.search(queries[:8], k=5)
    np.testing.assert_array_equal(ids_b, ids_s)
    np.testing.assert_array_equal(d_b, d_s)


def test_search_tiers_matches_per_tier_search(points, queries):
    """index.search_tiers lane t == index.search on tier t, bit for bit —
    including a tier padded up to a larger common capacity."""
    cfg_small = IndexConfig(capacity=300, dim=DIM, R=16, L_build=24,
                            L_search=32, alpha=1.2)
    cfg_big = IndexConfig(capacity=512, dim=DIM, R=16, L_build=24,
                          L_search=32, alpha=1.2)
    g1 = mem.build(points[:250], cfg_small, batch=64)
    g2 = mem.build(points[250:600], cfg_big, batch=64)
    q = jnp.asarray(queries[:8])
    stacked = stack_graphs([g1, g2])           # pads g1 from 300 -> 512
    ids, d, hops, cmps = mem.search_tiers(stacked, q, cfg_big, k=5, L=32)
    for ti, (g, cfg) in enumerate([(g1, cfg_small), (g2, cfg_big)]):
        wids, wd, whops, wcmps = mem.search(g, q, cfg_big, k=5, L=32)
        np.testing.assert_array_equal(np.asarray(ids[ti]), np.asarray(wids),
                                      err_msg=f"tier {ti}")
        np.testing.assert_array_equal(np.asarray(d[ti]), np.asarray(wd))
        np.testing.assert_array_equal(np.asarray(hops[ti]),
                                      np.asarray(whops))
        np.testing.assert_array_equal(np.asarray(cmps[ti]),
                                      np.asarray(wcmps))


_CROSS_TIER_DELETES = (0, 5, 399,      # LTI residents
                       2000, 2010,     # first RO snapshot residents
                       2149)           # RW resident


@pytest.mark.parametrize("W", [1, 4])
def test_unified_lti_lane_parity_with_deletes(points, queries, W):
    """The tentpole acceptance bar: LTI + RO + RW as ONE device program,
    bit-identical to the sequential search_lti + per-tier loop — with
    DeleteList members spread across every tier, at multiple beam widths."""
    sys_u = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    for s in (sys_u, sys_s):
        for e in _CROSS_TIER_DELETES:
            s.delete(e)
    ids_u, d_u = sys_u.search(queries, k=5, beam_width=W)
    ids_s, d_s = sys_s.search(queries, k=5, beam_width=W)
    np.testing.assert_array_equal(ids_u, ids_s)
    np.testing.assert_array_equal(d_u, d_s)
    assert not np.isin(ids_u, _CROSS_TIER_DELETES).any()


def test_unified_parity_across_delete_then_reinsert(points, queries):
    """A delete followed by re-insert revives the id in BOTH paths: the
    device-side drop-mask cache must see the revival (delete-epoch key)."""
    sys_u = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    for s in (sys_u, sys_s):
        s.search(queries[:4], k=5)          # warm the drop-mask cache
        s.delete(2000)
        s.delete(3)
        s.insert(3, points[3])              # revive an LTI resident
    ids_u, d_u = sys_u.search(queries, k=5)
    ids_s, d_s = sys_s.search(queries, k=5)
    np.testing.assert_array_equal(ids_u, ids_s)
    np.testing.assert_array_equal(d_u, d_s)
    assert 3 in np.asarray(sys_u.search(points[3:4], k=1)[0])


def test_unified_parity_after_merge(points, queries):
    """StreamingMerge retires RO tiers into the LTI; the unified program
    must restack and stay bit-identical to the oracle afterwards."""
    sys_u = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    for s in (sys_u, sys_s):
        s.delete(2001)
        s.merge()
    assert sys_u.stats.merges == 1 and not sys_u.ro
    ids_u, d_u = sys_u.search(queries, k=5)
    ids_s, d_s = sys_s.search(queries, k=5)
    np.testing.assert_array_equal(ids_u, ids_s)
    np.testing.assert_array_equal(d_u, d_s)


def test_unified_parity_after_localized_merge(points, queries):
    """Same restack contract when the merge's Delete phase runs the
    localized (affected-set) repair instead of the global sweep: the
    unified fan-out program must stay bit-identical to the oracle."""
    lcfg = IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                       L_search=64, alpha=1.2, repair_mode="local")
    sys_u = _three_tier_system(points, index=lcfg)
    sys_s = _three_tier_system(points, index=lcfg, batch_fanout=False)
    for s in (sys_u, sys_s):
        s.delete(5)                          # LTI resident -> Delete phase
        s.delete(2001)
        s.merge()
        assert s.stats.local_repairs >= 1 and s.stats.global_repairs == 0
    ids_u, d_u = sys_u.search(queries, k=5)
    ids_s, d_s = sys_s.search(queries, k=5)
    np.testing.assert_array_equal(ids_u, ids_s)
    np.testing.assert_array_equal(d_u, d_s)
    assert 5 not in np.asarray(ids_u)


def test_search_lanes_matches_dedicated_engines(points, queries):
    """Per-lane (ids, dists, hops, cmps) of the heterogeneous-lane search ==
    the dedicated engines: mem.search on each temp tier, search_lti on the
    PQ lane — counters included (IO-round accounting must not drift)."""
    icfg = IndexConfig(capacity=1024, dim=DIM, R=20, L_build=28,
                      L_search=40, alpha=1.2)
    pqc = PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)
    lti = build_lti(points[:500], icfg, pqc, batch=64)
    tcfg = IndexConfig(capacity=256, dim=DIM, R=20, L_build=28,
                       L_search=40, alpha=1.2)
    g1 = mem.build(points[500:700], tcfg, batch=32)
    g2 = mem.build(points[700:950], tcfg, batch=32)
    stack = stack_lanes([g1, g2], lti=lti.graph, codes=lti.codes,
                        codebook=lti.codebook.centroids)
    q = jnp.asarray(queries[:8])
    ids, d, hops, cmps = mem.search_lanes(stack, q, icfg, k=6, L=40)
    for ti, g in enumerate([g1, g2]):
        wids, wd, whops, wcmps = mem.search(g, q, icfg, k=6, L=40)
        np.testing.assert_array_equal(np.asarray(ids[ti]), np.asarray(wids),
                                      err_msg=f"lane {ti}")
        np.testing.assert_array_equal(np.asarray(d[ti]), np.asarray(wd))
        np.testing.assert_array_equal(np.asarray(hops[ti]), np.asarray(whops))
        np.testing.assert_array_equal(np.asarray(cmps[ti]), np.asarray(wcmps))
    wids, wd, whops, wcmps = search_lti(lti, q, icfg, k=6, L=40)
    np.testing.assert_array_equal(np.asarray(ids[2]), np.asarray(wids),
                                  err_msg="PQ lane")
    np.testing.assert_array_equal(np.asarray(d[2]), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(hops[2]), np.asarray(whops))
    np.testing.assert_array_equal(np.asarray(cmps[2]), np.asarray(wcmps))


def test_lane_stack_keeps_lti_at_own_capacity(points, queries):
    """The memory contract: temp lanes are padded to the largest TEMP
    capacity, NOT the LTI's — the stack is O(Tt x temp_cap), and the LTI
    lane rides at its own capacity with its codes un-padded."""
    icfg = IndexConfig(capacity=1024, dim=DIM, R=20, L_build=28,
                       L_search=40, alpha=1.2)
    pqc = PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)
    lti = build_lti(points[:500], icfg, pqc, batch=64)
    tcfg = IndexConfig(capacity=256, dim=DIM, R=20, L_build=28,
                       L_search=40, alpha=1.2)
    g1 = mem.build(points[500:700], tcfg, batch=32)
    g2 = mem.build(points[700:950], tcfg, batch=32)
    stack = stack_lanes([g1, g2], lti=lti.graph, codes=lti.codes,
                        codebook=lti.codebook.centroids)
    assert stack.temps.vectors.shape == (2, 256, DIM)   # temp cap, not 1024
    assert stack.lti.vectors.shape == (1024, DIM)
    assert stack.codes.shape[0] == 1024
    assert stack.n_lanes == 3 and stack.n_temp_lanes == 2
    # And the live system builds the same layout.
    sys_ = _three_tier_system(points)
    sys_._flush_inserts()              # buffered tail -> RW lane is live
    bundle = sys_._lane_bundle(*sys_._capture_lanes())
    _, bstack, t_tabs, l_tab, _, _ = bundle
    assert bstack.temps.vectors.shape[1] == sys_.cfg.temp_capacity
    assert bstack.lti.vectors.shape[0] == sys_.cfg.index.capacity
    assert t_tabs.shape == (3, sys_.cfg.temp_capacity)
    assert l_tab.shape == (sys_.cfg.index.capacity,)


def test_unified_int64_ids_under_x64(points, queries):
    """With jax_enable_x64 set, ids beyond int32 range ride the on-device
    merge as int64 pairs — no sequential fallback, bit-identical to the
    oracle."""
    import jax
    jax.config.update("jax_enable_x64", True)
    try:
        big = 2 ** 35
        def build(**kw):
            s = bootstrap_system(points[:300], np.arange(300),
                                 _sys_cfg(**kw))
            for i in range(40):
                s.insert(big + i, points[500 + i])
            return s
        sys_u = build()
        sys_s = build(batch_fanout=False)
        d0 = sys_u.stats.search_dispatches
        ids_u, d_u = sys_u.search(queries[:8], k=5)
        assert sys_u.stats.search_dispatches - d0 == 1   # no fallback
        ids_s, d_s = sys_s.search(queries[:8], k=5)
        np.testing.assert_array_equal(ids_u, ids_s)
        np.testing.assert_array_equal(d_u, d_s)
        got = sys_u.search(points[500:504], k=1)[0][:, 0]
        np.testing.assert_array_equal(got, big + np.arange(4))
    finally:
        jax.config.update("jax_enable_x64", False)


def test_unified_dispatch_count(points, queries):
    """The serving-cost claim: one device program per batch under the
    unified fan-out vs one per live tier (LTI + RW + 2 RO = 4) without."""
    sys_u = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    d0 = sys_u.stats.search_dispatches
    sys_u.search(queries[:4], k=5)
    assert sys_u.stats.search_dispatches - d0 == 1
    d0 = sys_s.stats.search_dispatches
    sys_s.search(queries[:4], k=5)
    assert sys_s.stats.search_dispatches - d0 == 4


def test_unified_parity_with_explicit_max_visits(points, queries):
    """An explicit IndexConfig.max_visits must bound temp lanes and the LTI
    lane identically in BOTH paths (temp_cfg mirrors every non-capacity
    field), or the unified program and the oracle diverge."""
    icfg = IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                       L_search=64, alpha=1.2, max_visits=40)
    sys_u = _three_tier_system(points, index=icfg)
    sys_s = _three_tier_system(points, index=icfg, batch_fanout=False)
    ids_u, d_u = sys_u.search(queries[:16], k=5)
    ids_s, d_s = sys_s.search(queries[:16], k=5)
    np.testing.assert_array_equal(ids_u, ids_s)
    np.testing.assert_array_equal(d_u, d_s)


def test_unified_falls_back_on_non_int32_ext_ids(points):
    """External ids outside int32 range cannot ride the on-device merge
    (ids travel as i32): the system must warn once and serve every search
    from the sequential oracle instead of silently wrapping the id."""
    import warnings
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    sys_.insert(-(2 ** 35), points[500])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sys_.search(points[:4], k=3)
        assert any("int32" in str(x.message) for x in w)
    d0 = sys_.stats.search_dispatches
    sys_.search(points[:4], k=3)
    assert sys_.stats.search_dispatches - d0 == 2    # LTI + RW, per tier


def test_rerank_candidates_masks_deleted():
    """Regression (§5.2 small fix): DeleteList members must be masked to
    INVALID *before* the exact-rerank gather so they don't burn rerank
    reads; valid live candidates pass through untouched."""
    reportable = jnp.asarray([True, False, True, False])
    ids = jnp.asarray([[0, 1, 2, 3, -1]], jnp.int32)
    out = np.asarray(rerank_candidates(ids, reportable))
    np.testing.assert_array_equal(out, [[0, -1, 2, -1, -1]])


def test_search_lti_rerank_ignores_deleted_vectors(points, queries):
    """End-to-end: poison the full-precision vectors of deleted LTI rows
    (simulating freed capacity-tier storage) — the rerank must not read
    them, so results stay finite and identical to the unpoisoned graph."""
    from repro.core.lti import LTIState
    icfg = IndexConfig(capacity=600, dim=DIM, R=20, L_build=28,
                       L_search=33, alpha=1.2)
    pqc = PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)
    lti = build_lti(points[:400], icfg, pqc, batch=64)
    victims = jnp.arange(0, 400, 7)
    g = lti.graph._replace(
        deleted=lti.graph.deleted.at[victims].set(True))
    poisoned = LTIState(
        g._replace(vectors=g.vectors.at[victims].set(jnp.nan)),
        lti.codes, lti.codebook)
    clean = LTIState(g, lti.codes, lti.codebook)
    q = jnp.asarray(queries[:8])
    ids_p, d_p, _, _ = search_lti(poisoned, q, icfg, k=5, L=33)
    ids_c, d_c, _, _ = search_lti(clean, q, icfg, k=5, L=33)
    np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_c))
    np.testing.assert_array_equal(np.asarray(d_p), np.asarray(d_c))
    assert np.isfinite(np.asarray(d_p)).all()
    assert not np.isin(np.asarray(ids_p), np.asarray(victims)).any()


def test_pad_graph_is_inert(points, queries):
    """Padding slots are inactive/unnavigable: search results unchanged."""
    cfg = IndexConfig(capacity=300, dim=DIM, R=16, L_build=24,
                      L_search=32, alpha=1.2)
    g = mem.build(points[:250], cfg, batch=64)
    q = jnp.asarray(queries[:8])
    a = mem.search(g, q, cfg, k=5, L=32)
    b = mem.search(pad_graph(g, 512), q, cfg, k=5, L=32)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_search_rejects_k_greater_than_L(points):
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    with pytest.raises(ValueError, match="k must be <= L"):
        sys_.search(points[:4], k=65, L=64)
    with pytest.raises(ValueError, match="k must be <= L"):
        sys_.search(points[:4], k=100)         # default L = 64


def test_threshold_merge_routes_through_background(points):
    """With background_merge on, the threshold merge runs on a worker thread;
    inserts return immediately and points stay searchable throughout."""
    sys_ = bootstrap_system(points[:400], np.arange(400),
                            _sys_cfg(merge_threshold=128,
                                     background_merge=True))
    for i in range(200):
        sys_.insert(2000 + i, points[500 + i])
    ids, _ = sys_.search(points[500:510], k=3)   # merge may be in flight
    assert (np.asarray(ids[:, 0]) == np.arange(2000, 2010)).mean() >= 0.8
    sys_.wait_merge()
    assert sys_.stats.merges >= 1
    ids, _ = sys_.search(points[500:510], k=3)
    assert (np.asarray(ids[:, 0]) == np.arange(2000, 2010)).mean() >= 0.8


def test_autotune_beam_picks_and_caches(points, queries):
    sys_ = bootstrap_system(points[:400], np.arange(400),
                            _sys_cfg(autotune_beam=True,
                                     merge_threshold=128))
    assert sys_._tuned_w is None
    sys_.search(queries[:4], k=5)
    w = sys_._tuned_w
    assert w in sys_.cfg.beam_width_candidates
    sys_.search(queries[:4], k=5)
    assert sys_._tuned_w == w                   # cached, not re-measured
    for i in range(160):                        # force a merge
        sys_.insert(3000 + i, points[600 + i])
    sys_.wait_merge()
    assert sys_.stats.merges >= 1
    assert sys_._tuned_w is None                # merge invalidates the cache
