"""Batched multi-tier query fan-out: the vmapped stacked-tier search must be
bit-identical to the sequential per-tier loop, tier padding must be inert,
k<=L must be validated, and threshold merges must honor the background knob."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import index as mem
from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.graph import pad_graph, stack_graphs
from repro.core.system import bootstrap_system

from conftest import DIM


def _sys_cfg(**kw):
    base = dict(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,   # keep tiers staged
        temp_capacity=256, insert_batch=32)
    base.update(kw)
    return SystemConfig(**base)


def _three_tier_system(points, **kw):
    """LTI + 2 frozen RO snapshots + a live RW tier."""
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg(**kw))
    for i in range(150):                       # 2 rollovers at 64 and 128
        sys_.insert(2000 + i, points[500 + i])
    return sys_


def test_batched_fanout_bit_identical_to_sequential(points, queries):
    """The acceptance bar: identical (ids, dists) on a 3-tier system."""
    sys_b = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    assert len(sys_b.ro) == 2 and len(sys_s.ro) == 2
    ids_b, d_b = sys_b.search(queries, k=5)
    ids_s, d_s = sys_s.search(queries, k=5)
    np.testing.assert_array_equal(ids_b, ids_s)
    np.testing.assert_array_equal(d_b, d_s)


def test_batched_fanout_bit_identical_kernel_path(points, queries):
    """Same parity with the Pallas ops layer engaged (interpret on CPU)."""
    kcfg = IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                       L_search=64, alpha=1.2, use_kernel=True)
    sys_b = _three_tier_system(points, index=kcfg)
    sys_s = _three_tier_system(points, index=kcfg, batch_fanout=False)
    ids_b, d_b = sys_b.search(queries[:8], k=5)
    ids_s, d_s = sys_s.search(queries[:8], k=5)
    np.testing.assert_array_equal(ids_b, ids_s)
    np.testing.assert_array_equal(d_b, d_s)


def test_search_tiers_matches_per_tier_search(points, queries):
    """index.search_tiers lane t == index.search on tier t, bit for bit —
    including a tier padded up to a larger common capacity."""
    cfg_small = IndexConfig(capacity=300, dim=DIM, R=16, L_build=24,
                            L_search=32, alpha=1.2)
    cfg_big = IndexConfig(capacity=512, dim=DIM, R=16, L_build=24,
                          L_search=32, alpha=1.2)
    g1 = mem.build(points[:250], cfg_small, batch=64)
    g2 = mem.build(points[250:600], cfg_big, batch=64)
    q = jnp.asarray(queries[:8])
    stacked = stack_graphs([g1, g2])           # pads g1 from 300 -> 512
    ids, d, hops, cmps = mem.search_tiers(stacked, q, cfg_big, k=5, L=32)
    for ti, (g, cfg) in enumerate([(g1, cfg_small), (g2, cfg_big)]):
        wids, wd, whops, wcmps = mem.search(g, q, cfg_big, k=5, L=32)
        np.testing.assert_array_equal(np.asarray(ids[ti]), np.asarray(wids),
                                      err_msg=f"tier {ti}")
        np.testing.assert_array_equal(np.asarray(d[ti]), np.asarray(wd))
        np.testing.assert_array_equal(np.asarray(hops[ti]),
                                      np.asarray(whops))
        np.testing.assert_array_equal(np.asarray(cmps[ti]),
                                      np.asarray(wcmps))


def test_pad_graph_is_inert(points, queries):
    """Padding slots are inactive/unnavigable: search results unchanged."""
    cfg = IndexConfig(capacity=300, dim=DIM, R=16, L_build=24,
                      L_search=32, alpha=1.2)
    g = mem.build(points[:250], cfg, batch=64)
    q = jnp.asarray(queries[:8])
    a = mem.search(g, q, cfg, k=5, L=32)
    b = mem.search(pad_graph(g, 512), q, cfg, k=5, L=32)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_search_rejects_k_greater_than_L(points):
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    with pytest.raises(ValueError, match="k must be <= L"):
        sys_.search(points[:4], k=65, L=64)
    with pytest.raises(ValueError, match="k must be <= L"):
        sys_.search(points[:4], k=100)         # default L = 64


def test_threshold_merge_routes_through_background(points):
    """With background_merge on, the threshold merge runs on a worker thread;
    inserts return immediately and points stay searchable throughout."""
    sys_ = bootstrap_system(points[:400], np.arange(400),
                            _sys_cfg(merge_threshold=128,
                                     background_merge=True))
    for i in range(200):
        sys_.insert(2000 + i, points[500 + i])
    ids, _ = sys_.search(points[500:510], k=3)   # merge may be in flight
    assert (np.asarray(ids[:, 0]) == np.arange(2000, 2010)).mean() >= 0.8
    sys_.wait_merge()
    assert sys_.stats.merges >= 1
    ids, _ = sys_.search(points[500:510], k=3)
    assert (np.asarray(ids[:, 0]) == np.arange(2000, 2010)).mean() >= 0.8


def test_autotune_beam_picks_and_caches(points, queries):
    sys_ = bootstrap_system(points[:400], np.arange(400),
                            _sys_cfg(autotune_beam=True,
                                     merge_threshold=128))
    assert sys_._tuned_w is None
    sys_.search(queries[:4], k=5)
    w = sys_._tuned_w
    assert w in sys_.cfg.beam_width_candidates
    sys_.search(queries[:4], k=5)
    assert sys_._tuned_w == w                   # cached, not re-measured
    for i in range(160):                        # force a merge
        sys_.insert(3000 + i, points[600 + i])
    sys_.wait_merge()
    assert sys_.stats.merges >= 1
    assert sys_._tuned_w is None                # merge invalidates the cache
