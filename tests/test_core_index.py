"""FreshVamana core: build quality, insert/delete correctness, counters."""
import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig
from repro.core.delete import consolidate_deletes, delete
from repro.core.graph import degree_stats
from repro.core.index import brute_force, build, insert, recall_at_k, search
from repro.core.prune import check_alpha_rng

from conftest import DIM, N


def _recall(state, cfg, queries, k=5, L=None):
    ids, d, hops, cmps = search(state, jnp.asarray(queries), cfg,
                                k=k, L=L or cfg.L_search)
    mask = state.active & ~state.deleted
    gt = brute_force(state.vectors, mask, jnp.asarray(queries), k)
    return float(recall_at_k(ids, gt)), hops, cmps


def test_build_recall(built_index, index_cfg, queries):
    rec, hops, cmps = _recall(built_index, index_cfg, queries)
    assert rec >= 0.90, f"build recall too low: {rec}"


def test_search_counters_bounded(built_index, index_cfg, queries):
    _, hops, cmps = _recall(built_index, index_cfg, queries)
    # paper §6.2: IO (hops) is about the candidate-list size, not O(N)
    assert float(hops.mean()) < 2.5 * index_cfg.L_search
    assert float(cmps.mean()) < N  # tiny fraction of brute force


def test_degrees_bounded(built_index, index_cfg):
    st = degree_stats(built_index)
    assert float(st["max_degree"]) <= index_cfg.R
    assert float(st["avg_degree"]) > 4


def test_alpha_rng_property_after_prune(built_index, index_cfg):
    """Rows satisfy the alpha-RNG invariant immediately after RobustPrune.

    (Raw graph rows may legitimately violate it: Algorithm 2 APPENDS
    back-edges without pruning while the degree budget allows — only
    pruned rows carry the invariant, which is what we check here.)
    """
    from repro.core.prune import prune_node
    vecs = built_index.vectors
    usable = built_index.active & ~built_index.deleted
    for p in range(0, N, 97):
        row = built_index.adjacency[p]
        res = prune_node(vecs, jnp.int32(p), row, usable,
                         index_cfg.alpha, index_cfg.R)
        assert bool(check_alpha_rng(res.ids, vecs[p], vecs,
                                    index_cfg.alpha)), p


def test_insert_new_points_searchable(built_index, index_cfg, points, rng):
    new = (points[:32] + 0.01).astype(np.float32)
    slots = jnp.arange(N, N + 32, dtype=jnp.int32)
    st = insert(built_index, slots, jnp.asarray(new), index_cfg)
    ids, d, _, _ = search(st, jnp.asarray(new), index_cfg, k=1, L=48)
    found = np.asarray(ids[:, 0])
    # the nearest neighbor of an inserted point should be itself (or its
    # near-duplicate source point)
    ok = (found == np.arange(N, N + 32)) | (found == np.arange(32))
    assert ok.mean() >= 0.9


def test_lazy_delete_filters_results(built_index, index_cfg, points):
    q = points[:8]
    ids0, *_ = search(built_index, jnp.asarray(q), index_cfg, k=1, L=48)
    victims = ids0[:, 0]
    st = delete(built_index, victims)
    ids1, *_ = search(st, jnp.asarray(q), index_cfg, k=5, L=48)
    assert not bool((ids1 == victims[:, None]).any())


def test_consolidate_removes_edges_and_reclaims(built_index, index_cfg, rng):
    victims = jnp.asarray(rng.choice(N, 100, replace=False).astype(np.int32))
    st = delete(built_index, victims)
    st = consolidate_deletes(st, index_cfg, block=256)
    adj = np.asarray(st.adjacency)
    vic = np.asarray(victims)
    live_rows = adj[np.setdiff1d(np.arange(N), vic)]
    assert not np.isin(live_rows[live_rows >= 0], vic).any()
    assert not bool(st.active[victims].any())
    assert not bool(st.deleted.any())


def test_consolidated_recall_holds(built_index, index_cfg, queries, rng):
    victims = jnp.asarray(rng.choice(N, 120, replace=False).astype(np.int32))
    st = consolidate_deletes(delete(built_index, victims), index_cfg)
    rec, *_ = _recall(st, index_cfg, queries)
    assert rec >= 0.88, rec


def test_masked_insert_lanes_noop(built_index, index_cfg, points):
    slots = jnp.asarray([N, -1, N + 1, -1], dtype=jnp.int32)
    st = insert(built_index, slots, jnp.asarray(points[:4]), index_cfg)
    assert int(st.active.sum()) == N + 2
