"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n,m,ksub,q", [
    (64, 8, 16, 1), (200, 8, 64, 5), (128, 16, 256, 3),
    (1000, 32, 256, 2), (37, 4, 16, 9),
])
def test_adc_matches_ref(n, m, ksub, q):
    codes = jnp.asarray(rng.integers(0, ksub, (n, m)).astype(np.uint8))
    luts = jnp.asarray(
        rng.standard_normal((q, m, ksub)).astype(np.float32)) ** 2
    got = ops.adc_distances(codes, luts)
    want = jax.vmap(lambda t: ref.adc_distances_ref(codes, t))(luts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,n,d", [
    (1, 128, 32), (37, 190, 48), (128, 256, 128), (5, 1000, 17),
    (64, 64, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_l2_matches_ref(q, n, d, dtype):
    qq = jnp.asarray(rng.standard_normal((q, d)).astype(dtype))
    xx = jnp.asarray(rng.standard_normal((n, d)).astype(dtype))
    got = ops.l2_distances(qq, xx)
    want = ref.l2_distances_ref(qq, xx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("q,n,k", [
    (1, 300, 10), (7, 300, 10), (8, 512, 1), (3, 1024, 64), (9, 77, 5),
])
def test_topk_matches_ref(q, n, k):
    d = jnp.asarray(rng.standard_normal((q, n)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int32)
    gd, gi = ops.block_topk(d, ids, k)
    wd, wi = ref.block_topk_ref(d, ids, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), atol=1e-6)
    assert (np.asarray(gi) == np.asarray(wi)).all()


def test_topk_with_inf_padding():
    d = jnp.asarray([[1.0, jnp.inf, 0.5, jnp.inf, 2.0]])
    ids = jnp.asarray([10, 11, 12, 13, 14], jnp.int32)
    gd, gi = ops.block_topk(d, ids, 4)
    assert list(np.asarray(gi[0])[:3]) == [12, 10, 14]
    assert np.asarray(gi[0])[3] == -1   # inf -> id -1


def test_adc_is_used_equivalently_in_core():
    """core.pq.adc == kernel adc (the wiring contract)."""
    from repro.core import pq as pqm
    from repro.core.config import PQConfig
    cfg = PQConfig(dim=32, m=8, ksub=32, kmeans_iters=3)
    data = jnp.asarray(rng.standard_normal((256, 32)).astype(np.float32))
    cb = pqm.train_pq(data, cfg)
    codes = pqm.encode(cb, data, cfg)
    qv = data[7]
    table = pqm.lut(cb, qv)
    want = pqm.adc(codes, table)
    got = ops.adc_distances(codes, table[None])[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
