"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n,m,ksub,q", [
    (64, 8, 16, 1), (200, 8, 64, 5), (128, 16, 256, 3),
    (1000, 32, 256, 2), (37, 4, 16, 9),
])
def test_adc_matches_ref(n, m, ksub, q):
    codes = jnp.asarray(rng.integers(0, ksub, (n, m)).astype(np.uint8))
    luts = jnp.asarray(
        rng.standard_normal((q, m, ksub)).astype(np.float32)) ** 2
    got = ops.adc_distances(codes, luts)
    want = jax.vmap(lambda t: ref.adc_distances_ref(codes, t))(luts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,n,d", [
    (1, 128, 32), (37, 190, 48), (128, 256, 128), (5, 1000, 17),
    (64, 64, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_l2_matches_ref(q, n, d, dtype):
    qq = jnp.asarray(rng.standard_normal((q, d)).astype(dtype))
    xx = jnp.asarray(rng.standard_normal((n, d)).astype(dtype))
    got = ops.l2_distances(qq, xx)
    want = ref.l2_distances_ref(qq, xx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("q,n,k", [
    (1, 300, 10), (7, 300, 10), (8, 512, 1), (3, 1024, 64), (9, 77, 5),
])
def test_topk_matches_ref(q, n, k):
    d = jnp.asarray(rng.standard_normal((q, n)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int32)
    gd, gi = ops.block_topk(d, ids, k)
    wd, wi = ref.block_topk_ref(d, ids, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), atol=1e-6)
    assert (np.asarray(gi) == np.asarray(wi)).all()


def test_topk_with_inf_padding():
    d = jnp.asarray([[1.0, jnp.inf, 0.5, jnp.inf, 2.0]])
    ids = jnp.asarray([10, 11, 12, 13, 14], jnp.int32)
    gd, gi = ops.block_topk(d, ids, 4)
    assert list(np.asarray(gi[0])[:3]) == [12, 10, 14]
    assert np.asarray(gi[0])[3] == -1   # inf -> id -1


def _frontier_case(seed, L, K, V, W, nvis_frac=0.5):
    """A random but engine-consistent frontier_select input: sorted candidate
    list with an INVALID tail, fresh neighbors with masked lanes, a visited
    set that is a subset of the candidate ids, vis_cnt == occupancy."""
    r = np.random.default_rng(seed)
    ncand = int(r.integers(1, L + 1))
    nnew = int(r.integers(0, K + 1))
    pool = r.permutation(10_000)[:ncand + nnew].astype(np.int32)
    cand_ids = np.full(L, -1, np.int32)
    cand_d = np.full(L, np.inf, np.float32)
    cand_ids[:ncand] = pool[:ncand]
    cand_d[:ncand] = np.sort(r.random(ncand).astype(np.float32))
    new_ids = np.full(K, -1, np.int32)
    new_d = np.full(K, np.inf, np.float32)
    new_ids[:nnew] = pool[ncand:]
    new_d[:nnew] = r.random(nnew).astype(np.float32)
    vis_ids = np.full(V, -1, np.int32)
    vis_d = np.full(V, np.inf, np.float32)
    nvis = min(int(ncand * nvis_frac), V - 1)
    taken = r.permutation(ncand)[:nvis]
    vis_ids[:nvis] = cand_ids[taken]
    vis_d[:nvis] = cand_d[taken]
    args = tuple(jnp.asarray(x) for x in
                 (cand_ids, cand_d, new_ids, new_d, vis_ids, vis_d))
    return args + (jnp.int32(nvis),)


@pytest.mark.parametrize("W", [1, 4, 16])       # 16 == L: full-width beam
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_frontier_select_matches_ref(seed, W):
    """Fused kernel vs jnp reference: bit-identical merged list, frontier,
    and visited arrays — including INVALID-padded candidate/neighbor lanes."""
    L, K, V = 16, 24, 30
    args = _frontier_case(seed, L, K, V, W)
    want = ops.frontier_select(*args, W=W, max_visits=V, use_kernel=False)
    got = ops.frontier_select(*args, W=W, max_visits=V, use_kernel=True)
    names = ["m_ids", "m_d", "f_ids", "f_d", "vis_ids", "vis_d", "vis_cnt"]
    for w, g, name in zip(want, got, names):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                      err_msg=f"{name} (W={W}, seed={seed})")


def test_frontier_select_visit_budget():
    """The frontier never exceeds the remaining visit budget, and a full
    visited set yields an empty frontier (the loop's stop condition)."""
    L, K, V, W = 8, 8, 6, 4
    args = _frontier_case(7, L, K, V, W, nvis_frac=0.0)
    # Exhaust the budget: visited occupancy == max_visits.
    full_vis = jnp.asarray(np.arange(20_000, 20_000 + V, dtype=np.int32))
    full_vd = jnp.zeros((V,), jnp.float32)
    for use_kernel in (False, True):
        out = ops.frontier_select(args[0], args[1], args[2], args[3],
                                  full_vis, full_vd, jnp.int32(V),
                                  W=W, max_visits=V, use_kernel=use_kernel)
        assert (np.asarray(out[2]) == -1).all()      # empty frontier
        assert int(out[6]) == V                      # count unchanged


def test_frontier_select_under_vmap():
    """The engine calls frontier_select inside jax.vmap over query lanes."""
    L, K, V, W = 12, 16, 20, 3
    batched = [jnp.stack(x) for x in zip(*[
        _frontier_case(100 + i, L, K, V, W) for i in range(5)])]

    def run(use_kernel):
        return jax.vmap(lambda *a: ops.frontier_select(
            *a, W=W, max_visits=V, use_kernel=use_kernel))(*batched)

    for w, g in zip(run(False), run(True)):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def _prune_case(seed, C, d, with_dups=False):
    """A random prune-engine input: candidate ids (optionally duplicated),
    usability mask, anchor distances from a real anchor vector."""
    r = np.random.default_rng(seed)
    vecs = r.standard_normal((C, d)).astype(np.float32)
    ids = r.permutation(10_000)[:C].astype(np.int32)
    if with_dups:
        ids[C // 2:] = ids[:C - C // 2]
    ids[r.random(C) < 0.1] = -1
    ok = (ids >= 0) & (r.random(C) > 0.25)
    anchor = r.standard_normal(d).astype(np.float32)
    diff = anchor[None] - vecs
    d_p = (diff * diff).sum(-1)
    return (jnp.asarray(d_p), jnp.asarray(vecs), jnp.asarray(ids),
            jnp.asarray(ok))


@pytest.mark.parametrize("alpha", [1.0, 1.2])
@pytest.mark.parametrize("seed,C,d,R", [
    (0, 40, 16, 8), (1, 130, 24, 12), (2, 7, 8, 16), (3, 260, 32, 4),
])
def test_robust_prune_fp_matches_ref(seed, C, d, R, alpha):
    """Fused prune kernel vs the jnp contract: bit-identical selected ids
    and counts, including INVALID lanes, masked lanes, and duplicates."""
    args = [jnp.stack(x) for x in zip(
        _prune_case(seed, C, d, with_dups=seed % 2 == 1),
        _prune_case(seed + 100, C, d))]
    w_ids, w_cnt = ops.robust_prune_fp(*args, alpha=alpha, R=R,
                                       use_kernel=False)
    g_ids, g_cnt = ops.robust_prune_fp(*args, alpha=alpha, R=R,
                                       use_kernel=True)
    np.testing.assert_array_equal(np.asarray(w_ids), np.asarray(g_ids))
    np.testing.assert_array_equal(np.asarray(w_cnt), np.asarray(g_cnt))


def _sdc_case(seed, C, m, ksub):
    r = np.random.default_rng(seed)
    cent = r.standard_normal((m, ksub, 3)).astype(np.float32)
    diff = cent[:, :, None, :] - cent[:, None, :, :]
    tables = jnp.asarray((diff * diff).sum(-1))
    codes = r.integers(0, ksub, (C, m)).astype(np.int32)
    ids = r.permutation(10_000)[:C].astype(np.int32)
    ids[r.random(C) < 0.1] = -1
    ok = (ids >= 0) & (r.random(C) > 0.25)
    lut = np.asarray(tables)[np.arange(m), codes[0]]
    d_p = lut[np.arange(m)[None, :], codes].sum(-1)
    return (jnp.asarray(d_p), jnp.asarray(codes), tables,
            jnp.asarray(ids), jnp.asarray(ok))


@pytest.mark.parametrize("seed,C,m,ksub,R", [
    (0, 40, 8, 16, 8), (1, 130, 8, 64, 12), (2, 60, 16, 32, 6),
])
def test_robust_prune_sdc_matches_ref(seed, C, m, ksub, R):
    d_p, codes, tables, ids, ok = _sdc_case(seed, C, m, ksub)
    args = (d_p[None], codes[None], tables, ids[None], ok[None])
    w_ids, w_cnt = ops.robust_prune_sdc(*args, alpha=1.2, R=R,
                                        use_kernel=False)
    g_ids, g_cnt = ops.robust_prune_sdc(*args, alpha=1.2, R=R,
                                        use_kernel=True)
    np.testing.assert_array_equal(np.asarray(w_ids), np.asarray(g_ids))
    np.testing.assert_array_equal(np.asarray(w_cnt), np.asarray(g_cnt))


def test_robust_prune_block_matches_per_row():
    """One block launch over B rows == B independent single-row launches
    (rows must not leak into each other through the block batching)."""
    cases = [_prune_case(50 + i, 48, 16) for i in range(6)]
    batched = [jnp.stack(x) for x in zip(*cases)]
    g_ids, g_cnt = ops.robust_prune_fp(*batched, alpha=1.2, R=8,
                                       use_kernel=True)
    for b, case in enumerate(cases):
        one_ids, one_cnt = ops.robust_prune_fp(
            *[x[None] for x in case], alpha=1.2, R=8, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(g_ids[b]),
                                      np.asarray(one_ids[0]))
        assert int(g_cnt[b]) == int(one_cnt[0])


def _repair_case(seed, N, R, d, cap=None):
    """An Algorithm-4 node repair input over a small random graph."""
    r = np.random.default_rng(seed)
    vecs = jnp.asarray(r.standard_normal((N, d)).astype(np.float32))
    adj = jnp.asarray(r.integers(-1, N, (N, R)).astype(np.int32))
    deleted = jnp.asarray(r.random(N) < 0.2)
    usable = ~deleted
    p = jnp.int32(int(r.integers(0, N)))
    row = adj[p]
    safe = jnp.maximum(row, 0)
    nbr_del = (row >= 0) & deleted[safe]
    if cap is None:
        exp, exp_ok = adj[safe], nbr_del
    else:
        take, idx = jax.lax.top_k(nbr_del.astype(jnp.int32), cap)
        exp = adj[jnp.where(take > 0, row[idx], 0)]
        exp_ok = take > 0
    raw = jnp.concatenate([row, exp.reshape(-1)])
    safe_raw = jnp.maximum(raw, 0)
    dd = vecs[p][None] - vecs[safe_raw]
    d_p = jnp.sum(dd * dd, -1)
    return (row, nbr_del, exp, exp_ok, usable[safe_raw], d_p,
            vecs[safe_raw], p, usable[p], vecs, safe_raw)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_delete_repair_fp_matches_ref(seed):
    """Fused repair kernel vs the jnp contract on engine-shaped inputs
    (a block of two nodes per launch)."""
    args = [jnp.stack(x) for x in zip(_repair_case(seed, 90, 12, 16)[:9],
                                      _repair_case(seed + 50, 90, 12,
                                                   16)[:9])]
    w = ops.delete_repair_fp(*args, alpha=1.2, R=12, use_kernel=False)
    g = ops.delete_repair_fp(*args, alpha=1.2, R=12, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_delete_repair_sdc_matches_ref(seed):
    """Capped SDC repair: kernel vs ref, codes/tables path."""
    from repro.core import pq as pqm
    from repro.core.config import PQConfig
    N, R, d, cap = 90, 12, 16, 4
    (row, nbr_del, exp, exp_ok, usable_c, _, _, p, live, vecs,
     safe_raw) = _repair_case(seed, N, R, d, cap=cap)
    pq_cfg = PQConfig(dim=d, m=4, ksub=16, kmeans_iters=3)
    cb = pqm.train_pq(vecs, pq_cfg)
    codes = pqm.encode(cb, vecs, pq_cfg)
    tables = pqm.sdc_tables(cb)
    d_p = pqm.adc(codes[safe_raw], pqm.sdc_lut(tables, codes[p]))
    cand_codes = codes[safe_raw].astype(jnp.int32)
    args = [x[None] for x in (row, nbr_del, exp, exp_ok, usable_c, d_p,
                              cand_codes)] + [tables, p[None], live[None]]
    w = ops.delete_repair_sdc(*args, alpha=1.2, R=R, use_kernel=False)
    g = ops.delete_repair_sdc(*args, alpha=1.2, R=R, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_robust_prune_padding_lanes_inert():
    """The compiled path pads the candidate axis to a 128 multiple with
    (+inf, -1, zero) lanes; a padded launch must match the unpadded one
    (on CPU only the unpadded branch runs, so exercise padding directly)."""
    from repro.kernels.ops import _pad_to
    from repro.kernels.robust_prune import robust_prune_fp_kernel
    d_p, vecs, ids, ok = _prune_case(3, 60, 16)
    dm = jnp.where(ok, d_p, jnp.inf)[None]
    ids = ids[None].astype(jnp.int32)
    unp = robust_prune_fp_kernel(dm, vecs[None], ids, alpha=1.2, R=8,
                                 interpret=True)
    pad = robust_prune_fp_kernel(
        _pad_to(dm, 1, 128, jnp.inf), _pad_to(vecs[None], 1, 128, 0.0),
        _pad_to(ids, 1, 128, -1), alpha=1.2, R=8, interpret=True)
    for u, p in zip(unp, pad):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(p))


def test_delete_repair_padding_lanes_inert():
    """Same contract for the repair kernel: the wrapper's padded operand
    layout (expansion lanes -1/0, +inf distances) must be inert."""
    from repro.kernels.ops import _pad_payload, _repair_operands
    from repro.kernels.delete_repair import delete_repair_fp_kernel
    case = [x[None] for x in _repair_case(5, 90, 12, 16)[:9]]
    row, nbr_del, exp, exp_ok, usable_c, d_p, cand_vecs, p, live = case
    outs = []
    for pad in (False, True):
        r, nd, e, eok, us, dp, pp, lv = _repair_operands(
            row, nbr_del, exp, exp_ok, usable_c, d_p, p, live,
            pad_lanes=pad)
        vecs = _pad_payload(cand_vecs.astype(jnp.float32), pad)
        outs.append(delete_repair_fp_kernel(
            r, nd, e, eok, us, dp, vecs, pp, lv, alpha=1.2, R=12,
            interpret=True))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_batch_distances_kernel_parity_both_backends():
    """batch_distances: kernels.ops vs jnp reference on FullPrecision and PQ
    backends, with INVALID-masked id lanes -> +inf on both paths."""
    from repro.core import pq as pqm
    from repro.core.config import PQConfig
    from repro.core.search import (FullPrecisionBackend, PQBackend,
                                   batch_distances)
    dim, n, B, K = 32, 400, 6, 40
    vecs = jnp.asarray(rng.standard_normal((n, dim)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((B, dim)).astype(np.float32))
    ids = rng.integers(0, n, (B, K)).astype(np.int32)
    ids[:, -5:] = -1
    ids = jnp.asarray(ids)

    fp = FullPrecisionBackend(vecs)
    d_ref = batch_distances(fp, qs, ids, use_kernel=False)
    d_ker = batch_distances(fp, qs, ids, use_kernel=True)
    assert bool(jnp.isinf(d_ref[:, -5:]).all())
    assert bool(jnp.isinf(d_ker[:, -5:]).all())
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)

    pq_cfg = PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=3)
    cb = pqm.train_pq(vecs, pq_cfg)
    codes = pqm.encode(cb, vecs, pq_cfg)
    pq = PQBackend(codes, cb)
    d_ref = batch_distances(pq, qs, ids, use_kernel=False)
    d_ker = batch_distances(pq, qs, ids, use_kernel=True)
    assert bool(jnp.isinf(d_ref[:, -5:]).all())
    assert bool(jnp.isinf(d_ker[:, -5:]).all())
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)


def test_adc_is_used_equivalently_in_core():
    """core.pq.adc == kernel adc (the wiring contract)."""
    from repro.core import pq as pqm
    from repro.core.config import PQConfig
    cfg = PQConfig(dim=32, m=8, ksub=32, kmeans_iters=3)
    data = jnp.asarray(rng.standard_normal((256, 32)).astype(np.float32))
    cb = pqm.train_pq(data, cfg)
    codes = pqm.encode(cb, data, cfg)
    qv = data[7]
    table = pqm.lut(cb, qv)
    want = pqm.adc(codes, table)
    got = ops.adc_distances(codes, table[None])[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
