"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.default_rng(0)


@pytest.mark.parametrize("n,m,ksub,q", [
    (64, 8, 16, 1), (200, 8, 64, 5), (128, 16, 256, 3),
    (1000, 32, 256, 2), (37, 4, 16, 9),
])
def test_adc_matches_ref(n, m, ksub, q):
    codes = jnp.asarray(rng.integers(0, ksub, (n, m)).astype(np.uint8))
    luts = jnp.asarray(
        rng.standard_normal((q, m, ksub)).astype(np.float32)) ** 2
    got = ops.adc_distances(codes, luts)
    want = jax.vmap(lambda t: ref.adc_distances_ref(codes, t))(luts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,n,d", [
    (1, 128, 32), (37, 190, 48), (128, 256, 128), (5, 1000, 17),
    (64, 64, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_l2_matches_ref(q, n, d, dtype):
    qq = jnp.asarray(rng.standard_normal((q, d)).astype(dtype))
    xx = jnp.asarray(rng.standard_normal((n, d)).astype(dtype))
    got = ops.l2_distances(qq, xx)
    want = ref.l2_distances_ref(qq, xx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("q,n,k", [
    (1, 300, 10), (7, 300, 10), (8, 512, 1), (3, 1024, 64), (9, 77, 5),
])
def test_topk_matches_ref(q, n, k):
    d = jnp.asarray(rng.standard_normal((q, n)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int32)
    gd, gi = ops.block_topk(d, ids, k)
    wd, wi = ref.block_topk_ref(d, ids, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), atol=1e-6)
    assert (np.asarray(gi) == np.asarray(wi)).all()


def test_topk_with_inf_padding():
    d = jnp.asarray([[1.0, jnp.inf, 0.5, jnp.inf, 2.0]])
    ids = jnp.asarray([10, 11, 12, 13, 14], jnp.int32)
    gd, gi = ops.block_topk(d, ids, 4)
    assert list(np.asarray(gi[0])[:3]) == [12, 10, 14]
    assert np.asarray(gi[0])[3] == -1   # inf -> id -1


def _frontier_case(seed, L, K, V, W, nvis_frac=0.5):
    """A random but engine-consistent frontier_select input: sorted candidate
    list with an INVALID tail, fresh neighbors with masked lanes, a visited
    set that is a subset of the candidate ids, vis_cnt == occupancy."""
    r = np.random.default_rng(seed)
    ncand = int(r.integers(1, L + 1))
    nnew = int(r.integers(0, K + 1))
    pool = r.permutation(10_000)[:ncand + nnew].astype(np.int32)
    cand_ids = np.full(L, -1, np.int32)
    cand_d = np.full(L, np.inf, np.float32)
    cand_ids[:ncand] = pool[:ncand]
    cand_d[:ncand] = np.sort(r.random(ncand).astype(np.float32))
    new_ids = np.full(K, -1, np.int32)
    new_d = np.full(K, np.inf, np.float32)
    new_ids[:nnew] = pool[ncand:]
    new_d[:nnew] = r.random(nnew).astype(np.float32)
    vis_ids = np.full(V, -1, np.int32)
    vis_d = np.full(V, np.inf, np.float32)
    nvis = min(int(ncand * nvis_frac), V - 1)
    taken = r.permutation(ncand)[:nvis]
    vis_ids[:nvis] = cand_ids[taken]
    vis_d[:nvis] = cand_d[taken]
    args = tuple(jnp.asarray(x) for x in
                 (cand_ids, cand_d, new_ids, new_d, vis_ids, vis_d))
    return args + (jnp.int32(nvis),)


@pytest.mark.parametrize("W", [1, 4, 16])       # 16 == L: full-width beam
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_frontier_select_matches_ref(seed, W):
    """Fused kernel vs jnp reference: bit-identical merged list, frontier,
    and visited arrays — including INVALID-padded candidate/neighbor lanes."""
    L, K, V = 16, 24, 30
    args = _frontier_case(seed, L, K, V, W)
    want = ops.frontier_select(*args, W=W, max_visits=V, use_kernel=False)
    got = ops.frontier_select(*args, W=W, max_visits=V, use_kernel=True)
    names = ["m_ids", "m_d", "f_ids", "f_d", "vis_ids", "vis_d", "vis_cnt"]
    for w, g, name in zip(want, got, names):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                      err_msg=f"{name} (W={W}, seed={seed})")


def test_frontier_select_visit_budget():
    """The frontier never exceeds the remaining visit budget, and a full
    visited set yields an empty frontier (the loop's stop condition)."""
    L, K, V, W = 8, 8, 6, 4
    args = _frontier_case(7, L, K, V, W, nvis_frac=0.0)
    # Exhaust the budget: visited occupancy == max_visits.
    full_vis = jnp.asarray(np.arange(20_000, 20_000 + V, dtype=np.int32))
    full_vd = jnp.zeros((V,), jnp.float32)
    for use_kernel in (False, True):
        out = ops.frontier_select(args[0], args[1], args[2], args[3],
                                  full_vis, full_vd, jnp.int32(V),
                                  W=W, max_visits=V, use_kernel=use_kernel)
        assert (np.asarray(out[2]) == -1).all()      # empty frontier
        assert int(out[6]) == V                      # count unchanged


def test_frontier_select_under_vmap():
    """The engine calls frontier_select inside jax.vmap over query lanes."""
    L, K, V, W = 12, 16, 20, 3
    batched = [jnp.stack(x) for x in zip(*[
        _frontier_case(100 + i, L, K, V, W) for i in range(5)])]

    def run(use_kernel):
        return jax.vmap(lambda *a: ops.frontier_select(
            *a, W=W, max_visits=V, use_kernel=use_kernel))(*batched)

    for w, g in zip(run(False), run(True)):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_batch_distances_kernel_parity_both_backends():
    """batch_distances: kernels.ops vs jnp reference on FullPrecision and PQ
    backends, with INVALID-masked id lanes -> +inf on both paths."""
    from repro.core import pq as pqm
    from repro.core.config import PQConfig
    from repro.core.search import (FullPrecisionBackend, PQBackend,
                                   batch_distances)
    dim, n, B, K = 32, 400, 6, 40
    vecs = jnp.asarray(rng.standard_normal((n, dim)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((B, dim)).astype(np.float32))
    ids = rng.integers(0, n, (B, K)).astype(np.int32)
    ids[:, -5:] = -1
    ids = jnp.asarray(ids)

    fp = FullPrecisionBackend(vecs)
    d_ref = batch_distances(fp, qs, ids, use_kernel=False)
    d_ker = batch_distances(fp, qs, ids, use_kernel=True)
    assert bool(jnp.isinf(d_ref[:, -5:]).all())
    assert bool(jnp.isinf(d_ker[:, -5:]).all())
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)

    pq_cfg = PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=3)
    cb = pqm.train_pq(vecs, pq_cfg)
    codes = pqm.encode(cb, vecs, pq_cfg)
    pq = PQBackend(codes, cb)
    d_ref = batch_distances(pq, qs, ids, use_kernel=False)
    d_ker = batch_distances(pq, qs, ids, use_kernel=True)
    assert bool(jnp.isinf(d_ref[:, -5:]).all())
    assert bool(jnp.isinf(d_ker[:, -5:]).all())
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)


def test_adc_is_used_equivalently_in_core():
    """core.pq.adc == kernel adc (the wiring contract)."""
    from repro.core import pq as pqm
    from repro.core.config import PQConfig
    cfg = PQConfig(dim=32, m=8, ksub=32, kmeans_iters=3)
    data = jnp.asarray(rng.standard_normal((256, 32)).astype(np.float32))
    cb = pqm.train_pq(data, cfg)
    codes = pqm.encode(cb, data, cfg)
    qv = data[7]
    table = pqm.lut(cb, qv)
    want = pqm.adc(codes, table)
    got = ops.adc_distances(codes, table[None])[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
