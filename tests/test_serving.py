"""The batched serving engine (docs/SERVING.md): `search_batch` must be
bit-identical per query to serving each query alone — across deletes, after
a merge, with and without the PQ-navigated LTI lane; `batch_queries`
micro-batching must chunk/pad without changing any result while
`search_dispatches` counts programs (B queries in one launch == 1); the
mesh-sharded LTI lane (`shard_lti`) must return bit-identical results for
any shard count — exercised in-process on 1 device and, via the
`scripts/shard_probe.py` subprocess, on 4 fake host devices; and the
query-batched `frontier_select` launch must match its vmapped reference."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.system import FreshDiskANN, bootstrap_system
from repro.kernels import ops

from conftest import DIM


def _sys_cfg(**kw):
    base = dict(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,   # keep tiers staged
        temp_capacity=256, insert_batch=32)
    base.update(kw)
    return SystemConfig(**base)


def _three_tier_system(points, **kw):
    """LTI + 2 frozen RO snapshots + a live RW tier."""
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg(**kw))
    for i in range(150):
        sys_.insert(2000 + i, points[500 + i])
    return sys_


def _per_query(sys_, queries, k):
    outs = [sys_.search_batch(queries[i:i + 1], k=k)
            for i in range(len(queries))]
    return (np.concatenate([o[0] for o in outs]),
            np.concatenate([o[1] for o in outs]))


# ---------------------------------------------------------- batched serving

def test_search_batch_matches_per_query(points, queries):
    """The tentpole bar: B queries in one program == B one-query programs,
    row for row — with DeleteList members spread across every tier."""
    sys_ = _three_tier_system(points)
    for e in (0, 5, 2000, 2149):
        sys_.delete(e)
    ids_b, d_b = sys_.search_batch(queries[:16], k=5)
    ids_1, d_1 = _per_query(sys_, queries[:16], k=5)
    np.testing.assert_array_equal(ids_b, ids_1)
    np.testing.assert_array_equal(d_b, d_1)


def test_search_batch_matches_per_query_no_lti_lane(points, queries):
    """PQ lane off: a system with no LTI (temp tiers only) must hold the
    same per-query contract through the temps-only unified program."""
    sys_ = FreshDiskANN(_sys_cfg())
    for i in range(150):
        sys_.insert(2000 + i, points[500 + i])
    sys_.delete(2003)
    ids_b, d_b = sys_.search_batch(queries[:12], k=5)
    ids_1, d_1 = _per_query(sys_, queries[:12], k=5)
    np.testing.assert_array_equal(ids_b, ids_1)
    np.testing.assert_array_equal(d_b, d_1)


def test_search_batch_matches_per_query_post_merge(points, queries):
    """After a StreamingMerge retires the RO tiers, the restacked program
    must still serve batches bit-identically to per-query calls."""
    sys_ = _three_tier_system(points)
    sys_.delete(2001)
    sys_.merge()
    assert sys_.stats.merges == 1 and not sys_.ro
    ids_b, d_b = sys_.search_batch(queries[:12], k=5)
    ids_1, d_1 = _per_query(sys_, queries[:12], k=5)
    np.testing.assert_array_equal(ids_b, ids_1)
    np.testing.assert_array_equal(d_b, d_1)


def test_search_batch_matches_sequential_oracle(points, queries):
    """Transitivity anchor: the batched program vs the per-tier sequential
    oracle on the same batch (batch_fanout=False)."""
    sys_b = _three_tier_system(points)
    sys_s = _three_tier_system(points, batch_fanout=False)
    ids_b, d_b = sys_b.search_batch(queries, k=5)
    ids_s, d_s = sys_s.search_batch(queries, k=5)
    np.testing.assert_array_equal(ids_b, ids_s)
    np.testing.assert_array_equal(d_b, d_s)


# ------------------------------------------------- micro-batching contract

def test_batch_queries_chunks_bit_identical(points, queries):
    """batch_queries=N serves a B-query request in ceil(B/N) fixed-shape
    programs with bit-identical results (tail chunk zero-padded)."""
    ref = _three_tier_system(points)
    ids_r, d_r = ref.search_batch(queries[:16], k=5)
    sys_ = _three_tier_system(points, batch_queries=6)
    d0, s0 = sys_.stats.search_dispatches, sys_.stats.searches
    ids, d = sys_.search_batch(queries[:16], k=5)     # 6 + 6 + 4(padded)
    assert sys_.stats.search_dispatches - d0 == 3
    assert sys_.stats.searches - s0 == 16             # queries, not pad rows
    np.testing.assert_array_equal(ids, ids_r)
    np.testing.assert_array_equal(d, d_r)


def test_batch_queries_pads_small_requests(points, queries):
    """A request smaller than the micro-batch width pads up to ONE program
    and slices the pad rows back off."""
    ref = _three_tier_system(points)
    ids_r, d_r = ref.search_batch(queries[:3], k=5)
    sys_ = _three_tier_system(points, batch_queries=8)
    d0 = sys_.stats.search_dispatches
    ids, d = sys_.search_batch(queries[:3], k=5)
    assert sys_.stats.search_dispatches - d0 == 1
    np.testing.assert_array_equal(ids, ids_r)
    np.testing.assert_array_equal(d, d_r)
    assert ids.shape == (3, 5)


def test_empty_request_is_a_no_op(points):
    """Regression: an empty query batch must return (0, k) arrays — not
    crash in the chunk concatenation — and launch no program, with and
    without micro-batching."""
    for bq in (0, 4):
        sys_ = _three_tier_system(points, batch_queries=bq)
        d0 = sys_.stats.search_dispatches
        ids, d = sys_.search_batch(np.zeros((0, DIM), np.float32), k=3)
        assert ids.shape == (0, 3) and d.shape == (0, 3)
        assert sys_.stats.search_dispatches == d0


def test_search_dispatches_counts_programs_not_queries(points, queries):
    """The counter contract under batching: B queries in one launch count
    ONE dispatch (and one per live tier on the sequential oracle), while
    `stats.searches` keeps counting queries."""
    sys_u = _three_tier_system(points)
    d0, s0 = sys_u.stats.search_dispatches, sys_u.stats.searches
    sys_u.search_batch(queries[:32], k=5)
    assert sys_u.stats.search_dispatches - d0 == 1
    assert sys_u.stats.searches - s0 == 32
    sys_s = _three_tier_system(points, batch_fanout=False)
    d0 = sys_s.stats.search_dispatches
    sys_s.search_batch(queries[:32], k=5)
    assert sys_s.stats.search_dispatches - d0 == 4    # LTI + RW + 2 RO
    # micro-batched sequential oracle: per tier per chunk.
    sys_c = _three_tier_system(points, batch_fanout=False, batch_queries=16)
    d0 = sys_c.stats.search_dispatches
    sys_c.search_batch(queries[:32], k=5)
    assert sys_c.stats.search_dispatches - d0 == 8    # 2 chunks x 4 tiers


# ------------------------------------------------------- sharded LTI lane

def test_shard_lti_single_device_parity(points, queries):
    """shard_lti on one device runs the real shard_map program (mesh of 1)
    and must be bit-identical to the unsharded unified path — the tier-1
    half of the shard-invariance contract."""
    ref = _three_tier_system(points)
    for e in (0, 5, 2000):
        ref.delete(e)
    ids_r, d_r = ref.search_batch(queries[:12], k=5)
    sys_ = _three_tier_system(points, shard_lti=1)
    for e in (0, 5, 2000):
        sys_.delete(e)
    d0 = sys_.stats.search_dispatches
    ids, d = sys_.search_batch(queries[:12], k=5)
    assert sys_.stats.search_dispatches - d0 == 1     # still ONE program
    np.testing.assert_array_equal(ids, ids_r)
    np.testing.assert_array_equal(d, d_r)


def test_shard_lti_survives_merge(points, queries):
    """A merge swaps the LTI generation: the sharded placement cache must
    miss and re-shard the NEW graph, keeping parity with the oracle."""
    ref = _three_tier_system(points)
    sys_ = _three_tier_system(points, shard_lti=1)
    for s in (ref, sys_):
        s.search_batch(queries[:4], k=5)      # warm the sharded placement
        s.delete(2001)
        s.merge()
    ids_r, d_r = ref.search_batch(queries[:12], k=5)
    ids, d = sys_.search_batch(queries[:12], k=5)
    np.testing.assert_array_equal(ids, ids_r)
    np.testing.assert_array_equal(d, d_r)


def test_shard_count_caps_at_device_census(points):
    """shard_lti beyond the device count degrades to every device present,
    never errors (the recipe says 'ask for the fleet you wish you had')."""
    sys_ = _three_tier_system(points, shard_lti=64)
    assert sys_._shard_count() >= 1
    ids, _ = sys_.search_batch(points[:4], k=3)
    assert ids.shape == (4, 3)


@pytest.mark.parametrize("n_dev", [4])
def test_shard_invariance_on_fake_devices(n_dev):
    """The multi-device half: run scripts/shard_probe.py in a subprocess
    with XLA_FLAGS forcing 4 fake host devices — shard counts 1/2/4 must be
    bit-identical to the unsharded program, one dispatch per micro-batch,
    chunk/pad invariant.  (A subprocess because the device census is fixed
    at jax import.)"""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env.pop("PYTHONPATH", None)               # probe inserts src/ itself
    out = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "shard_probe.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"probe failed:\n{out.stdout}\n{out.stderr}"
    assert "SHARD-PROBE OK" in out.stdout


# ------------------------------------------------- multi-replica routing

def test_replica_set_single_device_parity(points, queries):
    """ReplicaSet on one device (1 replica x 1 shard) runs the real
    replica-routed program and must be bit-identical to `search_batch` —
    the tier-1 half of the replica-invariance contract (the multi-device
    half is `scripts/serving_probe.py`)."""
    from repro.serving import ReplicaSet
    sys_ = _three_tier_system(points, batch_queries=4)
    for e in (0, 5, 2000, 2149):
        sys_.delete(e)
    ref_ids, ref_d = sys_.search_batch(queries[:12], k=5)
    rs = ReplicaSet(sys_, 1)
    ids, d = rs.search_batch(queries[:12], k=5)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(d, ref_d)


def test_replica_round_robin_accounting(points, queries):
    """Each fixed-shape micro-batch lands on the next replica in turn and
    is counted in `dispatches[r]`; `search_dispatches` still counts every
    program once.  (One device -> one replica; the spread across 2/4
    replicas is asserted in the probe.)"""
    from repro.serving import ReplicaSet
    sys_ = _three_tier_system(points, batch_queries=4)
    rs = ReplicaSet(sys_, 1)
    d0 = sys_.stats.search_dispatches
    rs.search_batch(queries[:10], k=5)          # 4 + 4 + 2(padded) chunks
    assert rs.dispatches == [3]
    assert sys_.stats.search_dispatches - d0 == 3
    # pinned routing bypasses round-robin
    rs.search_batch(queries[:2], k=5, replica=0)
    assert rs.dispatches == [4]
    with pytest.raises(ValueError):
        rs.search_batch(queries[:2], k=5, replica=7)


def test_replica_set_filter_parity(points, queries):
    """Filtered micro-batches through the replica router are bit-identical
    to the system's own filtered ``search_batch`` — the filter folds into
    the same drop mask on both paths, so replica routing cannot perturb a
    filtered result (the 4-fake-device half is ``scripts/filter_probe.py``).
    Stats accounting (filtered/tenant counters) accrues on either path."""
    from repro.core.graph import FilterSpec
    from repro.serving import ReplicaSet
    sys_ = bootstrap_system(
        points[:400], np.arange(400), _sys_cfg(batch_queries=4,
                                               filter_words=1),
        labels=[[i % 3] for i in range(400)],
        tenants=[i % 2 for i in range(400)])
    for i in range(60):
        sys_.insert(2000 + i, points[500 + i], labels=[i % 3],
                    tenant=i % 2)
    for e in (0, 5, 2000):
        sys_.delete(e)
    rs = ReplicaSet(sys_, 1)
    for spec in (FilterSpec(tenant=1), FilterSpec(all_of=(1,)),
                 FilterSpec(all_of=(0,), tenant=0)):
        ref_ids, ref_d = sys_.search_batch(queries[:12], k=5, filter=spec)
        ids, d = rs.search_batch(queries[:12], k=5, filter=spec)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(d, ref_d)
    f0 = sys_.stats.filtered_searches
    rs.search_batch(queries[:4], k=5, filter=FilterSpec(tenant=1))
    assert sys_.stats.filtered_searches - f0 == 4
    assert sys_.stats.tenant_searches.get(1, 0) >= 4
    # unfiltered requests through the same router stay on the cached
    # unfiltered drop mask — parity with the direct path is unchanged
    ref_ids, ref_d = sys_.search_batch(queries[:8], k=5)
    ids, d = rs.search_batch(queries[:8], k=5)
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(d, ref_d)


def test_replica_set_degrades_to_device_census(points):
    """Asking for more replicas x shards than devices exist degrades (cap
    shards, then replicas) instead of raising — same posture as
    `shard_lti`'s census cap."""
    from repro.serving import ReplicaSet
    sys_ = _three_tier_system(points)
    rs = ReplicaSet(sys_, 8, n_shards=8)
    assert rs.n_replicas >= 1 and rs.n_shards >= 1
    ids, _ = rs.search_batch(points[:4], k=3)
    assert ids.shape == (4, 3)


def test_replica_routing_survives_background_merge(points, queries):
    """A background merge swaps the LTI generation mid-service: every
    replica's placement cache must miss on its next dispatch and re-place
    the new graph, keeping parity with a fresh reference system."""
    from repro.serving import ReplicaSet
    ref = _three_tier_system(points)
    sys_ = _three_tier_system(points, batch_queries=4,
                              background_merge=True)
    rs = ReplicaSet(sys_, 1)
    rs.search_batch(queries[:4], k=5)           # warm the placement cache
    for s in (ref, sys_):
        s.delete(2001)
    ref.merge()
    sys_.merge(background=True)
    sys_.wait_merge()
    assert sys_.stats.merges == 1
    ids_r, d_r = ref.search_batch(queries[:12], k=5)
    ids, d = rs.search_batch(queries[:12], k=5)
    np.testing.assert_array_equal(ids, ids_r)
    np.testing.assert_array_equal(d, d_r)


def test_serving_invariance_on_fake_devices():
    """Multi-device half of the replica contract: scripts/serving_probe.py
    in a subprocess with 4 fake host devices — scheduler invariants under a
    virtual clock, per-query bit-parity 1 vs 2 vs 4 replicas, 2x2
    replicas-x-shards composition, round-robin accounting, and routing
    survival across a background merge."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.pop("PYTHONPATH", None)               # probe inserts src/ itself
    out = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "serving_probe.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"probe failed:\n{out.stdout}\n{out.stderr}"
    assert "SERVING-PROBE OK" in out.stdout


# ------------------------------------------- query-batched frontier kernel

def test_frontier_select_batch_matches_vmapped_ref(rng):
    """The [B]-leading-axis launch (one grid point per query row) must match
    the vmapped single-row reference bit-for-bit, mixed occupancy and all."""
    B, L, K, V, W = 5, 16, 24, 30, 4
    ci = np.full((B, L), -1, np.int32)
    cd = np.full((B, L), np.inf, np.float32)
    ni = np.full((B, K), -1, np.int32)
    nd = np.full((B, K), np.inf, np.float32)
    vi = np.full((B, V), -1, np.int32)
    vd = np.full((B, V), np.inf, np.float32)
    vc = np.zeros((B,), np.int32)
    for b in range(B):
        nc = int(rng.integers(1, L))
        ci[b, :nc] = rng.permutation(200)[:nc]
        cd[b, :nc] = np.sort(rng.random(nc)).astype(np.float32)
        nn = int(rng.integers(0, K))
        ni[b, :nn] = 300 + rng.permutation(200)[:nn]
        nd[b, :nn] = rng.random(nn).astype(np.float32)
        # Contract: vis_cnt == number of valid ids in vis_ids (the kernel
        # re-derives the count from occupancy), so only seed visited slots
        # from the VALID candidate prefix.
        nv = min(int(rng.integers(0, 4)), nc)
        vi[b, :nv] = ci[b, :nv]
        vd[b, :nv] = cd[b, :nv]
        vc[b] = nv
    args = [jnp.asarray(x) for x in (ci, cd, ni, nd, vi, vd, vc)]
    out_k = ops.frontier_select_batch(*args, W=W, max_visits=V,
                                      use_kernel=True)
    out_r = ops.frontier_select_batch(*args, W=W, max_visits=V,
                                      use_kernel=False)
    for x, y in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # ... and each row equals the single-lane call (B=1 grid).
    for b in range(B):
        one = ops.frontier_select(*[a[b] for a in args], W=W, max_visits=V,
                                  use_kernel=True)
        for x, y in zip(one, out_k):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y[b]))
