"""Beam-width search engine: W=1 parity against the legacy single-expansion
engine, recall-vs-beamwidth monotonicity, and kernel-vs-reference equality of
the batched distance path (``use_kernel`` on/off through ``kernels.ops``)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pq as pqm
from repro.core.config import IndexConfig, PQConfig
from repro.core.distance import INVALID, gather_l2
from repro.core.index import brute_force, build, recall_at_k, search
from repro.core.lti import build_lti, search_lti
from repro.core.search import (FullPrecisionBackend, PQBackend,
                               batch_distances, beam_search)

from conftest import DIM, N

os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")


# --------------------------------------------------------------------------
# Legacy engine (the pre-beam implementation, verbatim): expands exactly one
# node per while-loop iteration.  Kept here as the W=1 parity oracle.
# --------------------------------------------------------------------------
def _legacy_search_one(adjacency, navigable, start, dist_fn, L, max_visits):
    R = adjacency.shape[1]
    cand_ids = jnp.full((L,), INVALID, jnp.int32).at[0].set(
        start.astype(jnp.int32))
    d0 = dist_fn(cand_ids[:1])[0]
    cand_d = jnp.full((L,), jnp.inf, jnp.float32).at[0].set(d0)
    cand_exp = jnp.zeros((L,), bool)
    vis_ids = jnp.full((max_visits,), INVALID, jnp.int32)
    vis_d = jnp.full((max_visits,), jnp.inf, jnp.float32)
    state = (cand_ids, cand_d, cand_exp, vis_ids, vis_d,
             jnp.int32(0), jnp.int32(0))

    def cond(s):
        cand_ids, cand_d, cand_exp, _, _, vis_cnt, _ = s
        open_ = (cand_ids >= 0) & ~cand_exp & jnp.isfinite(cand_d)
        return jnp.any(open_) & (vis_cnt < max_visits)

    def body(s):
        cand_ids, cand_d, cand_exp, vis_ids, vis_d, vis_cnt, n_cmps = s
        open_ = (cand_ids >= 0) & ~cand_exp
        sel = jnp.argmin(jnp.where(open_, cand_d, jnp.inf))
        p = cand_ids[sel]
        cand_exp = cand_exp.at[sel].set(True)
        vis_ids = vis_ids.at[vis_cnt].set(p)
        vis_d = vis_d.at[vis_cnt].set(cand_d[sel])
        vis_cnt = vis_cnt + 1
        nbrs = adjacency[jnp.maximum(p, 0)]
        ok = (nbrs >= 0) & navigable[jnp.maximum(nbrs, 0)]
        in_list = (nbrs[:, None] == cand_ids[None, :]).any(axis=1)
        in_vis = (nbrs[:, None] == vis_ids[None, :]).any(axis=1)
        new = ok & ~in_list & ~in_vis
        nd = dist_fn(jnp.where(new, nbrs, INVALID))
        n_cmps = n_cmps + new.sum(dtype=jnp.int32)
        all_ids = jnp.concatenate([cand_ids, jnp.where(new, nbrs, INVALID)])
        all_d = jnp.concatenate([cand_d, nd])
        all_exp = jnp.concatenate([cand_exp, jnp.zeros((R,), bool)])
        order = jnp.argsort(all_d)[:L]
        return (all_ids[order], all_d[order], all_exp[order],
                vis_ids, vis_d, vis_cnt, n_cmps)

    cand_ids, cand_d, _, vis_ids, vis_d, vis_cnt, n_cmps = (
        jax.lax.while_loop(cond, body, state))
    return cand_ids, cand_d, vis_ids, vis_d, vis_cnt, n_cmps


def _legacy_search(adjacency, navigable, start, queries, vectors, L,
                   max_visits):
    def one(q):
        return _legacy_search_one(
            adjacency, navigable, start,
            lambda ids: gather_l2(q, vectors, ids), L, max_visits)

    return jax.vmap(one)(queries)


def test_w1_parity_with_legacy_engine(built_index, index_cfg, queries):
    """beam_width=1 + reference path reproduces the old engine bit-for-bit."""
    st = built_index
    L = index_cfg.L_search
    mv = index_cfg.visits_bound(L)
    q = jnp.asarray(queries)
    old_ids, old_d, old_vis, old_vis_d, old_cnt, old_cmps = _legacy_search(
        st.adjacency, st.active, st.start, q, st.vectors, L, mv)
    res = beam_search(st.adjacency, st.active, st.start, q,
                      FullPrecisionBackend(st.vectors),
                      L=L, max_visits=mv, beam_width=1, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(old_ids), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(old_d), np.asarray(res.dists))
    np.testing.assert_array_equal(np.asarray(old_vis),
                                  np.asarray(res.visited))
    np.testing.assert_array_equal(np.asarray(old_cnt),
                                  np.asarray(res.n_reads))
    np.testing.assert_array_equal(np.asarray(old_cnt),
                                  np.asarray(res.n_hops))  # 1 read per round
    np.testing.assert_array_equal(np.asarray(old_cmps),
                                  np.asarray(res.n_cmps))


def test_recall_monotone_and_hops_drop_with_beam(built_index, index_cfg,
                                                 queries):
    """W in {1, 2, 4}: recall holds within 1% while IO rounds drop >= 2x."""
    st = built_index
    mask = st.active & ~st.deleted
    gt = brute_force(st.vectors, mask, jnp.asarray(queries), 5)
    recalls, hops, reads = {}, {}, {}
    for W in (1, 2, 4):
        ids, d, h, _ = search(st, jnp.asarray(queries), index_cfg, k=5,
                              L=index_cfg.L_search, beam_width=W)
        recalls[W] = float(recall_at_k(ids, gt))
        hops[W] = float(h.mean())
    for W in (2, 4):
        assert recalls[W] >= recalls[1] - 0.01, (W, recalls)
        assert hops[W] < hops[W // 2], (W, hops)
    assert hops[4] <= hops[1] / 2.0, hops
    assert recalls[1] >= 0.9, recalls


def test_beam_lti_hops_drop(points, index_cfg, pq_cfg, queries):
    """The acceptance config: PQ-navigated search_lti, W=4 vs W=1."""
    lti = build_lti(points, index_cfg, pq_cfg, batch=128)
    out = {}
    for W in (1, 4):
        ids, d, h, _ = search_lti(lti, jnp.asarray(queries), index_cfg,
                                  k=5, L=index_cfg.L_search, beam_width=W)
        mask = lti.graph.active & ~lti.graph.deleted
        gt = brute_force(lti.graph.vectors, mask, jnp.asarray(queries), 5)
        out[W] = (float(recall_at_k(ids, gt)), float(h.mean()))
    assert out[4][1] <= out[1][1] / 2.0, out
    assert out[4][0] >= out[1][0] - 0.01, out


def test_backend_kernel_matches_reference(built_index, rng):
    """The batched distance path: kernels.ops vs jnp reference, both backends."""
    st = built_index
    B, K = 8, 96
    qs = jnp.asarray(rng.standard_normal((B, DIM)).astype(np.float32))
    ids = rng.integers(0, N, (B, K)).astype(np.int32)
    ids[:, -7:] = INVALID                     # masked lanes -> +inf
    ids = jnp.asarray(ids)

    fp = FullPrecisionBackend(st.vectors)
    d_ref = batch_distances(fp, qs, ids, use_kernel=False)
    d_ker = batch_distances(fp, qs, ids, use_kernel=True)
    assert bool(jnp.isinf(d_ref[:, -7:]).all())
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)

    pq_cfg = PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)
    cb = pqm.train_pq(st.vectors[:512], pq_cfg)
    codes = pqm.encode(cb, st.vectors, pq_cfg)
    pq = PQBackend(codes, cb)
    d_ref = batch_distances(pq, qs, ids, use_kernel=False)
    d_ker = batch_distances(pq, qs, ids, use_kernel=True)
    assert bool(jnp.isinf(d_ref[:, -7:]).all())
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("W", [1, 4])
def test_end_to_end_kernel_path(built_index, index_cfg, queries, W):
    """Full search through the Pallas ops layer (interpret mode): same
    candidates as the reference path up to distance-tie reordering."""
    st = built_index
    q = jnp.asarray(queries[:8])
    L = 32
    mv = index_cfg.visits_bound(L)
    ref = beam_search(st.adjacency, st.active, st.start, q,
                      FullPrecisionBackend(st.vectors),
                      L=L, max_visits=mv, beam_width=W, use_kernel=False)
    ker = beam_search(st.adjacency, st.active, st.start, q,
                      FullPrecisionBackend(st.vectors),
                      L=L, max_visits=mv, beam_width=W, use_kernel=True)
    np.testing.assert_allclose(np.asarray(ker.dists), np.asarray(ref.dists),
                               rtol=1e-3, atol=1e-2)
    overlap = (np.asarray(ker.ids)[:, :, None]
               == np.asarray(ref.ids)[:, None, :]).any(axis=2).mean()
    assert overlap >= 0.95, overlap
