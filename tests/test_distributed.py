"""Distribution layer: sharding rules, dry-run build graph, and true
multi-device behaviour (via a subprocess with 8 placeholder host devices —
tests themselves keep the default 1-device runtime)."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.distributed.sharding import spec_for
from repro.launch.mesh import make_host_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_spec_for_divisibility():
    mesh = make_host_mesh()   # (1, 1) mesh: everything divisible
    s = spec_for(mesh, (16, 32), ["data", "model"])
    assert len(s) == 2


def test_every_arch_has_assigned_cells():
    want = {
        "lm": {"train_4k", "prefill_32k", "decode_32k", "long_500k"},
        "gnn": {"full_graph_sm", "minibatch_lg", "ogb_products",
                "molecule"},
        "recsys": {"train_batch", "serve_p99", "serve_bulk",
                   "retrieval_cand"},
    }
    for name in ASSIGNED:
        arch = get_arch(name)
        shapes = {c.shape for c in arch.cells}
        assert shapes == want[arch.family], (name, shapes)


def test_long_500k_skips_documented():
    for name in ("qwen3-14b", "qwen2-1.5b", "qwen3-moe-30b-a3b"):
        assert get_arch(name).cell("long_500k").skip
    for name in ("gemma3-12b", "mixtral-8x7b"):
        assert not get_arch(name).cell("long_500k").skip


def test_input_specs_materialize_without_allocation():
    for name in ASSIGNED:
        arch = get_arch(name)
        for cell in arch.cells:
            specs = cell.specs()
            for k, v in specs.items():
                leaves = jax.tree.leaves(
                    v, is_leaf=lambda x: hasattr(x, "shape"))
                assert leaves, (name, cell.shape, k)


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import mesh_with_auto_axes
mesh = mesh_with_auto_axes((2, 4), ("data", "model"))

# 1) distributed ANN search == single-shard brute force on union of shards
from repro.core.config import IndexConfig, PQConfig
from repro.core.lti import build_lti
from repro.core import pq as pqm
from repro.core.graph import GraphState
from repro.core.lti import LTIState
from repro.launch.ann_steps import make_distributed_search

cfg = IndexConfig(capacity=256, dim=16, R=16, L_build=32, L_search=64,
                  alpha=1.2, max_visits=96)
pq = PQConfig(dim=16, m=8, ksub=32, kmeans_iters=6)
rng = np.random.default_rng(0)
centers = rng.standard_normal((16, 16)).astype(np.float32) * 4.0
shards = []
all_pts = []
for s in range(8):
    which = rng.integers(0, 16, 200)
    pts = (centers[which]
           + rng.standard_normal((200, 16))).astype(np.float32)
    all_pts.append(pts)
    shards.append(build_lti(pts, cfg, pq, seed=s))

def cat(field):
    return jnp.concatenate([getattr(l.graph, field) for l in shards])

g = GraphState(vectors=cat("vectors"), adjacency=cat("adjacency"),
               active=cat("active"), deleted=cat("deleted"),
               start=jnp.stack([l.graph.start for l in shards]),
               n_total=jnp.stack([l.graph.n_total for l in shards]))
lti = LTIState(g, jnp.concatenate([l.codes for l in shards]),
               shards[0].codebook)  # shared codebook approx: re-encode
codes = []
for s, l in enumerate(shards):
    c = pqm.encode(shards[0].codebook, jnp.asarray(all_pts[s]), pq)
    full = jnp.zeros((cfg.capacity, pq.m), jnp.uint8).at[:200].set(c)
    codes.append(full)
lti = LTIState(g, jnp.concatenate(codes), shards[0].codebook)

search = make_distributed_search(mesh, cfg, k=5)
# queries = perturbed dataset points from several shards
union0 = np.concatenate(all_pts)
q = (union0[rng.choice(1600, 8, replace=False)]
     + 0.05 * rng.standard_normal((8, 16))).astype(np.float32)
with mesh:
    ids, d = search(lti, jnp.asarray(q))
ids = np.asarray(ids)

# ground truth over the union
union = np.concatenate(all_pts)
slot_of = np.concatenate([np.arange(200) + s * cfg.capacity
                          for s in range(8)])
dist = ((union[None] - q[:, None]) ** 2).sum(-1)
gt = slot_of[np.argsort(dist, axis=1)[:, :5]]
inter = [len(set(ids[i].tolist()) & set(gt[i].tolist())) / 5
         for i in range(8)]
recall = float(np.mean(inter))

# 2) elastic checkpoint resharding: save on 1 device, restore onto 8
from repro.checkpoint.store import save_checkpoint, restore_checkpoint
tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
save_checkpoint("/tmp/_elastic_ck", 1, tree)
sh = {"w": NamedSharding(mesh, P("data", "model"))}
got, _ = restore_checkpoint("/tmp/_elastic_ck", shardings=sh)
ok_shard = (len(got["w"].sharding.device_set) == 8
            and np.allclose(np.asarray(got["w"]), tree["w"]))

# 3) int8 compressed all-reduce inside shard_map
from repro.optim.compress import int8_all_gather_reduce, bf16_all_reduce
from functools import partial
x = np.linspace(-1, 1, 8 * 32).astype(np.float32).reshape(8, 32)

def red(xs, key):
    return int8_all_gather_reduce({"g": xs}, key, "data")["g"]

from repro.launch.ann_steps import _shard_map
out = jax.jit(_shard_map(
    partial(red, key=jax.random.PRNGKey(0)),
    mesh=Mesh(np.array(jax.devices()).reshape(8), ("data",)),
    in_specs=P("data"), out_specs=P("data")))(x.reshape(8, 32))
want = np.broadcast_to(x.reshape(8, 32).mean(0, keepdims=True), (8, 32))
err = float(np.abs(np.asarray(out).reshape(8, 32) - want).max())

print(json.dumps({"recall": recall, "elastic_ok": bool(ok_shard),
                  "int8_err": err}))
"""


@pytest.fixture(scope="module")
def multidev_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_distributed_ann_search_recall(multidev_result):
    assert multidev_result["recall"] >= 0.8, multidev_result


def test_elastic_checkpoint_restore(multidev_result):
    assert multidev_result["elastic_ok"]


def test_int8_allreduce_accuracy(multidev_result):
    # stochastic-rounding int8: error bounded by the quantization step
    assert multidev_result["int8_err"] < 0.02, multidev_result
