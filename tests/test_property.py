"""Hypothesis property tests on the system's invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import pq as pqm
from repro.core.config import IndexConfig, PQConfig
from repro.core.insert import group_pairs
from repro.core.prune import check_alpha_rng, prune_node, robust_prune
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def point_cloud(draw, max_n=40, dim=8):
    n = draw(st.integers(4, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, dim)).astype(np.float32)


@given(point_cloud(), st.floats(1.0, 1.5), st.integers(2, 12))
@settings(**SETTINGS)
def test_robust_prune_invariants(cloud, alpha, R):
    """RobustPrune: (1) degree <= R, (2) no dup ids, (3) output satisfies
    the alpha-RNG coverage property, (4) nearest candidate always kept."""
    n = cloud.shape[0]
    p = 0
    cand = jnp.arange(1, n, dtype=jnp.int32)
    usable = jnp.ones((n,), bool)
    res = prune_node(jnp.asarray(cloud), jnp.int32(p), cand, usable,
                     alpha, R)
    ids = np.asarray(res.ids)
    valid = ids[ids >= 0]
    assert len(valid) <= R
    assert len(set(valid.tolist())) == len(valid)
    d = np.linalg.norm(cloud[1:] - cloud[0], axis=1)
    nearest = 1 + int(np.argmin(d))
    assert nearest in valid
    assert bool(check_alpha_rng(jnp.asarray(res.ids), jnp.asarray(cloud[0]),
                                jnp.asarray(cloud), alpha))


@given(point_cloud(max_n=30), st.integers(2, 8))
@settings(**SETTINGS)
def test_alpha_one_subset_of_alpha_bigger(cloud, R):
    """Bigger alpha keeps a superset-or-equal candidate count (denser)."""
    n = cloud.shape[0]
    cand = jnp.arange(1, n, dtype=jnp.int32)
    usable = jnp.ones((n,), bool)
    r1 = prune_node(jnp.asarray(cloud), jnp.int32(0), cand, usable, 1.0, R)
    r2 = prune_node(jnp.asarray(cloud), jnp.int32(0), cand, usable, 1.3, R)
    assert int(r2.count) >= int(r1.count)


@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(1, 64))
@settings(**SETTINGS)
def test_group_pairs_groups_correctly(seed, dmax, n_pairs):
    rng = np.random.default_rng(seed)
    n_slots = 16
    j = rng.integers(-1, n_slots, n_pairs).astype(np.int32)
    p = rng.integers(0, 1000, n_pairs).astype(np.int32)
    p = np.where(j >= 0, p, -1)
    buf, cnt = group_pairs(jnp.asarray(j), jnp.asarray(p), n_slots, dmax)
    buf, cnt = np.asarray(buf), np.asarray(cnt)
    for s in range(n_slots):
        want = sorted(p[j == s].tolist())
        assert cnt[s] == len(want)
        got = sorted(x for x in buf[s].tolist() if x >= 0)
        assert got == want[:dmax] or set(got) <= set(want)
        assert len(got) == min(len(want), dmax)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(**SETTINGS)
def test_pq_roundtrip_improves_with_ksub(seed, m):
    """PQ reconstruction error decreases as ksub grows."""
    rng = np.random.default_rng(seed)
    dim = 8 * m
    data = rng.standard_normal((200, dim)).astype(np.float32)
    errs = []
    for ksub in (4, 32):
        cfg = PQConfig(dim=dim, m=m, ksub=ksub, kmeans_iters=6, seed=0)
        cb = pqm.train_pq(jnp.asarray(data), cfg)
        rec = pqm.decode(cb, pqm.encode(cb, jnp.asarray(data), cfg), cfg)
        errs.append(float(jnp.mean((rec - data) ** 2)))
    assert errs[1] <= errs[0] + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_adc_equals_exact_on_reconstructions(seed):
    """ADC(q, code) == ||q - decode(code)||^2 exactly (per definition)."""
    rng = np.random.default_rng(seed)
    cfg = PQConfig(dim=16, m=4, ksub=16, kmeans_iters=4)
    data = rng.standard_normal((64, 16)).astype(np.float32)
    cb = pqm.train_pq(jnp.asarray(data), cfg)
    codes = pqm.encode(cb, jnp.asarray(data), cfg)
    q = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    adc = pqm.adc(codes, pqm.lut(cb, q))
    rec = pqm.decode(cb, codes, cfg)
    exact = jnp.sum((rec - q) ** 2, axis=1)
    np.testing.assert_allclose(np.asarray(adc), np.asarray(exact),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 128),
       st.integers(1, 16))
@settings(**SETTINGS)
def test_block_topk_matches_sort(seed, q, n, k):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((q, n)).astype(np.float32)
    ids = rng.permutation(n).astype(np.int32)
    gd, gi = ops.block_topk(jnp.asarray(d), jnp.asarray(ids), k)
    wd, wi = ref.block_topk_ref(jnp.asarray(d), jnp.asarray(ids), k)
    # for k > n the kernel pads with +inf/-1; the ref returns n entries —
    # compare the common prefix and check the padding contract
    m = min(k, n)
    np.testing.assert_allclose(np.asarray(gd)[:, :m],
                               np.asarray(wd)[:, :m], atol=1e-6)
    if k > n:
        assert bool(jnp.isinf(gd[:, n:]).all())
        assert (np.asarray(gi)[:, n:] == -1).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_wal_roundtrip(seed):
    import tempfile
    from repro.core.wal import WriteAheadLog, replay
    rng = np.random.default_rng(seed)
    tmp = tempfile.mkdtemp(prefix="waltest")
    path = os.path.join(tmp, f"w{seed}.bin")
    wal = WriteAheadLog(str(path), dim=6)
    records = []
    for _ in range(rng.integers(1, 30)):
        if rng.random() < 0.7:
            v = rng.standard_normal(6).astype(np.float32)
            e = int(rng.integers(0, 1000))
            wal.log_insert(e, v)
            records.append((0, e, v))
        else:
            e = int(rng.integers(0, 1000))
            wal.log_delete(e)
            records.append((1, e, None))
    wal.close()
    got = list(replay(str(path)))
    assert len(got) == len(records)
    for (op, e, v), (op2, e2, v2) in zip(records, got):
        assert op == op2 and e == e2
        if v is not None:
            np.testing.assert_array_equal(v, v2)
