"""The paper's core experimental claims, at CPU scale.

Fig. 1 — naive delete policies degrade recall over delete/re-insert cycles.
Fig. 2 — FreshVamana (alpha > 1 update rules) keeps recall stable.
Fig. 3 / App. C — alpha = 1 is unstable, alpha = 1.2 is stable.
Fig. 4 — StreamingMerge (PQ distances) recall stabilizes after a small dip.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import IndexConfig, PQConfig
from repro.core.delete import (consolidate_deletes, consolidate_policy_a,
                               consolidate_policy_b, delete)
from repro.core.index import brute_force, build, insert, recall_at_k, search
from repro.core.lti import build_lti, search_lti
from repro.core.merge import streaming_merge

from conftest import DIM, N

CYCLES = 8
FRAC = 0.25


def _recall(state, cfg, queries, k=5):
    ids, *_ = search(state, jnp.asarray(queries), cfg, k=k, L=cfg.L_search)
    mask = state.active & ~state.deleted
    gt = brute_force(state.vectors, mask, jnp.asarray(queries), k)
    return float(recall_at_k(ids, gt))


def _avg_degree(state):
    from repro.core.graph import degree_stats
    return float(degree_stats(state)["avg_degree"])


def _cycle(state, cfg, rng, consolidate_fn, n_del):
    """Delete n_del random live points, consolidate, re-insert the same."""
    live = np.flatnonzero(np.asarray(state.active & ~state.deleted))
    victims = rng.choice(live, n_del, replace=False).astype(np.int32)
    vecs = np.asarray(state.vectors)[victims]
    state = consolidate_fn(delete(state, jnp.asarray(victims)))
    for lo in range(0, n_del, 64):
        sl = victims[lo:lo + 64]
        pad = 64 - len(sl)
        slots = np.concatenate([sl, np.full(pad, -1)]).astype(np.int32)
        vv = np.zeros((64, state.vectors.shape[1]), np.float32)
        vv[:len(sl)] = vecs[lo:lo + 64]
        state = insert(state, jnp.asarray(slots), jnp.asarray(vv), cfg)
    return state


def _run_cycles(points, queries, cfg, consolidate_fn, cycles=CYCLES):
    rng = np.random.default_rng(7)
    state = build(points, cfg, batch=128)
    recalls = [_recall(state, cfg, queries)]
    degrees = [_avg_degree(state)]
    for _ in range(cycles):
        state = _cycle(state, cfg, rng, consolidate_fn, int(N * FRAC))
        recalls.append(_recall(state, cfg, queries))
        degrees.append(_avg_degree(state))
    return recalls, degrees


@pytest.fixture(scope="module")
def stability(points, queries, index_cfg):
    """One shared run per policy (expensive)."""
    fresh = _run_cycles(points, queries, index_cfg,
                        lambda s: consolidate_deletes(s, index_cfg))
    pol_a = _run_cycles(points, queries, index_cfg, consolidate_policy_a)
    pol_b = _run_cycles(points, queries, index_cfg,
                        lambda s: consolidate_policy_b(s, index_cfg))
    return {"fresh": fresh, "a": pol_a, "b": pol_b}


def test_fresh_vamana_recall_stable(stability):
    """Fig. 2: alpha-RNG update rules keep recall AND density stable."""
    recalls, degrees = stability["fresh"]
    assert recalls[-1] >= recalls[0] - 0.02, recalls
    assert degrees[-1] >= degrees[0] - 0.5, degrees


def test_naive_delete_policy_a_degrades(stability):
    """Fig. 1 / §4: edge-removal-only deletion sparsifies the graph (the
    paper's stated mechanism — "the graph becomes sparse ... hence less
    navigable") and ends below FreshVamana's recall."""
    (ra, da), (rf, df) = stability["a"], stability["fresh"]
    assert da[-1] < df[-1] - 1.0, (da, df)          # sparsification
    assert ra[-1] < rf[-1] - 0.003, (ra, rf)        # recall consequence


def test_naive_delete_policy_b_degrades(stability):
    """Fig. 1: aggressive (alpha=1) local patching sparsifies faster and
    costs more recall."""
    (rb, db), (rf, df) = stability["b"], stability["fresh"]
    (ra, da) = stability["a"]
    assert db[-1] < df[-1] - 2.0, (db, df)
    assert db[-1] < da[-1], (db, da)                # worse than policy A
    assert rb[-1] < rf[-1] - 0.015, (rb, rf)


def test_alpha_one_less_stable(points, queries, index_cfg):
    """Fig. 3 / App. C: alpha = 1 yields a sparser, lower-recall index than
    alpha = 1.2 under the same update stream."""
    cfg1 = dataclasses.replace(index_cfg, alpha=1.0)
    r1, d1 = _run_cycles(points, queries, cfg1,
                         lambda s: consolidate_deletes(s, cfg1), cycles=6)
    cfg2 = index_cfg
    r2, d2 = _run_cycles(points, queries, cfg2,
                         lambda s: consolidate_deletes(s, cfg2), cycles=6)
    assert d2[-1] > d1[-1] + 1.0, (d1, d2)          # denser graph
    assert r2[-1] >= r1[-1] - 0.005, (r1, r2)       # at least as accurate


def test_streaming_merge_recall_stable(points, queries, index_cfg, pq_cfg):
    """Fig. 4: merge cycles on PQ distances — small dip then stable."""
    lti = build_lti(points, index_cfg, pq_cfg)
    rng = np.random.default_rng(3)

    def lti_recall(l):
        ids, d, _, _ = search_lti(l, jnp.asarray(queries), index_cfg,
                                  k=5, L=index_cfg.L_search)
        mask = l.graph.active & ~l.graph.deleted
        gt = brute_force(l.graph.vectors, mask, jnp.asarray(queries), 5)
        return float(recall_at_k(ids, gt))

    recalls = [lti_recall(lti)]
    n_chg = int(N * FRAC)
    for _ in range(5):
        live = np.flatnonzero(np.asarray(lti.graph.active))
        victims = rng.choice(live, n_chg, replace=False)
        dmask = np.zeros(index_cfg.capacity, bool)
        dmask[victims] = True
        vecs = np.asarray(lti.graph.vectors)[victims]
        lti, _ = streaming_merge(
            lti, jnp.asarray(vecs), jnp.ones(n_chg, bool),
            jnp.asarray(dmask), index_cfg, pq_cfg,
            insert_chunk=64, block=512)
        recalls.append(lti_recall(lti))
    # stable after the initial PQ-approximation dip (paper Fig. 4)
    assert recalls[-1] >= recalls[1] - 0.05, recalls
    assert recalls[-1] >= recalls[0] - 0.12, recalls
