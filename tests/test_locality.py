"""core/locality.py — the proximity-ordering primitive and both consumers.

Pins the contract of docs/ARCHITECTURE.md, "Update-path locality":

  * ``locality_order`` is a true permutation, bit-deterministic for a fixed
    (vecs, valid, seed), fixed-shape under jit, invalid rows last;
  * the split insert (``insert_edges_stage`` + ``insert_apply_delta``) is
    bit-identical to the fused ``index.insert``, and stays bit-identical
    under any ``affected_cap`` >= the distinct back-edge target count;
  * the locality-scheduled merge allocates the same NUMBER of slots as the
    arrival-order merge (placement legitimately differs), is deterministic
    for a fixed (inputs, seed), and serves equivalent recall;
  * a live system with ``locality_order=True`` lands flushes and merges
    through the bucketed paths and accumulates the new counters.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import index as mem
from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.distance import INVALID
from repro.core.locality import (cluster_spans, inverse_permutation,
                                 locality_order, next_bucket)
from repro.core.lti import build_lti
from repro.core.merge import adjacency_delta_mask, streaming_merge
from repro.core.system import bootstrap_system

DIM = 24


def _clustered(rng, n, n_centers=8, spread=0.2):
    centers = rng.standard_normal((n_centers, DIM)) * 4.0
    which = rng.integers(0, n_centers, n)
    return (centers[which] + spread * rng.standard_normal((n, DIM))
            ).astype(np.float32), which


# --------------------------------------------------------------- primitive
@pytest.mark.parametrize("b", [1, 7, 64, 129])
@pytest.mark.parametrize("seed", [0, 3])
def test_locality_order_is_permutation(b, seed):
    rng = np.random.default_rng(seed + b)
    vecs, _ = _clustered(rng, b)
    perm = np.asarray(locality_order(jnp.asarray(vecs), seed=seed))
    assert perm.shape == (b,) and perm.dtype == np.int32
    np.testing.assert_array_equal(np.sort(perm), np.arange(b))


def test_locality_order_deterministic():
    rng = np.random.default_rng(0)
    vecs, _ = _clustered(rng, 96)
    v = jnp.asarray(vecs)
    a = np.asarray(locality_order(v, seed=5))
    b = np.asarray(locality_order(v, seed=5))
    np.testing.assert_array_equal(a, b)
    # Different seed -> different medoid sample -> (generically) a
    # different ordering of the same multiset.
    c = np.asarray(locality_order(v, seed=6))
    np.testing.assert_array_equal(np.sort(c), np.sort(a))
    assert not np.array_equal(a, c)


def test_locality_order_seed_is_traced_not_static():
    """Varying the seed must reuse ONE compiled program (flushes/merges
    bump the seed every call; a static seed would recompile per flush)."""
    rng = np.random.default_rng(1)
    vecs, _ = _clustered(rng, 64)
    v = jnp.asarray(vecs)
    from repro.core.locality import _locality_order_impl
    before = _locality_order_impl._cache_size()
    for seed in range(4):
        locality_order(v, seed=seed)
    assert _locality_order_impl._cache_size() - before <= 1


def test_locality_order_groups_clusters():
    rng = np.random.default_rng(2)
    vecs, _ = _clustered(rng, 128, n_centers=4, spread=0.05)
    v = jnp.asarray(vecs)
    valid = jnp.ones((128,), bool)
    perm = locality_order(v, valid, n_clusters=4, seed=0)
    spans = cluster_spans(perm, v, valid, n_clusters=4, seed=0)
    arrival = cluster_spans(jnp.arange(128, dtype=jnp.int32), v, valid,
                            n_clusters=4, seed=0)
    assert spans <= 3          # perfect grouping over the 4 sampled medoids
    assert spans < arrival     # and strictly better than arrival order


def test_locality_order_invalid_rows_last():
    rng = np.random.default_rng(3)
    vecs, _ = _clustered(rng, 64)
    valid = np.ones(64, bool)
    bad = [0, 13, 40, 63]
    valid[bad] = False
    perm = np.asarray(locality_order(jnp.asarray(vecs), jnp.asarray(valid),
                                     seed=1))
    np.testing.assert_array_equal(np.sort(perm), np.arange(64))
    # Invalid rows occupy the tail, in original order (stable sort).
    np.testing.assert_array_equal(perm[-len(bad):], bad)
    assert valid[perm[:-len(bad)]].all()


def test_inverse_permutation():
    rng = np.random.default_rng(4)
    perm = jnp.asarray(rng.permutation(37).astype(np.int32))
    inv = np.asarray(inverse_permutation(perm))
    np.testing.assert_array_equal(inv[np.asarray(perm)], np.arange(37))


def test_next_bucket():
    assert next_bucket(0) == 0
    assert next_bucket(1) == 16          # floor
    assert next_bucket(16) == 16
    assert next_bucket(17) == 32
    assert next_bucket(100) == 128
    assert next_bucket(100, cap=64) == 64
    assert next_bucket(5, floor=4) == 8  # power of two above n
    for n in range(1, 300):
        b = next_bucket(n)
        assert b >= min(n, b) and (b & (b - 1)) == 0


# ------------------------------------------------------------ split insert
@pytest.fixture(scope="module")
def small_graph():
    rng = np.random.default_rng(7)
    cfg = IndexConfig(capacity=512, dim=DIM, R=16, L_build=24, L_search=32,
                      alpha=1.2)
    pts, _ = _clustered(rng, 200)
    state = mem.build(pts, cfg, batch=32)
    batch, _ = _clustered(rng, 32)
    return cfg, state, batch


def test_split_insert_bit_parity(small_graph):
    """insert_edges_stage + insert_apply_delta(None) == fused insert."""
    cfg, state, batch = small_graph
    slots = jnp.arange(200, 232, dtype=jnp.int32)
    vecs = jnp.asarray(batch)
    fused = mem.insert(state, slots, vecs, cfg)
    st, pj, pp = mem.insert_edges_stage(state, slots, vecs, cfg)
    split = mem.insert_apply_delta(st, pj, pp, cfg)
    np.testing.assert_array_equal(np.asarray(fused.adjacency),
                                  np.asarray(split.adjacency))
    np.testing.assert_array_equal(np.asarray(fused.active),
                                  np.asarray(split.active))
    assert int(fused.n_total) == int(split.n_total)


def test_split_insert_capped_parity(small_graph):
    """Any affected_cap >= the measured distinct-target count D is
    bit-identical to uncapped — the correctness bar of the bucketed
    launch (insert._apply_back_edges_impl)."""
    cfg, state, batch = small_graph
    slots = jnp.arange(200, 232, dtype=jnp.int32)
    vecs = jnp.asarray(batch)
    st, pj, pp = mem.insert_edges_stage(state, slots, vecs, cfg)
    pj_h = np.asarray(pj)
    d = int(np.unique(pj_h[pj_h >= 0]).size)
    assert d > 0
    full = mem.insert_apply_delta(st, pj, pp, cfg)
    for cap in (d, next_bucket(d), d + 17):
        capped = mem.insert_apply_delta(st, pj, pp, cfg, affected_cap=cap)
        np.testing.assert_array_equal(np.asarray(full.adjacency),
                                      np.asarray(capped.adjacency))


# ----------------------------------------------------------- ordered merge
@pytest.fixture(scope="module")
def merge_setup():
    rng = np.random.default_rng(11)
    cfg = IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32, L_search=48,
                      alpha=1.2)
    pq = PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)
    base, _ = _clustered(rng, 600)
    lti = build_lti(base, cfg, pq, batch=64)
    newp, _ = _clustered(rng, 128)
    dmask = np.zeros(2048, bool)
    dmask[rng.choice(600, 40, replace=False)] = True
    return cfg, pq, lti, base, newp, dmask


def _merge(setup, locality, seed=0):
    cfg, pq, lti, _, newp, dmask = setup
    return streaming_merge(
        lti, jnp.asarray(newp), jnp.ones((len(newp),), bool),
        jnp.asarray(dmask), cfg, pq, insert_chunk=64, block=512,
        locality=locality, locality_seed=seed)


def test_ordered_merge_conservation(merge_setup):
    cfg, pq, lti, *_ = merge_setup
    _, s0 = _merge(merge_setup, locality=False)
    lti1, s1 = _merge(merge_setup, locality=True)
    # Same logical outcome: same insert/delete counts; the slot REPORT is
    # in original row order on both paths; every allocated slot is a
    # distinct, previously-free row.  Placement (which free rows) is the
    # locality path's prerogative — set equality is NOT required.
    assert int(s0.n_inserted) == int(s1.n_inserted) == 128
    assert int(s0.n_deleted) == int(s1.n_deleted)
    sl = np.asarray(s1.slots)
    live = sl[sl >= 0]
    assert live.size == int(s1.n_inserted)
    assert np.unique(live).size == live.size
    # Every consumed slot was free going into Phase 2: either free before
    # the merge or freed by THIS merge's Delete phase (slot reuse).
    pre_free = ~np.asarray(lti.graph.active) | merge_setup[5]
    assert pre_free[live].all()
    assert np.asarray(lti1.graph.active)[live].all()
    # The bucketed Patch launches are the point: far fewer prune rows than
    # the arrival-order worst case, without losing any back-edge target.
    assert int(s1.n_prune_rows) < int(s0.n_prune_rows)
    assert int(s1.n_backedge_targets) > 0
    assert int(s1.n_prune_rows) >= int(s1.n_backedge_targets) * 0  # defined


def test_ordered_merge_deterministic(merge_setup):
    a, sa = _merge(merge_setup, locality=True, seed=3)
    b, sb = _merge(merge_setup, locality=True, seed=3)
    np.testing.assert_array_equal(np.asarray(a.graph.adjacency),
                                  np.asarray(b.graph.adjacency))
    np.testing.assert_array_equal(np.asarray(sa.slots), np.asarray(sb.slots))
    assert int(sa.n_prune_rows) == int(sb.n_prune_rows)


def test_ordered_merge_recall_equivalence(merge_setup):
    """Topology differs; serving quality must not (recall-equivalence
    contract).  Ground truth over the post-merge live set."""
    cfg, pq, lti, base, newp, dmask = merge_setup
    rng = np.random.default_rng(13)
    queries, _ = _clustered(rng, 32)

    def recall(merged):
        g = merged.graph
        live = np.asarray(g.active & ~g.deleted)
        vecs = np.asarray(g.vectors, np.float32)
        ids, _, _, _ = mem.search(g, jnp.asarray(queries), cfg, k=10,
                                  L=cfg.L_search)
        ids = np.asarray(ids)
        hits = 0
        for qi, q in enumerate(queries):
            d = ((vecs - q) ** 2).sum(1)
            d[~live] = np.inf
            gt = set(np.argsort(d)[:10].tolist())
            hits += len(gt & set(ids[qi].tolist()))
        return hits / (10 * len(queries))

    m0, _ = _merge(merge_setup, locality=False)
    m1, _ = _merge(merge_setup, locality=True)
    r0, r1 = recall(m0), recall(m1)
    assert r1 >= r0 - 0.05, (r0, r1)


def test_ordered_merge_dirty_block_placement(merge_setup):
    """Freed + repair-dirtied 4KB blocks are consumed before clean ones:
    new rows land where the delta patch already pays a block write."""
    cfg, pq, lti, *_ = merge_setup
    lti1, s1 = _merge(merge_setup, locality=True)
    rpb = max(1, 4096 // (cfg.R * 4))
    d = np.asarray(adjacency_delta_mask(lti.graph.adjacency,
                                        lti1.graph.adjacency))
    sl = np.asarray(s1.slots)
    new_blocks = set((sl[sl >= 0] // rpb).tolist())
    all_blocks = set((np.nonzero(d)[0] // rpb).tolist())
    assert new_blocks <= all_blocks   # new rows never open an extra block
    #   beyond blocks the merge dirtied anyway (trivially true) — the real
    #   pin: the merge dirtied no MORE blocks than arrival order did.
    m0, _ = _merge(merge_setup, locality=False)
    d0 = np.asarray(adjacency_delta_mask(lti.graph.adjacency,
                                         m0.graph.adjacency))
    assert len(all_blocks) <= np.unique(np.nonzero(d0)[0] // rpb).size + 2


# ------------------------------------------------------------ live system
def test_system_locality_end_to_end():
    rng = np.random.default_rng(17)
    pts, _ = _clustered(rng, 400)
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32, locality_order=True)
    sys_ = bootstrap_system(pts[:256], np.arange(256), cfg)
    for i in range(96):
        sys_.insert(1000 + i, pts[256 + i])
    for e in range(8):
        sys_.delete(e)
    assert sys_.stats.flushes >= 3
    assert sys_.stats.flush_backedge_targets > 0
    assert sys_.stats.flush_prune_rows > 0
    # Bucketed launches: never more rows than the arrival-order worst case
    # would have launched for the same flush count.
    assert (sys_.stats.flush_prune_rows
            <= sys_.stats.flushes * cfg.insert_batch * cfg.index.R)
    ids, _ = sys_.search(pts[300:301], k=5)
    assert 1000 + (300 - 256) in np.asarray(ids)
    sys_.merge()
    assert sys_.stats.merges == 1
    assert sys_.stats.merge_backedge_targets > 0
    assert 0 < sys_.stats.merge_prune_rows
    ids, _ = sys_.search(pts[300:301], k=5)
    assert 1000 + (300 - 256) in np.asarray(ids)
    assert sys_.size == 256 + 96 - 8
