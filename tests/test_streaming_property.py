"""Streaming-correctness properties: random insert/delete/re-insert/search/
merge interleavings against a brute-force oracle.

The driver replays one op stream on a live FreshDiskANN while mirroring it in
a plain dict (the oracle).  After every search it asserts the §5.2 contract:

  * no deleted (and not re-inserted) id is ever returned,
  * no id the oracle has never seen is returned,
  * recall@k against oracle brute force stays above a floor — across RW->RO
    rollovers and StreamingMerges alike,
  * ``size`` equals the oracle's live count.

Runs as a deterministic seed sweep everywhere; when hypothesis is installed
the same driver is additionally driven by generated op streams.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force
from repro.core.system import FreshDiskANN, bootstrap_system

DIM = 16
RECALL_FLOOR = 0.70


def _cfg(**kw):
    base = dict(
        index=IndexConfig(capacity=1024, dim=DIM, R=16, L_build=24,
                          L_search=32, alpha=1.2),
        pq=PQConfig(dim=DIM, m=4, ksub=16, kmeans_iters=3),
        ro_snapshot_points=24, merge_threshold=48,
        temp_capacity=128, insert_batch=8)
    base.update(kw)
    return SystemConfig(**base)


def _mk_vec(rng):
    return rng.standard_normal(DIM).astype(np.float32)


def run_interleaving(seed: int, n_ops: int = 120, *, explicit_merges=True,
                     **cfg_kw) -> None:
    """Drive one random interleaving; raises on any broken invariant."""
    rng = np.random.default_rng(seed)
    n0 = 64
    base = rng.standard_normal((n0, DIM)).astype(np.float32)
    sys_ = bootstrap_system(base, np.arange(n0), _cfg(**cfg_kw))
    oracle: dict[int, np.ndarray] = {e: base[e] for e in range(n0)}
    graveyard: dict[int, np.ndarray] = {}      # deleted ids keep their vector
    next_id = 1000

    def check_search():
        k = int(rng.integers(1, 6))
        nq = int(rng.integers(1, 5))
        q = rng.standard_normal((nq, DIM)).astype(np.float32)
        ids, dists = sys_.search(q, k=k)
        live = set(oracle)
        dead = set(graveyard)
        for row in np.asarray(ids):
            for e in row:
                e = int(e)
                if e < 0:
                    continue
                assert e not in dead, f"deleted id {e} returned (seed {seed})"
                assert e in live, f"unknown id {e} returned (seed {seed})"
        # recall floor vs oracle brute force
        keys = np.asarray(sorted(oracle))
        mat = np.stack([oracle[e] for e in keys])
        kk = min(k, len(keys))
        gt_rows = np.asarray(brute_force(
            jnp.asarray(mat), jnp.ones(len(keys), bool), jnp.asarray(q), kk))
        hits = total = 0
        for row, gt in zip(np.asarray(ids), keys[gt_rows]):
            hits += len(set(int(x) for x in row if x >= 0) & set(gt.tolist()))
            total += kk
        assert hits / total >= RECALL_FLOOR, (
            f"recall {hits}/{total} below floor (seed {seed}, "
            f"merges={sys_.stats.merges}, snapshots={sys_.stats.snapshots})")

    for _ in range(n_ops):
        r = rng.random()
        if r < 0.45 or not oracle:                       # insert a new point
            v = _mk_vec(rng)
            sys_.insert(next_id, v)
            oracle[next_id] = v
            next_id += 1
        elif r < 0.60 and len(oracle) > 4:               # delete a live point
            e = int(rng.choice(sorted(oracle)))
            sys_.delete(e)
            graveyard[e] = oracle.pop(e)
        elif r < 0.70 and graveyard:                     # re-insert (revive)
            e = int(rng.choice(sorted(graveyard)))
            v = graveyard.pop(e)
            sys_.insert(e, v)
            oracle[e] = v
        elif r < 0.75 and explicit_merges:               # forced merge
            sys_.merge()
            sys_.wait_merge()
        else:                                            # search + invariants
            check_search()

    sys_.wait_merge()
    check_search()
    sys_._flush_inserts()
    assert sys_.size == len(oracle), (
        f"size {sys_.size} != oracle {len(oracle)} (seed {seed})")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_streaming_interleavings_fixed_seeds(seed):
    run_interleaving(seed)


def test_streaming_interleaving_background_merge():
    """Same invariants with threshold merges running on the worker thread."""
    run_interleaving(11, explicit_merges=False, background_merge=True,
                     merge_threshold=32)


def test_delete_then_flush_does_not_revive():
    """Regression: delete of a still-buffered insert must stick — the flush
    used to discard the delete and revive the id."""
    rng = np.random.default_rng(3)
    base = rng.standard_normal((32, DIM)).astype(np.float32)
    sys_ = bootstrap_system(base, np.arange(32), _cfg(insert_batch=64))
    v = _mk_vec(rng)
    sys_.insert(500, v)              # stays in the insert buffer (batch 64)
    sys_.delete(500)                 # delete while buffered
    ids, _ = sys_.search(v[None, :], k=5)    # search flushes the buffer
    assert 500 not in set(int(x) for x in np.asarray(ids)[0])
    assert sys_.size == 32


def test_reinsert_after_delete_across_merge():
    """delete -> merge (consumes the delete) -> re-insert must revive."""
    rng = np.random.default_rng(4)
    base = rng.standard_normal((48, DIM)).astype(np.float32)
    sys_ = bootstrap_system(base, np.arange(48), _cfg())
    sys_.delete(7)
    sys_.merge()
    sys_.wait_merge()
    ids, _ = sys_.search(base[7:8], k=3)
    assert 7 not in set(int(x) for x in np.asarray(ids)[0])
    sys_.insert(7, base[7])
    ids, _ = sys_.search(base[7:8], k=1)
    assert int(ids[0, 0]) == 7


def test_reinsert_with_new_vector_supersedes_old_copy():
    """Regression: delete(e) + insert(e, v2) + merge must remove e's OLD
    LTI row — a stale duplicate would let searches return e ranked by the
    pre-delete vector."""
    rng = np.random.default_rng(5)
    base = rng.standard_normal((48, DIM)).astype(np.float32)
    sys_ = bootstrap_system(base, np.arange(48), _cfg())
    v2 = base[7] + 100.0                 # far from the old value
    sys_.delete(7)
    sys_.insert(7, v2)
    sys_._flush_inserts()
    sys_.ro.append(sys_.rw)              # roll the revive into an RO tier
    sys_.rw = sys_._new_temp()
    sys_.merge()
    sys_.wait_merge()
    # exactly one LTI slot maps id 7, and it holds the NEW vector
    slots = np.nonzero(sys_.lti_ext_ids == 7)[0]
    assert len(slots) == 1, slots
    np.testing.assert_allclose(
        np.asarray(sys_.lti.graph.vectors[slots[0]]), v2, atol=1e-5)
    # a query at the OLD value must not see id 7 at distance ~0
    ids, d = sys_.search(base[7:8], k=3)
    row = {int(i): float(x) for i, x in zip(np.asarray(ids)[0],
                                            np.asarray(d)[0])}
    assert 7 not in row or row[7] > 1.0, row
    # ... while a query at the new value finds it immediately
    ids, _ = sys_.search(v2[None, :], k=1)
    assert int(ids[0, 0]) == 7


# ---------------------------------------------------------------------------
# Hypothesis-driven variant: generated op streams through the same driver.
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.integers(40, 120))
    @settings(max_examples=10, deadline=None)
    def test_streaming_interleavings_hypothesis(seed, n_ops):
        run_interleaving(seed, n_ops=n_ops)


def test_streaming_interleaving_localized_repair():
    """The full §5.2 invariant sweep with every merge Delete phase forced
    through the localized affected-set repair — outputs are bit-identical
    to the global sweep, so every oracle property must hold unchanged."""
    run_interleaving(
        21, index=IndexConfig(capacity=1024, dim=DIM, R=16, L_build=24,
                              L_search=32, alpha=1.2, repair_mode="local"))


def test_localized_vs_global_merge_routing_bit_parity():
    """Two systems replaying the same op stream — one routed always-local,
    one always-global — must hold bit-identical LTI graphs and search
    results after every merge (the tentpole's end-to-end parity claim)."""
    rng = np.random.default_rng(9)
    base = rng.standard_normal((64, DIM)).astype(np.float32)
    mk = lambda thr: bootstrap_system(
        base, np.arange(64),
        _cfg(local_repair_threshold=thr, reach_probe_samples=0))
    s_local, s_global = mk(1.0), mk(0.0)
    next_id = 1000
    for round_ in range(3):
        for sys_ in (s_local, s_global):
            for j in range(20):
                sys_.insert(next_id + j, base[j] + round_ + 1)
            for e in (round_ * 3, round_ * 3 + 1):
                sys_.delete(e)
            sys_._flush_inserts()
            sys_.merge()
            sys_.wait_merge()
        next_id += 100
        np.testing.assert_array_equal(
            np.asarray(s_local.lti.graph.adjacency),
            np.asarray(s_global.lti.graph.adjacency))
        q = rng.standard_normal((4, DIM)).astype(np.float32)
        ids_l, d_l = s_local.search(q, k=5)
        ids_g, d_g = s_global.search(q, k=5)
        np.testing.assert_array_equal(np.asarray(ids_l), np.asarray(ids_g))
        np.testing.assert_array_equal(np.asarray(d_l), np.asarray(d_g))
    assert s_local.stats.local_repairs == 3
    assert s_local.stats.global_repairs == 0
    assert s_global.stats.local_repairs == 0
    assert s_global.stats.global_repairs == 3


def test_reachability_gauge_low_rate_cycles():
    """Repeated low-rate delete/repair cycles: the unreachable-fraction
    gauge is probed after every merge, stays a valid fraction, and does
    not trend upward (the localized repair must not erode connectivity
    cycle over cycle)."""
    rng = np.random.default_rng(13)
    n0 = 96
    base = rng.standard_normal((n0, DIM)).astype(np.float32)
    sys_ = bootstrap_system(
        base, np.arange(n0),
        _cfg(local_repair_threshold=1.0, reach_probe_samples=64))
    gauges = []
    next_id = 1000
    for cycle in range(4):
        live = sorted(e for e in range(n0) if e not in sys_.deleted_ext)
        for e in rng.choice(live, 2, replace=False):
            sys_.delete(int(e))
        for j in range(4):
            sys_.insert(next_id, _mk_vec(rng))
            next_id += 1
        sys_._flush_inserts()
        sys_.merge()
        sys_.wait_merge()
        gauges.append(sys_.stats.unreachable_frac)
        assert 0.0 <= gauges[-1] <= 1.0
    assert sys_.stats.reach_probes >= 4
    assert sys_.stats.local_repairs >= 1
    # no upward trend: the last probe must not exceed the first by more
    # than the 64-sample binomial noise floor
    assert gauges[-1] <= gauges[0] + 0.125, gauges
    # escalation bookkeeping is consistent: every escalation forces the
    # NEXT sweep global, so escalations can never exceed global repairs + 1
    assert sys_.stats.repair_escalations <= sys_.stats.global_repairs + 1


# ---------------------------------------------------------------------------
# Tenant isolation: random multi-tenant interleavings must NEVER return a
# cross-tenant id, and one tenant's deletes must not perturb another tenant
# beyond shared-topology recall equivalence.
# ---------------------------------------------------------------------------
N_TENANTS = 3


def _tenant_cfg(**kw):
    # Small capacity: the isolation campaign replays hundreds of fresh
    # systems, and identical shapes keep every replay on cached programs.
    base = dict(
        index=IndexConfig(capacity=256, dim=DIM, R=12, L_build=20,
                          L_search=24, alpha=1.2),
        pq=PQConfig(dim=DIM, m=4, ksub=16, kmeans_iters=2),
        ro_snapshot_points=16, merge_threshold=32,
        temp_capacity=96, insert_batch=8, filter_words=1)
    base.update(kw)
    return SystemConfig(**base)


def run_tenant_interleaving(seed: int, n_ops: int = 16) -> None:
    """One random multi-tenant op stream; raises on any cross-tenant leak."""
    from repro.core.graph import FilterSpec
    rng = np.random.default_rng(seed)
    n0 = 24
    base = rng.standard_normal((n0, DIM)).astype(np.float32)
    owner = {e: e % N_TENANTS for e in range(n0)}
    sys_ = bootstrap_system(base, np.arange(n0), _tenant_cfg(),
                            tenants=[owner[e] for e in range(n0)])
    live = dict(owner)
    next_id = 1000

    def check_isolation():
        t = int(rng.integers(0, N_TENANTS))
        q = rng.standard_normal((2, DIM)).astype(np.float32)
        ids, _ = sys_.search_batch(q, 3, filter=FilterSpec(tenant=t))
        for row in np.asarray(ids):
            for e in (int(x) for x in row if x >= 0):
                assert owner.get(e) == t, (
                    f"cross-tenant leak: id {e} (tenant {owner.get(e)}) "
                    f"returned for tenant {t} (seed {seed})")
                assert e in live, (
                    f"deleted id {e} returned for tenant {t} (seed {seed})")

    for _ in range(n_ops):
        r = rng.random()
        if r < 0.5:
            t = int(rng.integers(0, N_TENANTS))
            sys_.insert(next_id, _mk_vec(rng), tenant=t)
            owner[next_id] = t
            live[next_id] = t
            next_id += 1
        elif r < 0.65 and len(live) > 6:
            e = int(rng.choice(sorted(live)))
            sys_.delete(e)
            del live[e]
        elif r < 0.72:
            sys_.merge()
            sys_.wait_merge()
        else:
            check_isolation()
    check_isolation()


def test_tenant_isolation_campaign():
    """The 200-interleaving zero-leak campaign: every generated multi-tenant
    stream, across flushes, rollovers and merges, returns only the filter's
    tenant.  Fixed shapes keep all 200 replays on cached device programs."""
    for seed in range(200):
        run_tenant_interleaving(seed, n_ops=12)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.integers(8, 24))
    @settings(max_examples=10, deadline=None)
    def test_tenant_isolation_hypothesis(seed, n_ops):
        run_tenant_interleaving(seed, n_ops=n_ops)


def test_tenant_deletes_do_not_perturb_others():
    """Delete every tenant-0 point (plus a merge); tenant-1's filtered
    recall against its own oracle must stay equivalent — shared topology
    may shift individual edges, but isolation means another tenant's churn
    cannot collapse this tenant's results."""
    from repro.core.graph import FilterSpec
    rng = np.random.default_rng(31)
    n0 = 60
    base = rng.standard_normal((n0, DIM)).astype(np.float32)
    mk = lambda: bootstrap_system(
        base, np.arange(n0), _tenant_cfg(),
        tenants=[e % N_TENANTS for e in range(n0)])
    s_keep, s_churn = mk(), mk()
    for e in range(0, n0, N_TENANTS):          # tenant-0 points
        s_churn.delete(e)
    s_churn.merge()
    s_churn.wait_merge()
    q = rng.standard_normal((8, DIM)).astype(np.float32)

    t1 = [e for e in range(n0) if e % N_TENANTS == 1]
    mat = base[t1]
    gt = np.asarray(brute_force(jnp.asarray(mat), jnp.ones(len(t1), bool),
                                jnp.asarray(q), 3))
    gt_ids = np.asarray(t1)[gt]

    def t1_recall(sys_):
        ids, _ = sys_.search_batch(q, 8, filter=FilterSpec(tenant=1))
        hits = total = 0
        for row, g in zip(np.asarray(ids)[:, :3], gt_ids):
            for e in (int(x) for x in row if x >= 0):
                assert e % N_TENANTS == 1, f"leak: {e}"
            hits += len(set(int(x) for x in row if x >= 0)
                        & set(g.tolist()))
            total += len(g)
        return hits / total

    r_keep, r_churn = t1_recall(s_keep), t1_recall(s_churn)
    assert r_keep >= 0.7, r_keep
    assert r_churn >= r_keep - 0.2, (r_keep, r_churn)


def test_consolidate_standalone():
    """FreshDiskANN.consolidate(): Algorithm 4 on the LTI outside a merge —
    deleted LTI residents leave the graph, the DeleteList retires ids with
    no surviving copy, searches stay correct."""
    rng = np.random.default_rng(17)
    base = rng.standard_normal((48, DIM)).astype(np.float32)
    sys_ = bootstrap_system(base, np.arange(48),
                            _cfg(reach_probe_samples=16))
    for e in (3, 4, 5):
        sys_.delete(e)
    n = sys_.consolidate()
    assert n == 3
    assert sys_.stats.consolidations == 1
    assert sys_.stats.reach_probes >= 1
    assert not {3, 4, 5} & sys_.deleted_ext      # only copies were in the LTI
    assert sys_.size == 45
    ids, _ = sys_.search(base[3:4], k=5)
    assert 3 not in set(int(x) for x in np.asarray(ids)[0])
    # a second call with an empty DeleteList is a no-op
    assert sys_.consolidate() == 0
    # revive after consolidate works exactly like revive after merge
    sys_.insert(3, base[3])
    ids, _ = sys_.search(base[3:4], k=1)
    assert int(ids[0, 0]) == 3
