"""Storage-tier contracts (docs/STORAGE.md): layout round trips, disk/dense
bit-parity across prefetch depths, the read/cache-hit conservation law,
DGAI delta patches, staging-buffer reuse, and the Pallas HBM gather leg.

The invariants here are the tier-1 half of what ``scripts/disk_probe.py``
asserts end to end in CI (``scripts/smoke.sh --disk``).
"""
import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.config import PQConfig, SystemConfig
from repro.core.lti import build_lti, lti_from_layout, search_lti, \
    write_lti_layout
from repro.core.search import DenseSource, FullPrecisionBackend, PQBackend, \
    beam_search
from repro.core.system import bootstrap_system
from repro.storage import DiskLTISearcher, hbm_gather_rows, HBMSource, \
    open_layout, patch_layout

from conftest import DIM


@pytest.fixture(scope="module")
def pq_cfg():
    return PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)


@pytest.fixture(scope="module")
def lti(points, index_cfg, pq_cfg):
    """A built LTI with a few deletions — the disk tier must mask them
    from results exactly like the in-memory engine."""
    state = build_lti(points[:700], index_cfg, pq_cfg)
    deleted = np.asarray(state.graph.deleted).copy()
    deleted[[3, 50, 311]] = True
    return state._replace(
        graph=state.graph._replace(deleted=jnp.asarray(deleted)))


@pytest.fixture(scope="module")
def layout(lti, tmp_path_factory):
    lay = write_lti_layout(
        str(tmp_path_factory.mktemp("storage") / "layout"), lti)
    yield lay
    lay.close()


@pytest.fixture(scope="module")
def dense_oracle(lti, index_cfg, queries):
    """(per-W) dense results incl. n_reads — the parity reference."""
    out = {}
    for W in (1, 2):
        ids, d, hops, cmps = search_lti(
            lti, jnp.asarray(queries), index_cfg, k=5, L=48, beam_width=W)
        res = beam_search(
            lti.graph.adjacency, lti.graph.active, lti.graph.start,
            jnp.asarray(queries), PQBackend(lti.codes, lti.codebook),
            L=48, max_visits=index_cfg.visits_bound(48), beam_width=W,
            use_kernel=index_cfg.kernel_enabled())
        out[W] = (np.asarray(ids), np.asarray(d), np.asarray(hops),
                  np.asarray(cmps), np.asarray(res.n_reads))
    return out


# ----------------------------------------------------------- layout on disk
def test_layout_roundtrip_bit_exact(lti, layout):
    """Every array written to the layout reads back bit-identical."""
    np.testing.assert_array_equal(np.asarray(layout.adjacency),
                                  np.asarray(lti.graph.adjacency))
    np.testing.assert_array_equal(np.asarray(layout.vectors),
                                  np.asarray(lti.graph.vectors))
    np.testing.assert_array_equal(np.asarray(layout.codes),
                                  np.asarray(lti.codes))
    np.testing.assert_array_equal(layout.centroids,
                                  np.asarray(lti.codebook.centroids))
    np.testing.assert_array_equal(layout.active,
                                  np.asarray(lti.graph.active))
    np.testing.assert_array_equal(layout.deleted,
                                  np.asarray(lti.graph.deleted))
    assert layout.start == int(lti.graph.start)
    assert layout.n_total == int(lti.graph.n_total)
    twin = lti_from_layout(layout.path)
    np.testing.assert_array_equal(np.asarray(twin.graph.adjacency),
                                  np.asarray(lti.graph.adjacency))


def test_topology_fixed_stride(layout):
    """Row i of topology.bin is exactly bytes [i*R*4, (i+1)*R*4)."""
    raw = np.fromfile(os.path.join(layout.path, "topology.bin"), np.int32)
    i = int(layout.start)
    row = raw[i * layout.R:(i + 1) * layout.R]
    np.testing.assert_array_equal(row, np.asarray(layout.adjacency[i]))


# ------------------------------------------------- disk == dense bit-parity
@pytest.mark.parametrize("W", (1, 2))
@pytest.mark.parametrize("depth", (0, 1, 2))
def test_disk_dense_parity(layout, index_cfg, queries, dense_oracle,
                           W, depth):
    """Cache off: ids, dists, hops, cmps AND n_reads are bit-identical to
    the in-memory engine at every prefetch depth — prefetch moves IO off
    the critical path, it never changes results or read counts."""
    ids_d, d_d, hops_d, cmps_d, reads_d = dense_oracle[W]
    s = DiskLTISearcher(layout, index_cfg, cache_mb=0, prefetch_depth=depth)
    try:
        ids, d, hops, cmps, reads = s.search(queries, k=5, L=48,
                                             beam_width=W)
        np.testing.assert_array_equal(np.asarray(ids), ids_d)
        np.testing.assert_array_equal(np.asarray(d), d_d)
        np.testing.assert_array_equal(np.asarray(hops), hops_d)
        np.testing.assert_array_equal(np.asarray(cmps), cmps_d)
        np.testing.assert_array_equal(np.asarray(reads), reads_d)
        st = s.stats
        assert st.cache_hits == 0                 # cache off -> no hits
        assert st.demand_reads + st.prefetch_hits == st.rows_requested
        if depth:
            assert st.prefetch_hits > 0           # the pipeline engaged
    finally:
        s.close()


@pytest.mark.parametrize("depth", (0, 1))
def test_cache_conservation_law(layout, index_cfg, queries, dense_oracle,
                                depth):
    """Cache on: every requested row is a file read XOR a cache hit, and
    reads + hits equals the in-memory engine's n_reads exactly."""
    ids_d, d_d, _, _, reads_d = dense_oracle[2]
    s = DiskLTISearcher(layout, index_cfg, cache_mb=4, prefetch_depth=depth)
    try:
        ids, d, _, _, reads = s.search(queries, k=5, L=48, beam_width=2)
        np.testing.assert_array_equal(np.asarray(ids), ids_d)
        np.testing.assert_array_equal(np.asarray(d), d_d)
        st = s.stats
        assert st.cache_hits > 0                  # 4MB over a tiny layout
        assert (st.demand_reads + st.prefetch_hits + st.cache_hits
                == st.rows_requested == int(reads_d.sum()))
        assert (int(np.asarray(reads).sum()) + st.cache_hits
                == int(reads_d.sum()))
    finally:
        s.close()


def test_n_reads_dense_regression(lti, index_cfg, queries):
    """The n_reads contract on the dense path (core/search.py module doc):
    every expanded row is a fetch, so reads == the visit count — and at
    W=1 exactly one row per IO round, so reads == hops."""
    res = beam_search(
        lti.graph.adjacency, lti.graph.active, lti.graph.start,
        jnp.asarray(queries), FullPrecisionBackend(lti.graph.vectors),
        L=48, max_visits=index_cfg.visits_bound(48), beam_width=1,
        use_kernel=False)
    counts = (np.asarray(res.visited) >= 0).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(res.n_reads), counts)
    np.testing.assert_array_equal(np.asarray(res.n_reads),
                                  np.asarray(res.n_hops))


# -------------------------------------------------------- prefetch pipeline
def test_staging_buffer_reuse(layout, index_cfg, queries):
    """The allocation-free steady state: after a warmup search the two
    staging buffers keep their identity and ``allocations`` goes quiet
    (the worker itself asserts every fill lands in an owned buffer)."""
    s = DiskLTISearcher(layout, index_cfg, cache_mb=0, prefetch_depth=2)
    try:
        jax.block_until_ready(s.search(queries, k=5, L=48, beam_width=2))
        pf = s.reader.prefetcher
        a0 = pf.allocations
        ident = [id(b) for b in pf.staging_buffers()]
        for _ in range(3):
            jax.block_until_ready(s.search(queries, k=5, L=48,
                                           beam_width=2))
        assert pf.allocations == a0
        assert [id(b) for b in pf.staging_buffers()] == ident
        assert pf._thread.is_alive()      # an assert in the worker kills it
    finally:
        s.close()


# ----------------------------------------------------------- delta patches
def test_patch_topology_only_writes_no_vector_bytes(lti, tmp_path):
    """The DGAI claim, measured: a topology-only update rewrites exactly
    the changed adjacency rows and ZERO vector/code bytes."""
    lay = write_lti_layout(str(tmp_path / "lay"), lti)
    lay.close()
    adj = np.asarray(lti.graph.adjacency).copy()
    adj[7] = adj[7][::-1].copy()                  # permute one row
    adj[123, 0] = -1
    patched = lti._replace(
        graph=lti.graph._replace(adjacency=jnp.asarray(adj)))
    ps = patch_layout(str(tmp_path / "lay"), patched.graph,
                      codes=patched.codes)
    assert ps.adj_rows == 2
    assert ps.vec_rows == 0 and ps.code_rows == 0
    assert ps.bytes_written == 2 * lay.row_bytes
    # Block counter: rows 7 and 123 live in distinct 4KB topology blocks
    # at this row size — the SSD-granular cost the locality merge shrinks.
    assert ps.adj_blocks == np.unique(
        np.asarray([7, 123]) // lay.block_rows).size
    re = open_layout(str(tmp_path / "lay"))
    np.testing.assert_array_equal(np.asarray(re.adjacency), adj)
    assert re.generation == 1                     # bumped LAST
    re.close()


def test_patch_noop_writes_nothing(lti, tmp_path):
    lay = write_lti_layout(str(tmp_path / "lay"), lti)
    lay.close()
    ps = patch_layout(str(tmp_path / "lay"), lti.graph, codes=lti.codes)
    assert ps.adj_rows == 0 and ps.vec_rows == 0 and ps.code_rows == 0
    assert ps.adj_blocks == 0
    assert ps.bytes_written == 0


# ------------------------------------------------------------- TPU HBM leg
def test_hbm_gather_rows_matches_dense(lti):
    """The Pallas scalar-prefetch gather is bit-identical to the dense
    indexed gather, including INVALID lanes (interpret mode on CPU)."""
    table = lti.graph.adjacency
    ids = jnp.asarray([0, 5, 17, -1, 2], jnp.int32)
    got = hbm_gather_rows(table, ids, interpret=True)
    want = DenseSource(table, lti.graph.active).rows(ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hbm_source_beam_parity(lti, index_cfg, queries):
    """A full beam search through HBMSource == DenseSource, bit for bit."""
    g = lti.graph
    kw = dict(L=48, max_visits=index_cfg.visits_bound(48), beam_width=2,
              use_kernel=False)
    ref = beam_search(g.adjacency, g.active, g.start,
                      jnp.asarray(queries[:8]),
                      PQBackend(lti.codes, lti.codebook), **kw)
    got = beam_search(None, None, g.start, jnp.asarray(queries[:8]),
                      PQBackend(lti.codes, lti.codebook),
                      source=HBMSource(g.adjacency, g.active),
                      R=g.adjacency.shape[1], **kw)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ system integration
def test_system_search_disk_parity_and_patch(tmp_path, points, queries):
    """End to end with ``storage_dir``: search_disk == search_batch across
    inserts, deletes and a StreamingMerge; the merge delta-patches the
    layout in place (storage_rows_patched > 0) instead of rewriting it."""
    from repro.core.config import IndexConfig
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=48, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32,
        storage_dir=str(tmp_path / "store"),
        prefetch_depth=1, adjacency_cache_mb=0)
    sys_ = bootstrap_system(points[:400], np.arange(400), cfg)
    assert os.path.isfile(
        str(tmp_path / "store" / "lti" / "topology.bin"))
    for i in range(96):
        sys_.insert(5000 + i, points[450 + i])
    for e in (1, 7, 5003):
        sys_.delete(e)
    q = queries[:8]
    ref = sys_.search_batch(q, k=5)
    got = sys_.search_disk(q, k=5)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    assert sys_.stats.io_rows_read > 0

    sys_.merge()
    assert sys_.stats.storage_rows_patched > 0
    ref = sys_.search_batch(q, k=5)
    got = sys_.search_disk(q, k=5)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    sys_.close_storage()


def test_system_knob_reconfigure_conservation(tmp_path, points, queries):
    """Depth/cache knobs change timing and the read/hit split, never the
    results; SystemStats obeys io_rows_read + io_cache_hits == requested."""
    from repro.core.config import IndexConfig
    cfg = SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=48, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=256, insert_batch=32,
        storage_dir=str(tmp_path / "store"),
        prefetch_depth=0, adjacency_cache_mb=0)
    sys_ = bootstrap_system(points[:400], np.arange(400), cfg)
    q = queries[:8]
    ref = sys_.search_batch(q, k=5)

    sys_.search_disk(q, k=5)
    baseline = sys_.stats.io_rows_read          # cache off, depth 0
    assert sys_.stats.io_cache_hits == 0

    sys_.cfg = dataclasses.replace(sys_.cfg, prefetch_depth=2,
                                   adjacency_cache_mb=4)
    sys_.close_storage()                        # reopen with the new knobs
    r0, c0 = sys_.stats.io_rows_read, sys_.stats.io_cache_hits
    got = sys_.search_disk(q, k=5)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])
    reads = sys_.stats.io_rows_read - r0
    hits = sys_.stats.io_cache_hits - c0
    assert hits > 0
    assert reads + hits == baseline             # conservation
    sys_.close_storage()
