"""Per-LM-arch smoke tests on the reduced configs: one forward + one train
step + decode/forward consistency, shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, lm_loss)
from repro.optim.adamw import adamw_init, adamw_update

LM_ARCHS = ["qwen3-14b", "qwen2-1.5b", "gemma3-12b", "mixtral-8x7b",
            "qwen3-moe-30b-a3b"]


@pytest.fixture(scope="module", params=LM_ARCHS)
def smoke(request):
    arch = get_arch(request.param)
    cfg = arch.smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 1, cfg.vocab)
    return request.param, cfg, params, toks


def test_forward_shapes_and_finite(smoke):
    name, cfg, params, toks = smoke
    logits, aux, _ = forward(params, toks, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), name
    if cfg.is_moe:
        assert float(aux) > 0


def test_train_step_reduces_loss(smoke):
    name, cfg, params, toks = smoke
    targets = jnp.roll(toks, -1, axis=1)

    @jax.jit
    def step(p, o):
        (loss, m), g = jax.value_and_grad(
            lambda pp: lm_loss(pp, toks, targets, cfg), has_aux=True)(p)
        p, o = adamw_update(p, g, o, lr=1e-2)
        return p, o, loss

    opt = adamw_init(params)
    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert np.isfinite(losses).all(), name
    assert losses[-1] < losses[0], (name, losses)


def test_decode_matches_forward(smoke):
    name, cfg, params, toks = smoke
    logits, _, _ = forward(params, toks, cfg)
    caches = init_cache(cfg, 2, 32)
    for t in range(32):
        lg, caches = decode_step(params, caches, toks[:, t],
                                 jnp.int32(t), cfg)
    err = float(jnp.abs(lg - logits[:, -1]).max())
    assert err < 5e-4, (name, err)


def test_prefill_caches_match_decode(smoke):
    name, cfg, params, toks = smoke
    _, _, pre = forward(params, toks, cfg, collect_cache=True)
    caches = init_cache(cfg, 2, 32)
    for t in range(32):
        _, caches = decode_step(params, caches, toks[:, t],
                                jnp.int32(t), cfg)
    for a, b in zip(pre, caches):
        assert a["k"].shape == b["k"].shape
        err = float(jnp.abs(a["k"] - b["k"]).max())
        assert err < 5e-4, (name, err)
        assert bool((a["pos"] == b["pos"]).all())


def test_param_count_matches_family(smoke):
    """Sanity: full-config param counts land near the advertised sizes."""
    name, cfg, params, toks = smoke
    full = get_arch(name).full_config
    n = full.param_count()
    expected = {"qwen3-14b": 14e9, "qwen2-1.5b": 1.7e9, "gemma3-12b": 13e9,
                "mixtral-8x7b": 47e9, "qwen3-moe-30b-a3b": 30e9}[name]
    assert 0.6 * expected < n < 1.45 * expected, (name, n)
    if full.is_moe:
        # mixtral: top-2 of 8 -> ~27% active (12.9B); qwen3-moe: ~11%
        assert full.active_param_count() < 0.35 * n
