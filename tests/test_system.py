"""End-to-end FreshDiskANN system behaviour (paper §5): API semantics,
RW->RO rollover, background merge, crash recovery, persistence."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.system import FreshDiskANN, bootstrap_system

from conftest import DIM


def _sys_cfg(tmp=None):
    return SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=128, merge_threshold=256,
        temp_capacity=512, insert_batch=64,
        wal_dir=str(tmp) if tmp else None)


@pytest.fixture(scope="module")
def booted(points):
    return bootstrap_system(points[:800], np.arange(800), _sys_cfg()), points


def _gt_search(live_map, queries, k):
    keys = np.asarray(sorted(live_map))
    mat = np.stack([live_map[kk] for kk in keys])
    gt = brute_force(jnp.asarray(mat), jnp.ones(len(keys), bool),
                     jnp.asarray(queries), k)
    return keys[np.asarray(gt)]


def test_search_after_bootstrap(booted, queries):
    sys_, points = booted
    ids, d = sys_.search(queries, k=5)
    live = dict(enumerate(points[:800]))
    gt = _gt_search(live, queries, 5)
    rec = float(recall_at_k(jnp.asarray(ids), jnp.asarray(gt)))
    assert rec >= 0.85, rec


def test_fresh_inserts_immediately_searchable(points, queries):
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg())
    for i in range(50):
        sys_.insert(1000 + i, points[400 + i])
    q = points[400:410]
    ids, _ = sys_.search(q, k=1)
    assert (np.asarray(ids[:, 0]) == np.arange(1000, 1010)).mean() >= 0.8


def test_deletes_reflected_without_merge(points):
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg())
    q = points[:5]
    ids0, _ = sys_.search(q, k=1)
    for e in np.asarray(ids0[:, 0]):
        sys_.delete(int(e))
    ids1, _ = sys_.search(q, k=5)
    assert not np.isin(np.asarray(ids0[:, 0]), np.asarray(ids1)).any()


def test_rollover_and_merge_threshold(points):
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg())
    for i in range(300):                       # > merge_threshold staged
        sys_.insert(2000 + i, points[500 + i])
    assert sys_.stats.snapshots >= 2
    assert sys_.stats.merges >= 1
    # merged points must remain searchable via the LTI
    q = points[500:520]
    ids, _ = sys_.search(q, k=1)
    assert (np.asarray(ids[:, 0]) >= 2000).mean() >= 0.8


def test_reinsert_after_delete_revives(points):
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    sys_.delete(7)
    sys_.insert(7, points[7])
    ids, _ = sys_.search(points[7:8], k=1)
    assert int(ids[0, 0]) == 7


def test_size_accounting(points):
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    for i in range(40):
        sys_.insert(5000 + i, points[300 + i])
    for e in range(20):
        sys_.delete(e)
    assert sys_.size == 300 + 40 - 20


def test_save_load_roundtrip(tmp_path, points, queries):
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg())
    for i in range(60):
        sys_.insert(3000 + i, points[400 + i])
    sys_.delete(3)
    ids0, d0 = sys_.search(queries[:8], k=5)
    sys_.save(str(tmp_path / "snap"))
    restored = FreshDiskANN.load(str(tmp_path / "snap"), _sys_cfg())
    ids1, d1 = restored.search(queries[:8], k=5)
    assert (np.asarray(ids0) == np.asarray(ids1)).mean() > 0.9


def test_wal_crash_recovery(tmp_path, points):
    cfg = _sys_cfg(tmp_path / "wal")
    sys_ = bootstrap_system(points[:300], np.arange(300), cfg)
    for i in range(40):
        sys_.insert(4000 + i, points[300 + i])
    sys_.delete(5)
    # "crash": rebuild a fresh system from the same base, replay the WAL
    sys2 = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    sys2.wal = None
    n = 0
    from repro.core.wal import replay
    for op, ext_id, vec in replay(os.path.join(str(tmp_path / "wal"),
                                               "wal.bin")):
        if op == 0:
            sys2.insert(ext_id, vec)
        else:
            sys2.delete(ext_id)
        n += 1
    assert n == 41
    ids, _ = sys2.search(points[300:305], k=1)
    assert (np.asarray(ids[:, 0]) == np.arange(4000, 4005)).mean() >= 0.8
    assert 5 in sys2.deleted_ext


def test_recover_loads_snapshot_before_wal(tmp_path, points):
    """recover(snapshot_path) restores the snapshot, then replays only the
    WAL suffix the snapshot doesn't already cover (no double-apply)."""
    cfg = _sys_cfg(tmp_path / "wal")
    sys_ = bootstrap_system(points[:300], np.arange(300), cfg)
    for i in range(20):                 # WAL-logged AND inside the snapshot
        sys_.insert(7000 + i, points[280 + i])
    sys_.save(str(tmp_path / "snap"))
    size_at_save = sys_.size
    # post-snapshot traffic lands only in the WAL suffix we replay
    for i in range(30):
        sys_.insert(8000 + i, points[300 + i])
    sys_.delete(9)
    # "crash": a fresh empty system with the same WAL recovers everything
    crashed = FreshDiskANN(cfg)
    n = crashed.recover(str(tmp_path / "snap"))
    assert n == 31                      # pre-save records are not re-applied
    assert crashed.size == size_at_save + 30 - 1
    ids, _ = crashed.search(points[300:305], k=1)
    assert (np.asarray(ids[:, 0]) == np.arange(8000, 8005)).mean() >= 0.8
    ids2, _ = crashed.search(points[10:12], k=1)   # snapshot points present
    assert (np.asarray(ids2[:, 0]) == np.arange(10, 12)).mean() >= 0.5
    assert 9 in crashed.deleted_ext


def test_ext_loc_tags_unified(tmp_path, points):
    """Location-map tags name real tiers (lti/rw/ro) after save/load."""
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    for i in range(200):                      # forces an RW->RO rollover
        sys_.insert(9000 + i, points[400 + i])
    sys_.save(str(tmp_path / "snap"))
    restored = FreshDiskANN.load(str(tmp_path / "snap"), _sys_cfg())
    for s in (sys_, restored):
        tags = {loc[0] for loc in s._ext_loc.values()}
        assert tags <= {"lti", "rw", "ro"}, tags
        assert "ro" in tags  # the rolled-over snapshot is tagged as RO


def test_background_merge_concurrent_search(points, queries):
    sys_ = bootstrap_system(points[:400], np.arange(400), _sys_cfg())
    for i in range(200):
        sys_.insert(6000 + i, points[500 + i])
    sys_.ro.append(sys_.rw)
    sys_.rw = sys_._new_temp()
    sys_.merge(background=True)
    ids, _ = sys_.search(queries[:4], k=5)   # search while merging
    sys_.wait_merge()
    assert sys_.stats.merges >= 1
    assert (np.asarray(ids) >= -1).all()


# --------------------------------------------------- flush-path concurrency
# The narrowed _insert_lock critical section (insert() holds it only for
# WAL + buffer bookkeeping; the device-side flush runs under _flush_lock
# after release) and the split insert/flush latency accounting.

def test_delete_during_inflight_flush_sticks(points, monkeypatch):
    """A delete issued while its point's flush is in flight must STICK:
    the flush publish loop may not touch the DeleteList (the buffered id
    was revived at append time, so any deleted_ext entry it would discard
    belongs to a LATER delete)."""
    import threading
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    started, release = threading.Event(), threading.Event()
    inner = sys_._flush_compute

    def gated(ids, vecs, bits, tens):
        started.set()
        assert release.wait(timeout=30)
        inner(ids, vecs, bits, tens)

    monkeypatch.setattr(sys_, "_flush_compute", gated)
    victim = 3000

    def filler():                       # fills the batch -> triggers flush
        for i in range(sys_.cfg.insert_batch):
            sys_.insert(victim + i, points[300 + i])

    t = threading.Thread(target=filler)
    t.start()
    assert started.wait(timeout=30)
    # Flush is mid-compute; insert()/delete() bookkeeping must not block on
    # it (the narrowed lock), and the delete must survive the publish.
    sys_.delete(victim)
    release.set()
    t.join(timeout=30)
    assert not t.is_alive()
    assert victim in sys_.deleted_ext
    ids, _ = sys_.search(points[300:301], k=3)
    assert victim not in np.asarray(ids)
    ids2, _ = sys_.search(points[301:302], k=3)
    assert victim + 1 in np.asarray(ids2)  # the rest of the batch flushed


def test_flush_latency_sampled_once_per_flush(points, monkeypatch):
    """insert_latency samples bookkeeping per insert; flush_latency samples
    the amortized device flush once per flush, and the slow part never
    bleeds into the per-insert numbers."""
    import time as _time
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    inner = sys_._flush_compute
    monkeypatch.setattr(
        sys_, "_flush_compute",
        lambda ids, vecs, bits, tens: (_time.sleep(0.25), inner(ids, vecs, bits, tens)))
    n = sys_.cfg.insert_batch * 2
    for i in range(n):
        sys_.insert(4000 + i, points[300 + i])
    snap = sys_.stats.serving_snapshot()
    assert sys_.stats.flushes == 2
    assert snap["flush"]["n"] == 2
    assert snap["flush"]["p50"] >= 0.25
    assert sys_.stats.insert_latency.seen == n
    assert max(sys_.stats.insert_latency.sample) < 0.25


def test_concurrent_insert_delete_search_no_deadlock(points):
    """Mixed traffic across threads with the narrowed locks: everything
    completes (no flush->insert->ro lock inversion) and accounting adds
    up."""
    import threading
    sys_ = bootstrap_system(points[:300], np.arange(300), _sys_cfg())
    errs = []

    def worker(base):
        try:
            for i in range(40):
                sys_.insert(base + i, points[(base + i) % 900])
                if i % 7 == 0:
                    sys_.delete(base + i)
                if i % 11 == 0:
                    sys_.search(points[i:i + 2], k=3)
        except Exception as e:                       # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(5000 + 100 * w,))
          for w in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120)
    assert not errs and all(not t.is_alive() for t in ts)
    assert sys_.stats.inserts == 160 and sys_.stats.deletes == 24
    sys_._flush_inserts()
    assert sys_.size == 300 + 160 - 24
