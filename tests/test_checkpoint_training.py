"""Checkpointing (atomic, async, GC), fault-tolerant training loop
(resume-by-step determinism), data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.data.pipelines import click_stream, lm_token_stream, vector_stream


def _tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    got, step = restore_checkpoint(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(got["a"], t["a"])
    np.testing.assert_array_equal(got["b"]["c"], t["b"]["c"])


def test_latest_step_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    ck.wait()
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(os.listdir(str(tmp_path)))
    assert len(steps) == 2                     # GC keeps last 2


def test_atomic_no_partial_dir(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


def test_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    save_checkpoint(str(tmp_path), 3, _tree())
    sh = {"a": NamedSharding(mesh, P()), "b": {"c": NamedSharding(mesh, P())}}
    got, _ = restore_checkpoint(str(tmp_path), shardings=sh)
    assert isinstance(got["a"], jax.Array)
    np.testing.assert_array_equal(np.asarray(got["a"]), _tree()["a"])


def test_training_resume_determinism(tmp_path):
    """Run 6 steps; crash; resume from step-3 checkpoint; states match."""
    from repro.configs import get_arch
    from repro.models.transformer import init_params, lm_loss
    from repro.optim.adamw import adamw_init
    from repro.training.loop import run_training
    from repro.training.steps import make_train_step
    from repro.launch.mesh import make_host_mesh

    cfg = get_arch("qwen2-1.5b").smoke_config
    mesh = make_host_mesh()
    step = jax.jit(make_train_step(
        lambda p, b: lm_loss(p, b["tokens"], b["targets"], cfg), lr=1e-3))
    stream = lambda s: lm_token_stream(4, 16, cfg.vocab, start_step=s)

    p0 = init_params(jax.random.PRNGKey(0), cfg)
    o0 = adamw_init(p0)
    ckpt = str(tmp_path / "run")
    # full run: 6 steps, checkpoint every 3
    pa, oa, _ = run_training(mesh, step, p0, o0, stream, n_steps=6,
                             ckpt_dir=ckpt, ckpt_every=3,
                             log_fn=lambda s: None)
    # "crashed" run: delete the step-6 checkpoint, resume from step 3
    import shutil
    shutil.rmtree(os.path.join(ckpt, "step_0000000006"))
    pb, ob, _ = run_training(mesh, step, p0, o0, stream, n_steps=6,
                             ckpt_dir=ckpt, ckpt_every=100,
                             log_fn=lambda s: None)
    flat_a = jax.tree.leaves(pa)
    flat_b = jax.tree.leaves(pb)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("maker,args", [
    (lm_token_stream, (4, 8, 100)),
    (click_stream, (4, 5, 50)),
    (vector_stream, (4, 6)),
])
def test_streams_deterministic_resume(maker, args):
    """stream(start_step=k) must equal skipping k batches — the resume
    contract that makes checkpoints self-contained."""
    s1 = maker(*args, seed=5)
    for _ in range(3):
        next(s1)
    b1 = next(s1)
    s2 = maker(*args, seed=5, start_step=3)
    b2 = next(s2)
    if isinstance(b1, dict):
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
    else:
        np.testing.assert_array_equal(b1, b2)


def test_grad_accumulation_equivalence():
    """accum_steps=2 must match accum_steps=1 on the same global batch."""
    from repro.configs import get_arch
    from repro.models.transformer import init_params, lm_loss
    from repro.optim.adamw import adamw_init
    from repro.training.steps import make_train_step

    cfg = get_arch("qwen2-1.5b").smoke_config
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 1, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    loss_fn = lambda pp, b: lm_loss(pp, b["tokens"], b["targets"], cfg)
    s1 = make_train_step(loss_fn, lr=1e-3)
    s2 = make_train_step(loss_fn, lr=1e-3, accum_steps=2)
    p1, _, m1 = s1(p, adamw_init(p), batch)
    p2, _, m2 = s2(p, adamw_init(p), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)
