"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py forces 512 placeholder devices."""
import gc

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig


DIM = 24
N = 1200


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Free compiled executables between test modules — the suite compiles
    hundreds of jit variants (5 LM archs x forward/decode/train, the ANN
    core, kernels in interpret mode); without this the CPU jaxlib arena
    grows monotonically and aborts natively near the end of the suite."""
    yield
    jax.clear_caches()
    gc.collect()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def points(rng):
    """Gaussian-mixture points (clustered, like real embeddings)."""
    centers = rng.standard_normal((24, DIM)) * 3.0
    which = rng.integers(0, 24, N)
    return (centers[which]
            + rng.standard_normal((N, DIM))).astype(np.float32)


@pytest.fixture(scope="session")
def queries(rng, points):
    # Depends on ``points`` (unused) to pin the draw order on the shared rng:
    # otherwise fixture instantiation order — which varies with the module
    # execution order — would change both streams and every recall number.
    del points
    centers = rng.standard_normal((24, DIM)) * 3.0
    which = rng.integers(0, 24, 64)
    return (centers[which]
            + rng.standard_normal((64, DIM))).astype(np.float32)


@pytest.fixture(scope="session")
def index_cfg():
    return IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                       L_search=48, alpha=1.2)


@pytest.fixture(scope="session")
def pq_cfg():
    return PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=5)


@pytest.fixture(scope="session")
def built_index(points, index_cfg):
    from repro.core.index import build
    return build(points, index_cfg, batch=128)
