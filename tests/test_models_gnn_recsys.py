"""GNN + recsys smoke tests on reduced configs: forward/train step per
assigned shape family, shapes + finiteness + learnability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.pipelines import click_stream, sasrec_stream, synthetic_graph
from repro.models import gnn, recsys as rec
from repro.optim.adamw import adamw_init, adamw_update

rng = np.random.default_rng(0)


# ---------------------------------------------------------------- GraphSAGE

@pytest.fixture(scope="module")
def sage():
    cfg = get_arch("graphsage-reddit").smoke_config
    g = synthetic_graph(400, 6, cfg.d_feat, cfg.n_classes)
    params = gnn.init_sage_params(jax.random.PRNGKey(0), cfg)
    return cfg, g, params


def test_sage_full_batch_learns(sage):
    cfg, g, params = sage
    feats, src, dst = map(jnp.asarray, (g["feats"], g["src"], g["dst"]))
    labels = jnp.asarray(g["labels"])
    mask = jnp.ones(400, bool)

    @jax.jit
    def step(p, o):
        loss, grads = jax.value_and_grad(
            lambda pp: gnn.sage_loss_full(pp, feats, src, dst, labels,
                                          mask, cfg))(p)
        p, o = adamw_update(p, grads, o, lr=1e-2, weight_decay=0.0)
        return p, o, loss

    opt = adamw_init(params)
    losses = []
    p = params
    for _ in range(30):
        p, opt, loss = step(p, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_sage_sampled_forward(sage):
    cfg, g, params = sage
    seeds = jnp.asarray(rng.integers(0, 400, 16), jnp.int32)
    logits = gnn.sage_forward_sampled(
        params, jax.random.PRNGKey(1), jnp.asarray(g["feats"]),
        jnp.asarray(g["offsets"]), jnp.asarray(g["nbrs"]), seeds, cfg)
    assert logits.shape == (16, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())


def test_sampler_respects_adjacency(sage):
    cfg, g, params = sage
    nodes = jnp.asarray(rng.integers(0, 400, 64), jnp.int32)
    got = gnn.sample_neighbors(jax.random.PRNGKey(2),
                               jnp.asarray(g["offsets"]),
                               jnp.asarray(g["nbrs"]), nodes, 5)
    offs, nbrs = g["offsets"], g["nbrs"]
    for i, v in enumerate(np.asarray(nodes)):
        actual = set(nbrs[offs[v]:offs[v + 1]].tolist()) or {int(v)}
        assert set(np.asarray(got[i]).tolist()) <= actual


def test_sage_batched_molecules(sage):
    cfg, g, params = sage
    G_, n_, e_ = 8, 12, 24
    feats = jnp.asarray(rng.standard_normal((G_, n_, cfg.d_feat)),
                        jnp.float32)
    src = jnp.asarray(rng.integers(0, n_, (G_, e_)), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n_, (G_, e_)), jnp.int32)
    m = jnp.asarray(rng.random((G_, e_)) < 0.7)
    logits = gnn.sage_forward_batched(params, feats, src, dst, m, cfg)
    assert logits.shape == (G_, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())


# ------------------------------------------------------------------ recsys

@pytest.mark.parametrize("name", ["fm", "deepfm", "xdeepfm"])
def test_fm_family_learns(name):
    cfg = get_arch(name).smoke_config
    params = rec.init_recsys_params(jax.random.PRNGKey(0), cfg)
    stream = click_stream(128, cfg.n_sparse, cfg.rows_per_field, seed=1)

    @jax.jit
    def step(p, o, ids, y):
        loss, grads = jax.value_and_grad(
            lambda pp: rec.recsys_loss(pp, ids, y, cfg))(p)
        p, o = adamw_update(p, grads, o, lr=5e-3, weight_decay=0.0)
        return p, o, loss

    opt = adamw_init(params)
    losses = []
    for _ in range(25):
        b = next(stream)
        params, opt, loss = step(params, opt, jnp.asarray(b["ids"]),
                                 jnp.asarray(b["labels"]))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), (name, losses[::8])


def test_sasrec_learns_and_retrieves():
    cfg = get_arch("sasrec").smoke_config
    params = rec.init_recsys_params(jax.random.PRNGKey(0), cfg)
    stream = sasrec_stream(64, cfg.seq_len, cfg.n_items, seed=2)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(
            lambda pp: rec.sasrec_loss(pp, b["seq"], b["pos"], b["neg"],
                                       cfg))(p)
        p, o = adamw_update(p, grads, o, lr=5e-3, weight_decay=0.0)
        return p, o, loss

    opt = adamw_init(params)
    losses = []
    for _ in range(30):
        b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses[::10]
    q = rec.sasrec_user_embedding(params, b["seq"], cfg)
    scores, ids = rec.retrieval_topk(q, params["item_emb"], 10)
    assert ids.shape == (64, 10)


def test_retrieval_topk_matches_bruteforce():
    cfg = get_arch("fm").smoke_config
    table = jnp.asarray(rng.standard_normal((500, cfg.embed_dim)),
                        jnp.float32)
    q = jnp.asarray(rng.standard_normal((3, cfg.embed_dim)), jnp.float32)
    scores, ids = rec.retrieval_topk(q, table, 10)
    want = np.argsort(-np.asarray(q @ table.T), axis=1)[:, :10]
    assert (np.asarray(ids) == want).all()


def test_embedding_bag_modes():
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
    ids = jnp.asarray([1, 2, 3, 10, 11], jnp.int32)
    segs = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    s = rec.embedding_bag(table, ids, segs, 2, mode="sum")
    m = rec.embedding_bag(table, ids, segs, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(table[1] + table[2]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m[1]),
                               np.asarray((table[3] + table[10]
                                           + table[11]) / 3), rtol=1e-6)
