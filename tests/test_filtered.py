"""Filtered & multi-tenant search against a brute-force filtered oracle.

The label subsystem folds a ``FilterSpec`` into the cached drop mask that
``unified_search`` already applies POST-search — one extra AND, no new
kernel — so two contracts anchor this suite:

  * validity is absolute: a filtered search NEVER returns an id that fails
    the predicate (label bits or tenant), at any selectivity, before or
    after merges, on the in-memory and the on-disk path alike;
  * selectivity = 1.0 is free: a filter every live point matches must be
    bit-identical (ids, dists, dispatch counters) to the unfiltered call —
    pinned as a regression so the filter path can never perturb the
    unfiltered one.

Recall floors at lower selectivities are measured against the brute-force
oracle restricted to matching points; the search is post-filtering, so the
floors scale L with 1/selectivity (the paper's standard filtered-search
accommodation) rather than expecting fixed-L recall to survive a 100x
candidate thinning.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.graph import FilterSpec, LabelTable, filter_match, pack_labels
from repro.core.system import bootstrap_system

from conftest import DIM

N_BOOT = 400
N_STREAM = 100
N_TENANTS = 4

# label bit -> fraction of points carrying it (the selectivity ladder)
SEL_BITS = {0: 1.0, 1: 0.5, 2: 0.1, 3: 0.01}


def _labels_for(i: int) -> list:
    ls = [0]
    if i % 2 == 0:
        ls.append(1)
    if i % 10 == 0:
        ls.append(2)
    if i % 100 == 0:
        ls.append(3)
    return ls


def _cfg(tmp=None, **kw):
    base = dict(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=128, merge_threshold=256,
        temp_capacity=512, insert_batch=64,
        filter_words=1, wal_dir=str(tmp) if tmp else None)
    base.update(kw)
    return SystemConfig(**base)


@pytest.fixture(scope="module")
def labeled(points):
    """System over conftest points: labeled bootstrap + labeled streaming
    inserts (so filters are exercised on the LTI lane AND the temp lanes),
    plus the side truth tables the oracle filters by."""
    sys_ = bootstrap_system(
        points[:N_BOOT], np.arange(N_BOOT), _cfg(),
        labels=[_labels_for(i) for i in range(N_BOOT)],
        tenants=[i % N_TENANTS for i in range(N_BOOT)])
    truth = {i: (points[i], _labels_for(i), i % N_TENANTS)
             for i in range(N_BOOT)}
    for j in range(N_STREAM):
        i, e = N_BOOT + j, 1000 + j
        sys_.insert(e, points[i], labels=_labels_for(i),
                    tenant=i % N_TENANTS)
        truth[e] = (points[i], _labels_for(i), i % N_TENANTS)
    sys_._flush_inserts()
    return sys_, truth


def _oracle(truth, pred, queries, k):
    """Brute-force filtered ground truth: top-k over points passing pred."""
    keys = np.asarray([e for e in sorted(truth) if pred(*truth[e][1:])])
    mat = np.stack([truth[e][0] for e in keys])
    d = ((mat[None, :, :] - np.asarray(queries)[:, None, :]) ** 2).sum(-1)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    return keys[order]


def _recall(ids, gt):
    hits = total = 0
    for row, g in zip(np.asarray(ids), gt):
        hits += len(set(int(x) for x in row if x >= 0)
                    & set(int(x) for x in g))
        total += len(g)
    return hits / total


# ------------------------------------------------------------ selectivity
@pytest.mark.parametrize("bit,sel", sorted(SEL_BITS.items()))
def test_filtered_recall_vs_oracle(labeled, queries, bit, sel):
    """Post-filtering semantics: the drop mask infs non-matching points out
    of the ALREADY-searched candidates, so a client widens k/L by ~1/sel
    and takes the leading k rows (matching ids sort first, -1 pads last) —
    the standard filtered-search accommodation this suite anchors."""
    sys_, truth = labeled
    k = 5
    k_eff = k if sel == 1.0 else min(256, int(np.ceil(k / sel * 1.5)))
    L = min(max(64, 2 * k_eff), 1024)
    spec = FilterSpec(all_of=(bit,))
    ids, dists = sys_.search_batch(queries, k_eff, L=L, filter=spec)
    ids = np.asarray(ids)[:, :k]
    # validity: every returned id carries the bit — zero false positives
    for row in ids:
        for e in (int(x) for x in row if x >= 0):
            assert bit in truth[e][1], (
                f"id {e} returned without label bit {bit} (sel={sel})")
    gt = _oracle(truth, lambda ls, t: bit in ls, queries, k)
    floor = {1.0: 0.80, 0.5: 0.60, 0.1: 0.50, 0.01: 0.50}[sel]
    rec = _recall(ids, gt)
    assert rec >= floor, f"filtered recall {rec:.3f} < {floor} (sel={sel})"


def test_selectivity_one_bit_parity(labeled, queries):
    """THE pinned regression: a filter every point matches is bit-identical
    to no filter at all — ids, dists, and dispatch accounting."""
    sys_, _ = labeled
    d0 = sys_.stats.search_dispatches
    ids_u, dist_u = sys_.search_batch(queries, 10)
    du = sys_.stats.search_dispatches - d0
    d0 = sys_.stats.search_dispatches
    ids_f, dist_f = sys_.search_batch(queries, 10,
                                      filter=FilterSpec(all_of=(0,)))
    df = sys_.stats.search_dispatches - d0
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_u))
    np.testing.assert_array_equal(np.asarray(dist_f), np.asarray(dist_u))
    assert df == du, (df, du)


def test_empty_filterspec_is_unfiltered(labeled, queries):
    """FilterSpec() constrains nothing: resolved to the unfiltered path
    (same cached drop mask, not merely equal results)."""
    sys_, _ = labeled
    f0 = sys_.stats.filtered_searches
    ids_u, dist_u = sys_.search_batch(queries, 5)
    ids_e, dist_e = sys_.search_batch(queries, 5, filter=FilterSpec())
    np.testing.assert_array_equal(np.asarray(ids_e), np.asarray(ids_u))
    np.testing.assert_array_equal(np.asarray(dist_e), np.asarray(dist_u))
    assert sys_.stats.filtered_searches == f0    # not counted as filtered


# ------------------------------------------------------------- tenants
def test_tenant_filter_vs_oracle(labeled, queries):
    sys_, truth = labeled
    for tenant in range(N_TENANTS):
        spec = FilterSpec(tenant=tenant)
        ids, _ = sys_.search_batch(queries, 5, L=128, filter=spec)
        for row in np.asarray(ids):
            for e in (int(x) for x in row if x >= 0):
                assert truth[e][2] == tenant, (
                    f"cross-tenant leak: id {e} (tenant {truth[e][2]}) "
                    f"returned for tenant {tenant}")
        gt = _oracle(truth, lambda ls, t: t == tenant, queries, 5)
        assert _recall(ids, gt) >= 0.5


def test_tenant_and_label_compose(labeled, queries):
    """tenant + label in one spec: the AND of both predicates."""
    sys_, truth = labeled
    spec = FilterSpec(all_of=(1,), tenant=2)
    ids, _ = sys_.search_batch(queries, 5, L=256, filter=spec)
    for row in np.asarray(ids):
        for e in (int(x) for x in row if x >= 0):
            assert 1 in truth[e][1] and truth[e][2] == 2


def test_tenant_search_accounting(labeled, queries):
    sys_, _ = labeled
    before = dict(sys_.stats.tenant_searches)
    sys_.search_batch(queries, 3, filter=FilterSpec(tenant=1))
    after = sys_.stats.tenant_searches
    assert after[1] - before.get(1, 0) == len(queries)
    assert sys_.stats.filtered_searches > 0


# -------------------------------------------------------- lifecycle
def test_filters_survive_delete_and_merge(points, queries):
    """Labels follow points through delete + StreamingMerge: the merged LTI
    answers filtered searches with the same validity guarantee, and the
    deleted ids are gone from filtered results too."""
    sys_ = bootstrap_system(
        points[:200], np.arange(200), _cfg(),
        labels=[_labels_for(i) for i in range(200)],
        tenants=[i % N_TENANTS for i in range(200)])
    for j in range(60):
        sys_.insert(1000 + j, points[200 + j],
                    labels=_labels_for(200 + j),
                    tenant=(200 + j) % N_TENANTS)
    victims = [4, 8, 1000, 1004]
    for e in victims:
        sys_.delete(e)
    sys_.merge()
    sys_.wait_merge()
    for tenant in range(N_TENANTS):
        ids, _ = sys_.search_batch(queries, 5, L=128,
                                   filter=FilterSpec(tenant=tenant))
        for row in np.asarray(ids):
            for e in (int(x) for x in row if x >= 0):
                assert e not in victims
                i = e - 1000 + 200 if e >= 1000 else e
                assert i % N_TENANTS == tenant, (
                    f"cross-tenant leak after merge: {e}")


def test_filtered_search_disk(points, queries, tmp_path):
    """The decoupled on-disk path honors the same FilterSpec: labels ride
    the layout's meta side tables and filter the LTI lane served off disk."""
    cfg = _cfg(tmp_path, storage_dir=str(tmp_path / "store"))
    sys_ = bootstrap_system(
        points[:200], np.arange(200), cfg,
        labels=[_labels_for(i) for i in range(200)],
        tenants=[i % N_TENANTS for i in range(200)])
    ids, _ = sys_.search_disk(queries[:8], 5, filter=FilterSpec(tenant=1))
    for row in np.asarray(ids):
        for e in (int(x) for x in row if x >= 0):
            assert e % N_TENANTS == 1, f"disk-path tenant leak: {e}"
    sys_.close_storage()


# ------------------------------------------------------ unit: bit packing
def test_pack_unpack_roundtrip():
    from repro.core.graph import unpack_labels
    row = pack_labels([0, 3, 31, 32, 63], 2)
    assert row.dtype == np.uint32 and row.shape == (2,)
    assert sorted(unpack_labels(row)) == [0, 3, 31, 32, 63]
    with pytest.raises(ValueError):
        pack_labels([64], 2)                      # out of range for 2 words


def test_filter_match_semantics():
    tab = LabelTable(4, 1)
    tab.set_row(0, pack_labels([0, 1], 1), 7)
    tab.set_row(1, pack_labels([1], 1), 7)
    tab.set_row(2, pack_labels([0], 1), 8)
    # row 3 untouched: no labels, no tenant
    m = filter_match(tab, FilterSpec(all_of=(0, 1)))
    assert m.tolist() == [True, False, False, False]
    m = filter_match(tab, FilterSpec(any_of=(0, 1)))
    assert m.tolist() == [True, True, True, False]
    m = filter_match(tab, FilterSpec(tenant=7))
    assert m.tolist() == [True, True, False, False]
    m = filter_match(tab, FilterSpec(all_of=(0,), tenant=8))
    assert m.tolist() == [False, False, True, False]
