"""The batched mutation engine (insert / delete-repair / merge prune paths).

Three contracts:
  1. kernel-vs-oracle bit-parity — every mutation caller (build, insert,
     consolidation, StreamingMerge in both distance flavors) produces the
     SAME graph with ``use_kernel=True`` (fused Pallas launches, interpret
     mode on CPU) as with the jnp oracle path;
  2. the Delta append path never duplicates an edge (degree-budget burn
     regression);
  3. alpha-RNG post-conditions: repaired rows satisfy the prune invariant
     (``prune.check_alpha_rng``) after ``consolidate_deletes`` and the
     StreamingMerge delete phase.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import index as mem
from repro.core.config import IndexConfig, PQConfig
from repro.core.delete import consolidate_deletes, delete
from repro.core.distance import INVALID
from repro.core.insert import apply_back_edges
from repro.core.lti import build_lti
from repro.core.merge import streaming_merge
from repro.core.prune import check_alpha_rng

from conftest import DIM


def _cfg(use_kernel, **kw):
    base = dict(capacity=1024, dim=DIM, R=16, L_build=24, L_search=32,
                alpha=1.2, use_kernel=use_kernel)
    base.update(kw)
    return IndexConfig(**base)


def _pq():
    return PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4)


# ---------------------------------------------------------------------------
# 1. kernel-path bit-parity through every mutation caller
# ---------------------------------------------------------------------------

def test_build_and_insert_parity(points):
    """index.build (LTI build path) + batched insert: fused prune kernels
    vs the jnp oracle, identical adjacency."""
    g_j = mem.build(points[:300], _cfg(False), batch=64)
    g_k = mem.build(points[:300], _cfg(True), batch=64)
    np.testing.assert_array_equal(np.asarray(g_j.adjacency),
                                  np.asarray(g_k.adjacency))
    slots = jnp.arange(300, 332, dtype=jnp.int32)
    vecs = jnp.asarray(points[300:332])
    i_j = mem.insert(g_j, slots, vecs, _cfg(False))
    i_k = mem.insert(g_k, slots, vecs, _cfg(True))
    np.testing.assert_array_equal(np.asarray(i_j.adjacency),
                                  np.asarray(i_k.adjacency))


def test_consolidate_parity(points):
    g = mem.build(points[:300], _cfg(False), batch=64)
    victims = jnp.arange(0, 300, 9)
    c_j = consolidate_deletes(delete(g, victims), _cfg(False))
    c_k = consolidate_deletes(delete(g, victims), _cfg(True))
    np.testing.assert_array_equal(np.asarray(c_j.adjacency),
                                  np.asarray(c_k.adjacency))
    np.testing.assert_array_equal(np.asarray(c_j.active),
                                  np.asarray(c_k.active))


@pytest.mark.parametrize("use_sdc", [False, True])
def test_streaming_merge_parity(points, use_sdc):
    """All three merge phases (delete repair, insert-phase prune, Delta
    patch) ride the engine: kernel and jnp paths produce the same LTI."""
    lti = build_lti(points[:300], _cfg(False), _pq(), batch=64)
    newv = jnp.asarray(points[300:400])
    valid = jnp.ones((100,), bool)
    dmask = jnp.zeros((1024,), bool).at[jnp.arange(0, 300, 11)].set(True)
    out = {}
    for uk in (False, True):
        cfg = _cfg(uk)
        merged, stats = streaming_merge(lti, newv, valid, dmask, cfg, _pq(),
                                        insert_chunk=32, block=256,
                                        use_sdc=use_sdc)
        out[uk] = (merged, stats)
    np.testing.assert_array_equal(np.asarray(out[False][0].graph.adjacency),
                                  np.asarray(out[True][0].graph.adjacency))
    np.testing.assert_array_equal(np.asarray(out[False][1].slots),
                                  np.asarray(out[True][1].slots))


# ---------------------------------------------------------------------------
# 2. Delta append-path dedupe (degree-budget burn regression)
# ---------------------------------------------------------------------------

def _tiny_graph(n=12, R=4, seed=0):
    rng = np.random.default_rng(seed)
    vecs = jnp.asarray(rng.standard_normal((n, DIM)).astype(np.float32))
    adj = jnp.full((n, R), INVALID, jnp.int32)
    usable = jnp.ones((n,), bool)
    return vecs, adj, usable, R


def test_back_edge_already_present_not_duplicated():
    """A source p already in N_out(j) must leave the row unchanged — the
    old append path burned a degree slot on the duplicate."""
    vecs, adj, usable, R = _tiny_graph()
    adj = adj.at[1, 0].set(2)                     # j=1 already links p=2
    pairs_j = jnp.asarray([1], jnp.int32)
    pairs_p = jnp.asarray([2], jnp.int32)
    for uk in (False, True):
        out = apply_back_edges(adj, vecs, usable, pairs_j, pairs_p,
                               alpha=1.2, R=R, use_kernel=uk)
        np.testing.assert_array_equal(np.asarray(out[1]), [2, -1, -1, -1])


def test_duplicate_pairs_append_once():
    """The same (j, p) pair listed twice appends p exactly once."""
    vecs, adj, usable, R = _tiny_graph()
    adj = adj.at[1, 0].set(3)
    pairs_j = jnp.asarray([1, 1], jnp.int32)
    pairs_p = jnp.asarray([5, 5], jnp.int32)
    for uk in (False, True):
        out = apply_back_edges(adj, vecs, usable, pairs_j, pairs_p,
                               alpha=1.2, R=R, use_kernel=uk)
        row = np.asarray(out[1])
        np.testing.assert_array_equal(np.sort(row[:2]), [3, 5])
        np.testing.assert_array_equal(row[2:], [-1, -1])


def test_dedupe_avoids_spurious_reprune():
    """Duplicates must not inflate the degree-budget test: a row with
    R-1 edges + one duplicate source stays on the append path (the true
    union fits), rather than burning the last slot or re-pruning."""
    vecs, adj, usable, R = _tiny_graph()
    adj = adj.at[1].set(jnp.asarray([2, 4, 6, INVALID], jnp.int32))
    pairs_j = jnp.asarray([1, 1], jnp.int32)
    pairs_p = jnp.asarray([2, 8], jnp.int32)      # 2 is a dup, 8 is new
    out = apply_back_edges(adj, vecs, usable, pairs_j, pairs_p,
                           alpha=1.2, R=R, use_kernel=False)
    np.testing.assert_array_equal(np.sort(np.asarray(out[1])), [2, 4, 6, 8])


# ---------------------------------------------------------------------------
# 3. alpha-RNG post-conditions over the repair passes
# ---------------------------------------------------------------------------

def _alpha_ok_fraction(state, rows_of, table, alpha):
    oks = [bool(check_alpha_rng(state.adjacency[p], table[p], table, alpha))
           for p in rows_of]
    return np.mean(oks) if oks else 1.0


def test_consolidate_rows_satisfy_alpha_rng(points):
    """Every row repaired by Algorithm 4 is a fresh RobustPrune output and
    must satisfy the alpha-RNG invariant against the prune table."""
    cfg = _cfg(False)
    g = mem.build(points[:300], cfg, batch=64)
    victims = jnp.arange(0, 300, 7)
    gd = delete(g, victims)
    safe = jnp.maximum(gd.adjacency, 0)
    had_del = ((gd.adjacency >= 0) & gd.deleted[safe]).any(axis=1)
    repaired = np.nonzero(
        np.asarray(had_del & gd.active & ~gd.deleted))[0][:40]
    out = consolidate_deletes(gd, cfg)
    frac = _alpha_ok_fraction(out, repaired, out.vectors, cfg.alpha)
    assert frac == 1.0


def test_merge_delete_phase_satisfies_alpha_rng(points):
    """A pure-delete StreamingMerge changes rows only through the delete
    phase's RobustPrune — every changed row must satisfy the invariant
    against the PQ-decoded table the prune actually ran on."""
    from repro.core import pq as pqm
    cfg, pq_cfg = _cfg(False), _pq()
    lti = build_lti(points[:300], cfg, pq_cfg, batch=64)
    dmask = jnp.zeros((1024,), bool).at[jnp.arange(0, 300, 6)].set(True)
    none = jnp.zeros((1, DIM), jnp.float32)
    merged, _ = streaming_merge(lti, none, jnp.zeros((1,), bool), dmask,
                                cfg, pq_cfg, insert_chunk=32, block=256)
    decoded = pqm.decode(merged.codebook, merged.codes, pq_cfg)
    changed = np.nonzero(np.asarray(
        (merged.graph.adjacency != lti.graph.adjacency).any(axis=1)
        & merged.graph.active))[0][:40]
    frac = _alpha_ok_fraction(merged.graph, changed, decoded, cfg.alpha)
    assert frac == 1.0


def test_full_merge_improves_alpha_rng_on_decoded_table(points):
    """With staged inserts the Patch phase may legally append (no prune),
    so not every live row satisfies the invariant — but every row the merge
    *does* prune is pruned on decoded-code distances, so the decoded-table
    invariant fraction must not regress vs the pre-merge graph (whose rows
    were built on exact vectors and mostly violate it)."""
    from repro.core import pq as pqm
    cfg, pq_cfg = _cfg(False), _pq()
    lti = build_lti(points[:300], cfg, pq_cfg, batch=64)
    newv = jnp.asarray(points[300:380])
    dmask = jnp.zeros((1024,), bool).at[jnp.arange(0, 300, 9)].set(True)
    pre_decoded = pqm.decode(lti.codebook, lti.codes, pq_cfg)
    pre_live = np.nonzero(np.asarray(lti.graph.active))[0]
    pre = _alpha_ok_fraction(lti.graph, pre_live, pre_decoded, cfg.alpha)
    merged, _ = streaming_merge(lti, newv, jnp.ones((80,), bool), dmask,
                                cfg, pq_cfg, insert_chunk=32, block=256)
    decoded = pqm.decode(merged.codebook, merged.codes, pq_cfg)
    live = np.nonzero(np.asarray(merged.graph.active))[0]
    post = _alpha_ok_fraction(merged.graph, live, decoded, cfg.alpha)
    assert post >= pre, (pre, post)


# ---------------------------------------------------------------------------
# 4. localized delete repair + the delete-path bugfix sweep
# ---------------------------------------------------------------------------

def test_localized_consolidate_bit_parity(points):
    """mode="local" gathers/repairs/scatters only the affected rows and
    must reproduce the global sweep bit-for-bit — whole adjacency, flags,
    and entry point, on both engine paths."""
    from repro.core.delete import affected_mask
    for uk in (False, True):
        cfg = _cfg(uk)
        g = mem.build(points[:300], cfg, batch=64)
        gd = delete(g, jnp.arange(0, 300, 13))
        aff = int(affected_mask(gd.adjacency, gd.deleted,
                                gd.active & ~gd.deleted).sum())
        assert 0 < aff < 300          # genuinely partial coverage
        a = consolidate_deletes(gd, cfg, mode="global")
        b = consolidate_deletes(gd, cfg, mode="local")
        np.testing.assert_array_equal(np.asarray(a.adjacency),
                                      np.asarray(b.adjacency))
        np.testing.assert_array_equal(np.asarray(a.active),
                                      np.asarray(b.active))
        assert int(a.start) == int(b.start)


def test_localized_consolidate_codes_bit_parity(points):
    """SDC flavor of the same contract (capped expansion, PQ distances)."""
    from repro.core import pq as pqm
    from repro.core.delete import consolidate_deletes_codes
    lti = build_lti(points[:300], _cfg(False), _pq(), batch=64)
    tables = pqm.sdc_tables(lti.codebook)
    for uk in (False, True):
        cfg = _cfg(uk)
        gd = delete(lti.graph, jnp.arange(0, 300, 13))
        a = consolidate_deletes_codes(gd, cfg, lti.codes, tables,
                                      block=256, mode="global")
        b = consolidate_deletes_codes(gd, cfg, lti.codes, tables,
                                      block=256, mode="local")
        np.testing.assert_array_equal(np.asarray(a.adjacency),
                                      np.asarray(b.adjacency))
        assert int(a.start) == int(b.start)


@pytest.mark.parametrize("use_sdc", [False, True])
def test_streaming_merge_local_parity(points, use_sdc):
    """A localized merge (eager Delete phase + jitted phases 2/3) must be
    bit-identical to the fused global merge."""
    cfg, pq_cfg = _cfg(False), _pq()
    lti = build_lti(points[:300], cfg, pq_cfg, batch=64)
    newv = jnp.asarray(points[300:400])
    valid = jnp.ones((100,), bool)
    dmask = jnp.zeros((1024,), bool).at[jnp.arange(0, 300, 11)].set(True)
    m_g, s_g = streaming_merge(lti, newv, valid, dmask, cfg, pq_cfg,
                               insert_chunk=32, block=256, use_sdc=use_sdc,
                               repair_mode="global")
    m_l, s_l = streaming_merge(lti, newv, valid, dmask, cfg, pq_cfg,
                               insert_chunk=32, block=256, use_sdc=use_sdc,
                               repair_mode="local")
    np.testing.assert_array_equal(np.asarray(m_g.graph.adjacency),
                                  np.asarray(m_l.graph.adjacency))
    np.testing.assert_array_equal(np.asarray(s_g.slots),
                                  np.asarray(s_l.slots))
    assert int(s_g.repair_cap_overflows) == int(s_l.repair_cap_overflows)


def test_localized_rows_satisfy_alpha_rng(points):
    """Post-condition: every row the localized pass repaired is a fresh
    RobustPrune output and satisfies the alpha-RNG invariant."""
    from repro.core.delete import affected_mask
    from repro.core.prune import check_alpha_rng_rows
    cfg = _cfg(False)
    g = mem.build(points[:300], cfg, batch=64)
    gd = delete(g, jnp.arange(0, 300, 7))
    aff = np.nonzero(np.asarray(affected_mask(
        gd.adjacency, gd.deleted, gd.active & ~gd.deleted)))[0]
    out = consolidate_deletes(gd, cfg, mode="local")
    oks = np.asarray(check_alpha_rng_rows(
        out.adjacency, jnp.asarray(aff.astype(np.int32)), out.vectors,
        cfg.alpha))
    assert oks.all()


def test_affected_mask_covers_changed_rows(points):
    """The rows the global sweep changes are exactly a subset of
    affected-set ∪ deleted — the localized mode's coverage guarantee."""
    from repro.core.delete import affected_mask
    cfg = _cfg(False)
    g = mem.build(points[:300], cfg, batch=64)
    gd = delete(g, jnp.arange(0, 300, 13))
    cover = np.asarray(affected_mask(
        gd.adjacency, gd.deleted, gd.active & ~gd.deleted)) \
        | np.asarray(gd.deleted)
    out = consolidate_deletes(gd, cfg, mode="global")
    changed = np.asarray((out.adjacency != gd.adjacency).any(axis=1))
    assert not (changed & ~cover).any()


def test_policy_a_repicks_inactive_start(points):
    """Regression: an already-inactive (not deleted) start slot must be
    re-picked by Policy A, not survive to seed searches from a dead node."""
    from repro.core.delete import consolidate_policy_a
    cfg = _cfg(False)
    g = mem.build(points[:300], cfg, batch=64)
    g = g._replace(active=g.active.at[g.start].set(False))
    out = consolidate_policy_a(g)
    assert int(out.start) != int(g.start)
    assert bool(out.active[out.start])


def test_delete_everything_then_reinsert(points):
    """Deleting 100%% of the points must leave the sentinel start (no
    garbage medoid of an all-false mask), searches must come back empty,
    and the next insert must re-seed the entry point."""
    cfg = _cfg(False)
    g = mem.build(points[:300], cfg, batch=64)
    gd = delete(g, jnp.arange(300, dtype=jnp.int32))
    for mode in ("global", "local"):
        out = consolidate_deletes(gd, cfg, mode=mode)
        assert int(out.start) == int(INVALID)
        assert not bool(out.active.any())
        ids, _, _, _ = mem.search(out, jnp.asarray(points[:4]), cfg,
                                  k=5, L=32)
        assert (np.asarray(ids) < 0).all()
    # re-insert into the emptied index: start re-seeds to the first slot
    out = consolidate_deletes(gd, cfg, mode="local")
    slots = jnp.arange(16, dtype=jnp.int32)
    st = mem.insert(out, slots, jnp.asarray(points[:16]), cfg)
    assert int(st.start) >= 0 and bool(st.active[st.start])
    ids, _, _, _ = mem.search(st, jnp.asarray(points[:4]), cfg, k=3, L=32)
    assert (np.asarray(ids)[:, 0] >= 0).all()


def test_merge_delete_everything_then_reinsert(points):
    """The merge Delete phase hitting 100%% of the LTI must hand phases
    2/3 the sentinel start, which then re-seeds from the first inserted
    slot — the merged LTI serves its new points."""
    cfg, pq_cfg = _cfg(False), _pq()
    lti = build_lti(points[:300], cfg, pq_cfg, batch=64)
    dmask = jnp.zeros((1024,), bool).at[jnp.arange(300)].set(True)
    newv = jnp.asarray(points[300:364])
    valid = jnp.ones((64,), bool)
    for mode in ("global", "local"):
        merged, stats = streaming_merge(lti, newv, valid, dmask, cfg,
                                        pq_cfg, insert_chunk=32, block=256,
                                        repair_mode=mode)
        g = merged.graph
        assert int(stats.n_deleted) == 300
        assert int(stats.n_inserted) == 64
        assert int(g.start) >= 0 and bool(g.active[g.start])
        assert int(g.active.sum()) == 64


def test_repair_cap_overflow_counter(points):
    """A node with more deleted out-neighbors than the SDC expansion cap
    must fire the overflow counter, and its repaired row must still shed
    every deleted edge (the keep-mask is uncapped)."""
    from repro.core import pq as pqm
    from repro.core.delete import (consolidate_deletes_codes,
                                   repair_cap_overflow)
    from repro.core.merge import SDC_REPAIR_CAP
    cfg = _cfg(False)
    lti = build_lti(points[:300], cfg, _pq(), batch=64)
    g = lti.graph
    # delete SDC_REPAIR_CAP+2 of one node's out-neighbors
    p = int(jnp.argmax((g.adjacency >= 0).sum(axis=1)))
    row = np.asarray(g.adjacency[p])
    victims = row[row >= 0][:SDC_REPAIR_CAP + 2].astype(np.int32)
    assert len(victims) == SDC_REPAIR_CAP + 2
    gd = delete(g, jnp.asarray(victims))
    usable = gd.active & ~gd.deleted
    n_over = int(repair_cap_overflow(gd.adjacency, gd.deleted, usable,
                                     SDC_REPAIR_CAP))
    assert n_over >= 1
    tables = pqm.sdc_tables(lti.codebook)
    out = consolidate_deletes_codes(gd, cfg, lti.codes, tables,
                                    block=256, cap=SDC_REPAIR_CAP)
    new_row = np.asarray(out.adjacency[p])
    assert not np.isin(new_row[new_row >= 0], victims).any()
    # ... and a pure-delete SDC merge surfaces the count in MergeStats
    dmask = jnp.zeros((1024,), bool).at[jnp.asarray(victims)].set(True)
    none = jnp.zeros((1, DIM), jnp.float32)
    _, stats = streaming_merge(lti, none, jnp.zeros((1,), bool), dmask,
                               cfg, _pq(), insert_chunk=32, block=256,
                               use_sdc=True)
    assert int(stats.repair_cap_overflows) == n_over
