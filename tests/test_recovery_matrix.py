"""Crash-recovery matrix (§5.6): snapshot + WAL-suffix replay across log
epochs.  Each case compares a crashed-and-recovered system against a
never-crashed twin that saw the identical op stream: ``size`` must match
exactly and search results must agree.

Matrix:
  * crash BEFORE any merge-truncate — plain snapshot + same-epoch suffix,
  * crash AFTER a merge-truncate — the merge snapshots (snapshot_dir) before
    truncating, the pre-merge epoch's offset is detected as stale via the
    epoch counter, and only the fresh epoch replays,
  * stale ``wal_offset`` pointing past EOF in the SAME epoch (a legacy
    truncation that reused the epoch counter) — recovery must fall back to
    replaying the whole log instead of seeking past the end,
  * snapshot with NO post-crash traffic (empty suffix).
"""
import os

import numpy as np
import pytest

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.system import FreshDiskANN, bootstrap_system
from repro.core.wal import log_epoch, truncate

from conftest import DIM

N0 = 300


def _cfg(tmp, wal="wal", snaps=None, merge_threshold=100_000, **kw):
    return SystemConfig(
        index=IndexConfig(capacity=2048, dim=DIM, R=24, L_build=32,
                          L_search=64, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=64, merge_threshold=merge_threshold,
        temp_capacity=512, insert_batch=32,
        wal_dir=str(tmp / wal) if wal else None,
        snapshot_dir=str(tmp / snaps) if snaps else None, **kw)


def _apply(sys_, ops):
    for op in ops:
        if op[0] == "i":
            sys_.insert(op[1], op[2])
        else:
            sys_.delete(op[1])


def _traffic(points, start, n, id0):
    return [("i", id0 + i, points[start + i]) for i in range(n)]


def _assert_twinned(recovered, twin, queries):
    assert recovered.size == twin.size
    ids_r, d_r = recovered.search(queries[:8], k=5)
    ids_t, d_t = twin.search(queries[:8], k=5)
    np.testing.assert_array_equal(ids_r, ids_t)
    np.testing.assert_array_equal(d_r, d_t)


def test_crash_before_merge_truncate(tmp_path, points, queries):
    """Snapshot, post-snapshot traffic, crash — the suffix past the recorded
    wal_offset replays (and pre-snapshot records are NOT double-applied)."""
    cfg = _cfg(tmp_path)
    live = bootstrap_system(points[:N0], np.arange(N0), cfg)
    twin = bootstrap_system(points[:N0], np.arange(N0),
                            _cfg(tmp_path, wal=None))
    pre = _traffic(points, N0, 40, 5000)
    _apply(live, pre)
    _apply(twin, pre)
    live.save(str(tmp_path / "snap"))
    post = _traffic(points, N0 + 40, 30, 6000) + [("d", 5003), ("d", 6002)]
    _apply(live, post)
    _apply(twin, post)

    crashed = FreshDiskANN(cfg)
    n = crashed.recover(str(tmp_path / "snap"))
    assert n == len(post)                  # suffix only, no double-apply
    _assert_twinned(crashed, twin, queries)
    assert 5003 in crashed.deleted_ext and 6002 in crashed.deleted_ext


def test_crash_after_merge_truncate(tmp_path, points, queries):
    """The threshold merge snapshots to snapshot_dir and truncates the log
    (epoch bump).  A crash afterwards recovers from the merge snapshot plus
    the fresh epoch only — nothing lost, nothing double-applied."""
    cfg = _cfg(tmp_path, snaps="snaps", merge_threshold=128)
    live = bootstrap_system(points[:N0], np.arange(N0), cfg)
    twin = bootstrap_system(points[:N0], np.arange(N0),
                            _cfg(tmp_path, wal=None, merge_threshold=128))
    pre = _traffic(points, N0, 160, 5000)   # crosses the merge threshold
    _apply(live, pre)
    _apply(twin, pre)
    assert live.stats.merges >= 1
    snap = live.latest_snapshot()
    assert snap and os.path.isdir(snap)
    # the log was truncated into a fresh epoch at the merge
    assert log_epoch(os.path.join(cfg.wal_dir, "wal.bin")) >= 1
    post = _traffic(points, N0 + 160, 25, 7000) + [("d", 7001)]
    _apply(live, post)
    _apply(twin, post)

    crashed = FreshDiskANN(cfg)
    n = crashed.recover()                  # auto-discovers the merge snapshot
    # The merge fires at staged == 128 (the 128th pre insert), so the fresh
    # epoch holds the 32 tail pre-inserts + the post records — and nothing
    # from before the truncation (no double-apply of the merged 128).
    assert n == (160 - 128) + len(post)
    _assert_twinned(crashed, twin, queries)


def test_stale_wal_offset_same_epoch(tmp_path, points, queries):
    """A recorded wal_offset past the log's EOF within the SAME epoch (a
    legacy truncation that did not bump the epoch counter): recovery must
    replay the whole log rather than seek past the end."""
    cfg = _cfg(tmp_path)
    live = bootstrap_system(points[:N0], np.arange(N0), cfg)
    _apply(live, _traffic(points, N0, 40, 5000))
    live.save(str(tmp_path / "snap"))      # records offset O1, epoch 0
    # Simulate the legacy truncation: restart the log file, SAME epoch, then
    # write fresh post-snapshot traffic into the now-shorter log.
    live.wal.close()
    wal_path = os.path.join(cfg.wal_dir, "wal.bin")
    truncate(wal_path, DIM, 0)
    assert log_epoch(wal_path) == 0
    live2 = FreshDiskANN.load(str(tmp_path / "snap"), cfg)
    post = _traffic(points, N0 + 40, 10, 8000)
    _apply(live2, post)                    # logs only the post records
    twin = FreshDiskANN.load(str(tmp_path / "snap"),
                             _cfg(tmp_path, wal=None))
    _apply(twin, post)

    crashed = FreshDiskANN(cfg)
    n = crashed.recover(str(tmp_path / "snap"))
    assert n == len(post)                  # full (short) log, not a seek past
    _assert_twinned(crashed, twin, queries)
    live2.wal.close()


def test_recover_with_empty_suffix(tmp_path, points, queries):
    """Snapshot with no traffic after it: recovery replays zero records and
    reproduces the snapshot state exactly."""
    cfg = _cfg(tmp_path)
    live = bootstrap_system(points[:N0], np.arange(N0), cfg)
    _apply(live, _traffic(points, N0, 40, 5000))
    live.save(str(tmp_path / "snap"))
    crashed = FreshDiskANN(cfg)
    n = crashed.recover(str(tmp_path / "snap"))
    assert n == 0
    _assert_twinned(crashed, live, queries)


def test_no_truncate_without_snapshot_dir(tmp_path, points):
    """Without snapshot_dir a merge must NOT truncate the WAL — the log is
    the only durable copy of the un-snapshotted records."""
    cfg = _cfg(tmp_path, merge_threshold=128)
    live = bootstrap_system(points[:N0], np.arange(N0), cfg)
    _apply(live, _traffic(points, N0, 160, 5000))
    assert live.stats.merges >= 1
    wal_path = os.path.join(cfg.wal_dir, "wal.bin")
    assert log_epoch(wal_path) == 0        # epoch never bumped
    live.wal.close()
    # Every streamed record is still in the log, so a full replay over a
    # fresh bootstrap (the static build is durable by construction)
    # reconstructs the whole stream — nothing was lost to the merge.
    crashed = bootstrap_system(points[:N0], np.arange(N0),
                               _cfg(tmp_path, merge_threshold=100_000))
    n = crashed.recover()
    assert n == 160
    twin = bootstrap_system(points[:N0], np.arange(N0),
                            _cfg(tmp_path, wal=None,
                                 merge_threshold=100_000))
    _apply(twin, _traffic(points, N0, 160, 5000))
    assert crashed.size == twin.size == N0 + 160


# ---------------------------------------------------------------------------
# Label/tenant durability: the same crash matrix with every inserted point
# carrying a label bitset + tenant id.  Labels ride the WAL as op-2 records,
# snapshots as LTI/temp side tables, and the decoupled layout's meta blobs —
# each crash epoch must reproduce them bit-for-bit (asserted through filtered
# search parity against the never-crashed twin, plus direct table equality).
# ---------------------------------------------------------------------------
N_TEN = 3


def _labeled_traffic(points, start, n, id0):
    return [("il", id0 + i, points[start + i], [i % 4], (id0 + i) % N_TEN)
            for i in range(n)]


def _apply_labeled(sys_, ops):
    for op in ops:
        if op[0] == "il":
            sys_.insert(op[1], op[2], labels=op[3], tenant=op[4])
        elif op[0] == "i":
            sys_.insert(op[1], op[2])
        else:
            sys_.delete(op[1])


def _label_map(sys_):
    """ext_id -> (tenant, bits) across every live tier — the durability
    ground truth, independent of which tier a copy landed in."""
    sys_._flush_inserts()
    out = {}
    tiers = [(sys_.lti_ext_ids, sys_.lti_labels)]
    tiers += [(t.ext_ids, t.labels) for t in [sys_.rw] + list(sys_.ro)]
    for ext, tab in tiers:
        for slot in np.nonzero(ext >= 0)[0]:
            e = int(ext[slot])
            if e in sys_.deleted_ext:
                continue
            out[e] = (int(tab.tenant[slot]), tuple(tab.bits[slot].tolist()))
    return out


def _assert_filter_twinned(recovered, twin, queries):
    """Unfiltered searches stay bit-twinned; labels are compared as exact
    per-id maps rather than through filtered-search bit-parity — recovery
    replays the suffix into a FRESH RW tier, so the recovered system's
    temp-tier split can legitimately differ from the twin's rollover
    history, which shifts kk-deep (post-filter-visible) candidates without
    any label having been lost.  Filtered results must still be leak-free
    on both systems."""
    from repro.core.graph import FilterSpec, filter_match, LabelTable
    _assert_twinned(recovered, twin, queries)
    m_r, m_t = _label_map(recovered), _label_map(twin)
    assert m_r == m_t, "label/tenant tables diverged after recovery"
    for spec in (FilterSpec(tenant=1), FilterSpec(all_of=(2,)),
                 FilterSpec(all_of=(0,), tenant=0)):
        for sys_ in (recovered, twin):
            ids, _ = sys_.search_batch(queries[:8], 5, filter=spec)
            for row in np.asarray(ids):
                for e in (int(x) for x in row if x >= 0):
                    ten, bits = m_r[e]
                    tab = LabelTable(1, len(bits),
                                     bits=np.asarray([bits], np.uint32),
                                     tenant=np.asarray([ten], np.int32))
                    assert filter_match(tab, spec)[0], (
                        f"filter leak after recovery: id {e} vs {spec}")


def _boot_labeled(points, cfg):
    return bootstrap_system(
        points[:N0], np.arange(N0), cfg,
        labels=[[i % 4] for i in range(N0)],
        tenants=[i % N_TEN for i in range(N0)])


def test_labels_survive_wal_replay(tmp_path, points, queries):
    """Crash before any merge: labeled op-2 records in the WAL suffix replay
    with their bitsets and tenants intact."""
    cfg = _cfg(tmp_path, filter_words=1)
    live = _boot_labeled(points, cfg)
    twin = _boot_labeled(points, _cfg(tmp_path, wal=None, filter_words=1))
    pre = _labeled_traffic(points, N0, 40, 5000)
    _apply_labeled(live, pre)
    _apply_labeled(twin, pre)
    live.save(str(tmp_path / "snap"))
    post = _labeled_traffic(points, N0 + 40, 30, 6000) + [("d", 5003)]
    _apply_labeled(live, post)
    _apply_labeled(twin, post)

    crashed = FreshDiskANN(cfg)
    n = crashed.recover(str(tmp_path / "snap"))
    assert n == len(post)
    _assert_filter_twinned(crashed, twin, queries)
    np.testing.assert_array_equal(crashed.lti_labels.bits,
                                  twin.lti_labels.bits)
    np.testing.assert_array_equal(crashed.lti_labels.tenant,
                                  twin.lti_labels.tenant)


def test_labels_survive_merge_truncate(tmp_path, points, queries):
    """Crash after a snapshot-before-truncate merge: the merged LTI's label
    side tables (scattered to merge-assigned slots) plus the fresh-epoch
    op-2 suffix reproduce every bitset."""
    cfg = _cfg(tmp_path, snaps="snaps", merge_threshold=128, filter_words=1)
    live = _boot_labeled(points, cfg)
    twin = _boot_labeled(points, _cfg(tmp_path, wal=None,
                                      merge_threshold=128, filter_words=1))
    pre = _labeled_traffic(points, N0, 160, 5000)  # crosses the threshold
    _apply_labeled(live, pre)
    _apply_labeled(twin, pre)
    assert live.stats.merges >= 1
    post = _labeled_traffic(points, N0 + 160, 25, 7000) + [("d", 7001)]
    _apply_labeled(live, post)
    _apply_labeled(twin, post)

    crashed = FreshDiskANN(cfg)
    n = crashed.recover()
    assert n == (160 - 128) + len(post)
    _assert_filter_twinned(crashed, twin, queries)
    # The merge moved labeled points INTO the LTI: their slots carry bits.
    merged = np.isin(crashed.lti_ext_ids, [op[1] for op in pre])
    assert merged.any()
    assert (crashed.lti_labels.tenant[merged] >= 0).all()


def test_labels_survive_decoupled_layout(tmp_path, points, queries):
    """Crash after a decoupled-layout merge snapshot: labels ride the
    layout's meta side tables and come back through open_layout — filtered
    search agrees with the twin on the in-memory AND the disk path."""
    from repro.core.graph import FilterSpec
    from repro.storage.layout import open_layout

    cfg = _cfg(tmp_path, snaps="snaps", merge_threshold=128,
               storage_dir=str(tmp_path / "store"), adjacency_cache_mb=0,
               filter_words=1)
    live = _boot_labeled(points, cfg)
    twin = _boot_labeled(points, _cfg(tmp_path, wal=None,
                                      merge_threshold=128, filter_words=1))
    pre = _labeled_traffic(points, N0, 160, 5000)
    _apply_labeled(live, pre)
    _apply_labeled(twin, pre)
    assert live.stats.merges >= 1
    snap = live.latest_snapshot()
    lay = open_layout(os.path.join(snap, "layout"))
    assert lay.label_bits is not None and lay.label_tenant is not None
    post = _labeled_traffic(points, N0 + 160, 25, 7000)
    _apply_labeled(live, post)
    _apply_labeled(twin, post)
    live.close_storage()
    live.wal.close()

    crashed = FreshDiskANN(cfg)
    n = crashed.recover()
    assert n == (160 - 128) + len(post)
    _assert_filter_twinned(crashed, twin, queries)
    # Disk path, filtered: validity straight off the recovered layout.
    ids_d, _ = crashed.search_disk(queries[:8], 5,
                                   filter=FilterSpec(tenant=1))
    for row in np.asarray(ids_d):
        for e in (int(x) for x in row if x >= 0):
            assert e % N_TEN == 1, f"disk-path tenant leak after crash: {e}"
    crashed.close_storage()


def test_recover_from_decoupled_layout_snapshot(tmp_path, points, queries):
    """With ``storage_dir`` set, the merge snapshot saves the LTI as the
    decoupled on-disk layout (``layout/`` directory) instead of a monolithic
    ``lti.npz`` — and ``recover()`` auto-detects the format, replays the
    suffix, and serves bit-identically to a never-crashed twin, on both the
    in-memory and the disk read path."""
    from repro.storage.layout import is_layout

    cfg = _cfg(tmp_path, snaps="snaps", merge_threshold=128,
               storage_dir=str(tmp_path / "store"), adjacency_cache_mb=0)
    live = bootstrap_system(points[:N0], np.arange(N0), cfg)
    twin = bootstrap_system(points[:N0], np.arange(N0),
                            _cfg(tmp_path, wal=None, merge_threshold=128))
    pre = _traffic(points, N0, 160, 5000)   # crosses the merge threshold
    _apply(live, pre)
    _apply(twin, pre)
    assert live.stats.merges >= 1
    snap = live.latest_snapshot()
    assert snap and os.path.isdir(snap)
    # The decoupled format, not the npz blob.
    assert is_layout(os.path.join(snap, "layout"))
    assert not os.path.exists(os.path.join(snap, "lti.npz"))
    post = _traffic(points, N0 + 160, 25, 7000) + [("d", 7001)]
    _apply(live, post)
    _apply(twin, post)
    live.close_storage()
    live.wal.close()

    crashed = FreshDiskANN(cfg)
    n = crashed.recover()                  # auto-discovers the merge snapshot
    assert n == (160 - 128) + len(post)
    _assert_twinned(crashed, twin, queries)
    # The recovered system re-synced its live layout under storage_dir:
    # the disk read path agrees bit-for-bit with the in-memory engine.
    ids_m, d_m = crashed.search_batch(queries[:8], k=5)
    ids_d, d_d = crashed.search_disk(queries[:8], k=5)
    np.testing.assert_array_equal(ids_m, ids_d)
    np.testing.assert_array_equal(d_m, d_d)
    crashed.close_storage()
