"""The continuous-batching scheduler (serving/scheduler.py) under a
virtual clock: every policy decision — close vs wait, admit vs shed, miss
vs meet — is deterministic because the policy core consults only the
injected clock.  The suite pins the batch-close invariants (a batch never
exceeds `batch_queries`; an admitted request's wait never exceeds its SLO
budget when the driver polls at `next_close_time`; an empty queue never
dispatches), bit-parity of scheduled results vs direct `search_batch` on
randomized ragged arrival traces, the backpressure/shed contract, and the
Reservoir percentile machinery behind the serving stats."""
import numpy as np
import pytest

from repro.core.system import Reservoir
from repro.serving import BatchScheduler, VirtualClock, WallClock

from conftest import DIM
from test_serving import _sys_cfg, _three_tier_system

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _sched_system(points, *, slo_ms=50.0, batch_queries=8, capacity=1024,
                  est_ms=5.0, **kw):
    clk = VirtualClock()
    sys_ = _three_tier_system(
        points, batch_queries=batch_queries, slo_ms=slo_ms,
        serve_queue_capacity=capacity, dispatch_estimate_ms=est_ms,
        clock=clk, **kw)
    return sys_, clk


def _advance(clk, sched, dt):
    """Advance the virtual clock by ``dt``, stopping at every intermediate
    batch-close time to run the scheduler — the deterministic equivalent of
    the wall-clock worker waking at ``next_close_time``."""
    target = clk.now() + dt
    while True:
        nct = sched.next_close_time()
        if nct is None or nct > target:
            break
        if nct > clk.now():
            clk.advance(nct - clk.now())
        if sched.run_once() == 0:
            break
    if target > clk.now():
        clk.advance(target - clk.now())


def _pump(sched):
    while sched.run_once():
        pass


# ------------------------------------------------------ close invariants

def test_full_batch_closes_immediately(points, queries):
    """Fill-to-width close: a full queue closes NOW, never overfills, and
    the partial remainder waits for its deadline."""
    sys_, clk = _sched_system(points)
    sizes = []
    ref = sys_.search_batch

    def serve(qs, k, L=None, beam_width=None):
        sizes.append(len(qs))
        return ref(qs, k, L=L, beam_width=beam_width)

    sched = BatchScheduler(sys_, k=5, serve=serve)
    assert sched.clock is clk        # injected via SystemConfig.clock
    for q in queries[:19]:
        sched.submit(q)
        _pump(sched)
    assert sizes == [8, 8]           # two full closes, 3 still queued
    assert sched.pending == 3
    assert sched.next_close_time() == pytest.approx(
        clk.now() + 0.050 - sched.dispatch_estimate)
    _advance(clk, sched, 1.0)
    assert sizes == [8, 8, 3]        # deadline close drained the tail
    assert max(sizes) <= sys_.cfg.batch_queries
    assert sys_.stats.deadline_misses == 0


def test_deadline_close_bounds_wait(points, queries):
    """SLO-budget invariant: driving the scheduler at `next_close_time`,
    no admitted request waits past its deadline (dispatch is instant on the
    virtual clock, and the close fires `dispatch_estimate` early)."""
    sys_, clk = _sched_system(points, slo_ms=20.0)
    sched = BatchScheduler(sys_, k=5)
    tickets = []
    for i, q in enumerate(queries[:7]):     # never fills the width of 8
        tickets.append(sched.submit(q))
        _advance(clk, sched, 0.003)
    _advance(clk, sched, 0.050)
    for t in tickets:
        assert t.done.is_set()
        assert t.latency <= 0.020 + 1e-12
        assert not t.missed
    assert sys_.stats.deadline_misses == 0
    # Batches closed on deadlines, not on fill: more than one dispatch.
    assert sys_.stats.batches_dispatched >= 2


def test_empty_queue_never_dispatches(points):
    """An empty queue has no close time and `run_once` is a no-op at any
    clock value — a deadline close never fires on nothing."""
    sys_, clk = _sched_system(points)
    sched = BatchScheduler(sys_, k=5)
    assert sched.next_close_time() is None
    assert sched.run_once() == 0
    clk.advance(10.0)
    assert sched.run_once() == 0
    assert sys_.stats.batches_dispatched == 0
    assert sched.flush() == 0


def test_no_slo_closes_only_on_fill(points, queries):
    """slo_ms=0 disables deadline closes: a partial batch sits until the
    queue fills or `flush` drains it."""
    sys_, clk = _sched_system(points, slo_ms=0.0)
    sched = BatchScheduler(sys_, k=5)
    for q in queries[:5]:
        sched.submit(q)
    assert sched.next_close_time() is None
    clk.advance(1e6)
    assert sched.run_once() == 0 and sched.pending == 5
    assert sched.flush() == 5
    assert sys_.stats.deadline_misses == 0   # no SLO -> nothing to miss


def test_deadline_miss_is_counted(points, queries):
    """A request completing past its deadline (the driver polled late) is
    served anyway and counted in `deadline_misses` with `missed` set."""
    sys_, clk = _sched_system(points, slo_ms=10.0)
    sched = BatchScheduler(sys_, k=5)
    t = sched.submit(queries[0])
    clk.advance(0.100)               # blow straight past the deadline
    assert sched.run_once() == 1
    assert t.missed and t.done.is_set()
    assert sys_.stats.deadline_misses == 1


def test_dispatch_estimate_ewma_is_deterministic(points, queries):
    """Under a virtual clock a dispatch measures 0 s, so the EWMA estimate
    decays as 0.8^n of its seed — the close-time policy is a pure function
    of the trace."""
    sys_, clk = _sched_system(points, est_ms=10.0)
    sched = BatchScheduler(sys_, k=5)
    assert sched.dispatch_estimate == pytest.approx(0.010)
    for q in queries[:16]:
        sched.submit(q)
    _pump(sched)                     # two full-width dispatches
    assert sched.dispatch_estimate == pytest.approx(0.010 * 0.8 ** 2)


def test_virtual_clock_only_advances():
    clk = VirtualClock(5.0)
    assert clk.now() == 5.0
    assert clk.advance(1.5) == 6.5
    with pytest.raises(ValueError):
        clk.advance(-0.1)
    assert isinstance(WallClock().now(), float)


# ------------------------------------------------------------- bit-parity

def test_scheduled_results_match_direct_search(points, queries, rng):
    """The de-interleave contract on randomized ragged traces: every
    scheduled request's (ids, dists) row is bit-identical to calling
    `search_batch` directly, whatever batches the arrivals landed in."""
    sys_, clk = _sched_system(points, batch_queries=4)
    for e in (0, 5, 2000, 2149):     # deletes in every tier, as in the
        sys_.delete(e)               # serving parity suite
    ref_ids, ref_d = sys_.search_batch(queries, k=5)
    sched = BatchScheduler(sys_, k=5)
    tickets, qi = [], 0
    while qi < len(queries):
        group = int(rng.integers(0, 4))
        for _ in range(min(group, len(queries) - qi)):
            tickets.append((qi, sched.submit(queries[qi])))
            qi += 1
            _pump(sched)
        _advance(clk, sched, float(rng.integers(0, 30)) / 1e3)
    _advance(clk, sched, 1.0)
    sched.flush()
    for i, t in tickets:
        assert t is not None and t.done.is_set()
        np.testing.assert_array_equal(t.ids, ref_ids[i])
        np.testing.assert_array_equal(t.dists, ref_d[i])


def test_worker_thread_serves_on_wall_clock(points, queries):
    """The threaded loop end-to-end (wall clock, no injected clock):
    submitted requests complete with the same rows as direct search."""
    sys_ = _three_tier_system(points, batch_queries=4, slo_ms=10.0)
    ref_ids, ref_d = sys_.search_batch(queries[:6], k=5)
    sched = BatchScheduler(sys_, k=5)
    sched.start()
    try:
        tickets = [sched.submit(q) for q in queries[:6]]
        for i, t in enumerate(tickets):
            ids, d = t.result(timeout=60.0)
            np.testing.assert_array_equal(ids, ref_ids[i])
            np.testing.assert_array_equal(d, ref_d[i])
    finally:
        sched.stop()


# ----------------------------------------------------------- backpressure

def test_backpressure_sheds_beyond_capacity(points, queries):
    """The bounded-queue contract: submissions past capacity return None
    and count in `shed_requests`; nothing else is dropped, and capacity
    frees as batches dispatch."""
    sys_, clk = _sched_system(points, capacity=6, slo_ms=0.0)
    sched = BatchScheduler(sys_, k=5)
    outs = [sched.submit(q) for q in queries[:10]]
    assert [t is None for t in outs] == [False] * 6 + [True] * 4
    assert sys_.stats.shed_requests == 4
    assert sys_.stats.scheduled_requests == 6
    assert sys_.stats.queue_depth == 6
    assert sched.flush() == 6        # shed requests are NOT in the queue
    assert sys_.stats.queue_depth == 0
    assert sched.submit(queries[0]) is not None   # capacity freed
    for t in outs[:6]:
        assert t.done.is_set()       # admitted requests were all served


# ------------------------------------------- filtered / multi-tenant

def _labeled_sched_system(points, **kw):
    """Labeled three-tier system behind a virtual clock: every point owns
    a tenant (id parity) and label bit 0, for filtered-ticket tests."""
    from repro.core.system import bootstrap_system
    clk = VirtualClock()
    cfg = _sys_cfg(batch_queries=4, slo_ms=50.0, serve_queue_capacity=64,
                   dispatch_estimate_ms=5.0, clock=clk, filter_words=1,
                   **kw)
    sys_ = bootstrap_system(points[:400], np.arange(400), cfg,
                            labels=[[0] for _ in range(400)],
                            tenants=[i % 2 for i in range(400)])
    for i in range(60):
        sys_.insert(2000 + i, points[500 + i], labels=[0], tenant=i % 2)
    return sys_, clk


def test_mixed_filter_batches_deinterleave(points, queries):
    """Tickets with different FilterSpecs never share a micro-batch: the
    scheduler groups on the OLDEST ticket's spec, preserving per-spec FIFO,
    and every served row is bit-identical to a direct filtered
    ``search_batch`` on that ticket's own query."""
    from repro.core.graph import FilterSpec
    sys_, clk = _labeled_sched_system(points)
    served = []
    ref = sys_.search_batch

    def serve(qs, k, L=None, beam_width=None, **kw):
        served.append((len(qs), kw.get("filter")))
        return ref(qs, k, L=L, beam_width=beam_width, **kw)

    sched = BatchScheduler(sys_, k=5, serve=serve)
    spec0, spec1 = FilterSpec(tenant=0), FilterSpec(tenant=1)
    plan = [spec0, spec1, spec0, None, spec1, spec0, None, spec1]
    tickets = [(sched.submit(queries[i], filter=s), s)
               for i, s in enumerate(plan)]
    _advance(clk, sched, 1.0)
    assert sched.flush() >= 0 and sched.pending == 0
    # every batch was single-spec, and per-spec arrival order was kept
    specs_served = [s for _, s in served]
    assert all(n <= 4 for n, _ in served)
    assert sorted(specs_served, key=str) == sorted(
        [spec0, spec1, None], key=str)       # one batch per distinct spec
    for (t, s), q in zip(tickets, queries):
        assert t is not None and t.done.is_set()
        kw = {"filter": s} if s is not None else {}
        ids, d = ref(q[None, :], 5, **kw)
        np.testing.assert_array_equal(t.ids, np.asarray(ids)[0])
        np.testing.assert_array_equal(t.dists, np.asarray(d)[0])
        if s is not None:                    # zero cross-tenant rows
            for e in (int(x) for x in t.ids if x >= 0):
                owner = (e % 2) if e < 2000 else ((e - 2000) % 2)
                assert owner == s.tenant


def test_tenant_quota_sheds_counted(points, queries):
    """``cfg.tenant_quota`` bounds one tenant's queued tickets: the excess
    is shed (None) and counted per tenant in ``tenant_sheds`` as well as
    ``shed_requests``; other tenants are untouched, and the quota frees as
    the tenant's batches dispatch."""
    from repro.core.graph import FilterSpec
    sys_, clk = _labeled_sched_system(points, tenant_quota=2)
    sched = BatchScheduler(sys_, k=5)
    spec0, spec1 = FilterSpec(tenant=0), FilterSpec(tenant=1)
    outs0 = [sched.submit(queries[i], filter=spec0) for i in range(4)]
    assert [t is None for t in outs0] == [False, False, True, True]
    assert sys_.stats.tenant_sheds == {0: 2}
    assert sys_.stats.shed_requests == 2
    # another tenant has its own quota — unaffected by tenant 0's sheds
    outs1 = [sched.submit(queries[4 + i], filter=spec1) for i in range(2)]
    assert all(t is not None for t in outs1)
    assert sys_.stats.tenant_sheds == {0: 2}
    # unfiltered traffic is never quota-shed
    assert sched.submit(queries[6]) is not None
    sched.flush()                            # drains tenant 0's tickets
    assert sched.submit(queries[7], filter=spec0) is not None
    assert sys_.stats.tenant_sheds == {0: 2}     # no new sheds


# ------------------------------------------------- hypothesis property

if HAVE_HYPOTHESIS:

    @given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 3)),
                    min_size=1, max_size=8),
           st.integers(5, 40))
    @settings(max_examples=10, deadline=None)
    def test_property_random_traces_hold_invariants(points, queries,
                                                    trace, slo_ms):
        """Random (inter-arrival ms, burst size) interleavings with random
        SLOs: batches never overfill, no admitted wait exceeds the budget,
        every request is served exactly once with rows bit-identical to
        direct search, and the accounting adds up."""
        sys_, clk = _sched_system(points, batch_queries=4,
                                  slo_ms=float(slo_ms))
        ref_ids, ref_d = sys_.search_batch(queries, k=5)
        sizes = []
        ref = sys_.search_batch

        def serve(qs, k, L=None, beam_width=None):
            sizes.append(len(qs))
            return ref(qs, k, L=L, beam_width=beam_width)

        sched = BatchScheduler(sys_, k=5, serve=serve)
        tickets, qi = [], 0
        for gap_ms, burst in trace:
            _advance(clk, sched, gap_ms / 1e3)
            for _ in range(burst):
                if qi >= len(queries):
                    break
                tickets.append((qi, sched.submit(queries[qi])))
                qi += 1
                _pump(sched)
        _advance(clk, sched, slo_ms / 1e3 + 1.0)
        assert sched.pending == 0    # every deadline has passed
        assert sizes and max(sizes) <= 4
        assert sum(sizes) == len(tickets)
        assert sys_.stats.deadline_misses == 0
        for i, t in tickets:
            assert t.done.is_set()
            assert t.latency <= slo_ms / 1e3 + 1e-12
            np.testing.assert_array_equal(t.ids, ref_ids[i])
            np.testing.assert_array_equal(t.dists, ref_d[i])


# ----------------------------------------------------- reservoir contract

def test_reservoir_exact_percentiles_when_unsaturated():
    """While seen <= size the reservoir holds the whole stream, so the
    percentile snapshot is exact: p50/p99 of 0..100 are 50 and 99."""
    r = Reservoir(size=1024)
    for x in np.random.default_rng(0).permutation(101):
        r.record(float(x))
    assert r.percentile(50.0) == 50.0
    assert r.percentile(99.0) == 99.0
    snap = r.snapshot()
    assert snap == {"p50": 50.0, "p99": 99.0, "n": 101}


def test_reservoir_empty_is_nan():
    r = Reservoir(size=8)
    assert np.isnan(r.percentile(50.0))
    assert r.snapshot()["n"] == 0


def test_reservoir_uniformity_smoke():
    """Vitter's R keeps each stream element with probability size/seen: the
    retained sample of the stream 0..9999 should look uniform — its mean
    within a few sigma of the stream mean, occupancy exactly `size`."""
    r = Reservoir(size=64, seed=3)
    n = 10_000
    for x in range(n):
        r.record(float(x))
    assert len(r.sample) == 64 and r.seen == n
    mean, mid = np.mean(r.sample), (n - 1) / 2
    sigma = (n / np.sqrt(12)) / np.sqrt(64)
    assert abs(mean - mid) < 4 * sigma
    # and the early prefix was not pinned: some late elements made it in.
    assert max(r.sample) > n * 0.8 and min(r.sample) < n * 0.2


def test_search_latency_sampled_per_dispatched_microbatch(points, queries):
    """The bench contract: every dispatched micro-batch is one sample in
    `stats.search_latency` (10 queries at width 4 -> 3 samples), and a
    no-op empty request adds none."""
    sys_ = _three_tier_system(points, batch_queries=4)
    assert sys_.stats.search_latency.seen == 0
    sys_.search_batch(queries[:10], k=5)
    assert sys_.stats.search_latency.seen == 3
    sys_.search_batch(np.zeros((0, DIM), np.float32), k=5)
    assert sys_.stats.search_latency.seen == 3
    snap = sys_.stats.serving_snapshot()
    assert snap["search"]["n"] == 3
    assert snap["search"]["p50"] > 0.0
