"""Paper §6.2 "I/O Cost of Search": IO rounds (hops — the SSD round-trip
proxy) and distance computations per query — a tiny fraction of brute force.
The beam-width sweep shows the hop/cmp trade-off: W concurrent reads per
round cut rounds ~W-fold at slightly higher cmp counts.

The sweep's measured (hops, cmps) points feed the beam-width autotuner
(``repro.core.autotune``): the emitted ``autotune_pick_L*`` records show
which W the cost model selects at each candidate-list size — the same
choice ``FreshDiskANN`` makes at serve time under ``autotune_beam``.

The disk section re-runs the sweep against the decoupled storage layout
(``repro.storage``, guide: docs/STORAGE.md) and measures what the
in-memory engine can't: actual bytes off ``topology.bin``, block
read-amplification, and the wall-time effect of the async prefetch
pipeline.  Page-cached mmap reads cost ~0 here, so the device is
simulated at ``DISK_LATENCY_US`` per queue submission
(``SystemConfig.io_latency_us``) — the ``d0`` rows are the demand-only
baseline and the ``d1``/``d2`` rows show the prefetch overlap win
(``speedup_vs_d0`` > 1).  A ``lat0`` row records the raw no-latency
callback overhead for honesty.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

import jax.numpy as jnp

from repro.core.autotune import BeamPoint, pick_beam_width
from repro.core.lti import build_lti, search_lti, write_lti_layout
from repro.storage import DiskLTISearcher

from .common import dataset, default_cfg, default_pq, emit, locality_stream, \
    queryset, timed, write_bench_json

# Simulated per-queue-submission device latency for the disk rows (us).
# ~500us is a pessimistic SATA-class read; at 0 the page-cached mmap makes
# prefetch overlap unmeasurable (its thread overhead still shows).
DISK_LATENCY_US = 500.0


def _disk_sweep(lti, cfg, q, quick: bool):
    """Disk rows: per (L, W, prefetch_depth) wall time + IO accounting."""
    with tempfile.TemporaryDirectory() as td:
        layout = write_lti_layout(os.path.join(td, "layout"), lti)
        row_bytes = layout.row_bytes
        grid = [(48, 2)] if quick else [(48, 1), (48, 2), (96, 2)]
        for L, W in grid:
            base = None
            for depth in (0, 1, 2):
                s = DiskLTISearcher(layout, cfg, cache_mb=0,
                                    prefetch_depth=depth,
                                    latency_us=DISK_LATENCY_US)
                s.search(q, k=5, L=L, beam_width=W)     # compile + warm
                before = s.stats.snapshot()
                out, secs = timed(s.search, q, k=5, L=L, beam_width=W,
                                  repeats=2)
                after = s.stats.snapshot()
                d = {k: after[k] - before[k] for k in before}
                reads = int(np.asarray(out[4]).sum())
                served = d["demand_reads"] + d["prefetch_hits"]
                hit = d["prefetch_hits"] / served if served else 0.0
                amp = (d["bytes_read"] / (served * row_bytes)
                       if served else 0.0)
                if depth == 0:
                    base = secs
                emit(f"disk_L{L}_W{W}_d{depth}", secs / len(q),
                     "reads=%d bytes=%d amp=%.2f hit=%.2f speedup=%.2fx" % (
                         reads, d["bytes_read"] // 2, amp, hit, base / secs),
                     L=L, W=W, prefetch_depth=depth,
                     latency_us=DISK_LATENCY_US, n_reads=reads,
                     bytes_read=d["bytes_read"] // 2,   # per repeat
                     read_amplification=amp, prefetch_hit_rate=hit,
                     speedup_vs_d0=base / secs)
                s.close()
        # No-latency demand-only row: the raw callback/mmap overhead floor.
        s = DiskLTISearcher(layout, cfg, cache_mb=0, prefetch_depth=0)
        s.search(q, k=5, L=48, beam_width=2)
        out, secs = timed(s.search, q, k=5, L=48, beam_width=2, repeats=2)
        emit("disk_L48_W2_d0_lat0", secs / len(q), "no simulated latency",
             L=48, W=2, prefetch_depth=0, latency_us=0.0)
        s.close()
        layout.close()


def _storage_delta_sweep(quick: bool):
    """IO cost of UPDATES: what each streaming merge writes back through
    the DGAI-style delta patch (``storage.layout.patch_layout``) on the
    clustered-expiry stream, arrival order vs locality-scheduled.

    ``storage_delta_*`` rows report, summed over the stream: adjacency
    rows rewritten, DISTINCT 4KB topology blocks dirtied (the real SSD
    write granularity — this is where proximity-ordered slot placement
    pays), and total bytes written.  The wall column is the merge compute,
    not the patch (the disk rows above cover read-path wall)."""
    import jax
    cycles, per, cap, ndel = ((4, 192, 8192, 48) if quick
                              else (6, 512, 16384, 96))
    base_blocks = None
    for loc in (False, True):
        jax.clear_caches()
        with tempfile.TemporaryDirectory() as td:
            recs = locality_stream(cycles, per, ndel, loc, cap=cap,
                                   layout_path=os.path.join(td, "layout"))
        rows = sum(r["adj_rows"] for r in recs)
        blocks = sum(r["adj_blocks"] for r in recs)
        byts = sum(r["bytes_written"] for r in recs)
        block_bytes = blocks * 4096          # what the SSD actually commits
        wall = sum(r["wall"] for r in recs[3:])
        extra = ({} if base_blocks is None
                 else {"blocks_vs_arrival": blocks / base_blocks})
        if base_blocks is None:
            base_blocks = max(1, blocks)
        tag = "on" if loc else "off"
        emit(f"storage_delta_{tag}", wall,
             f"cycles={cycles} adj_rows={rows} adj_blocks={blocks} "
             f"bytes={byts}",
             cycles=cycles, staged_per_cycle=per, adj_rows=rows,
             adj_blocks=blocks, bytes_written=byts,
             block_bytes_written=block_bytes, locality=int(loc),
             **extra)


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts, q = dataset(n), queryset()
    cfg, pq = default_cfg(n), default_pq()
    lti = build_lti(pts, cfg, pq)
    for L in ((48,) if quick else (32, 48, 64, 96)):
        sweep = []
        for W in ((1, 4) if quick else (1, 2, 4)):
            def s():
                return search_lti(lti, jnp.asarray(q), cfg, k=5, L=L,
                                  beam_width=W)

            (ids, d, hops, cmps), secs = timed(s)
            h, c = float(hops.mean()), float(cmps.mean())
            sweep.append(BeamPoint(W=W, hops=h, cmps=c, seconds=secs))
            emit(f"io_cost_L{L}_W{W}", secs / len(q),
                 "hops=%.0f cmps=%.0f frac_of_bruteforce=%.4f" % (
                     h, c, c / n),
                 L=L, W=W, hops=h, cmps=c, frac_of_bruteforce=c / n)
        best = pick_beam_width(sweep)
        emit(f"autotune_pick_L{L}", 0.0, f"W={best}", L=L, W=best)

    _disk_sweep(lti, cfg, q, quick)
    _storage_delta_sweep(quick)
    write_bench_json("io_cost", quick=quick, n=n,
                     disk_latency_us=DISK_LATENCY_US)


if __name__ == "__main__":
    main()
