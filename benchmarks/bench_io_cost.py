"""Paper §6.2 "I/O Cost of Search": IO rounds (hops — the SSD round-trip
proxy) and distance computations per query — a tiny fraction of brute force.
The beam-width sweep shows the hop/cmp trade-off: W concurrent reads per
round cut rounds ~W-fold at slightly higher cmp counts.

The sweep's measured (hops, cmps) points feed the beam-width autotuner
(``repro.core.autotune``): the emitted ``autotune_pick_L*`` records show
which W the cost model selects at each candidate-list size — the same
choice ``FreshDiskANN`` makes at serve time under ``autotune_beam``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.autotune import BeamPoint, pick_beam_width
from repro.core.lti import build_lti, search_lti

from .common import dataset, default_cfg, default_pq, emit, queryset, timed, \
    write_bench_json


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts, q = dataset(n), queryset()
    cfg, pq = default_cfg(n), default_pq()
    lti = build_lti(pts, cfg, pq)
    for L in ((48,) if quick else (32, 48, 64, 96)):
        sweep = []
        for W in ((1, 4) if quick else (1, 2, 4)):
            def s():
                return search_lti(lti, jnp.asarray(q), cfg, k=5, L=L,
                                  beam_width=W)

            (ids, d, hops, cmps), secs = timed(s)
            h, c = float(hops.mean()), float(cmps.mean())
            sweep.append(BeamPoint(W=W, hops=h, cmps=c, seconds=secs))
            emit(f"io_cost_L{L}_W{W}", secs / len(q),
                 "hops=%.0f cmps=%.0f frac_of_bruteforce=%.4f" % (
                     h, c, c / n),
                 L=L, W=W, hops=h, cmps=c, frac_of_bruteforce=c / n)
        best = pick_beam_width(sweep)
        emit(f"autotune_pick_L{L}", 0.0, f"W={best}", L=L, W=best)

    write_bench_json("io_cost", quick=quick, n=n)


if __name__ == "__main__":
    main()
