"""Paper Fig. 7: scaling.  Thread-count scaling becomes batch-size scaling
(the TPU's parallelism axis): search throughput vs query batch, merge runtime
vs block size (the paper's merge-thread knob), the beamwidth sweep (§6.2):
IO rounds vs recall as W grows — hops drop ~W-fold while recall holds — the
multi-tier fan-out sweep (system QPS vs RO-snapshot count, batched vs the
sequential per-tier loop), and the serving sweeps of docs/SERVING.md:
`batch_sweep` (system queries/s + dispatches-per-query vs search_batch
width, reported separately so batch size cannot inflate the dispatch win)
and `shard_sweep` (QPS vs LTI shard count; multi-shard rows come from the
fake-device CI step)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.lti import build_lti, search_lti
from repro.core.merge import streaming_merge
from repro.core.system import bootstrap_system

from .common import (dataset, default_cfg, default_pq, emit, queryset, timed,
                     write_bench_json)


def beam_sweep(lti, cfg, q, widths=(1, 2, 4), k=5, tag="fig7_beam"):
    """search_lti at each beam width: latency, IO rounds, reads, recall."""
    g = lti.graph
    gt = brute_force(g.vectors, g.active & ~g.deleted, jnp.asarray(q), k)
    base_hops = None
    for W in widths:
        def s():
            return search_lti(lti, jnp.asarray(q), cfg, k=k,
                              L=cfg.L_search, beam_width=W)

        s()  # warm the jit cache
        (ids, d, hops, cmps), secs = timed(s, repeats=3)
        rec = float(recall_at_k(ids, gt))
        h = float(hops.mean())
        base_hops = base_hops or h
        emit(f"{tag}_W{W}", secs,
             f"hops={h:.1f} speedup={base_hops / h:.2f}x "
             f"cmps={float(cmps.mean()):.0f} recall={rec:.4f}",
             W=W, hops=h, cmps=float(cmps.mean()), recall=rec,
             hop_speedup=base_hops / h)


def _serving_system(dim, per_tier, n_tiers, base, **cfg_kw):
    sys_cfg = SystemConfig(
        index=IndexConfig(capacity=4096, dim=dim, R=20, L_build=24,
                          L_search=32, alpha=1.2),
        pq=PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=3),
        ro_snapshot_points=per_tier, merge_threshold=10**9,
        temp_capacity=per_tier * 2, insert_batch=32, **cfg_kw)
    sys_ = bootstrap_system(base, np.arange(len(base)), sys_cfg)
    stream = dataset(per_tier * n_tiers, dim, seed=5)
    for i, v in enumerate(stream):
        sys_.insert(10_000 + i, v)
    return sys_


def fanout_sweep(quick: bool = False, tag: str = "fanout"):
    """System QPS + dispatch count vs RO-snapshot count, unified vs split.

    The unified path runs the LTI's PQ lane AND all temp tiers in ONE
    jitted device program, so its dispatch count is constant (1 per query
    *batch*) while the split per-tier loop pays one program per live tier
    (LTI + RW + T RO) — the §5.2 serving-cost claim, quantified per mode.
    QPS accounting under batching: queries/s (`qps`) and
    `dispatches_per_query` are reported SEPARATELY with the `batch` column
    alongside, so a wide batch cannot inflate the dispatch win — at
    batch=32 the split loop also amortizes its per-tier programs over 32
    queries; what separates the modes is dispatches per query, and qps
    measures wall-clock throughput at the stated batch.  The LTI lane is
    always live here (the bootstrap builds one), so the sweep exercises the
    heterogeneous ADC + L2 lane select.  On CPU XLA the stacked lanes
    serialize, so the QPS win only materializes on lane-parallel hardware;
    the dispatch-count columns are hardware-independent.
    """
    dim = 16 if quick else 24
    per_tier = 96
    nq = 16
    tiers = (2, 4) if quick else (2, 4, 8)
    base = dataset(256, dim, seed=3)
    q = queryset(nq, dim, seed=4)
    for n_tiers in tiers:
        results = {}
        for batched in (True, False):
            sys_ = _serving_system(dim, per_tier, n_tiers, base,
                                   batch_fanout=batched)
            sys_.search_batch(q, k=5)               # warm the jit cache
            d0 = sys_.stats.search_dispatches
            (_, _), secs = timed(lambda: sys_.search_batch(q, k=5),
                                 repeats=3)
            dispatches = (sys_.stats.search_dispatches - d0) / 3
            results[batched] = secs
            mode = "unified" if batched else "split"
            lti_lane = int(sys_.lti.graph.n_total) > 0
            emit(f"{tag}_T{n_tiers}_{mode}", secs,
                 f"batch={nq} qps={nq / secs:.0f} "
                 f"disp/query={dispatches / nq:.3f} "
                 f"ro_tiers={len(sys_.ro)} lti_lane={lti_lane}",
                 n_tiers=n_tiers, mode=mode, batch=nq, qps=nq / secs,
                 dispatches_per_search=dispatches,
                 dispatches_per_query=dispatches / nq, lti_lane=lti_lane)
        emit(f"{tag}_T{n_tiers}_speedup", results[False] - results[True],
             f"unified_over_split={results[False] / results[True]:.2f}x",
             n_tiers=n_tiers, speedup=results[False] / results[True])


def batch_sweep(quick: bool = False, tag: str = "serve_batch"):
    """search_batch throughput vs query-batch width B on a 3-tier system.

    One program serves the whole batch, so dispatches_per_query falls as
    1/B while queries/s rises with batch-level parallelism — the paper's
    "thousands of concurrent searches" axis, measured honestly: `qps` and
    `dispatches_per_query` are separate columns keyed by `batch`.  A final
    row serves a wide request through `batch_queries` micro-batching
    (fixed-shape chunks) to price the chunking overhead.
    """
    dim = 16 if quick else 24
    base = dataset(256, dim, seed=3)
    sys_ = _serving_system(dim, 96, 2, base)
    batches = (1, 8, 32) if quick else (1, 8, 32, 128)
    for b in batches:
        q = queryset(b, dim, seed=4)
        sys_.search_batch(q, k=5)                   # warm per-shape cache
        d0 = sys_.stats.search_dispatches
        (_, _), secs = timed(lambda: sys_.search_batch(q, k=5), repeats=3)
        disp = (sys_.stats.search_dispatches - d0) / 3
        emit(f"{tag}_B{b}", secs,
             f"batch={b} qps={b / secs:.0f} disp/query={disp / b:.3f}",
             batch=b, qps=b / secs, dispatches_per_search=disp,
             dispatches_per_query=disp / b)
    wide = batches[-1]
    micro = 8
    sys_m = _serving_system(dim, 96, 2, base, batch_queries=micro)
    q = queryset(wide, dim, seed=4)
    sys_m.search_batch(q, k=5)
    d0 = sys_m.stats.search_dispatches
    (_, _), secs = timed(lambda: sys_m.search_batch(q, k=5), repeats=3)
    disp = (sys_m.stats.search_dispatches - d0) / 3
    emit(f"{tag}_micro{micro}_B{wide}", secs,
         f"batch={wide} batch_queries={micro} qps={wide / secs:.0f} "
         f"disp/query={disp / wide:.3f}",
         batch=wide, batch_queries=micro, qps=wide / secs,
         dispatches_per_search=disp, dispatches_per_query=disp / wide)


def shard_sweep(quick: bool = False, tag: str = "serve_shards"):
    """search_batch QPS vs LTI shard count (the `shards` column).

    Covers every power-of-two shard count the device census allows — 1 on
    a plain CPU run; 1/2/4 under the fake-device CI step
    (XLA_FLAGS=--xla_force_host_platform_device_count=4, the
    docs/SERVING.md recipe).  Results are bit-identical across counts by
    construction (the owner-computes lane); what this measures is the
    collective overhead on CPU — the memory win (1/shards of the LTI per
    device) and any speedup need real accelerators, same caveat as the
    lane-parallelism columns above.
    """
    import jax
    dim = 16 if quick else 24
    nq = 32
    base = dataset(256, dim, seed=3)
    q = queryset(nq, dim, seed=4)
    n_dev = len(jax.devices())
    shard_counts = [n for n in (1, 2, 4) if n <= n_dev]
    ref = None
    for ns in shard_counts:
        sys_ = _serving_system(dim, 96, 2, base, shard_lti=ns)
        ids, _ = sys_.search_batch(q, k=5)          # warm + parity anchor
        if ref is None:
            ref = ids
        else:
            np.testing.assert_array_equal(ids, ref)
        (_, _), secs = timed(lambda: sys_.search_batch(q, k=5), repeats=3)
        emit(f"{tag}_S{ns}", secs,
             f"shards={ns} batch={nq} qps={nq / secs:.0f} devices={n_dev}",
             shards=ns, batch=nq, qps=nq / secs, devices=n_dev)


def serving_sweeps(quick: bool = True):
    """Standalone serving benches -> BENCH_serving.json (the CI step runs
    this under 4 fake host devices so the artifact carries real
    multi-shard rows): batch + shard sweeps plus the Poisson-arrival
    scheduler rows (sustained QPS, p50/p99, deadline-miss rate, occupancy
    with concurrent inserts/deletes — ``bench_concurrent.poisson_serving``)."""
    from .bench_concurrent import poisson_serving
    batch_sweep(quick)
    shard_sweep(quick)
    poisson_serving(quick)
    write_bench_json("serving", quick=quick)


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts = dataset(n)
    cfg, pq = default_cfg(n), default_pq()
    lti = build_lti(pts, cfg, pq)

    batches = (8, 64) if quick else (8, 32, 128, 512)
    for b in batches:
        q = queryset(b)

        def s():
            return search_lti(lti, jnp.asarray(q), cfg, k=5,
                              L=cfg.L_search)

        s()  # warm the jit cache
        _, secs = timed(s, repeats=3)
        emit(f"fig7_search_batch_{b}", secs,
             f"qps={b / secs:.0f}", batch=b, qps=b / secs)

    beam_sweep(lti, cfg, queryset(64), widths=(1, 2) if quick else (1, 2, 4))
    fanout_sweep(quick)
    batch_sweep(quick)
    shard_sweep(quick)

    rng = np.random.default_rng(1)
    n_chg = n // 10
    victims = rng.choice(n, n_chg, replace=False)
    dmask = np.zeros(cfg.capacity, bool)
    dmask[victims] = True
    vecs = np.asarray(lti.graph.vectors)[victims]
    blocks = (512,) if quick else (256, 1024, 4096)
    for blk in blocks:
        def m():
            out, _ = streaming_merge(
                lti, jnp.asarray(vecs), jnp.ones(n_chg, bool),
                jnp.asarray(dmask), cfg, pq, insert_chunk=128, block=blk)
            return out

        _, secs = timed(m)
        emit(f"fig7_merge_block_{blk}", secs,
             f"updates_per_sec={2 * n_chg / secs:.0f}",
             block=blk, updates_per_sec=2 * n_chg / secs)

    write_bench_json("throughput", quick=quick, n=n)


if __name__ == "__main__":
    main()
