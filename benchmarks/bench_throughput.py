"""Paper Fig. 7: scaling.  Thread-count scaling becomes batch-size scaling
(the TPU's parallelism axis): search throughput vs query batch, merge runtime
vs block size (the paper's merge-thread knob), and the beamwidth sweep (§6.2):
IO rounds vs recall as W grows — hops drop ~W-fold while recall holds."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.index import brute_force, recall_at_k
from repro.core.lti import build_lti, search_lti
from repro.core.merge import streaming_merge

from .common import dataset, default_cfg, default_pq, emit, queryset, timed


def beam_sweep(lti, cfg, q, widths=(1, 2, 4), k=5, tag="fig7_beam"):
    """search_lti at each beam width: latency, IO rounds, reads, recall."""
    g = lti.graph
    gt = brute_force(g.vectors, g.active & ~g.deleted, jnp.asarray(q), k)
    base_hops = None
    for W in widths:
        def s():
            return search_lti(lti, jnp.asarray(q), cfg, k=k,
                              L=cfg.L_search, beam_width=W)

        s()  # warm the jit cache
        (ids, d, hops, cmps), secs = timed(s, repeats=3)
        rec = float(recall_at_k(ids, gt))
        h = float(hops.mean())
        base_hops = base_hops or h
        emit(f"{tag}_W{W}", secs,
             f"hops={h:.1f} speedup={base_hops / h:.2f}x "
             f"cmps={float(cmps.mean()):.0f} recall={rec:.4f}")


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts = dataset(n)
    cfg, pq = default_cfg(n), default_pq()
    lti = build_lti(pts, cfg, pq)

    batches = (8, 64) if quick else (8, 32, 128, 512)
    for b in batches:
        q = queryset(b)

        def s():
            return search_lti(lti, jnp.asarray(q), cfg, k=5,
                              L=cfg.L_search)

        s()  # warm the jit cache
        _, secs = timed(s, repeats=3)
        emit(f"fig7_search_batch_{b}", secs,
             f"qps={b / secs:.0f}")

    beam_sweep(lti, cfg, queryset(64), widths=(1, 2) if quick else (1, 2, 4))

    rng = np.random.default_rng(1)
    n_chg = n // 10
    victims = rng.choice(n, n_chg, replace=False)
    dmask = np.zeros(cfg.capacity, bool)
    dmask[victims] = True
    vecs = np.asarray(lti.graph.vectors)[victims]
    blocks = (512,) if quick else (256, 1024, 4096)
    for blk in blocks:
        def m():
            out, _ = streaming_merge(
                lti, jnp.asarray(vecs), jnp.ones(n_chg, bool),
                jnp.asarray(dmask), cfg, pq, insert_chunk=128, block=blk)
            return out

        _, secs = timed(m)
        emit(f"fig7_merge_block_{blk}", secs,
             f"updates_per_sec={2 * n_chg / secs:.0f}")


if __name__ == "__main__":
    main()
