"""Paper Fig. 7: scaling.  Thread-count scaling becomes batch-size scaling
(the TPU's parallelism axis): search throughput vs query batch, merge runtime
vs block size (the paper's merge-thread knob), the beamwidth sweep (§6.2):
IO rounds vs recall as W grows — hops drop ~W-fold while recall holds — and
the multi-tier fan-out sweep: system QPS vs RO-snapshot count, batched
(one vmapped call over stacked tiers) vs the sequential per-tier loop."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.lti import build_lti, search_lti
from repro.core.merge import streaming_merge
from repro.core.system import bootstrap_system

from .common import (dataset, default_cfg, default_pq, emit, queryset, timed,
                     write_bench_json)


def beam_sweep(lti, cfg, q, widths=(1, 2, 4), k=5, tag="fig7_beam"):
    """search_lti at each beam width: latency, IO rounds, reads, recall."""
    g = lti.graph
    gt = brute_force(g.vectors, g.active & ~g.deleted, jnp.asarray(q), k)
    base_hops = None
    for W in widths:
        def s():
            return search_lti(lti, jnp.asarray(q), cfg, k=k,
                              L=cfg.L_search, beam_width=W)

        s()  # warm the jit cache
        (ids, d, hops, cmps), secs = timed(s, repeats=3)
        rec = float(recall_at_k(ids, gt))
        h = float(hops.mean())
        base_hops = base_hops or h
        emit(f"{tag}_W{W}", secs,
             f"hops={h:.1f} speedup={base_hops / h:.2f}x "
             f"cmps={float(cmps.mean()):.0f} recall={rec:.4f}",
             W=W, hops=h, cmps=float(cmps.mean()), recall=rec,
             hop_speedup=base_hops / h)


def fanout_sweep(quick: bool = False, tag: str = "fanout"):
    """System QPS + dispatch count vs RO-snapshot count, unified vs split.

    The unified path runs the LTI's PQ lane AND all temp tiers in ONE
    jitted device program, so its dispatch count is constant (1) while the
    split per-tier loop pays one program per live tier (LTI + RW + T RO) —
    the §5.2 serving-cost claim, quantified per mode.  The LTI lane is
    always live here (the bootstrap builds one), so the sweep exercises the
    heterogeneous ADC + L2 lane select.  On CPU XLA the stacked lanes
    serialize, so the QPS win only materializes on lane-parallel hardware;
    the dispatch-count column is hardware-independent.
    """
    dim = 16 if quick else 24
    per_tier = 96
    nq = 16
    icfg = dict(capacity=4096, dim=dim, R=20, L_build=24, L_search=32,
                alpha=1.2)
    tiers = (2, 4) if quick else (2, 4, 8)
    base = dataset(256, dim, seed=3)
    q = queryset(nq, dim, seed=4)
    for n_tiers in tiers:
        results = {}
        for batched in (True, False):
            sys_cfg = SystemConfig(
                index=IndexConfig(**icfg),
                pq=PQConfig(dim=dim, m=8, ksub=32, kmeans_iters=3),
                ro_snapshot_points=per_tier, merge_threshold=10**9,
                temp_capacity=per_tier * 2, insert_batch=32,
                batch_fanout=batched)
            sys_ = bootstrap_system(base, np.arange(len(base)), sys_cfg)
            stream = dataset(per_tier * n_tiers, dim, seed=5)
            for i, v in enumerate(stream):
                sys_.insert(10_000 + i, v)
            sys_.search(q, k=5)                     # warm the jit cache
            d0 = sys_.stats.search_dispatches
            (_, _), secs = timed(lambda: sys_.search(q, k=5), repeats=3)
            dispatches = (sys_.stats.search_dispatches - d0) / 3
            results[batched] = secs
            mode = "unified" if batched else "split"
            lti_lane = int(sys_.lti.graph.n_total) > 0
            emit(f"{tag}_T{n_tiers}_{mode}", secs,
                 f"qps={nq / secs:.0f} dispatches={dispatches:.0f} "
                 f"ro_tiers={len(sys_.ro)} lti_lane={lti_lane}",
                 n_tiers=n_tiers, mode=mode, qps=nq / secs,
                 dispatches_per_search=dispatches, lti_lane=lti_lane)
        emit(f"{tag}_T{n_tiers}_speedup", results[False] - results[True],
             f"unified_over_split={results[False] / results[True]:.2f}x",
             n_tiers=n_tiers, speedup=results[False] / results[True])


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts = dataset(n)
    cfg, pq = default_cfg(n), default_pq()
    lti = build_lti(pts, cfg, pq)

    batches = (8, 64) if quick else (8, 32, 128, 512)
    for b in batches:
        q = queryset(b)

        def s():
            return search_lti(lti, jnp.asarray(q), cfg, k=5,
                              L=cfg.L_search)

        s()  # warm the jit cache
        _, secs = timed(s, repeats=3)
        emit(f"fig7_search_batch_{b}", secs,
             f"qps={b / secs:.0f}", batch=b, qps=b / secs)

    beam_sweep(lti, cfg, queryset(64), widths=(1, 2) if quick else (1, 2, 4))
    fanout_sweep(quick)

    rng = np.random.default_rng(1)
    n_chg = n // 10
    victims = rng.choice(n, n_chg, replace=False)
    dmask = np.zeros(cfg.capacity, bool)
    dmask[victims] = True
    vecs = np.asarray(lti.graph.vectors)[victims]
    blocks = (512,) if quick else (256, 1024, 4096)
    for blk in blocks:
        def m():
            out, _ = streaming_merge(
                lti, jnp.asarray(vecs), jnp.ones(n_chg, bool),
                jnp.asarray(dmask), cfg, pq, insert_chunk=128, block=blk)
            return out

        _, secs = timed(m)
        emit(f"fig7_merge_block_{blk}", secs,
             f"updates_per_sec={2 * n_chg / secs:.0f}",
             block=blk, updates_per_sec=2 * n_chg / secs)

    write_bench_json("throughput", quick=quick, n=n)


if __name__ == "__main__":
    main()
