"""Update-path benchmarks: the mutation engine's throughput trajectory.

Two record families, both with an ``engine`` column (jnp oracle vs fused
Pallas kernels):

  ``prune_launch_*``   engine-level microbench of ONE ``robust_prune_batch``
                       launch at the insert and repair operating shapes —
                       the direct jnp-vs-fused comparison the acceptance
                       bar reads (the fused launch must not be slower).
  ``consolidate_{global,local}_*``  Algorithm-4 delete consolidation at
                       1% / 5% / 20% delete rates, global sweep vs the
                       localized affected-set repair (bit-identical
                       results; the local rows carry speedup_vs_global).
  everything else      end-to-end mutation ops: batched inserts
                       (Algorithm 2), delete consolidation (Algorithm 4),
                       and the three-phase StreamingMerge (§5.3, both
                       distance flavors).  On CPU these run the Pallas
                       *interpreter*; the insert/build rows also inherit
                       the query-side kernels' known interpreter overhead
                       (see ROADMAP.md), so the end-to-end kernel columns
                       bound — not demonstrate — the fusion win until run
                       on TPU (the JSON's top-level ``backend`` field
                       labels the columns).

Emits ``BENCH_update_path.json``.  Run:
``python -c "from benchmarks.bench_update_path import main; main()"``
(``main(quick=True)`` in CI / scripts/smoke.sh).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (dataset, default_cfg, default_pq, emit,
                               locality_stream, timed, write_bench_json)
from repro.core import index as mem
from repro.core.delete import consolidate_deletes, delete
from repro.core.lti import build_lti
from repro.core.merge import streaming_merge


def bench_prune_launch(engine: str, use_kernel: bool, dim: int) -> None:
    """One robust_prune_batch launch at the three hot operating shapes:
    the Algorithm-2 insert prune (visited-pool candidates), the Delta patch
    (combine = R + d_max lanes over a big affected block), and the
    StreamingMerge SDC repair (capped expansion over PQ codes)."""
    from repro.core.prune import (FullPrecisionPrune, SDCPrune,
                                  robust_prune_batch)
    from repro.core import pq as pqm
    from repro.core.config import PQConfig

    r = np.random.default_rng(0)
    table = jnp.asarray(r.standard_normal((4096, dim)).astype(np.float32))
    pq_cfg = PQConfig(dim=dim, m=8, ksub=64, kmeans_iters=2)
    cb = pqm.train_pq(table[:1024], pq_cfg)
    sdc = SDCPrune(pqm.encode(cb, table, pq_cfg), pqm.sdc_tables(cb))
    shapes = (("insert", "fp", 128, 116), ("patch", "fp", 1024, 56),
              ("repair_sdc", "sdc", 256, 252))
    for tag, kind, B, C in shapes:
        cand = jnp.asarray(r.integers(-1, 4096, (B, C)).astype(np.int32))
        ok = (cand >= 0)
        if kind == "fp":
            pb = FullPrecisionPrune(table)
            anchors = jnp.asarray(
                r.standard_normal((B, dim)).astype(np.float32))
        else:
            pb = sdc
            anchors = pb.anchor_of(jnp.asarray(
                r.integers(0, 4096, B).astype(np.int32)))
        run = jax.jit(lambda pb=pb, cand=cand, ok=ok, anchors=anchors:
                      robust_prune_batch(pb, cand, ok, alpha=1.2, R=28,
                                         use_kernel=use_kernel,
                                         anchors=anchors).ids)
        jax.block_until_ready(run())      # engine callers are always jitted
        _, t = timed(run, repeats=10)
        emit(f"prune_launch_{tag}_{engine}", t, f"B={B} C={C}",
             rows_per_s=B / t, engine=engine)


def bench_engine(engine: str, use_kernel: bool, pts: np.ndarray,
                 quick: bool) -> None:
    n, dim = pts.shape
    half = n // 2
    cfg = default_cfg(n, dim, use_kernel=use_kernel)
    pq_cfg = default_pq(dim)

    t0 = time.perf_counter()
    state = mem.build(pts[:half], cfg, batch=128)
    jax.block_until_ready(state.adjacency)
    emit(f"build_{engine}", time.perf_counter() - t0, f"n={half}",
         points_per_s=half / (time.perf_counter() - t0), engine=engine)

    # Batched insert (Algorithm 2): steady-state RW-tier flush shape.
    B = 128
    slots = jnp.arange(half, half + B, dtype=jnp.int32)
    vecs = jnp.asarray(pts[half:half + B])
    mem.insert(state, slots, vecs, cfg)                  # compile
    _, t_ins = timed(mem.insert, state, slots, vecs, cfg,
                     repeats=1 if quick else 3)
    emit(f"insert_batch_{engine}", t_ins, f"B={B}",
         inserts_per_s=B / t_ins, engine=engine)

    # Delete consolidation (Algorithm 4) over ~8% of the index.
    victims = jnp.asarray(np.arange(0, half, 13), jnp.int32)
    gd = delete(state, victims)
    consolidate_deletes(gd, cfg)                         # compile
    _, t_con = timed(consolidate_deletes, gd, cfg,
                     repeats=1 if quick else 3)
    nv = victims.shape[0]
    emit(f"consolidate_{engine}", t_con, f"ndel={nv}",
         deletes_per_s=nv / t_con, engine=engine)

    # StreamingMerge: deletes + staged inserts folded into the LTI.
    lti = build_lti(pts[:half], cfg, pq_cfg, batch=128)
    n_new = min(n - half, 256)
    newv = jnp.asarray(pts[half:half + n_new])
    valid = jnp.ones((n_new,), bool)
    dmask = jnp.zeros((cfg.capacity,), bool).at[
        jnp.arange(0, half, 17)].set(True)
    flavors = ((("sdc", True),) if quick               # the §5.3 operating
               else (("decoded", False), ("sdc", True)))   # point
    for flavor, use_sdc in flavors:
        args = (lti, newv, valid, dmask, cfg, pq_cfg)
        kw = dict(insert_chunk=128, block=512, use_sdc=use_sdc)
        jax.block_until_ready(
            streaming_merge(*args, **kw)[0].graph.adjacency)   # compile
        _, t_m = timed(lambda: streaming_merge(*args, **kw)[0].graph)
        emit(f"merge_{flavor}_{engine}", t_m,
             f"staged={n_new} del={int(dmask.sum())}",
             staged_per_s=n_new / t_m, engine=engine)


def bench_repair_modes(engine: str, use_kernel: bool, pts: np.ndarray,
                       quick: bool) -> None:
    """Global sweep vs localized affected-set repair across delete rates.

    Same deleted index, same repair engine — only the walk differs, so the
    ratio isolates the launches-skipped win.  At 1% deletes the affected
    set is a small fraction of the capacity-sized global sweep; by 20%
    most rows have a deleted out-neighbor and the gap closes."""
    n, dim = pts.shape
    half = n // 2
    cfg = default_cfg(n, dim, use_kernel=use_kernel)
    state = mem.build(pts[:half], cfg, batch=128)
    jax.block_until_ready(state.adjacency)
    rates = (0.01,) if quick else (0.01, 0.05, 0.20)
    for rate in rates:
        k = max(1, int(round(half * rate)))
        victims = jnp.asarray(
            np.linspace(0, half - 1, k).astype(np.int32))
        gd = delete(state, victims)
        t_by_mode = {}
        for mode in ("global", "local"):
            run = lambda m=mode: consolidate_deletes(gd, cfg, mode=m)
            jax.block_until_ready(run().adjacency)       # compile
            _, t = timed(run, repeats=1 if quick else 3)
            t_by_mode[mode] = t
            extra = ({} if mode == "global" else
                     {"speedup_vs_global": t_by_mode["global"] / t})
            emit(f"consolidate_{mode}_{rate:.0%}_{engine}", t,
                 f"ndel={k}", deletes_per_s=k / t, delete_rate=rate,
                 engine=engine, **extra)


def bench_locality(quick: bool) -> None:
    """Arrival-order vs locality-scheduled merges on the clustered-expiry
    stream (``common.locality_stream`` — the workload the proximity
    ordering exists for).  The ``merge_locality_*`` rows carry the three
    acceptance numbers: steady-state merge wall (cycles 0-2 pay
    compilation — insert-only shapes, then the first expiry cycle's
    launch buckets — on both arms and are excluded), Delta prune rows
    LAUNCHED (fixed-shape worst case vs measured power-of-two buckets),
    and distinct 4KB topology blocks the delta dirtied (placement
    compounding — the gap widens with cycles as cluster mates stay
    contiguous)."""
    cycles, per, cap, ndel = ((4, 192, 8192, 48) if quick
                              else (6, 512, 16384, 96))
    base = None
    for loc in (False, True):
        jax.clear_caches()
        recs = locality_stream(cycles, per, ndel, loc, cap=cap)
        steady = recs[3:]
        wall = sum(r["wall"] for r in steady)
        prune = sum(r["prune_rows"] for r in recs)
        targets = sum(r["backedge_targets"] for r in recs)
        rows = sum(r["delta_rows"] for r in recs)
        blocks = sum(r["delta_blocks"] for r in recs)
        extra = {} if base is None else {"speedup_vs_arrival": base / wall}
        if base is None:
            base = wall
        tag = "on" if loc else "off"
        emit(f"merge_locality_{tag}", wall,
             f"cycles={cycles} staged={per}/cyc prune_rows={prune} "
             f"targets={targets} delta_blocks={blocks}",
             cycles=cycles, staged_per_cycle=per, prune_rows=prune,
             backedge_targets=targets, delta_rows=rows,
             delta_blocks=blocks, locality=int(loc), **extra)


def main(quick: bool = False) -> str:
    import gc
    n = 600 if quick else 3000
    dim = 32
    pts = dataset(n, dim)
    for engine, use_kernel in (("jnp", False), ("kernel", True)):
        # Fresh executable cache per engine pass: the suite compiles many
        # jit variants and the CPU jaxlib arena otherwise grows enough to
        # distort the later engine's warm timings (see tests/conftest.py).
        jax.clear_caches()
        gc.collect()
        bench_prune_launch(engine, use_kernel, dim)
        bench_engine(engine, use_kernel, pts, quick)
        bench_repair_modes(engine, use_kernel, pts, quick)
    bench_locality(quick)
    return write_bench_json("update_path", quick=quick)


if __name__ == "__main__":
    main()
