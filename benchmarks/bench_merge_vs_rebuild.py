"""Paper Table 2 / Fig. 11: StreamingMerge cost vs full rebuild for 5%,
10%, 50% change sets — the paper's core cost claim (merge ~ O(change))."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.lti import build_lti
from repro.core.merge import streaming_merge

from .common import dataset, default_cfg, default_pq, emit, timed


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts = dataset(n)
    cfg, pq = default_cfg(n), default_pq()
    lti, t_build = timed(build_lti, pts, cfg, pq)
    emit("tab2_full_rebuild", t_build, f"n={n}")
    rng = np.random.default_rng(9)
    fracs = (0.1,) if quick else (0.05, 0.1, 0.5)
    for frac in fracs:
        n_chg = int(n * frac)
        live = np.flatnonzero(np.asarray(lti.graph.active))
        victims = rng.choice(live, n_chg, replace=False)
        dmask = np.zeros(cfg.capacity, bool)
        dmask[victims] = True
        vecs = np.asarray(lti.graph.vectors)[victims]

        def merge():
            out, _ = streaming_merge(
                lti, jnp.asarray(vecs), jnp.ones(n_chg, bool),
                jnp.asarray(dmask), cfg, pq, insert_chunk=128, block=1024)
            return out

        _, t_merge = timed(merge)
        emit(f"tab2_merge_{int(frac * 100)}pct", t_merge,
             f"rel_to_rebuild={t_merge / t_build:.3f}")


if __name__ == "__main__":
    main()
