"""Paper Fig. 5/6: the full-system workload — concurrent inserts, deletes
and searches with periodic background StreamingMerge; reports user-facing
latencies and recall (CPU-scale rendition of the week-long experiment)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.system import bootstrap_system

from .common import DIM, dataset, emit, queryset


def main(quick: bool = False):
    n = 1024 if quick else 2048
    updates = 512 if quick else 2048
    pts = dataset(n * 3)
    q = queryset(32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=n * 8, dim=DIM, R=24, L_build=32,
                          L_search=48, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=n // 8, merge_threshold=n // 4,
        temp_capacity=n, insert_batch=64)
    sys_ = bootstrap_system(pts[:n], np.arange(n), cfg)
    live = dict(enumerate(pts[:n]))
    rng = np.random.default_rng(2)

    ins_lat, del_lat, search_lat, recalls = [], [], [], []
    next_id = n
    for i in range(updates):
        t = time.perf_counter()
        sys_.insert(next_id, pts[n + (next_id % (2 * n))])
        ins_lat.append(time.perf_counter() - t)
        live[next_id] = pts[n + (next_id % (2 * n))]
        next_id += 1
        victim = int(rng.choice(sorted(live)))
        t = time.perf_counter()
        sys_.delete(victim)
        del_lat.append(time.perf_counter() - t)
        live.pop(victim)
        if (i + 1) % (updates // 4) == 0:
            t = time.perf_counter()
            ids, _ = sys_.search_batch(q, k=5)
            search_lat.append(time.perf_counter() - t)
            keys = np.asarray(sorted(live))
            mat = np.stack([live[k] for k in keys])
            gt = brute_force(jnp.asarray(mat), jnp.ones(len(keys), bool),
                             jnp.asarray(q), 5)
            recalls.append(float(recall_at_k(
                jnp.asarray(ids), jnp.asarray(keys[np.asarray(gt)]))))

    emit("fig6_insert_latency", float(np.median(ins_lat)),
         f"p90={np.percentile(ins_lat, 90) * 1e6:.0f}us")
    emit("fig6_delete_latency", float(np.median(del_lat)),
         f"p90={np.percentile(del_lat, 90) * 1e6:.0f}us")
    disp_per_q = sys_.stats.search_dispatches / max(sys_.stats.searches, 1)
    emit("fig5_search_latency", float(np.median(search_lat)),
         "recall_mean=%.3f merges=%d batch=%d disp/query=%.3f"
         % (np.mean(recalls), sys_.stats.merges, len(q), disp_per_q),
         batch=len(q), dispatches_per_query=disp_per_q)


if __name__ == "__main__":
    main()
