"""Paper Fig. 5/6: the full-system workload — concurrent inserts, deletes
and searches with periodic background StreamingMerge; reports user-facing
latencies and recall (CPU-scale rendition of the week-long experiment).

Latency reporting is reservoir-backed (``SystemStats`` — docs/SERVING.md,
"Counters"): searches ride ``batch_queries`` micro-batches so EVERY
dispatched micro-batch is one sample in ``stats.search_latency``, and the
rows carry structured ``p50``/``p99`` fields instead of free-text notes.

``poisson_serving`` is the serving-front-end bench (ISSUE: sustained QPS
under a Poisson arrival process): an open-loop arrival process drives the
``BatchScheduler`` worker thread while an updater thread inserts/deletes
concurrently and threshold merges run in the background; rows report
sustained QPS, p50/p99 serve latency, deadline-miss rate, mean batch
occupancy and shed counts per offered-load level.  It lands in
``BENCH_serving.json`` via ``bench_throughput.serving_sweeps``.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np
import jax.numpy as jnp

from repro.core.config import IndexConfig, PQConfig, SystemConfig
from repro.core.index import brute_force, recall_at_k
from repro.core.system import Reservoir, bootstrap_system
from repro.serving import BatchScheduler

from .common import DIM, dataset, emit, queryset, timed, write_bench_json


def main(quick: bool = False):
    n = 1024 if quick else 2048
    updates = 512 if quick else 2048
    pts = dataset(n * 3)
    q = queryset(32)
    cfg = SystemConfig(
        index=IndexConfig(capacity=n * 8, dim=DIM, R=24, L_build=32,
                          L_search=48, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=n // 8, merge_threshold=n // 4,
        temp_capacity=n, insert_batch=64, batch_queries=8)
    sys_ = bootstrap_system(pts[:n], np.arange(n), cfg)
    live = dict(enumerate(pts[:n]))
    rng = np.random.default_rng(2)

    del_lat, recalls = [], []
    next_id = n
    for i in range(updates):
        sys_.insert(next_id, pts[n + (next_id % (2 * n))])
        live[next_id] = pts[n + (next_id % (2 * n))]
        next_id += 1
        victim = int(rng.choice(sorted(live)))
        t = time.perf_counter()
        sys_.delete(victim)
        del_lat.append(time.perf_counter() - t)
        live.pop(victim)
        # Search every 1/16th of the stream: each call is 4 micro-batches
        # of 8, each one a sample in stats.search_latency (insert latency
        # samples land in stats.insert_latency via record_latency).
        if (i + 1) % (updates // 16) == 0:
            ids, _ = sys_.search_batch(q, k=5)
        if (i + 1) % (updates // 4) == 0:     # recall needs ground truth
            keys = np.asarray(sorted(live))
            mat = np.stack([live[k] for k in keys])
            gt = brute_force(jnp.asarray(mat), jnp.ones(len(keys), bool),
                             jnp.asarray(q), 5)
            recalls.append(float(recall_at_k(
                jnp.asarray(ids), jnp.asarray(keys[np.asarray(gt)]))))

    ins, sea = sys_.stats.insert_latency, sys_.stats.search_latency
    emit("fig6_insert_latency", ins.percentile(50.0),
         f"p99={ins.percentile(99.0) * 1e6:.0f}us n={ins.seen}",
         p50=ins.percentile(50.0), p99=ins.percentile(99.0), n=ins.seen)
    emit("fig6_delete_latency", float(np.median(del_lat)),
         f"p99={np.percentile(del_lat, 99) * 1e6:.0f}us n={len(del_lat)}",
         p50=float(np.percentile(del_lat, 50)),
         p99=float(np.percentile(del_lat, 99)), n=len(del_lat))
    disp_per_q = sys_.stats.search_dispatches / max(sys_.stats.searches, 1)
    emit("fig5_search_latency", sea.percentile(50.0),
         "p99=%.0fus recall_mean=%.3f merges=%d microbatches=%d "
         "disp/query=%.3f" % (sea.percentile(99.0) * 1e6, np.mean(recalls),
                              sys_.stats.merges, sea.seen, disp_per_q),
         p50=sea.percentile(50.0), p99=sea.percentile(99.0), n=sea.seen,
         batch_queries=cfg.batch_queries, recall_mean=float(np.mean(recalls)),
         dispatches_per_query=disp_per_q)
    write_bench_json("concurrent", quick=quick, n=n, updates=updates)


def poisson_serving(quick: bool = True, rates=(0.7, 2.5), tag="poisson"):
    """Sustained QPS under open-loop Poisson arrivals, per offered load.

    Offered load is relative to measured capacity: one warmed micro-batch
    dispatch is timed, capacity = batch_queries / dispatch_time, and each
    ``rate`` drives arrivals at rate * capacity (0.7 = sustainable,
    2.5 = overload — the row where shed/miss counters move; full batches
    amortize better than the single-batch calibration, so saturation needs
    real margin over the estimate).  Inserts and
    deletes run concurrently on an updater thread and threshold merges in
    the background (``background_merge``), so the rows price the serving
    loop against the full mutation pipeline, not a frozen index.
    """
    n = 768 if quick else 1536
    n_req = 192 if quick else 640
    pts = dataset(n * 2, seed=11)
    q = queryset(64, seed=12)
    cfg = SystemConfig(
        index=IndexConfig(capacity=n * 8, dim=DIM, R=24, L_build=32,
                          L_search=48, alpha=1.2),
        pq=PQConfig(dim=DIM, m=8, ksub=32, kmeans_iters=4),
        ro_snapshot_points=96, merge_threshold=384, temp_capacity=n,
        insert_batch=32, batch_queries=8, serve_queue_capacity=64,
        background_merge=True)
    sys_ = bootstrap_system(pts[:n], np.arange(n), cfg)
    sys_.search_batch(q[:8], k=5)                     # warm the base shape

    stop = threading.Event()
    next_id = [10_000_000]

    def updater():
        rngu = np.random.default_rng(7)
        start = next_id[0]
        while not stop.is_set():
            i = next_id[0]
            if i - start < 2 * n:       # bound liveset growth vs capacity
                sys_.insert(i, pts[n + i % n])
                next_id[0] = i + 1
            sys_.delete(int(rngu.integers(0, n)))      # base-set victims
            time.sleep(0.005)                          # don't starve serving

    upd = threading.Thread(target=updater, daemon=True)
    upd.start()

    # Prime under churn: walk the system through rollovers/merges so the
    # per-tier-count programs are compiled, then calibrate the dispatch
    # cost as the MEDIAN of single-micro-batch calls on the LIVE system —
    # an idle-system estimate undershoots wildly once flushes and lane
    # restacks ride the serving path.
    for _ in range(24):
        sys_.search_batch(q[:8], k=5)
    lats = []
    for _ in range(9):
        _, s = timed(lambda: sys_.search_batch(q[:8], k=5))
        lats.append(s)
    per_batch = float(np.median(lats))
    capacity_qps = cfg.batch_queries / per_batch
    # SLO sized to the machine: ~4 dispatch times of headroom.
    slo_ms = max(4.0 * per_batch * 1e3, 10.0)
    sys_.cfg = dataclasses.replace(sys_.cfg, slo_ms=slo_ms,
                                   dispatch_estimate_ms=per_batch * 1e3)

    for rate in rates:
        lam = rate * capacity_qps
        rng = np.random.default_rng(int(rate * 100))
        gaps = rng.exponential(1.0 / lam, n_req)
        sys_.stats.serve_latency = Reservoir(seed=2)  # fresh per load row
        s0 = sys_.stats.serving_snapshot()
        sched = BatchScheduler(sys_, k=5)
        sched.start()
        t0 = time.perf_counter()
        t_next = t0
        for gap in gaps:
            t_next += gap
            delay = t_next - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            sched.submit(q[int((t_next * 1e6)) % len(q)])
        sched.stop(flush=True)                         # drain the tail
        wall = time.perf_counter() - t0

        s1 = sys_.stats.serving_snapshot()
        served = s1["scheduled_requests"] - s0["scheduled_requests"]
        shed = s1["shed_requests"] - s0["shed_requests"]
        misses = s1["deadline_misses"] - s0["deadline_misses"]
        lat = sys_.stats.serve_latency
        qps = served / wall
        miss_rate = misses / max(served, 1)
        emit(f"{tag}_load{rate}", wall,
             f"qps={qps:.0f} offered={lam:.0f} p50={lat.percentile(50.0) * 1e3:.1f}ms "
             f"p99={lat.percentile(99.0) * 1e3:.1f}ms miss={miss_rate:.3f} "
             f"occ={sched.mean_occupancy:.2f} shed={shed} "
             f"merges={sys_.stats.merges}",
             rate=rate, offered_qps=lam, qps=qps, slo_ms=slo_ms,
             p50=lat.percentile(50.0), p99=lat.percentile(99.0),
             miss_rate=miss_rate, occupancy=sched.mean_occupancy,
             served=served, shed=shed, deadline_misses=misses,
             merges=sys_.stats.merges)

    stop.set()
    upd.join()
    sys_.wait_merge()


if __name__ == "__main__":
    main()
