"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4]

Output: CSV lines ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (bench_alpha_ablation, bench_build, bench_concurrent,
               bench_io_cost, bench_merge_recall, bench_merge_vs_rebuild,
               bench_recall_stability, bench_throughput, bench_update_path)

MODULES = [
    ("fig1_fig2_recall_stability", bench_recall_stability),
    ("fig3_alpha_ablation", bench_alpha_ablation),
    ("fig4_merge_recall", bench_merge_recall),
    ("tab1_build_time", bench_build),
    ("tab2_merge_vs_rebuild", bench_merge_vs_rebuild),
    ("fig5_fig6_concurrent", bench_concurrent),
    ("fig7_throughput_scaling", bench_throughput),
    ("sec6_io_cost", bench_io_cost),
    ("sec5_update_path", bench_update_path),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
