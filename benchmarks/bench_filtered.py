"""Filtered & multi-tenant search benchmark (BENCH_filtered.json).

Three sweeps, all oracle-anchored against brute force over the matching
subset:

  * selectivity sweep — filtered recall@k and QPS at label selectivity
    {1.0, 0.5, 0.1, 0.01} on one labeled system, next to the unfiltered
    baseline (the filter is one extra AND on the cached drop mask, so the
    QPS column IS the cost claim); the client widens k/L by ~1/selectivity
    (post-filtering semantics, tests/test_filtered.py);
  * tenant sweep — per-tenant filtered recall and QPS at 2 and 8 tenants
    (quota/shed accounting is the scheduler's, benched in BENCH_serving);
  * drift workload — ``common.tenant_drift_stream``: per-tenant clustered
    churn under embedding drift (the sasrec re-embedding shape) with
    ``locality_order`` on, merged every cycle; rows carry the per-tenant
    recall-stability series (min/mean across tenants per cycle).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.config import SystemConfig
from repro.core.graph import FilterSpec
from repro.core.system import bootstrap_system

from .common import (DIM, dataset, default_cfg, default_pq, emit, queryset,
                     tenant_drift_stream, write_bench_json)

SELECTIVITIES = (1.0, 0.5, 0.1, 0.01)


def _labeled_system(n, n_tenants):
    """Labeled system: bit b set on every ceil(1/sel_b) -th point, tenants
    striped, plus a streaming tail so filters cross the temp tiers."""
    pts = dataset(n + n // 4)
    cfg = SystemConfig(
        index=default_cfg(n=4 * n, dim=DIM), pq=default_pq(DIM),
        ro_snapshot_points=128, merge_threshold=100_000,
        temp_capacity=512, insert_batch=64, filter_words=1)

    def labels_for(i):
        return [b for b, sel in enumerate(SELECTIVITIES)
                if i % round(1 / sel) == 0]

    sys_ = bootstrap_system(
        pts[:n], np.arange(n), cfg,
        labels=[labels_for(i) for i in range(n)],
        tenants=[i % n_tenants for i in range(n)])
    truth = {i: (labels_for(i), i % n_tenants) for i in range(n)}
    for j in range(n // 4):
        i = n + j
        sys_.insert(i, pts[i], labels=labels_for(i), tenant=i % n_tenants)
        truth[i] = (labels_for(i), i % n_tenants)
    sys_._flush_inserts()
    return sys_, pts, truth


def _recall_vs_subset(ids, q, pts, keys, k):
    mat = pts[keys]
    d = ((mat[None] - q[:, None]) ** 2).sum(-1)
    gt = keys[np.argsort(d, axis=1)[:, :k]]
    hits = sum(len(set(int(x) for x in row if x >= 0) & set(g.tolist()))
               for row, g in zip(ids, gt))
    return hits / (k * len(q))


def selectivity_sweep(quick: bool = False):
    n = 1024 if quick else 2048
    n_tenants = 4
    sys_, pts, truth = _labeled_system(n, n_tenants)
    q = queryset(32)
    k = 10
    # unfiltered baseline: the bit-parity twin of the sel=1.0 row
    t0 = time.perf_counter()
    ids_u, _ = sys_.search_batch(q, k)
    base_s = time.perf_counter() - t0
    keys_all = np.asarray(sorted(truth))
    emit("filtered_baseline_unfiltered", base_s,
         f"recall={_recall_vs_subset(np.asarray(ids_u), q, pts, keys_all, k):.3f}",
         selectivity=1.0, n_tenants=n_tenants, filtered=0,
         recall=_recall_vs_subset(np.asarray(ids_u), q, pts, keys_all, k),
         qps=len(q) / base_s)
    for bit, sel in enumerate(SELECTIVITIES):
        spec = FilterSpec(all_of=(bit,))
        k_eff = k if sel == 1.0 else min(256, int(np.ceil(k / sel * 1.5)))
        L = min(max(64, 2 * k_eff), 1024)
        sys_.search_batch(q, k_eff, L=L, filter=spec)   # warm the program
        t0 = time.perf_counter()
        ids, _ = sys_.search_batch(q, k_eff, L=L, filter=spec)
        sec = time.perf_counter() - t0
        ids = np.asarray(ids)[:, :k]
        keys = np.asarray([e for e in sorted(truth)
                           if bit in truth[e][0]])
        rec = _recall_vs_subset(ids, q, pts, keys, k)
        emit(f"filtered_sel_{sel}", sec, f"recall={rec:.3f}",
             selectivity=sel, n_tenants=n_tenants, filtered=1,
             k_eff=k_eff, L=L, recall=rec, qps=len(q) / sec)


def tenant_sweep(quick: bool = False):
    n = 1024 if quick else 2048
    q = queryset(32)
    k = 10
    for n_tenants in (2, 8):
        sys_, pts, truth = _labeled_system(n, n_tenants)
        recalls, secs = [], 0.0
        for t in range(n_tenants):
            spec = FilterSpec(tenant=t)
            k_eff = min(128, k * n_tenants)
            L = min(max(64, 2 * k_eff), 1024)
            sys_.search_batch(q, k_eff, L=L, filter=spec)
            t0 = time.perf_counter()
            ids, _ = sys_.search_batch(q, k_eff, L=L, filter=spec)
            secs += time.perf_counter() - t0
            ids = np.asarray(ids)[:, :k]
            keys = np.asarray([e for e in sorted(truth)
                               if truth[e][1] == t])
            recalls.append(_recall_vs_subset(ids, q, pts, keys, k))
        emit(f"filtered_tenants_{n_tenants}", secs / n_tenants,
             f"recall_min={min(recalls):.3f}",
             selectivity=1.0 / n_tenants, n_tenants=n_tenants, filtered=1,
             recall=float(np.mean(recalls)), recall_min=min(recalls),
             qps=len(q) * n_tenants / secs)


def drift_workload(quick: bool = False):
    cycles = 3 if quick else 5
    per_tenant = 24 if quick else 48
    recs = tenant_drift_stream(cycles, per_tenant, n_tenants=4,
                               n_del=8, locality=True)
    for r in recs:
        emit(f"filtered_drift_cycle{r['cycle']}",
             r["merge_wall"],
             f"recall_min={r['recall_min']:.3f}",
             selectivity=0.25, n_tenants=4, filtered=1, drift=1,
             locality_order=1, cycle=r["cycle"],
             insert_wall=r["insert_wall"], recall=r["recall_mean"],
             recall_min=r["recall_min"])


def main(quick: bool = False):
    selectivity_sweep(quick)
    tenant_sweep(quick)
    drift_workload(quick)
    write_bench_json("filtered", quick=quick)


if __name__ == "__main__":
    main()
