"""Paper Fig. 4: recall evolution over StreamingMerge cycles (PQ distances
throughout — expect a small initial dip, then stability)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.index import brute_force, recall_at_k
from repro.core.lti import build_lti, search_lti
from repro.core.merge import streaming_merge

from .common import dataset, default_cfg, default_pq, emit, queryset, timed


def lti_recall(lti, cfg, q, k=5):
    ids, d, hops, _ = search_lti(lti, jnp.asarray(q), cfg, k=k,
                                 L=cfg.L_search)
    mask = lti.graph.active & ~lti.graph.deleted
    gt = brute_force(lti.graph.vectors, mask, jnp.asarray(q), k)
    return float(recall_at_k(ids, gt)), float(hops.mean())


def run(cycles=8, n=2000, frac=0.1):
    pts, q = dataset(n), queryset()
    cfg, pq = default_cfg(n), default_pq()
    lti = build_lti(pts, cfg, pq)
    rng = np.random.default_rng(4)
    recalls = [lti_recall(lti, cfg, q)[0]]
    n_chg = int(n * frac)
    for _ in range(cycles):
        live = np.flatnonzero(np.asarray(lti.graph.active))
        victims = rng.choice(live, n_chg, replace=False)
        dmask = np.zeros(cfg.capacity, bool)
        dmask[victims] = True
        vecs = np.asarray(lti.graph.vectors)[victims]
        lti, _ = streaming_merge(lti, jnp.asarray(vecs),
                                 jnp.ones(n_chg, bool), jnp.asarray(dmask),
                                 cfg, pq, insert_chunk=128, block=1024)
        recalls.append(lti_recall(lti, cfg, q)[0])
    return recalls


def main(quick: bool = False):
    cycles = 3 if quick else 8
    recalls, secs = timed(run, cycles=cycles)
    emit("fig4_merge_recall", secs / cycles,
         "r0=%.3f r1=%.3f final=%.3f" % (recalls[0], recalls[1],
                                         recalls[-1]))


if __name__ == "__main__":
    main()
