"""Paper Fig. 3 / App. C: effect of alpha on recall stability + density."""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.delete import consolidate_deletes, delete
from repro.core.graph import degree_stats
from repro.core.index import build, insert

from .common import dataset, default_cfg, emit, mem_recall, queryset, timed


def run_alpha(alpha: float, cycles=6, n=1500, frac=0.1):
    pts, q = dataset(n), queryset()
    cfg = dataclasses.replace(default_cfg(n), alpha=alpha)
    rng = np.random.default_rng(3)
    state = build(pts, cfg, batch=128)
    recalls = [mem_recall(state, cfg, q)[0]]
    degs = [float(degree_stats(state)["avg_degree"])]
    n_del = int(n * frac)
    for _ in range(cycles):
        live = np.flatnonzero(np.asarray(state.active & ~state.deleted))
        victims = rng.choice(live, n_del, replace=False).astype(np.int32)
        vecs = np.asarray(state.vectors)[victims]
        state = consolidate_deletes(delete(state, jnp.asarray(victims)), cfg)
        for lo in range(0, n_del, 128):
            sl = victims[lo:lo + 128]
            pad = 128 - len(sl)
            slots = np.concatenate([sl, np.full(pad, -1)]).astype(np.int32)
            vv = np.zeros((128, cfg.dim), np.float32)
            vv[:len(sl)] = vecs[lo:lo + 128]
            state = insert(state, jnp.asarray(slots), jnp.asarray(vv), cfg)
        recalls.append(mem_recall(state, cfg, q)[0])
        degs.append(float(degree_stats(state)["avg_degree"]))
    return recalls, degs


def main(quick: bool = False):
    alphas = (1.0, 1.2) if quick else (1.0, 1.1, 1.2, 1.4)
    cycles = 4 if quick else 6
    for a in alphas:
        (recalls, degs), secs = timed(run_alpha, a, cycles=cycles)
        emit(f"fig3_alpha_{a}", secs / cycles,
             "r0=%.3f rF=%.3f deg0=%.1f degF=%.1f" % (
                 recalls[0], recalls[-1], degs[0], degs[-1]))


if __name__ == "__main__":
    main()
