"""Shared benchmark utilities (CPU-scale datasets + recall measurement)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig, PQConfig
from repro.core.index import brute_force, recall_at_k, search

DIM = 32
N = 3000


def dataset(n=N, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((32, dim)) * 3.0
    which = rng.integers(0, 32, n)
    return (centers[which] + rng.standard_normal((n, dim))).astype(
        np.float32)


def queryset(nq=64, dim=DIM, seed=1):
    return dataset(nq, dim, seed)


def default_cfg(n=N, dim=DIM, **kw):
    base = dict(capacity=2 * n, dim=dim, R=28, L_build=40, L_search=60,
                alpha=1.2)
    base.update(kw)
    return IndexConfig(**base)


def default_pq(dim=DIM):
    return PQConfig(dim=dim, m=8, ksub=64, kmeans_iters=6)


def mem_recall(state, cfg, queries, k=5, L=None):
    ids, d, hops, cmps = search(state, jnp.asarray(queries), cfg, k=k,
                                L=L or cfg.L_search)
    mask = state.active & ~state.deleted
    gt = brute_force(state.vectors, mask, jnp.asarray(queries), k)
    return float(recall_at_k(ids, gt)), hops, cmps


def timed(fn, *args, repeats=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    import jax
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeats


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
