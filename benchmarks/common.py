"""Shared benchmark utilities (CPU-scale datasets + recall measurement).

Every ``emit`` both prints the legacy CSV line and appends a structured
record; ``write_bench_json`` dumps the run as ``BENCH_<name>.json`` so the
perf trajectory is machine-readable (CI archives these as artifacts).
"""
from __future__ import annotations

import json
import os
import platform
import time

import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig, PQConfig
from repro.core.index import brute_force, recall_at_k, search

DIM = 32
N = 3000


def dataset(n=N, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((32, dim)) * 3.0
    which = rng.integers(0, 32, n)
    return (centers[which] + rng.standard_normal((n, dim))).astype(
        np.float32)


def queryset(nq=64, dim=DIM, seed=1):
    return dataset(nq, dim, seed)


def default_cfg(n=N, dim=DIM, **kw):
    base = dict(capacity=2 * n, dim=dim, R=28, L_build=40, L_search=60,
                alpha=1.2)
    base.update(kw)
    return IndexConfig(**base)


def default_pq(dim=DIM):
    return PQConfig(dim=dim, m=8, ksub=64, kmeans_iters=6)


def mem_recall(state, cfg, queries, k=5, L=None):
    ids, d, hops, cmps = search(state, jnp.asarray(queries), cfg, k=k,
                                L=L or cfg.L_search)
    mask = state.active & ~state.deleted
    gt = brute_force(state.vectors, mask, jnp.asarray(queries), k)
    return float(recall_at_k(ids, gt)), hops, cmps


def timed(fn, *args, repeats=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    import jax
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeats


def locality_stream(cycles: int, per_cycle: int, n_del: int, locality: bool,
                    *, cap: int = 16384, dim: int = DIM, seed: int = 3,
                    layout_path: str | None = None,
                    measure_recall: bool = False) -> list[dict]:
    """Clustered-expiry streaming-merge driver shared by the locality
    benches (bench_update_path.bench_locality and bench_io_cost's
    storage-delta sweep — SAME stream, so their numbers compose).

    The workload is the streaming pattern locality ordering exists for:
    each cycle inserts ``per_cycle`` points drawn from four FRESH clusters
    (a moving distribution), and from cycle 2 on expires up to ``n_del``
    points of the cluster window inserted two cycles earlier (time-to-live
    deletes, clustered like the inserts that created them).  Slot->cluster
    tracking rides ``MergeStats.slots``.

    Returns one record per cycle: merge wall seconds, changed adjacency
    rows and DISTINCT 4KB topology blocks (``merge.adjacency_delta_mask``),
    Delta prune rows launched and distinct targets; with ``layout_path``
    the base LTI is written through ``repro.storage`` and every cycle's
    delta is patched to disk, adding the measured ``patch_layout`` stats
    (adj_rows / adj_blocks / bytes_written) to the record; with
    ``measure_recall`` each record adds recall@10 of a fixed clustered
    query set against brute force over the live set (the
    recall-equivalence contract, measured per cycle).
    """
    import jax
    from repro.core.lti import build_lti, write_lti_layout
    from repro.core.merge import adjacency_delta_mask, streaming_merge
    from repro.storage.layout import patch_layout

    rng0 = np.random.default_rng(seed)
    cfg = IndexConfig(capacity=cap, dim=dim, R=28, L_build=32, L_search=48,
                      alpha=1.2)
    pq = default_pq(dim)
    rpb = max(1, 4096 // (cfg.R * 4))
    centers = rng0.standard_normal((8 + 4 * cycles, dim)) * 4.0
    base = (centers[rng0.integers(0, 8, 512)]
            + 0.2 * rng0.standard_normal((512, dim))).astype(np.float32)
    lti = build_lti(base, cfg, pq, batch=64)
    if layout_path:
        write_lti_layout(layout_path, lti).close()
    q = (centers[rng0.integers(0, len(centers), 32)]
         + 0.2 * rng0.standard_normal((32, dim))).astype(np.float32)

    rng = np.random.default_rng(7)
    slot_cluster: dict[int, int] = {}
    out = []
    for cyc in range(cycles):
        window = np.arange(8 + cyc * 4, 8 + cyc * 4 + 4)
        which = rng.choice(window, per_cycle)
        newp = (centers[which] + 0.2 * rng.standard_normal(
            (per_cycle, dim))).astype(np.float32)
        dmask = np.zeros(cap, bool)
        if cyc >= 2:
            victim_cl = 8 + (cyc - 2) * 4          # expire the oldest window
            act = np.asarray(lti.graph.active & ~lti.graph.deleted)
            vict = [s for s, c in slot_cluster.items()
                    if c == victim_cl and act[s]][:n_del]
            dmask[vict] = True
        old_adj = lti.graph.adjacency
        t0 = time.perf_counter()
        lti, stats = streaming_merge(
            lti, jnp.asarray(newp), jnp.ones((per_cycle,), bool),
            jnp.asarray(dmask), cfg, pq, insert_chunk=128, block=512,
            locality=locality, locality_seed=cyc)
        jax.block_until_ready(lti.graph.adjacency)
        wall = time.perf_counter() - t0
        for i, s in enumerate(np.asarray(stats.slots)):
            if s >= 0:
                slot_cluster[int(s)] = int(which[i])
        delta = adjacency_delta_mask(old_adj, lti.graph.adjacency)
        changed = np.nonzero(np.asarray(delta))[0]
        rec = {"cycle": cyc, "wall": wall, "delta_rows": int(changed.size),
               "delta_blocks": int(np.unique(changed // rpb).size),
               "prune_rows": int(stats.n_prune_rows),
               "backedge_targets": int(stats.n_backedge_targets),
               "n_deleted": int(stats.n_deleted)}
        if layout_path:
            ps = patch_layout(layout_path, lti.graph, codes=lti.codes,
                              adj_changed=np.asarray(delta))
            rec.update(adj_rows=ps.adj_rows, adj_blocks=ps.adj_blocks,
                       bytes_written=ps.bytes_written)
        if measure_recall:
            rec["recall"] = mem_recall(lti.graph, cfg, q, k=10)[0]
        out.append(rec)
    return out


def tenant_drift_stream(cycles: int, per_tenant: int, n_tenants: int,
                        *, n_del: int = 8, dim: int = DIM, seed: int = 5,
                        locality: bool = True, k: int = 10) -> list[dict]:
    """Drifting multi-tenant churn driver for the filtered benches.

    Models the re-embedding shape of ``examples/sasrec_retrieval.py``:
    each tenant owns one embedding cluster whose center DRIFTS every cycle
    (a retrained model moves the whole catalog), so a cycle re-embeds part
    of each tenant's catalog — delete up to ``n_del`` of the tenant's
    oldest points, insert ``per_tenant`` fresh ones at the drifted center.
    Churn is clustered per tenant by construction, which is exactly the
    stream ``SystemConfig.locality_order`` exists for, and every cycle
    ends in a StreamingMerge so labels cross all three merge phases.

    Returns one record per cycle: merge wall seconds, insert wall seconds,
    and per-tenant filtered recall@k against brute force over THAT
    tenant's live points (the per-tenant recall-stability series —
    isolation means one tenant's churn cannot collapse another's recall).
    """
    from repro.core.config import SystemConfig
    from repro.core.graph import FilterSpec
    from repro.core.system import bootstrap_system

    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_tenants, dim)).astype(np.float32) * 4.0
    drift = rng.standard_normal((n_tenants, dim)).astype(np.float32) * 0.6
    n0 = per_tenant * n_tenants
    base = np.concatenate([
        centers[t] + 0.3 * rng.standard_normal((per_tenant, dim))
        for t in range(n_tenants)]).astype(np.float32)
    tenants0 = np.repeat(np.arange(n_tenants), per_tenant)
    cfg = SystemConfig(
        index=default_cfg(n=4 * n0 + 2048, dim=dim),
        pq=default_pq(dim),
        ro_snapshot_points=64, merge_threshold=100_000,
        temp_capacity=max(256, 2 * per_tenant * n_tenants),
        insert_batch=32, filter_words=1, locality_order=locality)
    sys_ = bootstrap_system(base, np.arange(n0), cfg,
                            labels=[[0]] * n0, tenants=tenants0.tolist())
    live: dict[int, tuple[int, np.ndarray]] = {
        e: (int(tenants0[e]), base[e]) for e in range(n0)}
    next_id, out = n0, []
    for cyc in range(cycles):
        centers += drift                      # the whole embedding drifts
        t_ins = time.perf_counter()
        for t in range(n_tenants):
            mine = sorted(e for e, (te, _) in live.items() if te == t)
            for e in mine[:n_del]:            # oldest re-embedded points
                sys_.delete(e)
                del live[e]
            newp = (centers[t] + 0.3 * rng.standard_normal(
                (per_tenant, dim))).astype(np.float32)
            for v in newp:
                sys_.insert(next_id, v, labels=[0], tenant=t)
                live[next_id] = (t, v)
                next_id += 1
        sys_._flush_inserts()
        ins_wall = time.perf_counter() - t_ins
        t_m = time.perf_counter()
        sys_.merge()
        sys_.wait_merge()
        merge_wall = time.perf_counter() - t_m
        per_tenant_recall = {}
        for t in range(n_tenants):
            mine = [e for e, (te, _) in live.items() if te == t]
            mat = np.stack([live[e][1] for e in mine])
            q = (centers[t] + 0.3 * rng.standard_normal(
                (16, dim))).astype(np.float32)
            d = ((mat[None] - q[:, None]) ** 2).sum(-1)
            gt = np.asarray(mine)[np.argsort(d, axis=1)[:, :k]]
            ids, _ = sys_.search_batch(q, k, L=max(64, 4 * k),
                                       filter=FilterSpec(tenant=t))
            hits = sum(len(set(int(x) for x in row if x >= 0)
                           & set(g.tolist()))
                       for row, g in zip(np.asarray(ids), gt))
            per_tenant_recall[t] = hits / (k * len(q))
        rec = {"cycle": cyc, "insert_wall": ins_wall,
               "merge_wall": merge_wall,
               "recall_per_tenant": per_tenant_recall,
               "recall_min": min(per_tenant_recall.values()),
               "recall_mean": float(np.mean(list(
                   per_tenant_recall.values())))}
        out.append(rec)
    return out


_RECORDS: list[dict] = []


def emit(name: str, seconds: float, derived: str = "", **metrics):
    """Print the legacy CSV line AND record a structured entry.

    ``metrics`` are free-form numeric fields (hops, cmps, recall, qps, ...)
    that land verbatim in the JSON record.
    """
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    _RECORDS.append({"name": name, "seconds": seconds,
                     "derived": derived, **metrics})


def write_bench_json(bench: str, out_dir: str | None = None, **meta) -> str:
    """Dump the records collected so far as ``BENCH_<bench>.json``."""
    import jax
    path = os.path.join(out_dir or os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), f"BENCH_{bench}.json")
    payload = {
        "bench": bench,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "unix_time": time.time(),
        **meta,
        "records": list(_RECORDS),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(_RECORDS)} records)", flush=True)
    _RECORDS.clear()
    return path
