"""Shared benchmark utilities (CPU-scale datasets + recall measurement).

Every ``emit`` both prints the legacy CSV line and appends a structured
record; ``write_bench_json`` dumps the run as ``BENCH_<name>.json`` so the
perf trajectory is machine-readable (CI archives these as artifacts).
"""
from __future__ import annotations

import json
import os
import platform
import time

import jax.numpy as jnp
import numpy as np

from repro.core.config import IndexConfig, PQConfig
from repro.core.index import brute_force, recall_at_k, search

DIM = 32
N = 3000


def dataset(n=N, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((32, dim)) * 3.0
    which = rng.integers(0, 32, n)
    return (centers[which] + rng.standard_normal((n, dim))).astype(
        np.float32)


def queryset(nq=64, dim=DIM, seed=1):
    return dataset(nq, dim, seed)


def default_cfg(n=N, dim=DIM, **kw):
    base = dict(capacity=2 * n, dim=dim, R=28, L_build=40, L_search=60,
                alpha=1.2)
    base.update(kw)
    return IndexConfig(**base)


def default_pq(dim=DIM):
    return PQConfig(dim=dim, m=8, ksub=64, kmeans_iters=6)


def mem_recall(state, cfg, queries, k=5, L=None):
    ids, d, hops, cmps = search(state, jnp.asarray(queries), cfg, k=k,
                                L=L or cfg.L_search)
    mask = state.active & ~state.deleted
    gt = brute_force(state.vectors, mask, jnp.asarray(queries), k)
    return float(recall_at_k(ids, gt)), hops, cmps


def timed(fn, *args, repeats=1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    import jax
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeats


_RECORDS: list[dict] = []


def emit(name: str, seconds: float, derived: str = "", **metrics):
    """Print the legacy CSV line AND record a structured entry.

    ``metrics`` are free-form numeric fields (hops, cmps, recall, qps, ...)
    that land verbatim in the JSON record.
    """
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    _RECORDS.append({"name": name, "seconds": seconds,
                     "derived": derived, **metrics})


def write_bench_json(bench: str, out_dir: str | None = None, **meta) -> str:
    """Dump the records collected so far as ``BENCH_<bench>.json``."""
    import jax
    path = os.path.join(out_dir or os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), f"BENCH_{bench}.json")
    payload = {
        "bench": bench,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "unix_time": time.time(),
        **meta,
        "records": list(_RECORDS),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(_RECORDS)} records)", flush=True)
    _RECORDS.clear()
    return path
