"""Paper Fig. 1 + Fig. 2: recall over delete/re-insert cycles.

FreshVamana (alpha-RNG consolidation) vs Delete Policy A (edge removal) and
Policy B with alpha=1 (aggressive pruning) — the naive baselines collapse,
FreshVamana holds.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.delete import (consolidate_deletes, consolidate_policy_a,
                               consolidate_policy_b, delete)
from repro.core.index import build, insert

from .common import (dataset, default_cfg, emit, mem_recall, queryset,
                     timed)


def run_cycles(policy: str, frac=0.10, cycles=8, n=2000):
    pts = dataset(n)
    q = queryset()
    cfg = default_cfg(n)
    rng = np.random.default_rng(5)
    state = build(pts, cfg, batch=128)
    fns = {
        "fresh": lambda s: consolidate_deletes(s, cfg),
        "naive_a": consolidate_policy_a,
        "naive_b": lambda s: consolidate_policy_b(s, cfg),
    }
    recalls = [mem_recall(state, cfg, q)[0]]
    n_del = int(n * frac)
    for _ in range(cycles):
        live = np.flatnonzero(np.asarray(state.active & ~state.deleted))
        victims = rng.choice(live, n_del, replace=False).astype(np.int32)
        vecs = np.asarray(state.vectors)[victims]
        state = fns[policy](delete(state, jnp.asarray(victims)))
        for lo in range(0, n_del, 128):
            sl = victims[lo:lo + 128]
            pad = 128 - len(sl)
            slots = np.concatenate([sl, np.full(pad, -1)]).astype(np.int32)
            vv = np.zeros((128, cfg.dim), np.float32)
            vv[:len(sl)] = vecs[lo:lo + 128]
            state = insert(state, jnp.asarray(slots), jnp.asarray(vv), cfg)
        recalls.append(mem_recall(state, cfg, q)[0])
    return recalls


def main(quick: bool = False):
    cycles = 4 if quick else 8
    for policy in ("fresh", "naive_a", "naive_b"):
        recalls, secs = timed(run_cycles, policy, cycles=cycles)
        emit(f"fig2_recall_stability_{policy}", secs / cycles,
             "cycle0=%.3f final=%.3f min=%.3f" % (
                 recalls[0], recalls[-1], min(recalls)))


if __name__ == "__main__":
    main()
