"""Paper Fig. 1 + Fig. 2: recall over delete/re-insert cycles.

FreshVamana (alpha-RNG consolidation) vs Delete Policy A (edge removal) and
Policy B with alpha=1 (aggressive pruning) — the naive baselines collapse,
FreshVamana holds.  ``fresh_local`` runs the same cycle through the
localized (affected-set) sweep and additionally tracks the exact
unreachable-live fraction (full-population probe, ``core.reach``) per
cycle: localized repair must not erode graph connectivity over cycles
(the ``unreachable_rise`` metric — docs/ARCHITECTURE.md, "Localized
delete repair").
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.delete import (consolidate_deletes, consolidate_policy_a,
                               consolidate_policy_b, delete)
from repro.core.index import build, insert
from repro.core.reach import unreachable_fraction

from .common import (dataset, default_cfg, emit, locality_stream,
                     mem_recall, queryset, timed, write_bench_json)


def run_cycles(policy: str, frac=0.10, cycles=8, n=2000, probe=False):
    pts = dataset(n)
    q = queryset()
    cfg = default_cfg(n)
    rng = np.random.default_rng(5)
    state = build(pts, cfg, batch=128)
    fns = {
        "fresh": lambda s: consolidate_deletes(s, cfg),
        "fresh_local": lambda s: consolidate_deletes(s, cfg, mode="local"),
        "naive_a": consolidate_policy_a,
        "naive_b": lambda s: consolidate_policy_b(s, cfg),
    }

    def gauge(s):
        # Full-population probe (every live point), not a sample: n is
        # small enough here that the exact fraction is affordable.
        return (float(unreachable_fraction(s, cfg, samples=n))
                if probe else 0.0)

    recalls = [mem_recall(state, cfg, q)[0]]
    unreach = [gauge(state)]
    n_del = int(n * frac)
    for _ in range(cycles):
        live = np.flatnonzero(np.asarray(state.active & ~state.deleted))
        victims = rng.choice(live, n_del, replace=False).astype(np.int32)
        vecs = np.asarray(state.vectors)[victims]
        state = fns[policy](delete(state, jnp.asarray(victims)))
        for lo in range(0, n_del, 128):
            sl = victims[lo:lo + 128]
            pad = 128 - len(sl)
            slots = np.concatenate([sl, np.full(pad, -1)]).astype(np.int32)
            vv = np.zeros((128, cfg.dim), np.float32)
            vv[:len(sl)] = vecs[lo:lo + 128]
            state = insert(state, jnp.asarray(slots), jnp.asarray(vv), cfg)
        recalls.append(mem_recall(state, cfg, q)[0])
        unreach.append(gauge(state))
    return recalls, unreach


def main(quick: bool = False):
    cycles = 4 if quick else 8
    for policy in ("fresh", "fresh_local", "naive_a", "naive_b"):
        probe = policy in ("fresh", "fresh_local")
        (recalls, unreach), secs = timed(run_cycles, policy, cycles=cycles,
                                         probe=probe)
        extra = ({"unreachable_cycle0": unreach[0],
                  "unreachable_final": unreach[-1],
                  "unreachable_max": max(unreach),
                  "unreachable_rise": unreach[-1] - unreach[0]}
                 if probe else {})
        emit(f"fig2_recall_stability_{policy}", secs / cycles,
             "cycle0=%.3f final=%.3f min=%.3f" % (
                 recalls[0], recalls[-1], min(recalls)), **extra)
    # Locality-scheduled merges on the clustered-expiry stream: topology
    # legitimately differs from arrival order, recall must not (the
    # recall-equivalence contract of docs/ARCHITECTURE.md, "Update-path
    # locality") — the off/on rows are the paired measurement.
    mc, per, cap, ndel = (4, 192, 8192, 48) if quick else (6, 512, 16384, 96)
    for loc in (False, True):
        recs, secs = timed(locality_stream, mc, per, ndel, loc, cap=cap,
                           measure_recall=True)
        rc = [r["recall"] for r in recs]
        emit(f"fig2_recall_stability_merge_locality_{'on' if loc else 'off'}",
             secs / mc, "cycle0=%.3f final=%.3f min=%.3f" % (
                 rc[0], rc[-1], min(rc)),
             recall_cycle0=rc[0], recall_final=rc[-1], recall_min=min(rc),
             locality=int(loc))
    return write_bench_json("recall_stability", quick=quick)


if __name__ == "__main__":
    main()
