"""Paper Table 1: Vamana (two-pass) vs FreshVamana (streamed single-pass)
build time on the same data + the recall each achieves."""
from __future__ import annotations

from repro.core.index import build

from .common import dataset, default_cfg, emit, mem_recall, queryset, timed


def main(quick: bool = False):
    n = 1500 if quick else 3000
    pts, q = dataset(n), queryset()
    cfg = default_cfg(n)
    st2, t2 = timed(build, pts, cfg, 128, 2)    # Vamana: 2 refinement passes
    st1, t1 = timed(build, pts, cfg, 128, 1)    # FreshVamana: streamed
    r2 = mem_recall(st2, cfg, q)[0]
    r1 = mem_recall(st1, cfg, q)[0]
    emit("tab1_build_vamana_2pass", t2, f"recall={r2:.3f}")
    emit("tab1_build_freshvamana", t1,
         f"recall={r1:.3f} speedup={t2 / t1:.2f}x")


if __name__ == "__main__":
    main()
