"""Pallas TPU kernel: streaming block top-k (smallest distances + ids).

Used for candidate-list maintenance in beam search and for merging per-shard
search results (the paper's cross-machine "aggregate results" step, §1).

The points axis is streamed block-by-block (sequential innermost grid axis);
a VMEM scratch holds the running top-k per query.  Within each step the
running list is merged with the new block by k rounds of (argmin, mask) —
pure VPU ops, no sort network needed for the k≲128 regime the paper uses.

Grid: (Q / block_q, N / block_n); the output tile is written on the final
N-step only.

Contract: ``ref.block_topk_ref`` (see docs/KERNELS.md); parity enforced by
``tests/test_kernels.py::test_topk_matches_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _topk_kernel(d_ref, id_ref, out_d_ref, out_i_ref, best_d, best_i,
                 *, k: int, n_nblocks: int):
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        best_d[...] = jnp.full_like(best_d, jnp.inf)
        best_i[...] = jnp.full_like(best_i, -1)

    block_d = d_ref[...].astype(jnp.float32)           # [BQ, BN]
    block_i = jnp.broadcast_to(id_ref[...][None, :], block_d.shape)

    cand_d = jnp.concatenate([best_d[...], block_d], axis=1)   # [BQ, k+BN]
    cand_i = jnp.concatenate([best_i[...], block_i], axis=1)

    bq, width = cand_d.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, width), 1)

    def select(j, carry):
        cd, new_d, new_i = carry
        m = jnp.min(cd, axis=1, keepdims=True)                  # [BQ, 1]
        # first column attaining the min (stable tie-break)
        is_min = cd == m
        col = jnp.min(jnp.where(is_min, cols, width), axis=1, keepdims=True)
        sel = cols == col
        picked_i = jnp.sum(jnp.where(sel, cand_i, 0), axis=1)
        picked_d = m[:, 0]
        new_d = jax.lax.dynamic_update_slice(
            new_d, picked_d[:, None], (0, j))
        new_i = jax.lax.dynamic_update_slice(
            new_i, jnp.where(jnp.isfinite(picked_d), picked_i,
                             -1)[:, None].astype(jnp.int32), (0, j))
        cd = jnp.where(sel, jnp.inf, cd)
        return cd, new_d, new_i

    init = (cand_d,
            jnp.full((bq, k), jnp.inf, jnp.float32),
            jnp.full((bq, k), -1, jnp.int32))
    _, nd, ni = jax.lax.fori_loop(0, k, select, init)
    best_d[...] = nd
    best_i[...] = ni

    @pl.when(n_idx == n_nblocks - 1)
    def _done():
        out_d_ref[...] = best_d[...]
        out_i_ref[...] = best_i[...]


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_n", "interpret"))
def block_topk_kernel(dists: jax.Array, ids: jax.Array, *, k: int,
                      block_q: int = 8, block_n: int = 512,
                      interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """dists f32 [Q, N], ids int32 [N] -> (dists [Q, k], ids [Q, k]) asc."""
    Q, N = dists.shape
    assert ids.shape == (N,)
    assert Q % block_q == 0 and N % block_n == 0
    n_nblocks = N // block_n
    grid = (Q // block_q, n_nblocks)
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k, n_nblocks=n_nblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_n), lambda q, n: (q, n)),
            pl.BlockSpec((block_n,), lambda q, n: (n,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda q, n: (q, 0)),
            pl.BlockSpec((block_q, k), lambda q, n: (q, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(dists, ids)
