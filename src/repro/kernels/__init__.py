"""Pallas TPU kernels for the FreshDiskANN compute hot-spots.

Three kernels, each with a pure-jnp oracle in ``ref.py`` and a jit'd public
wrapper in ``ops.py`` (which falls back to interpret mode on CPU):

  pq_adc       — asymmetric distance computation over PQ codes.  The paper's
                 single hottest op: every navigation step of the SSD/LTI index
                 scores R neighbors from their 32-byte codes.  TPU adaptation:
                 instead of scalar table lookups (SSD/CPU idiom), the LUT
                 gather is re-associated as one-hot(codes) @ LUT — an MXU
                 matmul — tiled so codes stream HBM->VMEM block-by-block.
  l2_distance  — tiled ||q - x||^2 via the matmul identity (rerank + brute
                 force ground truth + k-means assignment).
  block_topk   — streaming block top-k merge (candidate-list maintenance of
                 Algorithm 1 / final result aggregation across shards).

The *mutation* hot path (insert / delete-repair / StreamingMerge) adds two
fused kernels with the same ref/ops/parity structure:

  robust_prune — Algorithm 3's R sequential selection rounds (masked argmin
                 + winner coverage row + alpha-mask update) in ONE launch
                 per node, full-precision and SDC-code flavors; vmapped
                 over node blocks by ``core.prune.robust_prune_batch``.
  delete_repair— Algorithm 4's per-node repair step (neighbor-of-deleted-
                 neighbor candidate assembly + prune rounds + changed-row
                 select) in one launch; drives
                 ``core.delete.consolidate_deletes`` and the StreamingMerge
                 delete phase.

These wrappers ARE the search hot path: the beam-width engine in
``repro.core.search`` routes every iteration through them when
``use_kernel`` resolves true (``IndexConfig.use_kernel``; None -> auto-on
for TPU backends).  A ``DistanceBackend`` (``FullPrecisionBackend`` /
``PQBackend``) gathers the beam's W x R neighbor rows and scores them with
one ``l2_distances`` / ``adc_distances`` call on a padded fixed-shape batch,
and the candidate list is maintained with one ``block_topk`` merge per
round.  With ``use_kernel=False`` the engine runs the bit-identical jnp
reference path — the parity tests in ``tests/test_beam_search.py`` toggle
the flag both ways.
"""
from .ops import (adc_distances, l2_distances, block_topk,  # noqa: F401
                  robust_prune_fp, robust_prune_sdc,
                  delete_repair_fp, delete_repair_sdc)
