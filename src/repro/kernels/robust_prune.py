"""Pallas TPU kernel: fused RobustPrune rounds (the mutation-engine hot loop).

Algorithm 3 selects up to R out-neighbors by R sequential rounds of
(masked argmin over the anchor distances) -> (emit the winner) ->
(retire every candidate the winner alpha-covers).  The jnp engine pays the
round loop as R separate XLA steps per node; this kernel fuses all R rounds
— argmin, the winner's candidate<->candidate distance row, and the
alpha-coverage mask update — into ONE launch for a whole [B, C] block of
nodes (``core.prune.robust_prune_batch``), every round vectorized across
the block's rows.  A block launch (rather than a per-row launch vmapped
into a grid) matters doubly: the interpreter *scans* grid points
sequentially, so row-granular launches would serialize the block on CPU,
and on TPU one launch per block is exactly the HBM->VMEM streaming unit of
the sequential merge passes.

Two flavors share the round loop (``_prune_rounds``):

  ``robust_prune_fp_kernel``   coverage distances recomputed per round from
                               full-precision candidate vectors
                               (sum((v_star - v)^2), elementwise — exactly
                               the ``l2_sq`` the jnp oracle uses).
  ``robust_prune_sdc_kernel``  coverage distances from PQ codes via the
                               symmetric-distance tables: the winner's code
                               row is extracted with an exact one-hot sum,
                               its per-subspace LUT slice and the
                               candidates' lookups are `take_along_axis`
                               gathers of exactly one f32 each, and the
                               final sum runs over the same [.., m] axis as
                               ``pq.adc`` — bit-identical to the reference.

The winner row is selected with the (min, first-column) scheme shared with
``block_topk``/``frontier_select`` — identical tie-breaking to
``jnp.argmin``.  Anchor distances arrive pre-masked (+inf on unusable
lanes), so the alive set needs no separate mask operand; candidate-lane
padding carries (+inf, id -1) and is inert.  The candidate axis is the only
padded axis: per-round coverage reductions run over the unpadded feature
axes, keeping them bit-identical to the oracle's reductions.  TPU
hardening (row-tiled grid so a block's [B, C, d] payload streams through
VMEM, one-hot contractions replacing the SDC gathers) is tracked in
ROADMAP.md; interpret mode is the validated path on CPU.

Contracts: ``ref.robust_prune_fp_ref`` / ``ref.robust_prune_sdc_ref``
(see docs/KERNELS.md); parity enforced by
``tests/test_kernels.py::test_robust_prune_fp_matches_ref`` /
``test_robust_prune_sdc_matches_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prune_rounds(d_p, ids, cover_fn, *, alpha: float, R: int):
    """R fused RobustPrune rounds over a block of candidate rows.

    d_p [B, C] f32 anchor distances, pre-masked (+inf on dead lanes);
    ids [B, C] int32; ``cover_fn(col)`` maps the winners' column indices
    [B, 1] to their distances to every candidate [B, C].  Returns
    (out_ids [B, R], counts [B, 1]).
    """
    B, C = d_p.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, C), 1)

    def body(i, s):
        alive, out_i, cnt = s
        masked = jnp.where(alive, d_p, jnp.inf)
        m = jnp.min(masked, axis=1, keepdims=True)               # [B, 1]
        is_min = masked == m
        col = jnp.min(jnp.where(is_min, cols, C - 1), axis=1,
                      keepdims=True)                             # [B, 1]
        okr = jnp.isfinite(m)                                    # [B, 1]
        picked = jnp.take_along_axis(ids, col, axis=1)           # [B, 1]
        out_i = jax.lax.dynamic_update_slice(
            out_i, jnp.where(okr, picked, -1).astype(jnp.int32), (0, i))
        cnt = cnt + okr.astype(jnp.int32)
        d_star = cover_fn(col)                                   # [B, C]
        covered = alpha * d_star <= d_p
        alive = alive & ~covered & (cols != col)
        alive = alive & okr                                      # no winner ->
        return alive, out_i, cnt                                 # row retired

    alive0 = jnp.isfinite(d_p)
    out0 = jnp.full((B, R), -1, jnp.int32)
    _, out_i, cnt = jax.lax.fori_loop(
        0, R, body, (alive0, out0, jnp.zeros((B, 1), jnp.int32)))
    return out_i, cnt


def _fp_cover(vecs):
    """Full-precision coverage: d_star[b, c] = sum_d (v_star_b - v_bc)^2.

    The winner's vector is a single-row gather by its column index, and
    the squared-difference reduction runs over the same last axis as the
    oracle's ``l2_sq`` — bit-identical.
    """

    def cover(col):
        v_star = jnp.take_along_axis(vecs, col[:, :, None], axis=1)
        diff = v_star - vecs                                     # [B, C, d]
        return jnp.sum(diff * diff, axis=-1)

    return cover


def _sdc_cover(codes, tables):
    """SDC coverage from PQ codes: d_star[b, c] = sum_m T[m, cs_m, cc_m].

    codes [B, C, m] int32, tables [m, ksub, ksub] f32.  The winner's code
    row and both LUT lookups are single-element gathers (exact); the final
    reduction runs over the same [.., m] axis as ``pq.adc``.
    """

    m, ksub = tables.shape[0], tables.shape[1]
    flat = tables.reshape(m * ksub, ksub)
    base = jnp.arange(m, dtype=jnp.int32)[None, :] * ksub        # [1, m]
    codes_t = jnp.swapaxes(codes, 1, 2)                          # [B, m, C]

    def cover(col):
        c_star = jnp.take_along_axis(codes, col[:, :, None],
                                     axis=1)[:, 0]               # [B, m]
        lut_star = flat[base + c_star]                           # [B, m, k]
        g = jnp.take_along_axis(lut_star, codes_t, axis=2)       # [B, m, C]
        gathered = jnp.swapaxes(g, 1, 2)                         # [B, C, m]
        return jnp.sum(gathered, axis=-1)

    return cover


def _fp_kernel(d_ref, v_ref, i_ref, out_ref, cnt_ref, *, alpha, R):
    out, cnt = _prune_rounds(d_ref[...], i_ref[...],
                             _fp_cover(v_ref[...].astype(jnp.float32)),
                             alpha=alpha, R=R)
    out_ref[...] = out
    cnt_ref[...] = cnt


def _sdc_kernel(d_ref, c_ref, t_ref, i_ref, out_ref, cnt_ref, *, alpha, R):
    out, cnt = _prune_rounds(d_ref[...], i_ref[...],
                             _sdc_cover(c_ref[...],
                                        t_ref[...].astype(jnp.float32)),
                             alpha=alpha, R=R)
    out_ref[...] = out
    cnt_ref[...] = cnt


@functools.partial(jax.jit, static_argnames=("alpha", "R", "interpret"))
def robust_prune_fp_kernel(d_p: jax.Array, vecs: jax.Array, ids: jax.Array,
                           *, alpha: float, R: int,
                           interpret: bool = False):
    """d_p [B, C] pre-masked f32, vecs [B, C, d] f32, ids [B, C] int32 ->
    (out_ids [B, R] int32, counts [B, 1] int32)."""
    B, C = d_p.shape
    assert ids.shape == (B, C) and vecs.shape[:2] == (B, C)
    return pl.pallas_call(
        functools.partial(_fp_kernel, alpha=alpha, R=R),
        out_shape=[
            jax.ShapeDtypeStruct((B, R), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(d_p, vecs, ids)


@functools.partial(jax.jit, static_argnames=("alpha", "R", "interpret"))
def robust_prune_sdc_kernel(d_p: jax.Array, codes: jax.Array,
                            tables: jax.Array, ids: jax.Array,
                            *, alpha: float, R: int,
                            interpret: bool = False):
    """d_p [B, C] pre-masked f32, codes [B, C, m] int32,
    tables [m, ksub, ksub] f32, ids [B, C] int32 ->
    (out_ids [B, R] int32, counts [B, 1] int32)."""
    B, C = d_p.shape
    assert ids.shape == (B, C) and codes.shape[:2] == (B, C)
    return pl.pallas_call(
        functools.partial(_sdc_kernel, alpha=alpha, R=R),
        out_shape=[
            jax.ShapeDtypeStruct((B, R), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(d_p, codes, tables, ids)
