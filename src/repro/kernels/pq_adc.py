"""Pallas TPU kernel: PQ asymmetric distance computation (ADC).

The hot op of the paper's LTI: every beam-search step scores candidate nodes
from their ~32-byte PQ codes against a per-query lookup table,
``out[q, n] = sum_m lut[q, m, codes[n, m]]``.

TPU adaptation (the paper's CPU idiom is a scalar gather loop; TPUs hate
scalar gathers): re-associate the LUT gather as a *one-hot matmul*,

    onehot(codes)  [BN, m*ksub]  @  lut_flat.T  [m*ksub, BQ]  ->  [BN, BQ]

which lands on the MXU.  The one-hot tensor is never materialized in HBM —
it is built in VMEM per (code-block x query-block) grid cell from an iota
comparison, so HBM traffic is exactly codes (1 byte/entry) + LUTs + outputs.

Grid: (N / block_n, Q / block_q); each cell reads a [block_n, m] uint8 code
block and a [block_q, m, ksub] LUT block, both VMEM-resident.

Contract: ``ref.adc_distances_ref`` (see docs/KERNELS.md); parity enforced
by ``tests/test_kernels.py::test_adc_matches_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, lut_ref, out_ref, *, ksub: int):
    codes = codes_ref[...].astype(jnp.int32)          # [BN, m]
    lut = lut_ref[...].astype(jnp.float32)            # [BQ, m, ksub]
    bn, m = codes.shape
    bq = lut.shape[0]
    # one-hot over the fused (m, ksub) axis: onehot[n, m, k] = codes[n,m]==k
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, m, ksub), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)
    onehot2 = onehot.reshape(bn, m * ksub)
    lut2 = lut.reshape(bq, m * ksub)
    acc = jax.lax.dot_general(
        onehot2, lut2,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [BN, BQ]
    out_ref[...] = acc.T                               # [BQ, BN]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def adc_distances_kernel(codes: jax.Array, luts: jax.Array, *,
                         block_n: int = 128, block_q: int = 8,
                         interpret: bool = False) -> jax.Array:
    """codes uint8 [N, m], luts f32 [Q, m, ksub] -> f32 [Q, N].

    N and Q are padded to block multiples by the caller (``ops.py``).
    """
    N, m = codes.shape
    Q, m2, ksub = luts.shape
    assert m == m2, (m, m2)
    assert N % block_n == 0 and Q % block_q == 0, (N, Q, block_n, block_q)
    grid = (Q // block_q, N // block_n)
    return pl.pallas_call(
        functools.partial(_adc_kernel, ksub=ksub),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda q, n: (n, 0)),
            pl.BlockSpec((block_q, m, ksub), lambda q, n: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda q, n: (q, n)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.float32),
        interpret=interpret,
    )(codes, luts)
