"""Pallas TPU kernel: tiled squared-L2 distance matrix.

Used by exact rerank (the paper's "fetch full-precision vectors and re-rank"),
brute-force ground truth, and k-means assignment during PQ training.

``||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2`` — the cross term is an MXU matmul;
norms are fused into the same kernel so each (query-block, point-block) tile
is computed entirely in VMEM with one HBM read per operand tile.

Grid: (Q / block_q, N / block_n, d / block_d) with accumulation over the
contraction dimension in a VMEM scratch accumulator (classic Pallas matmul
schedule; the d-axis is the innermost, sequential grid dimension).

Contract: ``ref.l2_distances_ref`` (see docs/KERNELS.md); parity enforced
by ``tests/test_kernels.py::test_l2_matches_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _l2_kernel(q_ref, x_ref, out_ref, acc_ref, *, n_dblocks: int):
    d_idx = pl.program_id(2)

    @pl.when(d_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                 # [BQ, BD]
    x = x_ref[...].astype(jnp.float32)                 # [BN, BD]
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [BQ, BN]
    qn = jnp.sum(q * q, axis=1, keepdims=True)         # [BQ, 1]
    xn = jnp.sum(x * x, axis=1)[None, :]               # [1, BN]
    acc_ref[...] += qn - 2.0 * cross + xn

    @pl.when(d_idx == n_dblocks - 1)
    def _done():
        out_ref[...] = jnp.maximum(acc_ref[...], 0.0)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_n", "block_d", "interpret"))
def l2_distances_kernel(queries: jax.Array, points: jax.Array, *,
                        block_q: int = 128, block_n: int = 256,
                        block_d: int = 128,
                        interpret: bool = False) -> jax.Array:
    """queries [Q, d], points [N, d] -> f32 [Q, N] squared distances."""
    Q, d = queries.shape
    N, d2 = points.shape
    assert d == d2
    assert Q % block_q == 0 and N % block_n == 0 and d % block_d == 0
    n_dblocks = d // block_d
    grid = (Q // block_q, N // block_n, n_dblocks)
    return pl.pallas_call(
        functools.partial(_l2_kernel, n_dblocks=n_dblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_d), lambda q, n, k: (q, k)),
            pl.BlockSpec((block_n, block_d), lambda q, n, k: (n, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda q, n, k: (q, n)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, block_n), jnp.float32)],
        interpret=interpret,
    )(queries, points)
