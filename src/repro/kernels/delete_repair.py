"""Pallas TPU kernel: fused Algorithm-4 repair step for a block of nodes.

Deletion repair visits every live node p with a deleted out-neighbor and
rebuilds its row from

    C  <-  (N_out(p) \\ D)  u  ( U_{v in N_out(p) n D} N_out(v) )

followed by RobustPrune (paper Algorithm 4).  The jnp engine materializes
the masked candidate list, gathers, and then pays R separate prune rounds
per node; this kernel fuses the whole block step into ONE launch: the
neighbor-of-deleted-neighbor candidate assembly (kept-edge and expansion
masks), the anchor-distance masking, all R prune rounds (shared
``robust_prune._prune_rounds``, vectorized across the block's rows), and
the final changed-row select (untouched nodes — dead, or no deleted
neighbor — keep their row).  One launch per block is the same HBM->VMEM
streaming unit as the paper's sequential SSD block pass.

The HBM gathers stay OUTSIDE the kernel (XLA gathers in the engine): the
kernel receives each node's row, its neighbors' deleted flags, the
pre-gathered expansion rows, and the candidate payloads in *raw*
``concat(row, exp)`` order.  Masked lanes carry garbage payloads and are
provably inert (their anchor distance is forced to +inf before the rounds,
and the winner one-hot never lands on them).

Flavors mirror the prune kernel: ``delete_repair_fp_kernel``
(full-precision coverage) and ``delete_repair_sdc_kernel`` (PQ-code SDC
coverage, the StreamingMerge delete-phase operating point with a capped
expansion width).

Contracts: ``ref.delete_repair_fp_ref`` / ``ref.delete_repair_sdc_ref``
(see docs/KERNELS.md); parity enforced by
``tests/test_kernels.py::test_delete_repair_fp_matches_ref`` /
``test_delete_repair_sdc_matches_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .robust_prune import _fp_cover, _prune_rounds, _sdc_cover


def _assemble(row, nbr_del, exp, exp_ok, usable_c, d_p, p):
    """Candidate assembly + anchor-distance masking (kernel-side half).

    row [B, R], nbr_del [B, R] i32, exp [B, E] i32 (pre-gathered expansion
    rows, parent-major flattened, INVALID-padded past the real E_par * R
    lanes), exp_ok [B, E] i32 (per-LANE expansion validity — the parent
    flag repeated R times, zero on padding lanes), usable_c [B, C] i32,
    d_p [B, C] f32 raw, p [B, 1] i32.
    Returns (raw_ids [B, C], d_p_masked [B, C], changed [B, 1] bool).
    """
    nd = nbr_del != 0
    keep_ok = (row >= 0) & ~nd
    raw = jnp.concatenate([row, exp], axis=1)                    # [B, C]
    src_ok = jnp.concatenate([keep_ok, (exp_ok != 0) & (exp >= 0)], axis=1)
    ok = src_ok & (usable_c != 0) & (raw != p)
    d_pm = jnp.where(ok, d_p, jnp.inf)
    changed = jnp.any(nd & (row >= 0), axis=1, keepdims=True)
    return raw, d_pm, changed


def _fp_kernel(row_ref, nd_ref, exp_ref, eok_ref, us_ref, d_ref, v_ref,
               p_ref, live_ref, out_ref, *, alpha, R):
    raw, d_pm, changed = _assemble(row_ref[...], nd_ref[...], exp_ref[...],
                                   eok_ref[...], us_ref[...], d_ref[...],
                                   p_ref[...])
    out, _ = _prune_rounds(d_pm, raw,
                           _fp_cover(v_ref[...].astype(jnp.float32)),
                           alpha=alpha, R=R)
    out_ref[...] = jnp.where(changed & (live_ref[...] != 0), out,
                             row_ref[...])


def _sdc_kernel(row_ref, nd_ref, exp_ref, eok_ref, us_ref, d_ref, c_ref,
                t_ref, p_ref, live_ref, out_ref, *, alpha, R):
    raw, d_pm, changed = _assemble(row_ref[...], nd_ref[...], exp_ref[...],
                                   eok_ref[...], us_ref[...], d_ref[...],
                                   p_ref[...])
    out, _ = _prune_rounds(d_pm, raw,
                           _sdc_cover(c_ref[...],
                                      t_ref[...].astype(jnp.float32)),
                           alpha=alpha, R=R)
    out_ref[...] = jnp.where(changed & (live_ref[...] != 0), out,
                             row_ref[...])


@functools.partial(jax.jit, static_argnames=("alpha", "R", "interpret"))
def delete_repair_fp_kernel(row, nbr_del, exp, exp_ok, usable_c, d_p, vecs,
                            p, live, *, alpha: float, R: int,
                            interpret: bool = False):
    """One block's fused repair step, full-precision coverage.

    row [B, R] i32, nbr_del [B, R] i32, exp [B, E] i32,
    exp_ok [B, E] i32 (per-lane validity, see ``_assemble``),
    usable_c [B, C] i32 with C = R + E, d_p [B, C] f32 raw,
    vecs [B, C, d] f32 (raw candidate order), p [B, 1] i32, live [B, 1]
    i32 -> new rows [B, R] i32.
    """
    B, C = d_p.shape
    assert vecs.shape[:2] == (B, C) and usable_c.shape == (B, C)
    return pl.pallas_call(
        functools.partial(_fp_kernel, alpha=alpha, R=R),
        out_shape=jax.ShapeDtypeStruct(row.shape, jnp.int32),
        interpret=interpret,
    )(row, nbr_del, exp, exp_ok, usable_c, d_p, vecs, p, live)


@functools.partial(jax.jit, static_argnames=("alpha", "R", "interpret"))
def delete_repair_sdc_kernel(row, nbr_del, exp, exp_ok, usable_c, d_p,
                             codes, tables, p, live, *, alpha: float,
                             R: int, interpret: bool = False):
    """One block's fused repair step, SDC coverage from PQ codes.

    Same operands as the fp kernel with (codes [B, C, m] i32,
    tables [m, ksub, ksub] f32) replacing vecs -> new rows [B, R] i32.
    """
    B, C = d_p.shape
    assert codes.shape[:2] == (B, C) and usable_c.shape == (B, C)
    return pl.pallas_call(
        functools.partial(_sdc_kernel, alpha=alpha, R=R),
        out_shape=jax.ShapeDtypeStruct(row.shape, jnp.int32),
        interpret=interpret,
    )(row, nbr_del, exp, exp_ok, usable_c, d_p, codes, tables, p, live)
