"""Public jit'd wrappers for the Pallas kernels.

Responsibilities: pad inputs to block multiples, pick interpret mode on CPU
(this container validates kernels with ``interpret=True``; on TPU the same
code compiles to Mosaic), and slice padding back off.  Every wrapper is
numerically interchangeable with its ``ref.py`` oracle — the per-kernel
contracts (reference, shape/dtype/padding invariants, parity tests) are
tabulated in docs/KERNELS.md.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .block_topk import block_topk_kernel
from .delete_repair import delete_repair_fp_kernel, delete_repair_sdc_kernel
from .frontier_select import frontier_select_kernel
from .l2_distance import l2_distances_kernel
from .pq_adc import adc_distances_kernel
from .robust_prune import robust_prune_fp_kernel, robust_prune_sdc_kernel


def _interpret() -> bool:
    force = os.environ.get("REPRO_PALLAS_INTERPRET")
    if force is not None:
        return force not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, fill) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q",
                                             "use_kernel"))
def adc_distances(codes: jax.Array, luts: jax.Array, *,
                  block_n: int = 128, block_q: int = 8,
                  use_kernel: bool = True) -> jax.Array:
    """codes [N, m] uint8, luts [Q, m, ksub] -> [Q, N] f32 ADC distances."""
    if not use_kernel:
        return jax.vmap(lambda t: ref.adc_distances_ref(codes, t))(luts)
    N, Q = codes.shape[0], luts.shape[0]
    c = _pad_to(codes, 0, block_n, 0)
    t = _pad_to(luts, 0, block_q, 0.0)
    out = adc_distances_kernel(c, t, block_n=block_n, block_q=block_q,
                               interpret=_interpret())
    return out[:Q, :N]


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "block_d",
                                             "use_kernel"))
def l2_distances(queries: jax.Array, points: jax.Array, *,
                 block_q: int = 128, block_n: int = 256, block_d: int = 128,
                 use_kernel: bool = True) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] squared L2."""
    if not use_kernel:
        return ref.l2_distances_ref(queries, points)
    Q, d = queries.shape
    N = points.shape[0]
    bq = min(block_q, _ceil_mult(Q, 8))
    bn = min(block_n, _ceil_mult(N, 128))
    bd = min(block_d, d)
    q = _pad_to(_pad_to(queries, 0, bq, 0.0), 1, bd, 0.0)
    x = _pad_to(_pad_to(points, 0, bn, 0.0), 1, bd, 0.0)
    out = l2_distances_kernel(q, x, block_q=bq, block_n=bn, block_d=bd,
                              interpret=_interpret())
    return out[:Q, :N]


def _ceil_mult(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("W", "max_visits", "use_kernel"))
def frontier_select(cand_ids: jax.Array, cand_d: jax.Array,
                    new_ids: jax.Array, new_d: jax.Array,
                    vis_ids: jax.Array, vis_d: jax.Array,
                    vis_cnt: jax.Array, *, W: int,
                    max_visits: int | None = None, use_kernel: bool = True):
    """Fused beam-search round step (single query lane; vmap over queries).

    Semantics are ``ref.frontier_select_ref``: merge the K fresh neighbors
    into the sorted L-entry candidate list, pick the next W-wide open
    frontier, and append it to the visited arrays — one kernel launch instead
    of the block_topk + membership + argsort sequence.

    Contract: ``vis_cnt`` must equal the number of valid (>= 0) ids in
    ``vis_ids`` — the engine maintains this by construction and the Pallas
    kernel re-derives the count from occupancy instead of taking a scalar
    operand.  Returns (merged_ids [L], merged_d [L], frontier_ids [W],
    frontier_d [W], vis_ids', vis_d', vis_cnt').
    """
    if max_visits is None:
        max_visits = vis_ids.shape[0]
    if not use_kernel:
        return ref.frontier_select_ref(cand_ids, cand_d, new_ids, new_d,
                                       vis_ids, vis_d, vis_cnt,
                                       W=W, max_visits=max_visits)
    L, V = cand_ids.shape[0], vis_ids.shape[0]
    all_d = _pad_to(jnp.concatenate([cand_d, new_d])[None, :].astype(
        jnp.float32), 1, 128, jnp.inf)
    all_i = _pad_to(jnp.concatenate([cand_ids, new_ids])[None, :], 1, 128, -1)
    vis_ip = _pad_to(vis_ids[None, :], 1, 128, -1)
    vis_dp = _pad_to(vis_d[None, :].astype(jnp.float32), 1, 128, jnp.inf)
    m_d, m_i, f_d, f_i, ov_i, ov_d = frontier_select_kernel(
        all_d, all_i, vis_ip, vis_dp, L=L, W=W, max_visits=max_visits,
        interpret=_interpret())
    n_take = jnp.sum((f_i[0] >= 0).astype(jnp.int32))
    return (m_i[0], m_d[0], f_i[0], f_d[0],
            ov_i[0, :V], ov_d[0, :V], vis_cnt + n_take)


@functools.partial(jax.jit, static_argnames=("W", "max_visits", "use_kernel"))
def frontier_select_batch(cand_ids: jax.Array, cand_d: jax.Array,
                          new_ids: jax.Array, new_d: jax.Array,
                          vis_ids: jax.Array, vis_d: jax.Array,
                          vis_cnt: jax.Array, *, W: int,
                          max_visits: int | None = None,
                          use_kernel: bool = True):
    """``frontier_select`` with an explicit query-batch leading axis.

    All operands carry a leading [B] axis (``cand_ids`` [B, L], ``new_ids``
    [B, K], ``vis_ids`` [B, V], ``vis_cnt`` [B]); the whole serving batch's
    round step is ONE kernel launch, gridded one query row per grid point —
    the same grid a ``jax.vmap`` over the single-row call lowers to, made
    explicit.  Contract: ``ref.frontier_select_batch_ref`` (the vmapped
    single-row reference); per-row results are bit-identical to B separate
    ``frontier_select`` calls.
    """
    if max_visits is None:
        max_visits = vis_ids.shape[1]
    if not use_kernel:
        return ref.frontier_select_batch_ref(
            cand_ids, cand_d, new_ids, new_d, vis_ids, vis_d, vis_cnt,
            W=W, max_visits=max_visits)
    L, V = cand_ids.shape[1], vis_ids.shape[1]
    all_d = _pad_to(jnp.concatenate(
        [cand_d, new_d], axis=1).astype(jnp.float32), 1, 128, jnp.inf)
    all_i = _pad_to(jnp.concatenate([cand_ids, new_ids], axis=1), 1, 128, -1)
    vis_ip = _pad_to(vis_ids, 1, 128, -1)
    vis_dp = _pad_to(vis_d.astype(jnp.float32), 1, 128, jnp.inf)
    m_d, m_i, f_d, f_i, ov_i, ov_d = frontier_select_kernel(
        all_d, all_i, vis_ip, vis_dp, L=L, W=W, max_visits=max_visits,
        interpret=_interpret())
    n_take = jnp.sum((f_i >= 0).astype(jnp.int32), axis=1)
    return (m_i, m_d, f_i, f_d, ov_i[:, :V], ov_d[:, :V], vis_cnt + n_take)


@functools.partial(jax.jit, static_argnames=("alpha", "R", "use_kernel"))
def robust_prune_fp(d_p: jax.Array, vecs: jax.Array, ids: jax.Array,
                    ok: jax.Array, *, alpha: float, R: int,
                    use_kernel: bool = True):
    """Fused RobustPrune rounds over a [B, C] block of nodes, full precision.

    d_p [B, C] raw anchor distances, vecs [B, C, d] candidate vectors,
    ids [B, C] int32, ok [B, C] bool -> (out_ids [B, R] INVALID-padded,
    counts [B]).  ONE kernel launch per block
    (``core.prune.robust_prune_batch``).  The candidate axis is padded to a
    128 multiple with (+inf, id -1) inert lanes; the feature axis stays
    unpadded so the per-round coverage reduction is bit-identical to the
    oracle's.
    """
    if not use_kernel:
        out, cnt = jax.vmap(lambda dp, v, i, o: ref.robust_prune_fp_ref(
            dp, v, i, o, alpha=alpha, R=R))(d_p, vecs, ids, ok)
        return out, cnt
    interp = _interpret()
    dm = jnp.where(ok, d_p.astype(jnp.float32), jnp.inf)
    vp = vecs.astype(jnp.float32)
    idsp = ids.astype(jnp.int32)
    if not interp:
        # Mosaic wants 128-multiple lanes; the interpreter does not, and
        # the pad copies are pure overhead there.  Padding lanes carry
        # (+inf, id -1, zero vectors) and are provably inert.
        dm = _pad_to(dm, 1, 128, jnp.inf)
        idsp = _pad_to(idsp, 1, 128, -1)
        vp = _pad_to(vp, 1, 128, 0.0)
    out, cnt = robust_prune_fp_kernel(dm, vp, idsp, alpha=alpha, R=R,
                                      interpret=interp)
    return out, cnt[:, 0]


@functools.partial(jax.jit, static_argnames=("alpha", "R", "use_kernel"))
def robust_prune_sdc(d_p: jax.Array, codes: jax.Array, tables: jax.Array,
                     ids: jax.Array, ok: jax.Array, *, alpha: float, R: int,
                     use_kernel: bool = True):
    """Fused RobustPrune rounds over a [B, C] block, SDC coverage.

    d_p [B, C] raw anchor distances (any source: SDC for code anchors, ADC
    for vector anchors), codes [B, C, m] candidate PQ codes,
    tables [m, ksub, ksub] from ``pq.sdc_tables`` ->
    (out_ids [B, R], counts [B]).
    """
    if not use_kernel:
        out, cnt = jax.vmap(lambda dp, c, i, o: ref.robust_prune_sdc_ref(
            dp, c, tables, i, o, alpha=alpha, R=R))(d_p, codes, ids, ok)
        return out, cnt
    interp = _interpret()
    dm = jnp.where(ok, d_p.astype(jnp.float32), jnp.inf)
    cp = codes.astype(jnp.int32)
    idsp = ids.astype(jnp.int32)
    if not interp:
        dm = _pad_to(dm, 1, 128, jnp.inf)
        idsp = _pad_to(idsp, 1, 128, -1)
        cp = _pad_to(cp, 1, 128, 0)
    out, cnt = robust_prune_sdc_kernel(dm, cp, tables.astype(jnp.float32),
                                       idsp, alpha=alpha, R=R,
                                       interpret=interp)
    return out, cnt[:, 0]


def _repair_operands(row, nbr_del, exp, exp_ok, usable_c, d_p, p, live,
                     pad_lanes: bool):
    """Engine-shaped repair inputs -> kernel lane layout (i32 flags).

    The per-parent ``exp_ok`` is flattened to per-lane so the candidate
    axis can pad to a 128 multiple for Mosaic (``pad_lanes``, compiled
    path only): padding lanes carry (exp -1, exp_ok 0, usable 0, +inf) and
    are inert through assembly and every prune round.
    """
    B, R = row.shape[:2]
    e = exp.reshape(B, -1).astype(jnp.int32)
    eok = jnp.repeat(exp_ok.astype(jnp.int32), R, axis=1)
    us = usable_c.astype(jnp.int32)
    dp = d_p.astype(jnp.float32)
    if pad_lanes:
        # C = R + E: pad the expansion lanes so C lands on a 128 multiple.
        pad = (-(R + e.shape[1])) % 128
        widths = ((0, 0), (0, pad))
        e = jnp.pad(e, widths, constant_values=-1)
        eok = jnp.pad(eok, widths, constant_values=0)
        us = jnp.pad(us, widths, constant_values=0)
        dp = jnp.pad(dp, widths, constant_values=jnp.inf)
    return (row.astype(jnp.int32), nbr_del.astype(jnp.int32), e, eok, us,
            dp, p.reshape(B, 1).astype(jnp.int32),
            live.reshape(B, 1).astype(jnp.int32))


def _pad_payload(x, pad_lanes: bool):
    """Pad a [B, C, f] candidate payload to match `_repair_operands`."""
    if not pad_lanes:
        return x
    return _pad_to(x, 1, 128, 0)


@functools.partial(jax.jit, static_argnames=("alpha", "R", "use_kernel"))
def delete_repair_fp(row, nbr_del, exp, exp_ok, usable_c, d_p, vecs, p,
                     live, *, alpha: float, R: int, use_kernel: bool = True):
    """A block's fused Algorithm-4 repair step, full precision.

    row [B, R] int32, nbr_del [B, R] bool, exp [B, E_par, R] int32
    pre-gathered expansion rows, exp_ok [B, E_par] bool, usable_c [B, C]
    bool, d_p [B, C] raw anchor distances, vecs [B, C, d] (raw
    concat(row, exp) candidate order), p [B] node ids, live [B] bool ->
    new rows [B, R].  Candidate assembly, prune rounds, and the final
    changed-row select are ONE launch per block
    (``core.delete.consolidate_deletes``).

    The contract is strictly per-row: each output row is a pure function
    of its own operand slice, never of its neighbors in the block.  That
    is what lets the localized repair mode feed GATHERED blocks — an
    arbitrary (even duplicated, for padding) set of node ids per launch —
    and still be bit-identical to the global sweep's aligned blocks
    (``core.delete`` module doc, "local" mode).
    """
    if not use_kernel:
        return jax.vmap(lambda *a: ref.delete_repair_fp_ref(
            *a, alpha=alpha, R=R))(row, nbr_del, exp, exp_ok, usable_c,
                                   d_p, vecs, p, live)
    interp = _interpret()
    r, nd, e, eok, us, dp, pp, lv = _repair_operands(
        row, nbr_del, exp, exp_ok, usable_c, d_p, p, live,
        pad_lanes=not interp)
    return delete_repair_fp_kernel(r, nd, e, eok, us, dp,
                                   _pad_payload(vecs.astype(jnp.float32),
                                                not interp), pp, lv,
                                   alpha=alpha, R=R, interpret=interp)


@functools.partial(jax.jit, static_argnames=("alpha", "R", "use_kernel"))
def delete_repair_sdc(row, nbr_del, exp, exp_ok, usable_c, d_p, codes,
                      tables, p, live, *, alpha: float, R: int,
                      use_kernel: bool = True):
    """``delete_repair_fp`` with SDC coverage (codes [B, C, m], sdc
    tables)."""
    if not use_kernel:
        return jax.vmap(lambda r_, nd, e, eok, us, dp, c, pp, lv:
                        ref.delete_repair_sdc_ref(
                            r_, nd, e, eok, us, dp, c, tables, pp, lv,
                            alpha=alpha, R=R))(
            row, nbr_del, exp, exp_ok, usable_c, d_p, codes, p, live)
    interp = _interpret()
    r, nd, e, eok, us, dp, pp, lv = _repair_operands(
        row, nbr_del, exp, exp_ok, usable_c, d_p, p, live,
        pad_lanes=not interp)
    return delete_repair_sdc_kernel(r, nd, e, eok, us, dp,
                                    _pad_payload(codes.astype(jnp.int32),
                                                 not interp),
                                    tables.astype(jnp.float32), pp, lv,
                                    alpha=alpha, R=R, interpret=interp)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def block_topk(dists: jax.Array, ids: jax.Array, k: int, *,
               block_q: int = 8, block_n: int = 512,
               use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest of [Q, N] with global ids [N]; returns ([Q,k], [Q,k])."""
    if not use_kernel:
        return ref.block_topk_ref(dists, ids, k)
    Q, N = dists.shape
    bn = min(block_n, _ceil_mult(N, 128))
    bq = min(block_q, _ceil_mult(Q, 8))
    d = _pad_to(_pad_to(dists, 0, bq, jnp.inf), 1, bn, jnp.inf)
    i = _pad_to(ids, 0, bn, -1)
    od, oi = block_topk_kernel(d, i, k=k, block_q=bq, block_n=bn,
                               interpret=_interpret())
    return od[:Q], oi[:Q]
