"""Public jit'd wrappers for the Pallas kernels.

Responsibilities: pad inputs to block multiples, pick interpret mode on CPU
(this container validates kernels with ``interpret=True``; on TPU the same
code compiles to Mosaic), and slice padding back off.  Every wrapper is
numerically interchangeable with its ``ref.py`` oracle — the per-kernel
contracts (reference, shape/dtype/padding invariants, parity tests) are
tabulated in docs/KERNELS.md.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .block_topk import block_topk_kernel
from .frontier_select import frontier_select_kernel
from .l2_distance import l2_distances_kernel
from .pq_adc import adc_distances_kernel


def _interpret() -> bool:
    force = os.environ.get("REPRO_PALLAS_INTERPRET")
    if force is not None:
        return force not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, fill) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q",
                                             "use_kernel"))
def adc_distances(codes: jax.Array, luts: jax.Array, *,
                  block_n: int = 128, block_q: int = 8,
                  use_kernel: bool = True) -> jax.Array:
    """codes [N, m] uint8, luts [Q, m, ksub] -> [Q, N] f32 ADC distances."""
    if not use_kernel:
        return jax.vmap(lambda t: ref.adc_distances_ref(codes, t))(luts)
    N, Q = codes.shape[0], luts.shape[0]
    c = _pad_to(codes, 0, block_n, 0)
    t = _pad_to(luts, 0, block_q, 0.0)
    out = adc_distances_kernel(c, t, block_n=block_n, block_q=block_q,
                               interpret=_interpret())
    return out[:Q, :N]


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "block_d",
                                             "use_kernel"))
def l2_distances(queries: jax.Array, points: jax.Array, *,
                 block_q: int = 128, block_n: int = 256, block_d: int = 128,
                 use_kernel: bool = True) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] squared L2."""
    if not use_kernel:
        return ref.l2_distances_ref(queries, points)
    Q, d = queries.shape
    N = points.shape[0]
    bq = min(block_q, _ceil_mult(Q, 8))
    bn = min(block_n, _ceil_mult(N, 128))
    bd = min(block_d, d)
    q = _pad_to(_pad_to(queries, 0, bq, 0.0), 1, bd, 0.0)
    x = _pad_to(_pad_to(points, 0, bn, 0.0), 1, bd, 0.0)
    out = l2_distances_kernel(q, x, block_q=bq, block_n=bn, block_d=bd,
                              interpret=_interpret())
    return out[:Q, :N]


def _ceil_mult(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(jax.jit, static_argnames=("W", "max_visits", "use_kernel"))
def frontier_select(cand_ids: jax.Array, cand_d: jax.Array,
                    new_ids: jax.Array, new_d: jax.Array,
                    vis_ids: jax.Array, vis_d: jax.Array,
                    vis_cnt: jax.Array, *, W: int,
                    max_visits: int | None = None, use_kernel: bool = True):
    """Fused beam-search round step (single query lane; vmap over queries).

    Semantics are ``ref.frontier_select_ref``: merge the K fresh neighbors
    into the sorted L-entry candidate list, pick the next W-wide open
    frontier, and append it to the visited arrays — one kernel launch instead
    of the block_topk + membership + argsort sequence.

    Contract: ``vis_cnt`` must equal the number of valid (>= 0) ids in
    ``vis_ids`` — the engine maintains this by construction and the Pallas
    kernel re-derives the count from occupancy instead of taking a scalar
    operand.  Returns (merged_ids [L], merged_d [L], frontier_ids [W],
    frontier_d [W], vis_ids', vis_d', vis_cnt').
    """
    if max_visits is None:
        max_visits = vis_ids.shape[0]
    if not use_kernel:
        return ref.frontier_select_ref(cand_ids, cand_d, new_ids, new_d,
                                       vis_ids, vis_d, vis_cnt,
                                       W=W, max_visits=max_visits)
    L, V = cand_ids.shape[0], vis_ids.shape[0]
    all_d = _pad_to(jnp.concatenate([cand_d, new_d])[None, :].astype(
        jnp.float32), 1, 128, jnp.inf)
    all_i = _pad_to(jnp.concatenate([cand_ids, new_ids])[None, :], 1, 128, -1)
    vis_ip = _pad_to(vis_ids[None, :], 1, 128, -1)
    vis_dp = _pad_to(vis_d[None, :].astype(jnp.float32), 1, 128, jnp.inf)
    m_d, m_i, f_d, f_i, ov_i, ov_d = frontier_select_kernel(
        all_d, all_i, vis_ip, vis_dp, L=L, W=W, max_visits=max_visits,
        interpret=_interpret())
    n_take = jnp.sum((f_i[0] >= 0).astype(jnp.int32))
    return (m_i[0], m_d[0], f_i[0], f_d[0],
            ov_i[0, :V], ov_d[0, :V], vis_cnt + n_take)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def block_topk(dists: jax.Array, ids: jax.Array, k: int, *,
               block_q: int = 8, block_n: int = 512,
               use_kernel: bool = True) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest of [Q, N] with global ids [N]; returns ([Q,k], [Q,k])."""
    if not use_kernel:
        return ref.block_topk_ref(dists, ids, k)
    Q, N = dists.shape
    bn = min(block_n, _ceil_mult(N, 128))
    bq = min(block_q, _ceil_mult(Q, 8))
    d = _pad_to(_pad_to(dists, 0, bq, jnp.inf), 1, bn, jnp.inf)
    i = _pad_to(ids, 0, bn, -1)
    od, oi = block_topk_kernel(d, i, k=k, block_q=bq, block_n=bn,
                               interpret=_interpret())
    return od[:Q], oi[:Q]
