"""Pallas TPU kernel: fused beam-search round step ("frontier select").

One launch per IO round replaces the three separate device steps the search
loop used to pay (candidate-list merge via ``block_topk``, open-mask
recompute, frontier pick via ``argsort``):

  1. **merge** — stable top-L selection over the concatenation of the sorted
     candidate list (L lanes) and the freshly scored neighbors (K lanes),
     by L rounds of (min, first-column, mask) — the same VPU-only scheme as
     ``block_topk``.
  2. **open mask** — membership test of every merged entry against the
     visited set (one [L, V] broadcast compare).
  3. **frontier pick** — the first ``min(W, max_visits - vis_cnt)`` open
     entries in ascending-distance order (the merged list is sorted, so rank
     = cumsum of the open mask).
  4. **visited update** — the frontier is appended to the visited arrays at
     positions ``vis_cnt ..`` (a vectorized one-hot scatter).

``vis_cnt`` is *derived* from visited-array occupancy (the count of valid
ids): the engine appends only valid ids contiguously from slot 0, so
occupancy == vis_cnt by construction, and the kernel needs no scalar operand
(which keeps it trivially vmappable over query lanes).

All rows are [1, N] lane vectors padded to 128 multiples by the ops wrapper;
padding lanes carry (INVALID, +inf) and are inert in every step above.  The
launch carries a leading QUERY-BATCH grid axis — one grid point per query
row — so a B-query serving batch is one launch whether it arrives as an
explicit [B, ...] call (``ops.frontier_select_batch``) or as a ``jax.vmap``
over the engine's per-query step (both lower to the same grid).

Contract: ``ref.frontier_select_ref`` (see docs/KERNELS.md); parity
enforced by ``tests/test_kernels.py::test_frontier_select_matches_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _frontier_kernel(d_ref, i_ref, vis_i_ref, vis_d_ref,
                     m_d_ref, m_i_ref, f_d_ref, f_i_ref,
                     ov_i_ref, ov_d_ref, *, L: int, W: int, max_visits: int):
    all_d = d_ref[...].astype(jnp.float32)          # [1, M]
    all_i = i_ref[...]                              # [1, M]
    M = all_d.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, M), 1)

    # -- 1. stable top-L merge (selection scheme shared with block_topk) ----
    def select(j, carry):
        cd, out_d, out_i = carry
        m = jnp.min(cd, axis=1, keepdims=True)                  # [1, 1]
        is_min = cd == m
        col = jnp.min(jnp.where(is_min, cols, M), axis=1, keepdims=True)
        sel = cols == col
        picked_i = jnp.sum(jnp.where(sel, all_i, 0), axis=1)
        out_d = jax.lax.dynamic_update_slice(out_d, m, (0, j))
        out_i = jax.lax.dynamic_update_slice(
            out_i, jnp.where(jnp.isfinite(m[:, 0]), picked_i,
                             -1)[:, None].astype(jnp.int32), (0, j))
        cd = jnp.where(sel, jnp.inf, cd)
        return cd, out_d, out_i

    init = (all_d, jnp.full((1, L), jnp.inf, jnp.float32),
            jnp.full((1, L), -1, jnp.int32))
    _, m_d, m_i = jax.lax.fori_loop(0, L, select, init)
    m_d_ref[...] = m_d
    m_i_ref[...] = m_i

    # -- 2. open mask: merged entry valid, finite, and not yet visited ------
    vis_i = vis_i_ref[...]                          # [1, Vp]
    vis_d = vis_d_ref[...]
    Vp = vis_i.shape[1]
    in_vis = (m_i.reshape(L, 1) == vis_i.reshape(1, Vp)).any(
        axis=1).reshape(1, L)
    open_ = (m_i >= 0) & jnp.isfinite(m_d) & ~in_vis            # [1, L]

    # -- 3. frontier: first `allowed` open entries (list is sorted) ---------
    vis_cnt = jnp.sum((vis_i >= 0).astype(jnp.int32))
    allowed = jnp.minimum(W, max_visits - vis_cnt)
    rank = jnp.cumsum(open_.astype(jnp.int32), axis=1) - 1      # [1, L]
    take = open_ & (rank < allowed)
    wiota = jax.lax.broadcasted_iota(jnp.int32, (L, W), 1)
    fm = take.reshape(L, 1) & (rank.reshape(L, 1) == wiota)     # [L, W]
    fvalid = fm.any(axis=0).reshape(1, W)
    f_i = jnp.where(fvalid,
                    jnp.sum(jnp.where(fm, m_i.reshape(L, 1), 0),
                            axis=0).reshape(1, W), -1)
    f_d = jnp.where(fvalid,
                    jnp.sum(jnp.where(fm, m_d.reshape(L, 1), 0.0),
                            axis=0).reshape(1, W), jnp.inf)
    f_i_ref[...] = f_i
    f_d_ref[...] = f_d

    # -- 4. visited append: one-hot scatter at slots vis_cnt.. --------------
    viota = jax.lax.broadcasted_iota(jnp.int32, (Vp, W), 0)
    slot = vis_cnt + jax.lax.broadcasted_iota(jnp.int32, (Vp, W), 1)
    match = (viota == slot) & jnp.broadcast_to(fvalid, (Vp, W))
    written = match.any(axis=1).reshape(1, Vp)
    add_i = jnp.sum(jnp.where(match, jnp.broadcast_to(f_i, (Vp, W)), 0),
                    axis=1).reshape(1, Vp)
    add_d = jnp.sum(jnp.where(match, jnp.broadcast_to(f_d, (Vp, W)), 0.0),
                    axis=1).reshape(1, Vp)
    ov_i_ref[...] = jnp.where(written, add_i, vis_i)
    ov_d_ref[...] = jnp.where(written, add_d, vis_d)


@functools.partial(
    jax.jit, static_argnames=("L", "W", "max_visits", "interpret"))
def frontier_select_kernel(all_d: jax.Array, all_i: jax.Array,
                           vis_i: jax.Array, vis_d: jax.Array, *,
                           L: int, W: int, max_visits: int,
                           interpret: bool = False):
    """all_d/all_i [B, M] merged-input lanes, vis_i/vis_d [B, Vp] visited.

    The leading axis is the QUERY-BATCH axis: one grid point per query row,
    each running the fused round step above on its own [1, ...] block —
    exactly the layout a ``jax.vmap`` over the single-row call lowers to,
    made explicit so a B-query serving batch is one launch by construction
    (``ops.frontier_select_batch``).  B=1 is the classic single-lane call.

    Returns (merged_d [B, L], merged_i [B, L], frontier_d [B, W],
    frontier_i [B, W], new_vis_i [B, Vp], new_vis_d [B, Vp]).
    """
    B, M = all_d.shape
    _, Vp = vis_i.shape
    assert all_i.shape == (B, M) and vis_d.shape == (B, Vp)
    row = lambda n: pl.BlockSpec((1, n), lambda b: (b, 0))
    return pl.pallas_call(
        functools.partial(_frontier_kernel, L=L, W=W, max_visits=max_visits),
        grid=(B,),
        in_specs=[row(M), row(M), row(Vp), row(Vp)],
        out_specs=[row(L), row(L), row(W), row(W), row(Vp), row(Vp)],
        out_shape=[
            jax.ShapeDtypeStruct((B, L), jnp.float32),
            jax.ShapeDtypeStruct((B, L), jnp.int32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.int32),
            jax.ShapeDtypeStruct((B, Vp), jnp.int32),
            jax.ShapeDtypeStruct((B, Vp), jnp.float32),
        ],
        interpret=interpret,
    )(all_d, all_i, vis_i, vis_d)
