"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (asserted by the per-kernel
allclose sweeps in ``tests/test_kernels.py``).  They are also the CPU
fallback used when a kernel is disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_distances_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """ADC: ``out[n] = sum_m lut[m, codes[n, m]]``.

    codes: uint8/int32 [N, m]; lut: f32 [m, ksub] -> f32 [N].
    """
    c = codes.astype(jnp.int32)
    m = lut.shape[0]
    return jnp.sum(lut[jnp.arange(m)[None, :], c], axis=-1).astype(jnp.float32)


def l2_distances_ref(queries: jax.Array, points: jax.Array) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] squared L2 (exact, f32 accumulation)."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    xn = jnp.sum(x * x, axis=-1)
    return jnp.maximum(qn - 2.0 * (q @ x.T) + xn[None, :], 0.0)


def block_topk_ref(dists: jax.Array, ids: jax.Array, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest distances with their ids.

    dists: f32 [Q, N]; ids: int32 [N] -> (f32 [Q, k], int32 [Q, k]) sorted
    ascending.  +inf distances lose to everything; ties broken by id order
    as produced by a stable sort on distance.
    """
    order = jnp.argsort(dists, axis=-1, stable=True)[:, :k]
    d = jnp.take_along_axis(dists, order, axis=-1)
    i = jnp.take(ids, order)
    return d, i
