"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (asserted by the per-kernel
allclose sweeps in ``tests/test_kernels.py``).  They are also the CPU
fallback used when a kernel is disabled.  docs/KERNELS.md tabulates each
contract: reference function, shape/dtype/padding invariants, and the
bit-parity test that enforces it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_distances_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """ADC: ``out[n] = sum_m lut[m, codes[n, m]]``.

    codes: uint8/int32 [N, m]; lut: f32 [m, ksub] -> f32 [N].
    """
    c = codes.astype(jnp.int32)
    m = lut.shape[0]
    return jnp.sum(lut[jnp.arange(m)[None, :], c], axis=-1).astype(jnp.float32)


def l2_distances_ref(queries: jax.Array, points: jax.Array) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] squared L2 (exact, f32 accumulation)."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    xn = jnp.sum(x * x, axis=-1)
    return jnp.maximum(qn - 2.0 * (q @ x.T) + xn[None, :], 0.0)


def frontier_select_ref(cand_ids: jax.Array, cand_d: jax.Array,
                        new_ids: jax.Array, new_d: jax.Array,
                        vis_ids: jax.Array, vis_d: jax.Array,
                        vis_cnt: jax.Array, *, W: int,
                        max_visits: int | None = None):
    """One fused beam-search round step (single query lane).

    Merges the freshly scored neighbors ``(new_ids, new_d)`` into the sorted
    candidate list ``(cand_ids, cand_d)`` (stable top-L over the [L + K]
    concatenation), computes which merged entries are still *open* (valid,
    finite, and not a member of the visited set), picks the next frontier —
    the first ``min(W, max_visits - vis_cnt)`` open entries in ascending
    distance order — and appends it to the visited arrays.

    Returns ``(merged_ids [L], merged_d [L], frontier_ids [W],
    frontier_d [W], vis_ids', vis_d', vis_cnt')``; unused frontier lanes are
    INVALID/+inf.  ``max_visits`` defaults to ``len(vis_ids)`` (callers pass
    the true bound explicitly when the visited arrays are padded).
    """
    L = cand_ids.shape[0]
    if max_visits is None:
        max_visits = vis_ids.shape[0]
    all_ids = jnp.concatenate([cand_ids, new_ids])
    all_d = jnp.concatenate([cand_d, new_d])
    order = jnp.argsort(all_d, stable=True)[:L]
    m_ids, m_d = all_ids[order], all_d[order]
    # Non-finite lanes are reported as INVALID (the engine only ever produces
    # +inf on INVALID lanes, so this is a normalization, not a change).
    m_ids = jnp.where(jnp.isfinite(m_d), m_ids, -1)

    in_vis = (m_ids[:, None] == vis_ids[None, :]).any(axis=1)
    open_ = (m_ids >= 0) & jnp.isfinite(m_d) & ~in_vis
    allowed = jnp.minimum(W, max_visits - vis_cnt)
    rank = jnp.cumsum(open_.astype(jnp.int32)) - 1
    take = open_ & (rank < allowed)
    n_take = take.sum(dtype=jnp.int32)

    fpos = jnp.argsort(~take, stable=True)[:W]        # taken slots first
    fvalid = take[fpos]
    f_ids = jnp.where(fvalid, m_ids[fpos], -1)
    f_d = jnp.where(fvalid, m_d[fpos], jnp.inf)

    wpos = jnp.where(fvalid, vis_cnt + jnp.arange(W, dtype=jnp.int32),
                     vis_ids.shape[0])
    vis_ids = vis_ids.at[wpos].set(f_ids, mode="drop")
    vis_d = vis_d.at[wpos].set(f_d, mode="drop")
    return m_ids, m_d, f_ids, f_d, vis_ids, vis_d, vis_cnt + n_take


def block_topk_ref(dists: jax.Array, ids: jax.Array, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest distances with their ids.

    dists: f32 [Q, N]; ids: int32 [N] -> (f32 [Q, k], int32 [Q, k]) sorted
    ascending.  +inf distances lose to everything; ties broken by id order
    as produced by a stable sort on distance.
    """
    order = jnp.argsort(dists, axis=-1, stable=True)[:, :k]
    d = jnp.take_along_axis(dists, order, axis=-1)
    i = jnp.take(ids, order)
    return d, i
