"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (asserted by the per-kernel
allclose sweeps in ``tests/test_kernels.py``).  They are also the CPU
fallback used when a kernel is disabled.  docs/KERNELS.md tabulates each
contract: reference function, shape/dtype/padding invariants, and the
bit-parity test that enforces it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_distances_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """ADC: ``out[n] = sum_m lut[m, codes[n, m]]``.

    codes: uint8/int32 [N, m]; lut: f32 [m, ksub] -> f32 [N].
    """
    c = codes.astype(jnp.int32)
    m = lut.shape[0]
    return jnp.sum(lut[jnp.arange(m)[None, :], c], axis=-1).astype(jnp.float32)


def l2_distances_ref(queries: jax.Array, points: jax.Array) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] squared L2 (exact, f32 accumulation)."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    xn = jnp.sum(x * x, axis=-1)
    return jnp.maximum(qn - 2.0 * (q @ x.T) + xn[None, :], 0.0)


def frontier_select_ref(cand_ids: jax.Array, cand_d: jax.Array,
                        new_ids: jax.Array, new_d: jax.Array,
                        vis_ids: jax.Array, vis_d: jax.Array,
                        vis_cnt: jax.Array, *, W: int,
                        max_visits: int | None = None):
    """One fused beam-search round step (single query lane).

    Merges the freshly scored neighbors ``(new_ids, new_d)`` into the sorted
    candidate list ``(cand_ids, cand_d)`` (stable top-L over the [L + K]
    concatenation), computes which merged entries are still *open* (valid,
    finite, and not a member of the visited set), picks the next frontier —
    the first ``min(W, max_visits - vis_cnt)`` open entries in ascending
    distance order — and appends it to the visited arrays.

    Returns ``(merged_ids [L], merged_d [L], frontier_ids [W],
    frontier_d [W], vis_ids', vis_d', vis_cnt')``; unused frontier lanes are
    INVALID/+inf.  ``max_visits`` defaults to ``len(vis_ids)`` (callers pass
    the true bound explicitly when the visited arrays are padded).
    """
    L = cand_ids.shape[0]
    if max_visits is None:
        max_visits = vis_ids.shape[0]
    all_ids = jnp.concatenate([cand_ids, new_ids])
    all_d = jnp.concatenate([cand_d, new_d])
    order = jnp.argsort(all_d, stable=True)[:L]
    m_ids, m_d = all_ids[order], all_d[order]
    # Non-finite lanes are reported as INVALID (the engine only ever produces
    # +inf on INVALID lanes, so this is a normalization, not a change).
    m_ids = jnp.where(jnp.isfinite(m_d), m_ids, -1)

    in_vis = (m_ids[:, None] == vis_ids[None, :]).any(axis=1)
    open_ = (m_ids >= 0) & jnp.isfinite(m_d) & ~in_vis
    allowed = jnp.minimum(W, max_visits - vis_cnt)
    rank = jnp.cumsum(open_.astype(jnp.int32)) - 1
    take = open_ & (rank < allowed)
    n_take = take.sum(dtype=jnp.int32)

    fpos = jnp.argsort(~take, stable=True)[:W]        # taken slots first
    fvalid = take[fpos]
    f_ids = jnp.where(fvalid, m_ids[fpos], -1)
    f_d = jnp.where(fvalid, m_d[fpos], jnp.inf)

    wpos = jnp.where(fvalid, vis_cnt + jnp.arange(W, dtype=jnp.int32),
                     vis_ids.shape[0])
    vis_ids = vis_ids.at[wpos].set(f_ids, mode="drop")
    vis_d = vis_d.at[wpos].set(f_d, mode="drop")
    return m_ids, m_d, f_ids, f_d, vis_ids, vis_d, vis_cnt + n_take


def frontier_select_batch_ref(cand_ids: jax.Array, cand_d: jax.Array,
                              new_ids: jax.Array, new_d: jax.Array,
                              vis_ids: jax.Array, vis_d: jax.Array,
                              vis_cnt: jax.Array, *, W: int,
                              max_visits: int | None = None):
    """The query-batched contract: ``frontier_select_ref`` vmapped over a
    leading [B] axis — each query row's round step is independent, so the
    batched kernel (one grid point per row) must match this bit-for-bit.
    """
    import functools
    return jax.vmap(functools.partial(
        frontier_select_ref, W=W, max_visits=max_visits))(
        cand_ids, cand_d, new_ids, new_d, vis_ids, vis_d, vis_cnt)


def _sdc_cover_row(tables: jax.Array, codes: jax.Array, star: jax.Array
                   ) -> jax.Array:
    """SDC distances from candidate ``star`` to every candidate.

    ``tables`` [m, ksub, ksub] centroid-pair squared distances
    (``pq.sdc_tables``), ``codes`` [C, m] int32.  Op-for-op identical to
    ``pq.adc(codes, pq.sdc_lut(tables, codes[star]))`` — the gather order and
    the final sum over the m-axis must not drift, they are the bit-parity
    contract the Pallas kernel reproduces with one-hot contractions.
    """
    m = tables.shape[0]
    lut = tables[jnp.arange(m), codes[star]]                 # [m, ksub]
    gathered = lut[jnp.arange(m)[None, :], codes]            # [C, m]
    return jnp.sum(gathered, axis=-1)


def robust_prune_fp_ref(d_p: jax.Array, vecs: jax.Array, ids: jax.Array,
                        ok: jax.Array, *, alpha: float, R: int
                        ) -> tuple[jax.Array, jax.Array]:
    """RobustPrune (Algorithm 3) rounds over one candidate row, full precision.

    d_p [C] raw anchor->candidate distances (masked to +inf where ``~ok``),
    vecs [C, d] candidate vectors (garbage on masked lanes — never selected),
    ids [C] int32 candidate ids.  Runs exactly R rounds: masked argmin picks
    the closest alive candidate, its id is emitted, and every candidate it
    alpha-covers (``alpha * d(star, c) <= d(p, c)``) is retired.  Returns
    (out_ids [R] INVALID-padded, count scalar int32).

    This is the mutation-engine oracle: ``core.prune.robust_prune`` delegates
    here, and the fused Pallas kernel must match it bit-for-bit.
    """
    C = ids.shape[0]
    vecs = vecs.astype(jnp.float32)
    d_p = jnp.where(ok, d_p.astype(jnp.float32), jnp.inf)

    def body(i, s):
        alive, out_ids, cnt = s
        masked = jnp.where(alive, d_p, jnp.inf)
        star = jnp.argmin(masked)
        okr = jnp.isfinite(masked[star])
        out_ids = out_ids.at[i].set(jnp.where(okr, ids[star], -1))
        cnt = cnt + okr.astype(jnp.int32)
        diff = vecs[star][None, :] - vecs
        d_star = jnp.sum(diff * diff, axis=-1)               # [C]
        covered = alpha * d_star <= d_p
        alive = alive & ~covered & (jnp.arange(C) != star)
        alive = jnp.where(okr, alive, jnp.zeros_like(alive))
        return alive, out_ids, cnt

    alive0 = ok & jnp.isfinite(d_p)
    out0 = jnp.full((R,), -1, jnp.int32)
    _, out_ids, cnt = jax.lax.fori_loop(0, R, body,
                                        (alive0, out0, jnp.int32(0)))
    return out_ids, cnt


def robust_prune_sdc_ref(d_p: jax.Array, codes: jax.Array, tables: jax.Array,
                         ids: jax.Array, ok: jax.Array, *, alpha: float,
                         R: int) -> tuple[jax.Array, jax.Array]:
    """RobustPrune rounds with candidate-candidate distances from PQ codes.

    Same round structure as ``robust_prune_fp_ref`` but every coverage
    distance is symmetric-distance-computed from ``codes`` [C, m] int32 via
    ``tables`` [m, ksub, ksub] — the StreamingMerge operating point (one byte
    per subspace per candidate per round instead of dsub*4).
    """
    C = ids.shape[0]
    codes = codes.astype(jnp.int32)
    d_p = jnp.where(ok, d_p.astype(jnp.float32), jnp.inf)

    def body(i, s):
        alive, out_ids, cnt = s
        masked = jnp.where(alive, d_p, jnp.inf)
        star = jnp.argmin(masked)
        okr = jnp.isfinite(masked[star])
        out_ids = out_ids.at[i].set(jnp.where(okr, ids[star], -1))
        cnt = cnt + okr.astype(jnp.int32)
        d_star = _sdc_cover_row(tables, codes, star)
        covered = alpha * d_star <= d_p
        alive = alive & ~covered & (jnp.arange(C) != star)
        alive = jnp.where(okr, alive, jnp.zeros_like(alive))
        return alive, out_ids, cnt

    alive0 = ok & jnp.isfinite(d_p)
    out0 = jnp.full((R,), -1, jnp.int32)
    _, out_ids, cnt = jax.lax.fori_loop(0, R, body,
                                        (alive0, out0, jnp.int32(0)))
    return out_ids, cnt


def delete_repair_assemble_ref(row: jax.Array, nbr_del: jax.Array,
                               exp: jax.Array, exp_ok: jax.Array,
                               usable_c: jax.Array, p: jax.Array
                               ) -> tuple[jax.Array, jax.Array]:
    """Algorithm-4 candidate assembly for one node (shared contract half).

    row [R] out-neighbors, nbr_del [R] bool (neighbor is deleted), exp
    [E_par, R] neighbor-of-deleted-neighbor rows, exp_ok [E_par] bool (the
    expansion parent is a valid deleted neighbor), usable_c [C] bool gathered
    usability of the raw concatenated candidates, p scalar node id.  Returns
    (cand_ids [C] with INVALID on masked lanes, ok [C]) where
    C = R + E_par * R: kept-edge lanes are valid when the edge exists and its
    target is NOT deleted; expansion lanes when their parent IS deleted.
    """
    valid = row >= 0
    keep_ok = valid & ~nbr_del
    exp_flat = exp.reshape(-1)
    exp_flat_ok = jnp.repeat(exp_ok, exp.shape[1]) & (exp_flat >= 0)
    raw = jnp.concatenate([row, exp_flat])
    src_ok = jnp.concatenate([keep_ok, exp_flat_ok])
    ok = src_ok & usable_c & (raw != p)
    return jnp.where(src_ok, raw, -1), ok


def delete_repair_fp_ref(row, nbr_del, exp, exp_ok, usable_c, d_p, vecs,
                         p, live, *, alpha: float, R: int) -> jax.Array:
    """Fused Algorithm-4 block step for one node, full precision.

    Assembles the repair candidate set (kept live edges + neighbors of
    deleted neighbors), RobustPrunes it, and emits the new adjacency row —
    unchanged when the node is dead or had no deleted neighbor (the
    Algorithm-4 loop set).  Inputs are pre-gathered by the ops wrapper
    (vecs/d_p/usable_c follow the *raw* concat(row, exp) candidate order;
    masked lanes carry garbage and are inert).  Returns the new row [R].
    """
    cand_ids, ok = delete_repair_assemble_ref(row, nbr_del, exp, exp_ok,
                                              usable_c, p)
    new_row, _ = robust_prune_fp_ref(d_p, vecs, cand_ids, ok,
                                     alpha=alpha, R=R)
    changed = live & (nbr_del & (row >= 0)).any()
    return jnp.where(changed, new_row, row)


def delete_repair_sdc_ref(row, nbr_del, exp, exp_ok, usable_c, d_p, codes,
                          tables, p, live, *, alpha: float, R: int
                          ) -> jax.Array:
    """``delete_repair_fp_ref`` with SDC coverage distances from PQ codes."""
    cand_ids, ok = delete_repair_assemble_ref(row, nbr_del, exp, exp_ok,
                                              usable_c, p)
    new_row, _ = robust_prune_sdc_ref(d_p, codes, tables, cand_ids, ok,
                                      alpha=alpha, R=R)
    changed = live & (nbr_del & (row >= 0)).any()
    return jnp.where(changed, new_row, row)


def block_topk_ref(dists: jax.Array, ids: jax.Array, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest distances with their ids.

    dists: f32 [Q, N]; ids: int32 [N] -> (f32 [Q, k], int32 [Q, k]) sorted
    ascending.  +inf distances lose to everything; ties broken by id order
    as produced by a stable sort on distance.
    """
    order = jnp.argsort(dists, axis=-1, stable=True)[:, :k]
    d = jnp.take_along_axis(dists, order, axis=-1)
    i = jnp.take(ids, order)
    return d, i
