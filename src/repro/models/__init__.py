"""Model zoo for the assigned architectures.

  transformer.py — dense + MoE decoder LMs (GQA, qk-norm, QKV bias, RoPE,
                   RMSNorm, SwiGLU, sliding-window / local:global patterns,
                   ring-buffer KV caches, chunked flash-style attention).
  moe.py         — group-local top-k dispatch MoE (GShard-style capacity,
                   sort-free position assignment, EP/TP shardable einsums).
  gnn.py         — GraphSAGE: segment_sum message passing, fanout sampler.
  recsys.py      — EmbeddingBag, FM / DeepFM / xDeepFM (CIN) / SASRec.
"""
