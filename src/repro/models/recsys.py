"""Recsys model family: FM, DeepFM, xDeepFM (CIN), SASRec.

The hot path is the sparse-embedding lookup.  JAX has no EmbeddingBag — it is
built here from ``jnp.take`` + ``jax.ops.segment_sum`` (per the assignment);
tables are row-sharded over the mesh and lookups shard over the batch.

The paper's technique plugs in at the ``retrieval_cand`` shape: the
factorized (dot-product) part of each model scores a million candidates
through the FreshDiskANN index (or an exact batched dot as the baseline);
non-factorized interactions (CIN / MLP) re-score the shortlist — the paper's
PQ-navigate-then-rerank pattern at the model level.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                      # fm | deepfm | xdeepfm | sasrec
    n_sparse: int = 39             # number of categorical fields
    rows_per_field: int = 100_000  # hash-bucket rows per field
    embed_dim: int = 10
    mlp: Tuple[int, ...] = ()
    cin_layers: Tuple[int, ...] = ()
    # sasrec
    n_items: int = 1_000_000
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.rows_per_field


# ---------------------------------------------------------------------------
# EmbeddingBag (take + segment_sum)
# ---------------------------------------------------------------------------

def embedding_bag(table: jax.Array, ids: jax.Array, segments: jax.Array,
                  n_segments: int, mode: str = "sum") -> jax.Array:
    """Generic EmbeddingBag: ids int32 [K], segments int32 [K] (which bag each
    id belongs to) -> [n_segments, d].  mode: sum | mean."""
    vecs = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(vecs, segments, num_segments=n_segments)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), segments,
                                  num_segments=n_segments)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def field_lookup(table: jax.Array, ids: jax.Array,
                 cfg: RecsysConfig) -> jax.Array:
    """One-id-per-field lookup: ids [B, n_sparse] (already offset per field)
    -> [B, n_sparse, d].  The common Criteo-style fast path."""
    return jnp.take(table, ids, axis=0)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_recsys_params(key: jax.Array, cfg: RecsysConfig):
    ks = jax.random.split(key, 8)
    if cfg.kind == "sasrec":
        d = cfg.embed_dim
        blocks = []
        for i in range(cfg.n_blocks):
            bk = jax.random.split(ks[2 + i], 6)
            s = d ** -0.5
            blocks.append({
                "wq": jax.random.normal(bk[0], (d, d)) * s,
                "wk": jax.random.normal(bk[1], (d, d)) * s,
                "wv": jax.random.normal(bk[2], (d, d)) * s,
                "w1": jax.random.normal(bk[3], (d, d)) * s,
                "w2": jax.random.normal(bk[4], (d, d)) * s,
                "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
            })
        return {
            "item_emb": jax.random.normal(ks[0], (cfg.n_items, d)) * 0.01,
            "pos_emb": jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.01,
            "blocks": blocks,
        }

    rows, d = cfg.total_rows, cfg.embed_dim
    p = {
        "w0": jnp.zeros(()),
        "w_lin": jax.random.normal(ks[0], (rows,)) * 0.01,
        "V": jax.random.normal(ks[1], (rows, d)) * 0.01,
    }
    if cfg.mlp:
        dims = [cfg.n_sparse * d] + list(cfg.mlp) + [1]
        mlp = []
        for i in range(len(dims) - 1):
            mlp.append({
                "w": jax.random.normal(ks[2], (dims[i], dims[i + 1]))
                * dims[i] ** -0.5,
                "b": jnp.zeros((dims[i + 1],)),
            })
        p["mlp"] = mlp
    if cfg.cin_layers:
        hs = [cfg.n_sparse] + list(cfg.cin_layers)
        cin = []
        for i in range(len(cfg.cin_layers)):
            cin.append(jax.random.normal(
                ks[3], (hs[i + 1], hs[i], cfg.n_sparse))
                * (hs[i] * cfg.n_sparse) ** -0.5)
        p["cin"] = cin
        p["cin_head"] = jax.random.normal(
            ks[4], (sum(cfg.cin_layers),)) * 0.01
    return p


# ---------------------------------------------------------------------------
# FM family forwards
# ---------------------------------------------------------------------------

def fm_interaction(emb: jax.Array) -> jax.Array:
    """O(n*k) sum-square trick: 0.5 * sum_k((Σ_i v_ik)^2 - Σ_i v_ik^2)."""
    s = emb.sum(axis=-2)
    sq = (emb * emb).sum(axis=-2)
    return 0.5 * (s * s - sq).sum(axis=-1)


def _mlp_apply(mlp, x):
    for i, lp in enumerate(mlp):
        x = x @ lp["w"] + lp["b"]
        if i < len(mlp) - 1:
            x = jax.nn.relu(x)
    return x[..., 0]


def _cin_apply(cin, cin_head, emb):
    """Compressed Interaction Network (xDeepFM §3): x0 [B, m, d]."""
    x0 = emb
    xk = emb
    pooled = []
    for w in cin:                                 # w: [H_k, H_{k-1}, m]
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        xk = jnp.einsum("bhmd,khm->bkd", z, w)
        pooled.append(xk.sum(axis=-1))            # [B, H_k]
    return jnp.concatenate(pooled, axis=-1) @ cin_head


def recsys_forward(params, ids: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """ids int32 [B, n_sparse] (pre-offset per field) -> logits [B]."""
    emb = field_lookup(params["V"], ids, cfg)              # [B, m, d]
    lin = jnp.take(params["w_lin"], ids, axis=0).sum(-1)   # [B]
    out = params["w0"] + lin
    if cfg.kind in ("fm", "deepfm"):
        out = out + fm_interaction(emb)
    if cfg.kind in ("deepfm", "xdeepfm") and cfg.mlp:
        out = out + _mlp_apply(params["mlp"],
                               emb.reshape(emb.shape[0], -1))
    if cfg.kind == "xdeepfm" and cfg.cin_layers:
        out = out + _cin_apply(params["cin"], params["cin_head"], emb)
    return out


def recsys_loss(params, ids, labels, cfg: RecsysConfig):
    logits = recsys_forward(params, ids, cfg)
    return jnp.mean(
        jax.nn.softplus(logits) - labels.astype(jnp.float32) * logits)


# ---------------------------------------------------------------------------
# SASRec
# ---------------------------------------------------------------------------

def sasrec_encode(params, seq: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """seq int32 [B, S] (0 = padding) -> hidden [B, S, d]."""
    B, S = seq.shape
    d = cfg.embed_dim
    h = jnp.take(params["item_emb"], seq, axis=0) * (d ** 0.5)
    h = h + params["pos_emb"][None, :S]
    pad = seq == 0
    h = jnp.where(pad[..., None], 0.0, h)
    causal = jnp.tril(jnp.ones((S, S), bool))
    for bp in params["blocks"]:
        hn = _layer_norm(h, bp["ln1"])
        q, k, v = hn @ bp["wq"], hn @ bp["wk"], hn @ bp["wv"]
        s = jnp.einsum("bqd,bkd->bqk", q, k) / (d ** 0.5)
        s = jnp.where(causal[None] & ~pad[:, None, :], s, -1e30)
        h = h + jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, axis=-1), v)
        hn = _layer_norm(h, bp["ln2"])
        h = h + jax.nn.relu(hn @ bp["w1"]) @ bp["w2"]
        h = jnp.where(pad[..., None], 0.0, h)
    return h


def _layer_norm(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * (1.0 + scale)


def sasrec_loss(params, seq, pos_items, neg_items, cfg: RecsysConfig):
    """BPR-style loss with sampled negatives (SASRec §3.5).
    seq [B, S]; pos/neg [B, S] targets per position."""
    h = sasrec_encode(params, seq, cfg)
    pe = jnp.take(params["item_emb"], pos_items, axis=0)
    ne = jnp.take(params["item_emb"], neg_items, axis=0)
    ps = (h * pe).sum(-1)
    ns = (h * ne).sum(-1)
    mask = (pos_items != 0).astype(jnp.float32)
    loss = jax.nn.softplus(-(ps - ns)) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)


def sasrec_user_embedding(params, seq: jax.Array, cfg: RecsysConfig):
    """Final-position hidden state — the retrieval query vector."""
    return sasrec_encode(params, seq, cfg)[:, -1]


# ---------------------------------------------------------------------------
# Retrieval scoring (the paper-technique integration point)
# ---------------------------------------------------------------------------

def retrieval_scores(query_vecs: jax.Array, item_table: jax.Array
                     ) -> jax.Array:
    """Exact candidate scoring: [B, d] x [C, d] -> [B, C] inner products.
    The ANN path replaces this with a FreshDiskANN search over ``item_table``
    (see examples/sasrec_retrieval.py); this is the brute-force baseline.

    Scores are sharded (batch x model) — at serve_bulk scale the matrix is
    [262144, 1M] and must never be replicated."""
    from ..distributed.ctx import shard_act
    scores = jnp.einsum("bd,cd->bc", query_vecs, item_table)
    return shard_act(scores, "batch", "model")


def retrieval_topk(query_vecs: jax.Array, item_table: jax.Array, k: int,
                   n_blocks: int = 16):
    """Two-stage top-k: per-block (shard-local) top-k, then a tiny global
    top-k over n_blocks*k survivors.  A direct lax.top_k over the
    model-sharded candidate axis makes XLA replicate the full [B, C] score
    matrix (1 TiB at serve_bulk scale)."""
    from ..distributed.ctx import shard_act
    scores = retrieval_scores(query_vecs, item_table)
    B, C = scores.shape
    if C % n_blocks == 0 and C // n_blocks >= k:
        blk = C // n_blocks
        s = shard_act(scores.reshape(B, n_blocks, blk),
                      "batch", "model", None)
        d, i = jax.lax.top_k(s, k)                       # [B, nb, k]
        i = i + (jnp.arange(n_blocks) * blk)[None, :, None]
        d2, sel = jax.lax.top_k(d.reshape(B, -1), k)
        return d2, jnp.take_along_axis(i.reshape(B, -1), sel, axis=-1)
    return jax.lax.top_k(scores, k)
