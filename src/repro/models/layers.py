"""Shared transformer building blocks (pure functions over param pytrees).

Everything is written against stacked-layer parameter trees so the decoder
can ``lax.scan`` over layers; all shapes are static; dtypes follow the
config's activation dtype with f32 normalization/softmax accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., S, H, dh]; positions: broadcastable [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq   # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: silu(x @ w_gate) * (x @ w_up) @ w_down."""
    dtype = x.dtype
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(dtype))


def _softmax_f32(scores: jax.Array) -> jax.Array:
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


def _band_geometry(S: int, window: int, q_chunk: int, kv_chunk: int):
    if window and window < S:
        # the band must cover [q_start - window + 1, q_start + q_chunk):
        # width q_chunk + window - 1, plus kv_chunk alignment slack
        band_blocks = min((window + q_chunk) // kv_chunk + 2, S // kv_chunk)
    else:
        band_blocks = S // kv_chunk
    return band_blocks, band_blocks * kv_chunk


def _band_start(qi, S, band, q_chunk, kv_chunk):
    band_end = (qi + 1) * q_chunk
    start = jnp.maximum(band_end - band, 0)
    start = (start // kv_chunk) * kv_chunk
    return jnp.minimum(start, S - band)


def _block_mask(q_pos, k_pos, window):
    mask = k_pos[None, :] <= q_pos[:, None]                  # causal
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return mask


def _flash_fwd_impl(q5, k, v, window, q_chunk, kv_chunk,
                    p_dtype=jnp.float32):
    """q5 [B, S, KV, G, dh]; returns (out5 [B, S, KV, G, dh],
    lse [B, n_q, qc, KV, G] f32).

    Query chunks are processed by ``vmap`` (not scan) so the chunk axis can
    be sharded over the 'model' mesh axis — sequence parallelism: every
    chip owns S/|model| query rows while k/v are gathered per layer.
    """
    from ..distributed.ctx import shard_act

    B, S, KV, G, dh = q5.shape
    scale = dh ** -0.5
    n_q = S // q_chunk
    band_blocks, band = _band_geometry(S, window, q_chunk, kv_chunk)
    qg = q5.reshape(B, n_q, q_chunk, KV, G, dh)
    qg = shard_act(qg, "batch", "model", None, None, None, None)
    k = shard_act(k, "batch", None, None, None)
    v = shard_act(v, "batch", None, None, None)

    def one_q_chunk(qi, qc_):
        # qc_: [B, qc, KV, G, dh]
        start = _band_start(qi, S, band, q_chunk, kv_chunk)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kb = jnp.moveaxis(kb.reshape(B, band_blocks, kv_chunk, KV, dh), 1, 0)
        vb = jnp.moveaxis(vb.reshape(B, band_blocks, kv_chunk, KV, dh), 1, 0)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def one_kv_block(carry, binp):
            m, l, acc = carry
            kj, vj, blk = binp
            k_pos = start + blk * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qc_, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, window)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked blocks: exp(-inf - -inf) would be NaN
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(jnp.isfinite(s),
                          jnp.exp(s - m_safe[..., None]), 0.0
                          ).astype(p_dtype)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.astype(jnp.float32).sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        init = (
            jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, KV, G, q_chunk), jnp.float32),
            jnp.zeros((B, KV, G, q_chunk, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            one_kv_block, init, (kb, vb, jnp.arange(band_blocks)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
        # out [B, KV, G, qc, dh] -> [B, qc, KV, G, dh]; lse -> [B, qc, KV, G]
        return jnp.moveaxis(out, 3, 1), jnp.moveaxis(lse, 3, 1)

    outs, lses = jax.vmap(one_q_chunk, in_axes=(0, 1), out_axes=(1, 1))(
        jnp.arange(n_q), qg)
    out5 = outs.reshape(B, S, KV, G, dh)
    return out5, lses


def _flash_bwd_impl(window, q_chunk, kv_chunk, p_dtype, res,
                    dout5):
    """FlashAttention-2 two-pass backward: pass 1 vmaps query chunks
    (computes dq), pass 2 vmaps kv blocks (computes dk, dv).  Both vmapped
    axes are shardable; no O(S) accumulator is carried through a scan and
    no stacked score tensors are saved."""
    from ..distributed.ctx import shard_act

    q5, k, v, out5, lse = res        # lse [B, n_q, qc, KV, G]
    B, S, KV, G, dh = q5.shape
    scale = dh ** -0.5
    n_q = S // q_chunk
    band_blocks, band = _band_geometry(S, window, q_chunk, kv_chunk)
    dout5 = dout5.astype(jnp.float32)
    delta = jnp.sum(dout5 * out5.astype(jnp.float32), axis=-1)  # [B,S,KV,G]

    qg = q5.reshape(B, n_q, q_chunk, KV, G, dh)
    qg = shard_act(qg, "batch", "model", None, None, None, None)
    dog = dout5.reshape(B, n_q, q_chunk, KV, G, dh)
    dog = shard_act(dog, "batch", "model", None, None, None, None)
    deltag = delta.reshape(B, n_q, q_chunk, KV, G)
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)
    k = shard_act(k, "batch", None, None, None)
    v = shard_act(v, "batch", None, None, None)

    # ---- pass 1: dq, vmapped over query chunks --------------------------
    def dq_chunk(qi, qc_, doc, dlc, lsec):
        start = _band_start(qi, S, band, q_chunk, kv_chunk)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kb = jnp.moveaxis(kb.reshape(B, band_blocks, kv_chunk, KV, dh), 1, 0)
        vb = jnp.moveaxis(vb.reshape(B, band_blocks, kv_chunk, KV, dh), 1, 0)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        lse_q = jnp.moveaxis(lsec, 1, -1)            # [B, KV, G, qc]
        dl_q = jnp.moveaxis(dlc, 1, -1)

        def one_kv_block(dq_c, binp):
            kj, vj, blk = binp
            k_pos = start + blk * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qc_, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, window)[None, None, None]
            p = jnp.where(mask, jnp.exp(s - lse_q[..., None]), 0.0)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", doc.astype(p_dtype),
                            vj.astype(p_dtype),
                            preferred_element_type=jnp.float32)
            ds = (p * (dp - dl_q[..., None]) * scale).astype(p_dtype)
            dq_c = dq_c + jnp.einsum("bkgqc,bckd->bqkgd", ds,
                                     kj.astype(p_dtype),
                                     preferred_element_type=jnp.float32)
            return dq_c, None

        dq0 = jnp.zeros((B, q_chunk, KV, G, dh), jnp.float32)
        dq_c, _ = jax.lax.scan(one_kv_block, dq0,
                               (kb, vb, jnp.arange(band_blocks)))
        return dq_c

    dqs = jax.vmap(dq_chunk, in_axes=(0, 1, 1, 1, 1), out_axes=1)(
        jnp.arange(n_q), qg, dog, deltag, lse_safe)
    dq = dqs.reshape(B, S, KV, G, dh)

    # ---- pass 2: dk/dv, vmapped over kv blocks --------------------------
    # q/dout/delta/lse are gathered over 'model' first (one explicit
    # all-gather per layer) so the per-kv-block dynamic slices are local;
    # without this, SPMD falls into involuntary full rematerialization.
    n_kv = S // kv_chunk
    if window and window < S:
        qband_blocks = min((window + kv_chunk) // q_chunk + 2, n_q)
    else:
        qband_blocks = n_q
    qband = qband_blocks * q_chunk
    q_flat = shard_act(q5.astype(p_dtype), "batch", None, None, None,
                       None)
    do_flat = shard_act(dout5.astype(p_dtype), "batch", None, None, None,
                        None)
    dl_flat = shard_act(delta, "batch", None, None, None)
    ls_flat = shard_act(lse_safe.reshape(B, S, KV, G),
                        "batch", None, None, None)
    kg = shard_act(k.reshape(B, n_kv, kv_chunk, KV, dh),
                   "batch", "model", None, None, None)
    vg = shard_act(v.reshape(B, n_kv, kv_chunk, KV, dh),
                   "batch", "model", None, None, None)

    def dkv_block(ki, kj, vj):
        k_start = ki * kv_chunk
        # query rows attending this block: [k_start, k_start + kvc + window)
        qs = jnp.minimum((k_start // q_chunk) * q_chunk, S - qband)
        qb = jax.lax.dynamic_slice_in_dim(q_flat, qs, qband, axis=1)
        dob = jax.lax.dynamic_slice_in_dim(do_flat, qs, qband, axis=1)
        dlb = jax.lax.dynamic_slice_in_dim(dl_flat, qs, qband, axis=1)
        lsb = jax.lax.dynamic_slice_in_dim(ls_flat, qs, qband, axis=1)
        qb = jnp.moveaxis(
            qb.reshape(B, qband_blocks, q_chunk, KV, G, dh), 1, 0)
        dob = jnp.moveaxis(
            dob.reshape(B, qband_blocks, q_chunk, KV, G, dh), 1, 0)
        dlb = jnp.moveaxis(
            dlb.reshape(B, qband_blocks, q_chunk, KV, G), 1, 0)
        lsb = jnp.moveaxis(
            lsb.reshape(B, qband_blocks, q_chunk, KV, G), 1, 0)
        k_pos = k_start + jnp.arange(kv_chunk)

        def one_q_blk(carry, binp):
            dk_b, dv_b = carry                      # [B, kvc, KV, dh] f32
            qj, doj, dlj, lsj, blk = binp
            q_pos = qs + blk * q_chunk + jnp.arange(q_chunk)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qj, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(q_pos, k_pos, window)[None, None, None]
            lse_q = jnp.moveaxis(lsj, 1, -1)        # [B, KV, G, qc]
            dl_q = jnp.moveaxis(dlj, 1, -1)
            p = jnp.where(mask, jnp.exp(s - lse_q[..., None]), 0.0
                          ).astype(p_dtype)
            dv_b = dv_b + jnp.einsum("bkgqc,bqkgd->bckd", p,
                                     doj.astype(p_dtype),
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", doj.astype(p_dtype),
                            vj.astype(p_dtype),
                            preferred_element_type=jnp.float32)
            ds = (p.astype(jnp.float32) * (dp - dl_q[..., None]) * scale
                  ).astype(p_dtype)
            dk_b = dk_b + jnp.einsum("bkgqc,bqkgd->bckd", ds,
                                     qj.astype(p_dtype),
                                     preferred_element_type=jnp.float32)
            return (dk_b, dv_b), None

        init = (jnp.zeros((B, kv_chunk, KV, dh), jnp.float32),
                jnp.zeros((B, kv_chunk, KV, dh), jnp.float32))
        (dk_b, dv_b), _ = jax.lax.scan(
            one_q_blk, init,
            (qb, dob, dlb, lsb, jnp.arange(qband_blocks)))
        return dk_b, dv_b

    dks, dvs = jax.vmap(dkv_block, in_axes=(0, 1, 1), out_axes=1)(
        jnp.arange(n_kv), kg, vg)
    dk = dks.reshape(B, S, KV, dh)
    dv = dvs.reshape(B, S, KV, dh)
    return dq.astype(q5.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q5, k, v, window, q_chunk, kv_chunk, p_dtype):
    out5, _ = _flash_fwd_impl(q5, k, v, window, q_chunk, kv_chunk, p_dtype)
    return out5.astype(q5.dtype)


def _flash_fwd(q5, k, v, window, q_chunk, kv_chunk, p_dtype):
    out5, lse = _flash_fwd_impl(q5, k, v, window, q_chunk, kv_chunk,
                                p_dtype)
    out5 = out5.astype(q5.dtype)
    return out5, (q5, k, v, out5, lse)


_flash.defvjp(_flash_fwd, _flash_bwd_impl)


def chunked_attention(
    q: jax.Array,          # [B, S, H, dh]  (RoPE already applied)
    k: jax.Array,          # [B, S, KV, dh]
    v: jax.Array,          # [B, S, KV, dh]
    *,
    window: int = 0,       # 0 = full causal; >0 = sliding window
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    p_dtype="float32",     # dtype score/probability blocks are stored in;
    #                        bf16 halves the dominant attention HBM traffic
) -> jax.Array:
    """Flash attention in pure JAX with a custom VJP.

    Forward: scan over query chunks, inner scan over key blocks with running
    (max, denom) — the [S, S] score matrix is never materialized.  Backward:
    FlashAttention-2 style blockwise recomputation from the saved logsumexp,
    so reverse-mode does NOT stack per-block score residuals (the default
    scan VJP would save O(S^2) f32 per layer).

    For windowed layers only the diagonal band of kv blocks is visited
    (``window // kv_chunk + 2`` blocks per query chunk via dynamic_slice).
    """
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV                                  # GQA group size
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    assert S % q_chunk == 0 and S % kv_chunk == 0
    q5 = q.reshape(B, S, KV, G, dh)
    out5 = _flash(q5, k, v, window, q_chunk, kv_chunk,
                  jnp.dtype(p_dtype))
    return out5.reshape(B, S, H, dh)


def decode_attention(
    q: jax.Array,            # [B, 1, H, dh] (RoPE applied)
    k_cache: jax.Array,      # [B, W, KV, dh] (RoPE applied at write)
    v_cache: jax.Array,      # [B, W, KV, dh]
    cache_pos: jax.Array,    # [W] absolute position per slot (-1 = empty)
    pos: jax.Array,          # scalar — position of the query token
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache."""
    B, W, KV, dh = k_cache.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k_cache,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    ok = (cache_pos >= 0) & (cache_pos <= pos)
    if window:
        ok &= cache_pos > pos - window
    s = jnp.where(ok[None, None, None, :], s, -jnp.inf)
    p = _softmax_f32(s)
    out = jnp.einsum("bkgw,bwkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)
