"""Decoder-only transformer family covering the five assigned LM archs.

Key design points:
  * **Pattern groups** — a config declares a per-group layer pattern, e.g.
    ``("l","l","l","l","l","g")`` for gemma3's 5:1 local:global.  The decoder
    ``lax.scan``s over *groups* (stacked params) and unrolls the (short)
    pattern inside the scan body, so each pattern position has a *static*
    window size: local layers get ring-buffer KV caches of size ``window``,
    global layers full-length caches.  No dynamic branching on layer type.
  * **Chunked flash-style attention** (layers.chunked_attention) — the
    [S, S] score matrix is never materialized; 32k prefill fits in VMEM-sized
    tiles.
  * **GQA / qk-norm / QKV-bias / RoPE / RMSNorm / SwiGLU** per config.
  * **MoE** — when ``cfg.moe`` is set, the FFN is the group-local top-k
    dispatch MoE from ``moe.py`` (EP-shardable).
  * Every entry point is pure: ``(params, batch) -> out`` for jit/pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.ctx import gathered, shard_act
from .layers import (chunked_attention, decode_attention, rms_norm, rope,
                     swiglu)
from .moe import MoEConfig, init_moe_params, moe_ffn


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    window: int = 0                       # sliding window for 'l' layers
    pattern: Tuple[str, ...] = ("g",)     # per-group layer kinds: 'l'/'g'
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_groups: int = 16                  # dispatch groups (>= data shards)
    moe_cf: float = 1.25                  # expert capacity factor
    dtype: str = "bfloat16"
    q_chunk: int = 1024
    kv_chunk: int = 1024
    attn_p_dtype: str = "float32"   # flash-attn score-block storage dtype

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0
        return self.n_layers // len(self.pattern)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def moe_cfg(self, seq_len: int) -> MoEConfig:
        """moe_groups = seq-chunks per sequence; aligned to the 'model'
        mesh axis so dispatch-group tiles coincide with shards."""
        g = min(self.moe_groups, seq_len)
        while seq_len % g:
            g -= 1
        return MoEConfig(
            n_experts=self.moe_experts, top_k=self.moe_top_k,
            d_model=self.d_model, d_ff=self.moe_d_ff, n_groups=g,
            capacity_factor=self.moe_cf)

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        D, H, KV, dh, F = (self.d_model, self.n_heads, self.n_kv_heads,
                           self.d_head, self.d_ff)
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D
        if self.is_moe:
            ffn = self.moe_experts * 3 * D * self.moe_d_ff + D * self.moe_experts
        else:
            ffn = 3 * D * F
        per_layer = attn + ffn + 2 * D
        return self.n_layers * per_layer + 2 * self.vocab * D + D

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of the experts)."""
        if not self.is_moe:
            return self.param_count()
        D = self.d_model
        attn = D * self.n_heads * self.d_head * 2 \
            + 2 * D * self.n_kv_heads * self.d_head
        ffn = self.moe_top_k * 3 * D * self.moe_d_ff + D * self.moe_experts
        per_layer = attn + ffn + 2 * D
        return self.n_layers * per_layer + 2 * self.vocab * D + D


# ---------------------------------------------------------------------------
# Parameter initialization (stacked [n_groups, ...] per pattern position)
# ---------------------------------------------------------------------------

def _init_block(key, cfg: TransformerConfig):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.act_dtype
    ks = jax.random.split(key, 10)
    s = D ** -0.5
    p = {
        "ln1": jnp.zeros((D,), jnp.float32),
        "ln2": jnp.zeros((D,), jnp.float32),
        "wq": (jax.random.normal(ks[0], (D, H, dh)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (D, KV, dh)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (D, KV, dh)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (H, dh, D)) * (H * dh) ** -0.5
               ).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, dh), dt)
        p["bk"] = jnp.zeros((KV, dh), dt)
        p["bv"] = jnp.zeros((KV, dh), dt)
    if cfg.qk_norm:
        p["qnorm"] = jnp.zeros((dh,), jnp.float32)
        p["knorm"] = jnp.zeros((dh,), jnp.float32)
    if cfg.is_moe:
        p["moe"] = init_moe_params(ks[4], cfg.moe_cfg(cfg.moe_groups), dt)
    else:
        F = cfg.d_ff
        p["w_gate"] = (jax.random.normal(ks[5], (D, F)) * s).astype(dt)
        p["w_up"] = (jax.random.normal(ks[6], (D, F)) * s).astype(dt)
        p["w_down"] = (jax.random.normal(ks[7], (F, D)) * F ** -0.5
                       ).astype(dt)
    return p


def init_params(key: jax.Array, cfg: TransformerConfig):
    keys = jax.random.split(key, cfg.n_groups * len(cfg.pattern) + 2)
    blocks = []
    for pi in range(len(cfg.pattern)):
        gks = keys[pi * cfg.n_groups:(pi + 1) * cfg.n_groups]
        blocks.append(jax.vmap(lambda k: _init_block(k, cfg))(gks))
    dt = cfg.act_dtype
    return {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "lm_head": (jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab))
                    * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "blocks": blocks,
    }


def abstract_params(cfg: TransformerConfig):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _attention_train(bp, x, cfg: TransformerConfig, window: int,
                     positions: jax.Array):
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, gathered(bp["wq"]).astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, gathered(bp["wk"]).astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, gathered(bp["wv"]).astype(h.dtype))
    if cfg.qkv_bias:
        q, k, v = q + bp["bq"], k + bp["bk"], v + bp["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, bp["qnorm"], cfg.norm_eps)
        k = rms_norm(k, bp["knorm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, window=window,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                          p_dtype=cfg.attn_p_dtype)
    return x + jnp.einsum("bshk,hkd->bsd", o,
                          gathered(bp["wo"]).astype(o.dtype)), (k, v)


def _ffn(bp, x, cfg: TransformerConfig):
    B, S, D = x.shape
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_ffn(bp["moe"], h, cfg.moe_cfg(S))
        return x + y, aux
    y = swiglu(h, gathered(bp["w_gate"]), gathered(bp["w_up"]),
               gathered(bp["w_down"]))
    return x + y, jnp.float32(0.0)


def forward(params, tokens: jax.Array, cfg: TransformerConfig,
            *, collect_cache: bool = False, last_only: bool = False):
    """tokens [B, S] -> (logits [B, S, V] (or [B, 1, V] with last_only),
    aux_loss, caches|None).

    ``last_only`` computes the head only for the final position (prefill
    serving — avoids materializing [B, S, V]).

    caches (prefill): per pattern position, stacked over groups:
      k/v [n_groups, B, W_p, KV, dh] ring-filled with the last W_p tokens,
      pos [W_p] absolute positions.
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.act_dtype)
    x = shard_act(x, "batch", "model", None)     # sequence parallelism
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.float32(0.0)
    caches = [] if collect_cache else None

    for pi, kind in enumerate(cfg.pattern):
        window = cfg.window if kind == "l" else 0

        def group_body(x, bp, _pi=pi, _window=window):
            x, (k, v) = _attention_train(bp, x, cfg, _window, positions)
            x = shard_act(x, "batch", "model", None)
            x, aux = _ffn(bp, x, cfg)
            x = shard_act(x, "batch", "model", None)
            if collect_cache:
                W = min(_window or S, S)
                kc = shard_act(k[:, S - W:], "batch", "model", None, None)
                vc = shard_act(v[:, S - W:], "batch", "model", None, None)
                return x, (aux, kc, vc)
            return x, (aux, (), ())

        body = jax.checkpoint(group_body)
        x, (auxes, ks, vs) = jax.lax.scan(
            lambda c, bp: body(c, bp), x, params["blocks"][pi])
        aux_total = aux_total + auxes.sum()
        if collect_cache:
            W = min(window or S, S)
            caches.append({
                "k": ks, "v": vs,
                "pos": jnp.arange(S - W, S, dtype=jnp.int32),
            })

    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    # head stays V-sharded (no gather); x is resharded to batch-only so
    # logits come out [B(batch), S, V(model)] with zero head collectives.
    x = shard_act(x, "batch", None, None)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = shard_act(logits, "batch", None, "model")
    return logits, aux_total, caches


def lm_loss(params, tokens: jax.Array, targets: jax.Array,
            cfg: TransformerConfig, aux_weight: float = 0.01):
    logits, aux, _ = forward(params, tokens, cfg)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (single token against KV caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Empty caches: full-length for 'g' positions, ring of `window` for 'l'."""
    KV, dh = cfg.n_kv_heads, cfg.d_head
    dt = cfg.act_dtype
    caches = []
    for kind in cfg.pattern:
        W = min(cfg.window, max_len) if kind == "l" else max_len
        caches.append({
            "k": jnp.zeros((cfg.n_groups, batch, W, KV, dh), dt),
            "v": jnp.zeros((cfg.n_groups, batch, W, KV, dh), dt),
            "pos": jnp.full((W,), -1, jnp.int32),
        })
    return caches


def abstract_cache(cfg: TransformerConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def decode_step(params, caches, tokens: jax.Array, pos: jax.Array,
                cfg: TransformerConfig):
    """One decode step.  tokens [B] int32, pos scalar int32 (position of the
    new token).  Returns (logits [B, V], new caches)."""
    B = tokens.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = params["embed"][tokens][:, None, :].astype(cfg.act_dtype)  # [B,1,D]
    new_caches = []

    for pi, kind in enumerate(cfg.pattern):
        window = cfg.window if kind == "l" else 0
        cache = caches[pi]
        W = cache["k"].shape[2]
        slot = pos % W
        new_pos = cache["pos"].at[slot].set(pos)

        def group_body(x, inp, _window=window, _W=W, _slot=slot,
                       _new_pos=new_pos):
            bp, kc, vc = inp
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h,
                           gathered(bp["wq"]).astype(h.dtype))
            k = jnp.einsum("bsd,dhk->bshk", h,
                           gathered(bp["wk"]).astype(h.dtype))
            v = jnp.einsum("bsd,dhk->bshk", h,
                           gathered(bp["wv"]).astype(h.dtype))
            if cfg.qkv_bias:
                q, k, v = q + bp["bq"], k + bp["bk"], v + bp["bv"]
            if cfg.qk_norm:
                q = rms_norm(q, bp["qnorm"], cfg.norm_eps)
                k = rms_norm(k, bp["knorm"], cfg.norm_eps)
            pvec = jnp.broadcast_to(pos[None], (B, 1))
            q = rope(q, pvec, cfg.rope_theta)
            k = rope(k, pvec, cfg.rope_theta)
            # write the new k/v at the ring slot
            kc = jax.lax.dynamic_update_slice(kc, k, (0, _slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, _slot, 0, 0))
            o = decode_attention(q, kc, vc, _new_pos, pos, window=_window)
            x = x + jnp.einsum("bshk,hkd->bsd", o,
                               gathered(bp["wo"]).astype(o.dtype))
            x, _ = _ffn(bp, x, cfg)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            group_body, x, (params["blocks"][pi], cache["k"], cache["v"]))
        new_caches.append({"k": ks, "v": vs, "pos": new_pos})

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = shard_act(logits, "batch", None, "model")
    return logits[:, 0], new_caches
