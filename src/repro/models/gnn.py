"""GraphSAGE (Hamilton et al., arXiv:1706.02216) in pure JAX.

Message passing is built on ``jax.ops.segment_sum`` over an edge-index
(src, dst) list — JAX has no sparse SpMM beyond BCOO, so the scatter-add
formulation IS the kernel (and shards over the edge axis under GSPMD).

Two execution regimes, matching the assigned shapes:
  * full-batch  — one segment-mean over all edges per layer
    (full_graph_sm / ogb_products / molecule);
  * sampled     — layer-wise fanout neighbor sampling from a CSR adjacency
    (minibatch_lg), the "real neighbor sampler" the assignment requires;
    sampled neighborhoods are dense [B, f1, f2] tensors, so the compute is
    static-shaped and vmap/pjit friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SageConfig:
    name: str
    d_feat: int
    d_hidden: int
    n_layers: int = 2
    n_classes: int = 41
    fanout: Tuple[int, ...] = (25, 10)
    aggregator: str = "mean"
    dtype: str = "float32"


def init_sage_params(key: jax.Array, cfg: SageConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * cfg.n_layers
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        s = dims[i] ** -0.5
        layers.append({
            "w_self": jax.random.normal(k1, (dims[i], dims[i + 1])) * s,
            "w_nbr": jax.random.normal(k2, (dims[i], dims[i + 1])) * s,
            "b": jnp.zeros((dims[i + 1],)),
        })
    head = jax.random.normal(keys[-1], (cfg.d_hidden, cfg.n_classes)) \
        * cfg.d_hidden ** -0.5
    return {"layers": layers, "head": head}


def _sage_layer(lp, h_self: jax.Array, h_agg: jax.Array) -> jax.Array:
    out = h_self @ lp["w_self"] + h_agg @ lp["w_nbr"] + lp["b"]
    out = jax.nn.relu(out)
    norm = jnp.linalg.norm(out, axis=-1, keepdims=True)
    return out / jnp.maximum(norm, 1e-6)


# ---------------------------------------------------------------------------
# Full-batch forward: segment-mean message passing over the edge list
# ---------------------------------------------------------------------------

def sage_forward_full(params, feats: jax.Array, src: jax.Array,
                      dst: jax.Array, cfg: SageConfig) -> jax.Array:
    """feats [N, F]; src/dst int32 [E] -> logits [N, n_classes]."""
    N = feats.shape[0]
    deg = jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst,
                              num_segments=N)
    h = feats.astype(jnp.float32)
    for lp in params["layers"]:
        msg = h[src]                                          # [E, d] gather
        agg = jax.ops.segment_sum(msg, dst, num_segments=N)
        if cfg.aggregator == "mean":
            agg = agg / jnp.maximum(deg, 1.0)[:, None]
        h = _sage_layer(lp, h, agg)
    return h @ params["head"]


def sage_loss_full(params, feats, src, dst, labels, mask, cfg: SageConfig):
    logits = sage_forward_full(params, feats, src, dst, cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = jnp.where(mask, lse - gold, 0.0)
    return ce.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------------------
# Fanout neighbor sampler (CSR) + sampled forward
# ---------------------------------------------------------------------------

def sample_neighbors(key: jax.Array, offsets: jax.Array, nbrs: jax.Array,
                     nodes: jax.Array, fanout: int) -> jax.Array:
    """Uniform with-replacement fanout sampling.

    offsets int32/int64 [N+1], nbrs int32 [E], nodes int32 [...]
    -> int32 [..., fanout]; isolated nodes sample themselves.
    """
    deg = (offsets[nodes + 1] - offsets[nodes]).astype(jnp.int32)
    r = jax.random.randint(key, nodes.shape + (fanout,), 0, 1 << 30)
    idx = offsets[nodes][..., None] + (
        r % jnp.maximum(deg, 1)[..., None]).astype(offsets.dtype)
    picked = nbrs[idx]
    return jnp.where((deg > 0)[..., None], picked,
                     nodes[..., None].astype(picked.dtype))


def sage_forward_sampled(params, key, feats, offsets, nbrs, seeds,
                         cfg: SageConfig) -> jax.Array:
    """Layer-wise sampled forward: seeds [B] -> logits [B, n_classes]."""
    L = cfg.n_layers
    keys = jax.random.split(key, L)
    # frontier[l]: [B, f1, ..., fl]
    frontiers = [seeds]
    for l in range(L):
        nxt = sample_neighbors(keys[l], offsets, nbrs, frontiers[-1],
                               cfg.fanout[l])
        frontiers.append(nxt)
    # hs[l]: features of frontier l, refined bottom-up
    hs = [feats[f].astype(jnp.float32) for f in frontiers]
    for l in range(L - 1, -1, -1):
        lp = params["layers"][L - 1 - l]
        # aggregate frontier d+1 into frontier d for every remaining level
        new_hs = []
        for d in range(l + 1):
            agg = hs[d + 1].mean(axis=-2)
            new_hs.append(_sage_layer(lp, hs[d], agg))
        hs = new_hs
    return hs[0] @ params["head"]


def sage_loss_sampled(params, key, feats, offsets, nbrs, seeds, labels,
                      cfg: SageConfig):
    logits = sage_forward_sampled(params, key, feats, offsets, nbrs, seeds,
                                  cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - gold).mean()


# ---------------------------------------------------------------------------
# Batched small graphs (molecule shape): vmap over padded per-graph edges
# ---------------------------------------------------------------------------

def sage_forward_batched(params, feats: jax.Array, src: jax.Array,
                         dst: jax.Array, edge_mask: jax.Array,
                         cfg: SageConfig) -> jax.Array:
    """feats [G, n, F], src/dst [G, e], edge_mask [G, e] -> graph logits
    [G, n_classes] (mean-pooled node embeddings -> head)."""

    def one(f, s, d, m):
        n = f.shape[0]
        sm = jnp.where(m, s, 0)
        dm = jnp.where(m, d, n)          # masked edges scatter off the end
        deg = jax.ops.segment_sum(m.astype(jnp.float32), dm,
                                  num_segments=n + 1)[:n]
        h = f.astype(jnp.float32)
        for lp in params["layers"]:
            msg = jnp.where(m[:, None], h[sm], 0.0)
            agg = jax.ops.segment_sum(msg, dm, num_segments=n + 1)[:n]
            agg = agg / jnp.maximum(deg, 1.0)[:, None]
            h = _sage_layer(lp, h, agg)
        return h.mean(axis=0) @ params["head"]

    return jax.vmap(one)(feats, src, dst, edge_mask)
