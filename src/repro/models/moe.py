"""Mixture-of-Experts FFN with sharding-aligned 2-D dispatch groups.

GShard/Switch-style capacity dispatch adapted for GSPMD:

  * dispatch groups are **(batch, seq-chunk) tiles**: x [B, S, D] is viewed
    as [B, n_s, Sg, D] with B on the ('pod','data') axes and n_s on
    'model' — every group lives wholly on one chip, so routing, sort,
    position-assignment, dispatch-gather and combine-gather induce ZERO
    data movement.  (Flattening tokens into one axis cannot be
    block-sharded over two mesh axes — GSPMD falls into involuntary full
    rematerialization; measured as an 8 GiB/layer copy on mixtral.)
  * per (group, expert) capacity C = ceil(Sg * top_k * cf / E); overflow
    tokens drop (combine weight 0) — rare at cf >= 1.25;
  * position-within-expert via group-local sort + searchsorted (no serial
    loop);
  * the combine is a **gather** from the expert output buffer (the inverse
    permutation of the dispatch sort) — a scatter-add combine makes GSPMD
    all-reduce partial results (measured 16 GiB/layer);
  * expert compute is a batched einsum [B, n_s, E, C, D] x [E, D, F] on
    the MXU with ZeRO-3-gathered weights (EP/TP variants layer on top).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.ctx import gathered, shard_act


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                       # per-expert FFN width
    capacity_factor: float = 1.25
    n_groups: int = 1               # seq-chunks per sequence (align w/model)
    router_dtype: Any = jnp.float32


def init_moe_params(key: jax.Array, cfg: MoEConfig, dtype=jnp.bfloat16):
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in, s_ff = D ** -0.5, F ** -0.5
    return {
        "router": (jax.random.normal(kr, (D, E), jnp.float32) * s_in),
        "w_gate": (jax.random.normal(kg, (E, D, F)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, D, F)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (E, F, D)) * s_ff).astype(dtype),
    }


def capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.n_experts) + 1
    return max(c, cfg.top_k)


def moe_ffn(params, x: jax.Array, cfg: MoEConfig
            ) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    S must be divisible by cfg.n_groups (the launch configs use n_groups =
    the 'model' mesh axis size so group tiles coincide with shards).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_s = min(cfg.n_groups, S)
    while S % n_s:
        n_s -= 1
    Sg = S // n_s
    C = capacity(cfg, Sg)
    L = Sg * K

    x4 = shard_act(x.reshape(B, n_s, Sg, D), "batch", "model", None, None)
    router = gathered(params["router"]).astype(cfg.router_dtype)
    logits = jnp.einsum("bgsd,de->bgse", x4.astype(cfg.router_dtype),
                        router)                               # [B,n_s,Sg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                    # [B,n_s,Sg,K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch Transformer eq. 4) --------
    me = probs.mean(axis=(0, 1, 2))                           # [E]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (B * S * K))
    aux = E * jnp.sum(me * ce)

    # ---- group-local position-in-expert -----------------------------------
    ge = top_e.reshape(B, n_s, L)
    gt = jnp.broadcast_to(
        jnp.arange(Sg)[:, None], (Sg, K)).reshape(1, 1, L)
    gt = jnp.broadcast_to(gt, (B, n_s, L))                    # token-in-group
    gw = top_w.reshape(B, n_s, L)

    order = jnp.argsort(ge, axis=-1, stable=True)             # group-local
    se = jnp.take_along_axis(ge, order, axis=-1)
    st = jnp.take_along_axis(gt, order, axis=-1)
    first = jax.vmap(jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left")))(se)
    pos = jnp.arange(L, dtype=jnp.int32)[None, None] - first.astype(
        jnp.int32)
    keep = pos < C                                            # overflow drop

    # scatter token slots into the [B, n_s, E, C] dispatch index buffer
    slot_e = jnp.where(keep, se, E)
    slot_c = jnp.where(keep, pos, 0)
    bi = jnp.broadcast_to(jnp.arange(B)[:, None, None], slot_e.shape)
    gi = jnp.broadcast_to(jnp.arange(n_s)[None, :, None], slot_e.shape)
    disp_idx = jnp.full((B, n_s, E + 1, C), -1, jnp.int32)
    disp_idx = disp_idx.at[bi, gi, slot_e, slot_c].set(st, mode="drop")
    disp_idx = disp_idx[:, :, :E]                             # [B,n_s,E,C]

    # ---- dispatch gather -> expert compute --------------------------------
    safe = jnp.maximum(disp_idx, 0).reshape(B, n_s, E * C)
    xb = jnp.take_along_axis(x4, safe[..., None], axis=2)
    xb = xb.reshape(B, n_s, E, C, D)
    xb = jnp.where((disp_idx >= 0)[..., None], xb, 0).astype(x.dtype)
    xb = shard_act(xb, "batch", "model", None, None, None)

    w_gate = gathered(params["w_gate"]).astype(x.dtype)
    w_up = gathered(params["w_up"]).astype(x.dtype)
    w_down = gathered(params["w_down"]).astype(x.dtype)
    g = jnp.einsum("bgecd,edf->bgecf", xb, w_gate)
    u = jnp.einsum("bgecd,edf->bgecf", xb, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    yb = jnp.einsum("bgecf,efd->bgecd", h, w_down)
    yb = shard_act(yb, "batch", "model", None, None, None)

    # ---- combine: gather back (NO scatter) --------------------------------
    inv = jnp.argsort(order, axis=-1)
    tok_e = jnp.take_along_axis(slot_e, inv, axis=-1)
    tok_c = jnp.take_along_axis(slot_c, inv, axis=-1)
    tok_keep = jnp.take_along_axis(keep, inv, axis=-1)
    flat = jnp.where(tok_keep, tok_e * C + tok_c, 0)          # [B,n_s,L]
    yb_flat = yb.reshape(B, n_s, E * C, D)
    picked = jnp.take_along_axis(yb_flat, flat[..., None], axis=2)
    picked = shard_act(picked, "batch", "model", None, None)
    # combine math stays in the activation dtype: an f32 combine makes the
    # cotangent (and thus every expert-weight gradient buffer) f32 — 2x the
    # transient HBM for no accuracy gain (top_k <= 8 terms per token).
    picked = jnp.where(tok_keep[..., None], picked, 0).astype(x.dtype)
    w_tok = jnp.where(tok_keep, gw, 0.0).astype(x.dtype)
    out = (picked * w_tok[..., None]).reshape(B, n_s, Sg, K, D).sum(axis=3)
    out = shard_act(out, "batch", "model", None, None)
    return out.reshape(B, S, D).astype(x.dtype), aux
