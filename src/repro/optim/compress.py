"""Compressed gradient all-reduce (distributed-optimization trick).

Two schemes, both honest about what actually crosses the ICI links:

  * ``bf16_all_reduce`` — cast f32 grads to bf16 before psum: exactly half
    the collective bytes, hardware-native reduction.  The default trick.
  * ``int8_all_gather_reduce`` — symmetric int8 quantization (stochastic
    rounding, unbiased) + all_gather of the 1-byte codes + local sum.
    4x fewer bytes per hop than f32; total bytes scale with the axis size,
    so this wins for small reduction groups (e.g. the 2-pod 'pod' axis:
    2x(n-1)/n... vs ring-all-reduce it's bytes x (n-1) vs 2(n-1)/n — use
    only when n <= 8).

Both run inside shard_map (they use named-axis collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    x = g / scale
    # stochastic rounding keeps E[decompress(compress(g))] == g
    noise = jax.random.uniform(key, g.shape) - 0.5
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def bf16_all_reduce(grads, axis_name: str = "data"):
    """Mean-all-reduce in bf16: 2x fewer ICI bytes than f32."""
    n = jax.lax.psum(1, axis_name)

    def one(g):
        s = jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        return (s.astype(jnp.float32) / n).astype(g.dtype)

    return jax.tree.map(one, grads)


def int8_all_gather_reduce(grads, key: jax.Array, axis_name: str = "data"):
    """Mean-all-reduce via int8 all_gather + local decode-sum.

    Wire format per leaf: int8 codes (1 byte/elem/hop) + one f32 scale.
    Unbiased (stochastic rounding); quantization noise ~ scale/sqrt(12).
    """
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    n = jax.lax.psum(1, axis_name)

    out = []
    for g, k in zip(leaves, keys):
        q, s = int8_compress(g.astype(jnp.float32), k)
        qs = jax.lax.all_gather(q, axis_name)          # [n, ...] int8
        ss = jax.lax.all_gather(s, axis_name)          # [n]
        dec = qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * g.ndim)
        out.append((dec.sum(axis=0) / n).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


# Back-compat alias used by configs: int8 path.
int8_all_reduce = int8_all_gather_reduce
