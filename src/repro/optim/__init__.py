from .adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from .compress import int8_all_reduce, int8_compress, int8_decompress  # noqa: F401
