"""AdamW with param-like-sharded state (hand-rolled; no optax dependency).

State arrays mirror the parameter pytree, so whatever NamedSharding the
params carry propagates to m/v — FSDP falls out of GSPMD sharding rules
rather than bespoke code.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.int32(0), zeros,
                      jax.tree.map(jnp.zeros_like, zeros))


def adamw_update(params, grads, state: AdamWState, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0):
    """Returns (new_params, new_state).  Global-norm clipping included."""
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / c1, v / c2
        new_p = (p.astype(jnp.float32)
                 - lr * (mh / (jnp.sqrt(vh) + eps)
                         + weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v)
