"""repro.storage — the decoupled on-disk index layer (docs/STORAGE.md).

``layout``    topology/vector file formats, atomic writers, delta patches.
``cache``     block-granular LRU over the adjacency file.
``prefetch``  double-buffered async lookahead reader (+ the Pallas
              scalar-prefetch HBM gather for TPU).
``source``    ``DiskSource``/``DiskVectorBackend`` behind the engine's
              ``GraphSource``/``DistanceBackend`` protocols, and the
              disk-backed LTI searcher.
"""
from .cache import AdjacencyCache
from .layout import (BLOCK_BYTES, PatchStats, StorageLayout, is_layout,
                     open_layout, patch_layout, write_layout)
from .prefetch import HBMSource, Prefetcher, hbm_gather_rows
from .source import (DiskLTISearcher, DiskReader, DiskSource,
                     DiskVectorBackend, IOStats)

__all__ = [
    "AdjacencyCache", "BLOCK_BYTES", "DiskLTISearcher", "DiskReader",
    "DiskSource", "DiskVectorBackend", "HBMSource", "IOStats",
    "PatchStats", "Prefetcher", "StorageLayout", "hbm_gather_rows",
    "is_layout", "open_layout", "patch_layout", "write_layout",
]
