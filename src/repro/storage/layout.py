"""Decoupled on-disk index layout: topology split from vectors (DGAI-style).

The paper's LTI lives on an SSD; the engine's unit of IO is an adjacency
row (a "sector read").  This module gives those reads a real on-disk shape:

  ``header.json``    tiny JSON header: capacity / R / dim / m / dtype /
                     start / n_total / generation.  Rewritten last (via a
                     tmp + atomic rename), so the generation counter only
                     advances once a patch is fully on disk.
  ``topology.bin``   int32 [capacity, R], fixed stride of R*4 bytes per
                     row — the adjacency-block file.  Row ``i`` of the
                     graph is exactly bytes [i*R*4, (i+1)*R*4); a search
                     round's W frontier rows are W disjoint strided reads.
  ``data.bin``       the vector/code file: float32 [capacity, dim]
                     full-precision vectors followed by uint8 [capacity, m]
                     PQ codes.  Never touched by topology-only updates —
                     the decoupling that makes delta patches cheap.
  ``meta.npz``       the small in-memory side tables: ``active`` /
                     ``deleted`` flags, the slot->external-id table, and
                     the PQ codebook centroids.  Loaded fully into memory
                     at open (they are O(capacity) bits / O(m*ksub*dsub)
                     floats, not O(capacity*R)); only adjacency rows and
                     full-precision vectors stay disk-resident.

``write_layout`` stages into ``<path>.tmp`` and publishes with the same
fsync + atomic-rename discipline as the checkpoint store
(``checkpoint.store.commit_dir``).  ``patch_layout`` is the DGAI delta
path: it rewrites ONLY the adjacency rows (and newly staged vector/code
rows) that changed, in place, then bumps the header generation — a merge
that repaired 2% of the graph writes 2% of ``topology.bin`` and zero
vector bytes for the surviving points.

File formats, the prefetch dataflow, and knob recipes: docs/STORAGE.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Optional

import numpy as np

from ..checkpoint.store import commit_dir, fsync_dir

LAYOUT_VERSION = 1
HEADER = "header.json"
TOPOLOGY = "topology.bin"
DATA = "data.bin"
META = "meta.npz"

# Granularity of the adjacency-block cache and of read accounting: a block
# is BLOCK_BYTES of topology.bin (multiple rows when R*4 < BLOCK_BYTES),
# mirroring the paper's 4KB SSD sector.
BLOCK_BYTES = 4096


@dataclasses.dataclass
class PatchStats:
    """What a delta patch actually wrote (lands in ``SystemStats``)."""
    adj_rows: int = 0
    adj_blocks: int = 0  # DISTINCT 4KB topology blocks those rows live in —
    #   the real SSD-write granularity.  Locality-ordered merges concentrate
    #   changed rows, so adj_blocks shrinks faster than adj_rows
    #   (BENCH_io_cost.json's storage-delta sweep measures both).
    vec_rows: int = 0
    code_rows: int = 0
    bytes_written: int = 0
    generation: int = 0


@dataclasses.dataclass
class StorageLayout:
    """An open decoupled layout: mmap views + in-memory side tables."""
    path: str
    capacity: int
    R: int
    dim: int
    m: int
    vec_dtype: str
    start: int
    n_total: int
    generation: int
    adjacency: np.memmap        # [capacity, R] int32 (read-only view)
    vectors: np.memmap          # [capacity, dim] vec_dtype (read-only view)
    codes: Optional[np.memmap]  # [capacity, m] uint8, None when m == 0
    active: np.ndarray          # [capacity] bool (in-memory header table)
    deleted: np.ndarray         # [capacity] bool
    ext_ids: np.ndarray         # [capacity] int64, -1 free
    centroids: Optional[np.ndarray]  # [m, ksub, dsub] f32 PQ codebook
    label_bits: Optional[np.ndarray] = None   # [capacity, n_words] uint32
    #   packed per-point label bitsets (filtered search side table)
    label_tenant: Optional[np.ndarray] = None  # [capacity] int32 tenant
    #   ids, -1 untenanted — None on layouts written before labels landed

    @property
    def row_bytes(self) -> int:
        return self.R * 4

    @property
    def block_rows(self) -> int:
        """Adjacency rows per cache/IO block (>= 1)."""
        return max(1, BLOCK_BYTES // self.row_bytes)

    @property
    def n_blocks(self) -> int:
        return -(-self.capacity // self.block_rows)

    def graph_state(self):
        """Materialize the full ``GraphState`` in memory (tests/recovery —
        NOT the serving path, which reads rows through ``DiskSource``)."""
        import jax.numpy as jnp
        from ..core.graph import GraphState
        return GraphState(
            vectors=jnp.asarray(np.asarray(self.vectors)),
            adjacency=jnp.asarray(np.asarray(self.adjacency)),
            active=jnp.asarray(self.active),
            deleted=jnp.asarray(self.deleted),
            start=jnp.int32(self.start),
            n_total=jnp.int32(self.n_total))

    def lti_state(self):
        """Materialize the full ``LTIState`` (codes + codebook required)."""
        import jax.numpy as jnp
        from ..core import pq as pqm
        from ..core.lti import LTIState
        if self.codes is None or self.centroids is None:
            raise ValueError(f"layout at {self.path} has no PQ codes")
        return LTIState(self.graph_state(),
                        jnp.asarray(np.asarray(self.codes)),
                        pqm.PQCodebook(jnp.asarray(self.centroids)))

    def close(self) -> None:
        # memmaps release on GC; drop the references deterministically.
        self.adjacency = self.vectors = self.codes = None


def _header_dict(capacity, R, dim, m, vec_dtype, start, n_total, generation):
    return {"version": LAYOUT_VERSION, "capacity": int(capacity),
            "R": int(R), "dim": int(dim), "m": int(m),
            "vec_dtype": str(vec_dtype), "start": int(start),
            "n_total": int(n_total), "generation": int(generation)}


def _write_header(path: str, hdr: dict) -> None:
    """Publish the header last, atomically: tmp + fsync + rename."""
    tmp = os.path.join(path, HEADER + ".tmp")
    with open(tmp, "w") as f:
        json.dump(hdr, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, HEADER))
    fsync_dir(path)


def _write_meta(path: str, active, deleted, ext_ids, centroids,
                label_bits=None, label_tenant=None) -> None:
    tmp = os.path.join(path, META + ".tmp")
    blobs = {"active": np.asarray(active, bool),
             "deleted": np.asarray(deleted, bool),
             "ext_ids": np.asarray(ext_ids, np.int64)}
    if centroids is not None:
        blobs["centroids"] = np.asarray(centroids, np.float32)
    if label_bits is not None:
        blobs["label_bits"] = np.asarray(label_bits, np.uint32)
    if label_tenant is not None:
        blobs["label_tenant"] = np.asarray(label_tenant, np.int32)
    with open(tmp, "wb") as f:
        np.savez(f, **blobs)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, META))


def write_layout(path: str, graph, *, codes=None, codebook=None,
                 ext_ids: Optional[np.ndarray] = None,
                 generation: int = 0,
                 label_bits: Optional[np.ndarray] = None,
                 label_tenant: Optional[np.ndarray] = None) -> StorageLayout:
    """Serialize a ``GraphState`` (plus optional PQ codes/codebook) into a
    fresh decoupled layout at ``path`` and return it opened.

    Stages into ``<path>.tmp`` and atomically publishes, so a crash
    mid-write never leaves a half-layout at ``path``.
    """
    adj = np.ascontiguousarray(np.asarray(graph.adjacency, np.int32))
    vecs = np.ascontiguousarray(np.asarray(graph.vectors))
    capacity, R = adj.shape
    cd = None if codes is None else np.ascontiguousarray(
        np.asarray(codes, np.uint8))
    m = 0 if cd is None else cd.shape[1]
    cents = None
    if codebook is not None:
        cents = np.asarray(getattr(codebook, "centroids", codebook),
                           np.float32)
    if ext_ids is None:
        ext_ids = np.full(capacity, -1, np.int64)

    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, TOPOLOGY), "wb") as f:
        f.write(adj.tobytes())
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, DATA), "wb") as f:
        f.write(vecs.tobytes())
        if cd is not None:
            f.write(cd.tobytes())
        f.flush()
        os.fsync(f.fileno())
    _write_meta(tmp, np.asarray(graph.active), np.asarray(graph.deleted),
                ext_ids, cents, label_bits, label_tenant)
    hdr = _header_dict(capacity, R, vecs.shape[1], m, vecs.dtype.name,
                       int(graph.start), int(graph.n_total), generation)
    with open(os.path.join(tmp, HEADER), "w") as f:
        json.dump(hdr, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    commit_dir(tmp, path)
    return open_layout(path)


def is_layout(path: str) -> bool:
    return os.path.isfile(os.path.join(path, HEADER))


def open_layout(path: str, mode: str = "r") -> StorageLayout:
    """mmap an existing layout (``mode="r+"`` for in-place patching)."""
    with open(os.path.join(path, HEADER)) as f:
        hdr = json.load(f)
    if hdr["version"] != LAYOUT_VERSION:
        raise ValueError(f"layout version {hdr['version']} != "
                         f"{LAYOUT_VERSION} at {path}")
    cap, R, dim, m = hdr["capacity"], hdr["R"], hdr["dim"], hdr["m"]
    vdt = np.dtype(hdr["vec_dtype"])
    adjacency = np.memmap(os.path.join(path, TOPOLOGY), np.int32, mode,
                          shape=(cap, R))
    vectors = np.memmap(os.path.join(path, DATA), vdt, mode,
                        shape=(cap, dim))
    codes = None
    if m:
        codes = np.memmap(os.path.join(path, DATA), np.uint8, mode,
                          offset=cap * dim * vdt.itemsize, shape=(cap, m))
    with np.load(os.path.join(path, META)) as meta:
        active = meta["active"].copy()
        deleted = meta["deleted"].copy()
        ext_ids = meta["ext_ids"].copy()
        centroids = (meta["centroids"].copy()
                     if "centroids" in meta.files else None)
        label_bits = (meta["label_bits"].copy()
                      if "label_bits" in meta.files else None)
        label_tenant = (meta["label_tenant"].copy()
                        if "label_tenant" in meta.files else None)
    return StorageLayout(
        path=path, capacity=cap, R=R, dim=dim, m=m,
        vec_dtype=hdr["vec_dtype"], start=hdr["start"],
        n_total=hdr["n_total"], generation=hdr["generation"],
        adjacency=adjacency, vectors=vectors, codes=codes,
        active=active, deleted=deleted, ext_ids=ext_ids,
        centroids=centroids, label_bits=label_bits,
        label_tenant=label_tenant)


def patch_layout(path: str, graph, *, codes=None, ext_ids=None,
                 adj_changed: Optional[np.ndarray] = None,
                 label_bits: Optional[np.ndarray] = None,
                 label_tenant: Optional[np.ndarray] = None) -> PatchStats:
    """DGAI-style delta topology patch: rewrite only the adjacency rows that
    differ from what is on disk (plus vector/code rows of newly staged
    slots), update the side tables, and bump the header generation LAST —
    a reader that opens mid-patch at worst sees the old generation number
    over fully written rows, never a torn row (row writes are aligned
    whole-row pwrites).

    ``adj_changed`` (bool [capacity]) lets the caller supply the changed-row
    mask (e.g. ``merge.adjacency_delta_mask`` computed on device); without
    it the mask is derived by comparing against the mapped file.  Vector
    rows are compared unconditionally — the DGAI claim, which
    ``tests/test_storage.py`` pins, is that topology-only updates write
    zero vector bytes, and that must be *measured*, not assumed.
    """
    lay = open_layout(path, mode="r+")
    try:
        adj = np.asarray(graph.adjacency, np.int32)
        vecs = np.asarray(graph.vectors)
        if adj.shape != lay.adjacency.shape:
            raise ValueError(
                f"patch shape {adj.shape} != layout {lay.adjacency.shape}")
        if adj_changed is None:
            adj_changed = np.any(lay.adjacency != adj, axis=1)
        else:
            adj_changed = np.asarray(adj_changed, bool)
        vec_changed = np.any(np.asarray(lay.vectors) != vecs, axis=1)
        stats = PatchStats(generation=lay.generation + 1)
        changed_rows = np.nonzero(adj_changed)[0]
        for i in changed_rows:
            lay.adjacency[i] = adj[i]
        stats.adj_rows = int(adj_changed.sum())
        stats.adj_blocks = int(np.unique(changed_rows
                                         // lay.block_rows).size)
        stats.bytes_written += stats.adj_rows * lay.row_bytes
        for i in np.nonzero(vec_changed)[0]:
            lay.vectors[i] = vecs[i]
        stats.vec_rows = int(vec_changed.sum())
        stats.bytes_written += stats.vec_rows * vecs.shape[1] * vecs.itemsize
        if codes is not None and lay.codes is not None:
            cd = np.asarray(codes, np.uint8)
            code_changed = np.any(np.asarray(lay.codes) != cd, axis=1)
            for i in np.nonzero(code_changed)[0]:
                lay.codes[i] = cd[i]
            stats.code_rows = int(code_changed.sum())
            stats.bytes_written += stats.code_rows * cd.shape[1]
            lay.codes.flush()
        lay.adjacency.flush()
        lay.vectors.flush()
        _write_meta(path, np.asarray(graph.active),
                    np.asarray(graph.deleted),
                    ext_ids if ext_ids is not None else lay.ext_ids,
                    lay.centroids,
                    label_bits if label_bits is not None else lay.label_bits,
                    label_tenant if label_tenant is not None
                    else lay.label_tenant)
        _write_header(path, _header_dict(
            lay.capacity, lay.R, lay.dim, lay.m, lay.vec_dtype,
            int(graph.start), int(graph.n_total), stats.generation))
        return stats
    finally:
        lay.close()
