"""The async prefetch pipeline: double-buffered lookahead adjacency reads.

GreedySearch has a strict round-to-round dependency — round ``t+1``'s
frontier is only known after round ``t``'s distances land — so a prefetcher
cannot *know* the next frontier.  What it can know, deterministically, is
the engine's lookahead hint: after round ``t``'s ``frontier_select``, the
next ``depth * W`` still-open candidates in the list are exactly the nodes
the next frontier will be drawn from unless a fresh discovery beats them.
The engine ships that hint with every row fetch (the frontier->prefetch
handshake in ``core/search.py``), and this worker reads those rows from
``topology.bin`` WHILE the device scores round ``t``'s neighbors — the IO
for round ``t+1`` overlaps the compute for round ``t``:

    device:  | score round t | select | score round t+1 | select |
    worker:       | read hint rows t+1 |     | read hint rows t+2 |

Staging is double-buffered and allocation-free in steady state: two host
buffers are allocated once (grown only if a larger hint batch ever
arrives, counted in ``allocations``) and generations alternate between
them — the buffer-identity contract ``tests/test_storage.py`` asserts.
On CPU/GPU these play the role of pinned host staging memory; on TPU the
analogous structure is ``hbm_gather_rows`` below, where ``pallas_call``'s
implicit pipeline double-buffers the row DMAs HBM->VMEM.
"""
from __future__ import annotations

import functools
import queue
import threading
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core.distance import INVALID


class Prefetcher:
    """Background lookahead reader with two reusable staging buffers.

    ``fetch_batch(ids [n] int, out [>=n, R] int32) -> was_file_read [n]``
    is supplied by the ``DiskReader`` — one vectorized gather per staged
    generation, routed through the shared block cache, so a hinted row
    whose block is already cached is staged without touching the file (and
    the consumer counts it as a cache hit, not a read).  Batch (not
    per-row) staging matters: the whole generation must fit inside the
    device's distance/select window or the next round's ``wait()`` eats
    the overlap.

    Protocol (driven by ``DiskReader.fetch`` once per IO round):
      1. ``wait()``     — block until the in-flight generation is staged
                          (no-op when idle);
      2. ``lookup(id)`` — serve staged rows for the current round;
      3. ``submit(ids)``— start staging the next round's hint batch on the
                          worker thread and return immediately.
    Generations strictly alternate buffers, and a generation is consumed
    (step 2) only after its fill completed (step 1) and before the next
    submit (step 3) — so fills never race consumption and the pair of
    buffers is sufficient.
    """

    def __init__(self, fetch_batch: Callable, R: int):
        self.R = int(R)
        self._fetch_batch = fetch_batch
        self._buffers = [np.empty((0, self.R), np.int32),
                         np.empty((0, self.R), np.int32)]
        self.allocations = 0            # staging (re)allocations — grows
        #   only during warmup, then goes quiet (buffer-reuse contract)
        self._gen = 0
        self._map: dict[int, tuple[int, bool]] = {}   # id -> (slot, read?)
        self._cur: Optional[np.ndarray] = None
        self._done = threading.Event()
        self._done.set()
        self._queue: queue.Queue = queue.Queue()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def staging_buffers(self) -> tuple[np.ndarray, np.ndarray]:
        """The two staging buffers (identity-stable across rounds)."""
        return tuple(self._buffers)

    def submit(self, ids: np.ndarray) -> None:
        """Stage ``ids`` (unique, valid) on the worker; returns at once."""
        self._done.wait()               # never overwrite an in-flight fill
        prev = (self._map, self._cur)   # carry-over source (see _worker)
        self._gen += 1
        bi = self._gen & 1
        n = len(ids)
        if self._buffers[bi].shape[0] < n:
            # Geometric growth, and growth only — after warmup every round
            # reuses the same two arrays (the no-allocator-churn contract:
            # ``allocations`` goes quiet and the buffer identities pinned
            # by ``staging_buffers()`` stop changing).
            cap = max(n, 64, 2 * self._buffers[bi].shape[0])
            self._buffers[bi] = np.empty((cap, self.R), np.int32)
            self.allocations += 1
        self._map = {}
        self._cur = self._buffers[bi]
        self._done.clear()
        self._queue.put((bi, np.asarray(ids, np.int64), prev))

    def wait(self) -> None:
        self._done.wait()

    def lookup(self, node_id: int):
        """(row, was_file_read) if staged in the current generation, else
        None.  Call only after ``wait()``."""
        e = self._map.get(node_id)
        if e is None:
            return None
        return self._cur[e[0]], e[1]

    def close(self) -> None:
        self._queue.put(None)
        self._thread.join(timeout=5)

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            bi, ids, (prev_map, prev_buf) = item
            buf = self._buffers[bi]
            # The identity assertion behind the buffer-reuse contract: the
            # fill target IS one of the two construction-time (or grown-
            # once) staging arrays, never a per-round allocation.
            assert buf is self._buffers[bi]
            m = {}
            if len(ids):
                # Carry-over: a hint that missed last round stays open and
                # is usually re-hinted — its row is still sitting in the
                # OTHER staging buffer (generations alternate, and the next
                # submit can't start until this fill signals done), so copy
                # it across instead of re-reading the file.  Its
                # ``was_file_read`` flag rides along, so consumption-time
                # accounting is unchanged: the read already happened, it is
                # just not re-issued.
                carried, new_ids = [], []
                for nid in ids:
                    e = prev_map.get(int(nid))
                    if e is None:
                        new_ids.append(nid)
                    else:
                        carried.append((int(nid), e))
                nn = len(new_ids)
                if nn:
                    # One vectorized gather for the genuinely new rows,
                    # contiguous at the buffer front; the simulated device
                    # latency is charged on THIS thread
                    # (DiskReader._serve_batch) — overlapped with demand IO
                    # and the device's compute, not the query's critical
                    # path.
                    na = np.asarray(new_ids, np.int64)
                    was = self._fetch_batch(na, buf)
                    m = {int(nid): (j, bool(was[j]))
                         for j, nid in enumerate(na)}
                for j, (nid, e) in enumerate(carried):
                    buf[nn + j] = prev_buf[e[0]]
                    m[nid] = (nn + j, e[1])
            self._map = m
            self._done.set()


# ---------------------------------------------------------------------------
# TPU path: scalar-prefetch row gather.  pallas_call's implicit pipeline
# double-buffers the per-row DMAs (emit_pipeline-style), so while row i is
# being consumed in VMEM row i+1 is already streaming out of HBM — the
# device-side analogue of the host thread above.
# ---------------------------------------------------------------------------

def _gather_kernel(ids_ref, block_ref, out_ref):
    del ids_ref                         # consumed by the index_map only
    out_ref[...] = block_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def hbm_gather_rows(table: jax.Array, ids: jax.Array,
                    *, interpret: Optional[bool] = None) -> jax.Array:
    """Gather ``table[ids]`` ([N, R] int32, ids [W]) via a Pallas
    scalar-prefetch pipeline: ids ride as the scalar-prefetch operand, the
    BlockSpec index_map turns each grid step into one row DMA, and the
    pipeline keeps the next row's DMA in flight while the current one
    writes out — an HBM double buffer with no host involvement.

    Semantics match the dense gather exactly: ``ids < 0`` -> INVALID rows
    (same as ``DenseSource.rows``).  CPU validation runs interpret mode;
    the parity test is in ``tests/test_storage.py``.
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from ..kernels.ops import _interpret
    if interpret is None:
        interpret = _interpret()
    W = ids.shape[0]
    R = table.shape[1]
    safe = jnp.maximum(ids, 0).astype(jnp.int32)
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(W,),
            in_specs=[pl.BlockSpec((1, R), lambda i, ids_ref: (ids_ref[i], 0))],
            out_specs=pl.BlockSpec((1, R), lambda i, ids_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((W, R), table.dtype),
        interpret=interpret,
    )(safe, table)
    return jnp.where((ids >= 0)[:, None], out, INVALID)


class HBMSource:
    """``GraphSource`` whose row gathers stream through the Pallas
    scalar-prefetch pipeline — the TPU face of the storage tier, where
    "disk" is HBM and the double buffer is the pallas_call pipeline.
    Bit-identical to ``DenseSource`` (the parity test pins it)."""

    def __init__(self, adjacency: jax.Array, navigable: jax.Array):
        self.adjacency = adjacency
        self.navigable = navigable

    def rows(self, ids: jax.Array) -> jax.Array:
        return hbm_gather_rows(self.adjacency, ids)

    def node_ok(self, ids: jax.Array) -> jax.Array:
        return (ids >= 0) & self.navigable[jnp.maximum(ids, 0)]
