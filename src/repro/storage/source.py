"""``DiskSource`` — the on-disk sibling of ``core.search.DenseSource``.

The beam engine's topology reads go through the ``GraphSource`` protocol;
this module implements it over a decoupled layout (``storage.layout``):
every IO round's [B, W] frontier crosses into host land ONCE via
``jax.pure_callback`` (vmap_method="expand_dims", so the whole query
batch's round arrives as one callback — one batched IO, exactly the
paper's W-concurrent-sector-reads round), is served from the block cache /
prefetch staging / ``topology.bin``, and returns the rows plus a per-row
``fetched`` mask the engine folds into ``SearchResult.n_reads``.

Read accounting (the ``n_reads`` contract, ``core/search.py`` module doc):

  fetched=True   the row came off the file on this query's behalf — a
                 synchronous demand read, or a prefetch-staged row whose
                 block the worker actually read (the read happened, it was
                 just overlapped with compute).
  fetched=False  the row cost no file IO for this request: its block was
                 LRU-cached (read earlier for a *different* request), or
                 the prefetcher found it cached while staging.  Counted in
                 ``IOStats.cache_hits`` -> ``SystemStats.io_cache_hits``.

So with the cache off, ``n_reads`` is bit-identical to the dense engine's
at ANY prefetch depth (prefetch moves reads off the critical path, it does
not erase them), and with the cache on the conservation law
``n_reads + cache_hits == dense n_reads`` holds — both are pinned by
``tests/test_storage.py``.

Node validity (``node_ok``) and the slot->ext table never touch the disk:
they resolve from the layout's small in-memory header tables, mirroring
the paper's in-memory bitmaps over the SSD-resident graph.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core import pq as pqm
from ..core.config import IndexConfig
from ..core.distance import INVALID, l2_sq
from ..core.search import (PQBackend, batch_distances, beam_search,
                           rerank_candidates, topk_results)
from .cache import AdjacencyCache
from .layout import StorageLayout
from .prefetch import Prefetcher

# Simulated device concurrency: block reads issued together ride the queue
# QUEUE_DEPTH at a time, so a batch of B blocks costs ceil(B / QUEUE_DEPTH)
# round trips of ``latency_us`` — the §6.2 model where a round's W sector
# reads are concurrent, extended to finite queue capacity.
QUEUE_DEPTH = 8


@dataclasses.dataclass
class IOStats:
    """Host-side IO accounting for one ``DiskReader`` (monotonic; the
    system layer folds deltas into ``SystemStats``)."""
    rows_requested: int = 0     # valid adjacency rows the engine asked for
    demand_reads: int = 0       # rows served by a synchronous file read
    prefetch_hits: int = 0      # rows served from prefetch staging whose
    #   block the worker read from the file (overlapped IO — still a read)
    cache_hits: int = 0         # rows served with NO file IO for this
    #   request (block cache, or staged-from-cache)
    blocks_read: int = 0        # topology.bin block reads, all causes
    prefetch_blocks: int = 0    # ... of which issued by the worker thread
    bytes_read: int = 0         # topology.bin bytes off the file
    vector_rows: int = 0        # full-precision rows gathered for rerank
    vector_bytes: int = 0
    fetch_calls: int = 0        # host callbacks (== IO rounds, batched)

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)

    def read_amplification(self, row_bytes: int) -> float:
        """Bytes actually read / bytes of adjacency rows delivered — >1
        because a block read returns whole sectors for row-sized asks."""
        used = self.demand_reads + self.prefetch_hits
        if used == 0:
            return 0.0
        return self.bytes_read / float(used * row_bytes)


class DiskReader:
    """Host-side row server over an open layout: block cache + prefetch
    staging + mmap'd ``topology.bin``, with deterministic accounting.

    ``latency_us`` simulates device latency at QUEUE-SUBMISSION
    granularity: a batch of B distinct blocks issued together costs
    ceil(B / QUEUE_DEPTH) round trips — the §6.2 model, where a round's W
    sector reads ride the SSD queue concurrently, extended to finite
    queue capacity.  Demand batches sleep synchronously inside the
    callback (on the query's critical path); prefetch batches sleep on
    the worker thread, overlapped with the device's distance/select work
    — which is exactly the wall-time difference
    ``benchmarks/bench_io_cost.py`` measures.  On this container the data
    fits in page cache, so without the knob an mmap read costs ~0 and the
    overlap would be unmeasurable.  0 (the default, used by every parity
    test) adds nothing.
    """

    def __init__(self, layout: StorageLayout, *, cache_mb: int = 0,
                 prefetch: bool = False, latency_us: float = 0.0):
        self.layout = layout
        self.row_bytes = layout.row_bytes
        self.block_rows = layout.block_rows
        self.block_bytes = self.block_rows * self.row_bytes
        self.latency_s = latency_us * 1e-6
        self.cache = AdjacencyCache(cache_mb * (1 << 20), self.block_bytes)
        self.stats = IOStats()
        self._io_lock = threading.Lock()
        self.prefetcher = (Prefetcher(self._serve_prefetch, layout.R)
                           if prefetch else None)

    # ---------------------------------------------------------------- blocks
    def _read_block(self, block_id: int, *, prefetch: bool) -> np.ndarray:
        """One block off topology.bin (a sector read; the simulated device
        latency is charged per ROUND by the caller, not per block — the
        round's blocks ride the queue concurrently)."""
        lo = block_id * self.block_rows
        hi = min(lo + self.block_rows, self.layout.capacity)
        blk = np.asarray(self.layout.adjacency[lo:hi])
        self.stats.blocks_read += 1
        self.stats.bytes_read += self.block_bytes
        if prefetch:
            self.stats.prefetch_blocks += 1
        return blk

    def _serve_batch(self, ids: np.ndarray, *, prefetch: bool,
                     out: Optional[np.ndarray] = None):
        """(rows [n, R], was_file_read [n]) for ``ids`` (valid, int), one
        lock hold for the whole batch — the round's blocks are one queue
        submission, and the vectorized gather keeps the worker thread fast
        enough to hide inside the device's compute window.

        The simulated latency is charged HERE, after the lock drops, on
        whichever thread ran the batch: ceil(blocks / QUEUE_DEPTH) round
        trips.  Demand batches run on the callback thread (the query's
        critical path); prefetch batches run on the worker thread, where
        the sleep overlaps the device's compute — the wall-time difference
        the IO benchmark measures.
        """
        n = ids.shape[0]
        rows = out if out is not None else np.empty(
            (n, self.layout.R), np.int32)
        dst = rows[:n]          # view — ``out`` may be an oversized buffer
        was = np.zeros(n, bool)
        bs = ids // self.block_rows
        nb = 0
        with self._io_lock:
            if not self.cache.enabled:
                dst[:] = self.layout.adjacency[ids]
                nb = len(np.unique(bs))
                self.stats.blocks_read += nb
                self.stats.bytes_read += nb * self.block_bytes
                if prefetch:
                    self.stats.prefetch_blocks += nb
                was[:] = True
            else:
                for b in np.unique(bs):
                    sel = bs == b
                    blk = self.cache.get(int(b))
                    if blk is None:
                        blk = self._read_block(int(b), prefetch=prefetch)
                        self.cache.put(int(b), blk)
                        was[sel] = True
                        nb += 1
                    dst[sel] = blk[ids[sel] - int(b) * self.block_rows]
        if nb and self.latency_s:
            time.sleep(self.latency_s * -(-nb // QUEUE_DEPTH))
        return rows, was

    def _serve_prefetch(self, ids: np.ndarray, out: np.ndarray) -> np.ndarray:
        """The prefetch worker's staging gather: rows land directly in the
        reusable staging buffer; returns the per-row file-read mask."""
        return self._serve_batch(ids, prefetch=True, out=out)[1]

    # ----------------------------------------------------------------- rows
    def fetch(self, ids, hints):
        """The per-round callback target: ids [..., W] int32 frontier,
        hints [..., H] int32 lookahead -> (rows [..., W, R] int32,
        fetched [..., W] bool).

        Order per round: (1) wait out the in-flight prefetch generation;
        (2) classify the frontier against the staged rows (copying staged
        data out); (3) submit the next hint batch — BEFORE the demand
        read, so the worker's IO for round t+1 rides the queue
        concurrently with this round's demand IO and then keeps
        overlapping the device's distance/select work; (4) serve the
        demand remainder synchronously.  With the cache on, (3)||(4)
        means the read-vs-hit *split* can depend on which thread touches
        a shared block first, but every row is classified exactly once —
        the conservation law holds regardless of interleaving, and with
        the cache off every row is a read, so ``n_reads`` parity is
        schedule-independent.
        """
        ids = np.asarray(ids)
        hints = np.asarray(hints)
        R = self.layout.R
        fids = ids.reshape(-1)
        rows = np.full((fids.shape[0], R), INVALID, np.int32)
        fetched = np.zeros(fids.shape[0], bool)
        pf = self.prefetcher
        if pf is not None:
            pf.wait()
        self.stats.fetch_calls += 1
        valid = np.nonzero(fids >= 0)[0]
        self.stats.rows_requested += len(valid)
        if pf is not None:
            demand = []
            for i in valid:
                staged = pf.lookup(int(fids[i]))
                if staged is None:
                    demand.append(i)
                    continue
                row, was_read = staged
                if was_read:
                    self.stats.prefetch_hits += 1
                else:
                    self.stats.cache_hits += 1
                rows[i] = row
                fetched[i] = was_read
            demand = np.asarray(demand, np.int64)
        else:
            demand = valid
        if pf is not None and hints.size:
            h = np.unique(hints.reshape(-1))
            pf.submit(h[h >= 0])
        if demand.size:
            r, was = self._serve_batch(fids[demand].astype(np.int64),
                                       prefetch=False)
            rows[demand] = r
            fetched[demand] = was
            self.stats.demand_reads += int(was.sum())
            self.stats.cache_hits += int((~was).sum())
        return (rows.reshape(ids.shape + (R,)),
                fetched.reshape(ids.shape))

    def fetch_vectors(self, ids):
        """Rerank-path gather from the vector region of ``data.bin``:
        ids [..., K] -> rows [..., K, dim] float32 (zeros for ids < 0 —
        masked to +inf by the backend, exactly the dense path's handling)."""
        ids = np.asarray(ids)
        dim = self.layout.dim
        flat = ids.reshape(-1)
        out = np.zeros((flat.shape[0], dim), np.float32)
        ok = flat >= 0
        if ok.any():
            out[ok] = np.asarray(
                self.layout.vectors[flat[ok]], np.float32)
            self.stats.vector_rows += int(ok.sum())
            self.stats.vector_bytes += int(ok.sum()) * dim * 4
        return out.reshape(ids.shape + (dim,))

    def close(self) -> None:
        if self.prefetcher is not None:
            self.prefetcher.close()


class DiskSource:
    """``GraphSource`` over a ``DiskReader`` (see module doc).

    ``hint_width`` > 0 switches the engine into the frontier->prefetch
    handshake: it threads a ``depth * W``-wide lookahead hint through the
    beam loop and calls ``rows_hinted`` instead of ``rows``.  The presence
    of ``rows_hinted`` (not the width) is what routes the engine onto the
    counted-reads path, so depth 0 still gets exact disk accounting.
    """

    def __init__(self, reader: DiskReader, navigable: jax.Array,
                 hint_width: int = 0):
        self.reader = reader
        self.navigable = navigable
        self.hint_width = int(hint_width)
        self.R = reader.layout.R

    def rows_hinted(self, ids: jax.Array, hints: jax.Array):
        """ids [W], hints [H] -> (rows [W, R] int32, fetched [W] bool).
        Under vmap the callback sees the whole [B, W] round at once."""
        rows, fetched = jax.pure_callback(
            self.reader.fetch,
            (jax.ShapeDtypeStruct(ids.shape + (self.R,), jnp.int32),
             jax.ShapeDtypeStruct(ids.shape, jnp.bool_)),
            ids, hints, vmap_method="expand_dims")
        return rows, fetched

    def rows(self, ids: jax.Array) -> jax.Array:
        return self.rows_hinted(
            ids, jnp.full((0,), INVALID, jnp.int32))[0]

    def node_ok(self, ids: jax.Array) -> jax.Array:
        # Validity resolves from the in-memory header table — never an IO.
        return (ids >= 0) & self.navigable[jnp.maximum(ids, 0)]


class DiskVectorBackend:
    """``FullPrecisionBackend`` over the on-disk vector file (the exact
    rerank's "full-precision vectors fetched from the capacity tier").
    Bit-identical distances to the dense backend: same f32 bytes off
    ``data.bin``, same ``l2_sq`` contraction, same +inf masking."""

    def __init__(self, reader: DiskReader):
        self.reader = reader
        self.dim = reader.layout.dim

    def prepare(self, query: jax.Array) -> jax.Array:
        return query.astype(jnp.float32)

    def distances(self, ctx: jax.Array, ids: jax.Array, *,
                  use_kernel: bool = False) -> jax.Array:
        pts = jax.pure_callback(
            self.reader.fetch_vectors,
            jax.ShapeDtypeStruct(ids.shape + (self.dim,), jnp.float32),
            ids, vmap_method="expand_dims")
        d = l2_sq(ctx[None, :], pts)
        return jnp.where(ids >= 0, d, jnp.inf)


class DiskLTISearcher:
    """PQ-navigated beam search whose topology reads come off the layout —
    the disk-backed twin of ``core.lti.search_lti``.

    Navigation distances stay on in-memory PQ codes (the paper's
    ~32B/point fast-memory budget), adjacency rows stream from
    ``topology.bin`` through the cache + prefetch pipeline, and the exact
    rerank gathers full-precision rows from ``data.bin``.  With the cache
    off, results are bit-identical to ``search_lti`` on the same state —
    ids, dists, hops, cmps AND n_reads (the parity matrix in
    ``tests/test_storage.py``).

    The jitted driver closes over this instance's reader, so programs are
    cached per (searcher, k, L, W, rerank) — open one searcher per layout
    generation and reuse it across query batches.
    """

    def __init__(self, layout: StorageLayout, cfg: IndexConfig, *,
                 cache_mb: int = 0, prefetch_depth: int = 0,
                 latency_us: float = 0.0):
        if layout.codes is None or layout.centroids is None:
            raise ValueError("DiskLTISearcher needs a layout with PQ codes")
        self.layout = layout
        self.cfg = cfg
        self.prefetch_depth = int(prefetch_depth)
        self.reader = DiskReader(layout, cache_mb=cache_mb,
                                 prefetch=prefetch_depth > 0,
                                 latency_us=latency_us)
        # The in-memory header tables + navigation codes, on device.
        self.active = jnp.asarray(layout.active)
        self.reportable = jnp.asarray(layout.active & ~layout.deleted)
        self.codes = jnp.asarray(np.asarray(layout.codes))
        self.codebook = pqm.PQCodebook(jnp.asarray(layout.centroids))
        self.start = jnp.int32(layout.start)
        self._programs: dict = {}

    @property
    def stats(self) -> IOStats:
        return self.reader.stats

    def _program(self, k: int, L: int, W: int, rerank: bool):
        key = (k, L, W, rerank)
        fn = self._programs.get(key)
        if fn is not None:
            return fn
        cfg = self.cfg
        use_kernel = cfg.kernel_enabled()
        source = DiskSource(self.reader, self.active,
                            hint_width=self.prefetch_depth * W)
        backend = PQBackend(self.codes, self.codebook)
        vec_backend = DiskVectorBackend(self.reader)
        reportable = self.reportable
        start = self.start
        R = self.layout.R

        @jax.jit
        def run(queries):
            res = beam_search(None, None, start, queries, backend,
                              L=L, max_visits=cfg.visits_bound(L),
                              beam_width=W, use_kernel=use_kernel,
                              source=source, R=R)
            if rerank:
                exact = batch_distances(
                    vec_backend, queries,
                    rerank_candidates(res.ids, reportable),
                    use_kernel=use_kernel)
                res = res._replace(dists=exact)
            ids, d = topk_results(res, k, reportable)
            return ids, d, res.n_hops, res.n_cmps, res.n_reads

        self._programs[key] = run
        return run

    def search(self, queries, *, k: int, L: int,
               beam_width: Optional[int] = None, rerank: bool = True):
        """(ids [B,k], dists [B,k], hops [B], cmps [B], reads [B]) — the
        ``search_lti`` tuple plus the per-query disk read counts."""
        W = min(beam_width or self.cfg.beam_width, L)
        q = jnp.asarray(np.asarray(queries, np.float32))
        return self._program(k, L, W, rerank)(q)

    def close(self) -> None:
        self.reader.close()
