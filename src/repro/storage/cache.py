"""Block-granular LRU cache over the adjacency file.

The cache unit is one ``layout.BLOCK_BYTES`` block of ``topology.bin``
(``block_rows`` adjacency rows — the paper's 4KB sector), NOT a single
row: a real SSD read returns the whole sector, so caching at row
granularity would mis-model both hit rates and read amplification.

Deterministic by construction: eviction is strict LRU over a single
ordered dict, and the reader serializes all mutations (demand fetches and
the prefetch worker never touch the cache concurrently — the worker runs
only between the reader's round-``t`` serve and its round-``t+1`` wait).
The lock below still guards every operation so that invariant is safety,
not correctness.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np


class AdjacencyCache:
    """Thread-safe LRU of adjacency blocks, bounded by bytes."""

    def __init__(self, capacity_bytes: int, block_bytes: int):
        self.capacity_blocks = max(0, int(capacity_bytes) // int(block_bytes))
        self.block_bytes = int(block_bytes)
        self._blocks: OrderedDict[int, np.ndarray] = OrderedDict()
        self._lock = threading.Lock()
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def enabled(self) -> bool:
        return self.capacity_blocks > 0

    def get(self, block_id: int):
        """The cached block (rows [block_rows, R] int32) or None; a hit
        refreshes recency."""
        with self._lock:
            blk = self._blocks.get(block_id)
            if blk is not None:
                self._blocks.move_to_end(block_id)
            return blk

    def put(self, block_id: int, block: np.ndarray) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._blocks[block_id] = block
            self._blocks.move_to_end(block_id)
            while len(self._blocks) > self.capacity_blocks:
                self._blocks.popitem(last=False)
                self.evictions += 1

    def contains(self, block_id: int) -> bool:
        with self._lock:
            return block_id in self._blocks

    def clear(self) -> None:
        with self._lock:
            self._blocks.clear()
