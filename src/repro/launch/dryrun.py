import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and record roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices to
build the 2x16x16 production mesh (single-pod 16x16 uses the first 256).
Smoke tests and benchmarks do NOT import this module — they see 1 device.
"""
import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from ..configs import ASSIGNED, get_arch            # noqa: E402
from .build import build_cell                        # noqa: E402
from .hlo_analysis import analyze_compiled           # noqa: E402
from .mesh import make_production_mesh               # noqa: E402


def flatten_args(args):
    leaves = []
    for a in args:
        leaves.append(a)
    return leaves


def run_cell(arch_name: str, shape: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    arch = get_arch(arch_name)
    cell = arch.cell(shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    result = {"arch": arch_name, "shape": shape, "mesh": mesh_tag,
              "kind": cell.kind, "status": "?"}
    if cell.skip:
        result["status"] = "SKIP"
        result["reason"] = cell.skip
        _emit(result, out_dir, verbose)
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        built = build_cell(arch, cell, mesh)
        with mesh:
            jitted = jax.jit(built.fn, donate_argnums=built.donate)
            lowered = jitted.lower(*built.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        roof = analyze_compiled(compiled)
        result.update({
            "status": "OK",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
            },
            "roofline": roof.as_dict(),
        })
        if verbose:
            print(f"[dryrun] {arch_name} x {shape} x {mesh_tag}: "
                  f"memory_analysis: {ma}")
            print(f"[dryrun] cost_analysis: flops={roof.flops:.3e} "
                  f"bytes={roof.hbm_bytes:.3e} "
                  f"coll={roof.coll_bytes:.3e} ({roof.coll_breakdown})")
    except Exception as e:  # a failure here is a bug in the system
        result["status"] = "FAIL"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _emit(result, out_dir, verbose)
    return result


def _emit(result: dict, out_dir: str | None, verbose: bool):
    line = (f"[dryrun] {result['arch']} x {result['shape']} x "
            f"{result['mesh']}: {result['status']}")
    if result["status"] == "OK":
        r = result["roofline"]
        pk = result["memory_analysis"]["peak_bytes"] / 2**30
        line += (f" peak={pk:.2f}GiB/chip "
                 f"t_comp={r['t_compute']:.4f}s t_mem={r['t_memory']:.4f}s "
                 f"t_coll={r['t_collective']:.4f}s -> {r['bottleneck']}")
    elif result["status"] == "SKIP":
        line += f" ({result['reason'][:80]})"
    else:
        line += f" {result.get('error', '')[:300]}"
    if verbose:
        print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = (f"{result['arch']}__{result['shape']}__"
              f"{result['mesh']}.json")
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        archs = ASSIGNED + ["freshdiskann-1b"]
        fails = 0
        for name in archs:
            arch = get_arch(name)
            for cell in arch.cells:
                for mp in (False, True):
                    r = run_cell(name, cell.shape, mp, args.out,
                                 verbose=True)
                    fails += r["status"] == "FAIL"
        raise SystemExit(1 if fails else 0)

    shapes = ([args.shape] if args.shape
              else [c.shape for c in get_arch(args.arch).cells])
    fails = 0
    for s in shapes:
        r = run_cell(args.arch, s, args.multi_pod, args.out)
        fails += r["status"] == "FAIL"
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
