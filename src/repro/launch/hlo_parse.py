"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scanned program (layer stacks, chunked attention, k-means loops) is
undercounted by its trip count.  This module re-derives the three roofline
inputs directly from the optimized per-device HLO text:

  * **flops**            — 2*M*N*K per ``dot``/``convolution`` (including
    dots inside fusion computations), multiplied by the op's execution count;
  * **hbm bytes**        — per top-level op: operand + result bytes (fusions
    count their boundary, not their interior — matching XLA's fusion
    semantics), x execution count;
  * **collective bytes** — operand bytes of every collective op, x count.

Execution counts: ENTRY = 1; ``while`` body/condition = parent x trip count
(parsed from the loop condition's ``compare(iter, constant)``); ``call``/
branch computations inherit the parent count; fusion computations are
*not* traversed for bytes (interior is register/VMEM traffic) but are for
flops.  Data-dependent ``while`` loops (e.g. beam search) report trip=1 and
are flagged in ``dynamic_loops`` so callers can apply a domain bound.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "iota"}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    total_b = 0
    total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_e, total_b


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    args_str: str

    @property
    def operands(self) -> List[str]:
        # operand names up to the closing paren of the operand list
        depth, end = 0, len(self.args_str)
        for i, ch in enumerate(self.args_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return re.findall(r"%([\w.\-]+)", self.args_str[:end])

    @property
    def attrs(self) -> str:
        return self.args_str


def _balanced_span(s: str, start: int) -> int:
    """Index just past the paren that closes s[start] ('(')."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_header(line: str):
    """'%name (a: T, b: T) -> T {' -> (name, [(pname, type), ...]) or None."""
    st = line.strip()
    if not st.endswith("{") or "->" not in st:
        return None
    is_entry = st.startswith("ENTRY")
    if is_entry:
        st = st[len("ENTRY"):].strip()
    lp = st.find("(")
    if lp < 0:
        return None
    name = st[:lp].strip().lstrip("%").strip()
    if not name or "=" in name or " " in name:
        return None
    rp = _balanced_span(st, lp)
    params_str = st[lp + 1: rp - 1]
    params = []
    depth = 0
    cur = ""
    for ch in params_str + ",":
        if ch == "," and depth == 0:
            if ":" in cur:
                pname, ptype = cur.split(":", 1)
                params.append((pname.strip().lstrip("%"), ptype.strip()))
            cur = ""
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        cur += ch
    return name, params, is_entry


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if cur is None:
            h = _parse_header(line)
            if h:
                cur, params, is_entry = h
                comps[cur] = [Op(pn, pt, "parameter", "")
                              for pn, pt in params]
                if is_entry:
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(Op(*m.groups()))
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _find_trip_count(comps, cond_name: str) -> Optional[int]:
    ops = comps.get(cond_name)
    if not ops:
        return None
    consts = {}
    for op in ops:
        if op.opcode == "constant":
            mm = re.match(r"([\-0-9]+)\)", op.args_str)
            if mm:
                consts[op.name] = int(mm.group(1))
    for op in ops:
        if op.opcode == "compare" and "direction=LT" in op.args_str:
            for o in op.operands:
                if o in consts:
                    return consts[o]
    return None


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    dynamic_loops: List[str] = dataclasses.field(default_factory=list)
    n_while: int = 0


def _dot_flops(op: Op, sizes: Dict[str, tuple]) -> float:
    """2 * result_elems * K, K = product of lhs contracting dims."""
    res_elems, _ = _shape_elems_bytes(op.type_str)
    operands = op.operands
    if not operands:
        return 0.0
    lhs = sizes.get(operands[0])
    if lhs is None:
        return 0.0
    dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.args_str)
    if not dims_m:
        return float(2 * res_elems)
    shape_m = _SHAPE_RE.search(lhs[2])
    if not shape_m:
        return float(2 * res_elems)
    dims = [int(d) for d in shape_m.group(2).split(",") if d]
    k = 1
    for ci in dims_m.group(1).split(","):
        if ci:
            k *= dims[int(ci)]
    return float(2 * res_elems * k)


def analyze_text(text: str, collect=None) -> HloCost:
    """collect: optional list — filled with (bytes, comp, op, opcode, count)
    tuples for debugging the byte model."""
    comps = parse_module(text)
    entry = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)

    # per-computation shape tables
    sizes: Dict[str, Dict[str, tuple]] = {}
    for cname, ops in comps.items():
        tbl = {}
        for op in ops:
            e, b = _shape_elems_bytes(op.type_str)
            tbl[op.name] = (e, b, op.type_str)
        sizes[cname] = tbl

    # ---- slice-aware byte accounting ------------------------------------
    # XLA counts bytes actually touched: a dynamic-slice reads its result
    # size, not its full operand; an in-place dynamic-update-slice writes
    # the update size.  Mirror that per op and across fusion boundaries
    # (a fusion operand consumed only by slicing ops inside the fusion
    # contributes the sliced bytes).

    def _fusion_param_bytes(fname: str) -> Optional[dict]:
        """param index -> bytes read (None entry = full size)."""
        ops = comps.get(fname)
        if ops is None:
            return None
        tbl = sizes[fname]
        params = [op for op in ops if op.opcode == "parameter"]
        consumers: dict = {p.name: [] for p in params}
        for op in ops:
            for o in op.operands:
                if o in consumers:
                    consumers[o].append(op)
        out = {}
        for i, p in enumerate(params):
            uses = consumers[p.name]
            if uses and all(u.opcode in ("dynamic-slice", "gather")
                            and u.operands and u.operands[0] == p.name
                            for u in uses):
                # touched bytes ~ the sliced/gathered result sizes
                out[i] = sum(_shape_elems_bytes(u.type_str)[1]
                             for u in uses)
            elif uses and all(
                    u.opcode in ("dynamic-update-slice", "scatter",
                                 "scatter-add")
                    and u.operands and u.operands[0] == p.name
                    for u in uses):
                out[i] = 0          # in-place updated buffer: write counted
                #                     via the root below
            else:
                out[i] = None
        return out

    def _root_write_bytes(fname: str) -> Optional[float]:
        """bytes written by a fusion whose root is (a tuple of) DUS."""
        ops = comps.get(fname)
        if not ops:
            return None
        tbl = sizes[fname]
        root = ops[-1]
        roots = [root]
        if root.opcode == "tuple":
            byname = {o.name: o for o in ops}
            roots = [byname[o] for o in root.operands if o in byname]
        total = 0.0
        any_dus = False
        for r in roots:
            if r.opcode == "dynamic-update-slice" and len(r.operands) >= 2:
                upd = tbl.get(r.operands[1], (0, 0, ""))[1]
                total += 2 * upd          # read-modify-write of the window
                any_dus = True
            elif r.opcode in ("scatter", "scatter-add") \
                    and len(r.operands) >= 3:
                upd = tbl.get(r.operands[2], (0, 0, ""))[1]
                idx = tbl.get(r.operands[1], (0, 0, ""))[1]
                total += 2 * upd + idx
                any_dus = True
            else:
                total += _shape_elems_bytes(r.type_str)[1]
        return total if any_dus else None

    def _op_traffic(op: Op, tbl: dict) -> float:
        oc = op.opcode
        rb = _shape_elems_bytes(op.type_str)[1]
        operands = op.operands
        if oc in ("dynamic-slice", "gather"):
            idx = (tbl.get(operands[1], (0, 0, ""))[1]
                   if oc == "gather" and len(operands) > 1 else 0)
            return 2.0 * rb + idx
        if oc in ("dynamic-update-slice", "scatter", "scatter-add"):
            ui = 1 if oc == "dynamic-update-slice" else 2
            upd = tbl.get(operands[ui], (0, 0, ""))[1] \
                if len(operands) > ui else rb
            return 2.0 * upd
        if oc == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", op.args_str)
            fname = fm.group(1) if fm else None
            pb = _fusion_param_bytes(fname) if fname else None
            wb = _root_write_bytes(fname) if fname else None
            total = wb if wb is not None else rb
            for i, o in enumerate(operands):
                full = tbl.get(o, (0, 0, ""))[1]
                if pb is not None and i in pb and pb[i] is not None:
                    total += min(pb[i], full)
                else:
                    total += full
            return total
        return rb + sum(tbl.get(o, (0, 0, ""))[1] for o in operands)

    cost = HloCost()
    visited_stack: set = set()

    def comp_cost(cname: str, count: float, traverse_bytes: bool):
        if cname not in comps or count <= 0:
            return
        key = (cname, traverse_bytes)
        if key in visited_stack:
            return
        visited_stack.add(key)
        tbl = sizes[cname]
        for op in comps[cname]:
            oc = op.opcode
            if oc in ("dot", "convolution"):
                cost.flops += count * _dot_flops(op, tbl)
            if oc == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", op.args_str)
                if fm:
                    comp_cost(fm.group(1), count, traverse_bytes=False)
            if oc == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.args_str)
                bm = re.search(r"body=%?([\w.\-]+)", op.args_str)
                tm = _TRIP_RE.search(op.args_str)
                trip = int(tm.group(1)) if tm else (
                    _find_trip_count(comps, cm.group(1)) if cm else None)
                cost.n_while += 1
                if trip is None:
                    trip = 1
                    cost.dynamic_loops.append(f"{cname}/{op.name}")
                if bm:
                    comp_cost(bm.group(1), count * trip, traverse_bytes)
                if cm:
                    comp_cost(cm.group(1), count * trip, traverse_bytes)
                continue
            if oc in ("call", "conditional", "custom-call"):
                for cm2 in re.finditer(
                        r"(?:to_apply|calls|true_computation|"
                        r"false_computation)=%?([\w.\-]+)", op.args_str):
                    comp_cost(cm2.group(1), count, traverse_bytes)
                for cm3 in re.finditer(
                        r"branch_computations=\{([^}]*)\}", op.args_str):
                    for b in re.findall(r"%?([\w.\-]+)", cm3.group(1)):
                        comp_cost(b, count, traverse_bytes)
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in _COLLECTIVES:
                ob = sum(tbl.get(o, (0, 0, ""))[1] for o in op.operands)
                cost.coll_bytes += count * ob
                cost.coll_breakdown[base] = (
                    cost.coll_breakdown.get(base, 0.0) + count * ob)
            if traverse_bytes and oc not in _SKIP_BYTES \
                    and not oc.endswith("-done"):
                t = count * _op_traffic(op, tbl)
                cost.hbm_bytes += t
                if collect is not None:
                    collect.append((t, cname, op.name, oc, count))
        visited_stack.discard(key)

    if entry:
        comp_cost(entry, 1.0, True)
    return cost
