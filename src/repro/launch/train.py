"""Training driver: ``--arch <id>`` picks a config; ``--smoke`` uses the
reduced config (CPU-runnable).  Composes mesh + sharded train step + data
pipeline + fault-tolerant loop (checkpoint/restart via --ckpt-dir).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..data.pipelines import (click_stream, lm_token_stream, sasrec_stream,
                              synthetic_graph)
from ..distributed.ctx import activation_sharding
from ..optim.adamw import adamw_init
from ..training.loop import run_training
from ..training.steps import make_train_step
from .mesh import make_host_mesh


def build_smoke_trainer(arch_name: str, batch: int, seq: int, lr: float,
                        accum: int = 1):
    arch = get_arch(arch_name)
    cfg = arch.smoke_config
    key = jax.random.PRNGKey(0)

    if arch.family == "lm":
        from ..models import transformer as tf
        cfg = dataclasses.replace(cfg, q_chunk=min(cfg.q_chunk, seq),
                                  kv_chunk=min(cfg.kv_chunk, seq))
        params = tf.init_params(key, cfg)

        def loss_fn(p, b):
            return tf.lm_loss(p, b["tokens"], b["targets"], cfg)

        stream = lambda s: lm_token_stream(batch, seq, cfg.vocab,
                                           start_step=s)
    elif arch.family == "recsys":
        from ..models import recsys as rec
        params = rec.init_recsys_params(key, cfg)
        if cfg.kind == "sasrec":
            def loss_fn(p, b):
                loss = rec.sasrec_loss(p, b["seq"], b["pos"], b["neg"], cfg)
                return loss, {"bpr": loss}
            stream = lambda s: sasrec_stream(batch, cfg.seq_len,
                                             cfg.n_items, start_step=s)
        else:
            def loss_fn(p, b):
                loss = rec.recsys_loss(p, b["ids"], b["labels"], cfg)
                return loss, {"logloss": loss}
            stream = lambda s: click_stream(batch, cfg.n_sparse,
                                            cfg.rows_per_field, start_step=s)
    elif arch.family == "gnn":
        from ..models import gnn
        params = gnn.init_sage_params(key, cfg)
        g = synthetic_graph(512, 8, cfg.d_feat, cfg.n_classes)

        def loss_fn(p, b):
            loss = gnn.sage_loss_sampled(
                p, b["key"], jnp.asarray(g["feats"]),
                jnp.asarray(g["offsets"]), jnp.asarray(g["nbrs"]),
                b["seeds"], b["labels"], cfg)
            return loss, {"ce": loss}

        def stream(s):
            step = s
            while True:
                r = np.random.default_rng([7, step])
                seeds = r.integers(0, 512, batch)
                yield {"seeds": seeds.astype(np.int32),
                       "labels": g["labels"][seeds],
                       "key": np.array(
                           jax.random.key_data(jax.random.PRNGKey(step)))}
                step += 1
    else:
        raise ValueError(arch.family)

    step = make_train_step(loss_fn, lr=lr, accum_steps=accum)
    return params, step, stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    mesh = make_host_mesh()
    params, step, stream = build_smoke_trainer(
        args.arch, args.batch, args.seq, args.lr, args.accum)
    opt = adamw_init(params)

    def wrapped(p, o, b):
        with activation_sharding(mesh):
            return step(p, o, b)

    jit_step = jax.jit(wrapped, donate_argnums=(0, 1))
    params, opt, log = run_training(
        mesh, jit_step, params, opt, stream, n_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"[train] done: final metrics {log[-1] if log else {}}")


if __name__ == "__main__":
    main()
