"""Serving driver for the FreshDiskANN system (the paper's workload):
bootstraps an index, then runs a concurrent stream of inserts / deletes /
searches with periodic StreamingMerge, reporting recall + latencies.

    PYTHONPATH=src python -m repro.launch.serve --points 4096 --dim 32 \
        --updates 2000 --searches 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.config import IndexConfig, PQConfig, SystemConfig
from ..core.index import brute_force, recall_at_k
from ..core.system import bootstrap_system
from ..data.pipelines import vector_stream

import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--updates", type=int, default=2000)
    ap.add_argument("--searches", type=int, default=20)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--wal-dir", default=None)
    args = ap.parse_args()

    stream = vector_stream(args.points, args.dim, seed=3)
    base = next(stream)
    cfg = SystemConfig(
        index=IndexConfig(capacity=args.points * 4, dim=args.dim, R=24,
                          L_build=32, L_search=48, alpha=1.2),
        pq=PQConfig(dim=args.dim, m=8, ksub=64, kmeans_iters=6),
        ro_snapshot_points=args.points // 4,
        merge_threshold=args.points // 2,
        temp_capacity=args.points, insert_batch=64, wal_dir=args.wal_dir)
    t0 = time.perf_counter()
    sys_ = bootstrap_system(base, np.arange(args.points), cfg)
    print(f"[serve] bootstrap {args.points} pts in "
          f"{time.perf_counter() - t0:.1f}s")

    upd = vector_stream(64, args.dim, seed=11)
    q_stream = vector_stream(32, args.dim, seed=13)
    next_id = args.points
    live = dict(enumerate(np.asarray(base)))
    ins_lat, del_lat, search_recalls = [], [], []
    rng = np.random.default_rng(0)

    for i in range(args.updates // 64):
        batch = next(upd)
        for v in batch:
            t = time.perf_counter()
            sys_.insert(next_id, v)
            ins_lat.append(time.perf_counter() - t)
            live[next_id] = v
            next_id += 1
        # delete an equal number of random existing points
        victims = rng.choice(sorted(live), size=min(64, len(live) - 64),
                             replace=False)
        for ext in victims:
            t = time.perf_counter()
            sys_.delete(int(ext))
            del_lat.append(time.perf_counter() - t)
            live.pop(int(ext))
        if (i + 1) % 4 == 0:
            q = next(q_stream)
            ids, d = sys_.search(q, k=args.k)
            keys = np.asarray(sorted(live))
            mat = np.stack([live[k] for k in keys])
            gt = brute_force(jnp.asarray(mat), jnp.ones(len(keys), bool),
                             jnp.asarray(q), args.k)
            gt_ext = keys[np.asarray(gt)]
            rec = recall_at_k(jnp.asarray(ids), jnp.asarray(gt_ext))
            search_recalls.append(float(rec))
            print(f"[serve] step {i + 1}: size={sys_.size} "
                  f"recall@{args.k}={float(rec):.3f} "
                  f"ins_p50={np.median(ins_lat) * 1e3:.2f}ms "
                  f"merges={sys_.stats.merges}")

    print(f"[serve] final: recall_mean="
          f"{np.mean(search_recalls):.3f} inserts={sys_.stats.inserts} "
          f"deletes={sys_.stats.deletes} merges={sys_.stats.merges} "
          f"ins_p50={np.median(ins_lat) * 1e3:.2f}ms "
          f"del_p50={np.median(del_lat) * 1e6:.1f}us")


if __name__ == "__main__":
    main()
