"""Post-compile HLO analysis: collective bytes + roofline terms.

The compiled module is the *per-device* SPMD program, so every byte count
below is per-chip.  ``collective_bytes`` resolves operand names to their
defining ops' result shapes (operand shapes are not printed inline by this
XLA version).

Roofline model (TPU v5e targets):
    compute term    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
    memory term     = HLO_bytes / HBM_bw                (819 GB/s)
    collective term = collective_bytes / ICI_bw         (~50 GB/s/link)
(all per-chip; FLOPs/bytes from compiled.cost_analysis()).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (v5e: 4 links usable)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\((.*)\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes (per device) from optimized HLO."""
    sizes: Dict[str, int] = {}
    pending = []              # (kind, operand names) resolved after pass 1
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op, operands = m.groups()
        sizes[name] = _shape_bytes(type_str)
        base = op.split(".")[0]
        if base.endswith("-start"):
            base = base[:-6]
        if base.endswith("-done"):
            continue          # counted at -start
        if base in _COLLECTIVES:
            ops = re.findall(r"%[\w.\-]+", operands)
            pending.append((base, ops))
    out: Dict[str, int] = {}
    for kind, ops in pending:
        b = sum(sizes.get(o, 0) for o in ops)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops (trip-count-aware)
    hbm_bytes: float           # per-device bytes accessed
    coll_bytes: float          # per-device collective operand bytes
    coll_breakdown: Dict[str, int]
    peak_mem_bytes: Optional[float]   # temp + args + output (per device)
    xla_flops: float = 0.0     # raw cost_analysis (loop bodies counted once)
    xla_bytes: float = 0.0
    dynamic_loops: list = dataclasses.field(default_factory=list)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step estimate = max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "peak_mem_bytes": self.peak_mem_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck, "step_time": self.step_time,
            "xla_flops": self.xla_flops, "xla_bytes": self.xla_bytes,
            "dynamic_loops": self.dynamic_loops,
        }


def analyze_compiled(compiled) -> Roofline:
    """Trip-count-aware analysis via hlo_parse (XLA's cost_analysis counts
    while bodies once; see hlo_parse docstring).  The raw XLA numbers are
    kept in xla_* fields as a cross-check lower bound."""
    from .hlo_parse import analyze_text

    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    cost = analyze_text(text)
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                        + ma.output_size_in_bytes
                        - ma.alias_size_in_bytes)
    except Exception:
        pass
    roof = Roofline(cost.flops, cost.hbm_bytes, cost.coll_bytes,
                    {k: int(v) for k, v in cost.coll_breakdown.items()},
                    mem)
    roof.xla_flops = float(ca.get("flops", 0.0))
    roof.xla_bytes = float(ca.get("bytes accessed", 0.0))
    roof.dynamic_loops = cost.dynamic_loops
    return roof
