"""Build (step_fn, abstract_args, donate) for every (arch x cell x mesh).

This is the single dispatch point shared by the dry-run, the roofline
harness and the drivers: given an ArchSpec, a Cell and a Mesh it returns a
jit-ready function plus fully-sharded ShapeDtypeStruct arguments (params,
optimizer state, inputs) — nothing is allocated.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.common import ArchSpec, Cell
from ..distributed.sharding import (batch_axes, cache_shardings,
                                    generic_param_shardings,
                                    lm_param_shardings, spec_for,
                                    table_sharding)
from ..optim.adamw import adamw_init
from ..training.steps import make_train_step

REPL = P()


def _sds_with(shardings, abstract):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def _abstract_opt(params_abstract, shardings, mesh):
    opt = jax.eval_shape(adamw_init, params_abstract)
    step_s = NamedSharding(mesh, REPL)
    return opt._replace(
        step=jax.ShapeDtypeStruct(opt.step.shape, opt.step.dtype,
                                  sharding=step_s),
        m=_sds_with(shardings, opt.m),
        v=_sds_with(shardings, opt.v))


def _input_sds(mesh: Mesh, specs: dict, rules: dict) -> dict:
    """Attach shardings to raw input ShapeDtypeStructs by name."""
    ba = batch_axes(mesh)
    out = {}
    for name, sds in specs.items():
        if name in rules:
            spec = rules[name]
        elif hasattr(sds, "shape"):
            spec = spec_for(mesh, sds.shape,
                            [ba] + [None] * (len(sds.shape) - 1))
        else:
            spec = None
        if hasattr(sds, "shape"):
            out[name] = jax.ShapeDtypeStruct(
                sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec))
        else:
            out[name] = sds   # pytree (caches) — pre-sharded by caller
    return out


@dataclasses.dataclass
class Built:
    fn: Callable                 # positional-arg step function
    args: tuple                  # abstract, sharded args
    donate: tuple = ()
    static: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _build_lm(arch: ArchSpec, cell: Cell, mesh: Mesh) -> Built:
    from ..models import transformer as tf
    from ..serving.steps import make_lm_decode_step, make_lm_prefill_step

    cfg = arch.full_config
    abstract = tf.abstract_params(cfg)
    pshard = lm_param_shardings(mesh, abstract)
    params = _sds_with(pshard, abstract)
    ba = batch_axes(mesh)

    if cell.kind == "train":
        # microbatch accumulation for the big models: halves activation
        # residency at identical math (loss/grad averaged over microbatches)
        accum = 2 if cfg.param_count() > 5e9 else 1
        step = make_train_step(
            lambda p, b: tf.lm_loss(p, b["tokens"], b["targets"], cfg),
            accum_steps=accum)
        opt = _abstract_opt(abstract, pshard, mesh)
        batch = _input_sds(mesh, cell.specs(), {
            "tokens": P(ba, "model"), "targets": P(ba, "model")})
        return Built(step, (params, opt, batch), donate=(0, 1))

    if cell.kind == "prefill":
        fn = make_lm_prefill_step(cfg)
        batch = _input_sds(mesh, cell.specs(), {"tokens": P(ba, "model")})
        return Built(fn, (params, batch["tokens"]))

    if cell.kind == "decode":
        fn = make_lm_decode_step(cfg)
        specs = cell.specs()
        cshard = cache_shardings(mesh, specs["caches"], cell.meta["batch"])
        caches = _sds_with(cshard, specs["caches"])
        b = cell.meta["batch"]
        tok = jax.ShapeDtypeStruct(
            (b,), jnp.int32,
            sharding=NamedSharding(mesh, spec_for(mesh, (b,), [ba])))
        pos = jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, REPL))
        return Built(fn, (params, caches, tok, pos), donate=(1,))

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _build_gnn(arch: ArchSpec, cell: Cell, mesh: Mesh) -> Built:
    from ..models import gnn

    meta = cell.meta
    cfg = dataclasses.replace(
        arch.full_config, d_feat=meta["d_feat"],
        n_classes=meta["n_classes"],
        fanout=tuple(meta.get("fanout", arch.full_config.fanout)))
    abstract = jax.eval_shape(
        lambda k: gnn.init_sage_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pshard = generic_param_shardings(mesh, abstract)
    params = _sds_with(pshard, abstract)
    ba = batch_axes(mesh)
    all_ax = tuple(mesh.axis_names)

    def pad_shard(x, axes):
        """Pad axis 0 to a mesh-divisible size, then constrain sharding —
        the edge/node arrays of real graphs are never divisible."""
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        pad = (-x.shape[0]) % n
        if pad:
            widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            x = jnp.pad(x, widths)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(axes, *([None] * (x.ndim - 1)))))

    if cell.kind == "train_full":
        def loss_fn(p, b):
            n = b["feats"].shape[0]
            src = pad_shard(b["src"], all_ax)
            dst = pad_shard(b["dst"], all_ax)
            # padded edges self-loop on node 0 with zero weight via masking:
            # segment ids beyond n are dropped by num_segments bound below.
            src = jnp.where(jnp.arange(src.shape[0]) < b["src"].shape[0],
                            src, n - 1)
            dst = jnp.where(jnp.arange(dst.shape[0]) < b["dst"].shape[0],
                            dst, n - 1)
            loss = gnn.sage_loss_full(p, b["feats"], src, dst,
                                      b["labels"], b["mask"], cfg)
            return loss, {"ce": loss}
    elif cell.kind == "train_sampled":
        def loss_fn(p, b):
            loss = gnn.sage_loss_sampled(
                p, b["key"], b["feats"], b["offsets"], b["nbrs"],
                b["seeds"], b["labels"], cfg)
            return loss, {"ce": loss}
    elif cell.kind == "train_batched":
        def loss_fn(p, b):
            logits = gnn.sage_forward_batched(
                p, b["feats"], b["src"], b["dst"], b["edge_mask"], cfg)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, b["labels"][:, None], axis=-1)[:, 0]
            loss = (lse - gold).mean()
            return loss, {"ce": loss}
    else:
        raise ValueError(cell.kind)

    step = make_train_step(loss_fn)
    opt = _abstract_opt(abstract, pshard, mesh)
    rules = {"feats": REPL, "src": REPL, "dst": REPL, "labels": REPL,
             "mask": REPL, "offsets": REPL, "nbrs": REPL, "key": REPL,
             "seeds": P(ba)}
    if cell.kind == "train_batched":
        rules = {k: P(ba, *([None] * 1)) for k in
                 ("src", "dst", "edge_mask")}
        rules["feats"] = P(ba, None, None)
        rules["labels"] = P(ba)
    batch = _input_sds(mesh, cell.specs(), rules)
    return Built(step, (params, opt, batch), donate=(0, 1))


# ---------------------------------------------------------------------------
# Recsys family
# ---------------------------------------------------------------------------

def _build_recsys(arch: ArchSpec, cell: Cell, mesh: Mesh) -> Built:
    from ..models import recsys as rec
    from ..serving.steps import make_recsys_serve_step, make_retrieval_step

    cfg = arch.full_config
    abstract = jax.eval_shape(
        lambda k: rec.init_recsys_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pshard = generic_param_shardings(
        mesh, abstract, table_names=("V'", "w_lin", "item_emb"))
    params = _sds_with(pshard, abstract)
    ba = batch_axes(mesh)

    if cell.kind == "train":
        if cfg.kind == "sasrec":
            def loss_fn(p, b):
                loss = rec.sasrec_loss(p, b["seq"], b["pos"], b["neg"], cfg)
                return loss, {"bpr": loss}
        else:
            def loss_fn(p, b):
                loss = rec.recsys_loss(p, b["ids"], b["labels"], cfg)
                return loss, {"logloss": loss}
        step = make_train_step(loss_fn)
        opt = _abstract_opt(abstract, pshard, mesh)
        batch = _input_sds(mesh, cell.specs(), {})
        return Built(step, (params, opt, batch), donate=(0, 1))

    if cell.kind == "serve":
        if cfg.kind == "sasrec":
            def fn(p, seq):
                q = rec.sasrec_user_embedding(p, seq, cfg)
                return rec.retrieval_topk(q, p["item_emb"], 100)
            batch = _input_sds(mesh, cell.specs(), {})
            return Built(fn, (params, batch["seq"]))
        fn0 = make_recsys_serve_step(cfg)
        batch = _input_sds(mesh, cell.specs(), {})
        return Built(fn0, (params, batch["ids"]))

    if cell.kind == "retrieval":
        fn = make_retrieval_step(cfg, k=100)
        specs = cell.specs()
        rules = {"item_table": table_sharding(
            mesh, specs["item_table"].shape)}
        batch = _input_sds(mesh, specs, rules)
        user = batch.get("ids", batch.get("seq"))
        return Built(fn, (params, user, batch["item_table"]))

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# ANN (the paper's own config)
# ---------------------------------------------------------------------------

def _build_ann(arch: ArchSpec, cell: Cell, mesh: Mesh) -> Built:
    from . import ann_steps

    dep = arch.full_config
    lti = ann_steps.abstract_lti(dep.index, dep.pq, mesh)
    batch = _input_sds(mesh, cell.specs(), {
        "queries": REPL, "new_vecs": REPL, "new_valid": REPL})
    if cell.kind == "ann_search":
        fn = ann_steps.make_distributed_search(mesh, dep.index, k=dep.k)
        return Built(fn, (lti, batch["queries"]))
    if cell.kind == "ann_insert":
        fn = ann_steps.make_distributed_insert(mesh, dep.index)
        return Built(fn, (lti, batch["new_vecs"]), donate=(0,))
    if cell.kind == "ann_merge":
        n = len(mesh.devices.flat)
        dmask = jax.ShapeDtypeStruct(
            (dep.index.capacity * n,), jnp.bool_,
            sharding=NamedSharding(mesh, P(tuple(mesh.axis_names))))
        fn = ann_steps.make_distributed_merge(mesh, dep.index, dep.pq)
        return Built(fn, (lti, batch["new_vecs"], batch["new_valid"],
                          dmask), donate=(0,))
    raise ValueError(cell.kind)


_BUILDERS = {"lm": _build_lm, "gnn": _build_gnn, "recsys": _build_recsys,
             "ann": _build_ann}


def build_cell(arch: ArchSpec, cell: Cell, mesh: Mesh) -> Built:
    from ..distributed.ctx import activation_sharding

    built = _BUILDERS[arch.family](arch, cell, mesh)
    inner = built.fn

    @functools.wraps(inner)
    def with_ctx(*args):
        with activation_sharding(mesh):
            return inner(*args)

    built.fn = with_ctx
    return built
