"""Distributed FreshDiskANN steps over the production mesh.

The paper's own distribution design (§1): every chip hosts an independent
sub-index ("a thousand machines host a billion points each"); queries are
broadcast to all shards and results top-k-merged; updates are routed to one
shard by id hash; StreamingMerge is fully shard-local (zero ICI bytes — the
SSD-write-amplification discipline re-expressed as collective-byte
discipline on the pod).

Implemented with ``shard_map`` over every mesh axis: the global LTI arrays
carry a leading [n_shards * capacity] point axis; each shard's local block
is one FreshVamana/LTI instance.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import pq as pqm
from ..distributed.ctx import shard_map_compat as _shard_map
from ..core.config import IndexConfig, PQConfig
from ..core.graph import GraphState
from ..core.index import insert as mem_insert
from ..core.lti import LTIState
from ..core.merge import streaming_merge
from ..core.search import (FullPrecisionBackend, PQBackend, batch_distances,
                           beam_search, topk_results)


def _all_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def shard_specs(mesh: Mesh):
    """(in_specs pytree for LTIState, codebook spec, n_shards)."""
    ax = _all_axes(mesh)
    graph = GraphState(
        vectors=P(ax, None), adjacency=P(ax, None), active=P(ax),
        deleted=P(ax), start=P(ax), n_total=P(ax))
    lti = LTIState(graph=graph, codes=P(ax, None), codebook=None)
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return lti, P(), n


def abstract_lti(cfg: IndexConfig, pq_cfg: PQConfig, mesh: Mesh,
                 dtype=jnp.float32):
    """Global ShapeDtypeStructs for the sharded LTI (no allocation)."""
    n = len(mesh.devices.flat)
    ax = _all_axes(mesh)
    cap = cfg.capacity * n

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(
            shape, dt, sharding=NamedSharding(mesh, spec))

    graph = GraphState(
        vectors=sds((cap, cfg.dim), dtype, P(ax, None)),
        adjacency=sds((cap, cfg.R), jnp.int32, P(ax, None)),
        active=sds((cap,), jnp.bool_, P(ax)),
        deleted=sds((cap,), jnp.bool_, P(ax)),
        start=sds((n,), jnp.int32, P(ax)),
        n_total=sds((n,), jnp.int32, P(ax)),
    )
    codebook = pqm.PQCodebook(
        sds((pq_cfg.m, pq_cfg.ksub, pq_cfg.dsub), jnp.float32, P()))
    return LTIState(graph=graph,
                    codes=sds((cap, pq_cfg.m), jnp.uint8, P(ax, None)),
                    codebook=codebook)


def _shard_index(mesh: Mesh):
    """Flat shard id inside shard_map."""
    ax = _all_axes(mesh)
    idx = jnp.int32(0)
    for a in ax:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def make_distributed_search(mesh: Mesh, cfg: IndexConfig, *, k: int,
                            L: int | None = None,
                            beam_width: int | None = None) -> Callable:
    """(lti_global, queries[Q, d] replicated) -> (ids [Q, k], dists [Q, k]).

    Local PQ-navigated beam search on every shard (paper: broadcast), then a
    global top-k merge (all_gather of k candidates per shard — the only
    collective in the read path).
    """
    L = L or cfg.L_search
    W = beam_width or cfg.beam_width
    lti_specs, _, n_shards = shard_specs(mesh)
    ax = _all_axes(mesh)

    def local(lti: LTIState, queries):
        g = lti.graph
        start = g.start[0]
        use_kernel = cfg.kernel_enabled()
        res = beam_search(
            g.adjacency, g.active, start, queries,
            PQBackend(lti.codes, lti.codebook),
            L=L, max_visits=cfg.visits_bound(L), beam_width=W,
            use_kernel=use_kernel)
        reportable = g.active & ~g.deleted
        # exact rerank of the candidate list (paper §5.2: full-precision
        # vectors fetched from the capacity tier re-rank the ADC results —
        # essential when merging coarse ADC distances across shards)
        exact = batch_distances(FullPrecisionBackend(g.vectors), queries,
                                res.ids, use_kernel=use_kernel)
        ids, d = topk_results(res._replace(dists=exact), k, reportable)
        # globalize ids: shard offset into the flat point axis
        offset = _shard_index(mesh) * cfg.capacity
        ids = jnp.where(ids >= 0, ids + offset, ids)
        # merge across shards: gather [n_shards, Q, k] then local top-k
        all_ids = jax.lax.all_gather(ids, ax)      # [s0, s1(, s2), Q, k]
        all_d = jax.lax.all_gather(d, ax)
        Q = queries.shape[0]
        flat_ids = all_ids.reshape(-1, Q, k).transpose(1, 0, 2).reshape(Q, -1)
        flat_d = all_d.reshape(-1, Q, k).transpose(1, 0, 2).reshape(Q, -1)
        order = jnp.argsort(flat_d, axis=1)[:, :k]
        return (jnp.take_along_axis(flat_ids, order, axis=1),
                jnp.take_along_axis(flat_d, order, axis=1))

    lti_specs = LTIState(graph=lti_specs.graph, codes=lti_specs.codes,
                         codebook=pqm.PQCodebook(P()))
    return jax.jit(_shard_map(
        local, mesh=mesh, in_specs=(lti_specs, P()),
        out_specs=(P(), P()), check_vma=False))


def make_distributed_insert(mesh: Mesh, cfg: IndexConfig,
                            per_shard: int = 32) -> Callable:
    """(lti, new_vecs [B, d] replicated) -> lti with hash-routed inserts.

    Each shard picks the rows hashed to it (up to ``per_shard``), allocates
    free local slots, and runs the in-memory Algorithm 2 against its
    sub-index using full-precision vectors + PQ code updates.  No
    collectives at all — the paper's "updates are routed" path.
    """
    lti_specs, _, n_shards = shard_specs(mesh)
    pq_m = None  # resolved from codes shape at trace time

    def local(lti: LTIState, new_vecs):
        g = lti.graph
        B, dim = new_vecs.shape
        me = _shard_index(mesh)
        owner = ((jnp.arange(B, dtype=jnp.uint32)
                  * jnp.uint32(2654435761)) % n_shards).astype(jnp.int32)
        mine = owner == me
        # select up to per_shard of my rows (top_k over the 0/1 indicator)
        take, rows = jax.lax.top_k(mine.astype(jnp.int32), per_shard)
        rows = jnp.where(take > 0, rows, -1)
        vecs = jnp.where((rows >= 0)[:, None],
                         new_vecs[jnp.maximum(rows, 0)], 0.0)
        # allocate local free slots
        free = ~g.active
        _, slots = jax.lax.top_k(free.astype(jnp.int32), per_shard)
        slots = jnp.where((take > 0) & free[slots], slots, -1)
        new_graph = mem_insert(g._replace(start=g.start[0],
                                          n_total=g.n_total[0]),
                               slots, vecs, cfg)
        codes = pqm.encode(lti.codebook, vecs,
                           PQConfig(dim=dim, m=lti.codes.shape[1],
                                    ksub=lti.codebook.centroids.shape[1]))
        wslots = jnp.where(slots >= 0, slots, g.capacity)
        new_codes = lti.codes.at[wslots].set(codes, mode="drop")
        ng = new_graph._replace(start=new_graph.start[None],
                                n_total=new_graph.n_total[None])
        return LTIState(ng, new_codes, lti.codebook)

    lti_in = LTIState(graph=lti_specs.graph, codes=lti_specs.codes,
                      codebook=pqm.PQCodebook(P()))
    return jax.jit(_shard_map(
        local, mesh=mesh, in_specs=(lti_in, P()), out_specs=lti_in,
        check_vma=False),
        donate_argnums=(0,))


def make_distributed_merge(mesh: Mesh, cfg: IndexConfig, pq_cfg: PQConfig,
                           *, insert_chunk: int = 256,
                           block: int = 1024,
                           use_sdc: bool = False) -> Callable:
    """(lti, new_vecs [B, d] repl, new_valid [B], delete_mask global)
    -> merged lti.  StreamingMerge runs fully shard-local: each shard
    processes its hash-share of inserts and its slice of the DeleteList.
    Zero collective bytes — merge bandwidth scales linearly with shards.
    """
    lti_specs, _, n_shards = shard_specs(mesh)
    ax = _all_axes(mesh)

    def local(lti: LTIState, new_vecs, new_valid, delete_mask):
        g = lti.graph
        B = new_vecs.shape[0]
        per_shard = max(B // n_shards * 4, 8)
        me = _shard_index(mesh)
        owner = ((jnp.arange(B, dtype=jnp.uint32)
                  * jnp.uint32(2654435761)) % n_shards).astype(jnp.int32)
        mine = (owner == me) & new_valid
        take, rows = jax.lax.top_k(mine.astype(jnp.int32), per_shard)
        rows = jnp.where(take > 0, rows, -1)
        vecs = jnp.where((rows >= 0)[:, None],
                         new_vecs[jnp.maximum(rows, 0)], 0.0)
        local_lti = LTIState(
            g._replace(start=g.start[0], n_total=g.n_total[0]),
            lti.codes, lti.codebook)
        merged, _stats = streaming_merge(
            local_lti, vecs, take > 0, delete_mask, cfg, pq_cfg,
            insert_chunk=min(insert_chunk, per_shard), block=block,
            use_sdc=use_sdc)
        mg = merged.graph
        mg = mg._replace(start=mg.start[None], n_total=mg.n_total[None])
        return LTIState(mg, merged.codes, merged.codebook)

    lti_in = LTIState(graph=lti_specs.graph, codes=lti_specs.codes,
                      codebook=pqm.PQCodebook(P()))
    return jax.jit(_shard_map(
        local, mesh=mesh,
        in_specs=(lti_in, P(), P(), lti_specs.graph.deleted),
        out_specs=lti_in, check_vma=False),
        donate_argnums=(0,))
