"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model);
multi-pod: 2x16x16 = 512 chips (pod, data, model).
"""
from __future__ import annotations

import jax


def mesh_with_auto_axes(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax supports them (>= 0.5); on older versions Auto is already the default
    and the kwarg/enum do not exist, so plain ``make_mesh`` is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return mesh_with_auto_axes(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = len(jax.devices())
    data = n // model
    return mesh_with_auto_axes((data, model), ("data", "model"))
