"""Activation-sharding context + the ``shard_map`` version shim.

Model code is mesh-agnostic; the launch layer wraps step functions in
``activation_sharding(mesh)`` so that ``shard_act(x, 'batch', None, ...)``
calls inside the models become ``with_sharding_constraint``s against the
production mesh (and no-ops in single-device tests).

Dim tags: 'batch' -> the ('pod','data') super-axis; 'model' -> the tensor
axis; None -> unsharded.  A tag is dropped automatically when the dim size
is not divisible by the mesh axis size, so the same model code is legal for
every architecture/shape combination.

``shard_map_compat`` is the one place that papers over the jax 0.4/0.5 API
drift for explicitly-SPMD programs (the distributed ANN steps in
``launch.ann_steps`` and the mesh-sharded LTI serving lane in
``serving.steps`` both route through it — see docs/SERVING.md).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# jax >= 0.5 exposes shard_map at the top level and calls the replication
# check ``check_vma``; 0.4.x has it under experimental with ``check_rep``.
if hasattr(jax, "shard_map"):
    shard_map_compat = jax.shard_map
else:                                           # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map_compat(f, *, check_vma=True, **kw):
        return _shard_map_04(f, check_rep=check_vma, **kw)

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh]):
    token = _CTX.set(mesh)
    try:
        yield
    finally:
        _CTX.reset(token)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _expand(tag, ba):
    """'batch' -> the (pod, data) super-axis; tuples may mix tags."""
    if tag is None:
        return None
    if tag == "batch":
        return ba
    if isinstance(tag, str):
        return (tag,)
    out: tuple = ()
    for t in tag:
        e = _expand(t, ba)
        if e:
            out += e
    return out


def shard_act(x: jax.Array, *dims) -> jax.Array:
    """Constrain ``x`` so dim i follows dims[i].

    Tags: 'batch' (the ('pod','data') super-axis), a mesh axis name, a tuple
    of tags, or None.  Tags are dropped per-dim when the size is not
    divisible or the axis is already used — the same model code stays legal
    for every architecture/shape/mesh combination.
    """
    mesh = _CTX.get()
    if mesh is None:
        return x
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = []
    used: set = set()
    for tag, size in zip(dims, x.shape):
        names = _expand(tag, ba)
        if not names:
            spec.append(None)
            continue
        names = tuple(n for n in names if n in mesh.axis_names)
        if (not names or any(n in used for n in names)
                or size % _axsize(mesh, names) != 0):
            spec.append(None)
            continue
        used.update(names)
        spec.append(names if len(names) > 1 else names[0])
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def gathered(w: jax.Array) -> jax.Array:
    """ZeRO-3 weight gather: constrain a stored-sharded weight to fully
    replicated right before use, so GSPMD inserts one all-gather per layer
    (and the transposed reduce-scatter for its gradient) instead of
    all-reducing activation-sized partial products."""
    mesh = _CTX.get()
    if mesh is None:
        return w
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(mesh, P(*([None] * w.ndim))))
