from .sharding import (batch_axes, fsdp_rule, lm_param_shardings,
                       shard_tree, spec_for)  # noqa: F401
