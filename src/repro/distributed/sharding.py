"""Sharding rules for every architecture family.

Baseline scheme (the one every (arch x shape) cell dry-runs with):

  * activations — pure data parallel over ``('pod', 'data')`` (batch axis);
  * weights     — ZeRO-3 / FSDP: every weight sharded over 'model' on its
    input-ish dimension and over 'data' on a secondary dimension *when
    divisible*; GSPMD inserts the per-layer all-gathers.  This compiles for
    every architecture regardless of head counts (it never shards an
    attention-head axis, so H=40 or KV=8 vs a 16-way mesh axis is a
    non-issue) and gives maximal memory headroom;
  * KV caches   — sequence/window axis sharded over 'model';
  * embedding / recsys tables — row-sharded over ('data','model') when
    divisible (tables are the dominant state for recsys archs);
  * optimizer state — mirrors parameter shardings.

The perf hillclimb (EXPERIMENTS.md §Perf) layers Megatron-style TP / EP on
top of this for the three chosen cells.

Divisibility is checked per-dimension: a mesh axis is only assigned when it
divides the dim; otherwise that dim stays unsharded.  This keeps every spec
legal for jax.NamedSharding (which requires even shards).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple:
    """The data-parallel super-axis: ('pod', 'data') when a pod axis exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """Return ``axes`` if the dim is divisible by their product, else None."""
    return axes if dim % _axsize(mesh, axes) == 0 else None


def spec_for(mesh: Mesh, shape: Sequence[int], wants: Sequence[Any]) -> P:
    """Build a PartitionSpec assigning ``wants[i]`` to dim i when divisible.

    Drops an axis entirely if an earlier dim already claimed it.
    """
    used: set = set()
    out = []
    for dim, want in zip(shape, wants):
        ax = _fit(mesh, dim, want)
        if ax is None:
            out.append(None)
            continue
        names = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(n in used for n in names):
            out.append(None)
            continue
        used.update(names)
        out.append(ax)
    out += [None] * (len(shape) - len(out))
    return P(*out)


def fsdp_rule(mesh: Mesh, path: str, shape: Sequence[int]) -> P:
    """Baseline weight sharding by parameter name (see module docstring)."""
    nd = len(shape)
    if "embed" in path and nd == 2:               # [V, D] — rows over data
        return spec_for(mesh, shape, ["data", None])
    if "lm_head" in path:                         # [D, V] — V over model
        # V stays model-sharded so logits are computed V-sharded without
        # gathering the head (the loss reduces over the sharded V axis).
        return spec_for(mesh, shape, [None, "model"])
    if re.search(r"\bitem_emb|'V'|w_lin|\bV\b", path):
        pass  # handled by recsys_param_shardings
    if nd == 1 or "ln" in path or "norm" in path or path.endswith("b']"):
        return P()
    if re.search(r"w[qkv]'\]$", path) and nd == 4:   # [Gn, D, H, dh]
        return spec_for(mesh, shape, [None, "model", None, "data"])
    if path.endswith("wo']") and nd == 4:            # [Gn, H, dh, D]
        return spec_for(mesh, shape, [None, None, "data", "model"])
    if re.search(r"w_(gate|up)'\]$", path):
        if nd == 3:                                  # [Gn, D, F]
            return spec_for(mesh, shape, [None, "model", "data"])
        if nd == 4:                                  # [Gn, E, D, F] (MoE)
            # shard D x F (always divisible) — the E axis may be tiny
            # (mixtral: 8 < 16), so sharding it would cap at 16-way and
            # blow up the f32 optimizer state (47B x 12B / 16 > HBM).
            return spec_for(mesh, shape, [None, None, "model", "data"])
    if path.endswith("w_down']"):
        if nd == 3:                                  # [Gn, F, D]
            return spec_for(mesh, shape, [None, "data", "model"])
        if nd == 4:                                  # [Gn, E, F, D]
            return spec_for(mesh, shape, [None, None, "data", "model"])
    if path.endswith("router']"):                    # [Gn, D, E]
        return spec_for(mesh, shape, [None, "model", None])
    # generic fallback: shard the two largest dims over model/data
    return _generic_spec(mesh, shape)


def _generic_spec(mesh: Mesh, shape: Sequence[int]) -> P:
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    wants: list = [None] * len(shape)
    for i, ax in zip(order, ("model", "data")):
        wants[i] = ax
    return spec_for(mesh, shape, wants)


def lm_param_shardings(mesh: Mesh, abstract) -> Any:
    """NamedShardings for a transformer param pytree (abstract_params)."""

    def one(path, leaf):
        spec = fsdp_rule(mesh, jax.tree_util.keystr(path), leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract)


def table_sharding(mesh: Mesh, shape: Sequence[int]) -> P:
    """Recsys/GNN big-table rule: rows over (data, model) combined."""
    return spec_for(mesh, shape, [("data", "model"), None])


def generic_param_shardings(mesh: Mesh, abstract, table_names=()) -> Any:
    """GNN/recsys params: named big tables row-sharded, rest generic FSDP."""

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        if any(t in p for t in table_names):
            spec = table_sharding(mesh, leaf.shape)
        else:
            spec = _generic_spec(mesh, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract)


def shard_tree(mesh: Mesh, tree, spec_fn) -> Any:
    """tree of arrays -> device_put against spec_fn(path, leaf)."""

    def one(path, leaf):
        return jax.device_put(
            leaf, NamedSharding(mesh, spec_fn(path, leaf)))

    return jax.tree_util.tree_map_with_path(one, tree)


# ---------------------------------------------------------------------------
# ANN serving: the mesh-sharded LTI lane (docs/SERVING.md).
#
# The LTI's big per-point arrays (full-precision vectors, adjacency, PQ
# codes, flags) are row-partitioned over a 1-axis data mesh; the beam-search
# state stays replicated and every row access is owner-computed + psum'd
# (serving.steps).  These helpers own the specs + placement so the system
# layer and the serving step agree on the layout by construction.
# ---------------------------------------------------------------------------

def data_mesh(n_shards: int, axis: str = "data") -> Mesh:
    """A 1-axis mesh over the first ``n_shards`` local devices.

    Built directly from ``jax.devices()`` (not ``jax.make_mesh``) so a
    subset mesh — e.g. 2 shards on a 4-fake-device CPU — works on every
    supported jax version.
    """
    import numpy as np
    devs = jax.devices()
    if n_shards > len(devs):
        raise ValueError(
            f"data_mesh: {n_shards} shards requested but only "
            f"{len(devs)} devices present")
    return Mesh(np.asarray(devs[:n_shards]), (axis,))


def replica_mesh(n_replicas: int, n_shards: int = 1,
                 axes: tuple = ("replica", "data")) -> Mesh:
    """The 2-axis serving mesh: a ``[n_replicas, n_shards]`` device grid.

    Rows are data-parallel replicas (each serves whole queries against a
    full copy of the index), columns are the within-replica LTI row shards
    (``shard_lti`` composing inside each replica).  Built directly from
    ``jax.devices()`` like ``data_mesh`` so a subset grid — e.g. 2x2 on a
    4-fake-device CPU — works on every supported jax version.
    """
    import numpy as np
    devs = jax.devices()
    need = n_replicas * n_shards
    if need > len(devs):
        raise ValueError(
            f"replica_mesh: {n_replicas}x{n_shards} devices requested but "
            f"only {len(devs)} present")
    grid = np.asarray(devs[:need]).reshape(n_replicas, n_shards)
    return Mesh(grid, axes)


def replica_groups(mesh: Mesh, axis: str = "data") -> list:
    """Split a 2-axis replica mesh into its per-replica 1-axis data meshes.

    Each row of the grid becomes a standalone ``Mesh`` over that replica's
    devices — exactly what ``serving.steps.make_sharded_unified_step``
    consumes, so the within-replica sharded program needs no changes to
    run on a replica's device group (``serving.replica.ReplicaSet``)."""
    import numpy as np
    return [Mesh(row, (axis,)) for row in np.asarray(mesh.devices)]


def lti_lane_specs(axis: str = "data"):
    """(GraphState spec pytree, codes spec) for the row-sharded LTI lane.

    Per-point arrays shard their leading (slot) axis; the entry point and
    the allocation watermark are replicated scalars.
    """
    from ..core.graph import GraphState
    graph = GraphState(
        vectors=P(axis, None), adjacency=P(axis, None),
        active=P(axis), deleted=P(axis), start=P(), n_total=P())
    return graph, P(axis, None)


def place_lti_lane(mesh: Mesh, graph, codes, axis: str = "data"):
    """``device_put`` an LTI graph + PQ codes row-sharded over ``axis``.

    The graph capacity must divide the axis size (``graph.shard_lti`` pads
    it).  Placement is an optimization, not a requirement — the serving
    step's ``shard_map`` would reshard unplaced inputs on every call; this
    pins each row block to its owner once, when the lane bundle is built.
    """
    gspecs, cspec = lti_lane_specs(axis)
    placed = type(graph)(*[
        jax.device_put(x, NamedSharding(mesh, s))
        for x, s in zip(graph, gspecs)])
    return placed, jax.device_put(codes, NamedSharding(mesh, cspec))


def cache_shardings(mesh: Mesh, abstract_caches, batch: int) -> Any:
    """KV caches: [Gn, B, W, KV, dh] — B over batch axes, W over 'model'."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        if p.endswith("pos']"):
            return NamedSharding(mesh, P())
        spec = spec_for(mesh, leaf.shape, [None, ba, "model", None, None])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_caches)
