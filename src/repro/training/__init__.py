from .steps import make_lm_train_step, make_train_step  # noqa: F401
