"""Fault-tolerant training loop.

Composes: sharded train_step + deterministic data stream (resume = step
counter) + AsyncCheckpointer + crash recovery (auto-restore latest
checkpoint, elastic re-sharding against the current mesh).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..checkpoint.store import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from ..distributed.sharding import batch_axes


def run_training(
    mesh: Mesh,
    train_step: Callable,            # jitted (params, opt, batch) -> ...
    params: Any,
    opt_state: Any,
    data_stream_fn: Callable[[int], Iterator[dict]],  # start_step -> iter
    *,
    n_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 100,
    param_shardings: Any = None,
    opt_shardings: Any = None,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
) -> tuple[Any, Any, list]:
    """Returns (params, opt_state, metrics_log)."""
    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        shardings = None
        if param_shardings is not None and opt_shardings is not None:
            shardings = {"params": param_shardings, "opt": opt_shardings}
        tree, start = restore_checkpoint(ckpt_dir, shardings=shardings)
        params, opt_state = tree["params"], tree["opt"]
        log_fn(f"[loop] restored checkpoint at step {start} "
               f"onto {len(mesh.devices.flat)} devices")

    ba = batch_axes(mesh)
    batch_sharding = NamedSharding(mesh, P(ba))
    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    stream = data_stream_fn(start)
    log = []
    t0 = time.perf_counter()
    for step in range(start, n_steps):
        host_batch = next(stream)
        batch = {
            k: jax.device_put(v, NamedSharding(
                mesh, P(*( (ba,) + (None,) * (v.ndim - 1) ))))
            for k, v in host_batch.items()
        }
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if (step + 1) % log_every == 0 or step + 1 == n_steps:
            m = {k: float(v) for k, v in metrics.items()}
            dt = (time.perf_counter() - t0) / log_every
            t0 = time.perf_counter()
            log.append({"step": step + 1, **m, "sec_per_step": dt})
            log_fn(f"[loop] step {step + 1} "
                   + " ".join(f"{k}={v:.4f}" for k, v in m.items())
                   + f" ({dt:.3f}s/step)")
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(n_steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    del batch_sharding
    return params, opt_state, log
