"""Train-step factories: value_and_grad + AdamW + optional microbatch grad
accumulation, built as pure functions ready for ``jax.jit`` with explicit
in/out shardings (the launch layer supplies those).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWState, adamw_update


def make_train_step(loss_fn: Callable, *, lr: float = 3e-4,
                    weight_decay: float = 0.1, grad_clip: float = 1.0,
                    accum_steps: int = 1) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics dict).

    Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With ``accum_steps > 1`` the batch's leading axis is split
    into microbatches scanned sequentially (grad accumulation) — activation
    memory drops by the factor, FLOPs unchanged.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state: AdamWState, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def scan_body(g_acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return g_acc, (loss, metrics)

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(scan_body, g0, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)
        new_params, new_opt = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            grad_clip=grad_clip)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return step


def make_lm_train_step(cfg, **kw) -> Callable:
    from ..models.transformer import lm_loss

    def loss_fn(params, batch):
        return lm_loss(params, batch["tokens"], batch["targets"], cfg)

    return make_train_step(loss_fn, **kw)
