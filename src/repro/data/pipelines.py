"""Deterministic synthetic data pipelines.

Every stream is a pure function of (seed, step) so training can resume from
a checkpoint by step counter alone — the fault-tolerance contract: no data
state needs checkpointing beyond the integer step.

Streams yield numpy arrays (host) — the training loop shards them onto the
mesh with ``jax.device_put`` + NamedSharding.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def lm_token_stream(batch: int, seq_len: int, vocab: int, seed: int = 0,
                    start_step: int = 0) -> Iterator[dict]:
    """Zipf-ish token stream with local correlations (next-token learnable:
    target = (token * 31 + position) % vocab mixed with noise)."""
    step = start_step
    while True:
        r = _rng(seed, step)
        base = r.zipf(1.3, size=(batch, seq_len)).astype(np.int64)
        tokens = (base % (vocab - 2)) + 1
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = (tokens[:, -1] * 31 + 7) % (vocab - 2) + 1
        yield {"tokens": tokens.astype(np.int32),
               "targets": targets.astype(np.int32)}
        step += 1


def click_stream(batch: int, n_sparse: int, rows_per_field: int,
                 seed: int = 0, start_step: int = 0) -> Iterator[dict]:
    """Criteo-like categorical click stream with a planted logistic signal."""
    step = start_step
    w = _rng(seed, 0).standard_normal(n_sparse)
    while True:
        r = _rng(seed, step)
        ids = r.integers(0, rows_per_field, (batch, n_sparse))
        logit = ((ids % 7 - 3) * w).sum(axis=1) / np.sqrt(n_sparse)
        y = (r.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.int32)
        offset = np.arange(n_sparse) * rows_per_field
        yield {"ids": (ids + offset).astype(np.int32), "labels": y}
        step += 1


def vector_stream(batch: int, dim: int, n_clusters: int = 64, seed: int = 0,
                  start_step: int = 0) -> Iterator[np.ndarray]:
    """Gaussian-mixture vectors — the ANN index update/query stream."""
    centers = _rng(seed, 0).standard_normal((n_clusters, dim)) * 3.0
    step = start_step
    while True:
        r = _rng(seed, step)
        which = r.integers(0, n_clusters, batch)
        yield (centers[which]
               + r.standard_normal((batch, dim))).astype(np.float32)
        step += 1


def synthetic_graph(n_nodes: int, avg_degree: int, d_feat: int,
                    n_classes: int, seed: int = 0):
    """Power-law-ish random graph in CSR + homophilous features/labels."""
    r = _rng(seed, 0)
    n_edges = n_nodes * avg_degree
    src = r.integers(0, n_nodes, n_edges)
    dst = (src + r.zipf(1.5, n_edges)) % n_nodes   # locality-biased targets
    labels = r.integers(0, n_classes, n_nodes)
    feats = r.standard_normal((n_nodes, d_feat)).astype(np.float32)
    feats[:, 0] += labels                          # learnable signal
    order = np.argsort(dst, kind="stable")
    src_sorted = src[order].astype(np.int32)
    offsets = np.zeros(n_nodes + 1, np.int64)
    np.add.at(offsets, dst + 1, 1)
    offsets = np.cumsum(offsets)
    return {
        "feats": feats, "labels": labels.astype(np.int32),
        "src": src.astype(np.int32), "dst": dst.astype(np.int32),
        "offsets": offsets.astype(np.int32), "nbrs": src_sorted,
    }


def sasrec_stream(batch: int, seq_len: int, n_items: int, seed: int = 0,
                  start_step: int = 0) -> Iterator[dict]:
    """Markov-chain item sequences (learnable transitions) + BPR negatives."""
    step = start_step
    while True:
        r = _rng(seed, step)
        seq = np.zeros((batch, seq_len + 1), np.int64)
        seq[:, 0] = r.integers(1, n_items, batch)
        for t in range(seq_len):
            nxt = (seq[:, t] * 17 + 3) % (n_items - 1) + 1
            noise = r.integers(1, n_items, batch)
            take_noise = r.random(batch) < 0.3
            seq[:, t + 1] = np.where(take_noise, noise, nxt)
        neg = r.integers(1, n_items, (batch, seq_len))
        yield {"seq": seq[:, :-1].astype(np.int32),
               "pos": seq[:, 1:].astype(np.int32),
               "neg": neg.astype(np.int32)}
        step += 1
