"""Deterministic synthetic data pipelines (sharded batch iterators)."""
from .pipelines import (lm_token_stream, click_stream, vector_stream,
                        synthetic_graph, sasrec_stream)  # noqa: F401
