"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global (window 1024), 128k context, qk-norm,
d_head=256.  [hf:google/gemma-3-1b-pt; unverified]"""
from ..models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_head=256, d_ff=15360, vocab=262144, qk_norm=True, qkv_bias=False,
    rope_theta=1_000_000.0, window=1024,
    pattern=("l", "l", "l", "l", "l", "g"), q_chunk=256,
    kv_chunk=256, dtype="bfloat16")

SMOKE = TransformerConfig(
    name="gemma3-12b-smoke", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, qk_norm=True, window=8,
    pattern=("l", "l", "l", "l", "l", "g"), q_chunk=16, kv_chunk=16,
    dtype="float32")

ARCH = ArchSpec("gemma3-12b", "lm", FULL, SMOKE, lm_cells(FULL))
