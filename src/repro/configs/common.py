"""Config protocol shared by every architecture module.

Each ``configs/<arch>.py`` exposes ``ARCH: ArchSpec`` with:
  * ``full_config``  — the exact published configuration;
  * ``smoke_config`` — a reduced same-family config for CPU smoke tests;
  * ``cells``        — the assigned input shapes as `Cell`s, each carrying
    ``input_specs()`` (ShapeDtypeStruct stand-ins, no allocation) and a step
    kind the launch layer dispatches on.

A ``Cell.skip`` reason marks assigned-but-inapplicable combinations
(documented in DESIGN.md §Arch-applicability); they still appear in the
dry-run report as SKIP rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class Cell:
    shape: str                 # e.g. "train_4k"
    kind: str                  # train|prefill|decode|serve|retrieval|
    #                            train_full|train_sampled|train_batched
    specs: Callable[[], Dict[str, Any]]   # input name -> ShapeDtypeStruct
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    skip: str = ""             # non-empty => documented skip


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                # lm | gnn | recsys
    full_config: Any
    smoke_config: Any
    cells: Sequence[Cell]

    def cell(self, shape: str) -> Cell:
        for c in self.cells:
            if c.shape == shape:
                return c
        raise KeyError(f"{self.name}: no shape {shape}")


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def lm_cells(cfg) -> list[Cell]:
    """The four LM shapes.  long_500k is skipped for pure full-attention
    configs (every pattern position global and no window)."""
    full_attention = all(k == "g" for k in cfg.pattern)

    def train_specs():
        return {"tokens": S((256, 4096), jnp.int32),
                "targets": S((256, 4096), jnp.int32)}

    def prefill_specs():
        return {"tokens": S((32, 32768), jnp.int32)}

    def decode_specs(batch, seq):
        from ..models.transformer import abstract_cache
        return {"caches": abstract_cache(cfg, batch, seq),
                "tokens": S((batch,), jnp.int32),
                "pos": S((), jnp.int32)}

    return [
        Cell("train_4k", "train", train_specs,
             {"batch": 256, "seq": 4096}),
        Cell("prefill_32k", "prefill", prefill_specs,
             {"batch": 32, "seq": 32768}),
        Cell("decode_32k", "decode",
             lambda: decode_specs(128, 32768),
             {"batch": 128, "seq": 32768}),
        Cell("long_500k", "decode",
             lambda: decode_specs(1, 524288),
             {"batch": 1, "seq": 524288},
             skip=("pure full-attention arch: 500k decode needs "
                   "sub-quadratic attention (DESIGN.md §Arch-applicability)"
                   if full_attention else "")),
    ]


# ---------------------------------------------------------------------------
# GNN cells (graphsage)
# ---------------------------------------------------------------------------

def gnn_cells(cfg) -> list[Cell]:
    def full(n, e, f):
        return lambda: {
            "feats": S((n, f), jnp.float32),
            "src": S((e,), jnp.int32), "dst": S((e,), jnp.int32),
            "labels": S((n,), jnp.int32), "mask": S((n,), jnp.bool_),
        }

    def sampled(n, e, b):
        return lambda: {
            "feats": S((n, 602), jnp.float32),
            "offsets": S((n + 1,), jnp.int32),
            "nbrs": S((e,), jnp.int32),
            "seeds": S((b,), jnp.int32),
            "labels": S((b,), jnp.int32),
            "key": S((2,), jnp.uint32),
        }

    def molecule(g, n, e, f):
        return lambda: {
            "feats": S((g, n, f), jnp.float32),
            "src": S((g, e), jnp.int32), "dst": S((g, e), jnp.int32),
            "edge_mask": S((g, e), jnp.bool_),
            "labels": S((g,), jnp.int32),
        }

    return [
        Cell("full_graph_sm", "train_full", full(2708, 10556, 1433),
             {"d_feat": 1433, "n_classes": 7}),
        Cell("minibatch_lg", "train_sampled",
             sampled(232965, 114615892, 1024),
             {"d_feat": 602, "n_classes": 41, "fanout": (15, 10)}),
        Cell("ogb_products", "train_full", full(2449029, 61859140, 100),
             {"d_feat": 100, "n_classes": 47}),
        Cell("molecule", "train_batched", molecule(128, 30, 64, 32),
             {"d_feat": 32, "n_classes": 2}),
    ]


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------

def recsys_cells(cfg) -> list[Cell]:
    sasrec = cfg.kind == "sasrec"

    def ids(b):
        if sasrec:
            return {"seq": S((b, cfg.seq_len), jnp.int32)}
        return {"ids": S((b, cfg.n_sparse), jnp.int32)}

    def train(b):
        if sasrec:
            return lambda: {
                "seq": S((b, cfg.seq_len), jnp.int32),
                "pos": S((b, cfg.seq_len), jnp.int32),
                "neg": S((b, cfg.seq_len), jnp.int32)}
        return lambda: {**ids(b), "labels": S((b,), jnp.int32)}

    def retrieval():
        d = cfg.embed_dim
        return {**ids(1),
                "item_table": S((1_048_576, d), jnp.float32)}

    return [
        Cell("train_batch", "train", train(65536), {"batch": 65536}),
        Cell("serve_p99", "serve", lambda: ids(512), {"batch": 512}),
        Cell("serve_bulk", "serve", lambda: ids(262144), {"batch": 262144}),
        Cell("retrieval_cand", "retrieval", retrieval,
             {"batch": 1, "n_candidates": 1_048_576}),
    ]
