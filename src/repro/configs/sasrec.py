"""sasrec [recsys] — embed_dim=50 n_blocks=2 n_heads=1 seq_len=50,
self-attentive sequential recommendation.  [arXiv:1808.09781; paper]

The showcase FreshDiskANN integration: the encoder's final hidden state is
the retrieval query against the (streaming) item-embedding index — see
examples/sasrec_retrieval.py.
"""
from ..models.recsys import RecsysConfig
from .common import ArchSpec, recsys_cells

FULL = RecsysConfig(
    name="sasrec", kind="sasrec", embed_dim=50, n_items=1_048_576,
    seq_len=50, n_blocks=2, n_heads=1)

SMOKE = RecsysConfig(
    name="sasrec-smoke", kind="sasrec", embed_dim=16, n_items=512,
    seq_len=12, n_blocks=2, n_heads=1)

ARCH = ArchSpec("sasrec", "recsys", FULL, SMOKE, recsys_cells(FULL))
