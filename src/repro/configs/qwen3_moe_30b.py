"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) moe_d_ff=768
vocab=151936, MoE 128 experts top-8, qk-norm.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from ..models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_head=128, d_ff=0, vocab=151936, qk_norm=True,
    qkv_bias=False, rope_theta=1_000_000.0, pattern=("g",),
    moe_experts=128, moe_top_k=8, moe_d_ff=768, moe_groups=16,
    q_chunk=256, kv_chunk=256, dtype="bfloat16")

SMOKE = TransformerConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=0, vocab=512, qk_norm=True, pattern=("g",),
    moe_experts=8, moe_top_k=2, moe_d_ff=64, moe_groups=4, moe_cf=4.0,
    q_chunk=16, kv_chunk=16, dtype="float32")

ARCH = ArchSpec("qwen3-moe-30b-a3b", "lm", FULL, SMOKE, lm_cells(FULL))
