"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, GQA, QKV bias.  [arXiv:2407.10671; hf]"""
from ..models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_head=128, d_ff=8960, vocab=151936, qk_norm=False, qkv_bias=True,
    rope_theta=1_000_000.0, pattern=("g",), q_chunk=256, kv_chunk=256,
    dtype="bfloat16")

SMOKE = TransformerConfig(
    name="qwen2-1.5b-smoke", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_head=12, d_ff=96, vocab=512, qk_norm=False, qkv_bias=True,
    pattern=("g",), q_chunk=16, kv_chunk=16, dtype="float32")

ARCH = ArchSpec("qwen2-1.5b", "lm", FULL, SMOKE, lm_cells(FULL))
