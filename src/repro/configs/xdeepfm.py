"""xdeepfm [recsys] — n_sparse=39 embed_dim=10 cin_layers=200-200-200
mlp=400-400, CIN interaction.  [arXiv:1803.05170; paper]"""
from ..models.recsys import RecsysConfig
from .common import ArchSpec, recsys_cells

FULL = RecsysConfig(
    name="xdeepfm", kind="xdeepfm", n_sparse=39, rows_per_field=1_048_576,
    embed_dim=10, mlp=(400, 400), cin_layers=(200, 200, 200))

SMOKE = RecsysConfig(
    name="xdeepfm-smoke", kind="xdeepfm", n_sparse=5, rows_per_field=128,
    embed_dim=10, mlp=(32, 32), cin_layers=(8, 8, 8))

ARCH = ArchSpec("xdeepfm", "recsys", FULL, SMOKE, recsys_cells(FULL))
