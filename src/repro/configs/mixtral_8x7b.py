"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""
from ..models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_head=128, d_ff=0, vocab=32000, qk_norm=False,
    qkv_bias=False, rope_theta=1_000_000.0, window=4096, pattern=("l",),
    moe_experts=8, moe_top_k=2, moe_d_ff=14336, moe_groups=16,
    q_chunk=256, kv_chunk=256, dtype="bfloat16")

SMOKE = TransformerConfig(
    name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=0, vocab=512, window=16, pattern=("l",),
    moe_experts=4, moe_top_k=2, moe_d_ff=96, moe_groups=4, moe_cf=4.0,
    q_chunk=16, kv_chunk=16, dtype="float32")

ARCH = ArchSpec("mixtral-8x7b", "lm", FULL, SMOKE, lm_cells(FULL))
