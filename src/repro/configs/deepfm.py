"""deepfm [recsys] — n_sparse=39 embed_dim=10 mlp=400-400-400,
FM interaction + shared-embedding DNN.  [arXiv:1703.04247; paper]"""
from ..models.recsys import RecsysConfig
from .common import ArchSpec, recsys_cells

FULL = RecsysConfig(
    name="deepfm", kind="deepfm", n_sparse=39, rows_per_field=1_048_576,
    embed_dim=10, mlp=(400, 400, 400))

SMOKE = RecsysConfig(
    name="deepfm-smoke", kind="deepfm", n_sparse=5, rows_per_field=128,
    embed_dim=10, mlp=(32, 32, 32))

ARCH = ArchSpec("deepfm", "recsys", FULL, SMOKE, recsys_cells(FULL))
