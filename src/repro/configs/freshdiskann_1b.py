"""freshdiskann-1b — the paper's own billion-point operating point (§6.2),
pod-scaled: 2M points/chip x 512 chips ~ 1.05B points, R=64, L=75/100,
alpha=1.2, PQ-32.  The LTI is sharded over ('pod','model') as independent
sub-indices (the paper's own trillion-point distribution design, §1);
queries broadcast, results top-k-merged.

This config drives the ANN dry-run cells (search / insert / merge phases)
in launch/dryrun.py.
"""
import dataclasses

import jax.numpy as jnp

from ..core.config import IndexConfig, PQConfig, SystemConfig
from .common import ArchSpec, Cell, S


@dataclasses.dataclass(frozen=True)
class AnnDeployment:
    name: str
    points_per_shard: int
    dim: int
    index: IndexConfig
    pq: PQConfig
    query_batch: int
    insert_batch: int
    k: int = 5


FULL = AnnDeployment(
    name="freshdiskann-1b",
    points_per_shard=2_097_152,          # x512 chips = 1.07B points
    dim=128,
    # beam_width=4: each search round issues 4 concurrent adjacency reads
    # (§6.2 beamwidth) — ~4x fewer IO rounds per query at equal recall.
    index=IndexConfig(capacity=2_097_152, dim=128, R=64, L_build=75,
                      L_search=100, alpha=1.2, beam_width=4),
    pq=PQConfig(dim=128, m=32, ksub=256),
    query_batch=1024,                    # global concurrent queries
    insert_batch=4096,                   # staged inserts per merge chunk
)

SMOKE = AnnDeployment(
    name="freshdiskann-smoke",
    points_per_shard=1024,
    dim=32,
    index=IndexConfig(capacity=1024, dim=32, R=16, L_build=24, L_search=32,
                      alpha=1.2, max_visits=48),
    pq=PQConfig(dim=32, m=8, ksub=32, kmeans_iters=4),
    query_batch=8,
    insert_batch=32,
)


def _search_specs():
    c = FULL
    return {"queries": S((c.query_batch, c.dim), jnp.float32)}


def _insert_specs():
    c = FULL
    return {"new_vecs": S((c.insert_batch, c.dim), jnp.float32)}


def _merge_specs():
    c = FULL
    return {
        "new_vecs": S((c.insert_batch, c.dim), jnp.float32),
        "new_valid": S((c.insert_batch,), jnp.bool_),
        "delete_mask": S((c.index.capacity,), jnp.bool_),
    }


ARCH = ArchSpec(
    "freshdiskann-1b", "ann", FULL, SMOKE,
    [
        Cell("search_1b", "ann_search", _search_specs,
             {"points": FULL.points_per_shard}),
        Cell("insert_1b", "ann_insert", _insert_specs, {}),
        Cell("merge_1b", "ann_merge", _merge_specs, {}),
    ])
