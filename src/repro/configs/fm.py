"""fm [recsys] — n_sparse=39 embed_dim=10, pairwise FM interaction via the
O(nk) sum-square trick.  [ICDM'10 (Rendle); paper]"""
from ..models.recsys import RecsysConfig
from .common import ArchSpec, recsys_cells

FULL = RecsysConfig(
    name="fm", kind="fm", n_sparse=39, rows_per_field=1_048_576,
    embed_dim=10)

SMOKE = RecsysConfig(
    name="fm-smoke", kind="fm", n_sparse=5, rows_per_field=128,
    embed_dim=10)

ARCH = ArchSpec("fm", "recsys", FULL, SMOKE, recsys_cells(FULL))
