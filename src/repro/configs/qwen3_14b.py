"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from ..models.transformer import TransformerConfig
from .common import ArchSpec, lm_cells

FULL = TransformerConfig(
    name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_head=128, d_ff=17408, vocab=151936, qk_norm=True, qkv_bias=False,
    rope_theta=1_000_000.0, pattern=("g",), q_chunk=256, kv_chunk=256,
    dtype="bfloat16")

SMOKE = TransformerConfig(
    name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_head=16, d_ff=128, vocab=512, qk_norm=True, qkv_bias=False,
    pattern=("g",), q_chunk=16, kv_chunk=16, dtype="float32")

ARCH = ArchSpec("qwen3-14b", "lm", FULL, SMOKE, lm_cells(FULL))
