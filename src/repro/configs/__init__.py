"""Architecture registry: ``get_arch(name)`` / ``list_archs()``.

The 10 assigned architectures plus the paper's own billion-point deployment
config (``freshdiskann-1b``).
"""
from __future__ import annotations

import importlib

_MODULES = {
    "qwen3-14b": "qwen3_14b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma3-12b": "gemma3_12b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "graphsage-reddit": "graphsage_reddit",
    "fm": "fm",
    "xdeepfm": "xdeepfm",
    "sasrec": "sasrec",
    "deepfm": "deepfm",
    "freshdiskann-1b": "freshdiskann_1b",
}

ASSIGNED = [k for k in _MODULES if k != "freshdiskann-1b"]


def get_arch(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.ARCH


def list_archs() -> list[str]:
    return list(_MODULES)
