"""graphsage-reddit [gnn] — 2 layers, d_hidden=128, mean aggregator,
sample sizes 25-10.  [arXiv:1706.02216; paper]

The four shapes span three datasets (cora-scale / reddit / ogbn-products /
batched molecules); d_feat and n_classes are per-shape (Cell.meta) and the
launch layer specializes the config per cell.
"""
from ..models.gnn import SageConfig
from .common import ArchSpec, gnn_cells

FULL = SageConfig(
    name="graphsage-reddit", d_feat=602, d_hidden=128, n_layers=2,
    n_classes=41, fanout=(25, 10), aggregator="mean")

SMOKE = SageConfig(
    name="graphsage-smoke", d_feat=16, d_hidden=32, n_layers=2,
    n_classes=7, fanout=(5, 3), aggregator="mean")

ARCH = ArchSpec("graphsage-reddit", "gnn", FULL, SMOKE, gnn_cells(FULL))
