"""Graph state for FreshVamana indices.

The index is a fixed-capacity structure of dense arrays (TPU-friendly):
  vectors   f32[capacity, dim]   point coordinates ("full precision data")
  adjacency i32[capacity, R]     out-neighbors, INVALID (-1) padded
  active    bool[capacity]       slot holds a live point
  deleted   bool[capacity]       lazy-delete list membership (paper DeleteList)
  start     i32                  entry point (medoid)

Slots are allocated densely from 0; the system layer maps external ids to
slots.  ``deleted`` nodes remain navigable (paper §4.2 lazy deletion) until
``consolidate_deletes`` runs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import IndexConfig
from .distance import INVALID, l2_sq_batch


class GraphState(NamedTuple):
    vectors: jax.Array     # [capacity, dim]
    adjacency: jax.Array   # [capacity, R] int32
    active: jax.Array      # [capacity] bool
    deleted: jax.Array     # [capacity] bool
    start: jax.Array       # scalar int32
    n_total: jax.Array     # scalar int32: allocated slots (active or deleted)

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def R(self) -> int:
        return self.adjacency.shape[1]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


def empty_graph(cfg: IndexConfig) -> GraphState:
    return GraphState(
        vectors=jnp.zeros((cfg.capacity, cfg.dim), jnp.dtype(cfg.dtype)),
        adjacency=jnp.full((cfg.capacity, cfg.R), INVALID, jnp.int32),
        active=jnp.zeros((cfg.capacity,), bool),
        deleted=jnp.zeros((cfg.capacity,), bool),
        start=jnp.int32(0),
        n_total=jnp.int32(0),
    )


def pad_graph(state: GraphState, capacity: int) -> GraphState:
    """Grow a graph to ``capacity`` slots (new slots inert: inactive,
    INVALID-adjacent, zero vectors).  Searches over the padded graph are
    bit-identical to the original — padding slots are never navigable."""
    if state.capacity == capacity:
        return state
    if state.capacity > capacity:
        raise ValueError(f"cannot shrink graph {state.capacity} -> {capacity}")
    extra = capacity - state.capacity
    return state._replace(
        vectors=jnp.concatenate(
            [state.vectors,
             jnp.zeros((extra, state.dim), state.vectors.dtype)]),
        adjacency=jnp.concatenate(
            [state.adjacency, jnp.full((extra, state.R), INVALID, jnp.int32)]),
        active=jnp.concatenate([state.active, jnp.zeros((extra,), bool)]),
        deleted=jnp.concatenate([state.deleted, jnp.zeros((extra,), bool)]),
    )


def stack_graphs(states: list[GraphState]) -> GraphState:
    """Stack graphs on a new leading tier axis, padding each to the largest
    capacity.  The result is a GraphState pytree with [T, ...] leaves, ready
    for a vmapped multi-tier search (``index.search_tiers``)."""
    cap = max(s.capacity for s in states)
    padded = [pad_graph(s, cap) for s in states]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *padded)


class LaneStack(NamedTuple):
    """A heterogeneous-lane stack: the §5.2 query fan-out as ONE pytree.

    ``graphs`` holds every tier's graph padded to a common capacity with
    [T, ...] leaves (exactly ``stack_graphs``); ``is_pq`` selects, per lane,
    which distance backend the vmapped search uses — exact L2 over the lane's
    full-precision vectors for TempIndex lanes, PQ asymmetric distances (ADC)
    for the LTI lane.  ``codes``/``codebook`` are *shared* across lanes
    rather than stacked: only the PQ lane gathers meaningful rows from them,
    and the full-precision lanes' (discarded) ADC results never feed a
    ``where``-selected output, so one copy suffices and the stack stays
    O(sum of graph bytes) instead of O(T x LTI codes).

    Built by ``stack_lanes``; consumed by ``index.search_lanes`` /
    ``index.unified_search``.  See docs/ARCHITECTURE.md for the full
    query-engine picture.
    """

    graphs: GraphState     # [T, ...] leaves (stacked + padded)
    codes: jax.Array       # [capacity, m] uint8 — PQ codes (PQ lane only)
    codebook: jax.Array    # [m, ksub, dsub] f32 centroids (PQ lane only)
    is_pq: jax.Array       # [T] bool — lane backend select

    @property
    def n_lanes(self) -> int:
        return self.is_pq.shape[0]


def stack_lanes(states: list[GraphState], *,
                codes: Optional[jax.Array] = None,
                codebook: Optional[jax.Array] = None,
                pq_lane: Optional[int] = None) -> LaneStack:
    """Stack full-precision tier graphs and (optionally) one PQ-navigated
    lane into a ``LaneStack``.

    ``states[pq_lane]`` is the LTI's graph; ``codes`` ([lti_capacity, m]
    uint8) and ``codebook`` ([m, ksub, dsub] f32 centroids) are its PQ data,
    row-padded with zeros up to the common stacked capacity.  With
    ``pq_lane=None`` every lane is full-precision and tiny zero placeholders
    keep the pytree structure (and jit cache keys) stable.
    """
    stacked = stack_graphs(states)
    cap = stacked.vectors.shape[1]
    T = len(states)
    is_pq = jnp.zeros((T,), bool)
    if pq_lane is None:
        codes = jnp.zeros((cap, 1), jnp.uint8)
        codebook = jnp.zeros((1, 1, states[0].dim), jnp.float32)
    else:
        if codes is None or codebook is None:
            raise ValueError("pq_lane set but codes/codebook missing")
        is_pq = is_pq.at[pq_lane].set(True)
        pad = cap - codes.shape[0]
        if pad < 0:
            raise ValueError(
                f"PQ codes cover {codes.shape[0]} slots but the stacked "
                f"capacity is only {cap}")
        if pad:
            codes = jnp.concatenate(
                [codes, jnp.zeros((pad, codes.shape[1]), codes.dtype)])
        codebook = codebook.astype(jnp.float32)
    return LaneStack(stacked, codes, codebook, is_pq)


def medoid(vectors: jax.Array, mask: jax.Array, sample: int = 4096) -> jax.Array:
    """Index of the (sampled) medoid among ``mask``-active rows.

    The medoid is the paper's navigating/start node.  For large N we estimate
    against the masked mean (one pass) — identical to DiskANN's centroid-nearest
    entry point.
    """
    m = mask.astype(jnp.float32)
    mean = jnp.sum(vectors * m[:, None], axis=0) / jnp.maximum(jnp.sum(m), 1.0)
    d = l2_sq_batch(mean[None, :], vectors)[0]
    d = jnp.where(mask, d, jnp.inf)
    return jnp.argmin(d).astype(jnp.int32)


def degree_stats(state: GraphState) -> dict:
    """Average/max out-degree over active nodes (used by the alpha ablation)."""
    valid = (state.adjacency >= 0).sum(axis=1)
    act = state.active & ~state.deleted
    n = jnp.maximum(act.sum(), 1)
    return {
        "avg_degree": jnp.where(act, valid, 0).sum() / n,
        "max_degree": jnp.where(act, valid, 0).max(),
        "n_active": act.sum(),
    }
