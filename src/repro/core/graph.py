"""Graph state for FreshVamana indices.

The index is a fixed-capacity structure of dense arrays (TPU-friendly):
  vectors   f32[capacity, dim]   point coordinates ("full precision data")
  adjacency i32[capacity, R]     out-neighbors, INVALID (-1) padded
  active    bool[capacity]       slot holds a live point
  deleted   bool[capacity]       lazy-delete list membership (paper DeleteList)
  start     i32                  entry point (medoid)

Slots are allocated densely from 0; the system layer maps external ids to
slots.  ``deleted`` nodes remain navigable (paper §4.2 lazy deletion) until
``consolidate_deletes`` runs.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import IndexConfig
from .distance import INVALID, l2_sq_batch


class GraphState(NamedTuple):
    vectors: jax.Array     # [capacity, dim]
    adjacency: jax.Array   # [capacity, R] int32
    active: jax.Array      # [capacity] bool
    deleted: jax.Array     # [capacity] bool
    start: jax.Array       # scalar int32
    n_total: jax.Array     # scalar int32: allocated slots (active or deleted)

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def R(self) -> int:
        return self.adjacency.shape[1]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


def empty_graph(cfg: IndexConfig) -> GraphState:
    return GraphState(
        vectors=jnp.zeros((cfg.capacity, cfg.dim), jnp.dtype(cfg.dtype)),
        adjacency=jnp.full((cfg.capacity, cfg.R), INVALID, jnp.int32),
        active=jnp.zeros((cfg.capacity,), bool),
        deleted=jnp.zeros((cfg.capacity,), bool),
        start=jnp.int32(0),
        n_total=jnp.int32(0),
    )


def pad_graph(state: GraphState, capacity: int) -> GraphState:
    """Grow a graph to ``capacity`` slots (new slots inert: inactive,
    INVALID-adjacent, zero vectors).  Searches over the padded graph are
    bit-identical to the original — padding slots are never navigable."""
    if state.capacity == capacity:
        return state
    if state.capacity > capacity:
        raise ValueError(f"cannot shrink graph {state.capacity} -> {capacity}")
    extra = capacity - state.capacity
    return state._replace(
        vectors=jnp.concatenate(
            [state.vectors,
             jnp.zeros((extra, state.dim), state.vectors.dtype)]),
        adjacency=jnp.concatenate(
            [state.adjacency, jnp.full((extra, state.R), INVALID, jnp.int32)]),
        active=jnp.concatenate([state.active, jnp.zeros((extra,), bool)]),
        deleted=jnp.concatenate([state.deleted, jnp.zeros((extra,), bool)]),
    )


def stack_graphs(states: list[GraphState]) -> GraphState:
    """Stack graphs on a new leading tier axis, padding each to the largest
    capacity.  The result is a GraphState pytree with [T, ...] leaves, ready
    for a vmapped multi-tier search (``index.search_tiers``)."""
    cap = max(s.capacity for s in states)
    padded = [pad_graph(s, cap) for s in states]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *padded)


class LaneStack(NamedTuple):
    """A heterogeneous-lane stack: the §5.2 query fan-out as ONE pytree.

    Two lane groups, each at its own natural capacity:

    ``temps``  every TempIndex tier (RW + frozen RO snapshots), padded to
               the largest *temp* capacity and stacked into [Tt, ...] leaves
               (exactly ``stack_graphs``) — searched with exact L2 over each
               lane's full-precision vectors, vmapped.
    ``lti``    the LTI's graph at its OWN capacity, plus its PQ
               ``codes``/``codebook`` — searched with asymmetric PQ
               distances (ADC) as a single lane in the same program.

    Keeping the LTI lane un-stacked means the temp group costs
    O(Tt x temp_cap) instead of O(T x LTI_cap): at production scale the LTI
    capacity dwarfs every TempIndex, so padding temp lanes up to it (the
    pre-engine layout) multiplied the dominant term by the tier count.
    Either group may be ``None`` (no live temps / no LTI yet); the pytree
    treedef keys the jit cache, so the structure is stable per tier census.

    Built by ``stack_lanes``; consumed by ``index.search_lanes`` /
    ``index.unified_search``.  See docs/ARCHITECTURE.md for the full
    query-engine picture.
    """

    temps: Optional[GraphState]    # [Tt, ...] leaves (stacked + temp-padded)
    lti: Optional[GraphState]      # LTI graph, own capacity
    codes: Optional[jax.Array]     # [lti_capacity, m] uint8 — PQ codes
    codebook: Optional[jax.Array]  # [m, ksub, dsub] f32 centroids

    @property
    def n_temp_lanes(self) -> int:
        return 0 if self.temps is None else self.temps.active.shape[0]

    @property
    def n_lanes(self) -> int:
        return self.n_temp_lanes + (0 if self.lti is None else 1)


def stack_lanes(temp_states: list[GraphState], *,
                lti: Optional[GraphState] = None,
                codes: Optional[jax.Array] = None,
                codebook: Optional[jax.Array] = None) -> LaneStack:
    """Stack the full-precision temp tiers (padded to the largest TEMP
    capacity only) and attach the optional PQ-navigated LTI lane at its own
    capacity.  ``codes`` ([lti_capacity, m] uint8) and ``codebook``
    ([m, ksub, dsub] f32 centroids) are required with ``lti``."""
    stacked = stack_graphs(temp_states) if temp_states else None
    if lti is not None:
        if codes is None or codebook is None:
            raise ValueError("lti lane set but codes/codebook missing")
        if codes.shape[0] != lti.capacity:
            raise ValueError(
                f"PQ codes cover {codes.shape[0]} slots but the LTI "
                f"capacity is {lti.capacity}")
        codebook = codebook.astype(jnp.float32)
    else:
        codes = codebook = None
    return LaneStack(stacked, lti, codes, codebook)


def shard_lti(graph: GraphState, codes: jax.Array, n_shards: int, *,
              mesh=None, axis: str = "data") -> tuple[GraphState, jax.Array]:
    """Row-partition the LTI graph + its PQ codes over ``n_shards`` devices.

    Pads the capacity up to a multiple of ``n_shards`` (``pad_graph`` —
    padding slots are inert: inactive, INVALID-adjacent, zero codes) so
    every shard owns an equal contiguous block of rows, shard ``s``
    covering slots ``[s*cap/n, (s+1)*cap/n)``.  With ``mesh`` given, the
    arrays are additionally ``device_put`` row-sharded over its ``axis``
    (``distributed.sharding.place_lti_lane``), so each device holds only
    its block; the PQ codebook and the medoid entry point stay replicated
    (they ride in scalar/replicated specs).  The sharded serving lane
    (``serving.steps.make_sharded_unified_step``) consumes this layout;
    results are bit-identical to the unsharded lane for any shard count —
    see docs/SERVING.md.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    cap = -(-graph.capacity // n_shards) * n_shards
    graph = pad_graph(graph, cap)
    if codes.shape[0] < cap:
        codes = jnp.concatenate(
            [codes, jnp.zeros((cap - codes.shape[0], codes.shape[1]),
                              codes.dtype)])
    if mesh is not None:
        from ..distributed.sharding import place_lti_lane
        graph, codes = place_lti_lane(mesh, graph, codes, axis=axis)
    return graph, codes


def write_graph_layout(path: str, state: GraphState, *, codes=None,
                       codebook=None, ext_ids=None, generation: int = 0):
    """Serialize a graph into the decoupled on-disk layout (topology split
    from vectors — ``repro.storage.layout``, guide: docs/STORAGE.md) and
    return it opened.  Lazy import: ``storage`` is an optional consumer of
    the core, not a dependency of it."""
    from ..storage.layout import write_layout
    return write_layout(path, state, codes=codes, codebook=codebook,
                        ext_ids=ext_ids, generation=generation)


def graph_from_layout(path: str) -> GraphState:
    """Materialize a ``GraphState`` back from a decoupled layout (the
    recovery path; serving reads rows in place via ``storage.DiskSource``)."""
    from ..storage.layout import open_layout
    lay = open_layout(path)
    try:
        return lay.graph_state()
    finally:
        lay.close()


# --------------------------------------------------------------------------
# Filtered / multi-tenant search: per-point label bitsets + tenant ids.
#
# Labels live HOST-SIDE (numpy) as side tables parallel to the per-tier
# device arrays — exactly like the system layer's ``ext_ids`` tables.  At
# query time a FilterSpec folds into the cached drop-mask that
# ``index.unified_search`` already applies post-search (``lanes_to_ext``),
# so filtering costs one extra AND per candidate and touches no kernel.
# See docs/ARCHITECTURE.md "Filtered & multi-tenant search".
# --------------------------------------------------------------------------

NO_TENANT = -1


@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """A query-time predicate over per-point labels and/or tenant id.

    ``all_of`` / ``any_of`` are label bit indices; a point matches when it
    carries EVERY ``all_of`` bit and (if ``any_of`` is non-empty) AT LEAST
    ONE ``any_of`` bit.  ``tenant`` restricts matches to points inserted
    under that tenant id (the mandatory-filter multi-tenancy shape).
    Hashable/frozen so it can key the system's filter-mask cache and ride
    scheduler tickets; an empty spec matches everything.
    """
    all_of: tuple[int, ...] = ()
    any_of: tuple[int, ...] = ()
    tenant: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "all_of", tuple(sorted(self.all_of)))
        object.__setattr__(self, "any_of", tuple(sorted(self.any_of)))

    @property
    def is_empty(self) -> bool:
        return not self.all_of and not self.any_of and self.tenant is None


class LabelTable:
    """Packed per-slot label bitsets + tenant ids for ONE tier.

    ``bits``   uint32[capacity, n_words] — bit ``b`` of word ``b // 32``
               set when the point in that slot carries label ``b``.
    ``tenant`` int32[capacity] — owning tenant id, ``NO_TENANT`` (-1) for
               untenanted points.

    Mutated in place by the system layer's flush/merge/consolidate paths
    (always under the same locks as the matching ``ext_ids`` table) and
    read by ``filter_match`` to build query-time drop masks.
    """

    __slots__ = ("bits", "tenant")

    def __init__(self, capacity: int, n_words: int,
                 bits: np.ndarray | None = None,
                 tenant: np.ndarray | None = None):
        self.bits = (np.zeros((capacity, n_words), np.uint32)
                     if bits is None else np.asarray(bits, np.uint32))
        self.tenant = (np.full(capacity, NO_TENANT, np.int32)
                       if tenant is None else np.asarray(tenant, np.int32))

    @property
    def capacity(self) -> int:
        return self.bits.shape[0]

    @property
    def n_words(self) -> int:
        return self.bits.shape[1]

    def copy(self) -> "LabelTable":
        return LabelTable(self.capacity, self.n_words,
                          self.bits.copy(), self.tenant.copy())

    def set_row(self, slot: int, bits_row: np.ndarray, tenant: int):
        self.bits[slot] = bits_row
        self.tenant[slot] = tenant

    def clear_rows(self, mask_or_slots):
        self.bits[mask_or_slots] = 0
        self.tenant[mask_or_slots] = NO_TENANT

    def grow(self, capacity: int) -> "LabelTable":
        if capacity == self.capacity:
            return self
        if capacity < self.capacity:
            raise ValueError(
                f"cannot shrink label table {self.capacity} -> {capacity}")
        out = LabelTable(capacity, self.n_words)
        out.bits[:self.capacity] = self.bits
        out.tenant[:self.capacity] = self.tenant
        return out


def pack_labels(labels, n_words: int) -> np.ndarray:
    """Pack an iterable of label bit indices into a uint32[n_words] row."""
    row = np.zeros(n_words, np.uint32)
    for b in labels or ():
        b = int(b)
        if not 0 <= b < 32 * n_words:
            raise ValueError(
                f"label bit {b} out of range for {n_words} words "
                f"(cfg.filter_words covers bits [0, {32 * n_words}))")
        row[b // 32] |= np.uint32(1 << (b % 32))
    return row


def unpack_labels(row: np.ndarray) -> list[int]:
    """Inverse of ``pack_labels``: the sorted label bit indices set in a
    packed uint32 row (WAL replay turns stored bitsets back into the
    ``insert(labels=...)`` form)."""
    out = []
    for w, word in enumerate(np.asarray(row, np.uint32)):
        word = int(word)
        while word:
            low = word & -word
            out.append(32 * w + low.bit_length() - 1)
            word ^= low
    return out


def filter_match(table: LabelTable, spec: FilterSpec) -> np.ndarray:
    """bool[capacity] — which slots satisfy ``spec``.

    Vectorized over the packed words; an empty spec matches all slots.
    Row validity (active/deleted/ext-id) is NOT consulted here — the
    caller ORs ``~match`` into the delete drop mask, which already covers
    liveness.
    """
    match = np.ones(table.capacity, bool)
    if spec.tenant is not None:
        match &= table.tenant == spec.tenant
    if spec.all_of:
        want = pack_labels(spec.all_of, table.n_words)
        match &= ((table.bits & want) == want).all(axis=1)
    if spec.any_of:
        want = pack_labels(spec.any_of, table.n_words)
        match &= (table.bits & want).any(axis=1)
    return match


def medoid(vectors: jax.Array, mask: jax.Array, sample: int = 4096) -> jax.Array:
    """Index of the (sampled) medoid among ``mask``-active rows.

    The medoid is the paper's navigating/start node.  For large N we estimate
    against the masked mean (one pass) — identical to DiskANN's centroid-nearest
    entry point.
    """
    m = mask.astype(jnp.float32)
    mean = jnp.sum(vectors * m[:, None], axis=0) / jnp.maximum(jnp.sum(m), 1.0)
    d = l2_sq_batch(mean[None, :], vectors)[0]
    d = jnp.where(mask, d, jnp.inf)
    return jnp.argmin(d).astype(jnp.int32)


def degree_stats(state: GraphState) -> dict:
    """Average/max out-degree over active nodes (used by the alpha ablation)."""
    valid = (state.adjacency >= 0).sum(axis=1)
    act = state.active & ~state.deleted
    n = jnp.maximum(act.sum(), 1)
    return {
        "avg_degree": jnp.where(act, valid, 0).sum() / n,
        "max_degree": jnp.where(act, valid, 0).max(),
        "n_active": act.sum(),
    }
