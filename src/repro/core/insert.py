"""Insert (Algorithm 2) — batched, with the paper's Delta back-edge structure.

A batch of B new points is inserted in three fixed-shape stages:

  1. candidate generation: GreedySearch(s, p, 1, L) per new point against the
     *current* graph (vmapped);
  2. RobustPrune over the visited set -> the new point's out-neighbors;
  3. back-edges: the (target j, source p) pairs are the paper's Delta
     structure.  They are grouped by target with a sort + segment-position
     trick, then every affected node either appends (if still under the degree
     budget R) or re-prunes N_out(j) + {p...} — exactly Algorithm 2's branch.

Points inside one batch do not see each other (the paper's concurrent inserts
under fine-grained locking have the same quiescent-consistency window).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distance import INVALID
from .prune import prune_node, robust_prune
from .search import DistanceBackend, SearchResult, beam_search


class InsertEdges(NamedTuple):
    new_adj: jax.Array   # [B, R] out-neighbors for the new points
    pairs_j: jax.Array   # [B*R] back-edge targets (INVALID padded)
    pairs_p: jax.Array   # [B*R] back-edge sources
    search: SearchResult


def compute_insert_edges(
    adjacency: jax.Array,
    navigable: jax.Array,      # bool[N] traversable (active; incl. lazy-deleted)
    usable: jax.Array,         # bool[N] candidate-eligible (active & !deleted)
    start: jax.Array,
    prune_table: jax.Array,    # [N, d] vectors used for prune distances
    new_slots: jax.Array,      # [B] slot ids of the new points (already stored)
    new_vecs: jax.Array,       # [B, d]
    backend: DistanceBackend,
    *,
    L: int,
    max_visits: int,
    alpha: float,
    R: int,
    beam_width: int = 1,
    use_kernel: bool = False,
) -> InsertEdges:
    """Stages 1+2: search & prune.  Graph arrays are pre-insert (new points
    are stored but have no in-edges, so searches cannot reach them)."""
    res = beam_search(adjacency, navigable, start, new_vecs, backend,
                      L=L, max_visits=max_visits, beam_width=beam_width,
                      use_kernel=use_kernel)
    # Candidate pool: V union final list (Alg. 2 uses V; the list adds only
    # closer nodes, strictly improving the pool).
    cand = jnp.concatenate([res.visited, res.ids], axis=1)          # [B, V+L]

    def one(slot, vec, cand_ids):
        safe = jnp.maximum(cand_ids, 0)
        ok = (cand_ids >= 0) & usable[safe] & (cand_ids != slot)
        return robust_prune(vec, cand_ids, prune_table[safe], ok, alpha, R).ids

    new_adj = jax.vmap(one)(new_slots, new_vecs.astype(jnp.float32), cand)
    B = new_slots.shape[0]
    pairs_j = new_adj.reshape(B * R)
    pairs_p = jnp.broadcast_to(new_slots[:, None], (B, R)).reshape(B * R)
    pairs_p = jnp.where(pairs_j >= 0, pairs_p, INVALID)
    return InsertEdges(new_adj, pairs_j, pairs_p, res)


def group_pairs(pairs_j: jax.Array, pairs_p: jax.Array, n_slots: int,
                d_max: int) -> tuple[jax.Array, jax.Array]:
    """Group back-edge pairs by target: Delta buffer [N, d_max] + counts [N].

    Sort by target, compute the position-within-group via searchsorted, then a
    single scatter.  Overflow beyond d_max is dropped (counted by callers via
    the returned counts, capped at d_max on read).
    """
    P = pairs_j.shape[0]
    key = jnp.where(pairs_j >= 0, pairs_j, jnp.int32(n_slots))  # invalid last
    order = jnp.argsort(key)
    sj, sp = key[order], pairs_p[order]
    first = jnp.searchsorted(sj, sj, side="left")
    slot = jnp.arange(P, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = (sj < n_slots) & (slot < d_max)
    buf = jnp.full((n_slots, d_max), INVALID, jnp.int32)
    buf = buf.at[jnp.where(keep, sj, n_slots), jnp.where(keep, slot, 0)].set(
        sp, mode="drop")
    cnt = jnp.zeros((n_slots,), jnp.int32).at[key].add(
        (key < n_slots).astype(jnp.int32), mode="drop")
    return buf, cnt


def apply_back_edges_codes(
    adjacency: jax.Array,
    codes: jax.Array,        # [N, m] PQ codes
    tables: jax.Array,       # [m, ksub, ksub] sdc tables
    usable: jax.Array,
    pairs_j: jax.Array,
    pairs_p: jax.Array,
    *,
    alpha: float,
    R: int,
    d_max: int | None = None,
    chunk: int = 1024,
) -> jax.Array:
    """Patch phase with SDC distances (see apply_back_edges)."""
    from .prune import prune_node_codes

    N = adjacency.shape[0]
    P = pairs_j.shape[0]
    d_max = d_max if d_max is not None else R
    buf, cnt = group_pairs(pairs_j, pairs_p, N, d_max)
    a_max = min(P, N)
    _, affected = jax.lax.top_k((cnt > 0).astype(jnp.int32), a_max)

    def one(adj, j):
        row = adj[j]
        extra = buf[j]
        deg = (row >= 0).sum()
        add = jnp.minimum(cnt[j], d_max)
        combine = jnp.concatenate([row, extra])
        app_order = jnp.argsort(~(combine >= 0))
        appended = combine[app_order][:R]
        pruned = prune_node_codes(codes, tables, j, combine, usable,
                                  alpha, R).ids
        needs_prune = deg + add > R
        new_row = jnp.where(needs_prune, pruned, appended)
        return jnp.where(cnt[j] > 0, new_row, row)

    if a_max <= chunk:
        rows = jax.vmap(lambda j: one(adjacency, j))(affected)
        return adjacency.at[affected].set(rows)
    n_chunks = -(-a_max // chunk)
    pad = n_chunks * chunk - a_max
    aff = jnp.concatenate(
        [affected, jnp.full((pad,), N, jnp.int32)]).reshape(n_chunks, chunk)

    def block(adj, js):
        rows = jax.vmap(lambda j: one(adj, jnp.minimum(j, N - 1)))(js)
        return adj.at[jnp.where(js < N, js, N)].set(rows, mode="drop"), None

    adjacency, _ = jax.lax.scan(block, adjacency, aff)
    return adjacency


def apply_back_edges(
    adjacency: jax.Array,
    prune_table: jax.Array,
    usable: jax.Array,
    pairs_j: jax.Array,
    pairs_p: jax.Array,
    *,
    alpha: float,
    R: int,
    d_max: int | None = None,
    chunk: int = 1024,
) -> jax.Array:
    """Stage 3: apply Delta.  Affected nodes append or re-prune (Alg. 2).

    Affected nodes are processed in chunks via ``lax.map`` — the Patch-phase
    block pass of StreamingMerge (one block of rows streamed, patched, written
    back) and a memory bound for plain batched inserts alike.
    """
    N = adjacency.shape[0]
    P = pairs_j.shape[0]
    d_max = d_max if d_max is not None else R
    buf, cnt = group_pairs(pairs_j, pairs_p, N, d_max)
    # Every affected node appears (<= P of them); top_k over the 0/1 indicator
    # returns lowest-index ties first, so all 1s are captured when P <= a_max.
    a_max = min(P, N)
    _, affected = jax.lax.top_k((cnt > 0).astype(jnp.int32), a_max)

    def one(adj, j):
        row = adj[j]
        extra = buf[j]
        deg = (row >= 0).sum()
        add = jnp.minimum(cnt[j], d_max)
        combine = jnp.concatenate([row, extra])                    # [R + d_max]
        # append path: valid entries first, truncated to R.
        app_order = jnp.argsort(~(combine >= 0))                   # valids first
        appended = combine[app_order][:R]
        pruned = prune_node(prune_table, j, combine, usable, alpha, R).ids
        needs_prune = deg + add > R
        new_row = jnp.where(needs_prune, pruned, appended)
        return jnp.where(cnt[j] > 0, new_row, row)

    if a_max <= chunk:
        rows = jax.vmap(lambda j: one(adjacency, j))(affected)
        return adjacency.at[affected].set(rows)
    n_chunks = -(-a_max // chunk)
    pad = n_chunks * chunk - a_max
    aff = jnp.concatenate(
        [affected, jnp.full((pad,), N, jnp.int32)]).reshape(n_chunks, chunk)

    def block(adj, js):
        rows = jax.vmap(lambda j: one(adj, jnp.minimum(j, N - 1)))(js)
        return adj.at[jnp.where(js < N, js, N)].set(rows, mode="drop"), None

    adjacency, _ = jax.lax.scan(block, adjacency, aff)
    return adjacency
