"""Insert (Algorithm 2) — batched, with the paper's Delta back-edge structure.

A batch of B new points is inserted in three fixed-shape stages:

  1. candidate generation: GreedySearch(s, p, 1, L) per new point against the
     *current* graph (vmapped);
  2. RobustPrune over the visited set -> the new point's out-neighbors;
  3. back-edges: the (target j, source p) pairs are the paper's Delta
     structure.  They are grouped by target with a sort + segment-position
     trick, then every affected node either appends (if still under the degree
     budget R) or re-prunes N_out(j) + {p...} — exactly Algorithm 2's branch.

Every prune in stages 2 and 3 rides the batched prune engine
(``prune.robust_prune_batch``): one fused Pallas launch per node block under
``use_kernel``, the vmapped jnp oracle otherwise — bit-identical either way.
The Delta combine is deduplicated before the append-or-prune branch: a
source p already present in N_out(j) (or appearing twice in the pair list)
must not be appended again, silently burning degree budget.

Points inside one batch do not see each other (the paper's concurrent inserts
under fine-grained locking have the same quiescent-consistency window).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distance import INVALID
from .prune import (FullPrecisionPrune, SDCPrune, prune_node_batch,
                    robust_prune_batch)
from .search import DistanceBackend, SearchResult, beam_search


class InsertEdges(NamedTuple):
    new_adj: jax.Array   # [B, R] out-neighbors for the new points
    pairs_j: jax.Array   # [B*R] back-edge targets (INVALID padded)
    pairs_p: jax.Array   # [B*R] back-edge sources
    search: SearchResult


def compute_insert_edges(
    adjacency: jax.Array,
    navigable: jax.Array,      # bool[N] traversable (active; incl. lazy-deleted)
    usable: jax.Array,         # bool[N] candidate-eligible (active & !deleted)
    start: jax.Array,
    prune_table: jax.Array,    # [N, d] vectors used for prune distances
    new_slots: jax.Array,      # [B] slot ids of the new points (already stored)
    new_vecs: jax.Array,       # [B, d]
    backend: DistanceBackend,
    *,
    L: int,
    max_visits: int,
    alpha: float,
    R: int,
    beam_width: int = 1,
    use_kernel: bool = False,
) -> InsertEdges:
    """Stages 1+2: search & prune.  Graph arrays are pre-insert (new points
    are stored but have no in-edges, so searches cannot reach them).
    ``use_kernel`` routes BOTH the search hot loop and the batched prune
    through the Pallas ops layer."""
    res = beam_search(adjacency, navigable, start, new_vecs, backend,
                      L=L, max_visits=max_visits, beam_width=beam_width,
                      use_kernel=use_kernel)
    # Candidate pool: V union final list (Alg. 2 uses V; the list adds only
    # closer nodes, strictly improving the pool).
    cand = jnp.concatenate([res.visited, res.ids], axis=1)          # [B, V+L]

    safe = jnp.maximum(cand, 0)
    ok = (cand >= 0) & usable[safe] & (cand != new_slots[:, None])
    pb = FullPrecisionPrune(prune_table)
    d_p = pb.anchor_dists(new_vecs.astype(jnp.float32), cand)
    new_adj = robust_prune_batch(pb, cand, ok, alpha=alpha, R=R,
                                 use_kernel=use_kernel, d_p=d_p).ids
    B = new_slots.shape[0]
    pairs_j = new_adj.reshape(B * R)
    pairs_p = jnp.broadcast_to(new_slots[:, None], (B, R)).reshape(B * R)
    pairs_p = jnp.where(pairs_j >= 0, pairs_p, INVALID)
    return InsertEdges(new_adj, pairs_j, pairs_p, res)


def group_pairs(pairs_j: jax.Array, pairs_p: jax.Array, n_slots: int,
                d_max: int) -> tuple[jax.Array, jax.Array]:
    """Group back-edge pairs by target: Delta buffer [N, d_max] + counts [N].

    Sort by target, compute the position-within-group via searchsorted, then a
    single scatter.  Overflow beyond d_max is dropped (counted by callers via
    the returned counts, capped at d_max on read).
    """
    P = pairs_j.shape[0]
    key = jnp.where(pairs_j >= 0, pairs_j, jnp.int32(n_slots))  # invalid last
    order = jnp.argsort(key)
    sj, sp = key[order], pairs_p[order]
    first = jnp.searchsorted(sj, sj, side="left")
    slot = jnp.arange(P, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = (sj < n_slots) & (slot < d_max)
    buf = jnp.full((n_slots, d_max), INVALID, jnp.int32)
    buf = buf.at[jnp.where(keep, sj, n_slots), jnp.where(keep, slot, 0)].set(
        sp, mode="drop")
    cnt = jnp.zeros((n_slots,), jnp.int32).at[key].add(
        (key < n_slots).astype(jnp.int32), mode="drop")
    return buf, cnt


def _dedupe_combine(combine: jax.Array) -> jax.Array:
    """Mask later duplicates to INVALID, keeping the first occurrence.

    The Delta append path would otherwise append a source already present in
    N_out(j) (or listed twice in the pair buffer) a second time — a silent
    degree-budget burn.  Prune outputs are unaffected (a duplicate of the
    selected candidate is alpha-covered at distance 0 and retired anyway),
    so deduping changes only the append branch and its budget test.
    """
    Ct = combine.shape[-1]
    iota = jnp.arange(Ct)
    eq = combine[..., :, None] == combine[..., None, :]       # [.., i, j]
    dup = ((eq & (iota[None, :] < iota[:, None])).any(axis=-1)
           & (combine >= 0))
    return jnp.where(dup, INVALID, combine)


def _apply_back_edges_impl(adjacency, backend, usable, pairs_j, pairs_p, *,
                           alpha, R, d_max, chunk, use_kernel,
                           affected_cap=None):
    """Shared Delta application (stage 3 / StreamingMerge Patch phase).

    Affected nodes are processed in blocks via ``lax.scan`` — the Patch-phase
    block pass of StreamingMerge (one block of rows streamed, patched, written
    back) and a memory bound for plain batched inserts alike.

    ``affected_cap`` (static) bounds the number of processed rows below the
    worst case min(P, N).  The locality paths measure the DISTINCT back-edge
    target count D on the host and pass a power-of-two bucket >= D
    (``locality.next_bucket``), so a proximity-ordered batch whose pairs
    collide onto few targets launches a proportionally smaller prune — the
    fixed-shape program cannot shrink dynamically otherwise.  Correctness
    requires cap >= D: top_k over the 0/1 indicator captures every affected
    row exactly when the launch width covers the 1s.  None = worst case
    (bit-identical to the historical behavior).
    """
    N = adjacency.shape[0]
    P = pairs_j.shape[0]
    buf, cnt = group_pairs(pairs_j, pairs_p, N, d_max)
    # Every affected node appears (<= P of them); top_k over the 0/1 indicator
    # returns lowest-index ties first, so all 1s are captured when P <= a_max.
    a_max = min(P, N)
    if affected_cap is not None:
        a_max = max(1, min(a_max, int(affected_cap)))
    _, affected = jax.lax.top_k((cnt > 0).astype(jnp.int32), a_max)

    def rows_for(adj, js, usable):
        rows = adj[js]
        extra = buf[js]
        combine = _dedupe_combine(jnp.concatenate([rows, extra], axis=1))
        total = (combine >= 0).sum(axis=1)
        app_order = jnp.argsort(~(combine >= 0), axis=1)
        appended = jnp.take_along_axis(combine, app_order, axis=1)[:, :R]
        pruned = prune_node_batch(backend, js, combine, usable,
                                  alpha=alpha, R=R,
                                  use_kernel=use_kernel).ids
        new_rows = jnp.where((total > R)[:, None], pruned, appended)
        return jnp.where((cnt[js] > 0)[:, None], new_rows, rows)

    if a_max <= chunk:
        rows = rows_for(adjacency, affected, usable)
        return adjacency.at[affected].set(rows)
    n_chunks = -(-a_max // chunk)
    pad = n_chunks * chunk - a_max
    aff = jnp.concatenate(
        [affected, jnp.full((pad,), N, jnp.int32)]).reshape(n_chunks, chunk)

    def block(adj, js):
        rows = rows_for(adj, jnp.minimum(js, N - 1), usable)
        return adj.at[jnp.where(js < N, js, N)].set(rows, mode="drop"), None

    adjacency, _ = jax.lax.scan(block, adjacency, aff)
    return adjacency


def apply_back_edges_codes(
    adjacency: jax.Array,
    codes: jax.Array,        # [N, m] PQ codes
    tables: jax.Array,       # [m, ksub, ksub] sdc tables
    usable: jax.Array,
    pairs_j: jax.Array,
    pairs_p: jax.Array,
    *,
    alpha: float,
    R: int,
    d_max: int | None = None,
    chunk: int = 1024,
    use_kernel: bool = False,
    affected_cap: int | None = None,
) -> jax.Array:
    """Patch phase with SDC distances (see apply_back_edges)."""
    d_max = d_max if d_max is not None else R
    return _apply_back_edges_impl(
        adjacency, SDCPrune(codes, tables), usable, pairs_j, pairs_p,
        alpha=alpha, R=R, d_max=d_max, chunk=chunk, use_kernel=use_kernel,
        affected_cap=affected_cap)


def apply_back_edges(
    adjacency: jax.Array,
    prune_table: jax.Array,
    usable: jax.Array,
    pairs_j: jax.Array,
    pairs_p: jax.Array,
    *,
    alpha: float,
    R: int,
    d_max: int | None = None,
    chunk: int = 1024,
    use_kernel: bool = False,
    affected_cap: int | None = None,
) -> jax.Array:
    """Stage 3: apply Delta.  Affected nodes append or re-prune (Alg. 2)."""
    d_max = d_max if d_max is not None else R
    return _apply_back_edges_impl(
        adjacency, FullPrecisionPrune(prune_table), usable, pairs_j, pairs_p,
        alpha=alpha, R=R, d_max=d_max, chunk=chunk, use_kernel=use_kernel,
        affected_cap=affected_cap)
