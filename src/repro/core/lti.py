"""The Long-Term Index (LTI) — the storage-resident tier (paper §5.1).

An LTI is a FreshVamana graph whose *navigation* distances come from PQ codes
(the only per-point data kept in fast memory; ~32B/point), with full-precision
vectors resident in the capacity tier ("SSD" = pod HBM here) used only for the
final exact rerank of the candidate list — exactly DiskANN's search recipe.

``search_lti`` rides the fused beam engine (``core.search``): each IO round
is one batched ADC distance call plus one ``frontier_select`` launch.  In
the system fan-out (§5.2) the LTI normally rides as the PQ lane of the ONE
unified device program (``index.unified_search`` selects ADC for it and
exact L2 for the temp lanes, and reranks its candidates in-program);
``search_lti`` remains the standalone engine — the sequential oracle path
(``batch_fanout=False``), direct LTI queries, and the per-lane bit-parity
contract the unified program is tested against.  Its IO rounds model the
paper's SSD round trips, which is why the LTI lane dominates the beam-width
autotuner's max-over-lanes latency cost (``core.autotune``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import pq as pqm
from .config import IndexConfig, PQConfig
from .graph import GraphState
from .index import build as mem_build
from .search import (FullPrecisionBackend, PQBackend, batch_distances,
                     beam_search, rerank_candidates, topk_results)


class LTIState(NamedTuple):
    graph: GraphState      # adjacency + full-precision vectors + flags
    codes: jax.Array       # [capacity, m] uint8 PQ codes
    codebook: pqm.PQCodebook


def build_lti(vectors, cfg: IndexConfig, pq_cfg: PQConfig,
              train_sample: int = 65536, batch: int = 256,
              passes: int = 1, seed: int = 0) -> LTIState:
    """Static DiskANN-style build: graph from full-precision distances,
    PQ codebook trained on a sample, all points encoded."""
    graph = mem_build(vectors, cfg, batch=batch, passes=passes, seed=seed)
    n = vectors.shape[0]
    sample = jnp.asarray(vectors[:min(n, train_sample)])
    codebook = pqm.train_pq(sample, pq_cfg)
    codes = jnp.zeros((cfg.capacity, pq_cfg.m), jnp.uint8)
    codes = codes.at[:n].set(pqm.encode(codebook, jnp.asarray(vectors), pq_cfg))
    return LTIState(graph, codes, codebook)


def write_lti_layout(path: str, lti: LTIState, *, ext_ids=None,
                     generation: int = 0):
    """Serialize an LTI into the decoupled on-disk layout: adjacency rows to
    ``topology.bin``, full-precision vectors + PQ codes to ``data.bin``,
    flags/ext-ids/codebook to the in-memory side tables (``storage.layout``).
    Returns the layout opened; ``DiskLTISearcher`` over it is bit-identical
    to ``search_lti`` on this state."""
    from ..storage.layout import write_layout
    return write_layout(path, lti.graph, codes=lti.codes,
                        codebook=lti.codebook, ext_ids=ext_ids,
                        generation=generation)


def lti_from_layout(path: str) -> LTIState:
    """Materialize an ``LTIState`` back from a decoupled layout (recovery /
    tests; the serving path streams rows via ``storage.DiskSource``)."""
    from ..storage.layout import open_layout
    lay = open_layout(path)
    try:
        return lay.lti_state()
    finally:
        lay.close()


@functools.partial(jax.jit, static_argnames=("cfg", "k", "L", "rerank",
                                             "beam_width"))
def search_lti(lti: LTIState, queries: jax.Array, cfg: IndexConfig,
               *, k: int, L: int, rerank: bool = True,
               beam_width: Optional[int] = None):
    """PQ-navigated beam search + exact rerank (paper §5.2 / DiskANN).

    Returns (ids [B,k], dists [B,k], hops [B], cmps [B]).  ``hops`` counts IO
    rounds: at ``beam_width`` W each round issues up to W concurrent
    adjacency fetches, so the paper's "~120 random 4KB reads" metric is
    hops * W (exactly ``SearchResult.n_reads``) while latency follows hops.
    """
    g = lti.graph
    use_kernel = cfg.kernel_enabled()
    res = beam_search(g.adjacency, g.active, g.start, queries,
                      PQBackend(lti.codes, lti.codebook),
                      L=L, max_visits=cfg.visits_bound(L),
                      beam_width=beam_width or cfg.beam_width,
                      use_kernel=use_kernel)
    reportable = g.active & ~g.deleted
    if rerank:
        # Exact distances for the final L candidates ("full-precision vectors
        # fetched from the capacity tier").  DeleteList members are masked
        # BEFORE the gather: they can never be reported, so fetching their
        # full-precision rows would burn rerank reads for nothing.
        exact = batch_distances(
            FullPrecisionBackend(g.vectors), queries,
            rerank_candidates(res.ids, reportable), use_kernel=use_kernel)
        res = res._replace(dists=exact)
    ids, d = topk_results(res, k, reportable)
    return ids, d, res.n_hops, res.n_cmps
