"""Beam-width autotuning — pick W from the measured hop/cmp trade-off.

The paper's §6.2 beamwidth argument: each search iteration issues W
concurrent sector reads as ONE IO round, so raising W cuts the number of
rounds (latency) ~W-fold while paying a few extra distance computations
(the frontier expands nodes it would otherwise have pruned).  The right W
therefore depends on the ratio between the cost of an IO round and the cost
of a distance computation — a property of the serving hardware, not of the
index — which is exactly what ``bench_io_cost`` measures.

This module closes that loop: ``measure_widths`` runs a probe batch at each
candidate W and records the per-query hop/cmp counters; ``pick_beam_width``
scores each point under a linear cost model and returns the argmin.  The
cost model is counter-based (hops and cmps are deterministic), so the choice
is reproducible and immune to wall-clock noise on a shared machine.

``FreshDiskANN`` wires this in behind ``SystemConfig.autotune_beam``: the
first search calibrates and caches the width; a StreamingMerge invalidates
the cache (the graph — and hence the hop counts — changed).  Under
``batch_fanout`` the probe runs the unified fan-out program itself
(``index.unified_search``) and costs it the way the hardware pays for it:
per-query IO rounds = max over lanes (the vmapped lanes run concurrently,
so latency follows the slowest lane — normally the LTI), distance
computations = sum over lanes (total work).  Without batching it probes the
largest single tier, as before.

Batched/sharded serving (docs/SERVING.md) changes nothing here: the hop and
cmp counters are per-query and bit-identical whether a query is served
alone, inside a ``search_batch`` micro-batch, or against the mesh-sharded
LTI lane (the sharded lane replays the identical beam loop on replicated
state), so one probe calibrates every serving configuration of the same
tier census.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class BeamCostModel:
    """Relative cost of one IO round vs one distance computation.

    The defaults encode the paper's SSD regime (~100us random read vs ~0.4us
    for a handful of ADC lookups): an IO round costs ~250 comparisons.  On
    hardware where distance evaluation dominates (e.g. full-precision scoring
    on CPU), raise ``cmp_cost`` and the tuner will back off to smaller W.
    """

    io_round_cost: float = 1.0
    cmp_cost: float = 0.004


@dataclasses.dataclass(frozen=True)
class BeamPoint:
    """One measured operating point of the beam-width sweep."""

    W: int
    hops: float         # mean IO rounds per query
    cmps: float         # mean distance computations per query
    seconds: float = 0.0  # wall-clock of the probe (informational only)

    def cost(self, model: BeamCostModel) -> float:
        return self.hops * model.io_round_cost + self.cmps * model.cmp_cost


def measure_widths(search_fn: Callable[[int], tuple],
                   widths: Sequence[int]) -> list[BeamPoint]:
    """Probe ``search_fn(W) -> (hops [B], cmps [B])`` at each candidate W."""
    points = []
    for W in widths:
        t0 = time.perf_counter()
        hops, cmps = search_fn(W)
        points.append(BeamPoint(
            W=int(W), hops=float(np.mean(np.asarray(hops))),
            cmps=float(np.mean(np.asarray(cmps))),
            seconds=time.perf_counter() - t0))
    return points


def pick_beam_width(points: Sequence[BeamPoint],
                    model: BeamCostModel = BeamCostModel()) -> int:
    """The W minimizing the modeled per-query cost (ties -> smallest W)."""
    if not points:
        raise ValueError("empty beam-width sweep")
    best = min(points, key=lambda p: (p.cost(model), p.W))
    return best.W
