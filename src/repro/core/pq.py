"""Product Quantization (Jegou et al. [35]) — codebook training, encode,
decode, LUT construction and asymmetric distance computation (ADC).

The LTI stores only PQ codes in fast memory (paper §5: B = 32 bytes/vector);
every StreamingMerge distance and every LTI navigation distance is an ADC
against a per-query lookup table.  ``repro.kernels.pq_adc`` provides the
Pallas TPU kernel for the ADC hot loop; this module is the reference path and
the codebook machinery.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import PQConfig


class PQCodebook(NamedTuple):
    centroids: jax.Array   # [m, ksub, dsub] float32


def _assign(x_sub: jax.Array, cent: jax.Array) -> jax.Array:
    """x_sub [N, m, dsub], cent [m, ksub, dsub] -> codes [N, m] int32."""
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; argmin over ksub
    xc = jnp.einsum("nmd,mkd->nmk", x_sub, cent)
    cn = jnp.sum(cent * cent, axis=-1)                      # [m, ksub]
    return jnp.argmin(cn[None] - 2.0 * xc, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_pq(data: jax.Array, cfg: PQConfig) -> PQCodebook:
    """Lloyd's k-means per subspace (vectorised across all m subspaces)."""
    n = data.shape[0]
    x = data.astype(jnp.float32).reshape(n, cfg.m, cfg.dsub)
    key = jax.random.PRNGKey(cfg.seed)
    init_idx = jax.random.choice(key, n, (cfg.ksub,), replace=n < cfg.ksub)
    cent = jnp.transpose(x[init_idx], (1, 0, 2))            # [m, ksub, dsub]

    def step(cent, _):
        codes = _assign(x, cent)                            # [N, m]
        oh = jax.nn.one_hot(codes, cfg.ksub, dtype=jnp.float32)  # [N, m, k]
        sums = jnp.einsum("nmk,nmd->mkd", oh, x)
        cnts = jnp.sum(oh, axis=0)                          # [m, k]
        new = sums / jnp.maximum(cnts, 1.0)[..., None]
        cent = jnp.where((cnts > 0)[..., None], new, cent)  # keep empty as-is
        return cent, None

    cent, _ = jax.lax.scan(step, cent, None, length=cfg.kmeans_iters)
    return PQCodebook(cent)


@functools.partial(jax.jit, static_argnames=("cfg",))
def encode(codebook: PQCodebook, data: jax.Array, cfg: PQConfig) -> jax.Array:
    """Vectors -> uint8 codes [N, m]."""
    n = data.shape[0]
    x = data.astype(jnp.float32).reshape(n, cfg.m, cfg.dsub)
    return _assign(x, codebook.centroids).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("cfg",))
def decode(codebook: PQCodebook, codes: jax.Array, cfg: PQConfig) -> jax.Array:
    """Codes -> reconstructed vectors [N, dim] (used for prune distances)."""
    c = codes.astype(jnp.int32)                             # [N, m]
    recon = jnp.take_along_axis(
        codebook.centroids[None],                           # [1, m, k, dsub]
        c[:, :, None, None], axis=2)[:, :, 0, :]            # [N, m, dsub]
    return recon.reshape(codes.shape[0], cfg.m * cfg.dsub)


def lut(codebook: PQCodebook, query: jax.Array) -> jax.Array:
    """Per-query ADC lookup table [m, ksub] of squared subspace distances."""
    m, ksub, dsub = codebook.centroids.shape
    q = query.astype(jnp.float32).reshape(m, 1, dsub)
    diff = q - codebook.centroids
    return jnp.sum(diff * diff, axis=-1)                    # [m, ksub]


def adc(codes: jax.Array, table: jax.Array) -> jax.Array:
    """ADC: sum_m table[m, codes[:, m]] -> [N] approximate squared distances.

    Reference (jnp) path; the Pallas kernel computes the same contraction as a
    one-hot matmul on the MXU.
    """
    c = codes.astype(jnp.int32)                             # [N, m]
    m = table.shape[0]
    gathered = table[jnp.arange(m)[None, :], c]             # [N, m]
    return jnp.sum(gathered, axis=-1)


def adc_gather(codes: jax.Array, table: jax.Array, ids: jax.Array) -> jax.Array:
    """ADC for a subset of rows; INVALID ids -> +inf (search dist_fn shape)."""
    safe = jnp.maximum(ids, 0)
    d = adc(codes[safe], table)
    return jnp.where(ids >= 0, d, jnp.inf)


# ---------------------------------------------------------------------------
# SDC — symmetric distance computation between two PQ codes.
#
# sdc(a, b) == ||decode(a) - decode(b)||^2 exactly (the squared distance
# decomposes per subspace), but reads 1 byte/subspace per point instead of
# dsub*4 — this is what makes StreamingMerge's prune passes touch 16x fewer
# bytes than decoding vectors (the paper's "use the compressed PQ vectors
# for approximate distances", taken to its traffic-optimal form).
# ---------------------------------------------------------------------------

def sdc_tables(codebook: PQCodebook) -> jax.Array:
    """Centroid-pair squared distances [m, ksub, ksub] (~8MB for 32x256)."""
    c = codebook.centroids
    diff = c[:, :, None, :] - c[:, None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def sdc_lut(tables: jax.Array, code: jax.Array) -> jax.Array:
    """Anchor one code: returns an ADC-shaped LUT [m, ksub] so that
    ``adc(codes_b, sdc_lut(tables, a)) == sdc(a, b)`` for every b."""
    m = tables.shape[0]
    return tables[jnp.arange(m), code.astype(jnp.int32)]
