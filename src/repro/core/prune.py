"""RobustPrune (Algorithm 3) — the alpha-RNG pruning rule.

Fixed-shape, vmappable: candidates arrive as padded id arrays; the loop runs
exactly R rounds with masking (each round either selects one neighbor or is a
no-op once the candidate pool is exhausted).

An edge to c is dropped once some retained p* satisfies
``alpha * d(p*, c) <= d(p, c)`` — retained edges cover their "cone" with slack
alpha (paper §4).  With alpha = 1 this degenerates to the aggressive HNSW/NSG
rule (the paper's unstable baseline, reproduced in tests/benchmarks).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distance import INVALID, l2_sq


class PruneResult(NamedTuple):
    ids: jax.Array   # [R] selected out-neighbors, INVALID padded
    count: jax.Array  # scalar int32


def robust_prune(
    p_vec: jax.Array,        # [d] the node being pruned
    cand_ids: jax.Array,     # [C] candidate ids (may contain dups / INVALID)
    cand_vecs: jax.Array,    # [C, d] candidate vectors (garbage where INVALID)
    cand_ok: jax.Array,      # [C] bool — candidate usable (valid, not deleted, != p)
    alpha: float,
    R: int,
) -> PruneResult:
    C = cand_ids.shape[0]
    p_vec = p_vec.astype(jnp.float32)
    cand_vecs = cand_vecs.astype(jnp.float32)
    d_p = jnp.where(cand_ok, l2_sq(p_vec[None, :], cand_vecs), jnp.inf)  # [C]

    def body(i, s):
        alive, out_ids, cnt = s
        masked = jnp.where(alive, d_p, jnp.inf)
        star = jnp.argmin(masked)
        ok = jnp.isfinite(masked[star])
        out_ids = out_ids.at[i].set(jnp.where(ok, cand_ids[star], INVALID))
        cnt = cnt + ok.astype(jnp.int32)
        # alpha-RNG coverage: drop candidates the new neighbor covers.
        d_star = l2_sq(cand_vecs[star][None, :], cand_vecs)              # [C]
        covered = alpha * d_star <= d_p
        alive = alive & ~covered & (jnp.arange(C) != star)
        alive = jnp.where(ok, alive, jnp.zeros_like(alive))
        return alive, out_ids, cnt

    alive0 = cand_ok & jnp.isfinite(d_p)
    out0 = jnp.full((R,), INVALID, jnp.int32)
    _, out_ids, cnt = jax.lax.fori_loop(0, R, body, (alive0, out0, jnp.int32(0)))
    return PruneResult(out_ids, cnt)


def prune_node(
    vectors: jax.Array,      # [N, d] full table (or PQ-decoded table)
    p: jax.Array,            # scalar node id
    cand_ids: jax.Array,     # [C]
    usable: jax.Array,       # bool[N] — active and not deleted
    alpha: float,
    R: int,
) -> PruneResult:
    """RobustPrune against the stored table: gathers candidate vectors itself."""
    safe = jnp.maximum(cand_ids, 0)
    cand_vecs = vectors[safe]
    ok = (cand_ids >= 0) & usable[safe] & (cand_ids != p)
    return robust_prune(vectors[p], cand_ids, cand_vecs, ok, alpha, R)


def robust_prune_codes(
    d_p: jax.Array,          # [C] distances from p to candidates (any source:
    #                          sdc_lut for code anchors, pq.lut for vectors)
    cand_ids: jax.Array,     # [C]
    cand_codes: jax.Array,   # [C, m] uint8 PQ codes of the candidates
    cand_ok: jax.Array,      # [C] bool
    alpha: float,
    R: int,
    tables: jax.Array,       # [m, ksub, ksub] from pq.sdc_tables
) -> PruneResult:
    """Algorithm 3 with all candidate-candidate distances computed from PQ
    codes (SDC) — numerically identical to pruning on decoded vectors but
    touching m bytes per candidate per round instead of dim*4."""
    from . import pq as pqm

    C = cand_ids.shape[0]
    d_p = jnp.where(cand_ok, d_p, jnp.inf)

    def body(i, s):
        alive, out_ids, cnt = s
        masked = jnp.where(alive, d_p, jnp.inf)
        star = jnp.argmin(masked)
        ok = jnp.isfinite(masked[star])
        out_ids = out_ids.at[i].set(jnp.where(ok, cand_ids[star], INVALID))
        cnt = cnt + ok.astype(jnp.int32)
        d_star = pqm.adc(cand_codes, pqm.sdc_lut(tables, cand_codes[star]))
        covered = alpha * d_star <= d_p
        alive = alive & ~covered & (jnp.arange(C) != star)
        alive = jnp.where(ok, alive, jnp.zeros_like(alive))
        return alive, out_ids, cnt

    alive0 = cand_ok & jnp.isfinite(d_p)
    out0 = jnp.full((R,), INVALID, jnp.int32)
    _, out_ids, cnt = jax.lax.fori_loop(0, R, body, (alive0, out0,
                                                     jnp.int32(0)))
    return PruneResult(out_ids, cnt)


def prune_node_codes(codes, tables, p, cand_ids, usable, alpha, R
                     ) -> PruneResult:
    """SDC RobustPrune against the code table (anchor = p's own code)."""
    from . import pq as pqm

    safe = jnp.maximum(cand_ids, 0)
    cand_codes = codes[safe]
    ok = (cand_ids >= 0) & usable[safe] & (cand_ids != p)
    d_p = pqm.adc(cand_codes, pqm.sdc_lut(tables, codes[p]))
    return robust_prune_codes(d_p, cand_ids, cand_codes, ok, alpha, R,
                              tables)


def check_alpha_rng(adj_row: jax.Array, p_vec: jax.Array, vectors: jax.Array,
                    alpha: float) -> jax.Array:
    """Property check: no retained edge is alpha-covered by an earlier one.

    Returns True when the row satisfies the alpha-RNG invariant.  Used by the
    hypothesis property tests.
    """
    R = adj_row.shape[0]
    safe = jnp.maximum(adj_row, 0)
    vecs = vectors[safe].astype(jnp.float32)
    valid = adj_row >= 0
    d_p = jnp.where(valid, l2_sq(p_vec[None, :].astype(jnp.float32), vecs), jnp.inf)
    order = jnp.argsort(d_p)  # selection happens in distance order
    vecs_o = vecs[order]
    d_o = d_p[order]
    valid_o = valid[order]
    pair = l2_sq(vecs_o[:, None, :], vecs_o[None, :, :])  # [R, R]
    earlier = jnp.tril(jnp.ones((R, R), bool), k=-1)
    both = valid_o[:, None] & valid_o[None, :] & earlier
    # violation: an earlier-selected neighbor j alpha-covers i, yet i was kept.
    viol = both & (alpha * pair.T <= d_o[:, None]) & jnp.isfinite(d_o)[:, None]
    return ~viol.any()
