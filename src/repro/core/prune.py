"""RobustPrune (Algorithm 3) — the alpha-RNG pruning rule, as an engine.

An edge to c is dropped once some retained p* satisfies
``alpha * d(p*, c) <= d(p, c)`` — retained edges cover their "cone" with slack
alpha (paper §4).  With alpha = 1 this degenerates to the aggressive HNSW/NSG
rule (the paper's unstable baseline, reproduced in tests/benchmarks).

Mirroring ``core.search``'s ``DistanceBackend``, pruning is dispatched
through a ``PruneBackend`` — the distance source for the anchor->candidate
and candidate<->candidate computations:

  ``FullPrecisionPrune``  exact squared-L2 over a stored vector table
                          (in-memory TempIndex mutations, LTI build);
  ``SDCPrune``            symmetric distances straight from PQ codes
                          (StreamingMerge's traffic-optimal operating
                          point — m bytes per candidate per round).

``robust_prune_batch`` is the row-batched engine: a whole block of nodes per
call, each row fixed-shape (padded candidate ids + a usability mask), with
two execution paths per backend:

  ``use_kernel=False``  the jnp oracle — exactly R masked-argmin rounds per
                        row (``kernels.ref.robust_prune_*_ref``), vmapped.
                        Bit-identical to the pre-engine per-node functions.
  ``use_kernel=True``   ONE fused Pallas launch per row
                        (``kernels.robust_prune``): argmin + winner coverage
                        row + alpha-mask update for all R rounds in-kernel,
                        vmapped over the block.  Bit-identical to the oracle
                        (the acceptance bar; see docs/KERNELS.md).

The single-node helpers (``robust_prune``/``prune_node``/``*_codes``) remain
the oracle surface the property tests exercise directly.
"""
from __future__ import annotations

from typing import NamedTuple, Protocol

import jax
import jax.numpy as jnp

from .distance import l2_sq
from ..kernels import ops, ref


class PruneResult(NamedTuple):
    ids: jax.Array    # [R] ([B, R] from the batched engine), INVALID padded
    count: jax.Array  # scalar ([B]) int32


class PruneBackend(Protocol):
    """Distance dispatch for the prune engine (see module doc)."""

    def anchor_of(self, ps: jax.Array):
        """Node ids [B] -> per-row anchor context (vector / SDC lut)."""
        ...

    def anchor_dists(self, anchors, cand_ids: jax.Array) -> jax.Array:
        """Anchors x cand_ids [B, C] -> raw d(p, c) [B, C] (unmasked)."""
        ...

    def prune_rows(self, d_p, cand_ids, cand_ok, *, alpha: float, R: int,
                   use_kernel: bool) -> PruneResult:
        """Run the prune rounds over a block of rows."""
        ...


class FullPrecisionPrune(NamedTuple):
    """Exact squared-L2 pruning against a stored table ([N, d])."""

    table: jax.Array

    def anchor_of(self, ps: jax.Array) -> jax.Array:
        return self.table[jnp.maximum(ps, 0)].astype(jnp.float32)

    def anchor_dists(self, anchors: jax.Array, cand_ids: jax.Array
                     ) -> jax.Array:
        safe = jnp.maximum(cand_ids, 0)
        return jax.vmap(
            lambda a, c: l2_sq(a[None, :], self.table[c]))(anchors, safe)

    def prune_rows(self, d_p, cand_ids, cand_ok, *, alpha, R, use_kernel
                   ) -> PruneResult:
        vecs = self.table[jnp.maximum(cand_ids, 0)]          # [B, C, d]
        out, cnt = ops.robust_prune_fp(d_p, vecs, cand_ids, cand_ok,
                                       alpha=alpha, R=R,
                                       use_kernel=use_kernel)
        return PruneResult(out, cnt)


class SDCPrune(NamedTuple):
    """PQ-code pruning: every distance symmetric-distance-computed from
    ``codes`` [N, m] via ``tables`` [m, ksub, ksub] (``pq.sdc_tables``) —
    numerically identical to pruning on decoded vectors, ~16x less HBM
    traffic."""

    codes: jax.Array
    tables: jax.Array

    def anchor_of(self, ps: jax.Array) -> jax.Array:
        from . import pq as pqm
        return jax.vmap(lambda p: pqm.sdc_lut(
            self.tables, self.codes[jnp.maximum(p, 0)]))(ps)  # [B, m, ksub]

    def anchor_dists(self, anchors: jax.Array, cand_ids: jax.Array
                     ) -> jax.Array:
        from . import pq as pqm
        safe = jnp.maximum(cand_ids, 0)
        return jax.vmap(
            lambda lut, c: pqm.adc(self.codes[c], lut))(anchors, safe)

    def prune_rows(self, d_p, cand_ids, cand_ok, *, alpha, R, use_kernel
                   ) -> PruneResult:
        codes = self.codes[jnp.maximum(cand_ids, 0)].astype(jnp.int32)
        out, cnt = ops.robust_prune_sdc(d_p, codes, self.tables, cand_ids,
                                        cand_ok, alpha=alpha, R=R,
                                        use_kernel=use_kernel)
        return PruneResult(out, cnt)


def robust_prune_batch(
    backend: PruneBackend,
    cand_ids: jax.Array,       # [B, C] candidate ids (dups / INVALID ok)
    cand_ok: jax.Array,        # [B, C] bool — candidate usable
    *,
    alpha: float,
    R: int,
    use_kernel: bool = False,
    anchors=None,              # backend.anchor_of output (or caller-built)
    d_p: jax.Array | None = None,  # [B, C] precomputed anchor distances
) -> PruneResult:
    """Row-batched Algorithm 3: a whole block of nodes per call.

    Anchor distances come from ``d_p`` when given (e.g. the StreamingMerge
    insert phase anchors on the exact new vector via an ADC lut), else from
    ``backend.anchor_dists(anchors, cand_ids)``.  Returns ids [B, R] +
    counts [B].  ``use_kernel`` selects the fused Pallas path; both paths
    are bit-identical (tests/test_update_engine.py).
    """
    if d_p is None:
        d_p = backend.anchor_dists(anchors, cand_ids)
    return backend.prune_rows(d_p, cand_ids, cand_ok,
                              alpha=alpha, R=R, use_kernel=use_kernel)


def prune_node_batch(backend: PruneBackend, ps: jax.Array,
                     cand_ids: jax.Array, usable: jax.Array, *,
                     alpha: float, R: int, use_kernel: bool = False
                     ) -> PruneResult:
    """Batched ``prune_node``: anchors are stored nodes ``ps`` [B]; the
    usability mask excludes INVALID lanes, unusable slots, and self-edges."""
    safe = jnp.maximum(cand_ids, 0)
    ok = (cand_ids >= 0) & usable[safe] & (cand_ids != ps[:, None])
    return robust_prune_batch(backend, cand_ids, ok, alpha=alpha, R=R,
                              use_kernel=use_kernel,
                              anchors=backend.anchor_of(ps))


# ---------------------------------------------------------------------------
# Single-node oracles (the pre-engine surface; property tests use these).
# ---------------------------------------------------------------------------

def robust_prune(
    p_vec: jax.Array,        # [d] the node being pruned
    cand_ids: jax.Array,     # [C] candidate ids (may contain dups / INVALID)
    cand_vecs: jax.Array,    # [C, d] candidate vectors (garbage where INVALID)
    cand_ok: jax.Array,      # [C] bool — candidate usable (valid, not deleted)
    alpha: float,
    R: int,
) -> PruneResult:
    """Algorithm 3 over one node, full precision (delegates to the jnp
    contract in ``kernels.ref`` — the same rounds the Pallas kernel fuses)."""
    p_vec = p_vec.astype(jnp.float32)
    cand_vecs = cand_vecs.astype(jnp.float32)
    d_p = l2_sq(p_vec[None, :], cand_vecs)                   # [C]
    out, cnt = ref.robust_prune_fp_ref(d_p, cand_vecs, cand_ids, cand_ok,
                                       alpha=alpha, R=R)
    return PruneResult(out, cnt)


def prune_node(
    vectors: jax.Array,      # [N, d] full table (or PQ-decoded table)
    p: jax.Array,            # scalar node id
    cand_ids: jax.Array,     # [C]
    usable: jax.Array,       # bool[N] — active and not deleted
    alpha: float,
    R: int,
) -> PruneResult:
    """RobustPrune against the stored table: gathers candidate vectors itself."""
    safe = jnp.maximum(cand_ids, 0)
    cand_vecs = vectors[safe]
    ok = (cand_ids >= 0) & usable[safe] & (cand_ids != p)
    return robust_prune(vectors[p], cand_ids, cand_vecs, ok, alpha, R)


def robust_prune_codes(
    d_p: jax.Array,          # [C] distances from p to candidates (any source:
    #                          sdc_lut for code anchors, pq.lut for vectors)
    cand_ids: jax.Array,     # [C]
    cand_codes: jax.Array,   # [C, m] uint8 PQ codes of the candidates
    cand_ok: jax.Array,      # [C] bool
    alpha: float,
    R: int,
    tables: jax.Array,       # [m, ksub, ksub] from pq.sdc_tables
) -> PruneResult:
    """Algorithm 3 with all candidate-candidate distances computed from PQ
    codes (SDC) — numerically identical to pruning on decoded vectors but
    touching m bytes per candidate per round instead of dim*4."""
    out, cnt = ref.robust_prune_sdc_ref(d_p, cand_codes.astype(jnp.int32),
                                        tables, cand_ids, cand_ok,
                                        alpha=alpha, R=R)
    return PruneResult(out, cnt)


def prune_node_codes(codes, tables, p, cand_ids, usable, alpha, R
                     ) -> PruneResult:
    """SDC RobustPrune against the code table (anchor = p's own code)."""
    from . import pq as pqm

    safe = jnp.maximum(cand_ids, 0)
    cand_codes = codes[safe]
    ok = (cand_ids >= 0) & usable[safe] & (cand_ids != p)
    d_p = pqm.adc(cand_codes, pqm.sdc_lut(tables, codes[p]))
    return robust_prune_codes(d_p, cand_ids, cand_codes, ok, alpha, R,
                              tables)


def check_alpha_rng(adj_row: jax.Array, p_vec: jax.Array, vectors: jax.Array,
                    alpha: float) -> jax.Array:
    """Property check: no retained edge is alpha-covered by an earlier one.

    Returns True when the row satisfies the alpha-RNG invariant.  Used by the
    property tests and as a post-condition over ``consolidate_deletes`` /
    ``streaming_merge`` outputs (pass the table the prune actually ran on —
    PQ-decoded vectors for the merge phases).
    """
    R = adj_row.shape[0]
    safe = jnp.maximum(adj_row, 0)
    vecs = vectors[safe].astype(jnp.float32)
    valid = adj_row >= 0
    d_p = jnp.where(valid, l2_sq(p_vec[None, :].astype(jnp.float32), vecs), jnp.inf)
    order = jnp.argsort(d_p)  # selection happens in distance order
    vecs_o = vecs[order]
    d_o = d_p[order]
    valid_o = valid[order]
    pair = l2_sq(vecs_o[:, None, :], vecs_o[None, :, :])  # [R, R]
    earlier = jnp.tril(jnp.ones((R, R), bool), k=-1)
    both = valid_o[:, None] & valid_o[None, :] & earlier
    # violation: an earlier-selected neighbor j alpha-covers i, yet i was kept.
    viol = both & (alpha * pair.T <= d_o[:, None]) & jnp.isfinite(d_o)[:, None]
    return ~viol.any()


def check_alpha_rng_rows(adjacency: jax.Array, node_ids: jax.Array,
                         vectors: jax.Array, alpha: float) -> jax.Array:
    """Vectorized ``check_alpha_rng`` over a set of rows.

    [len(node_ids)] bool — per-row alpha-RNG verdicts for
    ``adjacency[node_ids]`` against anchors ``vectors[node_ids]``.  The
    localized delete repair's natural post-condition: pass the affected
    ids (``delete.affected_mask``) and the table the prune ran on, and
    every repaired row must come back True.
    """
    safe = jnp.maximum(node_ids, 0)
    return jax.vmap(
        lambda p: check_alpha_rng(adjacency[p], vectors[p], vectors, alpha)
    )(safe)
