"""Reachability monitor: a sampled probe of unreachable live points.

Localized delete repair (``delete.consolidate_deletes(mode="local")``)
repairs exactly the rows the global Algorithm-4 sweep would change, so
it inherits the sweep's connectivity properties — but any localized
scheme needs a guard against the unreachable-points pathology (points
that keep losing in-edges across repair cycles until no greedy path
reaches them; see PAPERS.md on graph degradation under deletions).

``unreachable_fraction`` estimates that pathology directly: sample
``samples`` live points, beam-search each one's OWN vector from the
entry point, and call a point unreachable when its slot shows up in
neither the result list nor the visited set.  A healthy Vamana graph
self-navigates — searching a stored vector lands on its own slot — so
the estimate is ~0 on intact graphs and grows as repair quality
degrades.  The system exposes it as the ``SystemStats.unreachable_frac``
gauge and escalates a localized repair back to the global sweep when the
estimate degrades more than ``SystemConfig.reach_escalate_frac`` ABOVE
the baseline recorded after the last global sweep (a freshly built graph
already carries a few percent of orphaned points — batched inserts whose
back-edges all lost the prune — which no delete repair caused or can
cure, so the guard is relative, not absolute).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .config import IndexConfig
from .graph import GraphState
from .search import FullPrecisionBackend, beam_search


@functools.partial(jax.jit, static_argnames=("cfg", "L"))
def _probe(adjacency, active, start, vectors, picks, cfg: IndexConfig, L: int):
    queries = vectors[picks]
    res = beam_search(adjacency, active, start, queries,
                      FullPrecisionBackend(vectors),
                      L=L, max_visits=cfg.visits_bound(L),
                      beam_width=cfg.beam_width,
                      use_kernel=cfg.kernel_enabled())
    seen = jnp.concatenate([res.ids, res.visited], axis=1)       # [n, L+V]
    found = jnp.any(seen == picks[:, None], axis=1)
    return 1.0 - jnp.mean(found.astype(jnp.float32))


def unreachable_fraction(state: GraphState, cfg: IndexConfig,
                         samples: int = 32, seed: int = 0,
                         L: int | None = None) -> float:
    """Estimate the fraction of live points greedy search cannot reach.

    Draws exactly ``samples`` live slots (with replacement when fewer live
    points exist — the probe batch stays a fixed shape, so repeated probes
    reuse one compiled program) and searches each one's own vector from
    ``state.start``.  Returns 0.0 for an empty index (nothing to reach)
    and 1.0 when live points exist but the entry point is the empty
    sentinel (everything is unreachable by definition).
    """
    live = np.asarray(state.active & ~state.deleted)
    live_ids = np.nonzero(live)[0]
    if len(live_ids) == 0 or samples <= 0:
        return 0.0
    if int(state.start) < 0:
        return 1.0
    rng = np.random.default_rng(seed)
    picks = rng.choice(live_ids, size=int(samples),
                       replace=len(live_ids) < int(samples)).astype(np.int32)
    L = cfg.L_search if L is None else L
    return float(_probe(state.adjacency, state.active, state.start,
                        state.vectors, jnp.asarray(picks), cfg, L))
