"""Distance computations.

Everything is squared-L2 (monotone in L2, so rankings are identical and we
avoid sqrt everywhere, as DiskANN does).  The batched form is written as
``||q||^2 - 2 q.x + ||x||^2`` so that the inner product lands on the MXU; the
Pallas kernel in ``repro.kernels.l2_distance`` implements the same contraction
with explicit VMEM tiling and is used by the ops-layer when enabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.inf
INVALID = -1  # sentinel node id


def l2_sq(a: jax.Array, b: jax.Array) -> jax.Array:
    """Squared L2 between broadcastable batches of vectors (last dim reduced)."""
    diff = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def l2_sq_batch(queries: jax.Array, points: jax.Array) -> jax.Array:
    """[Q, d] x [N, d] -> [Q, N] squared distances via the matmul identity."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)          # [Q, 1]
    xn = jnp.sum(x * x, axis=-1)                          # [N]
    d = qn - 2.0 * (q @ x.T) + xn[None, :]
    return jnp.maximum(d, 0.0)


def gather_l2(query: jax.Array, vectors: jax.Array, ids: jax.Array) -> jax.Array:
    """Distances from one query to ``vectors[ids]``; invalid ids -> +inf.

    ids: int32 [K] with INVALID padding.  Fetches are clamped so the gather is
    always in-bounds (TPU-friendly), then masked.
    """
    safe = jnp.maximum(ids, 0)
    pts = vectors[safe]                                   # [K, d]
    d = l2_sq(query[None, :], pts)
    return jnp.where(ids >= 0, d, INF)
