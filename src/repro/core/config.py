"""Configuration objects for the FreshDiskANN core.

All sizes are static so every core operation jit-compiles to fixed shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Parameters of a FreshVamana graph index (paper §4, §6.1).

    Attributes:
      capacity: maximum number of slots (N_max). Fixed at construction so all
        arrays are static; the paper's R/L/alpha defaults come from §6.2.
      dim: vector dimensionality.
      R: maximum out-degree of the graph (paper: 64).
      L_build: candidate-list size during build/insert (paper: L_c = 75).
      L_search: default candidate-list size during search (paper: L_s = 100).
      alpha: the alpha-RNG pruning threshold (paper: 1.2).
      max_visits: cap on greedy-search expansions (bounds the while_loop).
      dtype: storage dtype of full-precision vectors.
      beam_width: W — frontier nodes expanded per search iteration (paper
        §6.2 beamwidth).  Each iteration issues W concurrent adjacency
        fetches as one IO round; W=1 is the classic single-expansion search.
      use_kernel: route the device hot paths through the Pallas kernels in
        ``repro.kernels.ops`` — batched search distances + the fused
        frontier step on the query side, AND the mutation engine's fused
        RobustPrune / delete-repair launches (insert, consolidation,
        StreamingMerge) on the update side.  None (default) auto-selects:
        kernels on TPU, jnp reference path elsewhere.  Both paths are
        bit-identical; the jnp path is the parity oracle.
      repair_mode: how ``consolidate_deletes{_codes}`` walks the index.
        ``"global"`` is the paper's Algorithm-4 sweep over every block;
        ``"local"`` first computes the affected set (live nodes with >=1
        deleted out-neighbor), gathers only those rows into padded
        fixed-shape blocks, repairs them through the same batched prune
        engine, and scatters the rows back — bit-identical to the global
        sweep, order-of-magnitude cheaper at low delete rates.  The
        system routes merges between the two by delete rate
        (``SystemConfig.local_repair_threshold``).
      locality_clusters: number of sampled medoids ``core.locality`` uses to
        proximity-order an update batch when ``SystemConfig.locality_order``
        is on (flush reorder + locality merge).  More clusters = finer
        grouping but smaller groups to amortize prune launches over; 0 keeps
        the default.  Ignored while ``locality_order`` is off.
    """

    capacity: int
    dim: int
    R: int = 64
    L_build: int = 75
    L_search: int = 100
    alpha: float = 1.2
    max_visits: int = 0  # 0 -> derived: L + L//2 + 16
    dtype: str = "float32"
    beam_width: int = 1
    use_kernel: Optional[bool] = None
    repair_mode: str = "global"
    locality_clusters: int = 16

    def visits_bound(self, L: int) -> int:
        if self.max_visits:
            return self.max_visits
        return int(L + L // 2 + 16)

    def kernel_enabled(self) -> bool:
        """Resolve ``use_kernel`` (None -> Pallas on TPU only)."""
        if self.use_kernel is None:
            import jax
            return jax.default_backend() == "tpu"
        return bool(self.use_kernel)


@dataclasses.dataclass(frozen=True)
class PQConfig:
    """Product-quantization parameters (paper §5: B = 32 bytes/vector)."""

    dim: int
    m: int = 32          # number of subspaces == bytes per vector (ksub<=256)
    ksub: int = 256      # centroids per subspace
    kmeans_iters: int = 12
    seed: int = 0

    def __post_init__(self):
        if self.dim % self.m != 0:
            raise ValueError(f"dim={self.dim} not divisible by m={self.m}")

    @property
    def dsub(self) -> int:
        return self.dim // self.m


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """FreshDiskANN system-level knobs (paper §5, §6.2)."""

    index: IndexConfig
    pq: PQConfig
    # TempIndex limits: freeze RW->RO at `ro_snapshot_points`, trigger a
    # StreamingMerge when the total staged points exceed `merge_threshold`
    # (paper: 5M snapshots, 30M merge threshold for a ~1B LTI).
    ro_snapshot_points: int = 4096
    merge_threshold: int = 16384
    temp_capacity: int = 65536
    insert_batch: int = 256
    # Merge internals.
    merge_block: int = 1024       # nodes per sequential block pass ("SSD block")
    rerank: bool = True           # exact full-precision rerank of the LTI's
    #   final candidate list (paper §5.2; navigation stays on PQ codes)
    wal_dir: Optional[str] = None
    # Durability (§5.6): when set, every merge saves a snapshot here BEFORE
    # truncating the WAL, so snapshot + log-suffix always reconstructs the
    # full state.  Without it the log is never truncated (truncating with no
    # covering snapshot would lose the pre-merge records on crash).
    snapshot_dir: Optional[str] = None
    # Query engine (paper §5.2 fan-out).  Serving guide: docs/SERVING.md.
    batch_fanout: bool = True     # ONE jitted device program per query
    #   batch: RW + RO tiers + the PQ-navigated LTI lane searched as a
    #   heterogeneous LaneStack, with the DeleteList filter and cross-tier
    #   top-k merge on-device (index.unified_search).  False: sequential
    #   per-tier loop + host aggregation — the bit-parity oracle.
    batch_queries: int = 0        # serving micro-batch width for
    #   search_batch: 0 = run each request batch at its natural shape (a
    #   new jit specialization per distinct B); N > 0 = serve queries in
    #   fixed-shape chunks of N (the tail chunk zero-padded and sliced
    #   off), so ONE compiled program serves any request size.  Results
    #   are bit-identical per query either way; search_dispatches counts
    #   ceil(B / N) programs per request batch.
    shard_lti: int = 0            # shard the LTI lane's per-point arrays
    #   (vectors, adjacency, PQ codes, flags) row-wise over min(shard_lti,
    #   device_count) devices on a 1-axis data mesh (graph.shard_lti +
    #   serving.steps.make_sharded_unified_step).  The beam state stays
    #   replicated and every row access is owner-computed + psum'd, so
    #   results are bit-identical to the unsharded lane for any shard
    #   count.  Each device SEARCHES only its 1/n row block; note that in
    #   this repo the sharded placement is a serving-side copy — the
    #   system keeps its mutable source-of-truth LTI unsharded for
    #   merges/snapshots, so the net memory win needs a deployment that
    #   drops the unsharded copy (docs/SERVING.md, "What it costs").
    #   0 = off.  The sequential oracle (batch_fanout=False) ignores it.
    background_merge: bool = False  # threshold merges run on a worker thread
    #   so inserts never stall on a foreground StreamingMerge
    autotune_beam: bool = False   # pick W from the hop/cmp trade-off, costed
    #   against the unified fan-out program (see core.autotune)
    beam_width_candidates: tuple = (1, 2, 4, 8)
    # Decoupled on-disk storage (repro.storage — guide: docs/STORAGE.md).
    storage_dir: Optional[str] = None  # when set, the LTI is mirrored to a
    #   decoupled layout at <storage_dir>/lti (topology.bin + data.bin +
    #   header/meta): written at construction and load, delta-patched in
    #   place after every StreamingMerge (only changed adjacency rows are
    #   rewritten — vector bytes stay put for surviving points), and
    #   snapshots save the LTI as a layout instead of lti.npz.
    #   search_disk() serves the LTI lane from this layout through
    #   DiskSource with the knobs below.
    prefetch_depth: int = 1       # lookahead depth of the async prefetch
    #   pipeline, in frontier widths: each IO round the engine hands the
    #   next depth*W still-open candidates to a background reader that
    #   stages their adjacency rows while the device scores the current
    #   round.  0 disables the prefetch thread (demand reads only).
    #   Results are bit-identical at any depth; only timing changes.
    adjacency_cache_mb: int = 8   # LRU cache over 4KB adjacency blocks of
    #   topology.bin.  Hits are NOT IO reads: they land in
    #   SystemStats.io_cache_hits and n_reads drops accordingly (the
    #   conservation law in core/search.py's counter contract).  0 = off
    #   (every row request touches the file; n_reads matches the
    #   in-memory engine bit-for-bit).
    # Localized delete repair + reachability guard (docs/ARCHITECTURE.md,
    # "Localized delete repair").
    local_repair_threshold: float = 0.05  # a merge's Delete phase runs the
    #   localized (affected-set) repair when the LTI's delete rate —
    #   DeleteList members resident in the LTI / live LTI points — is at
    #   or below this fraction; above it the global Algorithm-4 sweep is
    #   cheaper (most rows are affected anyway).  Both paths are
    #   bit-identical; 0 forces every merge global.
    reach_probe_samples: int = 32 # reachability monitor: after every merge
    #   (and standalone consolidate()) sample this many live LTI points and
    #   beam-search each one's own vector from the entry point; the
    #   fraction NOT found lands in SystemStats.unreachable_frac.  0
    #   disables the probe.
    reach_escalate_frac: float = 0.05  # when a probe after a *localized*
    #   repair estimates an unreachable fraction more than this much ABOVE
    #   the baseline (the estimate after the last global sweep, or the
    #   first probe), the next Delete phase is forced to the global sweep
    #   (SystemStats.repair_escalations counts these).  The comparison is
    #   against the baseline, not zero: batched inserts orphan a few
    #   percent of points at small R (no in-edges survive the back-edge
    #   prune), which is a build artifact the delete path did not cause
    #   and cannot repair.  Sized to the probe's sampling noise at the
    #   default reach_probe_samples.
    # Locality-aware update batching (core/locality.py —
    # docs/ARCHITECTURE.md, "Update-path locality").
    locality_order: bool = False  # proximity-order update batches before
    #   they hit the graph: the insert buffer is reordered by sampled-medoid
    #   cluster at flush time (so a flush chunk's back-edge pairs collide
    #   onto fewer distinct targets and the Delta prune launch shrinks to a
    #   measured power-of-two bucket), and StreamingMerge's Phase 2 runs the
    #   locality schedule (cluster-ordered chunks inserted EAGERLY — each
    #   chunk's Delta lands before the next chunk searches, so cluster
    #   mates wire to each other and the back-edge patch concentrates onto
    #   the new rows, shrinking `adjacency_delta_mask` and therefore
    #   `patch_layout`'s rewritten rows/bytes).  Reordering legitimately
    #   changes slot assignment and topology: the contract is recall
    #   equivalence with arrival order plus bit-determinism for a fixed
    #   batch + seed, NOT bit-parity (docs/ARCHITECTURE.md).  Counters:
    #   SystemStats.{flush,merge}_backedge_targets / _prune_rows.
    io_latency_us: float = 0.0    # simulated device latency per IO round
    #   that touches topology.bin (a round's block reads ride the queue
    #   concurrently — §6.2).  Benchmarks only: page-cached mmap reads
    #   cost ~0 on this container, so prefetch overlap is unmeasurable
    #   without it.  Demand rounds sleep on the critical path, prefetch
    #   generations on the worker thread.
    # Continuous-batching serving front end (serving/scheduler.py —
    # docs/SERVING.md, "The serving loop").  The scheduler packs ragged
    # request arrivals into fixed-shape micro-batches of ``batch_queries``
    # queries, closing a batch when it fills OR when the oldest request's
    # deadline budget would be violated, whichever comes first.
    slo_ms: float = 0.0           # per-request latency SLO: a request
    #   submitted at t must complete by t + slo_ms.  The scheduler closes a
    #   partial batch once now + dispatch-estimate reaches the oldest
    #   request's deadline; requests completing late are counted in
    #   SystemStats.deadline_misses.  0 = no deadline (batches close only
    #   when full, or on flush()).
    serve_queue_capacity: int = 1024  # bounded request queue: submissions
    #   beyond this depth are SHED (rejected, SystemStats.shed_requests)
    #   instead of growing the queue without bound — overload surfaces as
    #   explicit backpressure, not as unbounded latency.
    dispatch_estimate_ms: float = 1.0  # seed of the scheduler's EWMA
    #   estimate of one micro-batch dispatch's wall time; the estimate is
    #   subtracted from the SLO budget when deciding the batch-close time
    #   and updated from measured dispatches.
    clock: Optional[object] = None  # injected Clock for the scheduler
    #   (serving.scheduler.Clock protocol): None = wall clock
    #   (time.monotonic).  Tests inject serving.scheduler.VirtualClock so
    #   every batch-close/shed/deadline decision is deterministic — the
    #   policy core consults only this clock, never the wall.
    # Filtered & multi-tenant search (docs/ARCHITECTURE.md, "Filtered &
    # multi-tenant search").
    filter_words: int = 0         # uint32 words per per-point label bitset
    #   (so labels cover bit indices [0, 32*filter_words)).  When > 0 every
    #   tier carries a host-side LabelTable parallel to its ext_ids table,
    #   labels persist through WAL/snapshots/storage meta, and
    #   search_batch(filter=FilterSpec(...)) folds the predicate into the
    #   cached drop mask — one extra AND per candidate, no kernel change.
    #   0 = label plumbing off; tenant ids still work (they need no words).
    tenant_quota: int = 0         # per-tenant in-flight ticket quota in the
    #   BatchScheduler: a tenant with this many queued (undispatched)
    #   requests has further submissions SHED (counted per tenant in
    #   SystemStats.tenant_sheds and globally in shed_requests).  0 = no
    #   per-tenant quota (only serve_queue_capacity backpressure applies).


# The paper's operating point for the billion-scale deployment (§6.2).
PAPER_BILLION = IndexConfig(
    capacity=1_073_741_824, dim=128, R=64, L_build=75, L_search=100, alpha=1.2
)
