r"""GreedySearch (Algorithm 1) — batched, fixed-shape, jit/vmap-friendly.

The search keeps the classic DiskANN beam state: a candidate list of the L
closest nodes seen so far (sorted), an expanded flag per entry, and the visited
(expanded) set V.  Each iteration expands the closest unexpanded candidate,
fetches its adjacency row (one "sector read" in the paper's SSD terms; one HBM
block gather here), scores the new neighbors, and merges.

Termination matches Algorithm 1 (loop while L \ V is nonempty) with an explicit
iteration bound so the ``lax.while_loop`` is well-formed.  Each iteration
expands exactly one node, so visited arrays are sized by the bound.

Distances are injected via ``make_dist_fn`` so the same search serves both the
in-memory full-precision index and the PQ-navigated LTI.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .distance import INVALID

# make_dist_fn: query -> (ids[int32, K] -> dists[f32, K], +inf for INVALID)
MakeDistFn = Callable[[jax.Array], Callable[[jax.Array], jax.Array]]


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, L]  final candidate list (sorted by distance)
    dists: jax.Array      # [B, L]
    visited: jax.Array    # [B, V]  expanded nodes in expansion order
    visited_dists: jax.Array  # [B, V]
    n_hops: jax.Array     # [B]     expansions (== "IO reads" per paper §6.2)
    n_cmps: jax.Array     # [B]     distance computations


def _search_one(
    adjacency: jax.Array,
    navigable: jax.Array,
    start: jax.Array,
    dist_fn: Callable[[jax.Array], jax.Array],
    L: int,
    max_visits: int,
) -> SearchResult:
    R = adjacency.shape[1]

    cand_ids = jnp.full((L,), INVALID, jnp.int32).at[0].set(start.astype(jnp.int32))
    d0 = dist_fn(cand_ids[:1])[0]
    cand_d = jnp.full((L,), jnp.inf, jnp.float32).at[0].set(d0)
    cand_exp = jnp.zeros((L,), bool)
    vis_ids = jnp.full((max_visits,), INVALID, jnp.int32)
    vis_d = jnp.full((max_visits,), jnp.inf, jnp.float32)

    state = (cand_ids, cand_d, cand_exp, vis_ids, vis_d,
             jnp.int32(0), jnp.int32(0), jnp.int32(1))

    def cond(s):
        cand_ids, cand_d, cand_exp, *_, vis_cnt, _, _ = s
        open_ = (cand_ids >= 0) & ~cand_exp & jnp.isfinite(cand_d)
        return jnp.any(open_) & (vis_cnt < max_visits)

    def body(s):
        cand_ids, cand_d, cand_exp, vis_ids, vis_d, vis_cnt, n_cmps, n_seen = s
        open_ = (cand_ids >= 0) & ~cand_exp
        sel = jnp.argmin(jnp.where(open_, cand_d, jnp.inf))
        p = cand_ids[sel]
        cand_exp = cand_exp.at[sel].set(True)
        vis_ids = vis_ids.at[vis_cnt].set(p)
        vis_d = vis_d.at[vis_cnt].set(cand_d[sel])
        vis_cnt = vis_cnt + 1

        nbrs = adjacency[jnp.maximum(p, 0)]                       # [R]
        ok = (nbrs >= 0) & navigable[jnp.maximum(nbrs, 0)]
        in_list = (nbrs[:, None] == cand_ids[None, :]).any(axis=1)
        in_vis = (nbrs[:, None] == vis_ids[None, :]).any(axis=1)
        new = ok & ~in_list & ~in_vis
        nd = dist_fn(jnp.where(new, nbrs, INVALID))               # inf if masked
        n_cmps = n_cmps + new.sum(dtype=jnp.int32)

        all_ids = jnp.concatenate([cand_ids, jnp.where(new, nbrs, INVALID)])
        all_d = jnp.concatenate([cand_d, nd])
        all_exp = jnp.concatenate([cand_exp, jnp.zeros((R,), bool)])
        order = jnp.argsort(all_d)[:L]
        return (all_ids[order], all_d[order], all_exp[order],
                vis_ids, vis_d, vis_cnt, n_cmps, n_seen)

    cand_ids, cand_d, cand_exp, vis_ids, vis_d, vis_cnt, n_cmps, _ = (
        jax.lax.while_loop(cond, body, state))
    return SearchResult(cand_ids, cand_d, vis_ids, vis_d, vis_cnt, n_cmps)


def greedy_search(
    adjacency: jax.Array,
    navigable: jax.Array,
    start: jax.Array,
    queries: jax.Array,
    make_dist_fn: MakeDistFn,
    *,
    L: int,
    max_visits: int,
) -> SearchResult:
    """Batched Algorithm 1 over ``queries`` [B, ...]."""

    def one(q):
        return _search_one(adjacency, navigable, start, make_dist_fn(q), L, max_visits)

    return jax.vmap(one)(queries)


def topk_results(
    res: SearchResult,
    k: int,
    reportable: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Final top-k, excluding DeleteList/inactive nodes (paper §5.2 filter).

    reportable: bool[capacity] — active & not deleted.
    """
    ids, dists = res.ids, res.dists
    ok = (ids >= 0) & reportable[jnp.maximum(ids, 0)]
    d = jnp.where(ok, dists, jnp.inf)
    order = jnp.argsort(d, axis=-1)[:, :k]
    out_ids = jnp.take_along_axis(ids, order, axis=-1)
    out_d = jnp.take_along_axis(d, order, axis=-1)
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, INVALID)
    return out_ids, out_d
