r"""Beam-width GreedySearch (Algorithm 1) — batched, fixed-shape, MXU-routed.

The search keeps the classic DiskANN beam state: a candidate list of the L
closest nodes seen so far (sorted ascending), and the visited (expanded) set
V.  Each iteration selects the ``beam_width`` (W) closest unexpanded
candidates, gathers their W x R adjacency rows in one shot (W concurrent
"sector reads" issued as ONE IO round, the paper's §6.2 beamwidth trick),
scores all W*R neighbors with a single batched distance call, and merges into
the candidate list via one top-L step.  W=1 reproduces the classic
one-node-per-iteration search exactly; W>1 cuts the while-loop trip count (and
hence search latency) by ~W at the cost of a few extra distance computations.

Counter semantics (paper §6.2 IO accounting):

  ``n_hops``   IO *rounds* — while-loop iterations.  Each round issues up to W
               concurrent adjacency fetches; latency is proportional to rounds.
  ``n_reads``  adjacency rows actually FETCHED from the graph source.  For
               in-memory sources (``DenseSource``, the owner-computes sharded
               source) every frontier row is a fetch, so reads == expanded
               nodes (the paper's "~120 random 4KB reads" metric; at W=1
               reads == hops).  A disk-backed source
               (``repro.storage.DiskSource``) reports a per-row fetched mask
               instead: rows its block cache served without touching the
               file are NOT reads — they are counted separately as
               ``SystemStats.io_cache_hits`` — while rows the prefetch
               pipeline read ahead still are (the IO happened; it was just
               overlapped off the critical path).  The conservation law
               ``n_reads + cache_hits == rows requested`` ties the two
               paths together; with the cache off, disk ``n_reads`` is
               bit-identical to the dense engine's at any prefetch depth
               (regression: tests/test_storage.py).
  ``n_cmps``   distance computations against fresh neighbors.

Distance computation is injected via a ``DistanceBackend`` — a tiny protocol
with two methods:

  ``prepare(query)``            per-query precompute (e.g. the PQ ADC lookup
                                table); returns an opaque context.
  ``distances(ctx, ids, use_kernel=...)``
                                distances from the prepared query to
                                ``ids`` (int32, INVALID-padded -> +inf).

Two implementations ship here: ``FullPrecisionBackend`` (exact squared-L2
over stored vectors) and ``PQBackend`` (asymmetric distance over PQ codes).
With ``use_kernel=True`` both dispatch their batched gather-and-score to the
Pallas wrappers in ``repro.kernels.ops`` (``l2_distances`` / ``adc_distances``)
on padded fixed-shape batches; with ``use_kernel=False`` the pure-jnp
reference path is used (bit-identical to the pre-beam implementation at W=1).

Each IO round pays exactly TWO device steps: the batched distance call, and
one fused ``frontier_select`` launch (``kernels.frontier_select``) that
merges the W*R fresh neighbors into the candidate list (stable top-L),
recomputes the open mask against the visited set, picks the next W-wide
frontier, and appends it to the visited arrays.  The pre-fusion engine paid
three separate steps per round (``block_topk`` merge, membership recompute,
``argsort`` frontier pick); the fused step is bit-identical to that sequence
(the jnp reference in ``kernels.ref.frontier_select_ref`` is the contract).

Graph rows are fetched through a ``GraphSource`` — a second tiny protocol
mirroring ``DistanceBackend``, but for the *topology* side of a round:
``rows(ids)`` returns the adjacency rows of a frontier and ``node_ok(ids)``
the navigability of freshly discovered neighbors.  ``DenseSource`` (the
default) indexes local dense arrays; the mesh-sharded LTI lane
(``serving.steps``) substitutes an owner-computes source whose gathers are
combined across shards with one ``psum`` — see docs/SERVING.md.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Protocol

import jax
import jax.numpy as jnp

from . import pq as pqm
from .distance import INVALID, l2_sq
from ..kernels import ops


class DistanceBackend(Protocol):
    """Batched distance dispatch for the search engine (see module doc)."""

    def prepare(self, query: jax.Array) -> Any:
        """Per-query precompute; the result is threaded through the loop."""
        ...

    def distances(self, ctx: Any, ids: jax.Array, *,
                  use_kernel: bool = False) -> jax.Array:
        """ids [K] int32 (INVALID-padded) -> dists [K] f32 (+inf for INVALID)."""
        ...


class FullPrecisionBackend(NamedTuple):
    """Exact squared-L2 against full-precision stored vectors."""

    vectors: jax.Array            # [capacity, d]

    def prepare(self, query: jax.Array) -> jax.Array:
        return query.astype(jnp.float32)

    def distances(self, ctx: jax.Array, ids: jax.Array, *,
                  use_kernel: bool = False) -> jax.Array:
        safe = jnp.maximum(ids, 0)
        pts = self.vectors[safe]                          # [K, d]
        if use_kernel:
            d = ops.l2_distances(ctx[None, :], pts)[0]
        else:
            d = l2_sq(ctx[None, :], pts)
        return jnp.where(ids >= 0, d, jnp.inf)


class PQBackend(NamedTuple):
    """Asymmetric distance computation over PQ codes (LTI navigation)."""

    codes: jax.Array              # [capacity, m] uint8
    codebook: pqm.PQCodebook

    def prepare(self, query: jax.Array) -> jax.Array:
        return pqm.lut(self.codebook, query)              # [m, ksub]

    def distances(self, ctx: jax.Array, ids: jax.Array, *,
                  use_kernel: bool = False) -> jax.Array:
        if use_kernel:
            safe = jnp.maximum(ids, 0)
            d = ops.adc_distances(self.codes[safe], ctx[None])[0]
            return jnp.where(ids >= 0, d, jnp.inf)
        return pqm.adc_gather(self.codes, ctx, ids)


class GraphSource(Protocol):
    """Adjacency/navigability row access for the search engine.

    The engine never indexes graph arrays directly — every topology read of
    an IO round goes through this protocol, so the same beam loop serves
    dense local arrays (``DenseSource``), row-sharded storage (the
    owner-computes source of the mesh-sharded LTI lane in
    ``serving.steps``), and the on-disk layout
    (``repro.storage.DiskSource``).

    A source may additionally implement the *hinted* extension —
    ``rows_hinted(ids, hints) -> (rows, fetched)`` plus an integer
    ``hint_width`` attribute.  Its presence routes the engine onto the
    frontier->prefetch handshake: the loop threads a ``hint_width``-wide
    lookahead (the next still-open candidates after each frontier pick)
    through the round, hands it to the source alongside the frontier so an
    async prefetcher can stage the *next* round's rows while this round's
    distances compute, and accumulates the returned per-row ``fetched``
    mask as ``n_reads`` (rows the source served from cache are hits, not
    reads — see the counter contract above).
    """

    def rows(self, ids: jax.Array) -> jax.Array:
        """ids [W] int32 -> adjacency rows [W, R]; INVALID rows for ids<0."""
        ...

    def node_ok(self, ids: jax.Array) -> jax.Array:
        """ids [K] int32 -> bool [K]: valid (>=0) and navigable."""
        ...


class DenseSource(NamedTuple):
    """Dense local-array graph access — the single-device default."""

    adjacency: jax.Array          # [capacity, R] int32
    navigable: jax.Array          # [capacity] bool

    def rows(self, ids: jax.Array) -> jax.Array:
        return jnp.where((ids >= 0)[:, None],
                         self.adjacency[jnp.maximum(ids, 0)], INVALID)

    def node_ok(self, ids: jax.Array) -> jax.Array:
        return (ids >= 0) & self.navigable[jnp.maximum(ids, 0)]


def batch_distances(backend: DistanceBackend, queries: jax.Array,
                    ids: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """[B, ...] queries x [B, K] ids -> [B, K] distances (exact-rerank path)."""

    def one(q, i):
        return backend.distances(backend.prepare(q), i, use_kernel=use_kernel)

    return jax.vmap(one)(queries, ids)


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, L]  final candidate list (sorted by distance)
    dists: jax.Array      # [B, L]
    visited: jax.Array    # [B, V]  expanded nodes in expansion order
    visited_dists: jax.Array  # [B, V]
    n_hops: jax.Array     # [B]     IO rounds (beam iterations; latency proxy)
    n_cmps: jax.Array     # [B]     distance computations
    n_reads: jax.Array    # [B]     adjacency rows fetched from the source
    #   ("IO reads" per §6.2) — cache-served rows excluded; see module doc


def _lookahead(cand_ids: jax.Array, cand_d: jax.Array, vis_ids: jax.Array,
               hint_w: int) -> jax.Array:
    """The engine half of the frontier->prefetch handshake: after a
    ``frontier_select`` the candidate list is sorted ascending and the
    selected frontier is already in the visited set, so the first
    ``hint_w`` entries that are valid, unvisited, and finite are exactly
    the nodes the NEXT frontier will be drawn from — unless a fresh
    discovery outranks them (those become demand reads).  Deterministic:
    a pure function of loop state, so prefetch hit/miss classification
    never depends on thread timing."""
    if hint_w <= 0:
        return jnp.full((0,), INVALID, jnp.int32)
    L = cand_ids.shape[0]
    in_vis = (cand_ids[:, None] == vis_ids[None, :]).any(axis=1)
    open_ = (cand_ids >= 0) & ~in_vis & jnp.isfinite(cand_d)
    # Stable "open entries first, in list (= distance) order" permutation.
    key = jnp.where(open_, jnp.arange(L, dtype=jnp.int32), jnp.int32(L))
    order = jnp.argsort(key)[:hint_w]
    return jnp.where(open_[order], cand_ids[order], INVALID)


def _search_one(
    source: GraphSource,
    start: jax.Array,
    backend: DistanceBackend,
    ctx: Any,
    *,
    R: int,
    L: int,
    max_visits: int,
    beam_width: int,
    use_kernel: bool,
) -> SearchResult:
    W = beam_width
    K = W * R

    cand_ids = jnp.full((L,), INVALID, jnp.int32).at[0].set(
        start.astype(jnp.int32))
    d0 = backend.distances(ctx, cand_ids[:1], use_kernel=use_kernel)[0]
    cand_d = jnp.full((L,), jnp.inf, jnp.float32).at[0].set(d0)
    vis_ids = jnp.full((max_visits,), INVALID, jnp.int32)
    vis_d = jnp.full((max_visits,), jnp.inf, jnp.float32)

    def step(cand_ids, cand_d, new_ids, new_d, vis_ids, vis_d, vis_cnt):
        # Fused round step: merge the K fresh neighbors into the candidate
        # list (stable top-L), pick the next W-wide open frontier, and append
        # it to the visited arrays — ONE kernel launch when use_kernel (the
        # old path paid block_topk + membership + argsort separately).
        return ops.frontier_select(cand_ids, cand_d, new_ids, new_d,
                                   vis_ids, vis_d, vis_cnt, W=W,
                                   max_visits=max_visits,
                                   use_kernel=use_kernel)

    # Round 0: no fresh neighbors yet — the step just picks the start node as
    # the initial frontier and marks it visited.
    empty_i = jnp.full((K,), INVALID, jnp.int32)
    empty_d = jnp.full((K,), jnp.inf, jnp.float32)
    cand_ids, cand_d, f_ids, f_d, vis_ids, vis_d, vis_cnt = step(
        cand_ids, cand_d, empty_i, empty_d, vis_ids, vis_d, jnp.int32(0))

    # Sources with the hinted extension (repro.storage.DiskSource) count
    # their own reads per round and receive the lookahead hint; the dense
    # path is untouched — its loop state and result are bit-identical to
    # the pre-storage engine.
    hinted = hasattr(source, "rows_hinted")
    hint_w = int(getattr(source, "hint_width", 0)) if hinted else 0

    state = (cand_ids, cand_d, f_ids, f_d, vis_ids, vis_d, vis_cnt,
             jnp.int32(0), jnp.int32(0))
    if hinted:
        state = state + (jnp.int32(0),
                         _lookahead(cand_ids, cand_d, vis_ids, hint_w))

    def cond(s):
        f_ids = s[2]
        # The step only emits frontier entries while open candidates and
        # visit budget remain, so the empty frontier IS the stop condition.
        return jnp.any(f_ids >= 0)

    def body(s):
        (cand_ids, cand_d, f_ids, f_d, vis_ids, vis_d, vis_cnt,
         n_cmps, n_hops) = s[:9]

        # --- one-shot W x R adjacency gather (one IO round) -----------------
        if hinted:
            n_reads, hint = s[9:]
            frows, fetched = source.rows_hinted(f_ids, hint)
            nbrs = frows.reshape(K)
            n_reads = n_reads + fetched.sum(dtype=jnp.int32)
        else:
            nbrs = source.rows(f_ids).reshape(K)
        ok = source.node_ok(nbrs)
        in_list = (nbrs[:, None] == cand_ids[None, :]).any(axis=1)
        in_vis = (nbrs[:, None] == vis_ids[None, :]).any(axis=1)
        new = ok & ~in_list & ~in_vis
        if W > 1:
            # Cross-row dedup: frontier nodes share neighbors; keep the first
            # occurrence so the candidate list stays duplicate-free.
            iota = jnp.arange(K, dtype=jnp.int32)
            dup = ((nbrs[:, None] == nbrs[None, :])
                   & (iota[None, :] < iota[:, None])).any(axis=1)
            new = new & ~dup

        # --- single batched distance call over all W*R neighbors ------------
        nd = backend.distances(ctx, jnp.where(new, nbrs, INVALID),
                               use_kernel=use_kernel)
        n_cmps = n_cmps + new.sum(dtype=jnp.int32)

        # --- fused merge + next-frontier pick + visited update ---------------
        cand_ids, cand_d, f_ids, f_d, vis_ids, vis_d, vis_cnt = step(
            cand_ids, cand_d, jnp.where(new, nbrs, INVALID), nd,
            vis_ids, vis_d, vis_cnt)
        out = (cand_ids, cand_d, f_ids, f_d, vis_ids, vis_d, vis_cnt,
               n_cmps, n_hops + 1)
        if hinted:
            # Handshake: publish the lookahead for the round after next —
            # it rides into the next ``rows_hinted`` call, whose source
            # prefetches it while that round's distances compute.
            out = out + (n_reads,
                         _lookahead(cand_ids, cand_d, vis_ids, hint_w))
        return out

    fin = jax.lax.while_loop(cond, body, state)
    (cand_ids, cand_d, _, _, vis_ids, vis_d, vis_cnt, n_cmps, n_hops) = (
        fin[:9])
    # Dense sources fetch every visited row, so reads == the visit count;
    # hinted sources counted actual fetches round by round.
    n_reads = fin[9] if hinted else vis_cnt
    return SearchResult(cand_ids, cand_d, vis_ids, vis_d,
                        n_hops, n_cmps, n_reads)


def beam_search(
    adjacency: jax.Array,
    navigable: jax.Array,
    start: jax.Array,
    queries: jax.Array,
    backend: DistanceBackend,
    *,
    L: int,
    max_visits: int,
    beam_width: int = 1,
    use_kernel: bool = False,
    source: GraphSource | None = None,
    R: int | None = None,
) -> SearchResult:
    """Batched beam-width Algorithm 1 over ``queries`` [B, ...].

    ``source`` overrides the graph-row access (default: dense local
    indexing of ``adjacency``/``navigable``).  The static out-degree comes
    from ``adjacency`` when present; a source without device-resident
    topology (``repro.storage.DiskSource``) passes ``adjacency=None`` and
    an explicit ``R`` instead.
    """
    if beam_width < 1:
        raise ValueError(f"beam_width must be >= 1, got {beam_width}")
    if R is None:
        R = adjacency.shape[1]
    W = min(beam_width, L)   # at most L candidates can be open at once
    src = DenseSource(adjacency, navigable) if source is None else source

    def one(q):
        return _search_one(src, start, backend, backend.prepare(q),
                           R=R, L=L, max_visits=max_visits,
                           beam_width=W, use_kernel=use_kernel)

    return jax.vmap(one)(queries)


def rerank_candidates(ids: jax.Array, reportable: jax.Array) -> jax.Array:
    """Mask non-reportable candidate ids to INVALID *before* the exact-rerank
    gather.

    DeleteList members and inactive slots can never be reported
    (``topk_results`` filters them), so fetching their full-precision vectors
    for the rerank is pure waste — on the paper's hardware that is a real
    SSD read per deleted candidate.  INVALID ids short-circuit every backend
    to +inf without touching the vector store.  Output-invariant: the masked
    lanes were already excluded from the final top-k.
    """
    return jnp.where((ids >= 0) & reportable[jnp.maximum(ids, 0)],
                     ids, INVALID)


def topk_results(
    res: SearchResult,
    k: int,
    reportable: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Final top-k, excluding DeleteList/inactive nodes (paper §5.2 filter).

    reportable: bool[capacity] — active & not deleted.
    """
    ok = (res.ids >= 0) & reportable[jnp.maximum(res.ids, 0)]
    return topk_masked(res.ids, res.dists, ok, k)


def topk_masked(
    ids: jax.Array,
    dists: jax.Array,
    ok: jax.Array,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """``topk_results`` with the reportability mask precomputed.

    The mesh-sharded LTI lane uses this directly: its reportability flags
    live row-sharded across devices, so the [B, L] ``ok`` mask is gathered
    owner-computes + psum *before* the (replicated) top-k ranking.
    """
    d = jnp.where(ok, dists, jnp.inf)
    order = jnp.argsort(d, axis=-1)[:, :k]
    out_ids = jnp.take_along_axis(ids, order, axis=-1)
    out_d = jnp.take_along_axis(d, order, axis=-1)
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, INVALID)
    return out_ids, out_d
