"""The FreshDiskANN system (paper §5): LTI + RW/RO-TempIndex + DeleteList +
WAL, with the StreamingMerge cycle and optional background merging.

JAX's functional state makes the paper's trickiest concurrency concern —
searching while a merge is underway — safe by construction: a merge produces a
*new* LTI value while searches keep reading the old immutable arrays; the swap
is a single reference assignment (the paper needs careful SSD double-buffering
for the same effect).  The (LTI, external-id table) pair is swapped as ONE
tuple so a concurrent search never pairs a new graph with a stale table, and
the RO snapshots being merged stay searchable until that swap lands — a
search during a merge sees every point in exactly one consistent place (or
transiently in two, which the cross-tier dedupe in ``_aggregate`` resolves).

Query fan-out (§5.2): a query must consult the LTI *and* every TempIndex.
``search_batch`` serves a whole query batch: all live tiers — the RW tier,
every frozen RO snapshot, AND the PQ-navigated LTI — are folded into one
heterogeneous ``LaneStack`` (``graph.stack_lanes``) and the B queries ride
ONE jitted device program (``index.unified_search``): the temp tiers as a
vmapped exact-L2 group padded to the largest TEMP capacity, the LTI lane at
its own capacity on PQ ADC, then the LTI's exact rerank, the per-group
slot->external-id mapping, the DeleteList filter, and the cross-tier top-k
merge all on-device, every stage vmapped over the query axis.  The stack
and the DeleteList drop-mask are cached between mutations, so a pure query
workload pays one dispatch per micro-batch however many snapshots
accumulate (``SystemConfig.batch_queries`` fixes the micro-batch width;
``SystemConfig.shard_lti`` row-shards the LTI lane over the mesh data axis
with bit-identical results — serving guide: docs/SERVING.md).
``SystemConfig.batch_fanout=False`` restores the fully sequential per-tier
loop + host-side aggregation (the bit-parity oracle for tests): both paths
return bit-identical (ids, dists).  See docs/ARCHITECTURE.md for the full
picture.

External ids are user-provided int64s; the system maps them to (tier, slot).
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import autotune
from . import index as mem
from . import pq as pqm
from .config import IndexConfig, PQConfig, SystemConfig
from .distance import INVALID
from .graph import (NO_TENANT, FilterSpec, GraphState, LabelTable,
                    empty_graph, filter_match, pack_labels, pad_graph,
                    stack_lanes)
from .locality import locality_order, next_bucket
from .lti import LTIState, build_lti, search_lti
from .merge import streaming_merge
from .reach import unreachable_fraction
from .wal import WriteAheadLog, log_epoch, replay


@dataclass
class _Temp:
    """One TempIndex instance + its slot<->external-id maps."""
    state: GraphState
    ext_ids: np.ndarray           # [capacity] int64, -1 free
    n: int = 0
    labels: Optional[LabelTable] = None  # per-slot label bitsets + tenant
    #   ids, row-parallel to ext_ids (filtered/multi-tenant search)


LATENCY_RESERVOIR = 1024


class Reservoir:
    """Fixed-size uniform sample of an unbounded stream (Vitter's
    algorithm R) with percentile snapshots over the retained sample.

    Every element of the stream has probability ``size / seen`` of being
    in the sample at any point, so ``percentile`` is an unbiased estimate
    of the stream percentile in O(size) memory however long we run — and
    EXACT while ``seen <= size`` (the sample is then the whole stream).
    The serving-latency contract tests live in ``tests/test_scheduler.py``.
    """

    def __init__(self, size: int = LATENCY_RESERVOIR, seed: int = 0):
        self.size = size
        self.sample: list = []
        self.seen = 0
        self._rng = np.random.default_rng(seed)

    def record(self, x: float) -> None:
        self.seen += 1
        if len(self.sample) < self.size:
            self.sample.append(x)
        else:
            j = int(self._rng.integers(self.seen))
            if j < self.size:
                self.sample[j] = x

    def percentile(self, p: float) -> float:
        """Linear-interpolated percentile of the sample (NaN when empty)."""
        if not self.sample:
            return float("nan")
        return float(np.percentile(self.sample, p))

    def snapshot(self) -> dict:
        """{p50, p99, n} — the reservoir-backed percentile snapshot the
        serving benchmarks and the stats surface report."""
        return {"p50": self.percentile(50.0), "p99": self.percentile(99.0),
                "n": self.seen}


@dataclass
class SystemStats:
    inserts: int = 0
    deletes: int = 0
    searches: int = 0
    merges: int = 0
    snapshots: int = 0
    merge_seconds: float = 0.0
    # Jitted device programs launched by the query path (the §5.2 fan-out's
    # serving-cost metric).  Contract under batching: B queries served in
    # one launch count ONE dispatch — the unified path pays 1 per
    # micro-batch (ceil(B / batch_queries) per request batch when
    # micro-batching is on, else 1), the sequential oracle pays 1 per live
    # tier per micro-batch.  `searches` counts queries; dispatches count
    # programs — divide for dispatches-per-query (benchmarks report both).
    # Flush/autotune dispatches are not counted — this tracks the
    # steady-state query path only.
    search_dispatches: int = 0
    # Storage-tier IO accounting (``cfg.storage_dir`` — docs/STORAGE.md).
    # Rows obey the conservation law of core/search.py's counter contract:
    # io_rows_read + io_cache_hits == rows the engine requested.
    io_rows_read: int = 0       # adjacency rows fetched off topology.bin
    #   (demand reads + prefetch-staged reads — the engine's n_reads)
    io_cache_hits: int = 0      # rows served by the block cache, no file IO
    io_prefetch_hits: int = 0   # ... of io_rows_read, staged ahead by the
    #   prefetch pipeline (IO overlapped off the critical path)
    io_bytes_read: int = 0      # topology.bin bytes read (whole blocks)
    storage_rows_patched: int = 0    # adjacency rows rewritten by the
    #   DGAI-style delta patches StreamingMerge issues
    storage_blocks_patched: int = 0  # DISTINCT 4KB topology blocks those
    #   rows live in — the real SSD write granularity; what the locality
    #   merge's dirty-block-first slot placement shrinks
    storage_bytes_written: int = 0   # bytes those patches (and full layout
    #   writes) put on disk
    # Localized delete repair + reachability monitor (docs/ARCHITECTURE.md,
    # "Localized delete repair").
    local_repairs: int = 0      # Delete phases run as the localized
    #   affected-set sweep (delete rate <= cfg.local_repair_threshold)
    global_repairs: int = 0     # Delete phases run as the global sweep
    consolidations: int = 0     # standalone consolidate() calls (Algorithm 4
    #   on the LTI outside a merge)
    repair_cap_overflows: int = 0  # nodes whose SDC delete repair had more
    #   deleted out-neighbors than the expansion cap (merge.SDC_REPAIR_CAP)
    #   — each dropped >=1 expansion ball; deleted edges are still pruned.
    reach_probes: int = 0       # reachability probes run (sampled self-search
    #   of live LTI points after merges/consolidations)
    repair_escalations: int = 0 # localized repairs whose probe exceeded
    #   cfg.reach_escalate_frac, forcing the next Delete phase global
    unreachable_frac: float = 0.0  # gauge: latest probe's estimate of the
    #   unreachable-live-point fraction (0.0 until the first probe)
    # Update-path locality (core/locality.py — docs/ARCHITECTURE.md,
    # "Update-path locality").  Counters accumulate whether
    # cfg.locality_order is on or off, so on/off runs are directly
    # comparable: targets counts DISTINCT back-edge rows with real work,
    # prune_rows counts rows the grouped Delta prune actually LAUNCHED
    # (worst-case min(P, N) on the arrival-order paths, measured
    # power-of-two buckets on the locality paths).
    flushes: int = 0                 # RW-tier buffer flushes
    flush_backedge_targets: int = 0  # distinct Delta targets across flushes
    flush_prune_rows: int = 0        # prune rows launched by flush Deltas
    merge_backedge_targets: int = 0  # distinct Delta targets across merges
    merge_prune_rows: int = 0        # prune rows launched by merge Patches
    # Continuous-batching serving front end (serving/scheduler.py —
    # docs/SERVING.md, "The serving loop").  Counters are owned here so one
    # stats surface covers queue, batch and dispatch behavior; the
    # scheduler updates them under its own lock.
    scheduled_requests: int = 0  # requests admitted to the serving queue
    shed_requests: int = 0       # requests REJECTED by queue backpressure
    #   (queue at cfg.serve_queue_capacity) — the bounded-queue contract:
    #   overload sheds explicitly instead of growing latency without bound
    batches_dispatched: int = 0  # micro-batches the scheduler closed and
    #   served (each is >= 1 and <= cfg.batch_queries requests)
    deadline_misses: int = 0     # requests completing after arrival +
    #   cfg.slo_ms (deadline-aware close aims the dispatch estimate at
    #   making this 0; late polls and underestimates land here)
    queue_depth: int = 0         # gauge: pending requests after the last
    #   scheduler submit/close (the backpressure observable)
    batch_occupancy: float = 0.0  # gauge: fill fraction (n / batch_queries)
    #   of the last dispatched micro-batch — 1.0 when batches close full,
    #   lower when the deadline closes them early
    # Filtered & multi-tenant search (docs/ARCHITECTURE.md, "Filtered &
    # multi-tenant search").
    filtered_searches: int = 0   # queries served under a non-empty
    #   FilterSpec (label predicate and/or tenant restriction)
    tenant_searches: dict = field(default_factory=dict)  # tenant id ->
    #   queries served under that tenant's mandatory filter
    tenant_sheds: dict = field(default_factory=dict)     # tenant id ->
    #   submissions SHED by the per-tenant quota (cfg.tenant_quota);
    #   every one also counts in shed_requests (the global total)
    # Latency reservoirs (Vitter's algorithm R, see ``Reservoir``): uniform
    # samples in O(LATENCY_RESERVOIR) memory however long we run, each
    # reporting p50/p99 via ``.snapshot()``.
    #   insert_latency  — per insert() call (WAL append + buffer append
    #                     ONLY; the amortized flush is sampled separately,
    #                     so per-insert p99 reflects the steady-state cost)
    #   flush_latency   — per buffer flush (device-side insert of one
    #                     drained buffer), sampled once per flush
    #   search_latency  — per dispatched search micro-batch (device program
    #                     wall time, recorded inside _search_dispatch)
    #   serve_latency   — per scheduled request, arrival -> completion on
    #                     the scheduler's clock (queue wait + dispatch)
    insert_latency: Reservoir = field(default_factory=Reservoir, repr=False)
    search_latency: Reservoir = field(
        default_factory=lambda: Reservoir(seed=1), repr=False)
    serve_latency: Reservoir = field(
        default_factory=lambda: Reservoir(seed=2), repr=False)
    flush_latency: Reservoir = field(
        default_factory=lambda: Reservoir(seed=3), repr=False)

    def record_latency(self, seconds: float) -> None:
        self.insert_latency.record(seconds)

    # Back-compat views of the insert reservoir's previous field names.
    @property
    def insert_latencies(self) -> list:
        return self.insert_latency.sample

    @property
    def latencies_seen(self) -> int:
        return self.insert_latency.seen

    def serving_snapshot(self) -> dict:
        """One structured view of the serving surface: p50/p99 for each
        latency reservoir plus the queue/batch counters — what the serving
        benchmarks emit as machine-readable fields."""
        return {
            "search": self.search_latency.snapshot(),
            "serve": self.serve_latency.snapshot(),
            "insert": self.insert_latency.snapshot(),
            "flush": self.flush_latency.snapshot(),
            "flushes": self.flushes,
            "scheduled_requests": self.scheduled_requests,
            "shed_requests": self.shed_requests,
            "batches_dispatched": self.batches_dispatched,
            "deadline_misses": self.deadline_misses,
            "queue_depth": self.queue_depth,
            "batch_occupancy": self.batch_occupancy,
            "filtered_searches": self.filtered_searches,
            "tenant_searches": dict(self.tenant_searches),
            "tenant_sheds": dict(self.tenant_sheds),
        }


class FreshDiskANN:
    def __init__(self, cfg: SystemConfig, lti: Optional[LTIState] = None,
                 lti_ext_ids: Optional[np.ndarray] = None):
        self.cfg = cfg
        icfg = cfg.index
        # Everything except capacity mirrors the LTI's config: the unified
        # fan-out searches temp lanes and the LTI lane with ONE IndexConfig
        # (visit bounds, dtype, kernel routing), so any field that diverged
        # here would break the bit-parity contract with the sequential
        # oracle (which searches temp tiers with THIS config).
        self.temp_cfg = dataclasses.replace(icfg, capacity=cfg.temp_capacity)
        if lti is None:
            g = empty_graph(icfg)
            cb = pqm.PQCodebook(jnp.zeros(
                (cfg.pq.m, cfg.pq.ksub, cfg.pq.dsub), jnp.float32))
            lti = LTIState(g, jnp.zeros((icfg.capacity, cfg.pq.m), jnp.uint8), cb)
        # The LTI, its external-id table AND its label table are
        # read/swapped as ONE tuple so a search concurrent with a merge
        # never mixes generations.
        self._n_label_words = cfg.filter_words
        self._lti_pair: tuple[LTIState, np.ndarray, LabelTable] = (
            lti, lti_ext_ids if lti_ext_ids is not None
            else np.full(icfg.capacity, -1, np.int64),
            LabelTable(icfg.capacity, cfg.filter_words))
        self.rw = self._new_temp()
        self.ro: list[_Temp] = []
        self.deleted_ext: set[int] = set()
        self._ext_loc: dict[int, tuple] = {}
        if lti_ext_ids is not None:
            for slot, e in enumerate(lti_ext_ids):
                if e >= 0:
                    self._ext_loc[int(e)] = ("lti", slot)
        self._insert_buf_v: list[np.ndarray] = []
        self._insert_buf_id: list[int] = []
        self._insert_buf_bits: list[np.ndarray] = []   # packed label rows
        self._insert_buf_tenant: list[int] = []        # NO_TENANT default
        self._wal_offset: Optional[int] = None  # WAL bytes a snapshot covers
        self._wal_epoch: Optional[int] = None   # ... and of which log epoch
        self.stats = SystemStats()
        self._merge_lock = threading.Lock()
        self._ro_lock = threading.Lock()     # guards self.ro mutations
        # Guards the insert buffer and RW-tier BOOKKEEPING (buffer append /
        # swap, DeleteList edits, ext-id maps).  The device-side flush
        # compute runs OUTSIDE it (under _flush_lock only), so concurrent
        # insert/delete/search calls are never blocked for a whole flush.
        # RLock: save -> _flush_inserts nests under it.
        self._insert_lock = threading.RLock()
        # Serializes flushes end to end: buffer swap + device compute +
        # RW-tier publish.  Anything that must observe a QUIESCED flush
        # path (save/snapshot, rollover freeze) takes it first.  Canonical
        # lock order everywhere: _flush_lock -> _insert_lock -> _ro_lock —
        # never acquire a lock to the LEFT of one you hold.  RLock:
        # rollover/save -> _flush_inserts nest.
        self._flush_lock = threading.RLock()
        self._flush_seq = 0                  # locality-order seed per flush
        self._merge_inflight = 0             # staged points being merged now
        self._merge_thread: Optional[threading.Thread] = None
        self._force_global_repair = False    # set when a reachability probe
        #   after a localized repair degrades past cfg.reach_escalate_frac
        #   above the baseline; the next Delete phase then runs the global
        #   sweep and clears it.
        self._reach_baseline: Optional[float] = None  # probe estimate after
        #   the last global sweep (or the first probe ever) — what a
        #   localized repair's probe is compared against.
        self._tuned_w: Optional[int] = None  # cached autotuned beam width
        # Unified-fan-out caches: the LaneStack + ext-id tables (keyed by
        # tier-state identity — states are immutable values, so a flush /
        # rollover / merge replaces them and misses the cache) and the
        # DeleteList drop-mask (additionally keyed by _delete_epoch, bumped
        # on every DeleteList mutation the tier states don't witness).
        self._fanout_cache: Optional[tuple] = None
        self._frozen_cache: Optional[tuple] = None
        self._drop_cache: Optional[tuple] = None
        # Filtered drop-masks: (key, epoch, {FilterSpec: drop}) — one dict
        # of per-spec masks per (lane census, delete epoch); any tier or
        # DeleteList mutation retires the whole dict.
        self._filter_cache: Optional[tuple] = None
        self._delete_epoch = 0
        self._int32_warned = False
        # Sharded-LTI-lane caches (cfg.shard_lti — see _sharded_program).
        self._shard_mesh = None
        self._shard_mesh_n = 0
        self._shard_place: Optional[tuple] = None
        self._shard_steps: dict = {}
        self.wal: Optional[WriteAheadLog] = None
        if cfg.wal_dir:
            os.makedirs(cfg.wal_dir, exist_ok=True)
            self.wal = WriteAheadLog(
                os.path.join(cfg.wal_dir, "wal.bin"), icfg.dim)
        # Decoupled storage tier (cfg.storage_dir — docs/STORAGE.md): the
        # live layout mirrors the LTI, the searcher over it is cached per
        # layout generation (a sync closes it; reopened lazily).
        self._disk_searcher = None
        if cfg.storage_dir:
            self._sync_storage()

    # The pair is the source of truth; the individual attributes remain for
    # the non-concurrent paths (init, load, recover) and for inspection.
    @property
    def lti(self) -> LTIState:
        return self._lti_pair[0]

    @lti.setter
    def lti(self, value: LTIState) -> None:
        self._lti_pair = (value, self._lti_pair[1], self._lti_pair[2])

    @property
    def lti_ext_ids(self) -> np.ndarray:
        return self._lti_pair[1]

    @lti_ext_ids.setter
    def lti_ext_ids(self, value: np.ndarray) -> None:
        self._lti_pair = (self._lti_pair[0], value, self._lti_pair[2])

    @property
    def lti_labels(self) -> LabelTable:
        return self._lti_pair[2]

    @lti_labels.setter
    def lti_labels(self, value: LabelTable) -> None:
        self._lti_pair = (self._lti_pair[0], self._lti_pair[1], value)

    # ------------------------------------------------------------------ API
    def insert(self, ext_id: int, vec: np.ndarray, labels=None,
               tenant: Optional[int] = None) -> None:
        """Route to the RW-TempIndex (paper §5.2); batched flush.

        ``labels`` is an optional iterable of label bit indices (packed
        into ``cfg.filter_words`` uint32 words — filtered search matches
        against them); ``tenant`` tags the point with an owning tenant id
        (a mandatory filter under multi-tenancy).  Both ride the WAL as a
        labeled-insert record, the insert buffer, and every tier's label
        table, so they follow the point across its whole lifecycle.

        The lock hold covers only the WAL append + buffer append; the
        device-side flush (when this insert fills the batch) runs after the
        lock is RELEASED, under ``_flush_lock``, so concurrent
        insert/delete/search calls are not blocked for a whole flush.
        ``insert_latency`` therefore samples the bookkeeping cost only —
        the amortized flush lands in ``flush_latency``, once per flush.
        """
        bits = (pack_labels(labels, self._n_label_words)
                if labels else None)
        ten = NO_TENANT if tenant is None else int(tenant)
        t0 = time.perf_counter()
        with self._insert_lock:
            if self.wal:
                if bits is not None or ten != NO_TENANT:
                    self.wal.log_insert_labeled(
                        ext_id, vec, ten,
                        bits if bits is not None else
                        np.zeros(self._n_label_words, np.uint32))
                else:
                    self.wal.log_insert(ext_id, vec)
            self._insert_buf_id.append(int(ext_id))
            self._insert_buf_v.append(np.asarray(vec, np.float32))
            self._insert_buf_bits.append(
                bits if bits is not None else
                np.zeros(self._n_label_words, np.uint32))
            self._insert_buf_tenant.append(ten)
            # Re-insert revives the id immediately (not just at flush time),
            # so `size` and the DeleteList agree while the point is buffered.
            if int(ext_id) in self.deleted_ext:
                self.deleted_ext.discard(int(ext_id))
                self._delete_epoch += 1  # drop-mask caches must see the revive
            full = len(self._insert_buf_id) >= self.cfg.insert_batch
        self.stats.inserts += 1
        self.stats.record_latency(time.perf_counter() - t0)
        if full:
            self._flush_inserts()
        self._maybe_rollover()

    def delete(self, ext_id: int) -> None:
        """DeleteList append — O(1), no graph edits (paper §4.2)."""
        with self._insert_lock:
            if self.wal:
                self.wal.log_delete(ext_id)
            e = int(ext_id)
            if e in self._insert_buf_id:
                # The point only exists in the insert buffer: drop it there,
                # or the next flush would revive the id (flush discards the
                # delete to implement re-insert-after-delete) and invert the
                # op order.
                keep = [i for i, x in enumerate(self._insert_buf_id)
                        if x != e]
                self._insert_buf_id = [self._insert_buf_id[i] for i in keep]
                self._insert_buf_v = [self._insert_buf_v[i] for i in keep]
                self._insert_buf_bits = [self._insert_buf_bits[i]
                                         for i in keep]
                self._insert_buf_tenant = [self._insert_buf_tenant[i]
                                           for i in keep]
            self.deleted_ext.add(e)
            self._delete_epoch += 1    # invalidate cached drop-masks
        self.stats.deletes += 1

    def search(self, queries: np.ndarray, k: int, L: Optional[int] = None,
               beam_width: Optional[int] = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """Compatibility alias for ``search_batch`` (the canonical serving
        entry point since the batched engine landed — see docs/SERVING.md)."""
        return self.search_batch(queries, k, L=L, beam_width=beam_width)

    def search_batch(self, queries: np.ndarray, k: int,
                     L: Optional[int] = None,
                     beam_width: Optional[int] = None,
                     filter: Optional[FilterSpec] = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Serve a whole query batch: LTI + every TempIndex, aggregate,
        filter DeleteList (§5.2).  Returns (ext_ids [B, k], dists [B, k]).

        The B queries ride the unified fan-out as ONE jitted device
        program (per micro-batch — see below): every lane's beam search is
        vmapped over the query axis, so B queries in one launch cost one
        dispatch, not B.  Per-query results are bit-identical to serving
        each query alone (vmap semantics; the per-query / sequential-tier
        oracle suite is ``tests/test_serving.py``).

        ``cfg.batch_queries`` micro-batches the request: N > 0 serves the
        batch in fixed-shape chunks of N queries (tail chunk zero-padded,
        pad rows sliced off), so one compiled program serves any request
        size; ``SystemStats.search_dispatches`` then counts ceil(B/N)
        programs.  ``cfg.shard_lti`` additionally row-shards the LTI
        lane's arrays over the mesh data axis — same results, each device
        searching only its row block (docs/SERVING.md has the recipe and
        the capacity caveats).

        ``beam_width`` overrides the configured W for every lane in the
        fan-out; with ``cfg.autotune_beam`` and no override, W comes from
        the cached hop/cmp calibration (see ``core.autotune``).

        ``cfg.batch_fanout=False`` runs the sequential per-tier loop with
        host-side aggregation — the bit-parity oracle: both paths return
        bit-identical (ids, dists).

        ``filter`` restricts results to points matching a ``FilterSpec``
        (label predicate and/or tenant id).  The predicate folds into the
        cached DeleteList drop-mask — applied POST-search, exactly where
        deletes already are — so the beam search itself is untouched: a
        filter that matches everything returns bit-identical (ids, dists)
        to the unfiltered call, and hops/cmps never change.
        """
        self._flush_inserts()
        fspec = filter if filter is not None and not filter.is_empty \
            else None
        L = L or self.cfg.index.L_search
        if k > L:
            raise ValueError(
                f"search(k={k}, L={L}): k must be <= L — the candidate list "
                f"holds only L entries, so more than L results cannot be "
                f"returned; raise L or lower k")
        W = beam_width or self._beam_width(queries)
        # Over-fetch so DeleteList filtering + cross-tier dedupe still leave k.
        kk = min(max(k * 2, k + 8), L)
        q = np.asarray(queries, np.float32)
        B = q.shape[0]
        self.stats.searches += B        # queries served, not programs
        if fspec is not None:
            self.stats.filtered_searches += B
            if fspec.tenant is not None:
                self.stats.tenant_searches[fspec.tenant] = (
                    self.stats.tenant_searches.get(fspec.tenant, 0) + B)
        if B == 0:                      # a no-op request is not a program
            return (np.zeros((0, k), np.int64),
                    np.zeros((0, k), np.float32))
        bq = self.cfg.batch_queries
        if not bq or B == bq:
            return self._search_dispatch(q, k, kk, L, W, fspec)
        outs = []
        for lo in range(0, B, bq):      # fixed-shape chunks, tail padded
            chunk = q[lo:lo + bq]
            n = len(chunk)
            if n < bq:                  # pad up to the compiled width
                qp = np.zeros((bq, q.shape[1]), np.float32)
                qp[:n] = chunk
                chunk = qp
            ids, d = self._search_dispatch(chunk, k, kk, L, W, fspec)
            outs.append((ids[:n], d[:n]))
        return (np.concatenate([o[0] for o in outs]),
                np.concatenate([o[1] for o in outs]))

    def _search_dispatch(self, queries: np.ndarray, k: int, kk: int,
                         L: int, W: int,
                         fspec: Optional[FilterSpec] = None
                         ) -> tuple[np.ndarray, np.ndarray]:
        """Timed wrapper: every dispatched micro-batch samples its wall
        time into ``stats.search_latency`` (the reservoir behind the
        serving benches' p50/p99 rows) — lane-less no-op calls, which
        launch no program, are not samples."""
        d0 = self.stats.search_dispatches
        t0 = time.perf_counter()
        out = self._search_dispatch_impl(queries, k, kk, L, W, fspec)
        if self.stats.search_dispatches > d0:
            self.stats.search_latency.record(time.perf_counter() - t0)
        return out

    def _search_dispatch_impl(self, queries: np.ndarray, k: int, kk: int,
                              L: int, W: int,
                              fspec: Optional[FilterSpec] = None
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Serve ONE fixed-shape micro-batch (all query-count accounting
        already done by ``search_batch``)."""
        q = jnp.asarray(queries, jnp.float32)
        nq = queries.shape[0]
        rw_t, ro_temps, lti_entry = self._capture_lanes()
        if rw_t is None and not ro_temps and lti_entry is None:
            return self._aggregate([], k, nq)
        if self.cfg.batch_fanout:
            bundle = self._lane_bundle(rw_t, ro_temps, lti_entry)
            if bundle is not None:
                key, stack, t_tabs, l_tab, tables_np, label_tabs = bundle
                if fspec is None:
                    t_drop, l_drop = self._drop_mask(key, tables_np)
                else:
                    t_drop, l_drop = self._filter_drop(
                        key, tables_np, label_tabs, fspec)
                # rerank only matters to the PQ lane; with no LTI lane it
                # would be dead compute.
                do_rerank = self.cfg.rerank and lti_entry is not None
                if lti_entry is not None and self._shard_count():
                    step, sstack = self._sharded_program(
                        stack, k=k, kk=kk, L=L, W=W, rerank=do_rerank)
                    ids, d, _, _ = step(sstack, t_tabs, l_tab, t_drop,
                                        l_drop, q)
                else:
                    ids, d, _, _ = mem.unified_search(
                        stack, t_tabs, l_tab, t_drop, l_drop, q,
                        self.cfg.index, k=k, k_lane=kk, L=L, beam_width=W,
                        rerank=do_rerank)
                self.stats.search_dispatches += 1
                return (np.asarray(ids).astype(np.int64),
                        np.asarray(d).astype(np.float32))
        # Sequential oracle: one device program per tier + host aggregation.
        cands: list[tuple[np.ndarray, np.ndarray]] = []   # (ext_ids, dists)
        if lti_entry is not None:
            lti, lti_table = lti_entry[0], lti_entry[1]
            ids, d, _, _ = search_lti(lti, q, self.cfg.index, k=kk, L=L,
                                      beam_width=W, rerank=self.cfg.rerank)
            self.stats.search_dispatches += 1
            ids = np.asarray(ids)
            cands.append((self._map_ext(ids, lti_table),
                          self._slot_filter(ids, np.asarray(d),
                                            lti_entry[2], fspec)))
        for t in ([rw_t] if rw_t is not None else []) + ro_temps:
            ids, d, _, _ = mem.search(t.state, q, self.temp_cfg, k=kk,
                                      L=L, beam_width=W)
            self.stats.search_dispatches += 1
            ids = np.asarray(ids)
            cands.append((self._map_ext(ids, t.ext_ids),
                          self._slot_filter(ids, np.asarray(d),
                                            t.labels, fspec)))
        return self._aggregate(cands, k, nq)

    @staticmethod
    def _slot_filter(slot_ids: np.ndarray, dists: np.ndarray,
                     labels: Optional[LabelTable],
                     fspec: Optional[FilterSpec]) -> np.ndarray:
        """Host half of the filtered drop for the per-tier paths: inf-out
        candidates whose slot fails ``fspec`` — the same post-search point
        where ``lanes_to_ext`` applies the on-device mask, so the
        sequential oracle and the unified fan-out stay bit-identical with
        filters on.  A missing label table drops everything (a tier that
        never saw a labeled insert has no matching points)."""
        if fspec is None:
            return dists
        d = dists.copy()
        ok = slot_ids >= 0
        if labels is None:
            d[ok] = np.inf
            return d
        m = filter_match(labels, fspec)
        dead = np.zeros(slot_ids.shape, bool)
        dead[ok] = ~m[slot_ids[ok]]
        d[dead] = np.inf
        return d

    # ------------------------------------------------- sharded LTI lane
    @property
    def lti_shards(self) -> int:
        """Effective LTI-lane shard count: ``cfg.shard_lti`` capped at the
        device census (0 = unsharded).  Public mirror of the serving
        engine's routing decision — see docs/SERVING.md."""
        return self._shard_count()

    def _shard_count(self) -> int:
        n = self.cfg.shard_lti
        if n <= 0:
            return 0
        return min(n, len(jax.devices()))

    def _sharded_program(self, stack, *, k, kk, L, W, rerank):
        """(step, stack-with-sharded-LTI) for the mesh-sharded fan-out.

        Three caches: the 1-axis data mesh (per shard count), the
        ``graph.shard_lti`` placement (keyed by LTI graph/codes identity —
        a merge swaps them and misses), and the jitted step per static
        (k, kk, L, W, rerank) tuple.
        """
        from ..distributed.sharding import data_mesh
        from ..serving.steps import make_sharded_unified_step
        from .graph import LaneStack, shard_lti
        n = self._shard_count()
        if self._shard_mesh is None or self._shard_mesh_n != n:
            self._shard_mesh = data_mesh(n)
            self._shard_mesh_n = n
            self._shard_place = None
            self._shard_steps = {}
        place = self._shard_place
        if (place is None or place[0] is not stack.lti
                or place[1] is not stack.codes):
            sg, sc = shard_lti(stack.lti, stack.codes, n,
                               mesh=self._shard_mesh)
            place = (stack.lti, stack.codes, sg, sc)
            self._shard_place = place
        key = (k, kk, L, W, rerank)
        step = self._shard_steps.get(key)
        if step is None:
            step = make_sharded_unified_step(
                self._shard_mesh, self.cfg.index, k=k, k_lane=kk, L=L,
                beam_width=W, rerank=rerank)
            self._shard_steps[key] = step
        return step, LaneStack(stack.temps, place[2], place[3],
                               stack.codebook)

    def _beam_width(self, queries: np.ndarray) -> int:
        """Resolve W: autotuned (and cached until the next merge) or static."""
        if not self.cfg.autotune_beam:
            return self.cfg.index.beam_width
        if self._tuned_w is None:
            tuned = self._calibrate_beam(queries)
            if tuned is None:          # no representative tier yet: don't
                return self.cfg.index.beam_width   # cache the fallback
            self._tuned_w = tuned
        return self._tuned_w

    def _calibrate_beam(self, queries: np.ndarray) -> Optional[int]:
        """Probe the serving configuration at each candidate W; pick by
        hop/cmp cost.

        With ``batch_fanout`` the probe runs the SAME unified device program
        queries pay for, so the tuner costs what serving costs: per-query
        IO rounds are the max over lanes (lanes run concurrently, latency
        follows the slowest lane — the LTI in steady state) and distance
        computations are summed across lanes (total work).  Without it the
        probe falls back to the largest single tier, as before.

        Returns None when no tier is big enough for the hop/cmp profile to
        be representative (a handful of points terminates in 1-2 hops at
        any W) — the caller then keeps using the static width WITHOUT
        caching, so calibration re-runs once the index has grown.
        """
        L = self.cfg.index.L_search
        probe = jnp.asarray(queries[:8], jnp.float32)
        rw_t, ro_temps, lti_entry = self._capture_lanes()
        sizes = ([rw_t.n] if rw_t is not None else []) \
            + [t.n for t in ro_temps] \
            + ([int(lti_entry[0].graph.n_total)] if lti_entry else [])
        if not sizes or max(sizes) < L:
            return None
        run = None
        if self.cfg.batch_fanout:
            bundle = self._lane_bundle(rw_t, ro_temps, lti_entry)
            if bundle is not None:
                key, stack, t_tabs, l_tab, tables_np, _ = bundle
                t_drop, l_drop = self._drop_mask(key, tables_np)

                def run(W):
                    _, _, hops, cmps = mem.unified_search(
                        stack, t_tabs, l_tab, t_drop, l_drop, probe,
                        self.cfg.index, k=1, k_lane=1, L=L, beam_width=W,
                        rerank=self.cfg.rerank and lti_entry is not None)
                    return (np.asarray(hops).max(axis=0),
                            np.asarray(cmps).sum(axis=0))
        if run is None:
            lti = self._lti_pair[0]
            if int(lti.graph.n_total) >= L:
                def run(W):
                    _, _, hops, cmps = search_lti(lti, probe, self.cfg.index,
                                                  k=1, L=L, beam_width=W)
                    return hops, cmps
            elif self.rw.n >= L:
                def run(W):
                    _, _, hops, cmps = mem.search(self.rw.state, probe,
                                                  self.temp_cfg, k=1, L=L,
                                                  beam_width=W)
                    return hops, cmps
            else:
                return None
        points = autotune.measure_widths(run, self.cfg.beam_width_candidates)
        return autotune.pick_beam_width(points)

    # ------------------------------------------------------------- plumbing
    def _capture_lanes(self):
        """One consistent capture of every searchable tier.

        Capture order matters: RW before RO before LTI.  A concurrent
        rollover moves RW -> RO, and a concurrent merge moves RO -> LTI, so
        capturing each tier BEFORE its points' destination means an
        interleaved move lands the points in BOTH captures (the cross-tier
        dedupe resolves that) rather than in neither (a gap).
        """
        rw = self.rw                             # single read
        rw_t = rw if rw.n > 0 else None
        with self._ro_lock:
            ro_temps = [t for t in self.ro if t.n > 0]
        lti, lti_table, lti_labels = self._lti_pair  # one generation
        lti_entry = ((lti, lti_table, lti_labels)
                     if int(lti.graph.n_total) > 0 else None)
        return rw_t, ro_temps, lti_entry

    @staticmethod
    def _key_hits(cached_key, key) -> bool:
        return (cached_key is not None and len(cached_key) == len(key)
                and all(a is b for a, b in zip(cached_key, key)))

    @staticmethod
    def _fits_int32(a: np.ndarray) -> bool:
        return (a.max(initial=-1) <= np.iinfo(np.int32).max
                and a.min(initial=0) >= np.iinfo(np.int32).min)

    def _lane_bundle(self, rw_t, ro_temps, lti_entry):
        """(key, LaneStack, temp tables [Tt, temp_cap] device, LTI table
        [lti_cap] device, tables np) for the unified fan-out — cached by
        tier-state identity (states are immutable values: a flush /
        rollover / merge replaces them, which misses the cache).

        Temp lanes are padded to the largest TEMP capacity only; the LTI
        lane rides at its own capacity (the stack is O(Tt x temp_cap)
        instead of O(T x LTI_cap)).  External ids travel as int32 when they
        fit; with ``jax_enable_x64`` set they widen to int64 pairs instead,
        and only when neither holds does the system warn once and fall back
        to the sequential per-tier path (bundle None, verdict cached).

        Two cache levels: the full bundle (missed by any tier mutation),
        and a frozen sub-cache of the RO lanes' padded graphs, the RO + LTI
        table rows, and the id-range verdict — those only change on
        rollover/merge, so the RW flushes that dominate a steady-state
        insert+search stream re-pad and re-scan ONLY the RW lane (the
        final [Tt, ...] device stack is still rebuilt: that copy is what
        buys the single dispatch).
        """
        fp = ([rw_t] if rw_t is not None else []) + ro_temps
        key = tuple(t.state for t in fp) + (
            (lti_entry[0],) if lti_entry is not None else ())
        cached = self._fanout_cache
        if cached is not None and self._key_hits(cached[0], key):
            return cached[1]

        tcap = max((t.state.capacity for t in fp), default=0)

        fkey = (tuple(t.state for t in ro_temps)
                + ((lti_entry[0],) if lti_entry is not None else ()))
        fcached = self._frozen_cache
        if (fcached is not None and fcached[1] == tcap
                and self._key_hits(fcached[0], fkey)):
            ro_states, ro_tabs, froz_ok = fcached[2:]
        else:
            ro_states = [pad_graph(t.state, tcap) for t in ro_temps]
            ro_tabs = np.full((len(ro_temps), tcap), -1, np.int64)
            for fi, t in enumerate(ro_temps):
                ro_tabs[fi, :len(t.ext_ids)] = t.ext_ids
            froz_ok = self._fits_int32(ro_tabs) and (
                lti_entry is None or self._fits_int32(lti_entry[1]))
            self._frozen_cache = (fkey, tcap, ro_states, ro_tabs, froz_ok)

        n_rw = 1 if rw_t is not None else 0
        rw_tabs = np.full((n_rw, tcap), -1, np.int64)
        if n_rw:
            rw_tabs[0, :len(rw_t.ext_ids)] = rw_t.ext_ids
        temp_tabs_np = np.concatenate([rw_tabs, ro_tabs])
        lti_tab_np = lti_entry[1] if lti_entry is not None else None
        if froz_ok and self._fits_int32(rw_tabs):
            id_dtype = np.int32
        elif jax.config.jax_enable_x64:
            id_dtype = np.int64     # billion-scale id spaces ride as i64
        else:
            if not self._int32_warned:
                self._int32_warned = True
                import warnings
                warnings.warn(
                    "external ids exceed int32: the on-device unified "
                    "fan-out is disabled, searches use the sequential "
                    "per-tier path (enable jax_enable_x64 to carry ids "
                    "as int64 instead)")
            self._fanout_cache = (key, None)
            return None
        lanes = ([pad_graph(rw_t.state, tcap)] if n_rw else []) + ro_states
        lti_graph = codes = codebook = None
        if lti_entry is not None:
            lti_graph = lti_entry[0].graph
            codes = lti_entry[0].codes
            codebook = lti_entry[0].codebook.centroids
        stack = stack_lanes(lanes, lti=lti_graph, codes=codes,
                            codebook=codebook)
        t_tabs = (jnp.asarray(temp_tabs_np.astype(id_dtype))
                  if lanes else None)
        l_tab = (jnp.asarray(lti_tab_np.astype(id_dtype))
                 if lti_entry is not None else None)
        # Label tables ride the bundle lane-ordered ([RW?] + RO, LTI) so
        # the filtered drop-mask aligns with the stacked lanes.
        label_tabs = ([t.labels for t in fp],
                      lti_entry[2] if lti_entry is not None else None)
        bundle = (key, stack, t_tabs, l_tab, (temp_tabs_np, lti_tab_np),
                  label_tabs)
        self._fanout_cache = (key, bundle)
        return bundle

    def _drop_mask(self, key: tuple, tables_np: tuple):
        """Per-group [.., cap] bool DeleteList membership masks for the
        on-device filter — (temp [Tt, temp_cap], lti [lti_cap] or None).
        Cached by (lane key, delete epoch): tier mutations change the key;
        DeleteList mutations the states don't witness (delete of an LTI/RO
        resident, re-insert revival) bump ``_delete_epoch``."""
        epoch = self._delete_epoch
        cached = self._drop_cache
        if (cached is not None and cached[1] == epoch
                and self._key_hits(cached[0], key)):
            return cached[2]
        t_mask, l_mask = self._delete_masks_np(tables_np)
        drop = (jnp.asarray(t_mask) if t_mask.shape[0] else None,
                jnp.asarray(l_mask) if l_mask is not None else None)
        self._drop_cache = (key, epoch, drop)
        return drop

    def _delete_masks_np(self, tables_np: tuple
                         ) -> tuple[np.ndarray, Optional[np.ndarray]]:
        """Host-side DeleteList membership masks over the lane tables —
        the shared base of ``_drop_mask`` and ``_filter_drop``."""
        temp_np, lti_np = tables_np
        deleted = self.deleted_ext.copy()        # GIL-atomic vs bg merge
        if deleted:
            dl = np.fromiter(deleted, np.int64, len(deleted))
            t_mask = np.isin(temp_np, dl)
            l_mask = np.isin(lti_np, dl) if lti_np is not None else None
        else:
            t_mask = np.zeros(temp_np.shape, bool)
            l_mask = (np.zeros(lti_np.shape, bool)
                      if lti_np is not None else None)
        return t_mask, l_mask

    def _filter_drop(self, key: tuple, tables_np: tuple, label_tabs: tuple,
                     fspec: FilterSpec):
        """Filtered drop masks: the DeleteList base ORed with ``~match`` of
        ``fspec`` against each lane's label table — one extra AND per
        candidate at the same post-search point deletes already pay, so the
        beam search itself (hops/cmps) is untouched.  Cached per
        (lane key, delete epoch) as a dict of per-spec masks; any tier or
        DeleteList mutation retires the whole dict."""
        epoch = self._delete_epoch
        cached = self._filter_cache
        if (cached is not None and cached[1] == epoch
                and self._key_hits(cached[0], key)):
            specs = cached[2]
        else:
            specs = {}
            self._filter_cache = (key, epoch, specs)
        drop = specs.get(fspec)
        if drop is not None:
            return drop
        t_mask, l_mask = self._delete_masks_np(tables_np)
        temp_labels, lti_labels = label_tabs
        for i, lt in enumerate(temp_labels):
            if lt is None:              # no labels ever seen: nothing matches
                t_mask[i] = True
                continue
            m = filter_match(lt, fspec)
            t_mask[i, :m.size] |= ~m
            t_mask[i, m.size:] = True   # lane padding can't match
        if l_mask is not None:
            if lti_labels is None:
                l_mask[:] = True
            else:
                l_mask |= ~filter_match(lti_labels, fspec)
        drop = (jnp.asarray(t_mask) if t_mask.shape[0] else None,
                jnp.asarray(l_mask) if l_mask is not None else None)
        specs[fspec] = drop
        return drop

    def _new_temp(self) -> _Temp:
        return _Temp(empty_graph(self.temp_cfg),
                     np.full(self.cfg.temp_capacity, -1, np.int64),
                     labels=LabelTable(self.cfg.temp_capacity,
                                       self._n_label_words))

    def _map_ext(self, slot_ids: np.ndarray, table: np.ndarray) -> np.ndarray:
        out = np.full(slot_ids.shape, -1, np.int64)
        ok = slot_ids >= 0
        out[ok] = table[slot_ids[ok]]
        return out

    def _aggregate(self, cands, k, nq):
        if not cands:
            return (np.full((nq, k), -1, np.int64),
                    np.full((nq, k), np.inf, np.float32))
        ids = np.concatenate([c[0] for c in cands], axis=1)
        ds = np.concatenate([c[1] for c in cands], axis=1).astype(np.float32)
        # filter DeleteList + invalid lanes (vectorized; no python loops).
        # .copy() is atomic under the GIL — a concurrent background merge
        # (deleted_ext -= consumed) must not race the iteration below.
        deleted = self.deleted_ext.copy()
        bad = ids < 0
        if deleted:
            dl = np.fromiter(deleted, np.int64, len(deleted))
            bad |= np.isin(ids, dl)
        ds[bad] = np.inf
        # dedupe keeping the closest instance of each id (an id may
        # transiently exist in LTI and a TempIndex after re-insertion): sort
        # each row by (id, dist), mask all but the first copy of every id,
        # then rank by distance and slice k.
        order = np.lexsort((ds, ids), axis=1)
        sid = np.take_along_axis(ids, order, axis=1)
        sd = np.take_along_axis(ds, order, axis=1)
        dup = np.zeros_like(sid, bool)
        dup[:, 1:] = (sid[:, 1:] == sid[:, :-1]) & (sid[:, 1:] >= 0)
        sd[dup] = np.inf
        top = np.argsort(sd, axis=1, kind="stable")[:, :k]
        res_d = np.take_along_axis(sd, top, axis=1)
        res_i = np.where(np.isfinite(res_d),
                         np.take_along_axis(sid, top, axis=1), -1)
        res_d = np.where(np.isfinite(res_d), res_d, np.inf)
        if res_i.shape[1] < k:     # fewer candidates than k: pad, as before
            pad = k - res_i.shape[1]
            res_i = np.pad(res_i, ((0, 0), (0, pad)), constant_values=-1)
            res_d = np.pad(res_d, ((0, 0), (0, pad)),
                           constant_values=np.inf)
        return res_i.astype(np.int64), res_d.astype(np.float32)

    def _flush_inserts(self) -> None:
        """Land the insert buffer in the RW tier.

        Locking: the buffer swap is the only step under ``_insert_lock``;
        the device-side compute + publish run under ``_flush_lock`` alone
        (canonical order flush -> insert), so a flush in flight never
        blocks concurrent insert/delete/search bookkeeping.  The unlocked
        emptiness peek is benign: a concurrently appended point is landed
        by ITS OWN insert's flush (or the next rendezvous), and the swap
        re-checks under the lock.

        Delete-vs-flight invariant: a buffered id is never in
        ``deleted_ext`` (``insert`` revives at append time, ``delete``
        drops buffered copies), so the publish loop below must NOT touch
        the DeleteList — a ``delete`` issued while the flush is in flight
        lands in ``deleted_ext`` and has to STAY there, masking the row
        this flush publishes (tests/test_system.py pins it).
        """
        if not self._insert_buf_id:
            return
        with self._flush_lock:
            with self._insert_lock:
                ids = self._insert_buf_id
                vecs = self._insert_buf_v
                bits = self._insert_buf_bits
                tens = self._insert_buf_tenant
                if not ids:
                    return
                self._insert_buf_id, self._insert_buf_v = [], []
                self._insert_buf_bits, self._insert_buf_tenant = [], []
            t0 = time.perf_counter()
            self._flush_compute(ids, vecs, bits, tens)
            self.stats.flushes += 1
            self.stats.flush_latency.record(time.perf_counter() - t0)

    def _flush_compute(self, ids: list, vecs: list, bits: list,
                       tens: list) -> None:
        """Device-side flush of one drained buffer (caller holds
        ``_flush_lock``; ``_insert_lock`` must NOT be required here).

        With ``cfg.locality_order`` the whole drained buffer is
        proximity-ordered first (seeded per flush), then every chunk runs
        the split insert (``mem.insert_edges_stage`` +
        ``mem.insert_apply_delta``): cluster mates share search frontiers
        and their back-edge pairs collide onto few DISTINCT targets, so the
        Delta prune launches at a measured power-of-two bucket instead of
        the worst case.  Arrival order runs the same split with
        ``affected_cap=None`` — bit-identical to the historical fused
        ``mem.insert`` (tests/test_locality.py) — so the
        targets-vs-launched counters accumulate comparably either way.

        Publish order per chunk: ext-id rows BEFORE the state swap, so a
        search capturing ``t.state`` mid-flush never maps a live row
        through a stale -1 entry.
        """
        B = self.cfg.insert_batch
        if self.cfg.locality_order and len(ids) > 1:
            perm = np.asarray(locality_order(
                jnp.asarray(np.stack(vecs)),
                n_clusters=self.cfg.index.locality_clusters or 16,
                seed=self._flush_seq))
            ids = [ids[i] for i in perm]
            vecs = [vecs[i] for i in perm]
            bits = [bits[i] for i in perm]
            tens = [tens[i] for i in perm]
        self._flush_seq += 1
        t = self.rw
        for lo in range(0, len(ids), B):
            chunk_i = ids[lo:lo + B]
            chunk_v = vecs[lo:lo + B]
            chunk_b = bits[lo:lo + B]
            chunk_t = tens[lo:lo + B]
            slots = np.arange(t.n, t.n + len(chunk_i), dtype=np.int32)
            if t.n == 0:
                # Seed the empty temp graph: first point becomes the start.
                st = t.state
                v0 = jnp.asarray(chunk_v[0], st.vectors.dtype)
                t.ext_ids[0] = chunk_i[0]
                t.labels.set_row(0, chunk_b[0], chunk_t[0])
                t.state = st._replace(
                    vectors=st.vectors.at[0].set(v0),
                    active=st.active.at[0].set(True),
                    start=jnp.int32(0), n_total=jnp.int32(1))
                self._ext_loc[chunk_i[0]] = ("rw", 0)
                chunk_i, chunk_v, slots = chunk_i[1:], chunk_v[1:], slots[1:] + 0
                chunk_b, chunk_t = chunk_b[1:], chunk_t[1:]
                t.n = 1
                if not chunk_i:
                    continue
            pad = B - len(chunk_i)
            pslots = np.concatenate(
                [slots, np.full(pad, INVALID, np.int32)])
            pvecs = np.zeros((B, self.cfg.index.dim), np.float32)
            pvecs[:len(chunk_v)] = np.stack(chunk_v)
            st, pj, pp = mem.insert_edges_stage(
                t.state, jnp.asarray(pslots), jnp.asarray(pvecs),
                self.temp_cfg)
            pj_h = np.asarray(pj)
            d_c = int(np.unique(pj_h[pj_h >= 0]).size)
            self.stats.flush_backedge_targets += d_c
            if self.cfg.locality_order:
                if d_c:
                    bucket = next_bucket(
                        d_c, cap=min(pj_h.size, self.cfg.temp_capacity))
                    self.stats.flush_prune_rows += bucket
                    st = mem.insert_apply_delta(st, pj, pp, self.temp_cfg,
                                                affected_cap=bucket)
            else:
                self.stats.flush_prune_rows += min(
                    pj_h.size, self.cfg.temp_capacity)
                st = mem.insert_apply_delta(st, pj, pp, self.temp_cfg)
            for j, (s, e) in enumerate(zip(slots, chunk_i)):
                t.ext_ids[s] = e
                t.labels.set_row(int(s), chunk_b[j], chunk_t[j])
            t.state = st
            for s, e in zip(slots, chunk_i):
                self._ext_loc[e] = ("rw", int(s))
            t.n += len(chunk_i)

    def _maybe_rollover(self) -> None:
        # flush_lock first (canonical order): the freeze must observe a
        # quiesced flush path, or the RW tier could be swapped out from
        # under an in-flight flush's publish loop.
        with self._flush_lock, self._insert_lock:
            if self.rw.n >= self.cfg.ro_snapshot_points:
                self._flush_inserts()
                frozen = self.rw
                with self._ro_lock:
                    self.ro.append(frozen)
                self.rw = self._new_temp()
                # The frozen snapshot's points are now RO-resident: retag so
                # the location map always names the tier a point lives in.
                for slot in np.nonzero(frozen.ext_ids >= 0)[0]:
                    e = int(frozen.ext_ids[slot])
                    if self._ext_loc.get(e) == ("rw", int(slot)):
                        self._ext_loc[e] = ("ro", int(slot))
                self.stats.snapshots += 1
            # Points already being consumed by an in-flight background merge
            # do not count toward the next threshold (they still sit in
            # self.ro so searches see them, but a second merge must not
            # re-stage them).  Read the RO list and the in-flight count
            # together under _ro_lock — the merge updates them atomically
            # under the same lock, and tearing the pair here would see the
            # pre-trim list with a zeroed count and launch a spurious merge.
            with self._ro_lock:
                staged = sum(t.n for t in self.ro) - self._merge_inflight
        # The merge itself runs OUTSIDE the insert lock (a foreground merge
        # holding it would deadlock against a background merge's snapshot).
        if staged >= self.cfg.merge_threshold:
            # With background_merge the insert path never stalls on the
            # StreamingMerge (paper §5.3's "merge runs concurrently").
            self.merge(background=self.cfg.background_merge)

    # -------------------------------------------------------------- merging
    def merge(self, background: bool = False) -> None:
        """StreamingMerge the RO-TempIndex points + DeleteList into the LTI."""
        if background:
            if self._merge_thread and self._merge_thread.is_alive():
                return
            self._merge_thread = threading.Thread(target=self._merge_impl)
            self._merge_thread.start()
        else:
            self._merge_impl()

    def wait_merge(self) -> None:
        if self._merge_thread:
            self._merge_thread.join()

    def _merge_impl(self) -> None:
        with self._merge_lock:
            t0 = time.perf_counter()
            # Snapshot the RO list but KEEP it searchable while the merge
            # runs: its points leave self.ro only after the new LTI (which
            # contains them) has been swapped in, so a concurrent search
            # never observes a gap.  The brief window where a point exists in
            # both the new LTI and an RO tier is resolved by the cross-tier
            # dedupe in _aggregate.
            with self._ro_lock:
                ro = list(self.ro)
                self._merge_inflight = sum(t.n for t in ro)
            try:
                self._merge_body(ro, t0)
            finally:
                # A failed merge must not leave the in-flight count set, or
                # every future threshold check would under-count and no
                # merge would ever run again.
                self._merge_inflight = 0

    def _merge_body(self, ro: list, t0: float) -> None:
        staged = sum(t.n for t in ro)
        icfg = self.cfg.index
        # The pre-merge adjacency anchors the delta patch: the live layout
        # is in sync with it, so rows that survive the merge unchanged need
        # no disk write (storage.layout.patch_layout).
        old_adj = self.lti.graph.adjacency if self.cfg.storage_dir else None
        # Stage vectors + ids from the RO snapshots (skip re-deleted ones).
        del_snapshot = set(self.deleted_ext)
        vecs = np.zeros((max(staged, 1), icfg.dim), np.float32)
        exts = np.full(max(staged, 1), -1, np.int64)
        sbits = np.zeros((max(staged, 1), self._n_label_words), np.uint32)
        sten = np.full(max(staged, 1), NO_TENANT, np.int32)
        w = 0
        for t in ro:
            sl = np.nonzero(t.ext_ids >= 0)[0][:t.n]
            v = np.asarray(t.state.vectors)[sl]
            for s, row in zip(sl, v):
                e = int(t.ext_ids[s])
                if e in del_snapshot:
                    continue
                vecs[w], exts[w] = row, e
                if t.labels is not None:   # labels follow the point
                    sbits[w] = t.labels.bits[s]
                    sten[w] = t.labels.tenant[s]
                w += 1
        valid = np.zeros(max(staged, 1), bool)
        valid[:w] = True
        # Remove from the LTI: DeleteList members AND rows superseded by a
        # staged re-insert — after delete(e) + insert(e, v2), e's old LTI
        # row still holds the pre-delete vector; without this it would
        # survive the merge as a stale duplicate and searches could return
        # e ranked by the OLD vector.
        dmask = np.zeros(icfg.capacity, bool)
        lti_ids = self.lti_ext_ids
        if del_snapshot:
            dl = np.asarray(sorted(del_snapshot), np.int64)
            dmask[np.isin(lti_ids, dl)] = True
        if w:
            dmask[np.isin(lti_ids, exts[:w])] = True
        repair_mode = self._pick_repair_mode(dmask)
        new_lti, stats = streaming_merge(
            self.lti, jnp.asarray(vecs), jnp.asarray(valid),
            jnp.asarray(dmask), icfg, self.cfg.pq,
            insert_chunk=self.cfg.insert_batch, block=self.cfg.merge_block,
            repair_mode=repair_mode,
            # Locality merge (docs/ARCHITECTURE.md, "Update-path
            # locality"): seeded by the merge ordinal so every merge is
            # deterministic for its inputs yet successive merges don't
            # reuse one medoid sample.
            locality=self.cfg.locality_order,
            locality_seed=self.stats.merges)
        jax.block_until_ready(new_lti.graph.adjacency)
        self.stats.repair_cap_overflows += int(stats.repair_cap_overflows)
        self.stats.merge_backedge_targets += int(stats.n_backedge_targets)
        self.stats.merge_prune_rows += int(stats.n_prune_rows)
        if repair_mode == "local":
            self.stats.local_repairs += 1
        else:
            self.stats.global_repairs += 1
            self._force_global_repair = False  # the escalation is served
        # Rebuild the external-id table: deleted rows out, new rows in
        # (the merge reports the slot it assigned to each staged row).
        new_ids = self.lti_ext_ids.copy()
        for e in new_ids[dmask]:
            e = int(e)
            if e >= 0 and self._ext_loc.get(e, ("?",))[0] == "lti":
                del self._ext_loc[e]     # removed from the LTI this cycle
        new_ids[dmask] = -1
        # Labels follow the same deleted-rows-out / staged-rows-in rebuild
        # as the ext-id table, scattered at the merge-assigned slots.
        new_labels = self.lti_labels.copy()
        new_labels.clear_rows(dmask)
        slots = np.asarray(stats.slots)
        ok = valid & (slots >= 0)
        for i, (s, e) in zip(np.nonzero(ok)[0], zip(slots[ok], exts[ok])):
            new_ids[s] = e
            new_labels.bits[s] = sbits[i]
            new_labels.tenant[s] = sten[i]
            self._ext_loc[e] = ("lti", int(s))
        # One-shot generation swap (graph + ext table + labels together),
        # then retire exactly the RO snapshots this merge consumed —
        # anything appended by a concurrent rollover stays.
        self._lti_pair = (new_lti, new_ids, new_labels)
        with self._ro_lock:
            self.ro = self.ro[len(ro):]
            self._merge_inflight = 0
        self._tuned_w = None       # the graph changed: re-calibrate W
        self._fanout_cache = None  # retired RO stacks must not stay resident
        self._frozen_cache = None
        self._drop_cache = None
        self._filter_cache = None
        self._shard_place = None   # the old LTI's sharded copy likewise
        if self.cfg.storage_dir:
            # Delta-patch the live layout: only the adjacency rows this
            # merge rewrote touch topology.bin; surviving points' vector
            # bytes stay put (the DGAI decoupling win, measured in
            # storage_bytes_written).
            from .merge import adjacency_delta_mask
            self._sync_storage(
                adj_changed=np.asarray(adjacency_delta_mask(
                    old_adj, new_lti.graph.adjacency)))
        # A delete may leave the DeleteList only when NO copy of the id
        # survives the merge anywhere — LTI residents left via the dmask
        # pass and merged-RO residents were skipped at staging, but a
        # delete of a point still living in the RW tier (or an RO
        # snapshot that rolled over after this merge began, or the
        # insert buffer) must SURVIVE, or the live copy would be revived.
        alive = self._live_ext_ids()
        dl = np.fromiter(del_snapshot, np.int64, len(del_snapshot))
        self.deleted_ext -= set(dl[~np.isin(dl, alive)].tolist())
        self._delete_epoch += 1
        if self.wal:
            if self.cfg.snapshot_dir:
                # Durability invariant (§5.6): snapshot BEFORE truncate, so
                # snapshot + log-suffix always covers the full state.  One
                # _insert_lock hold makes the pair atomic against concurrent
                # WAL writers — a record logged between the snapshot and the
                # truncation would otherwise be durable nowhere.  Restart
                # goes THROUGH the live handle: truncating the file under an
                # open positional handle would leave a zero-hole at its
                # stale offset on the next append.  _flush_lock is taken
                # FIRST (canonical order: flush -> insert) because the
                # snapshot's own flush nests under it.
                with self._flush_lock, self._insert_lock:
                    self._save_locked(
                        os.path.join(self.cfg.snapshot_dir,
                                     f"merge_{self.stats.merges + 1}"))
                    self.wal.restart(self.stats.merges + 1)
            # else: keep the whole log — with no snapshot covering the
            # pre-merge records, truncating would lose them on crash.
        self.stats.merges += 1
        self.stats.merge_seconds += time.perf_counter() - t0
        self._probe_reachability(repair_mode)

    def _pick_repair_mode(self, dmask: np.ndarray) -> str:
        """Route the merge's Delete phase: the localized affected-set sweep
        when the LTI's delete rate is at or below
        ``cfg.local_repair_threshold`` (and no reachability escalation is
        pending), the global Algorithm-4 sweep otherwise.  Both produce
        bit-identical graphs — this picks wall-clock, not semantics."""
        if self._force_global_repair:
            return "global"
        if self.cfg.index.repair_mode == "local":
            return "local"     # explicit user routing wins below escalation
        thr = self.cfg.local_repair_threshold
        if thr <= 0:
            return "global"
        active = np.asarray(self.lti.graph.active)
        n_live = int(active.sum())
        n_del = int(np.count_nonzero(dmask & active))
        return "local" if n_del <= thr * max(n_live, 1) else "global"

    def _probe_reachability(self, repair_mode: str) -> None:
        """Sampled self-search probe of the LTI after a Delete phase; sets
        the ``unreachable_frac`` gauge and arms the global-sweep escalation
        when a localized repair left too many live points stranded.

        Escalation compares against a BASELINE — the estimate recorded
        after the last global sweep (or the first probe) — because a few
        percent of points are unreachable on a freshly built graph already
        (batched inserts whose back-edges all lost the prune); the monitor
        guards against *repair-induced* degradation on top of that."""
        n = self.cfg.reach_probe_samples
        if n <= 0:
            return
        lti = self._lti_pair[0]
        frac = unreachable_fraction(lti.graph, self.cfg.index, samples=n,
                                    seed=self.stats.reach_probes)
        self.stats.unreachable_frac = frac
        self.stats.reach_probes += 1
        if repair_mode != "local" or self._reach_baseline is None:
            self._reach_baseline = frac
        elif frac > self._reach_baseline + self.cfg.reach_escalate_frac:
            self.stats.repair_escalations += 1
            self._force_global_repair = True

    def consolidate(self, mode: str = "local") -> int:
        """Standalone Algorithm 4 on the LTI — repair DeleteList residents
        without waiting for (or paying) a full StreamingMerge.

        The localized default makes this cheap at low delete rates: only
        the affected rows (plus the reclaimed deleted rows) change, and
        when ``cfg.storage_dir`` is set exactly that affected-union-deleted
        row set is delta-patched into the on-disk layout.  Returns the
        number of LTI points consolidated away.  Ids whose only copy was
        the LTI leave the DeleteList; copies in temp tiers keep their
        delete pending, exactly as a merge would."""
        from .delete import affected_mask, consolidate_deletes

        with self._merge_lock:
            icfg = self.cfg.index
            lti, table, labels = self._lti_pair
            del_snapshot = set(self.deleted_ext)
            dmask = np.zeros(icfg.capacity, bool)
            if del_snapshot:
                dl = np.asarray(sorted(del_snapshot), np.int64)
                dmask[np.isin(self.lti_ext_ids, dl)] = True
            dmask &= np.asarray(lti.graph.active)
            n_del = int(dmask.sum())
            if n_del == 0:
                return 0
            g = lti.graph
            g = g._replace(deleted=g.deleted | jnp.asarray(dmask))
            # The changed-row set is known a priori: affected rows get
            # repaired, deleted rows get cleared.  It anchors the storage
            # delta patch below — no post-hoc row compare needed.
            changed = np.asarray(affected_mask(
                g.adjacency, g.deleted, g.active & ~g.deleted)) | dmask
            decoded = pqm.decode(
                lti.codebook, lti.codes, self.cfg.pq).astype(jnp.float32)
            new_g = consolidate_deletes(g, icfg, block=self.cfg.merge_block,
                                        prune_table=decoded, mode=mode)
            jax.block_until_ready(new_g.adjacency)
            if mode == "local":
                self.stats.local_repairs += 1
            else:
                self.stats.global_repairs += 1
                self._force_global_repair = False
            # Retire the consolidated rows from the ext table, swap the
            # (LTI, table) pair as one generation, drop derived caches.
            new_ids = table.copy()
            for e in new_ids[dmask]:
                e = int(e)
                if e >= 0 and self._ext_loc.get(e, ("?",))[0] == "lti":
                    del self._ext_loc[e]
            new_ids[dmask] = -1
            new_labels = labels.copy()
            new_labels.clear_rows(dmask)
            self._lti_pair = (LTIState(new_g, lti.codes, lti.codebook),
                              new_ids, new_labels)
            self._tuned_w = None
            self._fanout_cache = None
            self._drop_cache = None
            self._filter_cache = None
            self._shard_place = None
            if self.cfg.storage_dir:
                self._sync_storage(adj_changed=changed)
            alive = self._live_ext_ids()
            dl = np.fromiter(del_snapshot, np.int64, len(del_snapshot))
            self.deleted_ext -= set(dl[~np.isin(dl, alive)].tolist())
            self._delete_epoch += 1
            self.stats.consolidations += 1
            self._probe_reachability(mode)
            return n_del

    # ------------------------------------------------------- storage tier
    def _storage_path(self) -> str:
        return os.path.join(self.cfg.storage_dir, "lti")

    def _sync_storage(self, adj_changed: Optional[np.ndarray] = None) -> None:
        """Mirror the live (LTI, ext-table) pair to the decoupled layout at
        ``cfg.storage_dir`` — a full write the first time, a DGAI-style
        delta patch afterwards (``adj_changed`` from the merge's device-side
        row compare when available).  Any open disk searcher is closed
        first: its in-memory header tables would go stale."""
        from ..storage import layout as slay
        self.close_storage()
        path = self._storage_path()
        os.makedirs(self.cfg.storage_dir, exist_ok=True)
        lti, table, labels = self._lti_pair
        if slay.is_layout(path):
            ps = slay.patch_layout(path, lti.graph, codes=lti.codes,
                                   ext_ids=table, adj_changed=adj_changed,
                                   label_bits=labels.bits,
                                   label_tenant=labels.tenant)
            self.stats.storage_rows_patched += ps.adj_rows
            self.stats.storage_blocks_patched += ps.adj_blocks
            self.stats.storage_bytes_written += ps.bytes_written
        else:
            lay = slay.write_layout(path, lti.graph, codes=lti.codes,
                                    codebook=lti.codebook, ext_ids=table,
                                    label_bits=labels.bits,
                                    label_tenant=labels.tenant)
            self.stats.storage_bytes_written += (
                lay.capacity * (lay.row_bytes + lay.dim * 4 + lay.m))
            lay.close()

    def _disk_searcher_get(self):
        """The cached ``DiskLTISearcher`` over the live layout (reopened
        after every sync, so it always serves the current generation)."""
        if self._disk_searcher is None:
            from ..storage import DiskLTISearcher, open_layout
            self._disk_searcher = DiskLTISearcher(
                open_layout(self._storage_path()), self.cfg.index,
                cache_mb=self.cfg.adjacency_cache_mb,
                prefetch_depth=self.cfg.prefetch_depth,
                latency_us=self.cfg.io_latency_us)
        return self._disk_searcher

    def close_storage(self) -> None:
        """Stop the prefetch thread and drop the layout mmaps (no-op when
        no disk searcher is open)."""
        if self._disk_searcher is not None:
            s, self._disk_searcher = self._disk_searcher, None
            s.close()
            s.layout.close()

    def search_disk(self, queries: np.ndarray, k: int,
                    L: Optional[int] = None,
                    beam_width: Optional[int] = None,
                    filter: Optional[FilterSpec] = None
                    ) -> tuple[np.ndarray, np.ndarray]:
        """The §5.2 fan-out with the LTI lane served OFF THE LAYOUT: PQ
        navigation on in-memory codes, adjacency rows streamed from
        ``topology.bin`` through the block cache + prefetch pipeline
        (``cfg.prefetch_depth`` / ``cfg.adjacency_cache_mb``), exact rerank
        from ``data.bin``.  Temp tiers are memory-resident by design (the
        paper's RW/RO TempIndices) and ride the sequential per-tier loop.

        With the cache off this returns bit-identical (ids, dists) to
        ``search_batch`` with ``batch_fanout=False``; reader IO deltas are
        folded into ``SystemStats`` (io_rows_read / io_cache_hits /
        io_prefetch_hits / io_bytes_read) after every call.
        """
        if not self.cfg.storage_dir:
            raise ValueError("search_disk needs SystemConfig.storage_dir")
        self._flush_inserts()
        fspec = filter if filter is not None and not filter.is_empty \
            else None
        L = L or self.cfg.index.L_search
        if k > L:
            raise ValueError(f"search(k={k}, L={L}): k must be <= L")
        W = beam_width or self.cfg.index.beam_width
        kk = min(max(k * 2, k + 8), L)
        q = np.asarray(queries, np.float32)
        B = q.shape[0]
        self.stats.searches += B
        if fspec is not None:
            self.stats.filtered_searches += B
            if fspec.tenant is not None:
                self.stats.tenant_searches[fspec.tenant] = (
                    self.stats.tenant_searches.get(fspec.tenant, 0) + B)
        if B == 0:
            return (np.zeros((0, k), np.int64),
                    np.zeros((0, k), np.float32))
        rw_t, ro_temps, lti_entry = self._capture_lanes()
        cands: list[tuple[np.ndarray, np.ndarray]] = []
        if lti_entry is not None:
            s = self._disk_searcher_get()
            before = s.stats.snapshot()
            ids, d, _, _, _ = s.search(q, k=kk, L=L, beam_width=W,
                                       rerank=self.cfg.rerank)
            # Dispatch is async — materialize before snapshotting, or the
            # IO counters are read mid-flight and the fold undercounts.
            ids, d = np.asarray(ids), np.asarray(d)
            self.stats.search_dispatches += 1
            after = s.stats.snapshot()

            def delta(key):
                return after[key] - before[key]

            self.stats.io_rows_read += (delta("demand_reads")
                                        + delta("prefetch_hits"))
            self.stats.io_cache_hits += delta("cache_hits")
            self.stats.io_prefetch_hits += delta("prefetch_hits")
            self.stats.io_bytes_read += delta("bytes_read")
            # Filter against the LAYOUT's own label side tables (the
            # generation this lane searched), not the live in-memory pair.
            lay_labels = None
            if s.layout.label_tenant is not None:
                lay_labels = LabelTable(
                    s.layout.capacity,
                    0 if s.layout.label_bits is None
                    else s.layout.label_bits.shape[1],
                    s.layout.label_bits, s.layout.label_tenant)
            cands.append((self._map_ext(ids, s.layout.ext_ids),
                          self._slot_filter(ids, d, lay_labels, fspec)))
        for t in ([rw_t] if rw_t is not None else []) + ro_temps:
            ids, d, _, _ = mem.search(t.state, q, self.temp_cfg, k=kk,
                                      L=L, beam_width=W)
            self.stats.search_dispatches += 1
            ids = np.asarray(ids)
            cands.append((self._map_ext(ids, t.ext_ids),
                          self._slot_filter(ids, np.asarray(d),
                                            t.labels, fspec)))
        return self._aggregate(cands, k, B)

    # ------------------------------------------------------------ snapshots
    def save(self, path: str) -> None:
        # Freeze the whole update path while we snapshot: flush first
        # (canonical order) so no flush is in flight, then the buffer/RW
        # bookkeeping.
        with self._flush_lock, self._insert_lock:
            self._save_locked(path)

    def _save_locked(self, path: str) -> None:
        # Caller holds _flush_lock + _insert_lock; both are RLocks, so the
        # nested flush re-enters them.
        self._flush_inserts()  # buffered inserts must land in temps
        os.makedirs(path, exist_ok=True)
        if self.cfg.storage_dir:
            # Decoupled snapshot: the LTI lands as a storage layout
            # (topology.bin + data.bin + side tables) instead of a
            # monolithic npz — the same files the live tier serves from,
            # so recovery reopens it with zero format conversion.
            from ..storage.layout import write_layout
            lay = write_layout(os.path.join(path, "layout"),
                               self.lti.graph, codes=self.lti.codes,
                               codebook=self.lti.codebook,
                               ext_ids=self.lti_ext_ids,
                               generation=self.stats.merges,
                               label_bits=self.lti_labels.bits,
                               label_tenant=self.lti_labels.tenant)
            lay.close()
        else:
            np.savez_compressed(
                os.path.join(path, "lti.npz"),
                **{f"g_{k}": np.asarray(v) for k, v in
                   self.lti.graph._asdict().items()},
                codes=np.asarray(self.lti.codes),
                centroids=np.asarray(self.lti.codebook.centroids),
                ext_ids=self.lti_ext_ids,
                label_bits=self.lti_labels.bits,
                label_tenant=self.lti_labels.tenant)
        # Temp entries are 5-tuples since labels landed; load() still
        # accepts the historical 3-tuples (label-free snapshots).
        ro_blob = [(t.state, t.ext_ids, t.n, t.labels)
                   for t in self.ro + [self.rw]]
        with open(os.path.join(path, "temps.pkl"), "wb") as f:
            pickle.dump([(jax.tree.map(np.asarray, s), e, n,
                          None if lb is None else lb.bits,
                          None if lb is None else lb.tenant)
                         for s, e, n, lb in ro_blob], f)
        # Record how much of the WAL (and which log epoch) this snapshot
        # already covers, so recovery replays only the suffix (no
        # double-apply).
        wal_offset = wal_epoch = None
        if self.wal and os.path.exists(self.wal.path):
            wal_offset = os.path.getsize(self.wal.path)
            wal_epoch = log_epoch(self.wal.path)
        with open(os.path.join(path, "meta.pkl"), "wb") as f:
            pickle.dump({"deleted": self.deleted_ext, "cfg": self.cfg,
                         "wal_offset": wal_offset,
                         "wal_epoch": wal_epoch}, f)

    @classmethod
    def load(cls, path: str, cfg: SystemConfig) -> "FreshDiskANN":
        from ..storage.layout import is_layout, open_layout
        lay_path = os.path.join(path, "layout")
        lti_label_bits = lti_label_tenant = None
        if is_layout(lay_path):
            # Decoupled snapshot (saved with cfg.storage_dir set): the LTI
            # comes back from the layout files; construction re-syncs the
            # live layout under the new storage_dir.
            lay = open_layout(lay_path)
            lti = lay.lti_state()
            ext_ids = lay.ext_ids.copy()
            lti_label_bits = lay.label_bits
            lti_label_tenant = lay.label_tenant
            lay.close()
        else:
            z = np.load(os.path.join(path, "lti.npz"))
            g = GraphState(*[jnp.asarray(z[f"g_{k}"])
                             for k in GraphState._fields])
            lti = LTIState(g, jnp.asarray(z["codes"]),
                           pqm.PQCodebook(jnp.asarray(z["centroids"])))
            ext_ids = z["ext_ids"].copy()
            if "label_tenant" in z.files:   # label-free snapshots lack these
                lti_label_bits = z["label_bits"]
                lti_label_tenant = z["label_tenant"]
        sys = cls(cfg, lti=lti, lti_ext_ids=ext_ids)
        if lti_label_tenant is not None:
            lb = sys.lti_labels
            lb.tenant[:] = lti_label_tenant
            if lti_label_bits is not None and lti_label_bits.size:
                w = min(lb.n_words, lti_label_bits.shape[1])
                lb.bits[:, :w] = lti_label_bits[:, :w]
        with open(os.path.join(path, "temps.pkl"), "rb") as f:
            temps = pickle.load(f)
        for i, entry in enumerate(temps):
            s, e, n = entry[:3]
            t = _Temp(GraphState(*[jnp.asarray(x) for x in s]), e.copy(), n,
                      labels=LabelTable(len(e), cfg.filter_words))
            if len(entry) >= 5 and entry[4] is not None:
                t.labels.tenant[:] = entry[4]
                if entry[3] is not None and entry[3].size:
                    w = min(t.labels.n_words, entry[3].shape[1])
                    t.labels.bits[:, :w] = entry[3][:, :w]
            # Last snapshot entry is the RW index, earlier ones are frozen RO
            # snapshots — tag them apart, matching the live-system tags.
            is_rw = i == len(temps) - 1
            if is_rw:
                sys.rw = t
            else:
                sys.ro.append(t)
            tag = "rw" if is_rw else "ro"
            for slot, ext in enumerate(e):
                if ext >= 0:
                    sys._ext_loc[int(ext)] = (tag, slot)
        with open(os.path.join(path, "meta.pkl"), "rb") as f:
            meta = pickle.load(f)
        sys.deleted_ext = set(meta["deleted"])
        sys._wal_offset = meta.get("wal_offset")
        sys._wal_epoch = meta.get("wal_epoch")
        return sys

    def latest_snapshot(self) -> Optional[str]:
        """The most recent merge snapshot under ``cfg.snapshot_dir``."""
        d = self.cfg.snapshot_dir
        if not d or not os.path.isdir(d):
            return None
        snaps = [s for s in os.listdir(d) if s.startswith("merge_")]
        if not snaps:
            return None
        return os.path.join(d, max(snaps, key=lambda s: int(s.split("_")[1])))

    def recover(self, snapshot_path: Optional[str] = None) -> int:
        """Crash recovery (§5.6): restore the latest snapshot (when given,
        else the newest merge snapshot under ``cfg.snapshot_dir``), then
        replay the WAL over it.  Returns the number of records replayed."""
        start = None
        if snapshot_path is None:
            snapshot_path = self.latest_snapshot()
        if snapshot_path:
            restored = FreshDiskANN.load(snapshot_path, self.cfg)
            if restored.wal:              # keep only our own WAL handle open
                restored.wal.close()
            self.lti = restored.lti
            self.lti_ext_ids = restored.lti_ext_ids
            self.lti_labels = restored.lti_labels
            self.rw = restored.rw
            self.ro = restored.ro
            self.deleted_ext = restored.deleted_ext
            self._ext_loc = restored._ext_loc
            self._insert_buf_v, self._insert_buf_id = [], []
            # The restored instance's construction already re-synced the
            # live layout under cfg.storage_dir; drop any searcher still
            # open over the pre-crash generation so the next search_disk
            # reopens against the restored one.
            self.close_storage()
            start = restored._wal_offset
            epoch = restored._wal_epoch
        n = 0
        wal_path = self.wal.path if self.wal else None
        if wal_path and os.path.exists(wal_path):
            # Replay only the suffix the snapshot doesn't already cover.  If
            # the log epoch changed since the snapshot (post-merge truncate)
            # everything in the current log postdates it: replay all of it.
            if start is not None and (start > os.path.getsize(wal_path)
                                      or epoch != log_epoch(wal_path)):
                start = None
            # Materialize before applying, and suppress re-logging while we
            # replay: the records are already in the log, and appending to
            # the file being iterated would never reach EOF.
            records = list(replay(wal_path, start))
            wal, self.wal = self.wal, None
            try:
                from .graph import unpack_labels
                from .wal import OP_DELETE, OP_INSERT
                for op, ext_id, vec in records:
                    if op == OP_INSERT:
                        self.insert(ext_id, vec)
                    elif op == OP_DELETE:
                        self.delete(ext_id)
                    else:       # labeled insert: (vec, tenant, bits)
                        self.insert(
                            ext_id, vec.vec,
                            labels=unpack_labels(vec.bits),
                            tenant=(None if vec.tenant == NO_TENANT
                                    else vec.tenant))
                    n += 1
                self._flush_inserts()
            finally:
                self.wal = wal
        return n

    # -------------------------------------------------------------- helpers
    @property
    def size(self) -> int:
        """Number of DISTINCT live external ids.

        Counts ids, not copies: after a delete + re-insert an id may
        transiently exist in the LTI *and* a TempIndex (or twice in one
        tier) until a merge retires the stale copy — searches dedupe those,
        and so does this accounting.
        """
        uniq = self._live_ext_ids()
        # .copy() is atomic under the GIL — a background merge shrinking the
        # set between len() and iteration would otherwise break fromiter.
        deleted = self.deleted_ext.copy()
        if not deleted:
            return len(uniq)
        dl = np.fromiter(deleted, np.int64, len(deleted))
        return int(len(uniq) - np.isin(uniq, dl).sum())

    def _live_ext_ids(self) -> np.ndarray:
        """Sorted unique external ids with a copy in ANY tier or the insert
        buffer (before DeleteList filtering).  Shared by ``size`` and the
        merge's delete-retirement check so the two always agree.  Stays in
        numpy end to end — no per-id Python object churn at scale."""
        parts = [self.lti_ext_ids] + [t.ext_ids for t in [self.rw] + self.ro]
        buf = list(self._insert_buf_id)      # atomic snapshot vs. inserts
        if buf:                              # not yet flushed to the RW index
            parts.append(np.asarray(buf, np.int64))
        arr = np.concatenate(parts)
        return np.unique(arr[arr >= 0])


def bootstrap_system(vectors: np.ndarray, ext_ids: np.ndarray,
                     cfg: SystemConfig, labels=None, tenants=None,
                     **build_kw) -> FreshDiskANN:
    """Build the initial static LTI (paper: start from a DiskANN build).

    ``labels`` (per-point iterables of label bit indices) and ``tenants``
    (per-point tenant ids) optionally tag the bootstrap points — the build
    assigns slots densely in input order, so row i's labels land in slot i.
    """
    lti = build_lti(vectors, cfg.index, cfg.pq, **build_kw)
    table = np.full(cfg.index.capacity, -1, np.int64)
    table[:len(ext_ids)] = ext_ids
    sys = FreshDiskANN(cfg, lti=lti, lti_ext_ids=table)
    if labels is not None:
        lb = sys.lti_labels
        for i, ls in enumerate(labels):
            lb.bits[i] = pack_labels(ls, lb.n_words)
    if tenants is not None:
        sys.lti_labels.tenant[:len(tenants)] = np.asarray(tenants, np.int32)
    return sys
