"""The FreshDiskANN system (paper §5): LTI + RW/RO-TempIndex + DeleteList +
WAL, with the StreamingMerge cycle and optional background merging.

JAX's functional state makes the paper's trickiest concurrency concern —
searching while a merge is underway — safe by construction: a merge produces a
*new* LTI value while searches keep reading the old immutable arrays; the swap
is a single reference assignment (the paper needs careful SSD double-buffering
for the same effect).

External ids are user-provided int64s; the system maps them to (tier, slot).
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import index as mem
from . import pq as pqm
from .config import IndexConfig, PQConfig, SystemConfig
from .distance import INVALID
from .graph import GraphState, empty_graph
from .lti import LTIState, build_lti, search_lti
from .merge import streaming_merge
from .wal import WriteAheadLog, log_epoch, replay, truncate


@dataclass
class _Temp:
    """One TempIndex instance + its slot<->external-id maps."""
    state: GraphState
    ext_ids: np.ndarray           # [capacity] int64, -1 free
    n: int = 0


LATENCY_RESERVOIR = 1024


@dataclass
class SystemStats:
    inserts: int = 0
    deletes: int = 0
    searches: int = 0
    merges: int = 0
    snapshots: int = 0
    merge_seconds: float = 0.0
    # Fixed-size reservoir (Vitter's algorithm R) — a uniform sample of all
    # insert latencies in O(LATENCY_RESERVOIR) memory, however long we run.
    insert_latencies: list = field(default_factory=list)
    latencies_seen: int = 0
    _lat_rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0), repr=False)

    def record_latency(self, seconds: float) -> None:
        self.latencies_seen += 1
        if len(self.insert_latencies) < LATENCY_RESERVOIR:
            self.insert_latencies.append(seconds)
        else:
            j = int(self._lat_rng.integers(self.latencies_seen))
            if j < LATENCY_RESERVOIR:
                self.insert_latencies[j] = seconds


class FreshDiskANN:
    def __init__(self, cfg: SystemConfig, lti: Optional[LTIState] = None,
                 lti_ext_ids: Optional[np.ndarray] = None):
        self.cfg = cfg
        icfg = cfg.index
        self.temp_cfg = IndexConfig(
            capacity=cfg.temp_capacity, dim=icfg.dim, R=icfg.R,
            L_build=icfg.L_build, L_search=icfg.L_search, alpha=icfg.alpha,
            beam_width=icfg.beam_width, use_kernel=icfg.use_kernel)
        if lti is None:
            g = empty_graph(icfg)
            cb = pqm.PQCodebook(jnp.zeros(
                (cfg.pq.m, cfg.pq.ksub, cfg.pq.dsub), jnp.float32))
            lti = LTIState(g, jnp.zeros((icfg.capacity, cfg.pq.m), jnp.uint8), cb)
        self.lti = lti
        self.lti_ext_ids = (lti_ext_ids if lti_ext_ids is not None
                            else np.full(icfg.capacity, -1, np.int64))
        self.rw = self._new_temp()
        self.ro: list[_Temp] = []
        self.deleted_ext: set[int] = set()
        self._ext_loc: dict[int, tuple] = {}
        if lti_ext_ids is not None:
            for slot, e in enumerate(lti_ext_ids):
                if e >= 0:
                    self._ext_loc[int(e)] = ("lti", slot)
        self._insert_buf_v: list[np.ndarray] = []
        self._insert_buf_id: list[int] = []
        self._wal_offset: Optional[int] = None  # WAL bytes a snapshot covers
        self._wal_epoch: Optional[int] = None   # ... and of which log epoch
        self.stats = SystemStats()
        self._merge_lock = threading.Lock()
        self._merge_thread: Optional[threading.Thread] = None
        self.wal: Optional[WriteAheadLog] = None
        if cfg.wal_dir:
            os.makedirs(cfg.wal_dir, exist_ok=True)
            self.wal = WriteAheadLog(
                os.path.join(cfg.wal_dir, "wal.bin"), icfg.dim)

    # ------------------------------------------------------------------ API
    def insert(self, ext_id: int, vec: np.ndarray) -> None:
        """Route to the RW-TempIndex (paper §5.2); batched flush."""
        t0 = time.perf_counter()
        if self.wal:
            self.wal.log_insert(ext_id, vec)
        self._insert_buf_id.append(int(ext_id))
        self._insert_buf_v.append(np.asarray(vec, np.float32))
        if len(self._insert_buf_id) >= self.cfg.insert_batch:
            self._flush_inserts()
        self.stats.inserts += 1
        self.stats.record_latency(time.perf_counter() - t0)
        self._maybe_rollover()

    def delete(self, ext_id: int) -> None:
        """DeleteList append — O(1), no graph edits (paper §4.2)."""
        if self.wal:
            self.wal.log_delete(ext_id)
        self.deleted_ext.add(int(ext_id))
        self.stats.deletes += 1

    def search(self, queries: np.ndarray, k: int, L: Optional[int] = None,
               beam_width: Optional[int] = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """Query LTI + every TempIndex, aggregate, filter DeleteList (§5.2).

        ``beam_width`` overrides the configured W for every per-tier search
        in the fan-out (LTI and all TempIndices alike).
        """
        self._flush_inserts()
        L = L or self.cfg.index.L_search
        W = beam_width or self.cfg.index.beam_width
        q = jnp.asarray(queries, jnp.float32)
        cands: list[tuple[np.ndarray, np.ndarray]] = []   # (ext_ids, dists)
        # Over-fetch so DeleteList filtering + cross-tier dedupe still leave k.
        kk = min(max(k * 2, k + 8), L)
        if int(self.lti.graph.n_total) > 0:
            ids, d, _, _ = search_lti(self.lti, q, self.cfg.index, k=kk, L=L,
                                      beam_width=W)
            cands.append((self._map_ext(np.asarray(ids), self.lti_ext_ids),
                          np.asarray(d)))
        for t in [self.rw] + self.ro:
            if t.n > 0:
                ids, d, _, _ = mem.search(t.state, q, self.temp_cfg, k=kk,
                                          L=L, beam_width=W)
                cands.append((self._map_ext(np.asarray(ids), t.ext_ids),
                              np.asarray(d)))
        self.stats.searches += len(queries)
        return self._aggregate(cands, k, queries.shape[0])

    # ------------------------------------------------------------- plumbing
    def _new_temp(self) -> _Temp:
        return _Temp(empty_graph(self.temp_cfg),
                     np.full(self.cfg.temp_capacity, -1, np.int64))

    def _map_ext(self, slot_ids: np.ndarray, table: np.ndarray) -> np.ndarray:
        out = np.full(slot_ids.shape, -1, np.int64)
        ok = slot_ids >= 0
        out[ok] = table[slot_ids[ok]]
        return out

    def _aggregate(self, cands, k, nq):
        if not cands:
            return (np.full((nq, k), -1, np.int64),
                    np.full((nq, k), np.inf, np.float32))
        ids = np.concatenate([c[0] for c in cands], axis=1)
        ds = np.concatenate([c[1] for c in cands], axis=1).astype(np.float32)
        # filter DeleteList + invalid lanes (vectorized; no python loops).
        # .copy() is atomic under the GIL — a concurrent background merge
        # (deleted_ext -= consumed) must not race the iteration below.
        deleted = self.deleted_ext.copy()
        bad = ids < 0
        if deleted:
            dl = np.fromiter(deleted, np.int64, len(deleted))
            bad |= np.isin(ids, dl)
        ds[bad] = np.inf
        # dedupe keeping the closest instance of each id (an id may
        # transiently exist in LTI and a TempIndex after re-insertion): sort
        # each row by (id, dist), mask all but the first copy of every id,
        # then rank by distance and slice k.
        order = np.lexsort((ds, ids), axis=1)
        sid = np.take_along_axis(ids, order, axis=1)
        sd = np.take_along_axis(ds, order, axis=1)
        dup = np.zeros_like(sid, bool)
        dup[:, 1:] = (sid[:, 1:] == sid[:, :-1]) & (sid[:, 1:] >= 0)
        sd[dup] = np.inf
        top = np.argsort(sd, axis=1, kind="stable")[:, :k]
        res_d = np.take_along_axis(sd, top, axis=1)
        res_i = np.where(np.isfinite(res_d),
                         np.take_along_axis(sid, top, axis=1), -1)
        res_d = np.where(np.isfinite(res_d), res_d, np.inf)
        if res_i.shape[1] < k:     # fewer candidates than k: pad, as before
            pad = k - res_i.shape[1]
            res_i = np.pad(res_i, ((0, 0), (0, pad)), constant_values=-1)
            res_d = np.pad(res_d, ((0, 0), (0, pad)),
                           constant_values=np.inf)
        return res_i.astype(np.int64), res_d.astype(np.float32)

    def _flush_inserts(self) -> None:
        if not self._insert_buf_id:
            return
        B = self.cfg.insert_batch
        ids = self._insert_buf_id
        vecs = self._insert_buf_v
        self._insert_buf_id, self._insert_buf_v = [], []
        t = self.rw
        for lo in range(0, len(ids), B):
            chunk_i = ids[lo:lo + B]
            chunk_v = vecs[lo:lo + B]
            slots = np.arange(t.n, t.n + len(chunk_i), dtype=np.int32)
            if t.n == 0:
                # Seed the empty temp graph: first point becomes the start.
                st = t.state
                v0 = jnp.asarray(chunk_v[0], st.vectors.dtype)
                t.state = st._replace(
                    vectors=st.vectors.at[0].set(v0),
                    active=st.active.at[0].set(True),
                    start=jnp.int32(0), n_total=jnp.int32(1))
                t.ext_ids[0] = chunk_i[0]
                self._ext_loc[chunk_i[0]] = ("rw", 0)
                self.deleted_ext.discard(chunk_i[0])
                chunk_i, chunk_v, slots = chunk_i[1:], chunk_v[1:], slots[1:] + 0
                t.n = 1
                if not chunk_i:
                    continue
            pad = B - len(chunk_i)
            pslots = np.concatenate(
                [slots, np.full(pad, INVALID, np.int32)])
            pvecs = np.zeros((B, self.cfg.index.dim), np.float32)
            pvecs[:len(chunk_v)] = np.stack(chunk_v)
            t.state = mem.insert(t.state, jnp.asarray(pslots),
                                 jnp.asarray(pvecs), self.temp_cfg)
            for s, e in zip(slots, chunk_i):
                t.ext_ids[s] = e
                self._ext_loc[e] = ("rw", int(s))
                self.deleted_ext.discard(e)  # re-insert revives the id
            t.n += len(chunk_i)

    def _maybe_rollover(self) -> None:
        if self.rw.n >= self.cfg.ro_snapshot_points:
            self._flush_inserts()
            frozen = self.rw
            self.ro.append(frozen)
            self.rw = self._new_temp()
            # The frozen snapshot's points are now RO-resident: retag so the
            # location map always names the tier a point actually lives in.
            for slot in np.nonzero(frozen.ext_ids >= 0)[0]:
                e = int(frozen.ext_ids[slot])
                if self._ext_loc.get(e) == ("rw", int(slot)):
                    self._ext_loc[e] = ("ro", int(slot))
            self.stats.snapshots += 1
        staged = sum(t.n for t in self.ro)
        if staged >= self.cfg.merge_threshold:
            self.merge()

    # -------------------------------------------------------------- merging
    def merge(self, background: bool = False) -> None:
        """StreamingMerge the RO-TempIndex points + DeleteList into the LTI."""
        if background:
            if self._merge_thread and self._merge_thread.is_alive():
                return
            self._merge_thread = threading.Thread(target=self._merge_impl)
            self._merge_thread.start()
        else:
            self._merge_impl()

    def wait_merge(self) -> None:
        if self._merge_thread:
            self._merge_thread.join()

    def _merge_impl(self) -> None:
        with self._merge_lock:
            t0 = time.perf_counter()
            ro, self.ro = self.ro, []
            staged = sum(t.n for t in ro)
            icfg = self.cfg.index
            # Stage vectors + ids from the RO snapshots (skip re-deleted ones).
            del_snapshot = set(self.deleted_ext)
            vecs = np.zeros((max(staged, 1), icfg.dim), np.float32)
            exts = np.full(max(staged, 1), -1, np.int64)
            w = 0
            for t in ro:
                sl = np.nonzero(t.ext_ids >= 0)[0][:t.n]
                v = np.asarray(t.state.vectors)[sl]
                for s, row in zip(sl, v):
                    e = int(t.ext_ids[s])
                    if e in del_snapshot:
                        continue
                    vecs[w], exts[w] = row, e
                    w += 1
            valid = np.zeros(max(staged, 1), bool)
            valid[:w] = True
            # DeleteList restricted to LTI-resident points.
            dmask = np.zeros(icfg.capacity, bool)
            lti_ids = self.lti_ext_ids
            if del_snapshot:
                dl = np.asarray(sorted(del_snapshot), np.int64)
                hit = np.isin(lti_ids, dl)
                dmask[hit] = True
            new_lti, stats = streaming_merge(
                self.lti, jnp.asarray(vecs), jnp.asarray(valid),
                jnp.asarray(dmask), icfg, self.cfg.pq,
                insert_chunk=self.cfg.insert_batch, block=self.cfg.merge_block)
            jax.block_until_ready(new_lti.graph.adjacency)
            # Rebuild the external-id table: deleted rows out, new rows in
            # (the merge reports the slot it assigned to each staged row).
            new_ids = self.lti_ext_ids.copy()
            new_ids[dmask] = -1
            slots = np.asarray(stats.slots)
            ok = valid & (slots >= 0)
            for s, e in zip(slots[ok], exts[ok]):
                new_ids[s] = e
                self._ext_loc[e] = ("lti", int(s))
            self.lti = new_lti
            self.lti_ext_ids = new_ids
            # Deletes consumed this cycle leave the DeleteList; deletes of
            # never-merged temp points are consumed too (their points stayed
            # out of the merge).
            self.deleted_ext -= del_snapshot
            if self.wal:
                truncate(self.wal.path, icfg.dim, self.stats.merges + 1)
            self.stats.merges += 1
            self.stats.merge_seconds += time.perf_counter() - t0

    # ------------------------------------------------------------ snapshots
    def save(self, path: str) -> None:
        self._flush_inserts()     # buffered inserts must land in the temps
        os.makedirs(path, exist_ok=True)
        np.savez_compressed(
            os.path.join(path, "lti.npz"),
            **{f"g_{k}": np.asarray(v) for k, v in
               self.lti.graph._asdict().items()},
            codes=np.asarray(self.lti.codes),
            centroids=np.asarray(self.lti.codebook.centroids),
            ext_ids=self.lti_ext_ids)
        ro_blob = [(t.state, t.ext_ids, t.n) for t in self.ro + [self.rw]]
        with open(os.path.join(path, "temps.pkl"), "wb") as f:
            pickle.dump([(jax.tree.map(np.asarray, s), e, n)
                         for s, e, n in ro_blob], f)
        # Record how much of the WAL (and which log epoch) this snapshot
        # already covers, so recovery replays only the suffix (no
        # double-apply).
        wal_offset = wal_epoch = None
        if self.wal and os.path.exists(self.wal.path):
            wal_offset = os.path.getsize(self.wal.path)
            wal_epoch = log_epoch(self.wal.path)
        with open(os.path.join(path, "meta.pkl"), "wb") as f:
            pickle.dump({"deleted": self.deleted_ext, "cfg": self.cfg,
                         "wal_offset": wal_offset,
                         "wal_epoch": wal_epoch}, f)

    @classmethod
    def load(cls, path: str, cfg: SystemConfig) -> "FreshDiskANN":
        z = np.load(os.path.join(path, "lti.npz"))
        g = GraphState(*[jnp.asarray(z[f"g_{k}"])
                         for k in GraphState._fields])
        lti = LTIState(g, jnp.asarray(z["codes"]),
                       pqm.PQCodebook(jnp.asarray(z["centroids"])))
        sys = cls(cfg, lti=lti, lti_ext_ids=z["ext_ids"].copy())
        with open(os.path.join(path, "temps.pkl"), "rb") as f:
            temps = pickle.load(f)
        for i, (s, e, n) in enumerate(temps):
            t = _Temp(GraphState(*[jnp.asarray(x) for x in s]), e.copy(), n)
            # Last snapshot entry is the RW index, earlier ones are frozen RO
            # snapshots — tag them apart, matching the live-system tags.
            is_rw = i == len(temps) - 1
            if is_rw:
                sys.rw = t
            else:
                sys.ro.append(t)
            tag = "rw" if is_rw else "ro"
            for slot, ext in enumerate(e):
                if ext >= 0:
                    sys._ext_loc[int(ext)] = (tag, slot)
        with open(os.path.join(path, "meta.pkl"), "rb") as f:
            meta = pickle.load(f)
        sys.deleted_ext = set(meta["deleted"])
        sys._wal_offset = meta.get("wal_offset")
        sys._wal_epoch = meta.get("wal_epoch")
        return sys

    def recover(self, snapshot_path: Optional[str] = None) -> int:
        """Crash recovery (§5.6): restore the latest snapshot (when given),
        then replay the WAL over it.  Returns the number of records replayed."""
        start = None
        if snapshot_path:
            restored = FreshDiskANN.load(snapshot_path, self.cfg)
            if restored.wal:              # keep only our own WAL handle open
                restored.wal.close()
            self.lti = restored.lti
            self.lti_ext_ids = restored.lti_ext_ids
            self.rw = restored.rw
            self.ro = restored.ro
            self.deleted_ext = restored.deleted_ext
            self._ext_loc = restored._ext_loc
            self._insert_buf_v, self._insert_buf_id = [], []
            start = restored._wal_offset
            epoch = restored._wal_epoch
        n = 0
        wal_path = self.wal.path if self.wal else None
        if wal_path and os.path.exists(wal_path):
            # Replay only the suffix the snapshot doesn't already cover.  If
            # the log epoch changed since the snapshot (post-merge truncate)
            # everything in the current log postdates it: replay all of it.
            if start is not None and (start > os.path.getsize(wal_path)
                                      or epoch != log_epoch(wal_path)):
                start = None
            # Materialize before applying, and suppress re-logging while we
            # replay: the records are already in the log, and appending to
            # the file being iterated would never reach EOF.
            records = list(replay(wal_path, start))
            wal, self.wal = self.wal, None
            try:
                for op, ext_id, vec in records:
                    if op == 0:
                        self.insert(ext_id, vec)
                    else:
                        self.delete(ext_id)
                    n += 1
                self._flush_inserts()
            finally:
                self.wal = wal
        return n

    # -------------------------------------------------------------- helpers
    @property
    def size(self) -> int:
        live = sum(t.n for t in [self.rw] + self.ro)
        live += len(self._insert_buf_id)     # not yet flushed to the RW index
        return (int(np.sum(self.lti_ext_ids >= 0)) + live
                - len(self.deleted_ext & set(self._ext_loc)))


def bootstrap_system(vectors: np.ndarray, ext_ids: np.ndarray,
                     cfg: SystemConfig, **build_kw) -> FreshDiskANN:
    """Build the initial static LTI (paper: start from a DiskANN build)."""
    lti = build_lti(vectors, cfg.index, cfg.pq, **build_kw)
    table = np.full(cfg.index.capacity, -1, np.int64)
    table[:len(ext_ids)] = ext_ids
    return FreshDiskANN(cfg, lti=lti, lti_ext_ids=table)
