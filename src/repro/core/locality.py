"""Locality-aware update batching — proximity-order a batch before it hits
the graph (Slipstream, arXiv 2606.02992; DGAI, arXiv 2510.25401).

FreshDiskANN's update cost is dominated by per-point graph work whose price
depends on *which rows it touches*: an insert's beam search walks a
neighborhood, its back-edges land on the nodes of that neighborhood, and the
Patch phase pays one grouped prune per **distinct** back-edge target.  Points
that arrive interleaved across the vector space scatter that work; points
processed in proximity order collide onto the same rows, so

  * a flush chunk's B beam searches expand overlapping frontiers,
  * its B*R Delta pairs hit far fewer distinct targets (one amortized group
    prune instead of one row per pair), and
  * a merge's back-edges concentrate onto rows that are being rewritten
    anyway (the just-inserted cluster mates), which the storage layer's
    delta patch converts into fewer rewritten rows and bytes
    (``storage.layout.patch_layout`` — the DGAI observation).

``locality_order`` is the ordering primitive: a seeded sampled-medoid sort
that is jit-friendly (fixed shapes, no host round-trip), deterministic for a
fixed ``(vecs, valid, seed)``, and a true permutation — the same multiset of
points goes in and comes out.  Consumers: ``system._flush_inserts`` (RW-tier
flushes) and ``merge.streaming_merge(..., locality=True)`` (the Phase-2
insert scan), both gated behind ``SystemConfig.locality_order``.

Contract (docs/ARCHITECTURE.md, "Update-path locality"): reordering
legitimately changes slot assignment and graph topology, so the acceptance
bar is *recall equivalence* with the arrival-order path plus
*bit-determinism* for a fixed input batch and seed — NOT bit-parity with the
unordered path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def locality_order(vecs: jax.Array, valid: Optional[jax.Array] = None, *,
                   n_clusters: int = 16, seed: int = 0,
                   key: Optional[jax.Array] = None) -> jax.Array:
    """Proximity-ordering permutation over a batch of vectors.

    Samples ``min(n_clusters, B)`` medoid rows from the batch (seeded,
    biased to valid rows), assigns every row to its nearest medoid, and
    returns ``perm`` [B] int32 sorting rows by (cluster, distance-to-medoid,
    original index) — cluster-mates become contiguous, nearest-to-medoid
    first, with the original index as the stable tiebreak.  Invalid rows
    (``valid`` False) sort last in original order.

    ``seed`` is folded into a PRNG key here so the jitted body traces the
    key as DATA — flushes and merges vary the seed every call, and a static
    seed would recompile the program each time.  Callers already holding a
    key (e.g. inside a larger traced program) pass ``key=`` instead.

    Properties the tests pin (tests/test_locality.py):
      * permutation — ``sort(perm) == arange(B)`` always;
      * deterministic — same ``(vecs, valid, seed)`` -> same perm, bit for
        bit (medoid sampling uses a fixed PRNG key, sorts are stable);
      * fixed-shape — jit-compiles once per (B, d, n_clusters), for ANY
        seed; no host round-trip, so it can run inside a larger jitted
        program.
    """
    if key is None:
        key = jax.random.PRNGKey(seed)
    if valid is None:
        valid = jnp.ones((vecs.shape[0],), bool)
    return _locality_order_impl(vecs, valid, key, n_clusters)


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _locality_order_impl(vecs: jax.Array, valid: jax.Array, key: jax.Array,
                         n_clusters: int) -> jax.Array:
    B = vecs.shape[0]
    k = max(1, min(n_clusters, B))
    v = vecs.astype(jnp.float32)
    # Seeded medoid sample, biased to valid rows.  The tiny floor keeps the
    # categorical well-defined when nothing is valid (the perm then only
    # orders padding, which callers drop).
    w = jnp.where(valid, 1.0, 1e-9)
    idx = jax.random.choice(key, B, shape=(k,), replace=True, p=w / w.sum())
    med = v[idx]                                             # [k, d]
    d = jnp.sum((v[:, None, :] - med[None, :, :]) ** 2, -1)  # [B, k]
    cl = jnp.argmin(d, axis=1).astype(jnp.int32)
    dc = jnp.take_along_axis(d, cl[:, None], axis=1)[:, 0]
    cl = jnp.where(valid, cl, jnp.int32(k))                  # invalid last
    dc = jnp.where(valid, dc, jnp.inf)
    # Two-pass stable sort == lexsort by (cluster major, distance minor,
    # original index as the final tiebreak).
    order = jnp.argsort(dc, stable=True)
    perm = order[jnp.argsort(cl[order], stable=True)]
    return perm.astype(jnp.int32)


def inverse_permutation(perm: jax.Array) -> jax.Array:
    """``inv`` with ``inv[perm[i]] == i`` — maps ordered positions back to
    original row indices (e.g. un-permuting the merge's slot report)."""
    return jnp.zeros_like(perm).at[perm].set(
        jnp.arange(perm.shape[0], dtype=perm.dtype))


def cluster_spans(perm: jax.Array, vecs: jax.Array, valid: jax.Array, *,
                  n_clusters: int = 16, seed: int = 0) -> int:
    """Number of cluster transitions along the ordered batch — a host-side
    diagnostic (lower = better grouping; a perfect ordering has at most
    ``n_clusters - 1`` transitions over the valid prefix)."""
    import numpy as np
    B = vecs.shape[0]
    k = max(1, min(n_clusters, B))
    v = jnp.asarray(vecs, jnp.float32)
    w = jnp.where(jnp.asarray(valid, bool), 1.0, 1e-9)
    idx = jax.random.choice(jax.random.PRNGKey(seed), B, shape=(k,),
                            replace=True, p=w / w.sum())
    d = jnp.sum((v[:, None, :] - v[idx][None, :, :]) ** 2, -1)
    cl = np.asarray(jnp.argmin(d, axis=1))[np.asarray(perm)]
    ok = np.asarray(valid, bool)[np.asarray(perm)]
    cl = cl[ok]
    return int((cl[1:] != cl[:-1]).sum()) if len(cl) > 1 else 0


def next_bucket(n: int, *, floor: int = 16, cap: int | None = None) -> int:
    """Round a dynamic affected-row count up to a power-of-two launch bucket.

    The locality paths size their Patch-phase prune launches from the
    *measured* distinct-target count; bucketing to powers of two (with a
    floor) bounds the number of jit specializations while keeping the
    launch proportional to real work instead of the worst case.
    """
    if n <= 0:
        return 0
    b = max(floor, 1 << (n - 1).bit_length())
    return min(b, cap) if cap is not None else b
