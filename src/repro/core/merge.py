"""StreamingMerge (paper §5.3) — fold staged inserts + the DeleteList into the
LTI in three phases, all distances from PQ codes.

  Delete phase — Algorithm 4 over the LTI, block-by-block (sequential pass 1).
  Insert phase — GreedySearch on the intermediate LTI per new point (PQ
      navigation), RobustPrune for its out-edges, back-edges staged as the
      Delta pair list of size O(|N|·R).
  Patch phase — Delta grouped by target and applied block-wise with the
      append-or-prune rule (sequential pass 2).

Faithfulness notes: every distance below is computed from the PQ codes
(decoded centroids), never from full-precision vectors — this is what produces
the paper's small steady-state recall dip (Fig. 4), which our tests reproduce.
In the paper phases 1/3 are sequential SSD passes; here they are ``lax.map``
block streams (HBM->VMEM).  Insert-phase searches are vmapped chunks: new
points have no in-edges until the Patch phase, so chunked execution is
order-equivalent to the paper's sequential inserts.

All three phases ride the batched mutation engine: the Delete phase repairs
each block through ``delete.consolidate_deletes{_codes}`` (fused
``delete_repair`` kernel under ``IndexConfig.use_kernel``), the Insert
phase prunes each chunk with ONE ``prune.robust_prune_batch`` call, and the
Patch phase applies Delta through ``insert.apply_back_edges{_codes}`` —
kernel- and jnp-path outputs are bit-identical (docs/ARCHITECTURE.md,
"Mutation engine").

Delete-phase sweep modes (``repair_mode`` kwarg, None -> ``cfg.repair_mode``):

- ``"global"`` — the whole merge stays ONE jitted device program (the
  historical shape): Algorithm 4 scans every block.
- ``"local"`` — the Delete phase runs the localized affected-set repair
  (``delete.consolidate_deletes(mode="local")``), which round-trips the
  affected ids through the host and therefore runs eagerly; phases 2+3
  still run as one jitted program (``_insert_patch_phases``, the same
  traced body the fused path inlines).  Outputs are bit-identical to the
  global merge — only wall-clock and dispatch count differ.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import pq as pqm
from .config import IndexConfig, PQConfig
from .delete import (consolidate_deletes, consolidate_deletes_codes,
                     repair_cap_overflow)
from .distance import INVALID
from .insert import (apply_back_edges, apply_back_edges_codes,
                     compute_insert_edges)
from .locality import locality_order, next_bucket
from .lti import LTIState
from .prune import SDCPrune, robust_prune_batch
from .search import PQBackend, beam_search

# Expansion cap of the SDC delete repair (candidate width R + cap*R);
# overflows — nodes with more deleted out-neighbors than the cap — are
# counted into MergeStats.repair_cap_overflows.
SDC_REPAIR_CAP = 8

# Mirrors ``storage.layout.BLOCK_BYTES`` (the 4KB SSD-sector granularity of
# topology.bin) without importing the storage tier into core: the locality
# merge's slot placement groups new rows by this block size so the delta
# patch dirties as few blocks as possible.
TOPOLOGY_BLOCK_BYTES = 4096


class MergeStats(NamedTuple):
    n_deleted: jax.Array
    n_inserted: jax.Array
    n_backedge_pairs: jax.Array
    slots: jax.Array            # [Nn] slot assigned per staged row (INVALID ok)
    repair_cap_overflows: jax.Array  # nodes whose SDC repair dropped >=1
    #   expansion ball (deleted out-neighbors > SDC_REPAIR_CAP); always 0
    #   on the full-precision (use_sdc=False) path, whose expansion is
    #   uncapped.
    n_backedge_targets: jax.Array    # DISTINCT Delta targets the Patch phase
    #   touched (rows with real work; <= n_backedge_pairs)
    n_prune_rows: jax.Array          # prune-engine rows the Patch phase
    #   LAUNCHED: the fixed-shape worst case min(P, N) on the arrival-order
    #   paths, the sum of measured power-of-two buckets on the locality
    #   path — the number the locality ordering exists to shrink.


def streaming_merge(
    lti: LTIState,
    new_vecs: jax.Array,        # [Nn, d] staged TempIndex points (rows may be
    new_valid: jax.Array,       # [Nn] bool  masked — fixed-shape staging)
    delete_mask: jax.Array,     # [capacity] bool — DeleteList membership
    cfg: IndexConfig,
    pq_cfg: PQConfig,
    *,
    insert_chunk: int = 256,
    block: int = 1024,
    use_sdc: bool = False,
    repair_mode: str | None = None,
    locality: bool = False,
    locality_seed: int = 0,
) -> tuple[LTIState, MergeStats]:
    """With ``use_sdc`` every prune distance comes straight from the PQ
    codes via symmetric-distance tables (numerically identical to pruning
    on decoded reconstructions, ~16x less HBM traffic, no decoded-table
    buffer) — EXPERIMENTS.md §Perf iteration 1 on the merge cell.

    ``locality=True`` runs Phase 2 on the locality schedule
    (``_streaming_merge_ordered``): staged rows are proximity-ordered by
    ``core.locality.locality_order`` (seeded by ``locality_seed``) and
    inserted as EAGER cluster-ordered chunks — each chunk's Delta is applied
    before the next chunk searches, so cluster mates wire to each other and
    the back-edge patch concentrates onto the just-inserted rows.  Slot
    assignment and topology legitimately differ from the arrival-order
    merge; the contract is recall equivalence + bit-determinism for a fixed
    (inputs, seed), not bit-parity (docs/ARCHITECTURE.md, "Update-path
    locality")."""
    mode = cfg.repair_mode if repair_mode is None else repair_mode
    if locality:
        return _streaming_merge_ordered(
            lti, new_vecs, new_valid, delete_mask, cfg, pq_cfg,
            insert_chunk=insert_chunk, block=block, use_sdc=use_sdc,
            mode=mode, seed=locality_seed)
    if mode == "local":
        return _streaming_merge_local(
            lti, new_vecs, new_valid, delete_mask, cfg, pq_cfg,
            insert_chunk=insert_chunk, block=block, use_sdc=use_sdc)
    return _streaming_merge_fused(
        lti, new_vecs, new_valid, delete_mask, cfg, pq_cfg,
        insert_chunk=insert_chunk, block=block, use_sdc=use_sdc)


@functools.partial(jax.jit, static_argnames=("cfg", "pq_cfg", "insert_chunk",
                                              "block", "use_sdc"))
def _streaming_merge_fused(lti, new_vecs, new_valid, delete_mask, cfg, pq_cfg,
                           *, insert_chunk, block, use_sdc):
    """The historical one-program merge: global Delete phase + phases 2/3,
    all inside a single jit."""
    g = lti.graph
    codebook = lti.codebook

    # ---- Phase 1: Delete (sequential block pass over the LTI) -------------
    # Prune distances use PQ codes only (paper: "we use the compressed PQ
    # vectors ... to calculate the approximate distances").
    n_del = (g.active & delete_mask).sum()
    g = g._replace(deleted=g.deleted | (delete_mask & g.active))
    overflow = jnp.int32(0)
    if use_sdc:
        tables = pqm.sdc_tables(codebook)
        decoded = None
        overflow = repair_cap_overflow(
            g.adjacency, g.deleted, g.active & ~g.deleted, SDC_REPAIR_CAP)
        g = consolidate_deletes_codes(g, cfg, lti.codes, tables,
                                      block=block, cap=SDC_REPAIR_CAP,
                                      mode="global")
    else:
        decoded = pqm.decode(codebook, lti.codes, pq_cfg).astype(jnp.float32)
        g = consolidate_deletes(g, cfg, block=block, prune_table=decoded,
                                mode="global")

    return _insert_patch_phases(
        g, lti.codes, codebook, decoded, new_vecs, new_valid, n_del,
        overflow, cfg, pq_cfg, insert_chunk=insert_chunk, block=block,
        use_sdc=use_sdc)


def _streaming_merge_local(lti, new_vecs, new_valid, delete_mask, cfg, pq_cfg,
                           *, insert_chunk, block, use_sdc):
    """Localized merge: eager affected-set Delete phase, then the SAME
    jitted phases-2/3 body as the fused path.  Bit-identical results."""
    g = lti.graph
    codebook = lti.codebook

    n_del = (g.active & delete_mask).sum()
    g = g._replace(deleted=g.deleted | (delete_mask & g.active))
    overflow = jnp.int32(0)
    if use_sdc:
        tables = pqm.sdc_tables(codebook)
        decoded = None
        overflow = repair_cap_overflow(
            g.adjacency, g.deleted, g.active & ~g.deleted, SDC_REPAIR_CAP)
        g = consolidate_deletes_codes(g, cfg, lti.codes, tables,
                                      block=block, cap=SDC_REPAIR_CAP,
                                      mode="local")
    else:
        decoded = pqm.decode(codebook, lti.codes, pq_cfg).astype(jnp.float32)
        g = consolidate_deletes(g, cfg, block=block, prune_table=decoded,
                                mode="local")

    return _insert_patch_phases(
        g, lti.codes, codebook, decoded, new_vecs, new_valid, n_del,
        overflow, cfg, pq_cfg, insert_chunk=insert_chunk, block=block,
        use_sdc=use_sdc)


@functools.partial(jax.jit, static_argnames=("cfg", "pq_cfg", "insert_chunk",
                                              "block", "use_sdc"))
def _insert_patch_phases(g, old_codes, codebook, decoded, new_vecs, new_valid,
                         n_del, overflow, cfg, pq_cfg, *, insert_chunk, block,
                         use_sdc):
    """Phases 2 (Insert) + 3 (Patch), shared by the fused and localized
    merge paths (inlined into the fused path's jit; the localized path's
    own device program)."""
    if use_sdc:
        tables = pqm.sdc_tables(codebook)

    # ---- Phase 2: Insert (random reads against the intermediate LTI) ------
    Nn = new_vecs.shape[0]
    # Allocate free slots for the new points (top_k over the free indicator
    # yields distinct, lowest-first free slots).
    free = ~g.active
    _, slots = jax.lax.top_k(free.astype(jnp.int32), Nn)
    slots = jnp.where(new_valid & (free[slots]), slots, INVALID)
    wslots = jnp.where(slots >= 0, slots, g.capacity)

    new_codes = pqm.encode(codebook, new_vecs, pq_cfg)
    codes = old_codes.at[wslots].set(new_codes, mode="drop")
    vectors = g.vectors.at[wslots].set(
        new_vecs.astype(g.vectors.dtype), mode="drop")
    active = g.active.at[wslots].set(True, mode="drop")
    if not use_sdc:
        decoded = decoded.at[wslots].set(
            pqm.decode(codebook, new_codes, pq_cfg), mode="drop")
    # Re-seed the entry point when the Delete phase emptied the index
    # (start=INVALID sentinel): the first allocated slot seeds this
    # merge's insert searches and every search after the swap.
    first_new = jnp.where((slots >= 0).any(),
                          slots[jnp.argmax(slots >= 0)], INVALID)
    start = jnp.where(g.start < 0, first_new, g.start).astype(jnp.int32)
    g = g._replace(vectors=vectors, active=active, start=start,
                   n_total=jnp.maximum(g.n_total,
                                       jnp.max(jnp.where(slots >= 0, slots, -1)) + 1))
    usable = g.active & ~g.deleted

    n_chunks = max(1, -(-Nn // insert_chunk))
    pad = n_chunks * insert_chunk - Nn
    c_slots = jnp.concatenate([slots, jnp.full((pad,), INVALID, jnp.int32)])
    c_vecs = jnp.concatenate(
        [new_vecs.astype(jnp.float32),
         jnp.zeros((pad, new_vecs.shape[1]), jnp.float32)])
    c_slots = c_slots.reshape(n_chunks, insert_chunk)
    c_vecs = c_vecs.reshape(n_chunks, insert_chunk, -1)

    backend = PQBackend(codes, codebook)
    use_kernel = cfg.kernel_enabled()

    def insert_block(carry, inp):
        adjacency = carry
        sl, vv = inp
        if use_sdc:
            # search via ADC; prune with d_p = exact-vector ADC and
            # candidate-candidate distances via SDC on codes — one batched
            # prune-engine call per insert chunk (fused kernel under
            # use_kernel).
            res = beam_search(adjacency, g.active, g.start, vv, backend,
                              L=cfg.L_build,
                              max_visits=cfg.visits_bound(cfg.L_build),
                              beam_width=cfg.beam_width,
                              use_kernel=use_kernel)
            cand = jnp.concatenate([res.visited, res.ids], axis=1)
            safe = jnp.maximum(cand, 0)
            ok = (cand >= 0) & usable[safe] & (cand != sl[:, None])
            d_p = jax.vmap(
                lambda c, vec: pqm.adc(codes[c], pqm.lut(codebook, vec))
            )(safe, vv)
            new_adj = robust_prune_batch(
                SDCPrune(codes, tables), cand, ok, alpha=cfg.alpha,
                R=cfg.R, use_kernel=use_kernel, d_p=d_p).ids
            src = jnp.broadcast_to(sl[:, None],
                                   new_adj.shape).reshape(-1)
        else:
            edges = compute_insert_edges(
                adjacency, g.active, usable, g.start, decoded, sl, vv,
                backend,
                L=cfg.L_build, max_visits=cfg.visits_bound(cfg.L_build),
                alpha=cfg.alpha, R=cfg.R, beam_width=cfg.beam_width,
                use_kernel=use_kernel)
            new_adj = edges.new_adj
            src = edges.pairs_p
        valid = sl >= 0
        new_adj = jnp.where(valid[:, None], new_adj, INVALID)
        adjacency = adjacency.at[jnp.where(valid, sl, g.capacity)].set(
            new_adj, mode="drop")
        pj = new_adj.reshape(-1)
        pp = jnp.where(pj >= 0, src, INVALID)
        return adjacency, (pj, pp)

    adjacency, (pairs_j, pairs_p) = jax.lax.scan(
        insert_block, g.adjacency, (c_slots, c_vecs))
    pairs_j = pairs_j.reshape(-1)   # O(|N|*R) Delta pair list
    pairs_p = pairs_p.reshape(-1)

    # ---- Phase 3: Patch (sequential block pass applying Delta) ------------
    if use_sdc:
        adjacency = apply_back_edges_codes(
            adjacency, codes, tables, usable, pairs_j, pairs_p,
            alpha=cfg.alpha, R=cfg.R, chunk=block, use_kernel=use_kernel)
    else:
        adjacency = apply_back_edges(
            adjacency, decoded, usable, pairs_j, pairs_p,
            alpha=cfg.alpha, R=cfg.R, chunk=block, use_kernel=use_kernel)

    g = g._replace(adjacency=adjacency)
    # Distinct Delta targets (device-side: sort + neighbor-compare).  The
    # arrival-order Patch launches the fixed-shape worst case min(P, N)
    # prune rows regardless of how many targets actually collide — the gap
    # between the two numbers is the headroom the locality path cashes in.
    skey = jnp.sort(jnp.where(pairs_j >= 0, pairs_j, jnp.int32(g.capacity)))
    live = skey < g.capacity
    distinct = (live & jnp.concatenate(
        [jnp.ones((1,), bool), skey[1:] != skey[:-1]])).sum()
    stats = MergeStats(n_del, (slots >= 0).sum(),
                       (pairs_j >= 0).sum(), slots,
                       jnp.asarray(overflow, jnp.int32),
                       distinct.astype(jnp.int32),
                       jnp.int32(min(pairs_j.shape[0], g.capacity)))
    return LTIState(g, codes, codebook), stats


def _streaming_merge_ordered(lti, new_vecs, new_valid, delete_mask, cfg,
                             pq_cfg, *, insert_chunk, block, use_sdc, mode,
                             seed):
    """Locality-schedule merge: eager Phase 1 (honoring ``mode``), then
    Phase 2 as cluster-ordered chunks inserted EAGERLY — each chunk's Delta
    is applied before the next chunk searches.

    Why eager: on the arrival-order paths new points have no in-edges until
    the single Phase-3 patch, so chunks cannot see each other and reordering
    alone changes nothing but slot labels.  With per-chunk patching, a
    chunk's searches DO reach its earlier-inserted cluster mates, so its
    out-edges and back-edges land on the new rows (being rewritten anyway)
    instead of scattering across the old graph — ``adjacency_delta_mask``
    shrinks, and ``patch_layout`` rewrites measurably fewer rows/bytes.
    Each chunk's Delta prune also launches at a measured power-of-two
    bucket (host-counted distinct targets -> ``affected_cap``) instead of
    the fixed-shape worst case.

    The host round-trip per chunk (distinct-target count) is the price of
    the dynamic launch size; ``MergeStats.n_prune_rows`` records the
    realized total so benchmarks can weigh it against the arrival-order
    worst case.  Deterministic for fixed (inputs, seed): the ordering is
    seeded, chunking is sequential, and every launch size is a pure
    function of the data."""
    g = lti.graph
    codebook = lti.codebook

    # ---- Phase 1: Delete (identical to the arrival-order paths) -----------
    n_del = (g.active & delete_mask).sum()
    g = g._replace(deleted=g.deleted | (delete_mask & g.active))
    overflow = jnp.int32(0)
    tables = decoded = None
    if use_sdc:
        tables = pqm.sdc_tables(codebook)
        overflow = repair_cap_overflow(
            g.adjacency, g.deleted, g.active & ~g.deleted, SDC_REPAIR_CAP)
        g = consolidate_deletes_codes(g, cfg, lti.codes, tables,
                                      block=block, cap=SDC_REPAIR_CAP,
                                      mode=mode)
    else:
        decoded = pqm.decode(codebook, lti.codes, pq_cfg).astype(jnp.float32)
        g = consolidate_deletes(g, cfg, block=block, prune_table=decoded,
                                mode=mode)

    # ---- Phase 2a: order + allocate + store (one jitted stage) ------------
    # Rows the Delete phase already rewrote (freed slots go all-INVALID,
    # repaired neighbors change) mark their 4KB topology blocks dirty for
    # this merge's patch: placing new points there costs ZERO extra block
    # writes (the DGAI placement observation).
    phase1_dirty = adjacency_delta_mask(lti.graph.adjacency, g.adjacency)
    g, codes, decoded, slots_ord, ord_vecs, perm = _locality_stage(
        g, lti.codes, codebook, decoded, new_vecs, new_valid, phase1_dirty,
        jax.random.PRNGKey(seed), cfg, pq_cfg, use_sdc=use_sdc)
    usable = g.active & ~g.deleted

    Nn = new_vecs.shape[0]
    n_chunks = max(1, -(-Nn // insert_chunk))
    pad = n_chunks * insert_chunk - Nn
    c_slots = jnp.concatenate(
        [slots_ord, jnp.full((pad,), INVALID, jnp.int32)]
    ).reshape(n_chunks, insert_chunk)
    c_vecs = jnp.concatenate(
        [ord_vecs.astype(jnp.float32),
         jnp.zeros((pad, new_vecs.shape[1]), jnp.float32)]
    ).reshape(n_chunks, insert_chunk, -1)

    # ---- Phase 2b/3: eager chunk loop, per-chunk Delta patch --------------
    adjacency = g.adjacency
    n_pairs = n_targets = n_rows = 0
    cap_max = min(insert_chunk * cfg.R, g.capacity)
    for c in range(n_chunks):
        adjacency, pj, pp = _ordered_insert_chunk(
            adjacency, g.active, g.start, usable, codes, codebook, tables,
            decoded, c_slots[c], c_vecs[c], cfg, use_sdc=use_sdc)
        pj_h = np.asarray(pj)
        d_c = int(np.unique(pj_h[pj_h >= 0]).size)
        n_pairs += int((pj_h >= 0).sum())
        if d_c == 0:
            continue
        bucket = next_bucket(d_c, cap=cap_max)
        n_targets += d_c
        n_rows += bucket
        adjacency = _ordered_patch(
            adjacency, codes, tables, decoded, usable, pj, pp, cfg,
            bucket=bucket, block=block, use_sdc=use_sdc)
    g = g._replace(adjacency=adjacency)

    # Report slots in ORIGINAL row order (perm is a permutation, so the
    # scatter covers every entry): staged row i landed in slot
    # slots_orig[i], whatever position the ordering gave it.
    slots_orig = jnp.full((Nn,), INVALID, jnp.int32).at[perm].set(slots_ord)
    stats = MergeStats(n_del, (slots_ord >= 0).sum(), jnp.int32(n_pairs),
                       slots_orig, jnp.asarray(overflow, jnp.int32),
                       jnp.int32(n_targets), jnp.int32(n_rows))
    return LTIState(g, codes, codebook), stats


@functools.partial(jax.jit, static_argnames=("cfg", "pq_cfg", "use_sdc"))
def _locality_stage(g, old_codes, codebook, decoded, new_vecs, new_valid,
                    phase1_dirty, key, cfg, pq_cfg, *, use_sdc):
    """Proximity-order the staged rows, allocate slots along the ordering,
    and store vectors/codes/flags — the ordered twin of the staging prologue
    in ``_insert_patch_phases``.

    Slot placement is locality-aware (two DGAI-style effects on the delta
    patch): free slots inside ALREADY-DIRTY 4KB topology blocks
    (``phase1_dirty`` rows — this merge's delete repairs and freed slots)
    are consumed first, since new rows there ride block writes the patch
    must issue anyway; the remainder fills fresh blocks in ascending order.
    Rows consume slots in cluster order, so cluster mates land CONTIGUOUS —
    which a LATER merge inserting near the same clusters cashes in, its
    back-edge targets then occupying few distinct blocks.  The count of
    allocated slots (and validity masking) matches the arrival-order merge;
    only the placement differs."""
    k = cfg.locality_clusters or 16
    perm = locality_order(new_vecs.astype(jnp.float32), new_valid,
                          n_clusters=k, key=key)
    ord_vecs = new_vecs[perm]
    ord_valid = new_valid[perm]
    Nn = ord_vecs.shape[0]
    cap = g.capacity
    free = ~g.active
    rpb = max(1, TOPOLOGY_BLOCK_BYTES // (cfg.R * 4))
    blk = jnp.arange(cap, dtype=jnp.int32) // rpb
    n_blocks = -(-cap // rpb)
    block_dirty = jnp.zeros((n_blocks,), jnp.int32).at[blk].add(
        phase1_dirty.astype(jnp.int32)) > 0
    # Rank: free slots in dirty blocks ascending, then free slots in clean
    # blocks ascending, then occupied slots (never taken — masked below).
    arange = jnp.arange(cap, dtype=jnp.int32)
    rank = jnp.where(block_dirty[blk], arange, cap + arange)
    rank = jnp.where(free, rank, 2 * cap)
    slots = jnp.argsort(rank)[:Nn].astype(jnp.int32)
    slots = jnp.where(ord_valid & free[slots], slots, INVALID)
    wslots = jnp.where(slots >= 0, slots, g.capacity)
    new_codes = pqm.encode(codebook, ord_vecs, pq_cfg)
    codes = old_codes.at[wslots].set(new_codes, mode="drop")
    vectors = g.vectors.at[wslots].set(
        ord_vecs.astype(g.vectors.dtype), mode="drop")
    active = g.active.at[wslots].set(True, mode="drop")
    if not use_sdc:
        decoded = decoded.at[wslots].set(
            pqm.decode(codebook, new_codes, pq_cfg), mode="drop")
    first_new = jnp.where((slots >= 0).any(),
                          slots[jnp.argmax(slots >= 0)], INVALID)
    start = jnp.where(g.start < 0, first_new, g.start).astype(jnp.int32)
    g = g._replace(vectors=vectors, active=active, start=start,
                   n_total=jnp.maximum(
                       g.n_total,
                       jnp.max(jnp.where(slots >= 0, slots, -1)) + 1))
    return g, codes, decoded, slots, ord_vecs, perm


@functools.partial(jax.jit, static_argnames=("cfg", "use_sdc"))
def _ordered_insert_chunk(adjacency, active, start, usable, codes, codebook,
                          tables, decoded, sl, vv, cfg, *, use_sdc):
    """One locality-schedule insert chunk: search + prune + scatter the new
    rows, returning the chunk's Delta pair list.  The traced body mirrors
    ``insert_block`` inside ``_insert_patch_phases`` — the difference is
    purely the call schedule (eager, so this chunk sees every previously
    patched chunk)."""
    backend = PQBackend(codes, codebook)
    use_kernel = cfg.kernel_enabled()
    N = adjacency.shape[0]
    if use_sdc:
        res = beam_search(adjacency, active, start, vv, backend,
                          L=cfg.L_build,
                          max_visits=cfg.visits_bound(cfg.L_build),
                          beam_width=cfg.beam_width, use_kernel=use_kernel)
        cand = jnp.concatenate([res.visited, res.ids], axis=1)
        safe = jnp.maximum(cand, 0)
        ok = (cand >= 0) & usable[safe] & (cand != sl[:, None])
        d_p = jax.vmap(
            lambda c, vec: pqm.adc(codes[c], pqm.lut(codebook, vec))
        )(safe, vv)
        new_adj = robust_prune_batch(
            SDCPrune(codes, tables), cand, ok, alpha=cfg.alpha,
            R=cfg.R, use_kernel=use_kernel, d_p=d_p).ids
        src = jnp.broadcast_to(sl[:, None], new_adj.shape).reshape(-1)
    else:
        edges = compute_insert_edges(
            adjacency, active, usable, start, decoded, sl, vv, backend,
            L=cfg.L_build, max_visits=cfg.visits_bound(cfg.L_build),
            alpha=cfg.alpha, R=cfg.R, beam_width=cfg.beam_width,
            use_kernel=use_kernel)
        new_adj = edges.new_adj
        src = edges.pairs_p
    valid = sl >= 0
    new_adj = jnp.where(valid[:, None], new_adj, INVALID)
    adjacency = adjacency.at[jnp.where(valid, sl, N)].set(
        new_adj, mode="drop")
    pj = new_adj.reshape(-1)
    pp = jnp.where(pj >= 0, src, INVALID)
    return adjacency, pj, pp


@functools.partial(jax.jit, static_argnames=("cfg", "bucket", "block",
                                             "use_sdc"))
def _ordered_patch(adjacency, codes, tables, decoded, usable, pj, pp, cfg, *,
                   bucket, block, use_sdc):
    """Per-chunk Delta application at a measured launch size: ``bucket``
    (static, power of two, >= the chunk's distinct target count) bounds the
    grouped prune to the rows that actually have work."""
    use_kernel = cfg.kernel_enabled()
    if use_sdc:
        return apply_back_edges_codes(
            adjacency, codes, tables, usable, pj, pp,
            alpha=cfg.alpha, R=cfg.R, chunk=block, use_kernel=use_kernel,
            affected_cap=bucket)
    return apply_back_edges(
        adjacency, decoded, usable, pj, pp,
        alpha=cfg.alpha, R=cfg.R, chunk=block, use_kernel=use_kernel,
        affected_cap=bucket)


@jax.jit
def adjacency_delta_mask(old_adj: jax.Array, new_adj: jax.Array) -> jax.Array:
    """[capacity] bool — rows the merge actually rewrote.

    A StreamingMerge touches only the delete-repaired, inserted, and
    back-edge-patched rows; everything else is bit-identical to the old
    adjacency.  The mask computes on device (one elementwise compare +
    row-reduce over the arrays the merge already holds) and drives the
    DGAI-style delta topology patch (``storage.layout.patch_layout``): only
    masked rows are rewritten in ``topology.bin``, and the vector file is
    untouched for surviving points."""
    return jnp.any(old_adj != new_adj, axis=1)
