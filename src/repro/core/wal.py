"""Redo-log (WAL) + snapshots — crash recovery (paper §5.6).

Every user-facing mutation (insert with its vector, delete) is appended to an
append-only log before being applied.  Recovery = load the most recent
RO/LTI snapshots (read-only, always consistent) and replay the log suffix to
rebuild the RW-TempIndex and DeleteList.

Record format (little-endian):
    u8 op (0=insert, 1=delete) | i64 external_id | f32[dim] vector (insert only)

Op 2 (labeled insert — filtered/multi-tenant points) extends op 0 with the
point's label sidecar between the id and the vector:
    u8 op=2 | i64 ext_id | i32 tenant | u8 n_words | u32[n_words] bits
    | f32[dim] vector
Logs containing only ops 0/1 are exactly the historical format, so old logs
replay unchanged and label-free systems never write op 2.
"""
from __future__ import annotations

import os
import struct
from typing import Iterator, NamedTuple, Optional

import numpy as np

_HDR = struct.Struct("<4sIQ")   # magic, dim, start_seqno
_REC = struct.Struct("<BQ")     # op, ext_id
_LBL = struct.Struct("<iB")     # tenant, n_words (labeled-insert sidecar)
MAGIC = b"FDWL"
OP_INSERT, OP_DELETE, OP_INSERT_LABELED = 0, 1, 2


class LabeledVec(NamedTuple):
    """Payload of an OP_INSERT_LABELED record (replay's third element)."""
    vec: np.ndarray
    tenant: int
    bits: np.ndarray  # uint32[n_words] packed label bitset


class WriteAheadLog:
    def __init__(self, path: str, dim: int, start_seqno: int = 0,
                 fsync: bool = False):
        self.path, self.dim, self.fsync = path, dim, fsync
        exists = os.path.exists(path) and os.path.getsize(path) > 0
        # ALWAYS append mode (O_APPEND): every write lands at the real EOF
        # even if the file is replaced underneath the handle.  A positional
        # ("wb") handle would keep writing at its own stale offset after an
        # external truncation, leaving a zero-hole that replay would parse
        # as garbage records.
        self._f = open(path, "ab")
        if not exists:
            self._f.write(_HDR.pack(MAGIC, dim, start_seqno))
            self._f.flush()

    def log_insert(self, ext_id: int, vec: np.ndarray) -> None:
        self._f.write(_REC.pack(OP_INSERT, ext_id))
        self._f.write(np.asarray(vec, np.float32).tobytes())
        self._flush()

    def log_insert_labeled(self, ext_id: int, vec: np.ndarray, tenant: int,
                           bits: np.ndarray) -> None:
        bits = np.asarray(bits, np.uint32)
        self._f.write(_REC.pack(OP_INSERT_LABELED, ext_id))
        self._f.write(_LBL.pack(int(tenant), bits.size))
        self._f.write(bits.tobytes())
        self._f.write(np.asarray(vec, np.float32).tobytes())
        self._flush()

    def log_delete(self, ext_id: int) -> None:
        self._f.write(_REC.pack(OP_DELETE, ext_id))
        self._flush()

    def _flush(self):
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def restart(self, start_seqno: int) -> None:
        """Start a fresh log epoch THROUGH this handle (close-truncate-reopen)
        — the only safe way to truncate a log that is still being written."""
        self._f.close()
        truncate(self.path, self.dim, start_seqno)
        self._f = open(self.path, "ab")

    def close(self):
        self._f.close()


def replay(path: str, start: Optional[int] = None
           ) -> Iterator[tuple[int, int, Optional[np.ndarray]]]:
    """Yield (op, ext_id, vector|None) records from a log file.

    ``start``: byte offset to resume from (a value previously captured with
    ``os.path.getsize`` on the flushed log — snapshots store it so recovery
    replays only the suffix written after the snapshot was taken).
    """
    with open(path, "rb") as f:
        hdr = f.read(_HDR.size)
        magic, dim, _ = _HDR.unpack(hdr)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad WAL magic")
        if start is not None and start > _HDR.size:
            f.seek(start)
        vec_bytes = 4 * dim
        while True:
            raw = f.read(_REC.size)
            if len(raw) < _REC.size:
                break  # torn tail tolerated: partial final record dropped
            op, ext_id = _REC.unpack(raw)
            if op == OP_INSERT:
                vraw = f.read(vec_bytes)
                if len(vraw) < vec_bytes:
                    break
                yield op, ext_id, np.frombuffer(vraw, np.float32).copy()
            elif op == OP_INSERT_LABELED:
                lraw = f.read(_LBL.size)
                if len(lraw) < _LBL.size:
                    break
                tenant, n_words = _LBL.unpack(lraw)
                braw = f.read(4 * n_words)
                vraw = f.read(vec_bytes)
                if len(braw) < 4 * n_words or len(vraw) < vec_bytes:
                    break
                yield op, ext_id, LabeledVec(
                    np.frombuffer(vraw, np.float32).copy(), tenant,
                    np.frombuffer(braw, np.uint32).copy())
            else:
                yield op, ext_id, None


def log_epoch(path: str) -> int:
    """The log's epoch counter (start_seqno header field; bumps on truncate)."""
    with open(path, "rb") as f:
        magic, _, seqno = _HDR.unpack(f.read(_HDR.size))
        if magic != MAGIC:
            raise ValueError(f"{path}: bad WAL magic")
        return seqno


def truncate(path: str, dim: int, start_seqno: int) -> None:
    """Start a fresh log epoch (after a successful snapshot+merge)."""
    with open(path, "wb") as f:
        f.write(_HDR.pack(MAGIC, dim, start_seqno))
