r"""Deletion: lazy DeleteList + consolidation (Algorithm 4).

``delete`` only marks nodes (paper §4.2 — deletes return in ~0.1us; deleted
nodes stay navigable but are filtered from results).  ``consolidate_deletes``
is the batched graph repair: every live node p with deleted out-neighbors gets

    C  <-  (N_out(p) u  U_{v in N_out(p) n D} N_out(v)) \ D \ {p}
    N_out(p)  <-  RobustPrune(p, C, alpha, R)

The pass is blocked (``lax.map`` over node blocks) — the TPU rendition of the
paper's sequential block-by-block SSD scan: one block of adjacency rows is
streamed HBM->VMEM, repaired in parallel, written back.  Under
``IndexConfig.use_kernel`` each block's repair is ONE fused Pallas launch
(``kernels.delete_repair``: candidate assembly + all R prune rounds +
changed-row select, vectorized across the block's rows); the pre-engine
jnp blocks are kept verbatim as the bit-parity oracle.

Two sweep modes (``IndexConfig.repair_mode``, overridable per call):

- ``"global"`` — the paper's full scan: every ``capacity/block`` block is
  repaired, affected or not.  Cost is independent of the delete rate.
- ``"local"`` — Algorithm 4's loop set is exactly the *affected set*
  (live nodes with >=1 deleted out-neighbor; ``affected_mask``).  The
  localized sweep finds those rows with one O(N*R) gather/compare pass,
  gathers them into fixed-shape padded blocks, repairs the blocks through
  the SAME per-block engine (one fused launch per block under the
  kernels), and scatters the repaired rows back.  Row repair is
  independent row-to-row, so the result is bit-identical to the global
  sweep while touching ~``|affected|/capacity`` of the blocks — an
  order of magnitude cheaper at low delete rates.  The affected ids are
  materialized on the host (data-dependent size), so the localized mode
  cannot run under an enclosing ``jit`` — ``streaming_merge`` dispatches
  around it.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import IndexConfig
from .distance import INVALID, l2_sq
from .graph import GraphState, medoid
from .prune import prune_node


def delete(state: GraphState, slots: jax.Array) -> GraphState:
    """Lazy delete: add to DeleteList (no graph edits)."""
    ok = slots >= 0
    safe = jnp.where(ok, slots, 0)
    deleted = state.deleted.at[safe].set(
        jnp.where(ok, True, state.deleted[safe]))
    return state._replace(deleted=deleted)


def affected_mask(adjacency: jax.Array, deleted: jax.Array,
                  usable: jax.Array) -> jax.Array:
    """Algorithm 4's loop set: live nodes with >=1 deleted out-neighbor.

    One O(N*R) gather/compare over the adjacency — the reverse-edge pass
    (in-neighbors of D) and D's own out-balls collapse into this forward
    scan because an edge p->v with v in D *is* p being an in-neighbor of
    D.  Rows outside the mask are untouched by the repair (their
    ``nbr_del.any()`` select keeps the old row), so repairing only the
    masked rows is bit-identical to the global sweep.
    """
    safe = jnp.maximum(adjacency, 0)
    nbr_del = (adjacency >= 0) & deleted[safe]
    return usable & nbr_del.any(axis=1)


def repair_cap_overflow(adjacency: jax.Array, deleted: jax.Array,
                        usable: jax.Array, cap: int) -> jax.Array:
    """Count live nodes whose deleted out-neighbors exceed the SDC
    expansion cap — each such node's repair silently dropped >=1
    expansion ball (``_repair_block_codes``).  Surfaced as
    ``SystemStats.repair_cap_overflows``; deleted edges are still pruned
    from the kept set regardless (the keep-mask is uncapped)."""
    safe = jnp.maximum(adjacency, 0)
    nbr_del = (adjacency >= 0) & deleted[safe]
    return jnp.sum((jnp.sum(nbr_del, axis=1) > cap) & usable)


def _finish_consolidate(state: GraphState, adjacency: jax.Array) -> GraphState:
    """Shared consolidation tail: slot reclamation + entry-point upkeep.

    The start is re-picked when the current one is deleted, inactive, or
    already the empty sentinel; when NO live point remains the start
    becomes INVALID (searches then return empty instead of seeding from a
    garbage medoid of an all-false mask) and the next insert re-seeds it.
    """
    adjacency = jnp.where(state.deleted[:, None], INVALID, adjacency)
    active = state.active & ~state.deleted
    stale = (state.start < 0) | state.deleted[state.start] \
        | ~state.active[state.start]
    start = jnp.where(
        active.any(),
        jnp.where(stale, medoid(state.vectors, active), state.start),
        INVALID).astype(jnp.int32)
    return state._replace(
        adjacency=adjacency, active=active,
        deleted=jnp.zeros_like(state.deleted), start=start)


def _scatter_repaired(adjacency, rows_fn, aff: np.ndarray, block: int, R):
    """Localized sweep body: pad the affected ids to a block multiple,
    repair block-by-block with the SAME engine as the global sweep
    (``rows_fn`` maps [n_blocks, block] ids -> repaired rows), and
    scatter the rows back.  Padding duplicates ``aff[0]`` — duplicate
    scatter indices write identical repaired rows, so the result is
    well-defined."""
    n_blocks = -(-len(aff) // block)
    padded = np.full(n_blocks * block, aff[0], dtype=np.int32)
    padded[:len(aff)] = aff
    ids = jnp.asarray(padded).reshape(n_blocks, block)
    rows = rows_fn(ids)
    return adjacency.at[ids.reshape(-1)].set(rows.reshape(-1, R))


def _repair_block(adjacency, prune_table, deleted, usable, node_ids, alpha, R):
    """Repair one block of nodes; returns new adjacency rows for the block.

    The jnp oracle path (``use_kernel=False``): per-node candidate assembly
    + ``prune_node`` (R sequential rounds as XLA loop steps)."""

    def one(p):
        row = adjacency[p]                                        # [R]
        safe = jnp.maximum(row, 0)
        valid = row >= 0
        nbr_del = valid & deleted[safe]
        keep = jnp.where(valid & ~nbr_del, row, INVALID)
        # neighbors of deleted neighbors
        exp = adjacency[safe]                                     # [R, R]
        exp = jnp.where(nbr_del[:, None], exp, INVALID)
        cand = jnp.concatenate([keep, exp.reshape(-1)])           # [R + R*R]
        new_row = prune_node(prune_table, p, cand, usable, alpha, R).ids
        # Only live nodes with >=1 deleted neighbor change (Alg. 4 loop set).
        live = usable[p]
        return jnp.where(live & nbr_del.any(), new_row, row)

    return jax.vmap(one)(node_ids)


def _repair_block_kernel(adjacency, prune_table, deleted, usable, node_ids,
                         alpha, R):
    """Kernel path: gathers stay in XLA; masks + R prune rounds + the
    changed-row select fuse into ONE ``delete_repair_fp`` launch for the
    whole block.  Bit-identical to ``_repair_block``."""
    rows = adjacency[node_ids]                                   # [B, R]
    safe = jnp.maximum(rows, 0)
    nbr_del = (rows >= 0) & deleted[safe]
    exp = adjacency[safe]                                        # [B, R, R]
    B = rows.shape[0]
    raw = jnp.concatenate([rows, exp.reshape(B, -1)], axis=1)
    safe_raw = jnp.maximum(raw, 0)
    cand_vecs = prune_table[safe_raw].astype(jnp.float32)        # [B, C, d]
    d_p = l2_sq(prune_table[node_ids][:, None, :].astype(jnp.float32),
                cand_vecs)
    return ops.delete_repair_fp(
        rows, nbr_del, exp, nbr_del, usable[safe_raw], d_p, cand_vecs,
        node_ids, usable[node_ids], alpha=alpha, R=R, use_kernel=True)


@partial(jax.jit, static_argnames=("alpha", "R", "kernel"))
def _repair_blocks_fp(adjacency, table, deleted, usable, ids, alpha, R,
                      kernel):
    """Blocked full-precision repair sweep, jitted ONCE per (shape,
    alpha, R, engine) so repeated consolidations — standalone calls, the
    localized merge path — reuse the compiled program instead of paying a
    prune-engine retrace per call.  Nested jit inlines, so the fused
    merge program is unchanged."""
    repair = _repair_block_kernel if kernel else _repair_block

    def run(b):
        return repair(adjacency, table, deleted, usable, b, alpha, R)

    return jax.lax.map(run, ids)


def consolidate_deletes(state: GraphState, cfg: IndexConfig,
                        block: int = 256,
                        prune_table: jax.Array | None = None,
                        mode: str | None = None) -> GraphState:
    """Algorithm 4 (global or localized sweep), then slot reclamation.

    prune_table: distance table for RobustPrune — full-precision vectors by
    default; the StreamingMerge delete phase passes PQ-decoded vectors instead
    (paper §5.3 Delete Phase).
    mode: ``"global"`` | ``"local"`` (None -> ``cfg.repair_mode``).  The
    localized sweep repairs only the affected rows — bit-identical output,
    but the affected ids round-trip through the host so it must not be
    called under an enclosing ``jit``.
    """
    N = state.capacity
    table = state.vectors if prune_table is None else prune_table
    usable = state.active & ~state.deleted

    def rows_fn(ids):
        return _repair_blocks_fp(state.adjacency, table, state.deleted,
                                 usable, ids, cfg.alpha, cfg.R,
                                 cfg.kernel_enabled())

    if (cfg.repair_mode if mode is None else mode) == "local":
        aff = np.nonzero(np.asarray(
            affected_mask(state.adjacency, state.deleted, usable)))[0]
        adjacency = (state.adjacency if len(aff) == 0 else
                     _scatter_repaired(state.adjacency, rows_fn, aff,
                                       block, cfg.R))
    else:
        n_blocks = -(-N // block)
        pad = n_blocks * block
        ids = jnp.arange(pad, dtype=jnp.int32).clip(0, N - 1).reshape(
            n_blocks, block)
        adjacency = rows_fn(ids).reshape(pad, cfg.R)[:N]
    # Reclaim: deleted slots become free (edges cleared, flags reset).
    return _finish_consolidate(state, adjacency)


def _repair_block_codes(adjacency, codes, tables, deleted, usable, node_ids,
                        alpha, R, cap):
    """SDC repair: distances from PQ codes; at most ``cap`` deleted
    neighbors expanded per node (candidate width R + cap*R instead of
    R + R^2 — random deletes of 5-10%% make >cap deleted neighbors
    vanishingly rare, and overflow only costs a few candidate edges)."""
    from .prune import prune_node_codes

    def one(p):
        row = adjacency[p]                                    # [R]
        safe = jnp.maximum(row, 0)
        valid = row >= 0
        nbr_del = valid & deleted[safe]
        keep = jnp.where(valid & ~nbr_del, row, INVALID)
        take, idx = jax.lax.top_k(nbr_del.astype(jnp.int32), cap)
        dn = jnp.where(take > 0, row[idx], 0)
        exp = adjacency[dn]                                   # [cap, R]
        exp = jnp.where((take > 0)[:, None], exp, INVALID)
        cand = jnp.concatenate([keep, exp.reshape(-1)])       # [R + cap*R]
        new_row = prune_node_codes(codes, tables, p, cand, usable,
                                   alpha, R).ids
        live = usable[p]
        return jnp.where(live & nbr_del.any(), new_row, row)

    return jax.vmap(one)(node_ids)


def _repair_block_codes_kernel(adjacency, codes, tables, deleted, usable,
                               node_ids, alpha, R, cap):
    """Kernel path of the capped SDC repair — one fused
    ``delete_repair_sdc`` launch for the whole block.  Bit-identical to
    ``_repair_block_codes``."""
    from . import pq as pqm

    rows = adjacency[node_ids]                                   # [B, R]
    safe = jnp.maximum(rows, 0)
    nbr_del = (rows >= 0) & deleted[safe]
    take, idx = jax.lax.top_k(nbr_del.astype(jnp.int32), cap)    # [B, cap]
    dn = jnp.where(take > 0, jnp.take_along_axis(rows, idx, axis=1), 0)
    exp = adjacency[dn]                                          # [B, cap, R]
    B = rows.shape[0]
    raw = jnp.concatenate([rows, exp.reshape(B, -1)], axis=1)
    safe_raw = jnp.maximum(raw, 0)
    cand_codes = codes[safe_raw].astype(jnp.int32)               # [B, C, m]
    d_p = jax.vmap(lambda sr, p: pqm.adc(codes[sr],
                                         pqm.sdc_lut(tables, codes[p])))(
        safe_raw, node_ids)
    return ops.delete_repair_sdc(
        rows, nbr_del, exp, take > 0, usable[safe_raw], d_p, cand_codes,
        tables, node_ids, usable[node_ids], alpha=alpha, R=R,
        use_kernel=True)


@partial(jax.jit, static_argnames=("alpha", "R", "cap", "kernel"))
def _repair_blocks_codes(adjacency, codes, tables, deleted, usable, ids,
                         alpha, R, cap, kernel):
    """SDC twin of ``_repair_blocks_fp`` — same jit-cache rationale."""
    repair = (_repair_block_codes_kernel if kernel
              else _repair_block_codes)

    def run(b):
        return repair(adjacency, codes, tables, deleted, usable, b,
                      alpha, R, cap)

    return jax.lax.map(run, ids)


def consolidate_deletes_codes(state: GraphState, cfg: IndexConfig,
                              codes: jax.Array, tables: jax.Array,
                              block: int = 1024,
                              cap: int = 8,
                              mode: str | None = None) -> GraphState:
    """Algorithm 4 with SDC distances (StreamingMerge delete phase at its
    traffic-optimal operating point — see EXPERIMENTS.md §Perf)."""
    N = state.capacity
    usable = state.active & ~state.deleted

    def rows_fn(ids):
        return _repair_blocks_codes(state.adjacency, codes, tables,
                                    state.deleted, usable, ids, cfg.alpha,
                                    cfg.R, cap, cfg.kernel_enabled())

    if (cfg.repair_mode if mode is None else mode) == "local":
        aff = np.nonzero(np.asarray(
            affected_mask(state.adjacency, state.deleted, usable)))[0]
        adjacency = (state.adjacency if len(aff) == 0 else
                     _scatter_repaired(state.adjacency, rows_fn, aff,
                                       block, cfg.R))
    else:
        n_blocks = -(-N // block)
        pad = n_blocks * block
        ids = jnp.arange(pad, dtype=jnp.int32).clip(0, N - 1).reshape(
            n_blocks, block)
        adjacency = rows_fn(ids).reshape(pad, cfg.R)[:N]
    return _finish_consolidate(state, adjacency)


# ----------------------------------------------------------------------------
# Naive baselines from §3.3 — used to reproduce Figure 1 (quality collapse).
# ----------------------------------------------------------------------------

def consolidate_policy_a(state: GraphState) -> GraphState:
    """Delete Policy A: drop all edges incident to deleted nodes, add nothing.

    Entry-point upkeep is the shared ``_finish_consolidate`` tail — the
    predicate matches ``consolidate_deletes`` (deleted OR already-inactive
    start is re-picked; an inactive start used to survive Policy A and
    seed searches from a dead node)."""
    safe = jnp.maximum(state.adjacency, 0)
    nbr_del = (state.adjacency >= 0) & state.deleted[safe]
    adjacency = jnp.where(nbr_del, INVALID, state.adjacency)
    return _finish_consolidate(state, adjacency)


def consolidate_policy_b(state: GraphState, cfg: IndexConfig,
                         block: int = 256) -> GraphState:
    """Delete Policy B: local patching with the aggressive alpha=1 prune."""
    cfg1 = IndexConfig(**{**cfg.__dict__, "alpha": 1.0})
    return consolidate_deletes(state, cfg1, block=block)
