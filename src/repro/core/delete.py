r"""Deletion: lazy DeleteList + consolidation (Algorithm 4).

``delete`` only marks nodes (paper §4.2 — deletes return in ~0.1us; deleted
nodes stay navigable but are filtered from results).  ``consolidate_deletes``
is the batched graph repair: every live node p with deleted out-neighbors gets

    C  <-  (N_out(p) u  U_{v in N_out(p) n D} N_out(v)) \ D \ {p}
    N_out(p)  <-  RobustPrune(p, C, alpha, R)

The pass is blocked (``lax.map`` over node blocks) — the TPU rendition of the
paper's sequential block-by-block SSD scan: one block of adjacency rows is
streamed HBM->VMEM, repaired in parallel, written back.  Under
``IndexConfig.use_kernel`` each block's repair is ONE fused Pallas launch
(``kernels.delete_repair``: candidate assembly + all R prune rounds +
changed-row select, vectorized across the block's rows); the pre-engine
jnp blocks are kept verbatim as the bit-parity oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import IndexConfig
from .distance import INVALID, l2_sq
from .graph import GraphState, medoid
from .prune import prune_node


def delete(state: GraphState, slots: jax.Array) -> GraphState:
    """Lazy delete: add to DeleteList (no graph edits)."""
    ok = slots >= 0
    safe = jnp.where(ok, slots, 0)
    deleted = state.deleted.at[safe].set(
        jnp.where(ok, True, state.deleted[safe]))
    return state._replace(deleted=deleted)


def _repair_block(adjacency, prune_table, deleted, usable, node_ids, alpha, R):
    """Repair one block of nodes; returns new adjacency rows for the block.

    The jnp oracle path (``use_kernel=False``): per-node candidate assembly
    + ``prune_node`` (R sequential rounds as XLA loop steps)."""

    def one(p):
        row = adjacency[p]                                        # [R]
        safe = jnp.maximum(row, 0)
        valid = row >= 0
        nbr_del = valid & deleted[safe]
        keep = jnp.where(valid & ~nbr_del, row, INVALID)
        # neighbors of deleted neighbors
        exp = adjacency[safe]                                     # [R, R]
        exp = jnp.where(nbr_del[:, None], exp, INVALID)
        cand = jnp.concatenate([keep, exp.reshape(-1)])           # [R + R*R]
        new_row = prune_node(prune_table, p, cand, usable, alpha, R).ids
        # Only live nodes with >=1 deleted neighbor change (Alg. 4 loop set).
        live = usable[p]
        return jnp.where(live & nbr_del.any(), new_row, row)

    return jax.vmap(one)(node_ids)


def _repair_block_kernel(adjacency, prune_table, deleted, usable, node_ids,
                         alpha, R):
    """Kernel path: gathers stay in XLA; masks + R prune rounds + the
    changed-row select fuse into ONE ``delete_repair_fp`` launch for the
    whole block.  Bit-identical to ``_repair_block``."""
    rows = adjacency[node_ids]                                   # [B, R]
    safe = jnp.maximum(rows, 0)
    nbr_del = (rows >= 0) & deleted[safe]
    exp = adjacency[safe]                                        # [B, R, R]
    B = rows.shape[0]
    raw = jnp.concatenate([rows, exp.reshape(B, -1)], axis=1)
    safe_raw = jnp.maximum(raw, 0)
    cand_vecs = prune_table[safe_raw].astype(jnp.float32)        # [B, C, d]
    d_p = l2_sq(prune_table[node_ids][:, None, :].astype(jnp.float32),
                cand_vecs)
    return ops.delete_repair_fp(
        rows, nbr_del, exp, nbr_del, usable[safe_raw], d_p, cand_vecs,
        node_ids, usable[node_ids], alpha=alpha, R=R, use_kernel=True)


def consolidate_deletes(state: GraphState, cfg: IndexConfig,
                        block: int = 256,
                        prune_table: jax.Array | None = None) -> GraphState:
    """Algorithm 4 over the whole index, then slot reclamation.

    prune_table: distance table for RobustPrune — full-precision vectors by
    default; the StreamingMerge delete phase passes PQ-decoded vectors instead
    (paper §5.3 Delete Phase).
    """
    N = state.capacity
    table = state.vectors if prune_table is None else prune_table
    usable = state.active & ~state.deleted
    n_blocks = -(-N // block)
    pad = n_blocks * block
    ids = jnp.arange(pad, dtype=jnp.int32).clip(0, N - 1).reshape(n_blocks, block)
    repair = (_repair_block_kernel if cfg.kernel_enabled()
              else _repair_block)

    rows = jax.lax.map(
        lambda b: repair(state.adjacency, table, state.deleted,
                         usable, b, cfg.alpha, cfg.R),
        ids)
    adjacency = rows.reshape(pad, cfg.R)[:N]
    # Reclaim: deleted slots become free (edges cleared, flags reset).
    adjacency = jnp.where(state.deleted[:, None], INVALID, adjacency)
    active = state.active & ~state.deleted
    start = jnp.where(
        state.deleted[state.start] | ~state.active[state.start],
        medoid(state.vectors, active), state.start).astype(jnp.int32)
    return state._replace(
        adjacency=adjacency, active=active,
        deleted=jnp.zeros_like(state.deleted), start=start)


def _repair_block_codes(adjacency, codes, tables, deleted, usable, node_ids,
                        alpha, R, cap):
    """SDC repair: distances from PQ codes; at most ``cap`` deleted
    neighbors expanded per node (candidate width R + cap*R instead of
    R + R^2 — random deletes of 5-10%% make >cap deleted neighbors
    vanishingly rare, and overflow only costs a few candidate edges)."""
    from .prune import prune_node_codes

    def one(p):
        row = adjacency[p]                                    # [R]
        safe = jnp.maximum(row, 0)
        valid = row >= 0
        nbr_del = valid & deleted[safe]
        keep = jnp.where(valid & ~nbr_del, row, INVALID)
        take, idx = jax.lax.top_k(nbr_del.astype(jnp.int32), cap)
        dn = jnp.where(take > 0, row[idx], 0)
        exp = adjacency[dn]                                   # [cap, R]
        exp = jnp.where((take > 0)[:, None], exp, INVALID)
        cand = jnp.concatenate([keep, exp.reshape(-1)])       # [R + cap*R]
        new_row = prune_node_codes(codes, tables, p, cand, usable,
                                   alpha, R).ids
        live = usable[p]
        return jnp.where(live & nbr_del.any(), new_row, row)

    return jax.vmap(one)(node_ids)


def _repair_block_codes_kernel(adjacency, codes, tables, deleted, usable,
                               node_ids, alpha, R, cap):
    """Kernel path of the capped SDC repair — one fused
    ``delete_repair_sdc`` launch for the whole block.  Bit-identical to
    ``_repair_block_codes``."""
    from . import pq as pqm

    rows = adjacency[node_ids]                                   # [B, R]
    safe = jnp.maximum(rows, 0)
    nbr_del = (rows >= 0) & deleted[safe]
    take, idx = jax.lax.top_k(nbr_del.astype(jnp.int32), cap)    # [B, cap]
    dn = jnp.where(take > 0, jnp.take_along_axis(rows, idx, axis=1), 0)
    exp = adjacency[dn]                                          # [B, cap, R]
    B = rows.shape[0]
    raw = jnp.concatenate([rows, exp.reshape(B, -1)], axis=1)
    safe_raw = jnp.maximum(raw, 0)
    cand_codes = codes[safe_raw].astype(jnp.int32)               # [B, C, m]
    d_p = jax.vmap(lambda sr, p: pqm.adc(codes[sr],
                                         pqm.sdc_lut(tables, codes[p])))(
        safe_raw, node_ids)
    return ops.delete_repair_sdc(
        rows, nbr_del, exp, take > 0, usable[safe_raw], d_p, cand_codes,
        tables, node_ids, usable[node_ids], alpha=alpha, R=R,
        use_kernel=True)


def consolidate_deletes_codes(state: GraphState, cfg: IndexConfig,
                              codes: jax.Array, tables: jax.Array,
                              block: int = 1024,
                              cap: int = 8) -> GraphState:
    """Algorithm 4 with SDC distances (StreamingMerge delete phase at its
    traffic-optimal operating point — see EXPERIMENTS.md §Perf)."""
    N = state.capacity
    usable = state.active & ~state.deleted
    n_blocks = -(-N // block)
    pad = n_blocks * block
    ids = jnp.arange(pad, dtype=jnp.int32).clip(0, N - 1).reshape(
        n_blocks, block)
    repair = (_repair_block_codes_kernel if cfg.kernel_enabled()
              else _repair_block_codes)
    rows = jax.lax.map(
        lambda b: repair(state.adjacency, codes, tables,
                         state.deleted, usable, b,
                         cfg.alpha, cfg.R, cap),
        ids)
    adjacency = rows.reshape(pad, cfg.R)[:N]
    adjacency = jnp.where(state.deleted[:, None], INVALID, adjacency)
    active = state.active & ~state.deleted
    start = jnp.where(
        state.deleted[state.start] | ~state.active[state.start],
        medoid(state.vectors, active), state.start).astype(jnp.int32)
    return state._replace(
        adjacency=adjacency, active=active,
        deleted=jnp.zeros_like(state.deleted), start=start)


# ----------------------------------------------------------------------------
# Naive baselines from §3.3 — used to reproduce Figure 1 (quality collapse).
# ----------------------------------------------------------------------------

def consolidate_policy_a(state: GraphState) -> GraphState:
    """Delete Policy A: drop all edges incident to deleted nodes, add nothing."""
    safe = jnp.maximum(state.adjacency, 0)
    nbr_del = (state.adjacency >= 0) & state.deleted[safe]
    adjacency = jnp.where(nbr_del, INVALID, state.adjacency)
    adjacency = jnp.where(state.deleted[:, None], INVALID, adjacency)
    active = state.active & ~state.deleted
    start = jnp.where(state.deleted[state.start],
                      medoid(state.vectors, active),
                      state.start).astype(jnp.int32)
    return state._replace(adjacency=adjacency, active=active,
                          deleted=jnp.zeros_like(state.deleted), start=start)


def consolidate_policy_b(state: GraphState, cfg: IndexConfig,
                         block: int = 256) -> GraphState:
    """Delete Policy B: local patching with the aggressive alpha=1 prune."""
    cfg1 = IndexConfig(**{**cfg.__dict__, "alpha": 1.0})
    return consolidate_deletes(state, cfg1, block=block)
