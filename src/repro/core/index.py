"""FreshVamana — the in-memory index (paper §4): build, insert, delete,
consolidate, search.  Functional core over ``GraphState``; every entry point
jit-compiles with static shapes.

``unified_search`` is the one-program §5.2 fan-out every stage of which is
vmapped over the query axis — the device half of the batched serving engine
(``system.search_batch``; serving guide: docs/SERVING.md).  Under
``SystemConfig.shard_lti`` the same program shape runs with the LTI lane
mesh-sharded (``serving.steps.make_sharded_unified_step``), reusing
``search_lanes`` / ``lanes_to_ext`` / ``fanout_merge`` from here.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import pq as pqm
from .config import IndexConfig
from .distance import INVALID
from .graph import GraphState, LaneStack, empty_graph, medoid
from .insert import apply_back_edges, compute_insert_edges
from .search import (FullPrecisionBackend, PQBackend, batch_distances,
                     beam_search, rerank_candidates, topk_results)


@functools.partial(jax.jit, static_argnames=("cfg", "L", "reprune"))
def insert(state: GraphState, slots: jax.Array, vecs: jax.Array,
           cfg: IndexConfig, L: Optional[int] = None,
           reprune: bool = False) -> GraphState:
    """Insert a batch (Algorithm 2).  ``slots`` may contain INVALID (masked
    lanes — used by the distributed routed insert).  With ``reprune`` the
    points may already be in the graph (second build pass): their out-rows are
    recomputed rather than appended."""
    L = L or cfg.L_build
    valid = slots >= 0
    wslots = jnp.where(valid, slots, state.capacity)  # OOB -> dropped scatter
    vectors = state.vectors.at[wslots].set(
        vecs.astype(state.vectors.dtype), mode="drop")
    active = state.active.at[wslots].set(True, mode="drop")
    deleted = state.deleted.at[wslots].set(False, mode="drop")
    # Re-seed the entry point when it is the empty sentinel (a consolidate
    # that deleted every live point leaves start=INVALID): the first valid
    # inserted slot becomes the new start so this batch's edge searches —
    # and every later search — have a live seed again.
    first_valid = jnp.where(valid.any(),
                            slots[jnp.argmax(valid)], state.start)
    start = jnp.where(state.start < 0, first_valid,
                      state.start).astype(jnp.int32)
    st = state._replace(
        vectors=vectors, active=active, deleted=deleted, start=start,
        n_total=jnp.maximum(state.n_total,
                            jnp.max(jnp.where(valid, slots, -1)) + 1))
    usable = st.active & ~st.deleted
    edges = compute_insert_edges(
        state.adjacency if not reprune else st.adjacency,
        st.active, usable, st.start, st.vectors,
        jnp.where(valid, slots, INVALID), vecs,
        FullPrecisionBackend(st.vectors),
        L=L, max_visits=cfg.visits_bound(L), alpha=cfg.alpha, R=cfg.R,
        beam_width=cfg.beam_width, use_kernel=cfg.kernel_enabled())
    new_adj = jnp.where(valid[:, None], edges.new_adj, INVALID)
    adjacency = st.adjacency.at[wslots].set(new_adj, mode="drop")
    pairs_j = jnp.where(valid[:, None], edges.new_adj, INVALID).reshape(-1)
    adjacency = apply_back_edges(
        adjacency, st.vectors, usable, pairs_j, edges.pairs_p,
        alpha=cfg.alpha, R=cfg.R, use_kernel=cfg.kernel_enabled())
    return st._replace(adjacency=adjacency)


@functools.partial(jax.jit, static_argnames=("cfg", "L", "reprune"))
def insert_edges_stage(state: GraphState, slots: jax.Array, vecs: jax.Array,
                       cfg: IndexConfig, L: Optional[int] = None,
                       reprune: bool = False):
    """Stages 1+2 of ``insert`` as a standalone program: store the batch,
    search + prune its out-edges, scatter the new rows — returning the
    staged state plus the Delta pair list *without* applying it.

    ``insert_edges_stage`` followed by ``insert_apply_delta`` (with
    ``affected_cap=None``) is bit-identical to one ``insert`` call
    (tests/test_locality.py pins this).  The locality-ordered flush uses
    the split so it can measure the chunk's DISTINCT back-edge target count
    on the host between the stages and size the Delta prune launch to a
    matching power-of-two bucket instead of the worst case.
    """
    L = L or cfg.L_build
    valid = slots >= 0
    wslots = jnp.where(valid, slots, state.capacity)
    vectors = state.vectors.at[wslots].set(
        vecs.astype(state.vectors.dtype), mode="drop")
    active = state.active.at[wslots].set(True, mode="drop")
    deleted = state.deleted.at[wslots].set(False, mode="drop")
    first_valid = jnp.where(valid.any(),
                            slots[jnp.argmax(valid)], state.start)
    start = jnp.where(state.start < 0, first_valid,
                      state.start).astype(jnp.int32)
    st = state._replace(
        vectors=vectors, active=active, deleted=deleted, start=start,
        n_total=jnp.maximum(state.n_total,
                            jnp.max(jnp.where(valid, slots, -1)) + 1))
    usable = st.active & ~st.deleted
    edges = compute_insert_edges(
        state.adjacency if not reprune else st.adjacency,
        st.active, usable, st.start, st.vectors,
        jnp.where(valid, slots, INVALID), vecs,
        FullPrecisionBackend(st.vectors),
        L=L, max_visits=cfg.visits_bound(L), alpha=cfg.alpha, R=cfg.R,
        beam_width=cfg.beam_width, use_kernel=cfg.kernel_enabled())
    new_adj = jnp.where(valid[:, None], edges.new_adj, INVALID)
    adjacency = st.adjacency.at[wslots].set(new_adj, mode="drop")
    pairs_j = new_adj.reshape(-1)
    return st._replace(adjacency=adjacency), pairs_j, edges.pairs_p


@functools.partial(jax.jit, static_argnames=("cfg", "affected_cap"))
def insert_apply_delta(state: GraphState, pairs_j: jax.Array,
                       pairs_p: jax.Array, cfg: IndexConfig,
                       affected_cap: Optional[int] = None) -> GraphState:
    """Stage 3 of ``insert``: apply the staged Delta pair list.

    ``affected_cap`` (static) sizes the grouped prune launch; the caller
    must guarantee cap >= distinct(pairs_j) or affected rows are silently
    dropped (``insert._apply_back_edges_impl``).  None = worst case,
    completing the bit-identical replication of ``insert``.
    """
    usable = state.active & ~state.deleted
    adjacency = apply_back_edges(
        state.adjacency, state.vectors, usable, pairs_j, pairs_p,
        alpha=cfg.alpha, R=cfg.R, use_kernel=cfg.kernel_enabled(),
        affected_cap=affected_cap)
    return state._replace(adjacency=adjacency)


def _search_impl(state: GraphState, queries: jax.Array, cfg: IndexConfig,
                 *, k: int, L: int, beam_width: Optional[int]):
    res = beam_search(state.adjacency, state.active, state.start, queries,
                      FullPrecisionBackend(state.vectors),
                      L=L, max_visits=cfg.visits_bound(L),
                      beam_width=beam_width or cfg.beam_width,
                      use_kernel=cfg.kernel_enabled())
    ids, d = topk_results(res, k, state.active & ~state.deleted)
    return ids, d, res.n_hops, res.n_cmps


@functools.partial(jax.jit, static_argnames=("cfg", "k", "L", "beam_width"))
def search(state: GraphState, queries: jax.Array, cfg: IndexConfig,
           *, k: int, L: int, beam_width: Optional[int] = None):
    """Batched search; returns (ids [B,k], dists [B,k], hops [B], cmps [B]).

    ``hops`` counts IO rounds: with ``beam_width`` W each round expands up to
    W frontier nodes, so hops drop ~W-fold vs the W=1 classic search.
    """
    return _search_impl(state, queries, cfg, k=k, L=L, beam_width=beam_width)


@functools.partial(jax.jit, static_argnames=("cfg", "k", "L", "beam_width"))
def search_tiers(states: GraphState, queries: jax.Array, cfg: IndexConfig,
                 *, k: int, L: int, beam_width: Optional[int] = None):
    """Multi-tier fan-out: one vmapped search over T stacked graphs.

    ``states`` is a GraphState pytree with [T, ...] leaves (from
    ``graph.stack_graphs``); every tier is searched with the same query
    batch in a single device step, so wall-clock no longer scales linearly
    in the number of RO snapshots.  Returns (ids [T,B,k], dists [T,B,k],
    hops [T,B], cmps [T,B]) — per-lane results bit-identical to running
    ``search`` tier by tier.
    """
    def one(st):
        return _search_impl(st, queries, cfg, k=k, L=L,
                            beam_width=beam_width)

    return jax.vmap(one)(states)


@functools.partial(jax.jit, static_argnames=("cfg", "k", "L", "beam_width",
                                             "rerank"))
def search_lanes(stack: LaneStack, queries: jax.Array, cfg: IndexConfig,
                 *, k: int, L: int, beam_width: Optional[int] = None,
                 rerank: bool = True):
    """Heterogeneous-lane fan-out: every live tier in one device program.

    The temp group runs as one vmapped exact-L2 search over the [Tt, ...]
    stack; the LTI lane (if present) runs PQ-ADC navigation at its own
    capacity in the same program.  With ``rerank`` the LTI lane's final
    candidate list gets the exact full-precision rerank *in-program*
    (DeleteList members masked before the gather, matching the
    ``search_lti`` contract).  Returns (ids [T,B,k], dists [T,B,k],
    hops [T,B], cmps [T,B]) with the LTI as the LAST lane — lane t
    bit-identical to running the dedicated engine (``search`` /
    ``search_lti``) on tier t alone.
    """
    use_kernel = cfg.kernel_enabled()
    outs = []
    if stack.temps is not None:
        def one(g: GraphState):
            return _search_impl(g, queries, cfg, k=k, L=L,
                                beam_width=beam_width)

        outs.append(jax.vmap(one)(stack.temps))
    if stack.lti is not None:
        g = stack.lti
        res = beam_search(g.adjacency, g.active, g.start, queries,
                          PQBackend(stack.codes, pqm.PQCodebook(
                              stack.codebook)),
                          L=L, max_visits=cfg.visits_bound(L),
                          beam_width=beam_width or cfg.beam_width,
                          use_kernel=use_kernel)
        reportable = g.active & ~g.deleted
        if rerank:
            exact = batch_distances(
                FullPrecisionBackend(g.vectors), queries,
                rerank_candidates(res.ids, reportable),
                use_kernel=use_kernel)
            res = res._replace(dists=exact)
        ids, d = topk_results(res, k, reportable)
        outs.append(tuple(x[None] for x in (ids, d, res.n_hops,
                                            res.n_cmps)))
    if not outs:
        raise ValueError("search_lanes: empty LaneStack")
    return tuple(jnp.concatenate(parts, axis=0) for parts in zip(*outs))


def lanes_to_ext(tables: jax.Array, drop: jax.Array, slot_ids: jax.Array,
                 dists: jax.Array):
    """Slot->external-id map + DeleteList mask for one lane group.

    tables [G, capacity] int32/int64, drop [G, capacity] bool,
    slot_ids/dists [G, B, C] -> (ext [G, B, C], dists with DeleteList
    members inf'd out).  The device half of the §5.2 aggregation that
    depends on a lane's capacity; groups of different capacities map
    separately and meet in ``fanout_merge``.
    """

    def one(tab, dr, sl, d):
        s = jnp.maximum(sl, 0)
        ext = jnp.where(sl >= 0, tab[s], -1)
        dead = (sl >= 0) & dr[s]
        return ext, jnp.where(dead, jnp.inf, d)

    return jax.vmap(one)(tables, drop, slot_ids, dists)


def fanout_merge(ids: jax.Array, ds: jax.Array, *, k: int):
    """On-device cross-tier merge (the device half of §5.2 aggregation).

    ids/ds [B, M] — every lane's externally-mapped candidates concatenated
    (``lanes_to_ext`` output, flattened lane-major).  Dedupes cross-tier
    copies keeping the closest instance and returns the global top-k per
    query: (ext_ids [B, k], dists [B, k] f32) with (-1, +inf) padding.
    Bit-identical to the host-side ``FreshDiskANN._aggregate`` on the same
    per-lane inputs; ids may be int32 or int64 (``jax_enable_x64``).
    """
    ds = jnp.where(ids < 0, jnp.inf, ds.astype(jnp.float32))
    # Dedupe keeping the closest copy of each id, then rank by distance —
    # the same lexsort / dup-mask / stable-argsort sequence as _aggregate.
    order = jnp.lexsort((ds, ids))
    sid = jnp.take_along_axis(ids, order, axis=1)
    sd = jnp.take_along_axis(ds, order, axis=1)
    dup = jnp.zeros(sid.shape, bool).at[:, 1:].set(
        (sid[:, 1:] == sid[:, :-1]) & (sid[:, 1:] >= 0))
    sd = jnp.where(dup, jnp.inf, sd)
    top = jnp.argsort(sd, axis=1, stable=True)[:, :k]
    rd = jnp.take_along_axis(sd, top, axis=1)
    ri = jnp.where(jnp.isfinite(rd),
                   jnp.take_along_axis(sid, top, axis=1), -1)
    return ri, jnp.where(jnp.isfinite(rd), rd, jnp.inf)


@functools.partial(jax.jit, static_argnames=("cfg", "k", "k_lane", "L",
                                             "beam_width", "rerank"))
def unified_search(stack: LaneStack, temp_tables: Optional[jax.Array],
                   lti_table: Optional[jax.Array],
                   temp_drop: Optional[jax.Array],
                   lti_drop: Optional[jax.Array],
                   queries: jax.Array, cfg: IndexConfig, *, k: int,
                   k_lane: int, L: int, beam_width: Optional[int] = None,
                   rerank: bool = True):
    """The whole §5.2 steady-state query as ONE jitted device program.

    Beam-searches every lane (TempIndex tiers on exact L2, vmapped at temp
    capacity; the LTI lane on PQ ADC at its own capacity), exact-reranks
    the LTI lane's candidates, takes the per-lane top-``k_lane``, maps each
    group's slots to external ids against its own table
    (``temp_tables`` [Tt, temp_cap], ``lti_table`` [lti_cap]), filters the
    DeleteList (``temp_drop``/``lti_drop``), and merges to the global
    top-``k`` — all on-device, one dispatch per query batch however many
    tiers are live.  Returns (ext_ids [B, k], dists [B, k], hops [T, B],
    cmps [T, B]); the per-lane counters feed the beam-width autotuner's
    unified cost model.
    """
    ids, d, hops, cmps = search_lanes(stack, queries, cfg, k=k_lane, L=L,
                                      beam_width=beam_width, rerank=rerank)
    B = queries.shape[0]
    Tt = stack.n_temp_lanes
    parts_i, parts_d = [], []
    if stack.temps is not None:
        ext, dd = lanes_to_ext(temp_tables, temp_drop, ids[:Tt], d[:Tt])
        parts_i.append(jnp.transpose(ext, (1, 0, 2)).reshape(B, -1))
        parts_d.append(jnp.transpose(dd, (1, 0, 2)).reshape(B, -1))
    if stack.lti is not None:
        ext, dd = lanes_to_ext(lti_table[None], lti_drop[None],
                               ids[Tt:], d[Tt:])
        parts_i.append(ext[0])
        parts_d.append(dd[0])
    mi, md = fanout_merge(jnp.concatenate(parts_i, axis=1),
                          jnp.concatenate(parts_d, axis=1), k=k)
    return mi, md, hops, cmps


def build(vectors: np.ndarray | jax.Array, cfg: IndexConfig,
          batch: int = 256, passes: int = 1, seed: int = 0,
          shuffle: bool = True) -> GraphState:
    """Static build = streamed FreshVamana inserts (paper App. B: this is the
    *FreshVamana build*; ``passes=2`` adds the Vamana-style refinement pass).

    The batch size is capped at n//8: points inside one batch cannot see
    each other (quiescent-consistency window), so a single-batch build
    would degenerate to a star around the medoid."""
    n, d = vectors.shape
    assert n <= cfg.capacity and d == cfg.dim
    batch = max(16, min(batch, n // 8)) if n >= 32 else max(1, n // 2)
    vecs = jnp.asarray(vectors)
    state = empty_graph(cfg)
    state = state._replace(
        vectors=state.vectors.at[:n].set(vecs.astype(state.vectors.dtype)))
    # Entry point = medoid of the build set (active yet or not — vectors are
    # stored; medoid over the first n rows).
    mask = jnp.zeros((cfg.capacity,), bool).at[:n].set(True)
    start = medoid(state.vectors, mask)
    # Seed: the medoid point is active with no edges.
    state = state._replace(
        active=state.active.at[start].set(True),
        start=start, n_total=jnp.int32(n))

    rng = np.random.default_rng(seed)
    order = rng.permutation(n) if shuffle else np.arange(n)
    for pass_i in range(passes):
        reprune = pass_i > 0
        for lo in range(0, n, batch):
            sl = order[lo:lo + batch]
            pad = batch - len(sl)
            slots = np.concatenate([sl, np.full(pad, INVALID)]).astype(np.int32)
            bv = np.zeros((batch, d), np.float32)
            bv[:len(sl)] = np.asarray(vectors)[sl]
            state = insert(state, jnp.asarray(slots), jnp.asarray(bv), cfg,
                           reprune=reprune)
    return state


def brute_force(vectors: jax.Array, mask: jax.Array, queries: jax.Array,
                k: int) -> jax.Array:
    """Exact k-NN over masked rows — ground truth for every recall number."""
    from .distance import l2_sq_batch
    d = l2_sq_batch(queries, vectors)
    d = jnp.where(mask[None, :], d, jnp.inf)
    return jax.lax.top_k(-d, k)[1]


def recall_at_k(found_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """k-recall@k (Definition 1.1): |X ∩ G| / k averaged over queries."""
    k = true_ids.shape[1]
    eq = found_ids[:, :, None] == true_ids[:, None, :]
    inter = eq.any(axis=2) & (found_ids >= 0)
    return inter.sum(axis=1).mean() / k
