"""FreshVamana — the in-memory index (paper §4): build, insert, delete,
consolidate, search.  Functional core over ``GraphState``; every entry point
jit-compiles with static shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import IndexConfig
from .distance import INVALID
from .graph import GraphState, empty_graph, medoid
from .insert import apply_back_edges, compute_insert_edges
from .search import FullPrecisionBackend, beam_search, topk_results


@functools.partial(jax.jit, static_argnames=("cfg", "L", "reprune"))
def insert(state: GraphState, slots: jax.Array, vecs: jax.Array,
           cfg: IndexConfig, L: Optional[int] = None,
           reprune: bool = False) -> GraphState:
    """Insert a batch (Algorithm 2).  ``slots`` may contain INVALID (masked
    lanes — used by the distributed routed insert).  With ``reprune`` the
    points may already be in the graph (second build pass): their out-rows are
    recomputed rather than appended."""
    L = L or cfg.L_build
    valid = slots >= 0
    wslots = jnp.where(valid, slots, state.capacity)  # OOB -> dropped scatter
    vectors = state.vectors.at[wslots].set(
        vecs.astype(state.vectors.dtype), mode="drop")
    active = state.active.at[wslots].set(True, mode="drop")
    deleted = state.deleted.at[wslots].set(False, mode="drop")
    st = state._replace(
        vectors=vectors, active=active, deleted=deleted,
        n_total=jnp.maximum(state.n_total,
                            jnp.max(jnp.where(valid, slots, -1)) + 1))
    usable = st.active & ~st.deleted
    edges = compute_insert_edges(
        state.adjacency if not reprune else st.adjacency,
        st.active, usable, st.start, st.vectors,
        jnp.where(valid, slots, INVALID), vecs,
        FullPrecisionBackend(st.vectors),
        L=L, max_visits=cfg.visits_bound(L), alpha=cfg.alpha, R=cfg.R,
        beam_width=cfg.beam_width, use_kernel=cfg.kernel_enabled())
    new_adj = jnp.where(valid[:, None], edges.new_adj, INVALID)
    adjacency = st.adjacency.at[wslots].set(new_adj, mode="drop")
    pairs_j = jnp.where(valid[:, None], edges.new_adj, INVALID).reshape(-1)
    adjacency = apply_back_edges(
        adjacency, st.vectors, usable, pairs_j, edges.pairs_p,
        alpha=cfg.alpha, R=cfg.R)
    return st._replace(adjacency=adjacency)


def _search_impl(state: GraphState, queries: jax.Array, cfg: IndexConfig,
                 *, k: int, L: int, beam_width: Optional[int]):
    res = beam_search(state.adjacency, state.active, state.start, queries,
                      FullPrecisionBackend(state.vectors),
                      L=L, max_visits=cfg.visits_bound(L),
                      beam_width=beam_width or cfg.beam_width,
                      use_kernel=cfg.kernel_enabled())
    ids, d = topk_results(res, k, state.active & ~state.deleted)
    return ids, d, res.n_hops, res.n_cmps


@functools.partial(jax.jit, static_argnames=("cfg", "k", "L", "beam_width"))
def search(state: GraphState, queries: jax.Array, cfg: IndexConfig,
           *, k: int, L: int, beam_width: Optional[int] = None):
    """Batched search; returns (ids [B,k], dists [B,k], hops [B], cmps [B]).

    ``hops`` counts IO rounds: with ``beam_width`` W each round expands up to
    W frontier nodes, so hops drop ~W-fold vs the W=1 classic search.
    """
    return _search_impl(state, queries, cfg, k=k, L=L, beam_width=beam_width)


@functools.partial(jax.jit, static_argnames=("cfg", "k", "L", "beam_width"))
def search_tiers(states: GraphState, queries: jax.Array, cfg: IndexConfig,
                 *, k: int, L: int, beam_width: Optional[int] = None):
    """Multi-tier fan-out: one vmapped search over T stacked graphs.

    ``states`` is a GraphState pytree with [T, ...] leaves (from
    ``graph.stack_graphs``); every tier is searched with the same query
    batch in a single device step, so wall-clock no longer scales linearly
    in the number of RO snapshots.  Returns (ids [T,B,k], dists [T,B,k],
    hops [T,B], cmps [T,B]) — per-lane results bit-identical to running
    ``search`` tier by tier.
    """
    def one(st):
        return _search_impl(st, queries, cfg, k=k, L=L,
                            beam_width=beam_width)

    return jax.vmap(one)(states)


def build(vectors: np.ndarray | jax.Array, cfg: IndexConfig,
          batch: int = 256, passes: int = 1, seed: int = 0,
          shuffle: bool = True) -> GraphState:
    """Static build = streamed FreshVamana inserts (paper App. B: this is the
    *FreshVamana build*; ``passes=2`` adds the Vamana-style refinement pass).

    The batch size is capped at n//8: points inside one batch cannot see
    each other (quiescent-consistency window), so a single-batch build
    would degenerate to a star around the medoid."""
    n, d = vectors.shape
    assert n <= cfg.capacity and d == cfg.dim
    batch = max(16, min(batch, n // 8)) if n >= 32 else max(1, n // 2)
    vecs = jnp.asarray(vectors)
    state = empty_graph(cfg)
    state = state._replace(
        vectors=state.vectors.at[:n].set(vecs.astype(state.vectors.dtype)))
    # Entry point = medoid of the build set (active yet or not — vectors are
    # stored; medoid over the first n rows).
    mask = jnp.zeros((cfg.capacity,), bool).at[:n].set(True)
    start = medoid(state.vectors, mask)
    # Seed: the medoid point is active with no edges.
    state = state._replace(
        active=state.active.at[start].set(True),
        start=start, n_total=jnp.int32(n))

    rng = np.random.default_rng(seed)
    order = rng.permutation(n) if shuffle else np.arange(n)
    for pass_i in range(passes):
        reprune = pass_i > 0
        for lo in range(0, n, batch):
            sl = order[lo:lo + batch]
            pad = batch - len(sl)
            slots = np.concatenate([sl, np.full(pad, INVALID)]).astype(np.int32)
            bv = np.zeros((batch, d), np.float32)
            bv[:len(sl)] = np.asarray(vectors)[sl]
            state = insert(state, jnp.asarray(slots), jnp.asarray(bv), cfg,
                           reprune=reprune)
    return state


def brute_force(vectors: jax.Array, mask: jax.Array, queries: jax.Array,
                k: int) -> jax.Array:
    """Exact k-NN over masked rows — ground truth for every recall number."""
    from .distance import l2_sq_batch
    d = l2_sq_batch(queries, vectors)
    d = jnp.where(mask[None, :], d, jnp.inf)
    return jax.lax.top_k(-d, k)[1]


def recall_at_k(found_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """k-recall@k (Definition 1.1): |X ∩ G| / k averaged over queries."""
    k = true_ids.shape[1]
    eq = found_ids[:, :, None] == true_ids[:, None, :]
    inter = eq.any(axis=2) & (found_ids >= 0)
    return inter.sum(axis=1).mean() / k
