"""Atomic pytree checkpointing with restore-time re-sharding.

Layout: ``<dir>/step_<n>.tmp/`` is written (one .npy per leaf + a pickled
treedef manifest), fsync'd, then atomically renamed to ``step_<n>/`` —
a crash mid-write never corrupts the latest complete checkpoint.

Restore takes an optional pytree of NamedShardings built against the
*current* mesh, so a run can resume on a different device count (elastic
scale up/down): arrays are loaded on host and ``device_put`` against the new
sharding — re-sharding is free at restore time.

``AsyncCheckpointer`` runs the serialization on a background thread, double-
buffered, so training steps overlap the checkpoint write (the paper's
"background merge" discipline applied to training state).
"""
from __future__ import annotations

import os
import pickle
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def fsync_dir(path: str) -> None:
    """fsync a directory so renames/creations inside it are durable."""
    dfd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def commit_dir(tmp: str, final: str) -> str:
    """Atomically publish ``tmp`` as ``final``: fsync the staged directory,
    replace any previous ``final``, rename, fsync the parent.  A crash at
    any point leaves either the old complete directory or the new one —
    never a torn mix.  Shared by the checkpoint writer and the decoupled
    storage layout (``repro.storage.layout``)."""
    fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    fsync_dir(os.path.dirname(os.path.abspath(final)))
    return final


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Blocking atomic save; returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    host = [np.asarray(l) for l in leaves]
    for i, arr in enumerate(host):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(tmp, "manifest.pkl"), "wb") as f:
        pickle.dump({"treedef": treedef, "n_leaves": len(leaves),
                     "step": step}, f)
    return commit_dir(tmp, final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Load (tree, step).  With ``shardings`` (pytree of NamedSharding
    matching the checkpointed tree) each leaf is placed directly onto the
    current mesh — elastic re-sharding across device counts."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.pkl"), "rb") as f:
        manifest = pickle.load(f)
    leaves = [np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
              for i in range(manifest["n_leaves"])]
    tree = jax.tree.unflatten(manifest["treedef"], leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda arr, s: jax.device_put(arr, s), tree, shardings)
    return tree, step


class AsyncCheckpointer:
    """Double-buffered background checkpoint writer."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # snapshot before mutation

        def work():
            save_checkpoint(self.ckpt_dir, step, host)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.ckpt_dir)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:010d}"),
                          ignore_errors=True)
